"""L2 correctness: the batched Sinkhorn model vs oracle + OT theory.

Checks both flavors (pallas / xla) of the lowered program against the
slow per-pair reference, plus the structural properties the paper proves:
fixed-point marginals, symmetry, monotone convergence toward the exact
transportation cost, and the independence-table limit as lam -> 0.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _hists(rng, d, n):
    h = rng.gamma(1.0, 1.0, size=(d, n)).astype(np.float32) + 1e-6
    return jnp.asarray(h / h.sum(axis=0, keepdims=True))


def _metric(rng, d):
    pts = rng.normal(size=(d, max(2, d // 10)))
    m = np.linalg.norm(pts[:, None, :] - pts[None, :, :], axis=-1)
    m /= np.median(m[m > 0])
    return jnp.asarray(m, jnp.float32)


@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("d,n", [(16, 1), (16, 4), (32, 8)])
def test_batch_matches_ref(d, n, use_pallas):
    rng = np.random.default_rng(d * 1000 + n)
    m = _metric(rng, d)
    r, c = _hists(rng, d, n), _hists(rng, d, n)
    lam = jnp.float32(5.0)
    got, err = model.sinkhorn_batch(m, lam, r, c, iters=50, use_pallas=use_pallas)
    want, _ = ref.sinkhorn_distance(m, lam, r, c, 50)
    np.testing.assert_allclose(got, want, rtol=1e-5)
    assert float(err) < 1e-3


def test_batch_equals_per_pair():
    """Batched solve == N independent single-pair solves (no cross-talk)."""
    rng = np.random.default_rng(3)
    d, n = 20, 6
    m = _metric(rng, d)
    r, c = _hists(rng, d, n), _hists(rng, d, n)
    lam = jnp.float32(8.0)
    batched, _ = model.sinkhorn_batch(m, lam, r, c, iters=40, use_pallas=False)
    for j in range(n):
        single, _ = model.sinkhorn_batch(
            m, lam, r[:, j:j + 1], c[:, j:j + 1], iters=40, use_pallas=False)
        np.testing.assert_allclose(batched[j], single[0], rtol=1e-5)


def test_fixed_point_marginals():
    """After enough iterations diag(u) K diag(v) has marginals (r, c)."""
    rng = np.random.default_rng(11)
    d = 24
    m = _metric(rng, d)
    r, c = _hists(rng, d, 1), _hists(rng, d, 1)
    plan, _ = model.sinkhorn_plan(m, jnp.float32(6.0), r, c, iters=500)
    np.testing.assert_allclose(plan.sum(axis=1), r[:, 0], atol=1e-5)
    np.testing.assert_allclose(plan.sum(axis=0), c[:, 0], atol=1e-5)
    assert np.all(np.asarray(plan) >= 0)


def test_symmetry():
    """d_M^lam(r, c) == d_M^lam(c, r) for symmetric M (Theorem 1)."""
    rng = np.random.default_rng(5)
    d = 16
    m = _metric(rng, d)
    r, c = _hists(rng, d, 1), _hists(rng, d, 1)
    lam = jnp.float32(7.0)
    a, _ = model.sinkhorn_batch(m, lam, r, c, iters=300, use_pallas=False)
    b, _ = model.sinkhorn_batch(m, lam, c, r, iters=300, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_monotone_in_lambda(seed):
    """d_M^lam decreases (toward d_M) as lam grows — Fig. 3's premise."""
    rng = np.random.default_rng(seed)
    d = 12
    m = _metric(rng, d)
    r, c = _hists(rng, d, 1), _hists(rng, d, 1)
    prev = None
    for lam in [1.0, 3.0, 9.0, 27.0]:
        val, _ = model.sinkhorn_batch(
            m, jnp.float32(lam), r, c, iters=800, use_pallas=False)
        v = float(val[0])
        if prev is not None:
            assert v <= prev + 1e-5
        prev = v


def test_independence_limit():
    """As lam -> 0, the plan tends to r c^T and the cost to r^T M c
    (Property 2: the Independence kernel)."""
    rng = np.random.default_rng(9)
    d = 14
    m = _metric(rng, d)
    r, c = _hists(rng, d, 1), _hists(rng, d, 1)
    val, _ = model.sinkhorn_batch(
        m, jnp.float32(1e-4), r, c, iters=200, use_pallas=False)
    indep = float(r[:, 0] @ m @ c[:, 0])
    np.testing.assert_allclose(float(val[0]), indep, rtol=1e-3)


def test_plan_cost_equals_distance():
    rng = np.random.default_rng(2)
    d = 18
    m = _metric(rng, d)
    r, c = _hists(rng, d, 1), _hists(rng, d, 1)
    lam = jnp.float32(5.0)
    plan, dist = model.sinkhorn_plan(m, lam, r, c, iters=200)
    val, _ = model.sinkhorn_batch(m, lam, r, c, iters=200, use_pallas=False)
    np.testing.assert_allclose(float(dist), float(val[0]), rtol=1e-4)
    np.testing.assert_allclose(float(jnp.sum(plan * m)), float(dist), rtol=1e-6)
