"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (including non-power-of-two dims that force
1-wide blocks) and value scales; every case must match ``ref.py`` to
float32 tolerance.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import sinkhorn_step as kern


def _rand(rng, shape, scale):
    return jnp.asarray(np.abs(rng.normal(size=shape)) * scale + 1e-6, jnp.float32)


dims = st.sampled_from([1, 2, 3, 4, 7, 8, 12, 16, 20, 32, 48, 64, 100, 128])
batches = st.sampled_from([1, 2, 3, 5, 8, 16, 32])
scales = st.sampled_from([1e-3, 1.0, 1e3])


@settings(max_examples=40, deadline=None)
@given(d=dims, n=batches, scale=scales, seed=st.integers(0, 2**31 - 1))
def test_scaled_ratio_matches_ref(d, n, scale, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, (d, d), scale)
    x = _rand(rng, (d, n), scale)
    b = _rand(rng, (d, n), scale)
    got = kern.scaled_ratio(a, x, b)
    want = ref.scaled_ratio(a, x, b)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-30)


@settings(max_examples=40, deadline=None)
@given(d=dims, n=batches, scale=scales, seed=st.integers(0, 2**31 - 1))
def test_weighted_colsum_matches_ref(d, n, scale, seed):
    rng = np.random.default_rng(seed)
    km = _rand(rng, (d, d), scale)
    u = _rand(rng, (d, n), 1.0)
    v = _rand(rng, (d, n), 1.0)
    got = kern.weighted_colsum(km, u, v)
    want = jnp.sum(u * (km @ v), axis=0, keepdims=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-25)


@pytest.mark.parametrize("d,bd,bk", [(32, 8, 16), (32, 32, 8), (64, 16, 64)])
def test_explicit_block_shapes(d, bd, bk):
    """Non-default BlockSpecs produce identical results (tiling is sound)."""
    rng = np.random.default_rng(0)
    a = _rand(rng, (d, d), 1.0)
    x = _rand(rng, (d, 4), 1.0)
    b = _rand(rng, (d, 4), 1.0)
    got = kern.scaled_ratio(a, x, b, bd=bd, bn=4, bk=bk)
    want = ref.scaled_ratio(a, x, b)
    np.testing.assert_allclose(got, want, rtol=2e-5)


def test_zero_denominator_rows_are_inert():
    """Rows whose K v product is exactly 0 must give 0, not inf/nan."""
    d, n = 8, 3
    a = jnp.zeros((d, d), jnp.float32)
    x = jnp.ones((d, n), jnp.float32)
    b = jnp.ones((d, n), jnp.float32)
    got = kern.scaled_ratio(a, x, b)
    assert np.all(np.isfinite(np.asarray(got)))
    np.testing.assert_array_equal(np.asarray(got), np.zeros((d, n)))


def test_sinkhorn_step_composes():
    """The composed step matches one ref iteration end to end."""
    rng = np.random.default_rng(7)
    d, n = 24, 5
    k_mat = _rand(rng, (d, d), 1.0)
    r = _rand(rng, (d, n), 1.0)
    c = _rand(rng, (d, n), 1.0)
    v = _rand(rng, (d, n), 1.0)
    u, v_new = kern.sinkhorn_step(k_mat, k_mat.T, r, c, v)
    u_want = ref.scaled_ratio(k_mat, v, r)
    v_want = ref.scaled_ratio(k_mat.T, u_want, c)
    np.testing.assert_allclose(u, u_want, rtol=2e-5)
    np.testing.assert_allclose(v_new, v_want, rtol=2e-5)


def test_pick_block_divides():
    for dim in [1, 2, 5, 16, 20, 100, 400, 512, 1000]:
        b = kern.pick_block(dim)
        assert dim % b == 0
        assert b >= 1


def test_vmem_budget_at_serving_shapes():
    """Default blocks at the largest artifact shape fit a 16 MiB VMEM."""
    d, n = 4096, 64
    bd = kern.pick_block(d)
    bn = kern.pick_block(n)
    bk = kern.pick_block(d)
    assert kern.vmem_bytes(bd, bn, bk) <= 16 * 1024 * 1024
