"""AOT pipeline tests: lowering, manifest round-trip, HLO executability.

The last test closes the loop inside python: it parses the emitted HLO text
back into an XlaComputation, compiles it on the same CPU backend the Rust
side uses (PJRT), executes it, and compares to the oracle — i.e. the
artifact bytes themselves are validated, not just the tracing path.
"""

import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref


def test_lower_variant_smoke():
    text = aot.lower_variant(16, 2, 3, "xla")
    assert "HloModule" in text
    assert "f32[16,16]" in text


def test_lower_pallas_variant_smoke():
    text = aot.lower_variant(16, 1, 2, "pallas")
    assert "HloModule" in text


def test_manifest_written(tmp_path):
    out = str(tmp_path / "arts")
    rc = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", out,
         "--ds", "16", "--ns", "1", "--iters", "2", "--skip-pallas"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True)
    assert rc.returncode == 0, rc.stderr
    with open(os.path.join(out, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1
    assert len(man["variants"]) == 1
    v = man["variants"][0]
    assert (v["d"], v["n"], v["iters"], v["flavor"]) == (16, 1, 2, "xla")
    assert os.path.exists(os.path.join(out, v["file"]))
    # Idempotence: second run skips.
    rc2 = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", out,
         "--ds", "16", "--ns", "1", "--iters", "2", "--skip-pallas"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True)
    assert "up to date" in rc2.stdout


@pytest.mark.parametrize("flavor", ["xla", "pallas"])
def test_hlo_text_reparses(flavor):
    """The emitted text parses back through the same HLO text parser the
    Rust runtime uses (``HloModuleProto::from_text_file``), with the
    expected entry signature. Numeric validation of the artifact bytes is
    done by the Rust integration tests (`rust/tests/runtime_artifacts.rs`),
    the actual consumer."""
    d, n, iters = 16, 4, 5
    text = aot.lower_variant(d, n, iters, flavor)
    comp = xc._xla.hlo_module_from_text(text)  # raises on parse failure
    proto = comp.as_serialized_hlo_module_proto()
    assert len(proto) > 0
    # Entry layout: (M (d,d), lam scalar, R (d,n), C (d,n)) -> ((n,), ())
    assert f"f32[{d},{d}]" in text
    assert f"f32[{d},{n}]" in text
    assert f"(f32[{n}]" in text and "f32[])}" in text


def test_flavors_agree():
    """pallas- and xla-flavor artifacts encode the same function."""
    d, n, iters = 16, 2, 25
    rng = np.random.default_rng(1)
    pts = rng.normal(size=(d, 4))
    m = jnp.asarray(
        np.linalg.norm(pts[:, None, :] - pts[None, :, :], axis=-1), jnp.float32)
    h = rng.gamma(1.0, 1.0, size=(d, 2 * n)).astype(np.float32) + 1e-6
    h /= h.sum(axis=0, keepdims=True)
    r, c = jnp.asarray(h[:, :n]), jnp.asarray(h[:, n:])
    a, _ = model.sinkhorn_batch(m, jnp.float32(3.0), r, c, iters=iters,
                                use_pallas=True)
    b, _ = model.sinkhorn_batch(m, jnp.float32(3.0), r, c, iters=iters,
                                use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=1e-5)


def test_fingerprint_stable():
    assert aot.input_fingerprint() == aot.input_fingerprint()
