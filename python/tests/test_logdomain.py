"""L1 correctness for the log-domain (stabilized) Pallas kernel."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import logdomain, ref


def _hists(rng, d, n):
    h = rng.gamma(1.0, 1.0, size=(d, n)).astype(np.float32) + 1e-6
    return jnp.asarray(h / h.sum(axis=0, keepdims=True))


def _metric(rng, d):
    pts = rng.normal(size=(d, max(2, d // 10)))
    m = np.linalg.norm(pts[:, None, :] - pts[None, :, :], axis=-1)
    m /= np.median(m[m > 0])
    return jnp.asarray(m, jnp.float32)


dims = st.sampled_from([2, 4, 8, 12, 16, 24, 32])
batches = st.sampled_from([1, 2, 4, 8])


@settings(max_examples=25, deadline=None)
@given(d=dims, n=batches, seed=st.integers(0, 2**31 - 1))
def test_lse_update_matches_oracle(d, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(d, d)) * 5.0, jnp.float32)
    f = jnp.asarray(rng.normal(size=(d, n)) * 5.0, jnp.float32)
    logb = jnp.asarray(rng.normal(size=(d, n)), jnp.float32)
    got = logdomain.lse_update(a, f, logb)
    want = logdomain.ref_lse_update(a, f, logb)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_streaming_lse_is_stable_at_huge_scores():
    # Running-max form must not overflow where naive exp would.
    d, n = 8, 2
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(d, d)) * 200.0, jnp.float32)
    f = jnp.asarray(rng.normal(size=(d, n)) * 200.0, jnp.float32)
    logb = jnp.zeros((d, n), jnp.float32)
    got = np.asarray(logdomain.lse_update(a, f, logb))
    assert np.all(np.isfinite(got))
    want = np.asarray(logdomain.ref_lse_update(a, f, logb))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_logdomain_matches_dense_at_moderate_lambda():
    rng = np.random.default_rng(3)
    d, n, iters = 16, 3, 60
    m = _metric(rng, d)
    r, c = _hists(rng, d, n), _hists(rng, d, n)
    lam = jnp.float32(6.0)
    dense, _ = ref.sinkhorn_distance(m, lam, r, c, iters)
    logd, _, _ = logdomain.sinkhorn_logdomain(m, lam, r, c, iters=iters,
                                              use_pallas=False)
    np.testing.assert_allclose(logd, dense, rtol=1e-4)


def test_logdomain_pallas_matches_ref_path():
    rng = np.random.default_rng(5)
    d, n, iters = 16, 2, 25
    m = _metric(rng, d)
    r, c = _hists(rng, d, n), _hists(rng, d, n)
    lam = jnp.float32(9.0)
    a, _, _ = logdomain.sinkhorn_logdomain(m, lam, r, c, iters=iters,
                                           use_pallas=True)
    b, _, _ = logdomain.sinkhorn_logdomain(m, lam, r, c, iters=iters,
                                           use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=1e-4)


def test_logdomain_survives_extreme_lambda():
    # The dense kernel is all-zero off-diagonal here; the log-domain path
    # must stay finite and approach the exact assignment-like cost.
    rng = np.random.default_rng(7)
    d = 8
    m = _metric(rng, d)
    r, c = _hists(rng, d, 1), _hists(rng, d, 1)
    lam = jnp.float32(2000.0)
    dist, f, g = logdomain.sinkhorn_logdomain(m, lam, r, c, iters=300,
                                              use_pallas=False)
    assert np.all(np.isfinite(np.asarray(dist)))
    assert float(dist[0]) > 0.0
    # Dense reference is NaN/0 here — the whole point of stabilization.
    k = np.exp(-float(lam) * np.asarray(m))
    assert np.all(k[~np.eye(d, dtype=bool)] == 0.0)


def test_empty_bins_stay_inert():
    rng = np.random.default_rng(9)
    d = 8
    m = _metric(rng, d)
    rw = np.zeros((d, 1), np.float32)
    cw = np.zeros((d, 1), np.float32)
    rw[:4] = 0.25
    cw[4:] = 0.25
    dist, f, g = logdomain.sinkhorn_logdomain(
        m, jnp.float32(9.0), jnp.asarray(rw), jnp.asarray(cw), iters=100,
        use_pallas=False)
    assert np.isfinite(float(dist[0]))
    # Duals of empty bins pinned at the floor.
    assert np.all(np.asarray(f)[4:, 0] < -1e20)
    assert np.all(np.asarray(g)[:4, 0] < -1e20)
