import os
import sys

# Make `compile` importable when pytest is run from python/ or repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

os.environ.setdefault("JAX_PLATFORMS", "cpu")


def _importable(name):
    try:
        __import__(name)
        return True
    except Exception:
        return False


# Every test module in this directory imports the JAX/Pallas stack at
# collection time (three of them also need hypothesis). On runners
# without those dependencies (e.g. the Rust-focused CI image) the
# affected modules must *skip*, not error: ignoring them keeps collection
# clean, and pytest's "no tests collected" exit code 5 is treated as
# success by the CI job.
collect_ignore = []
if not all(_importable(m) for m in ("numpy", "jax")):
    collect_ignore_glob = ["test_*.py"]
elif not _importable("hypothesis"):
    collect_ignore = ["test_kernels.py", "test_logdomain.py", "test_model.py"]
