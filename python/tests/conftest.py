import os
import sys

# Make `compile` importable when pytest is run from python/ or repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

os.environ.setdefault("JAX_PLATFORMS", "cpu")
