"""Layer-2 JAX compute graph: the batched dual-Sinkhorn divergence.

This is the program that ``aot.py`` lowers (once, at build time) to the HLO
artifacts the Rust runtime executes. It strings the L1 Pallas kernels into
the full Algorithm 1 of Cuturi (2013):

    K  = exp(-lam * M)           (computed once, inside the graph)
    KM = K * M
    v0 = 1/d
    repeat `iters` times:        (lax.fori_loop -> a single fused HLO loop)
        u = R / (K  v)
        v = C / (K^T u)
    dist_j = sum_i u_ij (KM v)_ij
    err    = max_ij | u * (K v) - R |      (marginal-violation diagnostic)

Inputs are column stacks R, C of shape (d, N): N independent problems are
solved in one call — the paper's vectorized form, and the unit of batching
for the Layer-3 coordinator. A shared source histogram is expressed by
tiling r across R's columns on the Rust side (d*N floats, negligible).

``iters`` is a compile-time constant per artifact variant: the paper (§5.4)
recommends a fixed iteration budget on parallel platforms precisely because
device-side convergence tests are what kills throughput; we follow it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import sinkhorn_step as kern
from .kernels import ref


def sinkhorn_batch(m_mat, lam, r, c, *, iters: int, use_pallas: bool = True):
    """Batched dual-Sinkhorn divergence.

    Args:
      m_mat: (d, d) ground cost matrix.
      lam: scalar regularization weight (runtime input, not baked).
      r: (d, n) source histograms (columns).
      c: (d, n) target histograms (columns).
      iters: fixed number of fixed-point iterations (compile-time).
      use_pallas: route the inner products through the L1 Pallas kernel
        (interpret mode) or through plain jnp contractions. Both lower to
        valid HLO; artifacts are emitted in both flavors (see aot.py).

    Returns:
      (dist (n,), err scalar) — distances and max marginal violation.
    """
    d = m_mat.shape[0]
    k_mat = jnp.exp(-lam * m_mat)
    kt_mat = k_mat.T
    km = k_mat * m_mat
    ratio = kern.scaled_ratio if use_pallas else ref.scaled_ratio

    v0 = jnp.full_like(c, 1.0 / d)

    def body(_, v):
        u = ratio(k_mat, v, r)
        return ratio(kt_mat, u, c)

    v = lax.fori_loop(0, iters, body, v0)
    u = ratio(k_mat, v, r)

    if use_pallas:
        dist = kern.weighted_colsum(km, u, v)[0, :]
    else:
        dist = jnp.sum(u * (km @ v), axis=0)

    row = u * (k_mat @ v)
    err = jnp.max(jnp.abs(row - r))
    return dist, err


def sinkhorn_plan(m_mat, lam, r, c, *, iters: int):
    """Single-pair variant returning the full transport plan P^lam (d, d).

    Used by the Rust side when the caller asks for the plan itself (e.g.
    the Fig. 3 gap study needs <P, M> under both solvers, and tests check
    plan marginals).
    """
    k_mat = jnp.exp(-lam * m_mat)
    kt_mat = k_mat.T
    d = m_mat.shape[0]
    v0 = jnp.full_like(c, 1.0 / d)

    def body(_, v):
        u = kern.scaled_ratio(k_mat, v, r)
        return kern.scaled_ratio(kt_mat, u, c)

    v = lax.fori_loop(0, iters, body, v0)
    u = kern.scaled_ratio(k_mat, v, r)
    plan = (u * k_mat) * v[:, 0].reshape(1, -1)
    dist = jnp.sum(plan * m_mat)
    return plan, dist


def make_batch_fn(d: int, n: int, iters: int, use_pallas: bool):
    """Close over the static config; returns fn(M, lam, R, C) for jit/lower."""

    def fn(m_mat, lam, r, c):
        return sinkhorn_batch(m_mat, lam, r, c, iters=iters, use_pallas=use_pallas)

    fn.__name__ = f"sinkhorn_d{d}_n{n}_it{iters}_{'pallas' if use_pallas else 'xla'}"
    return fn


def example_args(d: int, n: int):
    """ShapeDtypeStructs for lowering a (d, n) variant."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((d, d), f32),
        jax.ShapeDtypeStruct((), f32),
        jax.ShapeDtypeStruct((d, n), f32),
        jax.ShapeDtypeStruct((d, n), f32),
    )
