"""Pure-jnp oracles for the Pallas kernels and the L2 Sinkhorn model.

These are the *correctness ground truth* for everything below them in the
stack: the Pallas kernels (``sinkhorn_step.py``) are checked against these
functions by pytest/hypothesis, and the Rust CPU engine is checked against
the AOT artifacts, which are themselves checked against these.

The iteration is Algorithm 1 of Cuturi (2013) in its standard two-update
form::

    u = r / (K v)          K  = exp(-lam * M)
    v = c / (K^T u)        KM = K * M   (elementwise)

    d_M^lam(r, c) = sum(u * (KM @ v))

All functions are batched: ``r`` and ``c`` are (d, N) column stacks, so one
call evaluates N independent regularized-transport problems (the paper's
"compute the distance between r and a family of histograms C" vectorized
form, Section 4.1).

When JAX is unavailable the module falls back to NumPy (the two APIs are
interchangeable for the operations used here). This keeps the oracle — and
``gen_fixtures.py``, which freezes its outputs into the golden fixtures the
Rust tests assert against — runnable on JAX-less machines, in full f64
precision (JAX would need ``jax_enable_x64``).
"""

from __future__ import annotations

try:
    import jax.numpy as jnp
except ImportError:  # pragma: no cover - the JAX-less fixture-gen path
    import numpy as jnp  # type: ignore[no-redef]


def scaled_ratio(a, x, b):
    """Oracle for the L1 kernel: ``b / (a @ x)`` with a safe denominator.

    a: (d, d), x: (d, n), b: (d, n) -> (d, n).

    Entries where ``a @ x`` underflows to zero produce 0 rather than inf so
    that zero-mass bins (paper Algorithm 1 line 1 drops them) stay inert.
    """
    den = a @ x
    return jnp.where(den > 0.0, b / jnp.where(den > 0.0, den, 1.0), 0.0)


def sinkhorn_iterate(k_mat, r, c, iters):
    """Run ``iters`` Sinkhorn-Knopp fixed-point iterations.

    Returns the pair of scaling matrices (u, v), each (d, n), such that
    ``diag(u_j) K diag(v_j)`` approximately has marginals (r_j, c_j).
    """
    v = jnp.ones_like(c) / c.shape[0]
    u = jnp.zeros_like(r)
    for _ in range(int(iters)):
        u = scaled_ratio(k_mat, v, r)
        v = scaled_ratio(k_mat.T, u, c)
    u = scaled_ratio(k_mat, v, r)
    return u, v


def sinkhorn_distance(m_mat, lam, r, c, iters):
    """Dual-Sinkhorn divergence d_M^lam for each column pair (r_j, c_j).

    Returns (distances (n,), max marginal violation scalar).
    """
    k_mat = jnp.exp(-lam * m_mat)
    km = k_mat * m_mat
    u, v = sinkhorn_iterate(k_mat, r, c, iters)
    dists = jnp.sum(u * (km @ v), axis=0)
    # Diagnostic: how far diag(u) K diag(v) is from marginal r (inf-norm).
    row = u * (k_mat @ v)
    err = jnp.max(jnp.abs(row - r))
    return dists, err


def transport_plan(m_mat, lam, r, c, iters):
    """Full optimal plan P^lam = diag(u) K diag(v) for a single pair.

    r, c: (d, 1). Returns (d, d).
    """
    k_mat = jnp.exp(-lam * m_mat)
    u, v = sinkhorn_iterate(k_mat, r, c, iters)
    return (u[:, 0:1] * k_mat) * v[:, 0].reshape(1, -1)
