"""Layer-1 Pallas kernels for the Sinkhorn-Knopp hot loop.

The per-iteration cost of Algorithm 1 (Cuturi, 2013) is entirely in two
matrix products against the kernel matrix ``K = exp(-lam*M)``::

    u = r / (K  v)
    v = c / (K^T u)

Both are instances of one primitive, ``scaled_ratio(a, x, b) = b / (a @ x)``,
which this module implements as a tiled Pallas kernel, plus a fused
``weighted_colsum(km, u, v) = sum(u * (km @ v), axis=0)`` used once at the
end to read off the distances.

TPU mapping: the paper targets 2013-era
GPGPU vectorization; on TPU the natural formulation is a GEMM on the MXU.
``a`` is tiled into (BD, BK) VMEM blocks, ``x``/``b`` into (BK, BN)/(BD, BN)
panels; the grid is (rows, batch, reduction) with the reduction innermost so
each output tile is accumulated in-place in VMEM and divided into ``b`` on
the final reduction step — i.e. the elementwise ratio is *fused* into the
matmul epilogue and never round-trips to HBM.

Kernels are executed with ``interpret=True`` everywhere in this repo: the
CPU PJRT plugin cannot run Mosaic custom-calls, so interpret mode is both
the correctness path (pytest vs ``ref.py``) and what ``aot.py`` lowers into
the artifacts. Real-TPU perf would come from the BlockSpec VMEM/MXU
sizing below, measured on actual hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block-shape policy. 128 matches the MXU systolic-array edge; fall back to
# smaller powers of two (still lane-aligned) for small or odd dimensions.
_CANDIDATE_BLOCKS = (256, 128, 64, 32, 16, 8, 4, 2, 1)


def pick_block(dim: int, cap: int = 256) -> int:
    """Largest candidate block size that divides ``dim`` (and is <= cap)."""
    for b in _CANDIDATE_BLOCKS:
        if b <= cap and dim % b == 0:
            return b
    return 1


def _scaled_ratio_kernel(a_ref, x_ref, b_ref, o_ref, *, nk: int):
    """One (i, j, k) grid step of ``o = b / (a @ x)``.

    o_ref accumulates the partial dot products across the k (reduction)
    dimension; on the last k step it is replaced by ``b / acc`` (guarded
    against zero denominators so empty histogram bins stay inert).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        den = o_ref[...]
        safe = jnp.where(den > 0.0, den, 1.0)
        o_ref[...] = jnp.where(den > 0.0, b_ref[...] / safe, 0.0)


@functools.partial(jax.jit, static_argnames=("bd", "bn", "bk"))
def scaled_ratio(a, x, b, bd: int = 0, bn: int = 0, bk: int = 0):
    """``b / (a @ x)`` as a Pallas kernel.

    a: (d, d), x: (d, n), b: (d, n) -> (d, n) float32.

    Block sizes default to the largest MXU-friendly divisors of (d, n, d).
    """
    d, d2 = a.shape
    _, n = x.shape
    bd = bd or pick_block(d)
    bn = bn or pick_block(n)
    bk = bk or pick_block(d2)
    nk = d2 // bk
    grid = (d // bd, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_scaled_ratio_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bd, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bd, bn), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((bd, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, n), jnp.float32),
        interpret=True,
    )(a, x, b)


def _weighted_colsum_kernel(km_ref, u_ref, v_ref, o_ref, *, nk: int, nd: int):
    """One (j, i, k) grid step of ``o_j = sum_i u_ij * (km @ v)_ij``.

    Grid order puts the batch dimension outermost so each (1, BN) output
    tile stays resident while the (i, k) reduction sweeps the matrix.
    """
    i = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((i == 0) & (k == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    part = jnp.dot(km_ref[...], v_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] += jnp.sum(u_ref[...] * part, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("bd", "bn", "bk"))
def weighted_colsum(km, u, v, bd: int = 0, bn: int = 0, bk: int = 0):
    """``sum(u * (km @ v), axis=0)`` -> (1, n), the distance read-off.

    km: (d, d) elementwise product K * M; u, v: (d, n).
    """
    d, d2 = km.shape
    _, n = u.shape
    bd = bd or pick_block(d)
    bn = bn or pick_block(n)
    bk = bk or pick_block(d2)
    nk = d2 // bk
    nd = d // bd
    grid = (n // bn, nd, nk)
    return pl.pallas_call(
        functools.partial(_weighted_colsum_kernel, nk=nk, nd=nd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bd, bk), lambda j, i, k: (i, k)),
            pl.BlockSpec((bd, bn), lambda j, i, k: (i, j)),
            pl.BlockSpec((bk, bn), lambda j, i, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda j, i, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=True,
    )(km, u, v)


def sinkhorn_step(k_mat, kt_mat, r, c, v):
    """One full Sinkhorn-Knopp iteration built from the L1 primitive.

    Returns (u, v_new). ``kt_mat`` is K^T, precomputed by the caller so the
    transpose is materialized once per problem rather than once per step.
    """
    u = scaled_ratio(k_mat, v, r)
    v_new = scaled_ratio(kt_mat, u, c)
    return u, v_new


def vmem_bytes(bd: int, bn: int, bk: int, bytes_per_el: int = 4) -> int:
    """Estimated VMEM working set of one scaled_ratio grid step.

    a-tile (bd, bk) + x-panel (bk, bn) + b/out panels (bd, bn) each,
    double-buffered inputs (x2) as the Mosaic pipeliner would.
    """
    tiles = 2 * (bd * bk + bk * bn) + 2 * (bd * bn)
    return tiles * bytes_per_el
