"""Layer-1 Pallas kernel for the log-domain (stabilized) Sinkhorn update.

For large λ the dense kernel K = e^{−λM} underflows (f32 past λ·m ≈ 88)
and Algorithm 1's ratios break down. The standard remedy iterates the
dual variables f = log u, g = log v with log-sum-exp reductions::

    g_j = log c_j − LSE_i(−λ m_ij + f_i)
    f_i = log r_i − LSE_j(−λ m_ij + g_j)

This module provides the tiled Pallas primitive for one such half-update:
``lse_update(a, f, logb) = logb − LSE_rows(a + f)`` where ``a`` is the
(−λM or −λMᵀ) matrix, ``f`` a (d, n) dual panel and ``logb`` the (d, n)
log-marginals. The reduction runs over row tiles with the running-max
streaming form of LSE, so the grid layout matches ``sinkhorn_step``'s and
the same VMEM budget analysis applies (one (BD, BK) matrix tile + two
(·, BN) panels resident).

Like every kernel in this repo it executes with ``interpret=True``; the
oracle is :func:`ref_lse_update` below (kept here because the ref module
is import-shared with the dense path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .sinkhorn_step import pick_block

NEG_INF = -1e30  # safe stand-in for -inf inside f32 kernels


def ref_lse_update(a, f, logb):
    """Oracle: ``logb - logsumexp(a + f[:, None, :] over rows)``.

    a: (d_out, d_in); f: (d_in, n); logb: (d_out, n) -> (d_out, n).
    """
    # scores[i, k, j] = a[i, k] + f[k, j]; LSE over k.
    scores = a[:, :, None] + f[None, :, :]
    lse = jax.scipy.special.logsumexp(scores, axis=1)
    return logb - lse


def _lse_kernel(a_ref, f_ref, logb_ref, o_ref, m_ref, s_ref, *, nk: int):
    """Streaming-LSE grid step over the k (reduction) dimension.

    Maintains per-(i, j) running max ``m`` and running scaled sum ``s``:
    on each k tile, new_max = max(m, max_k(score)), s = s * exp(m - new_max)
    + sum_k exp(score - new_max). Epilogue: o = logb - (new_max + log s).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)

    # scores: (bd, bk, bn)
    scores = a_ref[...][:, :, None] + f_ref[...][None, :, :]
    tile_max = jnp.max(scores, axis=1)
    new_max = jnp.maximum(m_ref[...], tile_max)
    correction = jnp.exp(m_ref[...] - new_max)
    tile_sum = jnp.sum(jnp.exp(scores - new_max[:, None, :]), axis=1)
    s_ref[...] = s_ref[...] * correction + tile_sum
    m_ref[...] = new_max

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = logb_ref[...] - (m_ref[...] + jnp.log(s_ref[...]))


@functools.partial(jax.jit, static_argnames=("bd", "bn", "bk"))
def lse_update(a, f, logb, bd: int = 0, bn: int = 0, bk: int = 0):
    """One log-domain half-update as a Pallas kernel.

    a: (d, d) = −λM (or its transpose); f: (d, n) duals;
    logb: (d, n) log-marginals. Returns (d, n) float32.
    """
    d_out, d_in = a.shape
    _, n = f.shape
    bd = bd or pick_block(d_out, cap=64)
    bn = bn or pick_block(n, cap=64)
    bk = bk or pick_block(d_in, cap=64)
    nk = d_in // bk
    grid = (d_out // bd, n // bn, nk)
    out, _, _ = pl.pallas_call(
        functools.partial(_lse_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bd, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bd, bn), lambda i, j, k: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bd, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bd, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bd, bn), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d_out, n), jnp.float32),
            jax.ShapeDtypeStruct((d_out, n), jnp.float32),  # running max
            jax.ShapeDtypeStruct((d_out, n), jnp.float32),  # running sum
        ],
        interpret=True,
    )(a, f, logb)
    return out


def sinkhorn_logdomain(m_mat, lam, r, c, *, iters: int, use_pallas: bool = True):
    """Full log-domain Sinkhorn: returns (distances (n,), f, g).

    Matches the dense path exactly in exact arithmetic but stays finite
    at any λ. Empty bins (r or c == 0) carry −inf log-marginals and stay
    inert (their duals remain at the NEG_INF floor).
    """
    d = m_mat.shape[0]
    neg_a = -lam * m_mat
    log_r = jnp.where(r > 0, jnp.log(jnp.maximum(r, 1e-38)), NEG_INF)
    log_c = jnp.where(c > 0, jnp.log(jnp.maximum(c, 1e-38)), NEG_INF)
    update = lse_update if use_pallas else ref_lse_update

    # Mirror ref.sinkhorn_iterate exactly: v0 = 1/d (g0 = −log d), then
    # alternate u-update / v-update, with a trailing u-update.
    g = jnp.full_like(c, -jnp.log(jnp.float32(d)))
    f = jnp.zeros_like(r)
    for _ in range(int(iters)):
        f = update(neg_a, g, log_r)
        g = update(neg_a.T, f, log_c)
    f = update(neg_a, g, log_r)

    # d = sum_ij m_ij exp(f_i - lam m_ij + g_j) per column.
    scores = neg_a[None, :, :] if False else None  # (avoid big temp; loop)
    del scores
    # Vectorized evaluation: exp(f[:,None,:] ... ) — build (d, d, n) once
    # at test scale; production read-off happens in the dense artifact.
    t = f[:, None, :] + neg_a[:, :, None] + g[None, :, :]
    plan = jnp.exp(t)
    dist = jnp.sum(plan * m_mat[:, :, None], axis=(0, 1))
    return dist, f, g
