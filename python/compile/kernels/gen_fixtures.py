"""Freeze ``ref.py`` oracle outputs into golden JSON fixtures for Rust.

Regenerates ``rust/tests/fixtures/ref_cases.json``: a handful of small,
deeply converged Sinkhorn problems whose distances the Rust solvers
(``log_domain::solve``, ``SinkhornEngine``) must reproduce to 1e-9. The
cases are solved far past convergence so the recorded value is the fixed
point itself, not an iteration-order-dependent stopping state — the Rust
engine updates (v, u) per iteration while ``ref.py`` updates (u, v), so
only the fixed point is comparable at that precision.

Deterministic: histograms and ground metrics come from a seeded legacy
``numpy.random.RandomState`` (bit-stable across NumPy versions). Run from
anywhere::

    python python/compile/kernels/gen_fixtures.py

and commit the refreshed fixture if the oracle intentionally changed.
"""

from __future__ import annotations

import importlib.util
import json
import pathlib

import numpy as np

try:
    # The oracle runs on jax.numpy when JAX is present; fixtures must be
    # full f64 (JAX defaults to f32), so flip x64 on before ref.py loads.
    import jax

    jax.config.update("jax_enable_x64", True)
except ImportError:  # pragma: no cover - NumPy fallback is f64 already
    pass

_HERE = pathlib.Path(__file__).resolve()
_SPEC = importlib.util.spec_from_file_location("sinkhorn_ref", _HERE.parent / "ref.py")
ref = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(ref)

FIXTURE_PATH = _HERE.parents[3] / "rust" / "tests" / "fixtures" / "ref_cases.json"

# (name, d, lambda, zero_bins): kept small so the deep solves are instant
# and the JSON stays reviewable.
CASES = [
    ("d3_lam2_uniformish", 3, 2.0, 0),
    ("d4_lam5", 4, 5.0, 0),
    ("d6_lam9", 6, 9.0, 0),
    ("d8_lam9_sparse", 8, 9.0, 2),
    ("d5_lam30_stiff", 5, 30.0, 0),
    ("d8_lam14", 8, 14.0, 1),
]

# Truncated-kernel case (name, d, lambda, threshold): the oracle solves
# against the *threshold-truncated* Gibbs kernel using the exact rule of
# Rust's linalg::SparseKernel::build — drop K_ij unless
# K_ij > min(threshold · rowmax_i, exp(-lambda · 0.9 · median(M_offdiag)))
# (strict >; rowmax_i = exp(-lambda·min_j m_ij) = 1 for zero-diagonal
# metrics; 0.9 is TRUNCATION_SAFE_RADIUS). Appended after CASES so the
# seeded RNG stream — and therefore every existing fixture — is
# unchanged.
TRUNCATED_CASE = ("d12_lam12_truncated", 12, 12.0, 1e-4)

ITERS = 6000
# The fixture asserts 1e-9 agreement; require the oracle itself to have
# settled two orders tighter than that.
SETTLE_TOL = 1e-11


def truncate_kernel(m: np.ndarray, lam: float, thr: float) -> np.ndarray:
    """Rust SparseKernel::build's kept set, as a masked dense kernel."""
    d = m.shape[0]
    off = m[~np.eye(d, dtype=bool)]
    radius_cut = np.exp(-lam * 0.9 * float(np.median(off)))
    k = np.exp(-lam * m)
    rowmax = np.exp(-lam * m.min(axis=1, keepdims=True))
    cut = np.minimum(thr * rowmax, radius_cut)
    # Guard the fixture against platform exp() ulp differences: no
    # kernel entry may sit so close to the cut that a 1-ulp shift flips
    # its membership (which would move the fixed point by ~threshold).
    gap = np.abs(k - cut) / cut
    assert gap.min() > 1e-9, f"entry within {gap.min():.2e} of the truncation cut"
    return np.where(k > cut, k, 0.0)


def metric(rng: np.random.RandomState, d: int) -> np.ndarray:
    """Symmetric zero-diagonal L1 ground metric over random planar points."""
    pts = rng.rand(d, 2)
    m = np.abs(pts[:, None, :] - pts[None, :, :]).sum(axis=-1)
    np.fill_diagonal(m, 0.0)
    return m


def histogram(rng: np.random.RandomState, d: int, zeros: int) -> np.ndarray:
    w = rng.dirichlet(np.ones(d))
    for _ in range(zeros):
        w[rng.randint(d)] = 0.0
    if w.sum() <= 0.0:
        w = np.ones(d)
    return w / w.sum()


def main() -> None:
    rng = np.random.RandomState(2013)
    cases = []
    for name, d, lam, zeros in CASES:
        m = metric(rng, d)
        r = histogram(rng, d, zeros)
        c = histogram(rng, d, zeros)
        dist_half, err_half = ref.sinkhorn_distance(
            m, lam, r[:, None], c[:, None], ITERS // 2
        )
        dist, err = ref.sinkhorn_distance(m, lam, r[:, None], c[:, None], ITERS)
        settle = abs(float(dist[0]) - float(dist_half[0]))
        assert settle < SETTLE_TOL, f"{name}: oracle not settled ({settle:.3e})"
        cases.append(
            {
                "name": name,
                "d": d,
                "lambda": lam,
                "iterations": ITERS,
                "m": [float(x) for x in m.ravel()],
                "r": [float(x) for x in r],
                "c": [float(x) for x in c],
                "distance": float(dist[0]),
                "marginal_err": float(err),
                "settle": settle,
            }
        )
    name, d, lam, thr = TRUNCATED_CASE
    m = metric(rng, d)
    r = histogram(rng, d, 0)
    c = histogram(rng, d, 0)
    kt = truncate_kernel(m, lam, thr)
    assert 0 < (kt > 0).sum() < d * d, "fixture truncation must actually bite"
    u_half, v_half = ref.sinkhorn_iterate(kt, r[:, None], c[:, None], ITERS // 2)
    dist_half = float((u_half * ((kt * m) @ v_half)).sum())
    u, v = ref.sinkhorn_iterate(kt, r[:, None], c[:, None], ITERS)
    dist = float((u * ((kt * m) @ v)).sum())
    settle = abs(dist - dist_half)
    assert settle < SETTLE_TOL, f"{name}: truncated oracle not settled ({settle:.3e})"
    marginal_err = max(
        float(np.abs(u * (kt @ v) - r[:, None]).max()),
        float(np.abs(v * (kt.T @ u) - c[:, None]).max()),
    )
    # A settled distance is not enough: an *infeasible* truncated support
    # (no plan with marginals (r, c) on the kept entries) collapses the
    # scalings and the collapsed state also "settles". Only a marginal-
    # feasible fixed point is a valid fixture.
    assert marginal_err < 1e-7, f"{name}: truncated support infeasible ({marginal_err:.3e})"
    cases.append(
        {
            "name": name,
            "d": d,
            "lambda": lam,
            "iterations": ITERS,
            "kernel": "truncated",
            "threshold": thr,
            "m": [float(x) for x in m.ravel()],
            "r": [float(x) for x in r],
            "c": [float(x) for x in c],
            "distance": dist,
            "marginal_err": marginal_err,
            "settle": settle,
        }
    )

    doc = {
        "version": 1,
        "generator": "python/compile/kernels/gen_fixtures.py",
        "oracle": "python/compile/kernels/ref.py sinkhorn_distance (f64)",
        "cases": cases,
    }
    FIXTURE_PATH.parent.mkdir(parents=True, exist_ok=True)
    FIXTURE_PATH.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"wrote {len(cases)} cases to {FIXTURE_PATH}")


if __name__ == "__main__":
    main()
