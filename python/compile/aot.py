"""AOT lowering: JAX/Pallas Sinkhorn program -> HLO text artifacts.

Build-time entry point (``make artifacts``). For each shape variant
(d, n, iters, flavor) this lowers ``model.sinkhorn_batch`` with
``jax.jit(...).lower(...)`` and converts the StableHLO module to an
XlaComputation, dumping **HLO text** to ``artifacts/<name>.hlo.txt``.

HLO *text* — not ``lowered.compile()`` nor serialized HloModuleProto — is
the interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which the Rust side's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly.

Alongside the HLO files it writes ``artifacts/manifest.json`` so the Rust
runtime can discover variants without any naming convention coupling::

    {"version": 1, "dtype": "f32",
     "variants": [{"name": ..., "file": ..., "d": ..., "n": ...,
                   "iters": ..., "flavor": "pallas"|"xla"}, ...]}

Flavors: ``pallas`` routes the inner products through the Layer-1 Pallas
kernel in interpret mode (the faithful three-layer stack — interpret mode
lowers the grid to HLO while-loops); ``xla`` emits the same math as plain
dot ops, which XLA:CPU turns into tight GEMM loops. Both are validated
against the same oracle; the runtime defaults to ``xla`` for the hot path
and keeps ``pallas`` for parity checks (see README.md §Architecture).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model

# Default variant grid. d=400 is the 20x20 MNIST grid; powers of two cover
# the Fig. 4/5 speed sweeps; n is the coordinator's batch-class ladder.
DEFAULT_DS = (16, 64, 128, 144, 256, 400, 512)
DEFAULT_NS = (1, 16, 64)
DEFAULT_ITERS = (20,)
# Pallas-flavored artifacts are emitted for a small parity subset: interpret
# mode lowers each grid step as an HLO loop iteration, so big-d pallas
# artifacts are slow to lower and only needed to prove the layers compose.
PALLAS_PARITY = ((16, 1), (16, 16), (64, 16))


def to_hlo_text(lowered) -> str:
    """StableHLO module -> XLA HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(d: int, n: int, iters: int, flavor: str) -> str:
    fn = model.make_batch_fn(d, n, iters, use_pallas=(flavor == "pallas"))
    lowered = jax.jit(fn).lower(*model.example_args(d, n))
    return to_hlo_text(lowered)


def input_fingerprint() -> str:
    """Hash of the compile-path sources, so `make` can skip stale-free runs."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        if "__pycache__" in root:
            continue
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--ds", type=int, nargs="*", default=list(DEFAULT_DS))
    ap.add_argument("--ns", type=int, nargs="*", default=list(DEFAULT_NS))
    ap.add_argument("--iters", type=int, nargs="*", default=list(DEFAULT_ITERS))
    ap.add_argument("--skip-pallas", action="store_true",
                    help="skip the pallas-flavor parity artifacts")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    fingerprint = input_fingerprint()
    want = {
        "ds": args.ds, "ns": args.ns, "iters": args.iters,
        "skip_pallas": args.skip_pallas,
    }
    if not args.force and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = json.load(f)
        if old.get("fingerprint") == fingerprint and old.get("config") == want:
            print(f"artifacts up to date ({manifest_path}); skipping")
            return 0

    variants = []
    jobs = [(d, n, it, "xla") for d in args.ds for n in args.ns
            for it in args.iters]
    if not args.skip_pallas:
        jobs += [(d, n, args.iters[0], "pallas") for (d, n) in PALLAS_PARITY]

    for d, n, iters, flavor in jobs:
        name = f"sinkhorn_d{d}_n{n}_it{iters}_{flavor}"
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        print(f"lowering {name} ...", flush=True)
        text = lower_variant(d, n, iters, flavor)
        with open(path, "w") as f:
            f.write(text)
        variants.append({
            "name": name, "file": fname, "d": d, "n": n, "iters": iters,
            "flavor": flavor, "bytes": len(text),
        })
        print(f"  wrote {len(text)} chars -> {path}")

    with open(manifest_path, "w") as f:
        json.dump({
            "version": 1, "dtype": "f32", "fingerprint": fingerprint,
            "config": want, "variants": variants,
        }, f, indent=1)
    print(f"wrote manifest with {len(variants)} variants -> {manifest_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
