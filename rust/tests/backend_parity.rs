//! Backend parity: every solve path — dense engine, log-domain
//! stabilized, interleaved batch, and the sharded thread-pool executor —
//! computes the *same* d_M^λ, to 1e-9, across seeded random simplex
//! pairs. At matched fixed iteration budgets all paths run the identical
//! fixed-point recursion, so disagreement beyond float accumulation
//! noise means a real bug (wrong transpose, column cross-talk, shard
//! mis-assembly, …).

use sinkhorn_rs::backend::{
    dense_kernel_degenerate, BackendKind, GreenkhornBackend, ShardedExecutor,
    SolverBackend,
};
use sinkhorn_rs::linalg::KernelPolicy;
use sinkhorn_rs::metric::{CostMatrix, RandomMetric};
use sinkhorn_rs::simplex::{seeded_rng, Histogram};
use sinkhorn_rs::sinkhorn::{LambdaSchedule, ScalingInit, SinkhornConfig, SinkhornEngine};
use sinkhorn_rs::F;

const TOL: F = 1e-9;

fn workload(d: usize, n: usize, seed: u64) -> (CostMatrix, Vec<Histogram>, Vec<Histogram>) {
    let mut rng = seeded_rng(seed);
    let m = RandomMetric::new(d).sample(&mut rng);
    let rs = (0..n).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
    let cs = (0..n).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
    (m, rs, cs)
}

fn assert_close(a: F, b: F, what: &str) {
    assert!(
        (a - b).abs() <= TOL * (1.0 + b.abs()),
        "{what}: {a} vs {b} (diff {:.3e})",
        (a - b).abs()
    );
}

/// Dense vs log-domain vs interleaved batch vs thread-pool executor at a
/// matched fixed budget, across seeds, dims and λ.
#[test]
fn all_paths_agree_on_fixed_budget() {
    for seed in 0..6u64 {
        let d = 8 + 2 * (seed as usize % 4);
        let (m, rs, cs) = workload(d, 7, seed);
        for &lambda in &[3.0, 9.0] {
            // 300 iterations: fully converged at these (d, λ), and every
            // path executes exactly the same recursion depth.
            let cfg = SinkhornConfig::fixed(lambda, 300);
            let dense = SinkhornEngine::with_config(&m, cfg);
            let log = BackendKind::LogDomain.build(&m, cfg);
            let inter = BackendKind::Interleaved.build(&m, cfg);
            let mut pool =
                ShardedExecutor::new(&m, cfg, BackendKind::Interleaved, 3);

            let r_refs: Vec<&Histogram> = rs.iter().collect();
            let inter_panel = inter.solve_paired(&r_refs, &cs, &[]);
            let (pool_panel, reports) = pool.solve_panel_paired(&r_refs, &cs);
            assert_eq!(pool_panel.len(), cs.len());
            assert!(reports.len() > 1, "panel of 7 must shard across workers");

            for j in 0..cs.len() {
                let want = dense.distance(&rs[j], &cs[j]).value;
                let ctx = format!("seed={seed} d={d} lambda={lambda} j={j}");
                assert_close(
                    log.solve(&rs[j], &cs[j], &ScalingInit::Cold).value,
                    want,
                    &format!("log-domain vs dense ({ctx})"),
                );
                assert_close(
                    inter_panel[j].value,
                    want,
                    &format!("interleaved vs dense ({ctx})"),
                );
                assert_close(
                    pool_panel[j].value,
                    want,
                    &format!("executor vs dense ({ctx})"),
                );
            }
        }
    }
}

/// The underflow-degenerate regime: λ·max(M) far beyond e^x range, where
/// the dense kernel is numerically diagonal. The dense engine
/// auto-stabilizes, the log-domain backend is exact by construction, and
/// the executor's auto router must pick the log-domain strategy — all
/// three paths still agree to 1e-9. (The raw interleaved walk is
/// excluded by design: its kernel is unusable here, which is exactly why
/// the router exists.)
#[test]
fn degenerate_lambda_paths_agree() {
    let lambda = 20_000.0;
    for seed in 0..4u64 {
        let (m, rs, cs) = workload(8, 4, 100 + seed);
        assert!(
            dense_kernel_degenerate(&m, lambda),
            "seed {seed}: workload must underflow at lambda={lambda}"
        );
        let cfg = SinkhornConfig::fixed(lambda, 400);
        let dense = SinkhornEngine::with_config(&m, cfg);
        assert!(dense.is_stabilized());
        let log = BackendKind::LogDomain.build(&m, cfg);
        let mut pool = ShardedExecutor::auto(&m, cfg, 2);
        assert_eq!(pool.kind(), BackendKind::LogDomain);

        let r_refs: Vec<&Histogram> = rs.iter().collect();
        let (pool_panel, _) = pool.solve_panel_paired(&r_refs, &cs);
        for j in 0..cs.len() {
            let want = dense.distance(&rs[j], &cs[j]).value;
            assert!(want.is_finite() && want >= 0.0);
            let out = dense.distance(&rs[j], &cs[j]);
            assert!(out.stats.stabilized, "dense path must have stabilized");
            assert_close(
                log.solve(&rs[j], &cs[j], &ScalingInit::Cold).value,
                want,
                &format!("log-domain vs stabilized dense (seed={seed} j={j})"),
            );
            assert_close(
                pool_panel[j].value,
                want,
                &format!("executor vs stabilized dense (seed={seed} j={j})"),
            );
        }
    }
}

/// Sharding is invisible: for every backend kind, the executor's panel
/// equals the same backend run sequentially, element by element.
#[test]
fn executor_is_transparent_for_every_kind() {
    let (m, rs, cs) = workload(10, 13, 7);
    let r_refs: Vec<&Histogram> = rs.iter().collect();
    let cfg = SinkhornConfig::fixed(6.0, 120);
    for kind in [
        BackendKind::Dense,
        BackendKind::LogDomain,
        BackendKind::Interleaved,
        BackendKind::Greenkhorn,
        BackendKind::Exact,
    ] {
        let sequential = kind.build(&m, cfg).solve_paired(&r_refs, &cs, &[]);
        let mut pool = ShardedExecutor::new(&m, cfg, kind, 4);
        let (sharded, reports) = pool.solve_panel_paired(&r_refs, &cs);
        assert_eq!(sharded.len(), sequential.len(), "{kind}");
        let attributed: usize = reports.iter().map(|s| s.queries).sum();
        assert_eq!(attributed, cs.len(), "{kind}: shard accounting");
        for (j, (a, b)) in sharded.iter().zip(&sequential).enumerate() {
            assert!(
                (a.value - b.value).abs() <= TOL * (1.0 + b.value.abs()),
                "{kind} j={j}: sharded {} vs sequential {}",
                a.value,
                b.value
            );
        }
    }
}

/// Convergence-driven (tolerance) configs land every backend on the same
/// fixed point; Greenkhorn takes a different route (greedy coordinate
/// updates) so it gets a looser — but still tight — band.
#[test]
fn converged_paths_agree() {
    let tight = SinkhornConfig {
        lambda: 7.0,
        tolerance: 1e-12,
        max_iterations: 300_000,
        ..SinkhornConfig::converged(7.0)
    };
    for seed in 0..4u64 {
        let (m, rs, cs) = workload(12, 3, 200 + seed);
        let dense = SinkhornEngine::with_config(&m, tight);
        let log = BackendKind::LogDomain.build(&m, tight);
        let green = GreenkhornBackend::new(&m, tight);
        for j in 0..cs.len() {
            let want = dense.distance(&rs[j], &cs[j]).value;
            let lg = log.solve(&rs[j], &cs[j], &ScalingInit::Cold).value;
            assert!(
                (lg - want).abs() <= 1e-8 * (1.0 + want),
                "seed={seed} j={j}: log-domain {lg} vs dense {want}"
            );
            let gk = green.solve(&rs[j], &cs[j], &ScalingInit::Cold).value;
            assert!(
                (gk - want).abs() <= 1e-6 * (1.0 + want),
                "seed={seed} j={j}: greenkhorn {gk} vs dense {want}"
            );
        }
    }
}

/// Degenerate-parameter parity: truncation threshold 0 keeps every
/// representable kernel entry and rank-d/tolerance-0 pivoted Cholesky
/// factors to numerical full rank, so both structured backends must
/// reproduce the dense interleaved walk to 1e-12 at a matched fixed
/// budget — any divergence beyond float noise means the structured
/// operator is not the identity-parameter limit it claims to be.
#[test]
fn zero_truncation_and_full_rank_reproduce_dense() {
    const DTOL: F = 1e-12;
    for seed in 0..4u64 {
        let d = 8 + 2 * (seed as usize % 3);
        let (m, rs, cs) = workload(d, 5, 300 + seed);
        for &lambda in &[3.0, 9.0] {
            let base = SinkhornConfig::fixed(lambda, 200);
            let dense = BackendKind::Interleaved.build(&m, base);

            let mut trunc_cfg = base;
            trunc_cfg.kernel = KernelPolicy::Truncated { threshold: 0.0 };
            let trunc = BackendKind::Truncated.build(&m, trunc_cfg);
            assert_eq!(trunc.kernel_stats().mass_loss, 0.0);

            let mut lr_cfg = base;
            lr_cfg.kernel = KernelPolicy::LowRank { max_rank: 0, tolerance: 0.0 };
            let lowrank = BackendKind::LowRank.build(&m, lr_cfg);
            assert_eq!(lowrank.kernel_stats().rank, d, "PD kernel factors fully");

            let r_refs: Vec<&Histogram> = rs.iter().collect();
            let want = dense.solve_paired(&r_refs, &cs, &[]);
            let got_t = trunc.solve_paired(&r_refs, &cs, &[]);
            let got_l = lowrank.solve_paired(&r_refs, &cs, &[]);
            for j in 0..cs.len() {
                let ctx = format!("seed={seed} d={d} lambda={lambda} j={j}");
                assert!(
                    (got_t[j].value - want[j].value).abs()
                        <= DTOL * (1.0 + want[j].value.abs()),
                    "truncated(0) vs dense ({ctx}): {} vs {}",
                    got_t[j].value,
                    want[j].value
                );
                assert!(
                    (got_l[j].value - want[j].value).abs()
                        <= DTOL * (1.0 + want[j].value.abs()),
                    "low-rank(full) vs dense ({ctx}): {} vs {}",
                    got_l[j].value,
                    want[j].value
                );
            }
        }
    }
}

/// The same degenerate parity *under ε-scaling*: with a Geometric
/// schedule every anneal stage runs at its own λ_s, so the structured
/// paths must rebuild their kernel per stage exactly like the dense
/// prefix does. A stale-kernel bug (reusing the λ★ operator — or any
/// single stage's — across the prefix) shifts the carried scaling and
/// the fixed-budget outcome by ~1e-3, which this 1e-12 gate cannot miss.
#[test]
fn structured_parity_survives_geometric_schedule() {
    const DTOL: F = 1e-12;
    for seed in 0..4u64 {
        let d = 10;
        let (m, rs, cs) = workload(d, 4, 400 + seed);
        // Fixed budget keeps the whole trajectory comparable (a
        // convergence check would hide prefix differences behind the
        // shared fixed point).
        let mut base = SinkhornConfig::fixed(9.0, 120);
        base.schedule = LambdaSchedule::geometric(1.0);
        let dense = BackendKind::Interleaved.build(&m, base);

        let mut trunc_cfg = base;
        trunc_cfg.kernel = KernelPolicy::Truncated { threshold: 0.0 };
        let trunc = BackendKind::Truncated.build(&m, trunc_cfg);

        let mut lr_cfg = base;
        lr_cfg.kernel = KernelPolicy::LowRank { max_rank: 0, tolerance: 0.0 };
        let lowrank = BackendKind::LowRank.build(&m, lr_cfg);

        let r_refs: Vec<&Histogram> = rs.iter().collect();
        let want = dense.solve_paired(&r_refs, &cs, &[]);
        let got_t = trunc.solve_paired(&r_refs, &cs, &[]);
        let got_l = lowrank.solve_paired(&r_refs, &cs, &[]);
        for j in 0..cs.len() {
            assert!(
                (got_t[j].value - want[j].value).abs()
                    <= DTOL * (1.0 + want[j].value.abs()),
                "seed={seed} j={j}: annealed truncated(0) {} vs dense {}",
                got_t[j].value,
                want[j].value
            );
            assert!(
                (got_l[j].value - want[j].value).abs()
                    <= DTOL * (1.0 + want[j].value.abs()),
                "seed={seed} j={j}: annealed low-rank(full) {} vs dense {}",
                got_l[j].value,
                want[j].value
            );
        }
    }
}

/// Greenkhorn parity against the dense engine on spiky (Dirichlet)
/// histograms — the workload the greedy rule is meant to like.
#[test]
fn greenkhorn_parity_on_spiky_histograms() {
    let mut rng = seeded_rng(31);
    let d = 14;
    let m = RandomMetric::new(d).sample(&mut rng);
    let cfg = SinkhornConfig {
        lambda: 9.0,
        tolerance: 1e-11,
        max_iterations: 300_000,
        ..SinkhornConfig::converged(9.0)
    };
    let dense = SinkhornEngine::with_config(&m, cfg);
    let green = GreenkhornBackend::new(&m, cfg);
    for _ in 0..5 {
        let r = Histogram::sample_dirichlet(d, 0.3, &mut rng);
        let c = Histogram::sample_dirichlet(d, 0.3, &mut rng);
        let want = dense.distance(&r, &c).value;
        let out = green.solve(&r, &c, &ScalingInit::Cold);
        assert!(out.stats.converged, "greenkhorn must converge");
        assert!(
            (out.value - want).abs() <= 1e-6 * (1.0 + want),
            "greenkhorn {} vs dense {want}",
            out.value
        );
    }
}
