//! Integration: the full Layer-3 service over the real PJRT runtime —
//! batched queries through the dynamic batcher, XLA execution, CPU
//! fallback for unserved dimensions, and agreement with the direct
//! engines.

use sinkhorn_rs::coordinator::{
    BatcherConfig, CoordinatorConfig, DistanceService, EngineKind, MetricId, Query,
};
use sinkhorn_rs::metric::RandomMetric;
use sinkhorn_rs::simplex::{seeded_rng, Histogram};
use sinkhorn_rs::sinkhorn::{SinkhornConfig, SinkhornEngine};
use std::time::Duration;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    // Artifacts may exist while the build has no PJRT backend linked
    // (the default runtime::pjrt shim): skip politely rather than panic.
    if let Err(e) = sinkhorn_rs::runtime::XlaRuntime::new(&dir) {
        eprintln!("skipping: XLA runtime unavailable ({e})");
        return None;
    }
    Some(dir)
}

fn service(dir: std::path::PathBuf, max_batch: usize, delay_ms: u64) -> DistanceService {
    DistanceService::start(CoordinatorConfig {
        artifact_dir: Some(dir),
        batcher: BatcherConfig {
            max_batch,
            max_delay: Duration::from_millis(delay_ms),
            ..BatcherConfig::default()
        },
        ..Default::default()
    })
    .expect("service start")
}

#[test]
fn xla_service_matches_cpu_engine() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = service(dir, 16, 2);
    let d = 64;
    let mut rng = seeded_rng(0);
    let metric = RandomMetric::new(d).sample(&mut rng);
    svc.register_metric(MetricId(0), metric.clone()).unwrap();

    let engine = SinkhornEngine::with_config(&metric, SinkhornConfig::fixed(9.0, 20));
    let queries: Vec<(Histogram, Histogram)> = (0..16)
        .map(|_| {
            (
                Histogram::sample_uniform(d, &mut rng),
                Histogram::sample_uniform(d, &mut rng),
            )
        })
        .collect();
    let rxs: Vec<_> = queries
        .iter()
        .map(|(r, c)| {
            svc.submit(Query::new(MetricId(0), 9.0, r.clone(), c.clone()))
            .unwrap()
        })
        .collect();
    for ((r, c), rx) in queries.iter().zip(rxs) {
        let res = rx.recv().unwrap().unwrap();
        assert_eq!(res.engine, EngineKind::Xla, "expected the XLA backend");
        let want = engine.distance(r, c).value;
        let rel = (res.distance() - want).abs() / want.max(1e-12);
        // f32 artifact vs f64 engine at 20 fixed iterations: ~1e-3 drift.
        assert!(rel < 1e-2, "service {} vs engine {want}", res.distance());
        assert!(res.batch_size >= 1);
    }
    let stats = svc.stats().unwrap();
    assert_eq!(stats.queries, 16);
    assert!(stats.xla_batches >= 1);
    assert_eq!(stats.errors, 0);
    svc.shutdown();
}

#[test]
fn unserved_dimension_falls_back_to_cpu() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = service(dir, 4, 1);
    // d=23 has no artifact; cpu_fallback=true must still serve it.
    let d = 23;
    let mut rng = seeded_rng(1);
    let metric = RandomMetric::new(d).sample(&mut rng);
    svc.register_metric(MetricId(1), metric.clone()).unwrap();
    let r = Histogram::sample_uniform(d, &mut rng);
    let c = Histogram::sample_uniform(d, &mut rng);
    let res = svc
        .distance(Query::new(MetricId(1), 9.0, r.clone(), c.clone()))
        .unwrap();
    assert_eq!(res.engine, EngineKind::Cpu);
    let want = SinkhornEngine::with_config(&metric, SinkhornConfig::fixed(9.0, 20))
        .distance(&r, &c)
        .value;
    assert!((res.distance() - want).abs() < 1e-12);
    svc.shutdown();
}

#[test]
fn mixed_classes_route_correctly() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = service(dir, 8, 2);
    let mut rng = seeded_rng(2);
    let m64 = RandomMetric::new(64).sample(&mut rng);
    let m23 = RandomMetric::new(23).sample(&mut rng);
    svc.register_metric(MetricId(0), m64).unwrap();
    svc.register_metric(MetricId(1), m23).unwrap();

    let mut rxs = Vec::new();
    for k in 0..24 {
        let (id, d) = if k % 2 == 0 { (MetricId(0), 64) } else { (MetricId(1), 23) };
        let lambda = if k % 3 == 0 { 9.0 } else { 4.0 };
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        rxs.push((id, svc.submit(Query::new(id, lambda, r, c)).unwrap()));
    }
    for (id, rx) in rxs {
        let res = rx.recv().unwrap().unwrap();
        let expect = if id == MetricId(0) { EngineKind::Xla } else { EngineKind::Cpu };
        assert_eq!(res.engine, expect, "metric {id:?}");
        assert!(res.distance().is_finite() && res.distance() > 0.0);
    }
    let stats = svc.stats().unwrap();
    assert_eq!(stats.queries, 24);
    assert!(stats.xla_batches >= 1 && stats.cpu_batches >= 1);
    svc.shutdown();
}

#[test]
fn warmup_precompiles_all_variants() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = service(dir, 4, 1);
    let compiled = svc.warmup().unwrap();
    assert!(compiled >= 3, "expected several xla variants, got {compiled}");
    svc.shutdown();
}

#[test]
fn bad_artifact_dir_fails_fast_without_cpu_fallback() {
    let err = DistanceService::start(CoordinatorConfig {
        artifact_dir: Some(std::path::PathBuf::from("/nonexistent/artifacts")),
        cpu_fallback: false,
        ..Default::default()
    })
    .err()
    .expect("must fail");
    assert!(err.to_string().contains("runtime failure"));
}

#[test]
fn bad_artifact_dir_falls_back_to_cpu_by_default() {
    // With cpu_fallback on (the default), an unusable artifact dir — or a
    // build whose runtime::pjrt shim has no backend — must not prevent
    // serving: the coordinator warns and runs CPU-only.
    let svc = DistanceService::start(CoordinatorConfig {
        artifact_dir: Some(std::path::PathBuf::from("/nonexistent/artifacts")),
        ..Default::default()
    })
    .expect("service must start CPU-only");
    let mut rng = seeded_rng(77);
    let d = 10;
    let metric = RandomMetric::new(d).sample(&mut rng);
    svc.register_metric(MetricId(0), metric).unwrap();
    let r = Histogram::sample_uniform(d, &mut rng);
    let c = Histogram::sample_uniform(d, &mut rng);
    let res = svc
        .distance(Query::new(MetricId(0), 9.0, r, c))
        .unwrap();
    assert_eq!(res.engine, EngineKind::Cpu);
    assert!(res.distance().is_finite() && res.distance() > 0.0);
    svc.shutdown();
}

#[test]
fn retrieval_path_serves_end_to_end_cpu_only() {
    // The serve_demo retrieval flow, smoke-tested without artifacts:
    // ingest a clustered corpus, serve top-k queries through the pruned
    // cascade, and read the prune/recall gauges back.
    use sinkhorn_rs::coordinator::{CorpusId, RetrievalQuery};
    use sinkhorn_rs::data::ClusteredCorpus;
    let mut config = CoordinatorConfig::cpu_only();
    config.cpu_workers = 2;
    config.retrieval_probe_every = 2;
    // Serve the corpus partitioned: global entry ids and results are
    // shard-count invariant, so every assertion below is unchanged from
    // the monolithic PR 4 version of this test.
    config.retrieval_shards = 3;
    config.retrieval_threads = 2;
    let svc = DistanceService::start(config).unwrap();
    let d = 20;
    let mut rng = seeded_rng(404);
    let metric = RandomMetric::new(d).sample(&mut rng);
    svc.register_metric(MetricId(0), metric).unwrap();
    // 4 clusters x 12 mixture entries.
    let gen = ClusteredCorpus::new(d, 4, 12, 0.15);
    let (corpus, protos) = gen.generate(&mut rng);
    let indexed = svc
        .register_corpus(CorpusId(0), MetricId(0), 9.0, corpus)
        .unwrap();
    assert_eq!(indexed, 48);
    for (qi, proto) in protos.iter().enumerate() {
        let q = gen.mixture_at(proto, 0.15, &mut rng);
        let out = svc
            .retrieve(RetrievalQuery { corpus: CorpusId(0), r: q, k: 3 })
            .unwrap();
        assert_eq!(out.hits.len(), 3, "query {qi}");
        assert!(out.hits.iter().all(|h| h.distance.is_finite() && h.distance >= 0.0));
        assert_eq!(out.report.solved + out.report.pruned, 48);
        // A near-prototype query's best match comes from its own cluster
        // block (the stronger all-of-top-k form holds on this seed too,
        // but top-1 is the claim that is robust by construction: 85% of
        // the query's mass is the prototype itself).
        let lo = qi * 12;
        let hi = lo + 12;
        let best = out.hits[0].entry;
        assert!(
            (lo..hi).contains(&best),
            "query {qi}: best hit {best} outside cluster block [{lo}, {hi})"
        );
        // Every probe must confirm the pruned answer exactly.
        if let Some(probe) = out.report.probe {
            assert_eq!(probe.matched, probe.k, "query {qi}: recall probe failed");
        }
    }
    let snap = svc.stats().unwrap();
    assert_eq!(snap.retrievals, 4);
    assert_eq!(snap.recall_probes, 2);
    assert!((snap.recall() - 1.0).abs() < 1e-12);
    assert!(
        snap.retrieval_pruned > 0,
        "clustered corpus must prune something: {snap}"
    );
    // PR 5 gauges: every search ran off the engine thread, and since
    // PR 8 the table is keyed per corpus — one row whose per-shard
    // gauges show the 3-way partition.
    assert_eq!(snap.retrieval_offthread, 4);
    assert!(snap.retrieval_search_max_us > 0);
    assert_eq!(snap.retrieval_queue_depth, 0);
    assert_eq!(snap.retrieval_shards.len(), 1, "{snap}");
    let row = &snap.retrieval_shards[0];
    assert_eq!(row.corpus, 0, "{snap}");
    assert_eq!(row.searches, 4, "{snap}");
    assert_eq!(row.shards.len(), 3, "{snap}");
    assert_eq!(row.shards.iter().map(|g| g.live).sum::<usize>(), 48);
    assert!(snap.to_string().contains("rsearch("));
    svc.shutdown();
}

#[test]
fn corpus_mutation_api_serves_incremental_updates_end_to_end() {
    use sinkhorn_rs::coordinator::{CorpusId, RetrievalQuery, ServiceError};
    use sinkhorn_rs::data::ClusteredCorpus;
    let mut config = CoordinatorConfig::cpu_only();
    config.cpu_workers = 2;
    config.retrieval_shards = 2;
    let svc = DistanceService::start(config).unwrap();
    let d = 16;
    let mut rng = seeded_rng(505);
    let metric = RandomMetric::new(d).sample(&mut rng);
    svc.register_metric(MetricId(0), metric).unwrap();
    let gen = ClusteredCorpus::new(d, 4, 8, 0.15);
    let (corpus, protos) = gen.generate(&mut rng);
    svc.register_corpus(CorpusId(0), MetricId(0), 9.0, corpus).unwrap();

    // Mutations against unknown corpora fail cleanly.
    let err = svc.corpus_insert(CorpusId(9), Histogram::uniform(d)).unwrap_err();
    assert!(matches!(err, ServiceError::UnknownCorpus(CorpusId(9))));
    assert!(svc.corpus_tombstone(CorpusId(9), 0).is_err());
    assert!(svc.corpus_compact(CorpusId(9)).is_err());

    // Insert an exact duplicate of the query: fresh corpus-global id,
    // immediately searchable, and (being a duplicate) the top hit.
    let q = gen.mixture_at(&protos[2], 0.15, &mut rng);
    let id = svc.corpus_insert(CorpusId(0), q.clone()).unwrap();
    assert_eq!(id, 32, "fresh id after the 32 seed entries");
    let out = svc
        .retrieve(RetrievalQuery { corpus: CorpusId(0), r: q.clone(), k: 3 })
        .unwrap();
    assert_eq!(out.report.corpus, 33);
    assert_eq!(out.hits[0].entry, id, "duplicate of the query must win top-1");

    // Tombstone it: gone from the next search; compaction reclaims the
    // slot and bumps the per-shard gauges.
    assert!(svc.corpus_tombstone(CorpusId(0), id).unwrap());
    assert!(!svc.corpus_tombstone(CorpusId(0), id).unwrap(), "already dead");
    let out = svc
        .retrieve(RetrievalQuery { corpus: CorpusId(0), r: q.clone(), k: 3 })
        .unwrap();
    assert_eq!(out.report.corpus, 32);
    assert!(out.hits.iter().all(|h| h.entry != id));
    let rebuilt = svc.corpus_compact(CorpusId(0)).unwrap();
    assert_eq!(rebuilt, 1, "exactly the insert's shard holds a tombstone");
    assert_eq!(svc.corpus_compact(CorpusId(0)).unwrap(), 0);
    let out = svc
        .retrieve(RetrievalQuery { corpus: CorpusId(0), r: q, k: 3 })
        .unwrap();
    assert_eq!(out.report.corpus, 32, "compaction does not change the view");

    let snap = svc.stats().unwrap();
    assert_eq!(snap.retrieval_shards.len(), 1, "{snap}");
    let row = &snap.retrieval_shards[0];
    assert_eq!(row.corpus, 0, "{snap}");
    assert_eq!(row.shards.len(), 2, "{snap}");
    assert_eq!(row.shards.iter().map(|g| g.live).sum::<usize>(), 32);
    assert_eq!(row.shards.iter().map(|g| g.compactions).sum::<u64>(), 1);
    assert_eq!(row.shards.iter().map(|g| g.inserts).sum::<u64>(), 1);
    assert_eq!(snap.errors, 3, "the three unknown-corpus mutations");
    assert!(snap.to_string().contains("corpora={"));

    // Metric replacement invalidates the corpus for subsequent jobs.
    let m2 = RandomMetric::new(d).sample(&mut rng);
    svc.register_metric(MetricId(0), m2).unwrap();
    let err = svc.corpus_insert(CorpusId(0), Histogram::uniform(d)).unwrap_err();
    assert!(matches!(err, ServiceError::UnknownCorpus(CorpusId(0))));
    svc.shutdown();
}

#[test]
fn throughput_improves_with_batching_on_xla() {
    // Ablation guard: the whole point of the coordinator. Same 64
    // queries, batch width 1 vs 16 — wide batching must not be slower.
    // (On the CPU PJRT backend the win is modest; the assertion is
    // deliberately loose to stay robust on a noisy shared core.)
    let Some(dir) = artifacts_dir() else { return };
    let d = 64;
    let mut rng = seeded_rng(3);
    let metric = RandomMetric::new(d).sample(&mut rng);
    let queries: Vec<(Histogram, Histogram)> = (0..64)
        .map(|_| {
            (
                Histogram::sample_uniform(d, &mut rng),
                Histogram::sample_uniform(d, &mut rng),
            )
        })
        .collect();

    let mut timings = Vec::new();
    for &batch in &[1usize, 16] {
        let svc = service(dir.clone(), batch, 1);
        svc.register_metric(MetricId(0), metric.clone()).unwrap();
        svc.warmup().unwrap();
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = queries
            .iter()
            .map(|(r, c)| {
                svc.submit(Query::new(MetricId(0), 9.0, r.clone(), c.clone()))
                .unwrap()
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        timings.push(t0.elapsed().as_secs_f64());
        svc.shutdown();
    }
    eprintln!("batch=1: {:.3}s, batch=16: {:.3}s", timings[0], timings[1]);
    assert!(
        timings[1] < timings[0] * 1.5,
        "batching regressed throughput: {timings:?}"
    );
}
