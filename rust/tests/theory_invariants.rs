//! The paper's theoretical claims, checked numerically across the whole
//! stack: Theorem 1 (Sinkhorn distances are quasi-metrics), Lemma 1 (the
//! gluing lemma with entropic constraint), Properties 1–2 (the λ→∞ and
//! α=0 limits), and the duality bridge between d_{M,α} and d_M^λ.

use sinkhorn_rs::metric::{is_metric_matrix, GridMetric, RandomMetric};
use sinkhorn_rs::ot::EmdSolver;
use sinkhorn_rs::simplex::{
    entropy, independence_table, kl_divergence, seeded_rng, Histogram,
};
use sinkhorn_rs::sinkhorn::{
    independence_distance, SinkhornConfig, SinkhornEngine,
};
use sinkhorn_rs::F;

fn converged_engine(m: &sinkhorn_rs::metric::CostMatrix, lambda: F) -> SinkhornEngine {
    SinkhornEngine::with_config(
        m,
        SinkhornConfig {
            lambda,
            tolerance: 1e-11,
            max_iterations: 500_000,
            ..Default::default()
        },
    )
}

/// Theorem 1: d_M^λ (the 1_{r≠c}-gated Sinkhorn distance) satisfies the
/// triangle inequality for metric M. We verify on random triplets, for
/// several λ, with the dual-Sinkhorn divergence standing in for d_{M,α}
/// (they share optima by duality). The paper proves it for d_{M,α};
/// numerically the inequality holds comfortably away from degeneracy.
#[test]
fn theorem1_triangle_inequality() {
    for seed in 0..6u64 {
        let mut rng = seeded_rng(seed);
        let d = 10 + (seed as usize % 5);
        let m = RandomMetric::new(d).sample(&mut rng);
        assert!(is_metric_matrix(&m, 1e-9).is_ok());
        let x = Histogram::sample_uniform(d, &mut rng);
        let y = Histogram::sample_uniform(d, &mut rng);
        let z = Histogram::sample_uniform(d, &mut rng);
        for lambda in [2.0, 9.0, 30.0] {
            let engine = converged_engine(&m, lambda);
            let dxy = engine.distance(&x, &y).value;
            let dyz = engine.distance(&y, &z).value;
            let dxz = engine.distance(&x, &z).value;
            assert!(
                dxz <= dxy + dyz + 1e-6,
                "triangle violated (seed {seed}, lambda {lambda}): {dxz} > {dxy}+{dyz}"
            );
        }
    }
}

/// Theorem 1 (symmetry half) on the digits workload.
#[test]
fn theorem1_symmetry_on_grid_metric() {
    let m = GridMetric::new(4, 4).cost_matrix();
    let mut rng = seeded_rng(7);
    let engine = converged_engine(&m, 9.0);
    for _ in 0..5 {
        let r = Histogram::sample_uniform(16, &mut rng);
        let c = Histogram::sample_uniform(16, &mut rng);
        let ab = engine.distance(&r, &c).value;
        let ba = engine.distance(&c, &r).value;
        assert!((ab - ba).abs() < 1e-7 * (1.0 + ab));
    }
}

/// Property 1: for λ large, d_M^λ → d_M (the exact transportation cost).
#[test]
fn property1_large_lambda_recovers_emd() {
    let mut rng = seeded_rng(3);
    let d = 12;
    let m = RandomMetric::new(d).sample(&mut rng);
    let r = Histogram::sample_uniform(d, &mut rng);
    let c = Histogram::sample_uniform(d, &mut rng);
    let exact = EmdSolver::new(&m).solve(&r, &c).unwrap().cost;
    let sk = converged_engine(&m, 300.0).distance(&r, &c).value;
    let rel = (sk - exact) / exact;
    assert!(rel >= -1e-9, "sinkhorn below exact: {rel}");
    assert!(rel < 0.01, "lambda=300 should be within 1% of EMD, got {rel}");
}

/// Property 2: as λ→0 the divergence approaches the independence value
/// rᵀMc, and the Cholesky fast path computes the same number.
#[test]
fn property2_small_lambda_recovers_independence_kernel() {
    let g = GridMetric::new(3, 3);
    let m2 = g.squared_cost_matrix();
    let mut rng = seeded_rng(5);
    let r = Histogram::sample_uniform(9, &mut rng);
    let c = Histogram::sample_uniform(9, &mut rng);
    let indep = independence_distance(&m2, &r, &c);
    let sk = converged_engine(&m2, 1e-5).distance(&r, &c).value;
    assert!(
        (sk - indep).abs() / indep < 1e-3,
        "lambda->0 limit: {sk} vs r'Mc {indep}"
    );
}

/// Lemma 1 (gluing with entropic constraint): glue the optimal plans of
/// (x,y) and (y,z); the composition S must lie in U(x,z) and satisfy
/// KL(S ‖ xzᵀ) ≤ max KL of its factors (data-processing inequality).
#[test]
fn lemma1_gluing_preserves_entropy_bound() {
    let mut rng = seeded_rng(9);
    let d = 10;
    let m = RandomMetric::new(d).sample(&mut rng);
    let x = Histogram::sample_uniform(d, &mut rng);
    let y = Histogram::sample_uniform(d, &mut rng);
    let z = Histogram::sample_uniform(d, &mut rng);
    let engine = converged_engine(&m, 8.0);
    let (p, _) = engine.plan(&x, &y);
    let (q, _) = engine.plan(&y, &z);

    // s_ik = sum_j p_ij q_jk / y_j.
    let yv = y.values();
    let mut s = vec![0.0; d * d];
    for i in 0..d {
        for k in 0..d {
            let mut acc = 0.0;
            for j in 0..d {
                if yv[j] > 0.0 {
                    acc += p[i * d + j] * q[j * d + k] / yv[j];
                }
            }
            s[i * d + k] = acc;
        }
    }
    // Marginals: S ∈ U(x, z).
    for i in 0..d {
        let row: F = s[i * d..(i + 1) * d].iter().sum();
        assert!((row - x.values()[i]).abs() < 1e-6, "row {i}");
    }
    for k in 0..d {
        let col: F = (0..d).map(|i| s[i * d + k]).sum();
        assert!((col - z.values()[k]).abs() < 1e-6, "col {k}");
    }
    // Entropic constraint: KL(S||xz') <= max(KL(P||xy'), KL(Q||yz')).
    let kl = |t: &[F], a: &Histogram, b: &Histogram| {
        kl_divergence(t, &independence_table(a.values(), b.values()))
    };
    let kl_s = kl(&s, &x, &z);
    let kl_p = kl(&p, &x, &y);
    let kl_q = kl(&q, &y, &z);
    assert!(
        kl_s <= kl_p.max(kl_q) + 1e-6,
        "gluing raised mutual information: {kl_s} > max({kl_p}, {kl_q})"
    );
}

/// The entropic smoothing is monotone in λ (the Lagrangian duality
/// picture of §4): the optimal plan's entropy h(P^λ) decreases and its
/// transport cost ⟨P^λ, M⟩ = d_M^λ decreases toward d_M as λ grows.
#[test]
fn duality_monotonicity_in_lambda() {
    let mut rng = seeded_rng(13);
    let d = 10;
    let m = RandomMetric::new(d).sample(&mut rng);
    let r = Histogram::sample_uniform(d, &mut rng);
    let c = Histogram::sample_uniform(d, &mut rng);
    let mut prev_entropy = F::INFINITY;
    let mut prev_cost = F::INFINITY;
    for lambda in [0.5, 2.0, 8.0, 32.0] {
        let engine = converged_engine(&m, lambda);
        let (plan, out) = engine.plan(&r, &c);
        let h = entropy(&plan);
        assert!(h <= prev_entropy + 1e-7, "entropy rose at lambda={lambda}");
        assert!(
            out.value <= prev_cost + 1e-7,
            "d^lambda rose at lambda={lambda}: {} > {prev_cost}",
            out.value
        );
        prev_entropy = h;
        prev_cost = out.value;
    }
}

/// h(P) ≥ (h(r)+h(c))/2 lower bound used in the proof of Property 1 is
/// loose but correct; the tight upper bound h(P) ≤ h(r)+h(c) must hold
/// for every plan the stack produces (exact or entropic).
#[test]
fn entropy_bounds_on_produced_plans() {
    let mut rng = seeded_rng(17);
    let d = 9;
    let m = RandomMetric::new(d).sample(&mut rng);
    let r = Histogram::sample_uniform(d, &mut rng);
    let c = Histogram::sample_uniform(d, &mut rng);
    let bound = entropy(r.values()) + entropy(c.values());

    // Entropic plan.
    let (p, _) = converged_engine(&m, 6.0).plan(&r, &c);
    assert!(entropy(&p) <= bound + 1e-8);

    // Exact vertex plan — lower entropy than the smoothed one.
    let exact = EmdSolver::new(&m).solve(&r, &c).unwrap();
    let dense = exact.to_dense();
    assert!(entropy(&dense) <= entropy(&p) + 1e-8);
    // And the vertex support bound (≤ 2d-1) keeps entropy ≤ log(2d-1).
    assert!(entropy(&dense) <= ((2 * d - 1) as F).ln() + 1e-9);
}

/// The dual-Sinkhorn divergence upper-bounds the exact distance at every
/// λ (the Fig. 3 premise), on the digits ground metric.
#[test]
fn dual_sinkhorn_dominates_emd_on_grid() {
    let m = GridMetric::new(4, 4).cost_matrix();
    let mut rng = seeded_rng(21);
    let solver = EmdSolver::new(&m);
    for _ in 0..4 {
        let r = Histogram::sample_uniform(16, &mut rng);
        let c = Histogram::sample_uniform(16, &mut rng);
        let exact = solver.solve(&r, &c).unwrap().cost;
        for lambda in [1.0, 9.0, 40.0] {
            let sk = converged_engine(&m, lambda).distance(&r, &c).value;
            assert!(sk >= exact - 1e-8, "lambda={lambda}: {sk} < {exact}");
        }
    }
}
