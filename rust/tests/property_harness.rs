//! Randomized property harness over every `BackendKind`.
//!
//! Zero-dependency property testing built on the in-tree xoshiro RNG:
//! each seeded case samples a random ground metric, λ and histogram pair
//! (uniform / spiky Dirichlet / sparse with zero-mass bins), then asserts
//! the invariants every solve strategy must share:
//!
//! * **feasibility** — the implied transport plan's marginals match
//!   (r, c) to 1e-7 at convergence;
//! * **symmetry** — d(r, c) = d(c, r) for the (symmetric) metrics;
//! * **non-negativity / finiteness** of the reported distance, and the
//!   paper's d_M^λ ≥ d_M lower bound against the exact network simplex;
//! * **monotone objective** — Sinkhorn and Greenkhorn updates are exact
//!   block-coordinate ascent on the concave entropic dual, so the convex
//!   dual-descent objective Φ(u, v) = (uᵀKv − r·log u − c·log v)/λ is
//!   monotone non-increasing along every trajectory (the raw transport
//!   cost read-off is *not* monotone — it typically climbs toward the
//!   fixed point from a cold start — which is why the harness tracks Φ);
//! * **warm-start / ε-scaling transparency** — seeding a solve with a
//!   cached scaling or annealing λ through a geometric schedule changes
//!   iteration counts, never the fixed point (agreement to 1e-7, with
//!   warm starts never taking more iterations than cold).
//!
//! Case count: 200 in release (what CI runs), trimmed in debug builds so
//! plain `cargo test` stays fast — debug-mode Sinkhorn over the full
//! sample is ~an order of magnitude slower for no extra coverage.

use sinkhorn_rs::backend::{BackendKind, SolverBackend};
use sinkhorn_rs::linalg::{KernelOp, KernelPolicy};
use sinkhorn_rs::metric::{CostMatrix, RandomMetric};
use sinkhorn_rs::ot::EmdSolver;
use sinkhorn_rs::rng::Rng;
use sinkhorn_rs::simplex::{seeded_rng, Histogram};
use sinkhorn_rs::sinkhorn::{LambdaSchedule, ScalingInit, SinkhornConfig, SolveBudget};
use sinkhorn_rs::F;

#[cfg(not(debug_assertions))]
const CASES: u64 = 200;
#[cfg(debug_assertions)]
const CASES: u64 = 32;

/// The iterative scaling strategies (Exact is covered separately: it has
/// no iteration trajectory or λ).
const SCALING_KINDS: [BackendKind; 4] = [
    BackendKind::Dense,
    BackendKind::LogDomain,
    BackendKind::Interleaved,
    BackendKind::Greenkhorn,
];

/// The kernel-structured strategies, with the policies the serving layer
/// uses: λ-adaptive default truncation and the near-exact low-rank
/// default. Their contract differs from the dense kinds in exactly one
/// place: the plan they serve lives on the *approximate* kernel K̃, so
/// feasibility is checked against K̃ (tolerance 1e-7 + the reported
/// mass-loss bound), and — when the truncated support makes a pair
/// infeasible (no plan with marginals (r, c) exists on the kept
/// entries) — the backend's documented rescue serves the exact
/// log-domain solution instead (`stats.stabilized` marks those).
const STRUCTURED_KINDS: [(BackendKind, KernelPolicy); 2] = [
    (
        BackendKind::Truncated,
        KernelPolicy::Truncated { threshold: 1e-6 },
    ),
    (
        BackendKind::LowRank,
        KernelPolicy::LowRank { max_rank: 0, tolerance: 1e-9 },
    ),
];

struct Case {
    m: CostMatrix,
    r: Histogram,
    c: Histogram,
    lambda: F,
    d: usize,
}

fn sample_histogram(d: usize, rng: &mut Rng) -> Histogram {
    let h = if rng.bool(0.3) {
        Histogram::sample_dirichlet(d, 0.3, rng)
    } else {
        Histogram::sample_uniform(d, rng)
    };
    if rng.bool(0.2) && d > 2 {
        // Sparse variant: knock out one bin (zero-mass bins exercise the
        // solvers' 0/0 guards and the −∞ potentials).
        let mut w = h.values().to_vec();
        w[rng.below(d)] = 0.0;
        if w.iter().filter(|&&x| x > 0.0).count() >= 2 {
            return Histogram::from_weights(&w).expect("renormalizable");
        }
    }
    h
}

fn sample_case(seed: u64) -> Case {
    let mut rng = seeded_rng(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
    let d = rng.range_usize(3, 15);
    let m = RandomMetric::new(d).sample(&mut rng);
    let lambda = rng.range_f64(2.0, 20.0);
    let r = sample_histogram(d, &mut rng);
    let c = sample_histogram(d, &mut rng);
    Case { m, r, c, lambda, d }
}

fn tight(lambda: F) -> SinkhornConfig {
    SinkhornConfig {
        lambda,
        tolerance: 1e-9,
        max_iterations: 200_000,
        ..Default::default()
    }
}

/// The implied plan P = diag(u) K diag(v), densely reconstructed.
fn plan_of(case: &Case, u: &[F], v: &[F]) -> Vec<F> {
    let d = case.d;
    let mut p = vec![0.0; d * d];
    for i in 0..d {
        for j in 0..d {
            p[i * d + j] = u[i] * (-case.lambda * case.m.get(i, j)).exp() * v[j];
        }
    }
    p
}

/// Convex dual-descent objective Φ(u, v) = (uᵀKv − r·log u − c·log v)/λ.
/// Every Sinkhorn row/column rescale and every Greenkhorn coordinate
/// rescale is an exact minimization of Φ in that block, so Φ is monotone
/// non-increasing along all trajectories. Zero-mass terms (r_i = 0)
/// contribute nothing by convention.
fn dual_descent_objective(case: &Case, u: &[F], v: &[F]) -> F {
    let d = case.d;
    let mut mass = 0.0;
    for i in 0..d {
        for j in 0..d {
            mass += u[i] * (-case.lambda * case.m.get(i, j)).exp() * v[j];
        }
    }
    let mut dual = 0.0;
    for i in 0..d {
        if case.r.values()[i] > 0.0 {
            dual += case.r.values()[i] * u[i].max(1e-300).ln();
        }
    }
    for j in 0..d {
        if case.c.values()[j] > 0.0 {
            dual += case.c.values()[j] * v[j].max(1e-300).ln();
        }
    }
    (mass - dual) / case.lambda
}

#[test]
fn prop_feasibility_symmetry_nonnegativity() {
    for seed in 0..CASES {
        let case = sample_case(seed);
        let exact = EmdSolver::new(&case.m)
            .solve(&case.r, &case.c)
            .expect("exact solve")
            .cost;
        for kind in SCALING_KINDS {
            let backend = kind.build(&case.m, tight(case.lambda));
            let out = backend.solve(&case.r, &case.c, &ScalingInit::Cold);
            assert!(out.stats.converged, "seed {seed} {kind}: did not converge");
            assert!(out.value.is_finite(), "seed {seed} {kind}: non-finite value");
            assert!(out.value >= -1e-12, "seed {seed} {kind}: negative {}", out.value);
            assert!(
                out.value >= exact - 1e-6,
                "seed {seed} {kind}: {} below exact EMD {exact}",
                out.value
            );

            // Transport-plan marginal feasibility to 1e-7.
            let p = plan_of(&case, &out.u, &out.v);
            for i in 0..case.d {
                let row: F = p[i * case.d..(i + 1) * case.d].iter().sum();
                assert!(
                    (row - case.r.values()[i]).abs() < 1e-7,
                    "seed {seed} {kind}: row {i} marginal off by {:.3e}",
                    (row - case.r.values()[i]).abs()
                );
            }
            for j in 0..case.d {
                let col: F = (0..case.d).map(|i| p[i * case.d + j]).sum();
                assert!(
                    (col - case.c.values()[j]).abs() < 1e-7,
                    "seed {seed} {kind}: col {j} marginal off by {:.3e}",
                    (col - case.c.values()[j]).abs()
                );
            }

            // Symmetry: the metric is symmetric, so d(r, c) = d(c, r).
            let flipped = backend.solve(&case.c, &case.r, &ScalingInit::Cold);
            assert!(
                (flipped.value - out.value).abs() < 1e-7 * (1.0 + out.value.abs()),
                "seed {seed} {kind}: asymmetric {} vs {}",
                out.value,
                flipped.value
            );
        }

        // The exact backend shares the symmetry/non-negativity contract
        // (its feasibility is checked on the simplex plan directly).
        let exact_backend = BackendKind::Exact.build(&case.m, tight(case.lambda));
        let fwd = exact_backend.solve(&case.r, &case.c, &ScalingInit::Cold);
        let bwd = exact_backend.solve(&case.c, &case.r, &ScalingInit::Cold);
        assert!(fwd.value >= -1e-12 && fwd.value.is_finite());
        assert!((fwd.value - bwd.value).abs() < 1e-7 * (1.0 + fwd.value.abs()));
        let plan = EmdSolver::new(&case.m).solve(&case.r, &case.c).unwrap();
        for (got, want) in plan.row_marginal().iter().zip(case.r.values()) {
            assert!((got - want).abs() < 1e-7, "seed {seed} exact: row marginal");
        }
        for (got, want) in plan.col_marginal().iter().zip(case.c.values()) {
            assert!((got - want).abs() < 1e-7, "seed {seed} exact: col marginal");
        }
    }
}

#[test]
fn prop_warm_and_annealed_agree_with_cold() {
    for seed in 0..CASES {
        let case = sample_case(seed);
        for kind in SCALING_KINDS {
            let backend = kind.build(&case.m, tight(case.lambda));
            let cold = backend.solve(&case.r, &case.c, &ScalingInit::Cold);
            assert!(cold.stats.converged, "seed {seed} {kind}: cold not converged");

            // Warm start from the cold fixed point: same value, and never
            // more iterations than the cold solve took.
            let seed_scaling = ScalingInit::from_output(&cold);
            let warm = backend.solve(&case.r, &case.c, &seed_scaling);
            assert!(warm.stats.converged, "seed {seed} {kind}: warm not converged");
            assert!(
                (warm.value - cold.value).abs() < 1e-7 * (1.0 + cold.value.abs()),
                "seed {seed} {kind}: warm {} vs cold {}",
                warm.value,
                cold.value
            );
            assert!(
                warm.stats.iterations <= cold.stats.iterations,
                "seed {seed} {kind}: warm took {} iterations vs cold {}",
                warm.stats.iterations,
                cold.stats.iterations
            );

            // ε-scaling: annealing λ changes the path, not the answer.
            let annealed_cfg = SinkhornConfig {
                schedule: LambdaSchedule::geometric(1.0),
                ..tight(case.lambda)
            };
            let annealed = kind
                .build(&case.m, annealed_cfg)
                .solve(&case.r, &case.c, &ScalingInit::Cold);
            assert!(
                annealed.stats.converged,
                "seed {seed} {kind}: annealed not converged"
            );
            assert!(
                (annealed.value - cold.value).abs() < 1e-7 * (1.0 + cold.value.abs()),
                "seed {seed} {kind}: annealed {} vs cold {}",
                annealed.value,
                cold.value
            );
        }
    }
}

/// The implied plan against an explicit kernel matrix (row-major d×d).
fn plan_of_kernel(d: usize, k: &[F], u: &[F], v: &[F]) -> Vec<F> {
    let mut p = vec![0.0; d * d];
    for i in 0..d {
        for j in 0..d {
            p[i * d + j] = u[i] * k[i * d + j] * v[j];
        }
    }
    p
}

/// Structured backends: feasibility (against the kernel they iterate
/// with), the d^λ ≥ d_M lower bound modulo the approximation budget,
/// symmetry, and non-negativity — the satellite contract of the
/// KernelOp refactor.
#[test]
fn prop_structured_feasibility_symmetry_bounds() {
    for seed in 0..CASES {
        let case = sample_case(seed);
        let exact = EmdSolver::new(&case.m)
            .solve(&case.r, &case.c)
            .expect("exact solve")
            .cost;
        for (kind, policy) in STRUCTURED_KINDS {
            let cfg = SinkhornConfig { kernel: policy, ..tight(case.lambda) };
            let backend = kind.build(&case.m, cfg);
            let stats = backend.kernel_stats();
            let out = backend.solve(&case.r, &case.c, &ScalingInit::Cold);
            // The rescue contract makes convergence total: either the
            // structured fixed point or the exact log-domain solution.
            assert!(out.stats.converged, "seed {seed} {kind}: did not converge");
            assert!(out.value.is_finite(), "seed {seed} {kind}: non-finite value");
            assert!(out.value >= -1e-12, "seed {seed} {kind}: negative {}", out.value);
            // d^λ ≥ d_M modulo the truncation budget: a converged
            // truncated plan is itself feasible (so ≥ d_M holds with
            // only solver slack); the low-rank kernel can carry tiny
            // negative entries, bounded by its reported budgets.
            let budget = 1e-6 + 16.0 * stats.mass_loss.max(stats.frobenius_budget);
            assert!(
                out.value >= exact - budget,
                "seed {seed} {kind}: {} below exact EMD {exact} (budget {budget:.3e})",
                out.value
            );

            // Feasibility of the *served* plan: marginal tolerance
            // 1e-7 + the kernel's mass-loss bound, checked against the
            // kernel the backend actually iterated with (the full
            // kernel when the rescue served the log-domain solution).
            let k_eff = if out.stats.stabilized {
                KernelPolicy::Dense
                    .build(case.m.data(), case.d, case.lambda)
                    .materialize()
            } else {
                policy.build(case.m.data(), case.d, case.lambda).materialize()
            };
            let p = plan_of_kernel(case.d, k_eff.data(), &out.u, &out.v);
            let feas_tol = 1e-7 + stats.mass_loss;
            for i in 0..case.d {
                let row: F = p[i * case.d..(i + 1) * case.d].iter().sum();
                assert!(
                    (row - case.r.values()[i]).abs() < feas_tol,
                    "seed {seed} {kind}: row {i} marginal off by {:.3e}",
                    (row - case.r.values()[i]).abs()
                );
            }
            for j in 0..case.d {
                let col: F = (0..case.d).map(|i| p[i * case.d + j]).sum();
                assert!(
                    (col - case.c.values()[j]).abs() < feas_tol,
                    "seed {seed} {kind}: col {j} marginal off by {:.3e}",
                    (col - case.c.values()[j]).abs()
                );
            }

            // Symmetry: K̃ inherits M's symmetry (symmetric truncation
            // pattern, L·Lᵀ factorization), so d(r, c) = d(c, r).
            let flipped = backend.solve(&case.c, &case.r, &ScalingInit::Cold);
            assert!(
                (flipped.value - out.value).abs() < 1e-7 * (1.0 + out.value.abs()),
                "seed {seed} {kind}: asymmetric {} vs {}",
                out.value,
                flipped.value
            );
        }
    }
}

/// Warm starts and ε-scaling stay transparent on the structured
/// backends: same fixed point, never more iterations warm than cold.
#[test]
fn prop_structured_warm_and_annealed_agree() {
    for seed in 0..CASES {
        let case = sample_case(seed);
        for (kind, policy) in STRUCTURED_KINDS {
            let cfg = SinkhornConfig { kernel: policy, ..tight(case.lambda) };
            let backend = kind.build(&case.m, cfg);
            let cold = backend.solve(&case.r, &case.c, &ScalingInit::Cold);
            assert!(cold.stats.converged, "seed {seed} {kind}: cold not converged");

            let seed_scaling = ScalingInit::from_output(&cold);
            let warm = backend.solve(&case.r, &case.c, &seed_scaling);
            assert!(warm.stats.converged, "seed {seed} {kind}: warm not converged");
            assert!(
                (warm.value - cold.value).abs() < 1e-7 * (1.0 + cold.value.abs()),
                "seed {seed} {kind}: warm {} vs cold {}",
                warm.value,
                cold.value
            );
            // No strict iteration bound here, unlike the dense kinds: on
            // an approximate kernel a warm start from the cold output can
            // take a couple of extra half-steps to re-enter the tolerance
            // band; the fixed-point agreement above is the contract.

            let annealed_cfg = SinkhornConfig {
                schedule: LambdaSchedule::geometric(1.0),
                ..cfg
            };
            let annealed = kind
                .build(&case.m, annealed_cfg)
                .solve(&case.r, &case.c, &ScalingInit::Cold);
            assert!(
                annealed.stats.converged,
                "seed {seed} {kind}: annealed not converged"
            );
            assert!(
                (annealed.value - cold.value).abs() < 1e-7 * (1.0 + cold.value.abs()),
                "seed {seed} {kind}: annealed {} vs cold {}",
                annealed.value,
                cold.value
            );
        }
    }
}

/// The acceptance bar of the KernelOp refactor: on the paper's
/// λ-quantile serving workload (median-normalized random metric,
/// λ ∈ {50, 100}, n ≥ 128) the default truncation policy streams fewer
/// than half the dense entries, reports a negligible mass loss, and the
/// backend still serves every query within the documented tolerances
/// (structured fast path when the sparse support admits a plan, exact
/// log-domain rescue when it does not).
#[test]
fn truncated_kernel_sparse_and_sound_at_serving_lambda() {
    // Full precision in release (what CI runs); debug keeps the identical
    // structural assertions but converges to a looser tolerance so plain
    // `cargo test` stays fast at d = 128.
    #[cfg(not(debug_assertions))]
    let (solve_tol, feas_base) = (1e-9, 1e-7);
    #[cfg(debug_assertions)]
    let (solve_tol, feas_base) = (1e-7, 1e-5);
    let d = 128;
    let mut rng = seeded_rng(0xD15C0);
    let m = RandomMetric::new(d).sample(&mut rng);
    for &lambda in &[50.0, 100.0] {
        // ε-scaling keeps the cold high-λ solves short (it changes the
        // path, never the fixed point — see the warm/annealed tests).
        let cfg = SinkhornConfig {
            kernel: KernelPolicy::truncated_default(),
            schedule: LambdaSchedule::geometric(1.0),
            tolerance: solve_tol,
            ..tight(lambda)
        };
        let backend = BackendKind::Truncated.build(&m, cfg);
        let stats = backend.kernel_stats();
        assert!(
            2 * stats.nnz < d * d,
            "lambda={lambda}: nnz {} not under 0.5·n²",
            stats.nnz
        );
        assert!(
            stats.mass_loss < 1e-6,
            "lambda={lambda}: serving truncation must lose negligible mass, got {}",
            stats.mass_loss
        );
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        let out = backend.solve(&r, &c, &ScalingInit::Cold);
        assert!(out.stats.converged, "lambda={lambda}: not converged");
        let k_eff = if out.stats.stabilized {
            KernelPolicy::Dense.build(m.data(), d, lambda).materialize()
        } else {
            cfg.kernel.build(m.data(), d, lambda).materialize()
        };
        let p = plan_of_kernel(d, k_eff.data(), &out.u, &out.v);
        let feas_tol = feas_base + stats.mass_loss;
        for i in 0..d {
            let row: F = p[i * d..(i + 1) * d].iter().sum();
            assert!(
                (row - r.values()[i]).abs() < feas_tol,
                "lambda={lambda}: row {i} marginal off"
            );
        }
        for j in 0..d {
            let col: F = (0..d).map(|i| p[i * d + j]).sum();
            assert!(
                (col - c.values()[j]).abs() < feas_tol,
                "lambda={lambda}: col {j} marginal off"
            );
        }
    }
}

/// The anytime certificate contract (PR 6), across the scaling backends,
/// both kernel-structured policies, and the exact simplex:
///
/// * **bracketing** — at every iteration budget, the certified interval
///   [lo, hi] contains the exact d^λ (proxied by a tightly-converged
///   log-domain solve, which is exact at any λ, within solver slack);
///   for the structured kinds the certificate is priced against the
///   *exact* cost matrix, so the same dense d^λ must land inside even
///   though the estimate tracks the approximate kernel;
/// * **monotone width** — budget slices nest on the CERT_STRIDE lattice
///   and per-slice certificates are intersected, so the interval width
///   never increases as the budget grows;
/// * **unbounded transparency** — `SolveBudget::Unbounded` is
///   bit-identical to the plain `solve` entry point (value, iteration
///   count, convergence flag), with the certificate computed once on
///   the final state.
#[test]
fn prop_interval_certificate_brackets_exact_value() {
    const BUDGETS: [usize; 4] = [8, 16, 32, 64];
    // The budget sweep re-solves each case several times per backend, so
    // sample every 4th case like the other trajectory-probing property.
    for seed in (0..CASES).step_by(4) {
        let case = sample_case(seed);
        // Exact d^λ proxy: the log-domain fixed point at tolerance 1e-9.
        let reference = BackendKind::LogDomain
            .build(&case.m, tight(case.lambda))
            .solve(&case.r, &case.c, &ScalingInit::Cold)
            .value;
        let slack = 1e-6 * (1.0 + reference.abs());

        let mut matrix: Vec<(BackendKind, KernelPolicy)> = SCALING_KINDS
            .iter()
            .map(|&k| (k, KernelPolicy::Dense))
            .collect();
        matrix.extend(STRUCTURED_KINDS);
        for (kind, policy) in matrix {
            let cfg = SinkhornConfig { kernel: policy, ..tight(case.lambda) };
            let backend = kind.build(&case.m, cfg);

            // Unbounded reproduces the plain solve bit-for-bit.
            let plain = backend.solve(&case.r, &case.c, &ScalingInit::Cold);
            let free = backend.solve_outcome(
                &case.r,
                &case.c,
                &ScalingInit::Cold,
                SolveBudget::Unbounded,
            );
            assert_eq!(
                free.estimate, plain.value,
                "seed {seed} {kind}: unbounded outcome diverges from solve"
            );
            assert_eq!(free.iterations, plain.stats.iterations, "seed {seed} {kind}");
            assert_eq!(free.converged, plain.stats.converged, "seed {seed} {kind}");
            assert!(
                free.interval.lo <= reference + slack
                    && reference <= free.interval.hi + slack,
                "seed {seed} {kind}: exact {reference} outside converged \
                 [{}, {}]",
                free.interval.lo,
                free.interval.hi
            );

            // Budget sweep: bracketing at every cut, width monotone.
            let mut prev_width = F::INFINITY;
            for &budget in &BUDGETS {
                let out = backend.solve_outcome(
                    &case.r,
                    &case.c,
                    &ScalingInit::Cold,
                    SolveBudget::Iterations(budget),
                );
                assert!(
                    out.interval.lo <= reference + slack
                        && reference <= out.interval.hi + slack,
                    "seed {seed} {kind} budget {budget}: exact {reference} \
                     outside [{}, {}]",
                    out.interval.lo,
                    out.interval.hi
                );
                let width = out.interval.width();
                assert!(
                    width <= prev_width + 1e-12 * (1.0 + prev_width.min(1e300)),
                    "seed {seed} {kind}: width grew from {prev_width} to \
                     {width} at budget {budget}"
                );
                prev_width = width;
            }
        }

        // The exact simplex certifies a zero-width interval at its own
        // answer, which also brackets the entropic value from below.
        let exact_backend = BackendKind::Exact.build(&case.m, tight(case.lambda));
        let out = exact_backend.solve_outcome(
            &case.r,
            &case.c,
            &ScalingInit::Cold,
            SolveBudget::Iterations(8),
        );
        assert_eq!(out.interval.width(), 0.0, "seed {seed}: exact not a point");
        assert!(
            out.interval.lo <= reference + slack,
            "seed {seed}: exact EMD {} above entropic {reference}",
            out.interval.lo
        );
    }
}

#[test]
fn prop_dual_objective_monotone_across_iterations() {
    // Trajectory probing re-solves at growing fixed budgets (deterministic
    // solvers retrace the same path), so sample every 4th case.
    const BUDGETS: [usize; 6] = [1, 2, 4, 8, 16, 32];
    for seed in (0..CASES).step_by(4) {
        let case = sample_case(seed);
        for kind in SCALING_KINDS {
            let mut prev: Option<F> = None;
            for &budget in &BUDGETS {
                let backend =
                    kind.build(&case.m, SinkhornConfig::fixed(case.lambda, budget));
                let out = backend.solve(&case.r, &case.c, &ScalingInit::Cold);
                let phi = dual_descent_objective(&case, &out.u, &out.v);
                assert!(phi.is_finite(), "seed {seed} {kind}: Φ not finite");
                if let Some(prev_phi) = prev {
                    assert!(
                        phi <= prev_phi + 1e-9 * (1.0 + prev_phi.abs()),
                        "seed {seed} {kind}: Φ rose from {prev_phi} to {phi} \
                         at budget {budget}"
                    );
                }
                prev = Some(phi);
            }
        }
    }
}
