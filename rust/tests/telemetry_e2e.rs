//! Integration: the PR 10 telemetry stack end-to-end — live Prometheus
//! scrapes over a real workload, windowed rollup decay, counter
//! monotonicity under concurrent traffic, and burn-rate-driven shedding
//! arming for a breaching tenant while a compliant tenant stays unshed.

use sinkhorn_rs::coordinator::{
    BatcherConfig, CoordinatorConfig, CorpusId, DistanceService, MetricId, Query,
    RetrievalQuery,
};
use sinkhorn_rs::metric::RandomMetric;
use sinkhorn_rs::simplex::{seeded_rng, Histogram};
use sinkhorn_rs::sinkhorn::SolveBudget;
use sinkhorn_rs::telemetry::{http_get, parse_exposition, SloPolicy, TelemetryConfig};
use sinkhorn_rs::util::json::Json;
use std::time::{Duration, Instant};

const D: usize = 12;
const SCRAPE_TIMEOUT: Duration = Duration::from_secs(5);

fn telemetry_service(
    max_batch: usize,
    window: Duration,
    windows: usize,
    slo: Option<SloPolicy>,
) -> DistanceService {
    let mut config = CoordinatorConfig::cpu_only();
    config.batcher = BatcherConfig {
        max_batch,
        max_delay: Duration::from_millis(1),
        ..BatcherConfig::default()
    };
    config.cpu_iterations = 60;
    config.telemetry = Some(TelemetryConfig {
        bind: "127.0.0.1:0".into(),
        window,
        windows,
        slo,
    });
    DistanceService::start(config).unwrap()
}

fn register_metric(svc: &DistanceService, id: u32, seed: u64) {
    let mut rng = seeded_rng(seed);
    let m = RandomMetric::new(D).sample(&mut rng);
    svc.register_metric(MetricId(id), m).unwrap();
}

fn pair(rng: &mut sinkhorn_rs::rng::Rng) -> (Histogram, Histogram) {
    (Histogram::sample_uniform(D, rng), Histogram::sample_uniform(D, rng))
}

#[test]
fn telemetry_off_by_default_serves_without_a_scrape_server() {
    let mut config = CoordinatorConfig::cpu_only();
    config.batcher = BatcherConfig {
        max_batch: 4,
        max_delay: Duration::from_millis(1),
        ..BatcherConfig::default()
    };
    let svc = DistanceService::start(config).unwrap();
    assert!(svc.scrape_addr().is_none(), "no telemetry config, no server");
    register_metric(&svc, 0, 1);
    let mut rng = seeded_rng(2);
    for _ in 0..4 {
        let (r, c) = pair(&mut rng);
        svc.distance(Query::new(MetricId(0), 9.0, r, c)).unwrap();
    }
    let snap = svc.stats().unwrap();
    assert_eq!(snap.queries, 4);
    assert_eq!(snap.errors, 0);
    svc.shutdown();
}

/// The monotonicity contract documented on `StatsSnapshot`: every plain
/// counter field is nondecreasing across successive snapshots taken
/// while client threads are actively submitting.
#[test]
fn snapshot_counters_are_monotone_under_live_traffic() {
    let svc = telemetry_service(4, Duration::from_millis(50), 4, None);
    register_metric(&svc, 0, 3);
    let mut handles = Vec::new();
    for t in 0..3u64 {
        let client = svc.client();
        handles.push(std::thread::spawn(move || {
            let mut rng = seeded_rng(10 + t);
            for _ in 0..30 {
                let (r, c) = pair(&mut rng);
                client.distance(Query::new(MetricId(0), 9.0, r, c)).unwrap();
            }
        }));
    }
    let mut prev: Option<Vec<u64>> = None;
    for _ in 0..40 {
        let s = svc.stats().unwrap();
        let counters = vec![
            s.queries,
            s.batches,
            s.xla_batches,
            s.cpu_batches,
            s.errors,
            s.warm_hits,
            s.warm_misses,
            s.retrievals,
            s.deadline_misses,
            s.budget_sheds,
            s.certified_solves,
        ];
        if let Some(prev) = &prev {
            for (i, (a, b)) in prev.iter().zip(&counters).enumerate() {
                assert!(b >= a, "counter #{i} regressed: {a} -> {b}");
            }
        }
        prev = Some(counters);
        std::thread::sleep(Duration::from_millis(2));
    }
    for h in handles {
        h.join().unwrap();
    }
    let snap = svc.stats().unwrap();
    assert_eq!(snap.queries, 90);
    assert_eq!(snap.errors, 0);
    svc.shutdown();
}

/// Satellite 4's live half: after a two-corpus workload, a real HTTP
/// scrape of `/metrics` parses as Prometheus v0.0.4 and carries the
/// per-tenant series; `/healthz` and `/snapshot` serve valid JSON;
/// `/slo` serves the windowed report.
#[test]
fn live_metrics_scrape_serves_per_tenant_series() {
    let svc = telemetry_service(2, Duration::from_secs(10), 4, None);
    let addr = svc.scrape_addr().expect("telemetry on binds a scrape server");
    register_metric(&svc, 0, 4);
    register_metric(&svc, 1, 5);
    let mut rng = seeded_rng(6);
    for id in [0u32, 1] {
        let entries: Vec<Histogram> =
            (0..24).map(|_| Histogram::sample_uniform(D, &mut rng)).collect();
        svc.register_corpus(CorpusId(id), MetricId(id), 9.0, entries).unwrap();
    }
    for id in [0u32, 1] {
        for _ in 0..4 {
            let (r, c) = pair(&mut rng);
            svc.distance(Query::new(MetricId(id), 9.0, r, c)).unwrap();
        }
        for _ in 0..3 {
            let q = Histogram::sample_uniform(D, &mut rng);
            let out = svc
                .retrieve(RetrievalQuery { corpus: CorpusId(id), r: q, k: 4 })
                .unwrap();
            assert_eq!(out.hits.len(), 4);
        }
    }

    let (status, body) = http_get(addr, "/metrics", SCRAPE_TIMEOUT).unwrap();
    assert_eq!(status, 200, "{body}");
    let lines = parse_exposition(&body).unwrap();
    assert!(!lines.is_empty());
    for needle in [
        "sinkhorn_queries_total 8",
        "sinkhorn_tenant_queries_total{tenant=\"m0\"} 4",
        "sinkhorn_tenant_queries_total{tenant=\"m1\"} 4",
        "sinkhorn_tenant_searches_total{tenant=\"c0\"} 3",
        "sinkhorn_tenant_searches_total{tenant=\"c1\"} 3",
        "sinkhorn_corpus_searches_total{tenant=\"c0\"} 3",
        "sinkhorn_corpus_searches_total{tenant=\"c1\"} 3",
        "sinkhorn_tenant_latency_us_bucket{tenant=\"m0\",le=\"+Inf\"} 4",
        "sinkhorn_retrievals_total 6",
    ] {
        assert!(body.contains(needle), "missing `{needle}` in:\n{body}");
    }

    let (status, health) = http_get(addr, "/healthz", SCRAPE_TIMEOUT).unwrap();
    assert_eq!(status, 200);
    let health = Json::parse(&health).unwrap();
    assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"));
    let retrieval = health.get("retrieval").expect("retrieval block");
    assert_eq!(retrieval.get("spawned").and_then(Json::as_bool), Some(true));
    assert_eq!(
        retrieval.get("corpora").and_then(Json::as_array).map(|a| a.len()),
        Some(2)
    );

    let (status, snap) = http_get(addr, "/snapshot", SCRAPE_TIMEOUT).unwrap();
    assert_eq!(status, 200);
    let snap = Json::parse(&snap).unwrap();
    assert_eq!(snap.get("queries").and_then(Json::as_f64), Some(8.0));
    assert_eq!(snap.get("retrievals").and_then(Json::as_f64), Some(6.0));

    let (status, slo) = http_get(addr, "/slo", SCRAPE_TIMEOUT).unwrap();
    assert_eq!(status, 200);
    assert!(slo.contains("slo_window(n=4)"), "{slo}");
    assert!(slo.contains("m0(q=4"), "{slo}");
    assert!(slo.contains("c1(s=3"), "{slo}");

    let (status, _) = http_get(addr, "/nope", SCRAPE_TIMEOUT).unwrap();
    assert_eq!(status, 404);
    svc.shutdown();
}

/// Acceptance criterion: the windowed deadline-miss rate demonstrably
/// decays to zero within N windows after the misses stop.
#[test]
fn windowed_miss_rate_decays_after_load_stops() {
    let svc = telemetry_service(
        1,
        Duration::from_millis(60),
        3,
        Some(SloPolicy::default()),
    );
    let addr = svc.scrape_addr().unwrap();
    register_metric(&svc, 0, 7);
    let mut rng = seeded_rng(8);
    for _ in 0..10 {
        let (r, c) = pair(&mut rng);
        svc.distance(
            Query::new(MetricId(0), 9.0, r, c)
                .with_budget(SolveBudget::Deadline(Instant::now())),
        )
        .unwrap();
    }
    let (_, before) = http_get(addr, "/slo", SCRAPE_TIMEOUT).unwrap();
    assert!(
        before.contains("miss_rate=1.000"),
        "expected a saturated windowed miss rate, got: {before}"
    );
    // Let every ring slot age out (3 windows x 60ms, plus slack), then
    // the same windowed view must read clean — cumulative totals keep
    // the misses, the rollups forget them.
    std::thread::sleep(Duration::from_millis(400));
    let (_, after) = http_get(addr, "/slo", SCRAPE_TIMEOUT).unwrap();
    assert!(
        after.contains("m0(q=0 miss=0 miss_rate=0.000"),
        "windowed miss rate should decay to 0, got: {after}"
    );
    let snap = svc.stats().unwrap();
    assert_eq!(snap.deadline_misses, 10, "cumulative counters never decay");
    svc.shutdown();
}

/// Acceptance criterion: a tenant breaching its latency SLO trips the
/// burn-rate gauges and arms policy-driven shedding — its next batches
/// run under the policy's iteration cap — while a compliant tenant on
/// the same service keeps its full iteration budget.
#[test]
fn breaching_tenant_is_shed_while_compliant_tenant_is_not() {
    const CAP: usize = 8;
    // The latency objective is deliberately generous: the breaching
    // tenant's bad events come from its expired deadlines alone, so a
    // slow CI machine can never accidentally arm the compliant tenant.
    let policy = SloPolicy {
        p99_latency: Duration::from_secs(1),
        shed_iterations: Some(CAP),
        ..SloPolicy::default()
    };
    let svc =
        telemetry_service(1, Duration::from_millis(200), 4, Some(policy));
    let addr = svc.scrape_addr().unwrap();
    register_metric(&svc, 0, 9);
    register_metric(&svc, 1, 10);
    let mut rng = seeded_rng(11);

    // Tenant m0 burns its error budget: every query carries an already
    // expired deadline, so each served answer is a bad event.
    for _ in 0..10 {
        let (r, c) = pair(&mut rng);
        svc.distance(
            Query::new(MetricId(0), 9.0, r, c)
                .with_budget(SolveBudget::Deadline(Instant::now())),
        )
        .unwrap();
    }

    // m0's next unbounded query is shed to the policy cap...
    let (r, c) = pair(&mut rng);
    let shed = svc.distance(Query::new(MetricId(0), 9.0, r, c)).unwrap();
    assert!(
        shed.outcome.iterations <= CAP,
        "armed tenant should run under the {CAP}-iteration cap, ran {}",
        shed.outcome.iterations
    );
    // ...while the compliant tenant m1 keeps the full budget.
    let (r, c) = pair(&mut rng);
    let clean = svc.distance(Query::new(MetricId(1), 9.0, r, c)).unwrap();
    assert!(
        clean.outcome.iterations > CAP,
        "compliant tenant must not be shed, ran {}",
        clean.outcome.iterations
    );

    let (_, metrics) = http_get(addr, "/metrics", SCRAPE_TIMEOUT).unwrap();
    assert!(
        metrics.contains("sinkhorn_slo_armed{tenant=\"m0\"} 1"),
        "{metrics}"
    );
    assert!(
        metrics.contains("sinkhorn_slo_armed{tenant=\"m1\"} 0"),
        "{metrics}"
    );
    let (_, report) = http_get(addr, "/slo", SCRAPE_TIMEOUT).unwrap();
    assert!(report.contains("ARMED"), "{report}");
    let snap = svc.stats().unwrap();
    assert!(snap.budget_sheds >= 1, "shed batches are counted");
    svc.shutdown();
}
