//! Integration: PR 9 end-to-end tracing. Sampled queries must produce
//! *well-formed trace trees* — every span's exit is at or after its
//! entry, children nest inside their parents, and sequential children's
//! durations sum to no more than the parent wall time — across both
//! service paths: an iteration-budgeted distance solve (query ⊃ batcher +
//! solve ⊃ slice) and a routed, budgeted retrieval over a 3-shard corpus
//! (retrieve ⊃ mailbox + search ⊃ shard ⊃ cascade + refine ⊃ slice).
//! The exported Chrome trace must round-trip through the crate's own
//! JSON parser.
//!
//! All timestamps come from one sink epoch via monotonic reads, so
//! containment is asserted exactly; only *sums* of child durations get
//! slack (floor-truncation to µs can inflate each child by <1µs).

use std::time::Duration;

use sinkhorn_rs::coordinator::{
    BatcherConfig, CoordinatorConfig, CorpusId, DistanceService, MetricId, Query,
    RetrievalQuery,
};
use sinkhorn_rs::data::ClusteredCorpus;
use sinkhorn_rs::metric::RandomMetric;
use sinkhorn_rs::retrieval::RoutingConfig;
use sinkhorn_rs::simplex::{seeded_rng, Histogram};
use sinkhorn_rs::sinkhorn::SolveBudget;
use sinkhorn_rs::trace::{chrome_trace, Span, SpanData, Stage, TraceConfig, TraceId};
use sinkhorn_rs::util::json::Json;

/// Per-child slack (µs) for duration-sum assertions: each child span's
/// floor-truncated duration can exceed its real duration by <1µs.
const SUM_SLACK_US: u64 = 2;

fn assert_well_formed(span: &Span) {
    assert!(
        span.end_us >= span.start_us,
        "span exit precedes entry: {span:?}"
    );
}

fn contained(child: &Span, parent: &Span) -> bool {
    child.start_us >= parent.start_us && child.end_us <= parent.end_us
}

fn assert_contained(child: &Span, parent: &Span) {
    assert!(
        contained(child, parent),
        "child span escapes its parent:\n  child  {child:?}\n  parent {parent:?}"
    );
}

fn of_stage(spans: &[Span], stage: Stage) -> Vec<Span> {
    spans.iter().copied().filter(|s| s.stage == stage).collect()
}

fn sum_us(spans: &[Span]) -> u64 {
    spans.iter().map(Span::duration_us).sum()
}

/// Group retained spans by trace id, keeping only traces that recorded
/// the given root stage, ordered by trace id.
fn traces_with_root(spans: &[Span], root: Stage) -> Vec<(TraceId, Vec<Span>)> {
    let mut ids: Vec<TraceId> = spans
        .iter()
        .filter(|s| s.stage == root)
        .map(|s| s.trace)
        .collect();
    ids.sort();
    ids.dedup();
    ids.into_iter()
        .map(|id| {
            (
                id,
                spans.iter().copied().filter(|s| s.trace == id).collect(),
            )
        })
        .collect()
}

/// The exported Chrome trace document must be valid JSON under the
/// crate's own parser, with one "X" event per span carrying µs
/// timestamps on the trace's process track.
fn assert_chrome_roundtrip(spans: &[Span]) {
    let doc = chrome_trace(spans);
    let text = format!("{doc}");
    let parsed = Json::parse(&text).expect("chrome trace must be self-parseable");
    let events = parsed.as_array().expect("array document");
    assert_eq!(events.len(), spans.len());
    for (event, span) in events.iter().zip(spans) {
        assert_eq!(event.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(
            event.get("name").and_then(Json::as_str),
            Some(span.stage.name())
        );
        assert_eq!(
            event.get("ts").and_then(Json::as_f64),
            Some(span.start_us as f64)
        );
        assert_eq!(
            event.get("pid").and_then(Json::as_f64),
            Some(span.trace.0 as f64)
        );
    }
}

#[test]
fn budgeted_distance_traces_form_a_tree() {
    let mut config = CoordinatorConfig::cpu_only();
    config.cpu_workers = 2;
    config.batcher = BatcherConfig {
        max_batch: 4,
        max_delay: Duration::from_millis(2),
        ..BatcherConfig::default()
    };
    config.trace = Some(TraceConfig { sample_every: 1, ring_capacity: 4096 });
    let svc = DistanceService::start(config).unwrap();
    let d = 16;
    let mut rng = seeded_rng(909);
    let metric = RandomMetric::new(d).sample(&mut rng);
    svc.register_metric(MetricId(0), metric).unwrap();

    // An iteration budget routes through the certified slice driver and
    // terminates deterministically (a deadline budget in fixed-iteration
    // mode would slice until the wall clock actually expires): 48
    // iterations = 6 CERT_STRIDE slices per query.
    let queries = 6;
    for _ in 0..queries {
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        let out = svc
            .distance(
                Query::new(MetricId(0), 9.0, r, c)
                    .with_budget(SolveBudget::Iterations(48)),
            )
            .unwrap();
        assert!(out.distance().is_finite());
    }

    let sink = svc.trace_sink().expect("tracing configured");
    let spans = sink.sampled_spans();
    for span in &spans {
        assert_well_formed(span);
    }
    let traces = traces_with_root(&spans, Stage::Query);
    assert_eq!(traces.len(), queries, "sample_every=1 traces every query");

    for (id, spans) in &traces {
        let roots = of_stage(spans, Stage::Query);
        assert_eq!(roots.len(), 1, "trace {id:?}: exactly one root");
        let root = roots[0];
        let batcher = of_stage(spans, Stage::Batcher);
        let solve = of_stage(spans, Stage::Solve);
        let slices = of_stage(spans, Stage::Slice);
        assert_eq!(batcher.len(), 1, "trace {id:?}");
        assert_eq!(solve.len(), 1, "trace {id:?}");
        assert!(!slices.is_empty(), "a budgeted solve must record slices");

        // Nesting: batcher wait and the panel solve partition the root.
        assert_contained(&batcher[0], &root);
        assert_contained(&solve[0], &root);
        assert!(batcher[0].end_us <= solve[0].start_us, "wait precedes solve");
        for slice in &slices {
            assert_contained(slice, &solve[0]);
            match slice.data {
                SpanData::Slice { iterations, width, .. } => {
                    assert!(iterations >= 1, "an executed slice iterated");
                    assert!(
                        width >= 0.0,
                        "certified interval width is non-negative: {width}"
                    );
                }
                other => panic!("slice span carries slice payload, got {other:?}"),
            }
        }
        // Sequential children: wait + solve can't exceed the query wall.
        assert!(
            sum_us(&batcher) + sum_us(&solve)
                <= root.duration_us() + 2 * SUM_SLACK_US,
            "batcher {}us + solve {}us > query {}us",
            sum_us(&batcher),
            sum_us(&solve),
            root.duration_us()
        );
    }

    assert_eq!(sink.dropped(), 0, "rings were sized generously");
    assert_chrome_roundtrip(&traces[0].1);

    // The snapshot folds the same spans into per-stage quantile rows.
    let snap = svc.stats().unwrap();
    assert_eq!(snap.traces_sampled, queries as u64);
    assert!(snap.trace_spans >= 3 * queries as u64);
    assert_eq!(snap.trace_spans_dropped, 0);
    let stages: Vec<&str> = snap.stages.iter().map(|r| r.stage).collect();
    for want in ["query", "batcher", "solve", "slice"] {
        assert!(stages.contains(&want), "missing stage row {want}: {stages:?}");
    }
    for row in &snap.stages {
        assert!(row.count >= 1);
        assert!(row.p50_us <= row.p99_us, "{row:?}");
        assert_eq!(row.tenant, "m0");
    }
    let rendered = snap.to_string();
    assert!(rendered.contains("stages={"), "{rendered}");
    assert!(rendered.contains("traces(sampled=6"), "{rendered}");
    svc.shutdown();
}

#[test]
fn routed_budgeted_retrieval_traces_form_a_tree() {
    let mut config = CoordinatorConfig::cpu_only();
    config.cpu_workers = 2;
    config.retrieval_shards = 3;
    // One walker thread: the per-shard walks are sequential, so shard
    // durations must additionally *sum* below the search wall.
    config.retrieval_threads = 1;
    config.retrieval_budget = SolveBudget::Iterations(24);
    config.retrieval_routing = Some(RoutingConfig {
        centroids: 4,
        probes: 2,
        min_shortlist: 8,
        iterations: 8,
    });
    config.trace = Some(TraceConfig { sample_every: 1, ring_capacity: 4096 });
    let svc = DistanceService::start(config).unwrap();
    let d = 12;
    let mut rng = seeded_rng(910);
    let metric = RandomMetric::new(d).sample(&mut rng);
    svc.register_metric(MetricId(0), metric).unwrap();
    let gen = ClusteredCorpus::new(d, 4, 12, 0.12);
    let (corpus, protos) = gen.generate(&mut rng);
    let indexed = svc
        .register_corpus(CorpusId(0), MetricId(0), 9.0, corpus)
        .unwrap();
    assert_eq!(indexed, 48);

    for proto in &protos {
        let q = gen.mixture_at(proto, 0.12, &mut rng);
        let out = svc
            .retrieve(RetrievalQuery { corpus: CorpusId(0), r: q, k: 3 })
            .unwrap();
        assert_eq!(out.hits.len(), 3);
        assert!(out.report.routed, "ANN router must own candidate generation");
    }

    let sink = svc.trace_sink().expect("tracing configured");
    let spans = sink.sampled_spans();
    for span in &spans {
        assert_well_formed(span);
    }
    let traces = traces_with_root(&spans, Stage::Retrieve);
    assert_eq!(traces.len(), protos.len(), "every retrieval is traced");

    for (id, spans) in &traces {
        let roots = of_stage(spans, Stage::Retrieve);
        assert_eq!(roots.len(), 1, "trace {id:?}: exactly one root");
        let root = roots[0];
        let mailbox = of_stage(spans, Stage::Mailbox);
        let search = of_stage(spans, Stage::Search);
        let shards = of_stage(spans, Stage::Shard);
        let cascades = of_stage(spans, Stage::Cascade);
        let refines = of_stage(spans, Stage::Refine);
        let slices = of_stage(spans, Stage::Slice);
        assert_eq!(mailbox.len(), 1, "trace {id:?}");
        assert_eq!(search.len(), 1, "trace {id:?}");
        assert_eq!(shards.len(), 3, "one span per corpus shard");
        assert_eq!(cascades.len(), 3, "one cascade per shard walk");
        assert_eq!(refines.len(), 3, "one refine per shard walk");
        assert!(
            !slices.is_empty(),
            "budgeted refine must record certified slices"
        );

        // Nesting, layer by layer.
        assert_contained(&mailbox[0], &root);
        assert_contained(&search[0], &root);
        assert!(
            mailbox[0].end_us <= search[0].start_us,
            "queue wait precedes the walk"
        );
        for shard in &shards {
            assert_contained(shard, &search[0]);
        }
        for inner in cascades.iter().chain(&refines) {
            assert!(
                shards.iter().any(|s| contained(inner, s)),
                "cascade/refine span outside every shard walk: {inner:?}"
            );
        }
        for slice in &slices {
            assert!(
                refines.iter().any(|r| contained(slice, r)),
                "slice span outside every refine: {slice:?}"
            );
        }

        // Sequential children sum below their parent's wall time.
        assert!(
            sum_us(&mailbox) + sum_us(&search)
                <= root.duration_us() + 2 * SUM_SLACK_US,
            "mailbox {}us + search {}us > retrieve {}us",
            sum_us(&mailbox),
            sum_us(&search),
            root.duration_us()
        );
        assert!(
            sum_us(&shards) <= search[0].duration_us() + 3 * SUM_SLACK_US,
            "serial shard walks {}us > search {}us",
            sum_us(&shards),
            search[0].duration_us()
        );

        // Typed payloads carried the cascade/refine detail.
        match search[0].data {
            SpanData::Search { hits, routed, .. } => {
                assert_eq!(hits, 3);
                assert!(routed);
            }
            other => panic!("search span carries search payload, got {other:?}"),
        }
        let mut priced = 0;
        for cascade in &cascades {
            match cascade.data {
                SpanData::Cascade { priced: p, .. } => priced += p,
                other => panic!("cascade payload mismatch: {other:?}"),
            }
        }
        assert!(priced >= 3, "the shortlists covered at least top-k");
        assert!(
            priced < 48,
            "min_shortlist=8 over 3x16 entries must shortlist sublinearly"
        );
    }

    assert_eq!(sink.dropped(), 0, "rings were sized generously");
    assert_chrome_roundtrip(&traces[0].1);

    let snap = svc.stats().unwrap();
    assert_eq!(snap.traces_sampled, protos.len() as u64);
    assert_eq!(snap.trace_spans_dropped, 0);
    let stages: Vec<&str> = snap.stages.iter().map(|r| r.stage).collect();
    for want in ["retrieve", "mailbox", "search", "shard", "cascade", "refine", "slice"] {
        assert!(stages.contains(&want), "missing stage row {want}: {stages:?}");
    }
    for row in &snap.stages {
        assert_eq!(row.tenant, "c0");
    }
    // Satellite: index-build time from registration surfaced per corpus.
    assert_eq!(snap.retrieval_shards.len(), 1);
    assert!(
        snap.retrieval_shards[0].build_us > 0,
        "48-entry sharded index build takes measurable time"
    );
    let rendered = snap.to_string();
    assert!(rendered.contains("build_us="), "{rendered}");
    assert!(rendered.contains("stages={"), "{rendered}");
    svc.shutdown();
}

#[test]
fn untraced_service_records_nothing_and_renders_no_stage_section() {
    let mut config = CoordinatorConfig::cpu_only();
    config.cpu_workers = 2;
    let svc = DistanceService::start(config).unwrap();
    assert!(svc.trace_sink().is_none(), "tracing defaults off");
    let d = 8;
    let mut rng = seeded_rng(911);
    let metric = RandomMetric::new(d).sample(&mut rng);
    svc.register_metric(MetricId(0), metric).unwrap();
    let r = Histogram::sample_uniform(d, &mut rng);
    let c = Histogram::sample_uniform(d, &mut rng);
    svc.distance(
        Query::new(MetricId(0), 9.0, r, c).with_budget(SolveBudget::Iterations(16)),
    )
    .unwrap();
    let snap = svc.stats().unwrap();
    assert!(snap.stages.is_empty());
    assert_eq!(snap.traces_sampled, 0);
    assert_eq!(snap.trace_spans, 0);
    assert!(!snap.to_string().contains("stages={"));
    svc.shutdown();
}
