//! Exactness of pruned retrieval: the bound cascade must never change
//! the answer.
//!
//! The acceptance contract of the retrieval subsystem is that pruned
//! top-k retrieval returns **identical results to brute-force panel
//! solves** — same distances at 1e-9, same order modulo ties — for the
//! Dense, Truncated and LowRank kernel policies on a ≥200-entry
//! randomized corpus, with the truncated path routed through the
//! existing rescue gate (an infeasible-on-support pair must come back
//! log-domain-exact, never as a collapsed-column read-off).
//!
//! Why the refine tolerance is 1e-12 while the comparison is 1e-9: the
//! pruned and brute-force walks group candidates into *different*
//! executor panels, and an interleaved panel iterates until its slowest
//! column converges — so the same pair can receive a few extra
//! fixed-point iterations in one walk than the other. Solving three
//! orders of magnitude past the comparison tolerance makes that
//! grouping effect invisible. (At the serving-λ truncated policy the
//! radius-floored cut keeps relative mass loss ~1e-16, so
//! whole-panel-rescue grouping differences are equally invisible.)
//!
//! Like `property_harness`, the sample self-trims under debug_assertions
//! (debug-mode Sinkhorn at 1e-12 over full corpora is an order of
//! magnitude slower); CI runs the full release sample.

use sinkhorn_rs::backend::BackendKind;
use sinkhorn_rs::data::ClusteredCorpus;
use sinkhorn_rs::linalg::KernelPolicy;
use sinkhorn_rs::metric::RandomMetric;
use sinkhorn_rs::retrieval::{CorpusIndex, Hit, RetrievalConfig, RetrievalService};
use sinkhorn_rs::simplex::{seeded_rng, Histogram};
use sinkhorn_rs::F;

const K: usize = 10;
const DIST_TOL: F = 1e-9;

fn release_else(release: usize, debug: usize) -> usize {
    if cfg!(debug_assertions) {
        debug
    } else {
        release
    }
}

fn refine_config(
    lambda: F,
    kernel: KernelPolicy,
    backend: Option<BackendKind>,
) -> RetrievalConfig {
    let mut config = RetrievalConfig::serving(lambda);
    config.sinkhorn.tolerance = 1e-12;
    config.sinkhorn.max_iterations = 200_000;
    config.sinkhorn.kernel = kernel;
    config.backend = backend;
    config.workers = 3;
    // Cold solves on both sides: the brute pass must not seed the
    // pruned pass through the per-entry warm cache, so every per-pair
    // difference is panel-grouping only (≪ the 1e-9 comparison at the
    // 1e-12 refine tolerance). Warm-start agreement has its own
    // coverage in retrieval::search unit tests.
    config.warm_start = false;
    config
}

/// The shared exactness contract ([`sinkhorn_rs::retrieval::topk_equivalent`]):
/// same distances position by position, same entry sets modulo tie
/// swaps. The bench (`benches/retrieval.rs`) asserts the same helper at
/// its own, looser serving tolerance.
fn assert_same_topk(got: &[Hit], want: &[Hit], label: &str) {
    if let Err(violation) = sinkhorn_rs::retrieval::topk_equivalent(got, want, DIST_TOL)
    {
        panic!("{label}: pruned vs brute-force top-k diverged: {violation}");
    }
}

/// The acceptance matrix: ≥200-entry randomized corpora, pruned top-10
/// vs brute force at 1e-9, across the three kernel policies. The
/// truncated rows run at serving λ = 50 where the default threshold
/// genuinely truncates (nnz < d²) and infeasible-on-support pairs reach
/// the rescue gate.
#[test]
fn pruned_topk_equals_brute_force_across_kernel_policies() {
    let d = 32;
    let per = release_else(25, 3); // 8 clusters ⇒ 200-entry corpora in release
    // Debug runs the truncated row at λ = 30: the radius-floored cut
    // keeps the *identical* sparse support (so the same pairs stay
    // infeasible and the rescue assert still bites) while the log-domain
    // rescues mix ~4x faster; release runs the acceptance λ = 50.
    let trunc_lambda = release_else(50, 30) as F;
    let policies: [(&str, F, KernelPolicy); 3] = [
        ("dense", 9.0, KernelPolicy::Dense),
        ("truncated", trunc_lambda, KernelPolicy::truncated_default()),
        ("low_rank", 9.0, KernelPolicy::low_rank_default()),
    ];
    let mut total_pruned = 0usize;
    let mut total_candidates = 0usize;
    let mut truncated_rescues = 0usize;
    for (round, &(label, lambda, kernel)) in policies.iter().enumerate() {
        for (flavor, mix) in [("clustered", 0.12), ("unstructured", 1.0)] {
            let mut rng = seeded_rng(1000 + round as u64);
            let m = RandomMetric::new(d).sample(&mut rng);
            let gen = ClusteredCorpus::new(d, 8, per, mix);
            let (corpus, protos) = gen.generate(&mut rng);
            let n = corpus.len();
            let index = CorpusIndex::from_histograms(&m, corpus, 4).unwrap();
            let mut svc =
                RetrievalService::new(index, refine_config(lambda, kernel, None));
            if label == "truncated" {
                assert!(
                    svc.backend_kind() == BackendKind::Truncated,
                    "explicit truncated policy must route to the truncated backend"
                );
            }
            // One query near a prototype, one unrelated.
            let near = gen.mixture_at(&protos[0], 0.12, &mut rng);
            let far = Histogram::sample_uniform(d, &mut rng);
            let queries: Vec<(&str, &Histogram)> = if cfg!(debug_assertions) {
                vec![("near", &near)]
            } else {
                vec![("near", &near), ("far", &far)]
            };
            for (qname, q) in queries {
                let tag = format!("{label}/{flavor}/{qname}");
                let brute = svc.brute_force(q, K).unwrap();
                let (got, report) = svc.top_k(q, K).unwrap();
                assert_same_topk(&got, &brute, &tag);
                assert_eq!(
                    report.solved + report.pruned,
                    n,
                    "{tag}: every candidate either solved or pruned"
                );
                assert_eq!(report.failed, 0, "{tag}: no failed solves");
                total_pruned += report.pruned;
                total_candidates += report.corpus;
                if label == "truncated" {
                    truncated_rescues += report.rescued;
                }
            }
        }
    }
    // The cascade must be doing real work somewhere in the matrix
    // (clustered corpora prune most of the far clusters).
    assert!(
        total_pruned * 4 > total_candidates,
        "cascade pruned only {total_pruned}/{total_candidates}"
    );
    // The truncated sections must exercise the rescue gate: at λ = 50
    // the kept support makes some prototype-to-prototype routes
    // infeasible, and those solves must come back log-domain-exact.
    assert!(
        truncated_rescues > 0,
        "no truncated solve was rescued — the gate was never exercised"
    );
}

/// Backend sweep: pruning is exact under every solve strategy, including
/// the per-pair backends with no panel coupling at all.
#[test]
fn pruned_topk_equals_brute_force_across_backends() {
    let d = 16;
    let n = release_else(64, 24);
    let backends = [
        BackendKind::Interleaved,
        BackendKind::Dense,
        BackendKind::LogDomain,
        BackendKind::Greenkhorn,
    ];
    for (round, &kind) in backends.iter().enumerate() {
        let mut rng = seeded_rng(2000 + round as u64);
        let m = RandomMetric::new(d).sample(&mut rng);
        let corpus: Vec<Histogram> =
            (0..n).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let index = CorpusIndex::from_histograms(&m, corpus, 4).unwrap();
        let mut config = refine_config(9.0, KernelPolicy::Dense, Some(kind));
        if kind == BackendKind::Greenkhorn {
            // Greedy single-coordinate updates crawl at 1e-12; the
            // parity claim is unaffected (identical path on both sides).
            config.sinkhorn.tolerance = 1e-9;
        }
        let mut svc = RetrievalService::new(index, config);
        assert_eq!(svc.backend_kind(), kind);
        let q = Histogram::sample_uniform(d, &mut rng);
        let brute = svc.brute_force(&q, 5).unwrap();
        let (got, report) = svc.top_k(&q, 5).unwrap();
        assert_same_topk(&got, &brute, kind.as_str());
        assert_eq!(report.failed, 0);
    }
}

/// The exact (network simplex) backend is the λ = ∞ member: bounds lower
/// bound d_M itself, so pruning stays exact there too.
#[test]
fn pruned_topk_is_exact_for_the_exact_backend() {
    let d = 12;
    let n = release_else(48, 16);
    let mut rng = seeded_rng(3000);
    let m = RandomMetric::new(d).sample(&mut rng);
    let corpus: Vec<Histogram> =
        (0..n).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
    let index = CorpusIndex::from_histograms(&m, corpus, 4).unwrap();
    let mut svc = RetrievalService::new(
        index,
        refine_config(9.0, KernelPolicy::Dense, Some(BackendKind::Exact)),
    );
    let q = Histogram::sample_uniform(d, &mut rng);
    let brute = svc.brute_force(&q, 4).unwrap();
    let (got, _) = svc.top_k(&q, 4).unwrap();
    assert_same_topk(&got, &brute, "exact");
}

/// Randomized sweep at serving λ: many (corpus, query) draws, every one
/// held to pruned == brute — the harness section backing the README's
/// exactness claim.
#[test]
fn randomized_pruning_harness() {
    let cases = release_else(12, 3);
    for case in 0..cases {
        let mut rng = seeded_rng(4000 + case as u64);
        let d = 8 + (case % 4) * 8;
        let n = release_else(200, 32);
        let m = RandomMetric::new(d).sample(&mut rng);
        let mix = if case % 2 == 0 { 0.15 } else { 1.0 };
        let (corpus, _) = ClusteredCorpus::new(d, 8, n / 8, mix).generate(&mut rng);
        let index = CorpusIndex::from_histograms(&m, corpus, 4).unwrap();
        // Debug swaps the λ = 50 slot for 30 (same truncated support,
        // much faster log-domain rescues).
        let lambda = [9.0, 20.0, release_else(50, 30) as F][case % 3];
        let kernel = [
            KernelPolicy::Dense,
            KernelPolicy::Auto,
            KernelPolicy::truncated_default(),
        ][case % 3];
        let mut svc = RetrievalService::new(index, refine_config(lambda, kernel, None));
        let q = Histogram::sample_dirichlet(d, 0.5, &mut rng);
        let brute = svc.brute_force(&q, K).unwrap();
        let (got, report) = svc.top_k(&q, K).unwrap();
        assert_same_topk(&got, &brute, &format!("case {case} (λ={lambda})"));
        assert_eq!(report.failed, 0, "case {case}");
    }
}
