//! Integration: the AOT artifacts, loaded and executed through PJRT,
//! agree numerically with the pure-Rust CPU engine — byte-level
//! validation of the python→rust interchange.
//!
//! Requires `make artifacts` to have run (skips politely otherwise, so
//! `cargo test` stays green on a fresh checkout).

use sinkhorn_rs::metric::RandomMetric;
use sinkhorn_rs::runtime::{Flavor, XlaRuntime};
use sinkhorn_rs::simplex::{seeded_rng, Histogram};
use sinkhorn_rs::sinkhorn::{SinkhornConfig, SinkhornEngine};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    // Artifacts may exist while the build has no PJRT backend linked
    // (the default runtime::pjrt shim): skip politely rather than panic.
    if let Err(e) = XlaRuntime::new(&dir) {
        eprintln!("skipping: XLA runtime unavailable ({e})");
        return None;
    }
    Some(dir)
}

#[test]
fn manifest_loads_and_warmup_compiles_one() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(&dir).expect("runtime");
    assert_eq!(rt.platform(), "cpu");
    assert!(!rt.manifest().variants.is_empty());
    let v = rt.select(16, 1, Flavor::Xla).expect("d=16 variant");
    assert_eq!(v.d, 16);
    // First execution compiles and caches.
    let mut rng = seeded_rng(0);
    let m = RandomMetric::new(16).sample(&mut rng);
    let r = Histogram::sample_uniform(16, &mut rng);
    let c = Histogram::sample_uniform(16, &mut rng);
    let out = rt
        .execute(&v, &m, 9.0, &[r.values().to_vec()], &[c.values().to_vec()])
        .expect("execute");
    assert_eq!(out.distances.len(), 1);
    assert!(out.distances[0].is_finite() && out.distances[0] > 0.0);
    assert!(out.marginal_error < 0.2, "marginal err {}", out.marginal_error);
    assert_eq!(rt.cached_executables(), 1);
    assert_eq!(rt.exec_counts()[&v.name], 1);
}

#[test]
fn xla_matches_cpu_engine_across_dims_and_lambdas() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(&dir).expect("runtime");
    for &d in &[16usize, 64] {
        for &lambda in &[1.0f64, 5.0, 9.0] {
            let mut rng = seeded_rng(d as u64 * 100 + lambda as u64);
            let m = RandomMetric::new(d).sample(&mut rng);
            let r = Histogram::sample_uniform(d, &mut rng);
            let cs: Vec<Histogram> = (0..5)
                .map(|_| Histogram::sample_uniform(d, &mut rng))
                .collect();
            let got = rt
                .distances(&m, lambda, &r, &cs, Flavor::Xla)
                .expect("xla distances");
            // The artifacts bake 20 iterations; match the CPU engine.
            let engine =
                SinkhornEngine::with_config(&m, SinkhornConfig::fixed(lambda, 20));
            for (c, &g) in cs.iter().zip(&got) {
                let want = engine.distance(&r, c).value;
                let rel = (g - want).abs() / want.max(1e-12);
                // The artifact computes in f32 while the engine is f64;
                // at a fixed 20 iterations the un-contracted transient
                // amplifies rounding to the ~1e-3 level.
                assert!(
                    rel < 1e-2,
                    "d={d} lambda={lambda}: xla {g} vs cpu {want} (rel {rel:.2e})"
                );
            }
        }
    }
}

#[test]
fn pallas_flavor_matches_xla_flavor() {
    // The L1 Pallas kernel path (interpret mode) and the plain-XLA path
    // are the same function: prove the layers compose on real artifacts.
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(&dir).expect("runtime");
    let d = 16;
    if rt.select(d, 1, Flavor::Pallas).is_err() {
        eprintln!("skipping: no pallas artifacts");
        return;
    }
    let mut rng = seeded_rng(5);
    let m = RandomMetric::new(d).sample(&mut rng);
    let r = Histogram::sample_uniform(d, &mut rng);
    let cs: Vec<Histogram> =
        (0..3).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
    let a = rt.distances(&m, 7.0, &r, &cs, Flavor::Pallas).expect("pallas");
    let b = rt.distances(&m, 7.0, &r, &cs, Flavor::Xla).expect("xla");
    for (x, y) in a.iter().zip(&b) {
        assert!(
            (x - y).abs() < 1e-5 * (1.0 + y.abs()),
            "pallas {x} vs xla {y}"
        );
    }
}

#[test]
fn batching_is_equivalent_to_singles() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(&dir).expect("runtime");
    let d = 64;
    let mut rng = seeded_rng(9);
    let m = RandomMetric::new(d).sample(&mut rng);
    let r = Histogram::sample_uniform(d, &mut rng);
    let cs: Vec<Histogram> = (0..16)
        .map(|_| Histogram::sample_uniform(d, &mut rng))
        .collect();
    let batched = rt.distances(&m, 9.0, &r, &cs, Flavor::Xla).expect("batched");
    for (c, &want) in cs.iter().zip(&batched) {
        let single = rt
            .distances(&m, 9.0, &r, std::slice::from_ref(c), Flavor::Xla)
            .expect("single")[0];
        assert!(
            (single - want).abs() < 1e-5 * (1.0 + want.abs()),
            "batch cross-talk: {single} vs {want}"
        );
    }
}

#[test]
fn chunking_covers_oversized_batches() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(&dir).expect("runtime");
    let d = 16;
    let widest = rt
        .manifest()
        .variants
        .iter()
        .filter(|v| v.d == d && v.flavor == Flavor::Xla)
        .map(|v| v.n)
        .max()
        .unwrap();
    let mut rng = seeded_rng(3);
    let m = RandomMetric::new(d).sample(&mut rng);
    let r = Histogram::sample_uniform(d, &mut rng);
    let cs: Vec<Histogram> = (0..widest + 7)
        .map(|_| Histogram::sample_uniform(d, &mut rng))
        .collect();
    let out = rt.distances(&m, 9.0, &r, &cs, Flavor::Xla).expect("chunked");
    assert_eq!(out.len(), widest + 7);
    assert!(out.iter().all(|v| v.is_finite() && *v > 0.0));
}

#[test]
fn zero_mass_bins_are_tolerated() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(&dir).expect("runtime");
    let d = 16;
    let mut rng = seeded_rng(12);
    let m = RandomMetric::new(d).sample(&mut rng);
    // Half the bins empty on each side.
    let mut rw = vec![0.0; d];
    let mut cw = vec![0.0; d];
    for i in 0..d / 2 {
        rw[i] = 1.0;
        cw[d / 2 + i] = 1.0;
    }
    let r = Histogram::from_weights(&rw).unwrap();
    let c = Histogram::from_weights(&cw).unwrap();
    let got = rt
        .distances(&m, 9.0, &r, &[c.clone()], Flavor::Xla)
        .expect("sparse")[0];
    assert!(got.is_finite() && got > 0.0);
    let want = SinkhornEngine::with_config(&m, SinkhornConfig::fixed(9.0, 20))
        .distance(&r, &c)
        .value;
    // f32 artifact vs f64 engine with extreme dynamic range (half the
    // bins empty): allow 2% relative drift at 20 fixed iterations.
    assert!((got - want).abs() / want < 2e-2, "{got} vs {want}");
}

#[test]
fn shape_validation_errors() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(&dir).expect("runtime");
    let v = rt.select(16, 1, Flavor::Xla).unwrap();
    let mut rng = seeded_rng(1);
    let m_wrong = RandomMetric::new(32).sample(&mut rng);
    let r = Histogram::sample_uniform(16, &mut rng);
    let err = rt
        .execute(&v, &m_wrong, 9.0, &[r.values().to_vec()], &[r.values().to_vec()])
        .unwrap_err();
    assert!(err.to_string().contains("metric dim"));
    // Unknown dimension.
    let e2 = rt.select(17, 1, Flavor::Xla).unwrap_err();
    assert!(e2.to_string().contains("d=17"));
    // Histogram of the wrong length inside the batch.
    let m16 = RandomMetric::new(16).sample(&mut rng);
    let bad = vec![0.5; 7];
    let e3 = rt
        .execute(&v, &m16, 9.0, &[bad], &[r.values().to_vec()])
        .unwrap_err();
    assert!(e3.to_string().contains("dims") || e3.to_string().contains("batch"));
}
