//! Golden-fixture parity: the Rust solvers vs the Python oracle.
//!
//! `rust/tests/fixtures/ref_cases.json` freezes deeply converged outputs
//! of `python/compile/kernels/ref.py` (the same oracle the Pallas kernels
//! are validated against), so the Rust CPU paths and the Python/Pallas
//! stack cannot silently diverge: both sides must land on the same fixed
//! point to 1e-9. Regenerate with
//! `python python/compile/kernels/gen_fixtures.py` if the oracle
//! intentionally changes.
//!
//! The fixtures record *fixed points* (solved far past convergence), not
//! stopping states: the oracle updates (u, v) per iteration while the
//! Rust engine updates (v, u), so intermediate iterates differ by design
//! and only the limit is comparable at this precision.

use sinkhorn_rs::backend::{BackendKind, SolverBackend};
use sinkhorn_rs::linalg::KernelPolicy;
use sinkhorn_rs::metric::CostMatrix;
use sinkhorn_rs::simplex::Histogram;
use sinkhorn_rs::sinkhorn::{log_domain, LambdaSchedule, ScalingInit, SinkhornConfig, SinkhornEngine};
use sinkhorn_rs::util::json::Json;
use sinkhorn_rs::F;

const FIXTURES: &str = include_str!("fixtures/ref_cases.json");
const TOL: F = 1e-9;

struct Case {
    name: String,
    d: usize,
    lambda: F,
    m: Vec<F>,
    r: Vec<F>,
    c: Vec<F>,
    distance: F,
    /// `Some(threshold)` for cases the oracle solved against the
    /// threshold-truncated kernel (`"kernel": "truncated"`); the dense
    /// oracle tests skip these — their fixed point is the *truncated*
    /// kernel's, pinned by `truncated_backend_matches_python_oracle`.
    truncated: Option<F>,
}

fn load_cases() -> Vec<Case> {
    let doc = Json::parse(FIXTURES).expect("fixture JSON parses");
    assert_eq!(doc.get("version").and_then(Json::as_usize), Some(1));
    let cases = doc.get("cases").and_then(Json::as_array).expect("cases array");
    assert!(cases.len() >= 5, "expected a meaningful fixture set");
    cases
        .iter()
        .map(|case| {
            let nums = |key: &str| -> Vec<F> {
                case.get(key)
                    .and_then(Json::as_array)
                    .unwrap_or_else(|| panic!("field {key}"))
                    .iter()
                    .map(|x| x.as_f64().expect("numeric entry"))
                    .collect()
            };
            let d = case.get("d").and_then(Json::as_usize).expect("d");
            let truncated = match case.get("kernel").and_then(Json::as_str) {
                Some("truncated") => Some(
                    case.get("threshold")
                        .and_then(Json::as_f64)
                        .expect("truncated case carries its threshold"),
                ),
                Some(other) => panic!("unknown fixture kernel flavor {other:?}"),
                None => None,
            };
            let c = Case {
                name: case
                    .get("name")
                    .and_then(Json::as_str)
                    .expect("name")
                    .to_string(),
                d,
                lambda: case.get("lambda").and_then(Json::as_f64).expect("lambda"),
                m: nums("m"),
                r: nums("r"),
                c: nums("c"),
                distance: case.get("distance").and_then(Json::as_f64).expect("distance"),
                truncated,
            };
            assert_eq!(c.m.len(), d * d, "{}: matrix shape", c.name);
            assert_eq!(c.r.len(), d, "{}: r shape", c.name);
            assert_eq!(c.c.len(), d, "{}: c shape", c.name);
            c
        })
        .collect()
}

fn tight(lambda: F) -> SinkhornConfig {
    SinkhornConfig {
        lambda,
        tolerance: 1e-13,
        max_iterations: 200_000,
        ..Default::default()
    }
}

#[test]
fn log_domain_matches_python_oracle() {
    let cases = load_cases();
    for case in cases.iter().filter(|c| c.truncated.is_none()) {
        let out = log_domain::solve(
            &case.m,
            case.d,
            case.lambda,
            &tight(case.lambda),
            &case.r,
            &case.c,
        );
        assert!(out.stats.converged, "{}: log-domain did not converge", case.name);
        assert!(
            (out.value - case.distance).abs() < TOL,
            "{}: log-domain {} vs oracle {} (dev {:.3e})",
            case.name,
            out.value,
            case.distance,
            (out.value - case.distance).abs()
        );
    }
}

#[test]
fn dense_engine_matches_python_oracle() {
    let cases = load_cases();
    for case in cases.iter().filter(|c| c.truncated.is_none()) {
        let metric = CostMatrix::from_rows(case.d, case.m.clone());
        let r = Histogram::from_weights(&case.r).unwrap();
        let c = Histogram::from_weights(&case.c).unwrap();
        let engine = SinkhornEngine::with_config(&metric, tight(case.lambda));
        let out = engine.distance(&r, &c);
        assert!(out.stats.converged, "{}: engine did not converge", case.name);
        assert!(
            (out.value - case.distance).abs() < TOL,
            "{}: engine {} vs oracle {} (dev {:.3e})",
            case.name,
            out.value,
            case.distance,
            (out.value - case.distance).abs()
        );
    }
}

#[test]
fn annealed_log_domain_matches_python_oracle() {
    // The ε-scaling path must land on the same fixed point as the
    // straight iteration — tied here to an *external* reference, not just
    // to another in-crate solver.
    let cases = load_cases();
    for case in cases.iter().filter(|c| c.truncated.is_none()) {
        let cfg = SinkhornConfig {
            schedule: LambdaSchedule::geometric(0.5),
            ..tight(case.lambda)
        };
        let out =
            log_domain::solve(&case.m, case.d, case.lambda, &cfg, &case.r, &case.c);
        assert!(out.stats.converged, "{}: annealed did not converge", case.name);
        assert!(
            (out.value - case.distance).abs() < TOL,
            "{}: annealed {} vs oracle {} (dev {:.3e})",
            case.name,
            out.value,
            case.distance,
            (out.value - case.distance).abs()
        );
    }
}

#[test]
fn truncated_backend_matches_python_oracle() {
    // The truncated fixture freezes the fixed point of the *threshold-
    // truncated* kernel (the oracle applies the exact SparseKernel::build
    // rule, safety radius included), so the Rust truncated backend must
    // reproduce it to the same 1e-9 the dense oracle tests pin. The
    // generator certifies the case marginal-feasible on the kept support
    // — the solve must come back from the structured fast path, not the
    // log-domain rescue.
    let cases: Vec<Case> =
        load_cases().into_iter().filter(|c| c.truncated.is_some()).collect();
    assert!(!cases.is_empty(), "fixture set must carry a truncated case");
    for case in cases {
        let threshold = case.truncated.expect("filtered on truncated");
        let metric = CostMatrix::from_rows(case.d, case.m.clone());
        let cfg = SinkhornConfig {
            kernel: KernelPolicy::Truncated { threshold },
            ..tight(case.lambda)
        };
        let backend = BackendKind::Truncated.build(&metric, cfg);
        let stats = backend.kernel_stats();
        assert!(
            stats.nnz < case.d * case.d,
            "{}: fixture truncation must bite (nnz {})",
            case.name,
            stats.nnz
        );
        let r = Histogram::from_weights(&case.r).unwrap();
        let c = Histogram::from_weights(&case.c).unwrap();
        let out = backend.solve(&r, &c, &ScalingInit::Cold);
        assert!(out.stats.converged, "{}: did not converge", case.name);
        assert!(
            !out.stats.stabilized,
            "{}: feasible truncated case must not need the rescue",
            case.name
        );
        assert!(
            (out.value - case.distance).abs() < TOL,
            "{}: truncated {} vs oracle {} (dev {:.3e})",
            case.name,
            out.value,
            case.distance,
            (out.value - case.distance).abs()
        );
    }
}
