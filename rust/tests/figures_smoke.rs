//! Integration: every figure harness runs end to end at smoke scale and
//! reproduces the paper's qualitative *shape* (who wins, what is
//! monotone) — full-scale runs go through the `repro` CLI.

use sinkhorn_rs::distances::ClassicalDistance;
use sinkhorn_rs::exp::{fig2, fig3, fig4, fig5};
use sinkhorn_rs::util::bench::Bench;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn fig2_smoke_sinkhorn_competitive() {
    let config = fig2::Fig2Config {
        grid: 8,
        ns: vec![60],
        folds: 4,
        repeats: 1,
        distances: vec![
            fig2::DistanceKind::Classical(ClassicalDistance::SquaredEuclidean),
            fig2::DistanceKind::Classical(ClassicalDistance::Hellinger),
            fig2::DistanceKind::Independence,
            fig2::DistanceKind::Sinkhorn,
        ],
        sinkhorn_iterations: 20,
        seed: 99,
    };
    let pts = fig2::run(&config);
    assert_eq!(pts.len(), 4);
    let err_of = |name: &str| {
        pts.iter().find(|p| p.distance == name).unwrap().mean_error
    };
    // Everyone beats 10-class chance by a wide margin.
    for p in &pts {
        assert!(p.mean_error < 0.6, "{}: {}", p.distance, p.mean_error);
        assert_eq!(p.experiments, 4);
    }
    // The paper's headline ordering at smoke scale: Sinkhorn is at least
    // competitive with the squared Euclidean baseline.
    assert!(
        err_of("sinkhorn") <= err_of("sq_euclidean") + 0.05,
        "sinkhorn {} vs sq_euclidean {}",
        err_of("sinkhorn"),
        err_of("sq_euclidean")
    );
}

#[test]
fn fig3_smoke_gap_shrinks_with_lambda() {
    let pts = fig3::run(&fig3::Fig3Config {
        grid: 8,
        pairs: 8,
        lambdas: vec![1.0, 5.0, 25.0],
        ..Default::default()
    });
    assert_eq!(pts.len(), 3);
    assert!(pts[0].gaps.median > pts[2].gaps.median);
    assert!(pts.iter().all(|p| p.gaps.min > -1e-9));
    // Large-lambda plateau: median gap under 60% once lambda >= 25
    // (paper: ~10% at paper scale; smoke scale is coarser).
    assert!(pts[2].gaps.median < 0.6, "median {}", pts[2].gaps.median);
}

#[test]
fn fig4_smoke_sinkhorn_beats_emd_and_grows_slower() {
    let pts = fig4::run(&fig4::Fig4Config {
        dims: vec![32, 64],
        lambdas: vec![9.0],
        artifact_dir: artifacts_dir(),
        bench: Bench { warmup: 0, max_samples: 3, budget_secs: 10.0 },
        ..Default::default()
    });
    let get = |solver_prefix: &str, d: usize| {
        pts.iter()
            .find(|p| p.solver.starts_with(solver_prefix) && p.d == d)
            .map(|p| p.seconds_per_distance)
    };
    let emd64 = get("emd", 64).unwrap();
    let sk64 = get("sinkhorn_cpu", 64).unwrap();
    assert!(
        sk64 < emd64,
        "sinkhorn ({sk64}) should beat exact EMD ({emd64}) at d=64"
    );
    // Super-linear growth of the exact solver between d=32 and d=64.
    let emd32 = get("emd", 32).unwrap();
    assert!(emd64 > emd32, "emd did not grow with d");
    if artifacts_dir().is_some() {
        let xla64 = get("sinkhorn_xla", 64).expect("xla column present");
        assert!(xla64.is_finite() && xla64 > 0.0);
    }
}

#[test]
fn fig5_smoke_iterations_grow_with_lambda() {
    let pts = fig5::run(&fig5::Fig5Config {
        dims: vec![32, 64],
        lambdas: vec![1.0, 9.0, 50.0],
        trials: 3,
        ..Default::default()
    });
    assert_eq!(pts.len(), 6);
    for &d in &[32usize, 64] {
        let at = |lam: f64| {
            pts.iter()
                .find(|p| p.d == d && (p.lambda - lam).abs() < 1e-9)
                .unwrap()
                .mean_iterations
        };
        assert!(at(1.0) < at(9.0), "d={d}");
        assert!(at(9.0) < at(50.0), "d={d}");
    }
}

#[test]
fn renders_are_nonempty() {
    let f5 = fig5::run(&fig5::Fig5Config {
        dims: vec![16],
        lambdas: vec![1.0],
        trials: 2,
        ..Default::default()
    });
    assert!(fig5::render(&f5).contains("lambda"));
}
