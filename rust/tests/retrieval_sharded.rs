//! Shard-count invariance of the partitioned retrieval runtime, plus
//! the off-engine-thread serving contract.
//!
//! The PR 5 acceptance bar:
//!
//! * the merged pruned top-k over {1, 2, 3, 7} shards is equivalent
//!   (tie-aware, 1e-9 — [`sinkhorn_rs::retrieval::topk_equivalent`]) to
//!   the monolithic brute-force oracle, for every kernel policy and
//!   backend kind in the existing exactness matrix, *before and after*
//!   an insert/tombstone/compact cycle, including the Truncated(λ=50)
//!   policy where the rescue gate fires;
//! * `retrieve` no longer executes the cascade walk on the coordinator
//!   engine thread: a large corpus search interleaved with
//!   deadline-batched distance queries must leave the distance-latency
//!   gauge far below the search walltime.
//!
//! PR 8 adds the cross-tenant isolation bar: with the mailbox-per-corpus
//! dispatcher, one tenant's searches must keep completing *inside*
//! another tenant's long bulk job (index build), per-corpus submission
//! order must survive the refactor, and the stats snapshot must key
//! gauge rows per corpus.
//!
//! Like `retrieval_exactness`, the sample self-trims under
//! debug_assertions (and swaps λ = 50 → 30 on the truncated rows: the
//! radius-floored cut keeps the identical sparse support while the
//! log-domain rescues mix ~4x faster); CI runs the full release sample.

use sinkhorn_rs::backend::BackendKind;
use sinkhorn_rs::data::ClusteredCorpus;
use sinkhorn_rs::linalg::KernelPolicy;
use sinkhorn_rs::metric::RandomMetric;
use sinkhorn_rs::retrieval::{
    topk_equivalent, CorpusIndex, Hit, RetrievalConfig, RetrievalService,
    ShardedCorpus, ShardingConfig,
};
use sinkhorn_rs::simplex::{seeded_rng, Histogram};
use sinkhorn_rs::F;

const K: usize = 10;
const DIST_TOL: F = 1e-9;
const SHARD_COUNTS: [usize; 4] = [1, 2, 3, 7];

fn release_else(release: usize, debug: usize) -> usize {
    if cfg!(debug_assertions) {
        debug
    } else {
        release
    }
}

/// Same refine derivation as the exactness suite: solve three orders of
/// magnitude past the 1e-9 comparison so panel-grouping effects (which
/// differ per shard count) stay invisible, and keep both walks cold so
/// every difference is grouping only.
fn refine_config(
    lambda: F,
    kernel: KernelPolicy,
    backend: Option<BackendKind>,
) -> RetrievalConfig {
    let mut config = RetrievalConfig::serving(lambda);
    config.sinkhorn.tolerance = 1e-12;
    config.sinkhorn.max_iterations = 200_000;
    config.sinkhorn.kernel = kernel;
    config.backend = backend;
    config.workers = 3;
    config.warm_start = false;
    config
}

fn sharding(shards: usize) -> ShardingConfig {
    ShardingConfig { shards, threads: 2, ..Default::default() }
}

fn assert_equiv(got: &[Hit], want: &[Hit], tol: F, label: &str) {
    if let Err(violation) = topk_equivalent(got, want, tol) {
        panic!("{label}: top-k diverged: {violation}");
    }
}

/// The identical mutation cycle for every shard-count variant: the
/// inserted histograms and the global-id counter are deterministic, so
/// ids and the surviving entry set match across variants even though
/// least-loaded routing places the inserts on different shards.
fn mutate(sc: &mut ShardedCorpus, extra: &[Histogram], tombstones: &[usize]) {
    for h in extra {
        sc.insert(h.clone()).unwrap();
    }
    for &t in tombstones {
        assert!(sc.tombstone(t), "tombstone target {t} must be live");
    }
    sc.compact();
}

/// Kernel-policy matrix over a clustered corpus: merged pruned top-k ≡
/// monolithic brute force at every shard count, before and after the
/// mutation cycle, with the truncated rescue gate exercised.
#[test]
fn sharded_topk_matches_monolithic_brute_force_across_kernel_policies() {
    let d = 32;
    let per = release_else(25, 3); // 8 clusters ⇒ 200-entry corpora in release
    let trunc_lambda = release_else(50, 30) as F;
    let policies: [(&str, F, KernelPolicy); 3] = [
        ("dense", 9.0, KernelPolicy::Dense),
        ("truncated", trunc_lambda, KernelPolicy::truncated_default()),
        ("low_rank", 9.0, KernelPolicy::low_rank_default()),
    ];
    for (round, &(label, lambda, kernel)) in policies.iter().enumerate() {
        let mut rng = seeded_rng(5000 + round as u64);
        let m = RandomMetric::new(d).sample(&mut rng);
        let gen = ClusteredCorpus::new(d, 8, per, 0.12);
        let (corpus, protos) = gen.generate(&mut rng);
        let n = corpus.len();
        let q = gen.mixture_at(&protos[0], 0.12, &mut rng);

        // The monolithic brute-force oracle (the acceptance bar).
        let index = CorpusIndex::from_histograms(&m, corpus.clone(), 4).unwrap();
        let mut mono =
            RetrievalService::new(index, refine_config(lambda, kernel, None));
        let brute = mono.brute_force(&q, K).unwrap();

        // Mutation material, fixed across variants: three inserts near
        // another prototype, tombstones on two originals (one from the
        // query's own cluster, so the top-k actually changes) and on
        // the first inserted id.
        let mut mrng = seeded_rng(6000 + round as u64);
        let extra: Vec<Histogram> =
            (0..3).map(|_| gen.mixture_at(&protos[1], 0.12, &mut mrng)).collect();
        let tombstones = [0usize, per + 1, n];

        let mut truncated_rescues = 0usize;
        let mut post_oracle: Option<Vec<Hit>> = None;
        for &shards in &SHARD_COUNTS {
            let tag = |stage: &str| format!("{label}/s{shards}/{stage}");
            let mut sc = ShardedCorpus::new(
                &m,
                corpus.clone(),
                4,
                refine_config(lambda, kernel, None),
                sharding(shards),
            )
            .unwrap();
            assert_eq!(sc.shard_count(), shards);
            let (hits, report) = sc.search(&q, K).unwrap();
            assert_equiv(&hits, &brute, DIST_TOL, &tag("pre"));
            assert_eq!(report.solved + report.pruned, n, "{}", tag("pre"));
            assert_eq!(report.failed, 0, "{}", tag("pre"));
            if label == "truncated" {
                truncated_rescues += report.rescued;
            }

            mutate(&mut sc, &extra, &tombstones);
            let (hits, report) = sc.search(&q, K).unwrap();
            assert_eq!(report.corpus, n, "{}: 3 inserts − 3 tombstones", tag("post"));
            assert!(
                hits.iter().all(|h| !tombstones.contains(&h.entry)),
                "{}: tombstoned entries resurfaced: {hits:?}",
                tag("post")
            );
            let brute_post = sc.brute_force(&q, K).unwrap();
            assert_equiv(&hits, &brute_post, DIST_TOL, &tag("post/self"));
            // Every variant's post-mutation view must agree with the
            // first (1-shard ≡ monolithic) oracle.
            match &post_oracle {
                None => post_oracle = Some(brute_post),
                Some(oracle) => {
                    assert_equiv(&brute_post, oracle, DIST_TOL, &tag("post/brute"));
                    assert_equiv(&hits, oracle, DIST_TOL, &tag("post/pruned"));
                }
            }
        }
        if label == "truncated" {
            assert!(
                truncated_rescues > 0,
                "no truncated solve was rescued — the gate was never exercised"
            );
        }
    }
}

/// Backend sweep (the existing exactness matrix, Exact included):
/// shard-count invariance holds under every solve strategy, with a
/// quick insert/tombstone/compact cycle per variant.
#[test]
fn sharded_topk_matches_brute_force_across_backends() {
    let d = 16;
    let n = release_else(64, 24);
    let backends = [
        BackendKind::Interleaved,
        BackendKind::Dense,
        BackendKind::LogDomain,
        BackendKind::Greenkhorn,
        BackendKind::Exact,
    ];
    for (round, &kind) in backends.iter().enumerate() {
        let mut rng = seeded_rng(7000 + round as u64);
        let m = RandomMetric::new(d).sample(&mut rng);
        let corpus: Vec<Histogram> =
            (0..n).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let q = Histogram::sample_uniform(d, &mut rng);
        let mut config = refine_config(9.0, KernelPolicy::Dense, Some(kind));
        if kind == BackendKind::Greenkhorn {
            // Greedy single-coordinate updates crawl at 1e-12; the
            // invariance claim is unaffected (per-pair solves are
            // grouping-independent, so every variant runs the identical
            // path per pair).
            config.sinkhorn.tolerance = 1e-9;
        }
        let index = CorpusIndex::from_histograms(&m, corpus.clone(), 4).unwrap();
        let mut mono = RetrievalService::new(index, config);
        let brute = mono.brute_force(&q, 5).unwrap();
        for &shards in &SHARD_COUNTS {
            let tag = format!("{}/s{shards}", kind.as_str());
            let mut sc =
                ShardedCorpus::new(&m, corpus.clone(), 4, config, sharding(shards))
                    .unwrap();
            let (hits, report) = sc.search(&q, 5).unwrap();
            assert_equiv(&hits, &brute, DIST_TOL, &tag);
            assert_eq!(report.failed, 0, "{tag}");
            // Mutation cycle: an inserted duplicate of the query must
            // surface, the tombstoned previous best must vanish, and
            // pruned ≡ merged brute force still holds after compaction.
            let dup = sc.insert(q.clone()).unwrap();
            assert_eq!(dup, n, "{tag}: fresh corpus-global id");
            assert!(sc.tombstone(brute[0].entry), "{tag}");
            sc.compact();
            let post_brute = sc.brute_force(&q, 5).unwrap();
            let (post_hits, _) = sc.search(&q, 5).unwrap();
            assert_equiv(&post_hits, &post_brute, DIST_TOL, &format!("{tag}/post"));
            assert!(
                post_hits.iter().any(|h| h.entry == dup),
                "{tag}: inserted duplicate of the query missing from top-5"
            );
            assert!(post_hits.iter().all(|h| h.entry != brute[0].entry), "{tag}");
        }
    }
}

/// PR 7: the opt-in ANN router over the merged sharded view. The exact
/// routing-disabled search is the oracle; the routed search must engage
/// the router, shortlist sublinearly, recall the oracle's top-k
/// (tie-aware, one-entry slack on this small clustered corpus), and
/// ride the insert/tombstone/compact lifecycle with routing still
/// active afterwards.
#[test]
fn routed_sharded_search_recalls_the_exact_oracle() {
    use sinkhorn_rs::retrieval::{probe_outcome, RoutingConfig};

    let d = 16;
    let per = release_else(24, 8); // 8 clusters
    let mut rng = seeded_rng(9100);
    let m = RandomMetric::new(d).sample(&mut rng);
    let gen = ClusteredCorpus::new(d, 8, per, 0.1);
    let (corpus, protos) = gen.generate(&mut rng);
    let n = corpus.len();
    let q = gen.mixture_at(&protos[0], 0.1, &mut rng);
    let config = refine_config(9.0, KernelPolicy::Dense, None);

    // The exact oracle: default (routing-disabled) sharding.
    let mut exact =
        ShardedCorpus::new(&m, corpus.clone(), 4, config, sharding(2)).unwrap();
    let (oracle, exact_report) = exact.search(&q, K).unwrap();
    assert!(!exact_report.routed, "default sharding must stay exact");
    assert_eq!(
        exact_report.shortlist, n,
        "disabled routing prices every live entry"
    );

    let routing = RoutingConfig {
        centroids: 16,
        probes: 4,
        min_shortlist: 2 * K,
        iterations: 8,
    };
    let mut sc = ShardedCorpus::new(
        &m,
        corpus.clone(),
        4,
        config,
        ShardingConfig { routing: Some(routing), ..sharding(2) },
    )
    .unwrap();
    let (hits, report) = sc.search(&q, K).unwrap();
    assert!(report.routed, "router must engage on an embeddable metric");
    assert!(
        report.shortlist < n,
        "shortlist must be sublinear: {} vs corpus {n}",
        report.shortlist
    );
    assert_eq!(
        report.solved + report.pruned,
        report.shortlist,
        "with routing on, the cascade prices exactly the shortlist"
    );
    let probe = probe_outcome(&hits, &oracle, DIST_TOL);
    assert!(
        probe.matched + 1 >= K,
        "routed recall too low: {}/{K} vs exact oracle",
        probe.matched
    );

    // Mutation lifecycle under routing: an inserted duplicate of the
    // query is assigned to its nearest centroid incrementally and must
    // surface; tombstoning hides it at shortlist time; compaction
    // rebuilds the router from the surviving entries.
    let dup = sc.insert(q.clone()).unwrap();
    assert_eq!(dup, n, "fresh corpus-global id");
    let (post_hits, post_report) = sc.search(&q, K).unwrap();
    assert!(post_report.routed);
    assert!(
        post_hits.iter().any(|h| h.entry == dup),
        "inserted duplicate of the query missing from the routed top-k"
    );
    assert!(sc.tombstone(dup), "inserted duplicate must be live");
    let (hidden_hits, _) = sc.search(&q, K).unwrap();
    assert!(
        hidden_hits.iter().all(|h| h.entry != dup),
        "tombstoned entry resurfaced through the router"
    );
    sc.compact();
    let (final_hits, final_report) = sc.search(&q, K).unwrap();
    assert!(final_report.routed, "compaction must rebuild the router");
    assert_eq!(final_report.corpus, n);
    let final_oracle = sc.brute_force(&q, K).unwrap();
    let probe = probe_outcome(&final_hits, &final_oracle, DIST_TOL);
    assert!(
        probe.matched + 1 >= K,
        "post-compaction routed recall too low: {}/{K}",
        probe.matched
    );
}

/// The off-engine-thread contract: a large corpus search (with a
/// brute-force recall probe riding on it) runs concurrently with
/// deadline-batched distance queries, and the distance flush latency
/// gauge stays far below the search walltime. Under the pre-PR5 inline
/// design the first distance query submitted behind the search would
/// have waited out the entire walk.
#[test]
fn retrieval_never_stalls_engine_thread_deadline_flushes() {
    use sinkhorn_rs::coordinator::{
        BatcherConfig, CoordinatorConfig, CorpusId, DistanceService, MetricId,
        Query, RetrievalQuery,
    };
    use std::time::Duration;

    let d = release_else(32, 16);
    let n = release_else(512, 96);
    let mut config = CoordinatorConfig::cpu_only();
    config.cpu_workers = 2;
    config.retrieval_shards = 2;
    config.retrieval_threads = 2;
    // Probe every search: the brute-force oracle doubles the walk, the
    // worst realistic stall pressure.
    config.retrieval_probe_every = 1;
    config.batcher = BatcherConfig {
        max_batch: 8,
        max_delay: Duration::from_millis(3),
        ..BatcherConfig::default()
    };
    let svc = DistanceService::start(config).unwrap();
    let mut rng = seeded_rng(8000);
    let m = RandomMetric::new(d).sample(&mut rng);
    svc.register_metric(MetricId(0), m).unwrap();
    let gen = ClusteredCorpus::new(d, 8, n / 8, 0.15);
    let (corpus, protos) = gen.generate(&mut rng);
    let indexed = svc
        .register_corpus(CorpusId(0), MetricId(0), 9.0, corpus)
        .unwrap();
    assert_eq!(indexed, (n / 8) * 8);
    let q = gen.mixture_at(&protos[0], 0.15, &mut rng);

    // Fire the search, then pump blocking distance queries at the
    // engine until it completes.
    let rx = svc
        .submit_retrieval(RetrievalQuery { corpus: CorpusId(0), r: q, k: K })
        .unwrap();
    let mut interleaved = 0u64;
    let outcome = loop {
        match rx.try_recv() {
            Ok(out) => break out.unwrap(),
            Err(std::sync::mpsc::TryRecvError::Empty) => {
                let r = Histogram::sample_uniform(d, &mut rng);
                let c = Histogram::sample_uniform(d, &mut rng);
                svc.distance(Query::new(MetricId(0), 9.0, r, c))
                    .unwrap();
                interleaved += 1;
            }
            Err(e) => panic!("retrieval promise broken: {e}"),
        }
    };
    assert_eq!(outcome.hits.len(), K);
    let probe = outcome.report.probe.expect("probe_every=1 must probe");
    assert_eq!(probe.matched, probe.k, "merged-view probe must confirm");

    let snap = svc.stats().unwrap();
    // Off-thread gauges: exactly one runtime search, walltime recorded,
    // queue drained, both shards visible.
    assert_eq!(snap.retrieval_offthread, 1);
    assert!(snap.retrieval_search_max_us > 0);
    assert_eq!(snap.retrieval_queue_depth, 0);
    // PR 8: shard gauges are keyed per corpus — one tenant registered,
    // whose row carries both shards.
    assert_eq!(snap.retrieval_shards.len(), 1, "{snap}");
    assert_eq!(snap.retrieval_shards[0].corpus, 0, "{snap}");
    assert_eq!(snap.retrieval_shards[0].shards.len(), 2, "{snap}");
    assert_eq!(snap.recall_probes, 1);
    assert!((snap.recall() - 1.0).abs() < 1e-12);

    // The stall assertion proper. `snap.max_latency_us` is the distance
    // queries' flush-latency gauge (retrieval latencies are tracked
    // separately), and the search walltime dwarfs it — under the old
    // inline design the first interleaved query's latency would have
    // been ≈ the whole search. Guarded: on a machine fast enough to
    // finish the search before one distance round-trip there is nothing
    // to measure.
    let search_us = snap.retrieval_search_max_us;
    eprintln!(
        "search {search_us} us, {interleaved} interleaved distance queries, \
         worst flush {} us",
        snap.max_latency_us
    );
    if interleaved > 0 && search_us > 60_000 {
        assert!(
            snap.max_latency_us < search_us / 3,
            "distance flushes stalled behind the search: worst {} us vs \
             search {search_us} us",
            snap.max_latency_us
        );
    } else {
        eprintln!("search finished too quickly to overlap; stall assertion skipped");
    }
    svc.shutdown();
}

/// PR 8 tenant isolation: with two retrieval dispatchers, searches of a
/// small corpus B must keep completing *while* a large corpus A is being
/// registered (index build = the heaviest bulk job), because the two
/// corpora own separate mailboxes. Under the PR 5 single-loop design
/// every B search submitted behind A's registration waited out the whole
/// build. Afterwards, a blocking insert → search → tombstone → search
/// sequence on B checks that per-corpus submission order survived the
/// dispatcher refactor, and the stats snapshot must key both tenants.
#[test]
fn tenant_b_searches_complete_during_tenant_a_registration() {
    use sinkhorn_rs::coordinator::{
        CoordinatorConfig, CorpusId, DistanceService, MetricId, RetrievalQuery,
    };
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::mpsc::channel;
    use std::time::Instant;

    let mut config = CoordinatorConfig::cpu_only();
    config.cpu_workers = 2;
    config.retrieval_shards = 1;
    config.retrieval_threads = 1;
    config.retrieval_dispatchers = 2;
    let svc = DistanceService::start(config).unwrap();
    let mut rng = seeded_rng(8800);

    // Tenant B: tiny corpus, searches return in well under a millisecond.
    let db = 8;
    let mb = RandomMetric::new(db).sample(&mut rng);
    svc.register_metric(MetricId(1), mb).unwrap();
    let corpus_b: Vec<Histogram> =
        (0..64).map(|_| Histogram::sample_uniform(db, &mut rng)).collect();
    svc.register_corpus(CorpusId(1), MetricId(1), 9.0, corpus_b).unwrap();
    let qb = Histogram::sample_uniform(db, &mut rng);
    let search_b = |k: usize| {
        svc.retrieve(RetrievalQuery { corpus: CorpusId(1), r: qb.clone(), k })
            .unwrap()
    };
    // Warm B once so executor spin-up is not part of the timed window.
    assert_eq!(search_b(3).hits.len(), 3);

    // Tenant A: large enough that the index build takes observable time.
    let da = 32;
    let na = release_else(6000, 400);
    let ma = RandomMetric::new(da).sample(&mut rng);
    svc.register_metric(MetricId(0), ma).unwrap();
    let corpus_a: Vec<Histogram> =
        (0..na).map(|_| Histogram::sample_uniform(da, &mut rng)).collect();

    let done = AtomicBool::new(false);
    let (started_tx, started_rx) = channel::<()>();
    let (during, wall) = std::thread::scope(|scope| {
        let svc = &svc;
        let done = &done;
        let handle = scope.spawn(move || {
            let t0 = Instant::now();
            started_tx.send(()).unwrap();
            let indexed = svc
                .register_corpus(CorpusId(0), MetricId(0), 9.0, corpus_a)
                .unwrap();
            done.store(true, Ordering::SeqCst);
            (indexed, t0.elapsed())
        });
        // Only count B round trips that start after A's registration was
        // handed off and finish before its ack lands: completions
        // strictly inside A's registration window.
        started_rx.recv().unwrap();
        let mut during = 0u64;
        while !done.load(Ordering::SeqCst) {
            assert_eq!(search_b(3).hits.len(), 3);
            if !done.load(Ordering::SeqCst) {
                during += 1;
            }
        }
        let (indexed, wall) = handle.join().unwrap();
        assert_eq!(indexed, na);
        (during, wall)
    });
    eprintln!(
        "corpus A registration {} us, {during} corpus-B searches completed inside it",
        wall.as_micros()
    );
    // Timing-guarded like the stall test above: on a machine that builds
    // A's index faster than a couple of B round trips there is nothing
    // to measure. `during >= 2` rules out the one search that can race
    // ahead of the registration message.
    if wall.as_millis() > 50 {
        assert!(
            during >= 2,
            "corpus B starved during corpus A's registration: only {during} \
             searches completed in {} ms",
            wall.as_millis()
        );
    } else {
        eprintln!("registration finished too quickly to overlap; isolation assertion skipped");
    }

    // Per-corpus submission order: each blocking call below acks through
    // B's mailbox, so the next observes exactly the previous one's
    // effect — an interleaved dispatcher that reordered within a corpus
    // would surface the duplicate late or resurrect the tombstone.
    let dup = svc.corpus_insert(CorpusId(1), qb.clone()).unwrap();
    assert_eq!(dup, 64, "fresh corpus-global id");
    let top = search_b(1);
    assert_eq!(top.hits[0].entry, dup, "inserted duplicate must rank first");
    assert!(svc.corpus_tombstone(CorpusId(1), dup).unwrap());
    let hidden = search_b(3);
    assert!(
        hidden.hits.iter().all(|h| h.entry != dup),
        "tombstoned entry resurfaced: {:?}",
        hidden.hits
    );

    // Both tenants keyed in one snapshot (satellite of the gauge-
    // clobbering fix): corpus 0 and corpus 1 rows coexist, and B's row
    // carries the searches we just ran.
    let snap = svc.stats().unwrap();
    let keys: Vec<u32> = snap.retrieval_shards.iter().map(|c| c.corpus).collect();
    assert_eq!(keys, vec![0, 1], "{snap}");
    // Warm search + the counted window searches + the two ordering
    // searches, at minimum (the window's last uncounted round trip may
    // add one more).
    let row_b = &snap.retrieval_shards[1];
    assert!(
        row_b.searches >= during + 3,
        "corpus B searches under-counted: {} vs at least {}",
        row_b.searches,
        during + 3
    );
    svc.shutdown();
}
