//! Bench/reproduction driver for Figure 3: the relative gap
//! (d_M^λ − d_M)/d_M between the Sinkhorn distance and the exact EMD,
//! as a boxplot series over λ, plus the wallclock of both solvers on the
//! digits workload.
//!
//! Run via `cargo bench --bench fig3_gap` (accepts BENCH_QUICK=1).

use sinkhorn_rs::exp::fig3;

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let config = fig3::Fig3Config {
        grid: if quick { 8 } else { 12 },
        pairs: if quick { 8 } else { 36 },
        ..Default::default()
    };
    eprintln!(
        "fig3_gap: grid={} (d={}), {} digit pairs, lambdas={:?}",
        config.grid,
        config.grid * config.grid,
        config.pairs,
        config.lambdas
    );
    let t0 = std::time::Instant::now();
    let points = fig3::run(&config);
    println!("{}", fig3::render(&points));
    // Shape assertions (the figure's qualitative content).
    assert!(points.windows(2).all(|w| w[1].gaps.median <= w[0].gaps.median + 1e-9),
        "median gap must decrease with lambda");
    assert!(points.iter().all(|p| p.gaps.min > -1e-9), "gap must be >= 0");
    println!("fig3_gap total {:.1}s", t0.elapsed().as_secs_f64());
}
