//! Coordinator micro-benchmarks: the cost of the batching layer itself.
//!
//! * `push/flush` throughput of the pure PendingBatcher (no threads);
//! * end-to-end service overhead per query on the CPU backend (tiny d so
//!   solve time is negligible and the plumbing dominates);
//! * service throughput vs batch width on the XLA backend (the Fig. 4
//!   "GPU" column, serving-shaped) — the batching ablation.
//!
//! Run via `cargo bench --bench batcher`.

use sinkhorn_rs::coordinator::{
    BatcherConfig, CoordinatorConfig, DistanceService, MetricId, PendingBatcher,
    Query, ShapeClass,
};
use sinkhorn_rs::metric::RandomMetric;
use sinkhorn_rs::simplex::{seeded_rng, Histogram};
use sinkhorn_rs::util::bench::Bench;
use std::time::{Duration, Instant};

fn main() {
    let bench = Bench::default();

    // --- pure batcher data structure ---
    let t = bench.report("batcher_push_pop_1k", "classes=4 max_batch=64", || {
        let mut b: PendingBatcher<u64> = PendingBatcher::new(BatcherConfig {
            max_batch: 64,
            max_delay: Duration::from_millis(1),
            ..BatcherConfig::default()
        });
        let now = Instant::now();
        let mut flushed = 0usize;
        for i in 0..1000u64 {
            let class = ShapeClass::new(MetricId((i % 4) as u32), 64, 9.0);
            if let Some(ready) = b.push(class, i, now) {
                flushed += ready.items.len();
            }
        }
        flushed += b.drain(now).into_iter().map(|r| r.items.len()).sum::<usize>();
        assert_eq!(flushed, 1000);
        flushed
    });
    println!("  -> {:.0} ns per enqueue+flush", t.median_ns / 1000.0);

    // --- service overhead per query (CPU backend, trivial work) ---
    let svc = DistanceService::start(CoordinatorConfig {
        artifact_dir: None,
        batcher: BatcherConfig {
            max_batch: 32,
            max_delay: Duration::from_micros(200),
            ..BatcherConfig::default()
        },
        cpu_iterations: 1,
        ..Default::default()
    })
    .unwrap();
    let mut rng = seeded_rng(0);
    let d = 8;
    svc.register_metric(MetricId(0), RandomMetric::new(d).sample(&mut rng)).unwrap();
    let queries: Vec<(Histogram, Histogram)> = (0..256)
        .map(|_| {
            (
                Histogram::sample_uniform(d, &mut rng),
                Histogram::sample_uniform(d, &mut rng),
            )
        })
        .collect();
    let t = bench.report("service_roundtrip_256", "cpu d=8 iters=1", || {
        let rxs: Vec<_> = queries
            .iter()
            .map(|(r, c)| {
                svc.submit(Query::new(MetricId(0), 9.0, r.clone(), c.clone()))
                .unwrap()
            })
            .collect();
        rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap().distance()).sum::<f64>()
    });
    println!("  -> {:.1} us per query (submit->response, incl. batching)", t.median_us() / 256.0);
    svc.shutdown();

    // --- batching ablation on the XLA backend ---
    let artifacts = std::path::PathBuf::from("artifacts");
    if artifacts.join("manifest.json").exists() {
        let d = 64;
        let mut rng = seeded_rng(1);
        let metric = RandomMetric::new(d).sample(&mut rng);
        let queries: Vec<(Histogram, Histogram)> = (0..64)
            .map(|_| {
                (
                    Histogram::sample_uniform(d, &mut rng),
                    Histogram::sample_uniform(d, &mut rng),
                )
            })
            .collect();
        for &max_batch in &[1usize, 4, 16, 64] {
            let svc = DistanceService::start(CoordinatorConfig {
                artifact_dir: Some(artifacts.clone()),
                batcher: BatcherConfig {
                    max_batch,
                    max_delay: Duration::from_millis(1),
                    ..BatcherConfig::default()
                },
                ..Default::default()
            })
            .unwrap();
            svc.register_metric(MetricId(0), metric.clone()).unwrap();
            svc.warmup().unwrap();
            let quick = Bench { warmup: 1, max_samples: 7, budget_secs: 20.0 };
            let t = quick.report(
                "service_xla_64queries",
                &format!("d=64 max_batch={max_batch}"),
                || {
                    let rxs: Vec<_> = queries
                        .iter()
                        .map(|(r, c)| {
                            svc.submit(Query::new(MetricId(0), 9.0, r.clone(), c.clone()))
                            .unwrap()
                        })
                        .collect();
                    rxs.into_iter()
                        .map(|rx| rx.recv().unwrap().unwrap().distance())
                        .sum::<f64>()
                },
            );
            println!(
                "  -> max_batch={max_batch}: {:.2} ms per 64 queries ({:.0} q/s)",
                t.median_ms(),
                64.0 / (t.median_ns / 1e9)
            );
            svc.shutdown();
        }
    } else {
        eprintln!("no artifacts/: skipping the XLA ablation");
    }
}
