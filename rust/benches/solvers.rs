//! Solver micro-benchmarks: the building blocks underneath the figures.
//!
//! * network simplex wallclock and pivot counts vs d;
//! * Sinkhorn CPU per-iteration cost vs d (dense) and the log-domain
//!   stabilized path's overhead factor;
//! * independence-kernel fast path vs direct O(d²) evaluation;
//! * the synthetic-digit renderer throughput.
//!
//! Run via `cargo bench --bench solvers`.

use sinkhorn_rs::data::{DigitClass, DigitConfig, SyntheticDigits};
use sinkhorn_rs::metric::{GridMetric, RandomMetric};
use sinkhorn_rs::ot::EmdSolver;
use sinkhorn_rs::simplex::{seeded_rng, Histogram};
use sinkhorn_rs::sinkhorn::{
    independence_distance, IndependenceKernel, SinkhornConfig, SinkhornEngine,
};
use sinkhorn_rs::util::bench::Bench;

fn main() {
    let bench = Bench { warmup: 1, max_samples: 9, budget_secs: 15.0 };

    // --- network simplex scaling ---
    for &d in &[32usize, 64, 128, 256] {
        let mut rng = seeded_rng(d as u64);
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        let solver = EmdSolver::new(&m);
        let plan = solver.solve(&r, &c).unwrap();
        bench.report(
            "network_simplex",
            &format!("d={d} pivots={} priced={}", plan.stats.pivots, plan.stats.arcs_priced),
            || solver.solve(&r, &c).unwrap().cost,
        );
    }

    // --- Sinkhorn per-iteration cost (fixed 20 iterations) ---
    for &d in &[64usize, 256, 512] {
        let mut rng = seeded_rng(d as u64 + 1);
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        let engine = SinkhornEngine::with_config(&m, SinkhornConfig::fixed(9.0, 20));
        let t = bench.report("sinkhorn_cpu_20it", &format!("d={d}"), || {
            engine.distance(&r, &c).value
        });
        println!(
            "  -> {:.2} us per iteration (2 matvecs of d={d})",
            t.median_us() / 20.0
        );
    }

    // --- log-domain overhead factor ---
    {
        let d = 128;
        let mut rng = seeded_rng(99);
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        let cfg = SinkhornConfig::fixed(9.0, 20);
        let dense = SinkhornEngine::with_config(&m, cfg);
        let td = bench.report("sinkhorn_dense", "d=128 20it", || dense.distance(&r, &c).value);
        let tl = bench.report("sinkhorn_logdomain", "d=128 20it", || {
            sinkhorn_rs::sinkhorn::log_domain::solve(
                m.data(), d, 9.0, &cfg, r.values(), c.values(),
            )
            .value
        });
        println!(
            "  -> log-domain costs {:.1}x the dense path (stability premium)",
            tl.median_ns / td.median_ns
        );
    }

    // --- independence kernel: direct vs Cholesky-prepared ---
    {
        let g = GridMetric::new(20, 20);
        let m2 = g.squared_cost_matrix();
        let kernel = IndependenceKernel::new(&m2).expect("EDM");
        let mut rng = seeded_rng(5);
        let r = Histogram::sample_uniform(400, &mut rng);
        let c = Histogram::sample_uniform(400, &mut rng);
        let td = bench.report("independence_direct", "d=400", || {
            independence_distance(&m2, &r, &c)
        });
        let pr = kernel.prepare(&r);
        let pc = kernel.prepare(&c);
        let tf = bench.report("independence_prepared", "d=400", || {
            kernel.distance(&pr, &pc)
        });
        println!(
            "  -> appendix-remark speedup: {:.0}x after preprocessing",
            td.median_ns / tf.median_ns
        );
    }

    // --- digit rendering throughput ---
    {
        let gen = SyntheticDigits::new(DigitConfig::default());
        let mut rng = seeded_rng(8);
        bench.report("digit_render_20x20", "", || {
            gen.sample(DigitClass(7), &mut rng).histogram.dim()
        });
    }
}
