//! Solver micro-benchmarks: the building blocks underneath the figures.
//!
//! * network simplex wallclock and pivot counts vs d;
//! * Sinkhorn CPU per-iteration cost vs d (dense) and the log-domain
//!   stabilized path's overhead factor;
//! * sequential vs sharded-thread-pool panel execution (the PR1
//!   multi-core claim; writes `BENCH_PR1.json` at the crate root);
//! * cold vs warm-started repeated-query panels and fixed-λ vs ε-scaled
//!   cold solves (the PR2 convergence-control claim; writes
//!   `BENCH_PR2.json` at the crate root);
//! * dense vs truncated vs low-rank kernel operators at serving-scale λ
//!   (the PR3 KernelOp claim; writes `BENCH_PR3.json` and hard-asserts
//!   the truncated kernel streams under half the dense entries);
//! * certified-interval width vs iteration budget at λ ∈ {9, 50} (the
//!   PR6 anytime claim; writes `BENCH_PR6.json` and hard-asserts the
//!   width is monotone nonincreasing in the budget);
//! * tracing overhead on the budgeted panel hot path (the PR9
//!   zero-overhead claim; writes `BENCH_PR9.json` — untraced runs must
//!   sit inside a 2% noise floor, 1/64 sampling inside 10%);
//! * telemetry overhead on the service round-trip path (the PR10
//!   zero-work-when-off claim; writes `BENCH_PR10.json` — the
//!   registry + windowed rollups + live 1 Hz Prometheus scrapes must
//!   stay inside 10% of the telemetry-off service);
//! * Greenkhorn greedy updates vs full Sinkhorn sweeps;
//! * independence-kernel fast path vs direct O(d²) evaluation;
//! * the synthetic-digit renderer throughput.
//!
//! Run via `cargo bench --bench solvers`.

use sinkhorn_rs::backend::{BackendKind, GreenkhornBackend, ShardedExecutor, SolverBackend};
use sinkhorn_rs::coordinator::{
    BatcherConfig, CoordinatorConfig, DistanceService, MetricId, Query,
};
use sinkhorn_rs::data::{DigitClass, DigitConfig, SyntheticDigits};
use sinkhorn_rs::linalg::KernelPolicy;
use sinkhorn_rs::metric::{GridMetric, RandomMetric};
use sinkhorn_rs::ot::EmdSolver;
use sinkhorn_rs::simplex::{seeded_rng, Histogram};
use sinkhorn_rs::sinkhorn::{
    independence_distance, log_domain, BatchSinkhorn, IndependenceKernel,
    LambdaSchedule, ScalingInit, SinkhornConfig, SinkhornEngine, SolveBudget,
};
use sinkhorn_rs::telemetry::{http_get, SloPolicy, TelemetryConfig};
use sinkhorn_rs::trace::{PanelTrace, Tenant, TraceConfig, TraceId, TraceSink};
use sinkhorn_rs::util::bench::Bench;
use sinkhorn_rs::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let bench = Bench { warmup: 1, max_samples: 9, budget_secs: 15.0 };

    // --- network simplex scaling ---
    for &d in &[32usize, 64, 128, 256] {
        let mut rng = seeded_rng(d as u64);
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        let solver = EmdSolver::new(&m);
        let plan = solver.solve(&r, &c).unwrap();
        bench.report(
            "network_simplex",
            &format!("d={d} pivots={} priced={}", plan.stats.pivots, plan.stats.arcs_priced),
            || solver.solve(&r, &c).unwrap().cost,
        );
    }

    // --- Sinkhorn per-iteration cost (fixed 20 iterations) ---
    for &d in &[64usize, 256, 512] {
        let mut rng = seeded_rng(d as u64 + 1);
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        let engine = SinkhornEngine::with_config(&m, SinkhornConfig::fixed(9.0, 20));
        let t = bench.report("sinkhorn_cpu_20it", &format!("d={d}"), || {
            engine.distance(&r, &c).value
        });
        println!(
            "  -> {:.2} us per iteration (2 matvecs of d={d})",
            t.median_us() / 20.0
        );
    }

    // --- log-domain overhead factor ---
    {
        let d = 128;
        let mut rng = seeded_rng(99);
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        let cfg = SinkhornConfig::fixed(9.0, 20);
        let dense = SinkhornEngine::with_config(&m, cfg);
        let td = bench.report("sinkhorn_dense", "d=128 20it", || dense.distance(&r, &c).value);
        let tl = bench.report("sinkhorn_logdomain", "d=128 20it", || {
            sinkhorn_rs::sinkhorn::log_domain::solve(
                m.data(), d, 9.0, &cfg, r.values(), c.values(),
            )
            .value
        });
        println!(
            "  -> log-domain costs {:.1}x the dense path (stability premium)",
            tl.median_ns / td.median_ns
        );
    }

    // --- sequential vs sharded panel execution (the PR1 claim) ---
    {
        let d = 256;
        let panel = 64;
        let iters = 20;
        let mut rng = seeded_rng(77);
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let cs: Vec<Histogram> =
            (0..panel).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let cfg = SinkhornConfig::fixed(9.0, iters);

        let sequential = BatchSinkhorn::new(&m, cfg);
        let t_seq = bench.report(
            "panel_sequential",
            &format!("d={d} n={panel} {iters}it single-thread BatchSinkhorn"),
            || sequential.distances(&r, &cs).len(),
        );

        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let mut pool = ShardedExecutor::new(&m, cfg, BackendKind::Interleaved, workers);
        let t_par = bench.report(
            "panel_sharded",
            &format!("d={d} n={panel} {iters}it workers={workers}"),
            || pool.solve_panel(&r, &cs).0.len(),
        );

        let speedup = t_seq.median_ns / t_par.median_ns;
        println!(
            "  -> sharded executor: {speedup:.2}x over single-threaded \
             BatchSinkhorn on {workers} worker(s)"
        );

        let mut doc = BTreeMap::new();
        let mut set = |k: &str, v: Json| {
            doc.insert(k.to_string(), v);
        };
        set("bench", Json::String("panel_sequential_vs_sharded".into()));
        set("status", Json::String("measured".into()));
        set("d", Json::Number(d as f64));
        set("panel", Json::Number(panel as f64));
        set("iterations", Json::Number(iters as f64));
        set("lambda", Json::Number(9.0));
        set("workers", Json::Number(workers as f64));
        set("backend", Json::String(BackendKind::Interleaved.as_str().into()));
        set("sequential_median_ns", Json::Number(t_seq.median_ns));
        set("sharded_median_ns", Json::Number(t_par.median_ns));
        set("speedup", Json::Number(speedup));
        set(
            "note",
            Json::String(
                "written by `cargo bench --bench solvers`; \
                 sequential = BatchSinkhorn, sharded = ShardedExecutor"
                    .into(),
            ),
        );
        drop(set);
        let rendered = format!("{}\n", Json::Object(doc));
        match std::fs::write("BENCH_PR1.json", &rendered) {
            Ok(()) => println!("  -> recorded BENCH_PR1.json"),
            Err(e) => eprintln!("  -> could not write BENCH_PR1.json: {e}"),
        }
        // A hard gate would flake on noisy shared runners; enforce only
        // when explicitly asked (BENCH_STRICT=1), warn loudly otherwise.
        if workers > 1 && speedup <= 1.0 {
            let msg = format!(
                "sharded executor did not beat single-threaded BatchSinkhorn \
                 ({speedup:.2}x with {workers} workers)"
            );
            if std::env::var("BENCH_STRICT").is_ok() {
                panic!("{msg}");
            }
            eprintln!("WARNING: {msg}");
        }
    }

    // --- cold vs warm repeated-query panel + ε-scaling (the PR2 claim) ---
    {
        let d = 64;
        let panel = 32;
        let mut rng = seeded_rng(2024);
        let m = RandomMetric::new(d).sample(&mut rng);
        let rs_owned: Vec<Histogram> =
            (0..panel).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let cs: Vec<Histogram> =
            (0..panel).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let rs: Vec<&Histogram> = rs_owned.iter().collect();
        let cfg = SinkhornConfig {
            lambda: 9.0,
            tolerance: 1e-8,
            max_iterations: 50_000,
            ..Default::default()
        };
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let mut ex = ShardedExecutor::new(&m, cfg, BackendKind::Interleaved, workers)
            .with_warm_store(0, 9.0, 1024);

        // Pass 1 populates the per-worker stores (all misses = cold);
        // pass 2 replays the identical query panel (all hits = warm).
        let t0 = Instant::now();
        let (cold_out, cold_reports) = ex.solve_panel_paired(&rs, &cs);
        let cold_wall = t0.elapsed();
        let t1 = Instant::now();
        let (warm_out, warm_reports) = ex.solve_panel_paired(&rs, &cs);
        let warm_wall = t1.elapsed();

        let cold_iters: usize = cold_out.iter().map(|o| o.stats.iterations).sum();
        let warm_iters: usize = warm_out.iter().map(|o| o.stats.iterations).sum();
        let hits: usize = warm_reports.iter().map(|s| s.warm_hits).sum();
        let misses: usize = cold_reports.iter().map(|s| s.warm_misses).sum();
        println!(
            "cold_vs_warm_panel       d={d} n={panel} lambda=9 tol=1e-8: \
             cold {cold_iters} iters ({:.1} ms), warm {warm_iters} iters \
             ({:.1} ms), {hits}/{panel} hits",
            cold_wall.as_secs_f64() * 1e3,
            warm_wall.as_secs_f64() * 1e3,
        );
        // Deterministic, not timing-based: warm-started repeats must need
        // strictly fewer iterations than the cold pass on the same panel.
        assert_eq!(misses, panel, "pass 1 must be all-cold");
        assert_eq!(hits, panel, "pass 2 must be all-warm");
        assert!(
            warm_iters < cold_iters,
            "warm pass took {warm_iters} iterations vs cold {cold_iters}"
        );

        // ε-scaling on a slow-mixing (high-λ) cold solve, log-domain path.
        let lam_hi = 60.0;
        let hi_cfg = SinkhornConfig {
            lambda: lam_hi,
            tolerance: 1e-8,
            max_iterations: 200_000,
            ..Default::default()
        };
        let r0 = &rs_owned[0];
        let c0 = &cs[0];
        let cold_hi =
            log_domain::solve(m.data(), d, lam_hi, &hi_cfg, r0.values(), c0.values());
        let anneal_cfg =
            SinkhornConfig { schedule: LambdaSchedule::geometric(2.0), ..hi_cfg };
        let annealed = log_domain::solve(
            m.data(), d, lam_hi, &anneal_cfg, r0.values(), c0.values(),
        );
        println!(
            "anneal_high_lambda       d={d} lambda={lam_hi}: fixed {} iters, \
             geometric(2.0) {} iters (values {:.6} / {:.6})",
            cold_hi.stats.iterations,
            annealed.stats.iterations,
            cold_hi.value,
            annealed.value,
        );

        let mut doc = BTreeMap::new();
        let mut set = |k: &str, v: Json| {
            doc.insert(k.to_string(), v);
        };
        set("bench", Json::String("cold_vs_warm_panel".into()));
        set("status", Json::String("measured".into()));
        set("d", Json::Number(d as f64));
        set("panel", Json::Number(panel as f64));
        set("lambda", Json::Number(9.0));
        set("tolerance", Json::Number(1e-8));
        set("workers", Json::Number(workers as f64));
        set("backend", Json::String(BackendKind::Interleaved.as_str().into()));
        set("cold_iterations", Json::Number(cold_iters as f64));
        set("warm_iterations", Json::Number(warm_iters as f64));
        set("warm_hits", Json::Number(hits as f64));
        set("cold_wall_ns", Json::Number(cold_wall.as_nanos() as f64));
        set("warm_wall_ns", Json::Number(warm_wall.as_nanos() as f64));
        set(
            "iteration_ratio",
            Json::Number(cold_iters as f64 / warm_iters.max(1) as f64),
        );
        set("anneal_lambda", Json::Number(lam_hi));
        set(
            "anneal_fixed_iterations",
            Json::Number(cold_hi.stats.iterations as f64),
        );
        set(
            "anneal_scheduled_iterations",
            Json::Number(annealed.stats.iterations as f64),
        );
        set(
            "note",
            Json::String(
                "written by `cargo bench --bench solvers`; cold/warm = two \
                 passes of the same query panel through a ShardedExecutor \
                 with per-worker warm-start stores; anneal = log-domain \
                 solve at high lambda, fixed vs geometric(2.0) schedule"
                    .into(),
            ),
        );
        drop(set);
        let rendered = format!("{}\n", Json::Object(doc));
        match std::fs::write("BENCH_PR2.json", &rendered) {
            Ok(()) => println!("  -> recorded BENCH_PR2.json"),
            Err(e) => eprintln!("  -> could not write BENCH_PR2.json: {e}"),
        }
    }

    // --- dense vs truncated vs low-rank kernel operators (the PR3 claim) ---
    {
        let d = 128;
        let panel = 16;
        let iters = 20;
        let mut rng = seeded_rng(3031);
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let cs: Vec<Histogram> =
            (0..panel).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();

        let mut doc = BTreeMap::new();
        let mut set = |k: &str, v: Json| {
            doc.insert(k.to_string(), v);
        };
        set("bench", Json::String("kernel_operator_panel".into()));
        set("status", Json::String("measured".into()));
        set("d", Json::Number(d as f64));
        set("panel", Json::Number(panel as f64));
        set("iterations", Json::Number(iters as f64));
        set("dense_nnz", Json::Number((d * d) as f64));

        // Truncated vs dense at the paper's serving-scale λ-quantile
        // points. The flop claim is structural, not timing-based: one
        // iteration streams 2·nnz multiply-adds per panel column, so
        // `nnz < 0.5·d²` is "strictly fewer flops" deterministically.
        for &lambda in &[50.0, 100.0] {
            let cfg = SinkhornConfig::fixed(lambda, iters);
            let dense = BackendKind::Interleaved.build(&m, cfg);
            let trunc = BackendKind::Truncated.build(&m, cfg);
            let tstats = trunc.kernel_stats();
            assert!(
                2 * tstats.nnz < d * d,
                "lambda={lambda}: truncated nnz {} must stay under 0.5·d²",
                tstats.nnz
            );
            let td = bench.report(
                "kernel_dense",
                &format!("d={d} n={panel} lambda={lambda} {iters}it"),
                || dense.solve_panel(&r, &cs).len(),
            );
            let tt = bench.report(
                "kernel_truncated",
                &format!(
                    "d={d} n={panel} lambda={lambda} {iters}it nnz={} loss={:.1e}",
                    tstats.nnz, tstats.mass_loss
                ),
                || trunc.solve_panel(&r, &cs).len(),
            );
            println!(
                "  -> lambda={lambda}: truncated streams {:.1}% of the dense \
                 entries ({:.2}x wallclock)",
                100.0 * tstats.nnz as f64 / (d * d) as f64,
                td.median_ns / tt.median_ns
            );
            let tag = format!("lam{}", lambda as u64);
            set(&format!("truncated_nnz_{tag}"), Json::Number(tstats.nnz as f64));
            set(
                &format!("truncated_mass_loss_{tag}"),
                Json::Number(tstats.mass_loss),
            );
            set(
                &format!("flops_ratio_{tag}"),
                Json::Number(tstats.nnz as f64 / (d * d) as f64),
            );
            set(&format!("dense_median_ns_{tag}"), Json::Number(td.median_ns));
            set(
                &format!("truncated_median_ns_{tag}"),
                Json::Number(tt.median_ns),
            );
        }

        // Low-rank in its natural habitat: a Gaussian kernel (squared-
        // Euclidean ground cost, the paper's footnote-1 EDM family) has
        // exponentially decaying spectrum — unlike e^{−λ‖·‖}, whose
        // polynomial eigen-tail keeps numerical rank near full.
        {
            let g = GridMetric::new(12, 12);
            let m2 = g.squared_cost_matrix();
            let dg = g.dim();
            let lambda = 0.02;
            let mut cfg = SinkhornConfig::fixed(lambda, iters);
            let dense = BackendKind::Interleaved.build(&m2, cfg);
            cfg.kernel = KernelPolicy::LowRank { max_rank: 0, tolerance: 1e-6 };
            let lowrank = BackendKind::LowRank.build(&m2, cfg);
            let ls = lowrank.kernel_stats();
            let rg = Histogram::sample_uniform(dg, &mut rng);
            let cgs: Vec<Histogram> =
                (0..panel).map(|_| Histogram::sample_uniform(dg, &mut rng)).collect();
            let td = bench.report(
                "kernel_dense_gaussian",
                &format!("d={dg} n={panel} lambda={lambda} {iters}it"),
                || dense.solve_panel(&rg, &cgs).len(),
            );
            let tl = bench.report(
                "kernel_lowrank_gaussian",
                &format!(
                    "d={dg} n={panel} lambda={lambda} {iters}it rank={}",
                    ls.rank
                ),
                || lowrank.solve_panel(&rg, &cgs).len(),
            );
            println!(
                "  -> gaussian kernel factors to rank {}/{dg} \
                 ({:.1}% of dense entry streams, {:.2}x wallclock)",
                ls.rank,
                100.0 * ls.nnz as f64 / (dg * dg) as f64,
                td.median_ns / tl.median_ns
            );
            set("lowrank_d", Json::Number(dg as f64));
            set("lowrank_lambda", Json::Number(lambda));
            set("lowrank_rank", Json::Number(ls.rank as f64));
            set("lowrank_nnz", Json::Number(ls.nnz as f64));
            set("lowrank_dense_median_ns", Json::Number(td.median_ns));
            set("lowrank_median_ns", Json::Number(tl.median_ns));
        }

        set(
            "note",
            Json::String(
                "written by `cargo bench --bench solvers`; dense/truncated = \
                 Interleaved vs Truncated backends on a median-normalized \
                 random metric; nnz is entries streamed per apply (the \
                 per-iteration flop proxy); lowrank rows use a Gaussian \
                 (squared-Euclidean) grid kernel"
                    .into(),
            ),
        );
        drop(set);
        let rendered = format!("{}\n", Json::Object(doc));
        match std::fs::write("BENCH_PR3.json", &rendered) {
            Ok(()) => println!("  -> recorded BENCH_PR3.json"),
            Err(e) => eprintln!("  -> could not write BENCH_PR3.json: {e}"),
        }
    }

    // --- anytime deadline sweep: interval width vs budget (PR6 claim) ---
    {
        let d = 64;
        let mut rng = seeded_rng(6006);
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        const BUDGETS: [usize; 6] = [8, 16, 32, 64, 128, 256];

        let mut doc = BTreeMap::new();
        let mut set = |k: &str, v: Json| {
            doc.insert(k.to_string(), v);
        };
        set("bench", Json::String("anytime_interval_sweep".into()));
        set("status", Json::String("measured".into()));
        set("d", Json::Number(d as f64));
        set("cert_stride", Json::Number(sinkhorn_rs::sinkhorn::CERT_STRIDE as f64));

        for &lambda in &[9.0, 50.0] {
            let cfg = SinkhornConfig {
                lambda,
                tolerance: 1e-9,
                max_iterations: 200_000,
                ..Default::default()
            };
            // Log-domain: exact at both λ points, so the sweep isolates
            // the certificate narrowing from stabilization rescues.
            let backend = BackendKind::LogDomain.build(&m, cfg);
            let free = backend.solve_outcome(
                &r,
                &c,
                &ScalingInit::Cold,
                SolveBudget::Unbounded,
            );
            let tag = format!("lam{}", lambda as u64);
            set(
                &format!("converged_width_{tag}"),
                Json::Number(free.interval.width()),
            );
            set(
                &format!("converged_iterations_{tag}"),
                Json::Number(free.iterations as f64),
            );
            let mut prev = f64::INFINITY;
            println!(
                "anytime_interval_sweep   d={d} lambda={lambda}: converged in \
                 {} iters at width {:.3e}",
                free.iterations,
                free.interval.width()
            );
            for &budget in &BUDGETS {
                let t = bench.report(
                    "anytime_budgeted",
                    &format!("d={d} lambda={lambda} cap={budget}"),
                    || {
                        backend
                            .solve_outcome(
                                &r,
                                &c,
                                &ScalingInit::Cold,
                                SolveBudget::Iterations(budget),
                            )
                            .interval
                            .width()
                    },
                );
                let out = backend.solve_outcome(
                    &r,
                    &c,
                    &ScalingInit::Cold,
                    SolveBudget::Iterations(budget),
                );
                let width = out.interval.width();
                println!(
                    "  -> cap={budget}: width {width:.3e} after {} iters \
                     ({:.1} us)",
                    out.iterations,
                    t.median_us()
                );
                // Deterministic anytime contract: more budget never
                // widens the certificate.
                assert!(
                    width <= prev + 1e-12 * (1.0 + prev.min(1e300)),
                    "lambda={lambda}: width grew from {prev:.3e} to \
                     {width:.3e} at cap {budget}"
                );
                prev = width;
                set(
                    &format!("width_{tag}_cap{budget}"),
                    Json::Number(width),
                );
                set(
                    &format!("median_ns_{tag}_cap{budget}"),
                    Json::Number(t.median_ns),
                );
            }
        }
        set(
            "note",
            Json::String(
                "written by `cargo bench --bench solvers`; certified interval \
                 width (hi - lo on the exact d^lambda) vs iteration budget on \
                 the log-domain backend; widths are asserted monotone \
                 nonincreasing in the budget"
                    .into(),
            ),
        );
        drop(set);
        let rendered = format!("{}\n", Json::Object(doc));
        match std::fs::write("BENCH_PR6.json", &rendered) {
            Ok(()) => println!("  -> recorded BENCH_PR6.json"),
            Err(e) => eprintln!("  -> could not write BENCH_PR6.json: {e}"),
        }
    }

    // --- tracing overhead on the budgeted panel hot path (PR9 claim) ---
    {
        let d = 64;
        let panel = 256;
        let iters = 40;
        let budget = SolveBudget::Iterations(iters);
        let mut rng = seeded_rng(9009);
        let m = RandomMetric::new(d).sample(&mut rng);
        let rs_owned: Vec<Histogram> =
            (0..panel).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let cs: Vec<Histogram> =
            (0..panel).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let rs: Vec<&Histogram> = rs_owned.iter().collect();
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let cfg = SinkhornConfig::fixed(9.0, iters);
        let mut ex = ShardedExecutor::new(&m, cfg, BackendKind::Interleaved, workers);

        // Two disabled passes bracket the noise floor: the disabled path
        // is `Option::None` branches with no timestamp reads, so any gap
        // between them is runner noise, not tracing.
        let t_off_a = bench.report(
            "trace_disabled",
            &format!("d={d} n={panel} cap={iters} workers={workers} pass=a"),
            || ex.solve_panel_outcomes(&rs, &cs, &[], budget).0.len(),
        );
        let t_off_b = bench.report(
            "trace_disabled",
            &format!("d={d} n={panel} cap={iters} workers={workers} pass=b"),
            || ex.solve_panel_outcomes(&rs, &cs, &[], budget).0.len(),
        );

        // The serving-default sampling rate: 1 of every 64 panel columns
        // carries a TraceId and emits per-slice certificate spans.
        let sink = TraceSink::new(TraceConfig { sample_every: 64, ring_capacity: 4096 });
        let traces: Vec<Option<TraceId>> = (0..panel)
            .map(|j| if j % 64 == 0 { Some(TraceId(j as u64)) } else { None })
            .collect();
        let t_on = bench.report(
            "trace_sampled_1of64",
            &format!("d={d} n={panel} cap={iters} workers={workers}"),
            || {
                ex.solve_panel_outcomes_traced(
                    &rs,
                    &cs,
                    &[],
                    budget,
                    Some(PanelTrace {
                        sink: Arc::clone(&sink),
                        tenant: Tenant::Metric(0),
                        traces: traces.clone(),
                    }),
                )
                .0
                .len()
            },
        );
        // Deterministic, not timing-based: the sampled pass recorded
        // slice spans and the rings never had to drop under this load.
        assert!(sink.span_count() > 0, "sampled columns must emit spans");
        assert_eq!(sink.dropped(), 0, "4096-span rings must absorb this load");

        let disabled_drift =
            (t_off_b.median_ns - t_off_a.median_ns).abs() / t_off_a.median_ns;
        let sampled_overhead =
            (t_on.median_ns - t_off_a.median_ns) / t_off_a.median_ns;
        println!(
            "  -> disabled-path drift {:.2}% (noise floor), 1/64-sampled \
             overhead {:+.2}%",
            100.0 * disabled_drift,
            100.0 * sampled_overhead
        );

        let mut doc = BTreeMap::new();
        let mut set = |k: &str, v: Json| {
            doc.insert(k.to_string(), v);
        };
        set("bench", Json::String("tracing_overhead_panel".into()));
        set("status", Json::String("measured".into()));
        set("d", Json::Number(d as f64));
        set("panel", Json::Number(panel as f64));
        set("iteration_cap", Json::Number(iters as f64));
        set("workers", Json::Number(workers as f64));
        set("backend", Json::String(BackendKind::Interleaved.as_str().into()));
        set("sample_every", Json::Number(64.0));
        set("disabled_a_median_ns", Json::Number(t_off_a.median_ns));
        set("disabled_b_median_ns", Json::Number(t_off_b.median_ns));
        set("sampled_median_ns", Json::Number(t_on.median_ns));
        set("disabled_drift", Json::Number(disabled_drift));
        set("sampled_overhead", Json::Number(sampled_overhead));
        set("spans_recorded", Json::Number(sink.span_count() as f64));
        set("spans_dropped", Json::Number(sink.dropped() as f64));
        set(
            "note",
            Json::String(
                "written by `cargo bench --bench solvers`; budgeted 256-column \
                 panel through ShardedExecutor::solve_panel_outcomes: two \
                 untraced passes (noise floor) vs a pass with 1/64 columns \
                 carrying a TraceId into per-slice certificate spans"
                    .into(),
            ),
        );
        drop(set);
        let rendered = format!("{}\n", Json::Object(doc));
        match std::fs::write("BENCH_PR9.json", &rendered) {
            Ok(()) => println!("  -> recorded BENCH_PR9.json"),
            Err(e) => eprintln!("  -> could not write BENCH_PR9.json: {e}"),
        }
        // Hard gates flake on noisy shared runners; enforce only under
        // BENCH_STRICT=1, warn loudly otherwise (PR1 precedent).
        if disabled_drift > 0.02 {
            let msg = format!(
                "disabled-path drift {:.2}% exceeds the 2% budget \
                 (untraced runs must be indistinguishable)",
                100.0 * disabled_drift
            );
            if std::env::var("BENCH_STRICT").is_ok() {
                panic!("{msg}");
            }
            eprintln!("WARNING: {msg}");
        }
        if sampled_overhead > 0.10 {
            let msg = format!(
                "1/64-sampled tracing costs {:.2}% over the untraced panel \
                 (budget: 10%)",
                100.0 * sampled_overhead
            );
            if std::env::var("BENCH_STRICT").is_ok() {
                panic!("{msg}");
            }
            eprintln!("WARNING: {msg}");
        }
    }

    // --- telemetry overhead on the service round-trip path (PR10 claim) ---
    {
        let d = 16;
        let burst = 48;
        let mut rng = seeded_rng(10_010);
        let rs: Vec<Histogram> =
            (0..burst).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let cs: Vec<Histogram> =
            (0..burst).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let mk_service = |telemetry: Option<TelemetryConfig>| {
            let mut cfg = CoordinatorConfig::cpu_only();
            cfg.batcher = BatcherConfig {
                max_batch: 8,
                max_delay: Duration::from_micros(200),
                ..BatcherConfig::default()
            };
            cfg.cpu_iterations = 40;
            cfg.telemetry = telemetry;
            let svc = DistanceService::start(cfg).expect("service start");
            let mut mrng = seeded_rng(10_011);
            svc.register_metric(MetricId(0), RandomMetric::new(d).sample(&mut mrng))
                .expect("register");
            svc
        };
        let run = |svc: &DistanceService| {
            let mut acc = 0usize;
            for (r, c) in rs.iter().zip(&cs) {
                let out = svc
                    .distance(Query::new(MetricId(0), 9.0, r.clone(), c.clone()))
                    .expect("distance");
                acc += out.outcome.iterations;
            }
            acc
        };

        // Two telemetry-off passes bracket the noise floor: with
        // `telemetry: None` the registry allocates no rings and the
        // recording calls reduce to today's plain-field folds.
        let svc_off = mk_service(None);
        let t_off_a = bench.report(
            "telemetry_disabled",
            &format!("d={d} burst={burst} pass=a"),
            || run(&svc_off),
        );
        let t_off_b = bench.report(
            "telemetry_disabled",
            &format!("d={d} burst={burst} pass=b"),
            || run(&svc_off),
        );

        // Telemetry on with the full stack live: windowed rollups, an
        // SLO monitor evaluating every engine turn, and a background
        // scraper hitting /metrics at 1 Hz while queries flow.
        let svc_on = mk_service(Some(TelemetryConfig {
            bind: "127.0.0.1:0".into(),
            window: Duration::from_secs(1),
            windows: 4,
            slo: Some(SloPolicy::default()),
        }));
        let addr = svc_on.scrape_addr().expect("exporter bound");
        let stop = Arc::new(AtomicBool::new(false));
        let scraper = {
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut scrapes = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    if matches!(
                        http_get(addr, "/metrics", Duration::from_secs(2)),
                        Ok((200, _))
                    ) {
                        scrapes += 1;
                    }
                    // 1 Hz cadence, chunked so shutdown is prompt.
                    for _ in 0..20 {
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        std::thread::sleep(Duration::from_millis(50));
                    }
                }
                scrapes
            })
        };
        let t_on = bench.report(
            "telemetry_on_1hz_scrapes",
            &format!("d={d} burst={burst} windows=4x1s slo=default"),
            || run(&svc_on),
        );
        stop.store(true, Ordering::Relaxed);
        let scrapes = scraper.join().expect("scraper join");

        // Deterministic, not timing-based: the exporter really served
        // registry-backed series while the bench ran.
        let (code, body) =
            http_get(addr, "/metrics", Duration::from_secs(5)).expect("final scrape");
        assert_eq!(code, 200, "/metrics must serve during load");
        assert!(
            body.contains("sinkhorn_queries_total"),
            "scrape must carry registry series"
        );

        let disabled_drift =
            (t_off_b.median_ns - t_off_a.median_ns).abs() / t_off_a.median_ns;
        let on_overhead = (t_on.median_ns - t_off_a.median_ns) / t_off_a.median_ns;
        println!(
            "  -> telemetry-off drift {:.2}% (noise floor), on+1Hz-scrapes \
             overhead {:+.2}% ({scrapes} live scrapes)",
            100.0 * disabled_drift,
            100.0 * on_overhead
        );

        let mut doc = BTreeMap::new();
        let mut set = |k: &str, v: Json| {
            doc.insert(k.to_string(), v);
        };
        set("bench", Json::String("telemetry_overhead_service".into()));
        set("status", Json::String("measured".into()));
        set("d", Json::Number(d as f64));
        set("burst", Json::Number(burst as f64));
        set("cpu_iterations", Json::Number(40.0));
        set("windows", Json::Number(4.0));
        set("window_secs", Json::Number(1.0));
        set("scrapes", Json::Number(scrapes as f64));
        set("disabled_a_median_ns", Json::Number(t_off_a.median_ns));
        set("disabled_b_median_ns", Json::Number(t_off_b.median_ns));
        set("on_median_ns", Json::Number(t_on.median_ns));
        set("disabled_drift", Json::Number(disabled_drift));
        set("on_overhead", Json::Number(on_overhead));
        set(
            "note",
            Json::String(
                "written by `cargo bench --bench solvers`; 48-query serial \
                 round-trip bursts through DistanceService: two telemetry-off \
                 passes (noise floor) vs telemetry on with 4x1s windows, a \
                 default SLO policy, and a live 1 Hz /metrics scraper"
                    .into(),
            ),
        );
        drop(set);
        let rendered = format!("{}\n", Json::Object(doc));
        match std::fs::write("BENCH_PR10.json", &rendered) {
            Ok(()) => println!("  -> recorded BENCH_PR10.json"),
            Err(e) => eprintln!("  -> could not write BENCH_PR10.json: {e}"),
        }
        // Hard gates flake on noisy shared runners; enforce only under
        // BENCH_STRICT=1, warn loudly otherwise (PR1 precedent).
        if on_overhead > 0.10 {
            let msg = format!(
                "telemetry + 1 Hz scrapes cost {:.2}% over the telemetry-off \
                 service (budget: 10%)",
                100.0 * on_overhead
            );
            if std::env::var("BENCH_STRICT").is_ok() {
                panic!("{msg}");
            }
            eprintln!("WARNING: {msg}");
        }
        svc_off.shutdown();
        svc_on.shutdown();
    }

    // --- Greenkhorn greedy updates vs full Sinkhorn sweeps ---
    {
        let d = 256;
        let mut rng = seeded_rng(88);
        let m = RandomMetric::new(d).sample(&mut rng);
        // Spiky marginals: the regime greedy selection is built for.
        let r = Histogram::sample_dirichlet(d, 0.2, &mut rng);
        let c = Histogram::sample_dirichlet(d, 0.2, &mut rng);
        let cfg = SinkhornConfig {
            lambda: 9.0,
            tolerance: 1e-4,
            max_iterations: 2_000,
            ..SinkhornConfig::converged(9.0)
        };
        let dense = SinkhornEngine::with_config(&m, cfg);
        let td = bench.report("sinkhorn_dense_tol1e4", "d=256 dirichlet(0.2)", || {
            dense.distance(&r, &c).value
        });
        let green = GreenkhornBackend::new(&m, cfg);
        let tg = bench.report("greenkhorn_tol1e4", "d=256 dirichlet(0.2)", || {
            green.solve(&r, &c, &ScalingInit::Cold).value
        });
        println!(
            "  -> greenkhorn/dense wallclock ratio {:.2}x (lower is better)",
            tg.median_ns / td.median_ns
        );
    }

    // --- independence kernel: direct vs Cholesky-prepared ---
    {
        let g = GridMetric::new(20, 20);
        let m2 = g.squared_cost_matrix();
        let kernel = IndependenceKernel::new(&m2).expect("EDM");
        let mut rng = seeded_rng(5);
        let r = Histogram::sample_uniform(400, &mut rng);
        let c = Histogram::sample_uniform(400, &mut rng);
        let td = bench.report("independence_direct", "d=400", || {
            independence_distance(&m2, &r, &c)
        });
        let pr = kernel.prepare(&r);
        let pc = kernel.prepare(&c);
        let tf = bench.report("independence_prepared", "d=400", || {
            kernel.distance(&pr, &pc)
        });
        println!(
            "  -> appendix-remark speedup: {:.0}x after preprocessing",
            td.median_ns / tf.median_ns
        );
    }

    // --- digit rendering throughput ---
    {
        let gen = SyntheticDigits::new(DigitConfig::default());
        let mut rng = seeded_rng(8);
        bench.report("digit_render_20x20", "", || {
            gen.sample(DigitClass(7), &mut rng).histogram.dim()
        });
    }
}
