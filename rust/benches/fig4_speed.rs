//! Bench/reproduction driver for Figure 4: average computational time per
//! distance vs dimension — exact EMD (network simplex) vs Sinkhorn CPU
//! (λ = 1, 9) vs Sinkhorn on the batched XLA/PJRT runtime.
//!
//! Run via `cargo bench --bench fig4_speed` (BENCH_QUICK=1 shrinks dims;
//! BENCH_FULL=1 extends to d=1024 like the paper's long tail).

use sinkhorn_rs::exp::fig4;
use sinkhorn_rs::util::bench::Bench;

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let full = std::env::var("BENCH_FULL").is_ok();
    let artifacts = std::path::PathBuf::from("artifacts");
    let config = fig4::Fig4Config {
        dims: if quick {
            vec![32, 64, 128]
        } else if full {
            vec![64, 128, 256, 512, 1024]
        } else {
            vec![64, 128, 256, 512]
        },
        artifact_dir: artifacts.join("manifest.json").exists().then_some(artifacts),
        bench: if quick {
            Bench { warmup: 0, max_samples: 3, budget_secs: 5.0 }
        } else {
            Bench { warmup: 1, max_samples: 9, budget_secs: 30.0 }
        },
        ..Default::default()
    };
    eprintln!("fig4_speed: dims={:?}", config.dims);
    let t0 = std::time::Instant::now();
    let points = fig4::run(&config);
    println!("{}", fig4::render(&points));

    // Shape assertion: Sinkhorn (lambda=9) beats exact EMD at every
    // measured dimension, by a factor that grows with d.
    let mut last_ratio = 0.0;
    for &d in &config.dims {
        let emd = points
            .iter()
            .find(|p| p.solver == "emd" && p.d == d && !p.over_cap)
            .map(|p| p.seconds_per_distance);
        let sk = points
            .iter()
            .find(|p| p.solver.starts_with("sinkhorn_cpu l=9") && p.d == d)
            .map(|p| p.seconds_per_distance);
        if let (Some(emd), Some(sk)) = (emd, sk) {
            let ratio = emd / sk;
            println!("d={d}: emd/sinkhorn(l=9) speed ratio = {ratio:.0}x");
            assert!(ratio > 1.0, "sinkhorn must win at d={d}");
            last_ratio = ratio;
        }
    }
    assert!(last_ratio > 10.0, "expected >10x at the largest dim");
    println!("fig4_speed total {:.1}s", t0.elapsed().as_secs_f64());
}
