//! Bench/reproduction driver for Figure 5: the number of Sinkhorn-Knopp
//! iterations needed to reach ‖x − x'‖₂ ≤ 0.01, vs dimension, for a grid
//! of λ — the paper's evidence that e^{−λM} diagonal dominance slows the
//! fixed point and that a fixed iteration budget is the right call on
//! parallel hardware.
//!
//! Run via `cargo bench --bench fig5_iters` (BENCH_QUICK=1 shrinks).

use sinkhorn_rs::exp::fig5;

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let config = fig5::Fig5Config {
        dims: if quick { vec![32, 64] } else { vec![64, 128, 256, 512] },
        trials: if quick { 3 } else { 8 },
        ..Default::default()
    };
    eprintln!("fig5_iters: dims={:?} lambdas={:?}", config.dims, config.lambdas);
    let t0 = std::time::Instant::now();
    let points = fig5::run(&config);
    println!("{}", fig5::render(&points));

    // Shape: iterations grow monotonically with lambda at every d.
    for &d in &config.dims {
        let series: Vec<f64> = config
            .lambdas
            .iter()
            .map(|&l| {
                points
                    .iter()
                    .find(|p| p.d == d && (p.lambda - l).abs() < 1e-12)
                    .unwrap()
                    .mean_iterations
            })
            .collect();
        assert!(
            series.windows(2).all(|w| w[0] <= w[1] + 1e-9),
            "iterations not monotone in lambda at d={d}: {series:?}"
        );
    }
    println!("fig5_iters total {:.1}s", t0.elapsed().as_secs_f64());
}
