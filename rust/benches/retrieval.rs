//! Retrieval bench: pruned top-k vs brute-force panel solves (the PR 4
//! claim; writes `BENCH_PR4.json` at the crate root), plus the PR 5
//! sharded-vs-monolithic panel (writes `BENCH_PR5.json`): the same
//! clustered workload partitioned over {1, 2, 3, 7} shards, with the
//! merged pruned top-k hard-asserted equivalent to the monolithic
//! brute-force top-k and the per-shard-count walltime recorded; plus
//! the PR 7 ANN-routing panel (writes `BENCH_PR7.json`): a 100k-entry
//! clustered corpus where the k-means router's shortlist is
//! hard-asserted to reach probed recall ≥ 0.95 at a shortlist fraction
//! under 0.1 against the exact routing-disabled oracle; plus the PR 8
//! cross-tenant isolation panel (writes `BENCH_PR8.json`): corpus B's
//! worst blocking search latency while corpus A undergoes a forced
//! full compaction, measured on the mailbox-per-corpus dispatcher (2
//! dispatchers) vs the serialized single-dispatcher baseline, with the
//! concurrent p99 hard-asserted under 25% of the serialized one.
//!
//! Workload: a clustered synthetic corpus (8 Dirichlet(0.3) prototypes,
//! 32 mixture entries each, d = 64 median-normalized random metric) and
//! a query drawn near one prototype — the corpus-has-structure regime a
//! retrieval system actually serves. Two serving rows:
//!
//! * λ = 9 with the dense kernel policy (the paper's moderate-λ
//!   serving point);
//! * λ = 50 with the default truncated policy (the high-λ point where
//!   the CSR kernel genuinely truncates and infeasible-on-support pairs
//!   route through the rescue gate).
//!
//! Both rows hard-assert, deterministically (not timing-based):
//!
//! * pruned fraction > 0.5 — the bound cascade must discard most of the
//!   corpus without a solve;
//! * pruned top-k == brute-force top-k (same entries, distances within
//!   1e-7 relative) — pruning must never change the answer.
//!
//! Run via `cargo bench --bench retrieval`.

use sinkhorn_rs::data::ClusteredCorpus;
use sinkhorn_rs::linalg::KernelPolicy;
use sinkhorn_rs::metric::RandomMetric;
use sinkhorn_rs::retrieval::{
    CorpusIndex, RetrievalConfig, RetrievalService, RoutingConfig, ShardedCorpus,
    ShardingConfig,
};
use sinkhorn_rs::simplex::seeded_rng;
use sinkhorn_rs::util::json::Json;
use sinkhorn_rs::F;
use std::collections::BTreeMap;
use std::time::Instant;

const D: usize = 64;
const CLUSTERS: usize = 8;
const PER_CLUSTER: usize = 32;
const K: usize = 10;
const MIX: F = 0.12;

fn main() {
    let mut rng = seeded_rng(4040);
    let m = RandomMetric::new(D).sample(&mut rng);
    let gen = ClusteredCorpus::new(D, CLUSTERS, PER_CLUSTER, MIX);
    let (corpus, protos) = gen.generate(&mut rng);
    let n = corpus.len();
    let query = gen.mixture_at(&protos[0], MIX, &mut rng);

    let mut doc = BTreeMap::new();
    let mut set = |k: &str, v: Json| {
        doc.insert(k.to_string(), v);
    };
    set("bench", Json::String("retrieval_pruned_vs_brute".into()));
    set("status", Json::String("measured".into()));
    set("d", Json::Number(D as f64));
    set("corpus", Json::Number(n as f64));
    set("clusters", Json::Number(CLUSTERS as f64));
    set("k", Json::Number(K as f64));

    let rows: [(&str, F, KernelPolicy); 2] = [
        ("dense_lam9", 9.0, KernelPolicy::Dense),
        ("truncated_lam50", 50.0, KernelPolicy::truncated_default()),
    ];
    for (tag, lambda, kernel) in rows {
        let index = CorpusIndex::from_histograms(&m, corpus.clone(), 4)
            .expect("bench corpus indexes");
        let mut config = RetrievalConfig::serving(lambda);
        config.sinkhorn.kernel = kernel;
        // Fresh warm state per timed pass: this bench measures the cold
        // cascade, not cache effects (solvers bench covers warm starts).
        config.warm_start = false;
        let mut svc = RetrievalService::new(index, config);

        let t0 = Instant::now();
        let brute = svc.brute_force(&query, K).expect("brute force");
        let brute_wall = t0.elapsed();
        let t1 = Instant::now();
        let (hits, report) = svc.top_k(&query, K).expect("pruned top-k");
        let pruned_wall = t1.elapsed();

        // --- exactness: pruning must not change the answer (the shared
        // contract of `retrieval::topk_equivalent`, at this bench's
        // serving tolerance — the exactness test asserts the same helper
        // at 1e-9 over a 1e-12 refine) ---
        if let Err(violation) =
            sinkhorn_rs::retrieval::topk_equivalent(&hits, &brute, 1e-7)
        {
            panic!("{tag}: pruned vs brute-force top-k diverged: {violation}");
        }
        // --- pruning power: most of the corpus never gets solved ---
        let fraction = report.pruned_fraction();
        assert!(
            fraction > 0.5,
            "{tag}: pruned fraction {fraction:.3} must exceed 0.5 \
             (solved {}, pruned {})",
            report.solved,
            report.pruned
        );
        let speedup =
            brute_wall.as_secs_f64() / pruned_wall.as_secs_f64().max(1e-12);
        println!(
            "retrieval_{tag}  d={D} corpus={n} k={K} λ={lambda}: solved {} / \
             pruned {} ({:.1}%), rescued {}, brute {:.2}s vs pruned {:.2}s \
             ({speedup:.2}x)",
            report.solved,
            report.pruned,
            100.0 * fraction,
            report.rescued,
            brute_wall.as_secs_f64(),
            pruned_wall.as_secs_f64(),
        );
        set(&format!("{tag}_lambda"), Json::Number(lambda));
        set(&format!("{tag}_solved"), Json::Number(report.solved as f64));
        set(&format!("{tag}_pruned"), Json::Number(report.pruned as f64));
        set(&format!("{tag}_pruned_fraction"), Json::Number(fraction));
        set(&format!("{tag}_rescued"), Json::Number(report.rescued as f64));
        set(&format!("{tag}_panels"), Json::Number(report.panels as f64));
        set(
            &format!("{tag}_pruned_by_tier"),
            Json::Array(vec![
                Json::Number(report.pruned_mass as f64),
                Json::Number(report.pruned_centroid as f64),
                Json::Number(report.pruned_projection as f64),
            ]),
        );
        set(&format!("{tag}_brute_wall_ns"), Json::Number(brute_wall.as_nanos() as f64));
        set(&format!("{tag}_pruned_wall_ns"), Json::Number(pruned_wall.as_nanos() as f64));
        set(&format!("{tag}_speedup"), Json::Number(speedup));
        set(&format!("{tag}_topk_match"), Json::Bool(true));
    }
    set(
        "note",
        Json::String(
            "written by `cargo bench --bench retrieval`; pruned = \
             RetrievalService::top_k (bound cascade + panel refine), brute = \
             RetrievalService::brute_force over the same executor; \
             topk_match is hard-asserted, as is pruned_fraction > 0.5; \
             pruned_by_tier = [mass, centroid, projection]"
                .into(),
        ),
    );
    drop(set);
    let rendered = format!("{}\n", Json::Object(doc));
    match std::fs::write("BENCH_PR4.json", &rendered) {
        Ok(()) => println!("  -> recorded BENCH_PR4.json"),
        Err(e) => eprintln!("  -> could not write BENCH_PR4.json: {e}"),
    }

    sharded_panel(&m, &corpus, &query);
    routing_panel();
    tenant_isolation_panel();
}

/// PR 5 panel: the dense λ = 9 serving row over {1, 2, 3, 7} shards.
/// The monolithic brute force is the oracle for every shard count
/// (hard-asserted via the shared `topk_equivalent` contract at the
/// bench's serving tolerance); per-shard-count walltime is recorded so
/// the first real `cargo bench` run documents the merge overhead.
fn sharded_panel(
    m: &sinkhorn_rs::metric::CostMatrix,
    corpus: &[sinkhorn_rs::simplex::Histogram],
    query: &sinkhorn_rs::simplex::Histogram,
) {
    let n = corpus.len();
    let mut doc = BTreeMap::new();
    let mut set = |k: &str, v: Json| {
        doc.insert(k.to_string(), v);
    };
    set("bench", Json::String("retrieval_sharded_vs_monolithic".into()));
    set("status", Json::String("measured".into()));
    set("d", Json::Number(D as f64));
    set("corpus", Json::Number(n as f64));
    set("k", Json::Number(K as f64));
    set("lambda", Json::Number(9.0));

    let mut config = RetrievalConfig::serving(9.0);
    config.sinkhorn.kernel = KernelPolicy::Dense;
    config.warm_start = false; // cold cascade on every row, like PR 4

    let index = CorpusIndex::from_histograms(m, corpus.to_vec(), 4)
        .expect("bench corpus indexes");
    let mut mono = RetrievalService::new(index, config);
    let t0 = Instant::now();
    let brute = mono.brute_force(query, K).expect("monolithic brute force");
    let mono_wall = t0.elapsed();
    set("monolithic_brute_wall_ns", Json::Number(mono_wall.as_nanos() as f64));

    for shards in [1usize, 2, 3, 7] {
        let sharding = ShardingConfig { shards, ..Default::default() };
        let mut sc =
            ShardedCorpus::new(m, corpus.to_vec(), 4, config, sharding)
                .expect("bench corpus shards");
        let t0 = Instant::now();
        let (hits, report) = sc.search(query, K).expect("sharded search");
        let wall = t0.elapsed();
        // --- exactness across the partition: merged top-k ≡ monolithic ---
        if let Err(violation) =
            sinkhorn_rs::retrieval::topk_equivalent(&hits, &brute, 1e-7)
        {
            panic!("shards={shards}: merged vs monolithic top-k diverged: {violation}");
        }
        println!(
            "retrieval_sharded s={shards}  d={D} corpus={n} k={K}: solved {} / \
             pruned {} ({:.1}%), {:.3}s (monolithic brute {:.3}s)",
            report.solved,
            report.pruned,
            100.0 * report.pruned_fraction(),
            wall.as_secs_f64(),
            mono_wall.as_secs_f64(),
        );
        set(&format!("s{shards}_wall_ns"), Json::Number(wall.as_nanos() as f64));
        set(&format!("s{shards}_solved"), Json::Number(report.solved as f64));
        set(&format!("s{shards}_pruned"), Json::Number(report.pruned as f64));
        set(
            &format!("s{shards}_pruned_fraction"),
            Json::Number(report.pruned_fraction()),
        );
        set(&format!("s{shards}_topk_match"), Json::Bool(true));
    }
    set(
        "note",
        Json::String(
            "written by `cargo bench --bench retrieval`; sharded = \
             ShardedCorpus::search (per-shard cascade + refine, associative \
             heap merge) at shard counts {1,2,3,7}, oracle = monolithic \
             RetrievalService::brute_force over the same corpus; topk_match \
             is hard-asserted via retrieval::topk_equivalent at 1e-7"
                .into(),
        ),
    );
    drop(set);
    let rendered = format!("{}\n", Json::Object(doc));
    match std::fs::write("BENCH_PR5.json", &rendered) {
        Ok(()) => println!("  -> recorded BENCH_PR5.json"),
        Err(e) => eprintln!("  -> could not write BENCH_PR5.json: {e}"),
    }
}

/// PR 7 panel: ANN routing over a ≥100k-entry clustered corpus at a
/// retrieval-friendly d = 16 (writes `BENCH_PR7.json`). The oracle is
/// the *exact* routing-disabled sharded search over the same corpus —
/// itself locked to the brute-force top-k by the exactness suites — so
/// the recall measured here is the recall of the one deliberately
/// inexact stage. Hard asserts, aggregated over every query:
///
/// * probed recall ≥ 0.95 (tie-aware, via `retrieval::probe_outcome`);
/// * shortlist fraction < 0.1 — the router must hand the exact cascade
///   under a tenth of the corpus.
fn routing_panel() {
    const RD: usize = 16;
    const RCLUSTERS: usize = 8;
    const RPER: usize = 12_500;
    const RK: usize = 10;
    const QUERIES: usize = 5;
    const RMIX: F = 0.1;

    let mut rng = seeded_rng(7070);
    let m = RandomMetric::new(RD).sample(&mut rng);
    let gen = ClusteredCorpus::new(RD, RCLUSTERS, RPER, RMIX);
    let (corpus, protos) = gen.generate(&mut rng);
    let n = corpus.len();
    assert!(n >= 100_000, "routing panel needs >= 100k entries (got {n})");
    let queries: Vec<_> = (0..QUERIES)
        .map(|q| gen.mixture_at(&protos[q % RCLUSTERS], RMIX, &mut rng))
        .collect();

    let mut config = RetrievalConfig::serving(9.0);
    config.sinkhorn.kernel = KernelPolicy::Dense;
    config.warm_start = false;
    let routing =
        RoutingConfig { centroids: 128, probes: 10, min_shortlist: 64, iterations: 8 };

    let exact_sharding = ShardingConfig { shards: 2, ..Default::default() };
    let routed_sharding = ShardingConfig {
        shards: 2,
        routing: Some(routing),
        ..Default::default()
    };
    let t0 = Instant::now();
    let mut exact =
        ShardedCorpus::new(&m, corpus.clone(), 4, config, exact_sharding)
            .expect("routing panel corpus shards");
    let exact_build = t0.elapsed();
    let t0 = Instant::now();
    let mut routed = ShardedCorpus::new(&m, corpus, 4, config, routed_sharding)
        .expect("routing panel corpus shards (routed)");
    let routed_build = t0.elapsed();

    let (mut matched, mut expected) = (0usize, 0usize);
    let (mut shortlisted, mut candidates) = (0u64, 0u64);
    let mut exact_wall = std::time::Duration::ZERO;
    let mut routed_wall = std::time::Duration::ZERO;
    for (qi, query) in queries.iter().enumerate() {
        let t0 = Instant::now();
        let (oracle, _) = exact.search(query, RK).expect("exact search");
        exact_wall += t0.elapsed();
        let t0 = Instant::now();
        let (hits, report) = routed.search(query, RK).expect("routed search");
        routed_wall += t0.elapsed();
        assert!(report.routed, "query {qi}: the router must engage");
        let probe = sinkhorn_rs::retrieval::probe_outcome(&hits, &oracle, 1e-7);
        matched += probe.matched;
        expected += probe.k;
        shortlisted += report.shortlist as u64;
        candidates += report.corpus as u64;
    }
    let recall = matched as f64 / expected.max(1) as f64;
    let fraction = shortlisted as f64 / candidates.max(1) as f64;
    // --- the PR 7 acceptance contract, hard-asserted ---
    assert!(
        recall >= 0.95,
        "routing recall {recall:.3} must reach 0.95 ({matched}/{expected})"
    );
    assert!(
        fraction < 0.1,
        "shortlist fraction {fraction:.3} must stay under 0.1 \
         ({shortlisted}/{candidates})"
    );
    let speedup =
        exact_wall.as_secs_f64() / routed_wall.as_secs_f64().max(1e-12);
    println!(
        "retrieval_routing  d={RD} corpus={n} k={RK} queries={QUERIES}: \
         recall {recall:.3}, shortlist fraction {fraction:.4}, exact {:.2}s \
         vs routed {:.2}s ({speedup:.2}x)",
        exact_wall.as_secs_f64(),
        routed_wall.as_secs_f64(),
    );

    let mut doc = BTreeMap::new();
    let mut set = |k: &str, v: Json| {
        doc.insert(k.to_string(), v);
    };
    set("bench", Json::String("retrieval_ann_routing".into()));
    set("status", Json::String("measured".into()));
    set("d", Json::Number(RD as f64));
    set("corpus", Json::Number(n as f64));
    set("clusters", Json::Number(RCLUSTERS as f64));
    set("k", Json::Number(RK as f64));
    set("queries", Json::Number(QUERIES as f64));
    set("lambda", Json::Number(9.0));
    set("shards", Json::Number(2.0));
    set("centroids", Json::Number(routing.centroids as f64));
    set("probes", Json::Number(routing.probes as f64));
    set("min_shortlist", Json::Number(routing.min_shortlist as f64));
    set("recall", Json::Number(recall));
    set("shortlist_fraction", Json::Number(fraction));
    set("matched", Json::Number(matched as f64));
    set("expected", Json::Number(expected as f64));
    set("exact_build_wall_ns", Json::Number(exact_build.as_nanos() as f64));
    set("routed_build_wall_ns", Json::Number(routed_build.as_nanos() as f64));
    set("exact_search_wall_ns", Json::Number(exact_wall.as_nanos() as f64));
    set("routed_search_wall_ns", Json::Number(routed_wall.as_nanos() as f64));
    set("speedup", Json::Number(speedup));
    set(
        "note",
        Json::String(
            "written by `cargo bench --bench retrieval`; routed = \
             ShardedCorpus::search with per-shard k-means ANN routing \
             (RoutingConfig on ShardingConfig), oracle = the exact \
             routing-disabled search over the same 100k-entry clustered \
             corpus; recall >= 0.95 and shortlist_fraction < 0.1 are \
             hard-asserted via retrieval::probe_outcome at 1e-7"
                .into(),
        ),
    );
    drop(set);
    let rendered = format!("{}\n", Json::Object(doc));
    match std::fs::write("BENCH_PR7.json", &rendered) {
        Ok(()) => println!("  -> recorded BENCH_PR7.json"),
        Err(e) => eprintln!("  -> could not write BENCH_PR7.json: {e}"),
    }
}

/// PR 8 panel: cross-tenant head-of-line blocking under a forced
/// compaction (writes `BENCH_PR8.json`). Two tenants share one
/// `RetrievalRuntime`: corpus A is large (24k entries, d = 64, one
/// shard, auto-compaction disabled) with ~20% of its entries
/// tombstoned, corpus B is tiny (24 entries, d = 8) so its searches
/// return in well under a millisecond. Each run submits A's full-shard
/// compaction, sleeps until it is *in flight* (not merely queued — lane
/// priority would trivially fix the queued case), then measures B's
/// blocking search latencies. Hard assert: with 2 dispatchers
/// (mailbox-per-corpus isolation) B's worst latency stays under 25% of
/// the single-dispatcher serialized baseline's, where every B search
/// waits out A's compaction.
fn tenant_isolation_panel() {
    use sinkhorn_rs::retrieval::{RegisterSpec, RetrievalRuntime};
    use sinkhorn_rs::simplex::Histogram;
    use std::sync::mpsc::channel;
    use std::time::Duration;

    const AD: usize = 64;
    const AN: usize = 24_000;
    const BD: usize = 8;
    const BN: usize = 24;
    const BK: usize = 2;
    const BQ: usize = 5;

    let mut rng = seeded_rng(8080);
    let ma = RandomMetric::new(AD).sample(&mut rng);
    let corpus_a: Vec<Histogram> =
        (0..AN).map(|_| Histogram::sample_uniform(AD, &mut rng)).collect();
    let mb = RandomMetric::new(BD).sample(&mut rng);
    let corpus_b: Vec<Histogram> =
        (0..BN).map(|_| Histogram::sample_uniform(BD, &mut rng)).collect();
    let qb = Histogram::sample_uniform(BD, &mut rng);

    // One run: register both tenants, tombstone ~20% of A, force A's
    // compaction, measure B's blocking search latencies (µs, queue wait
    // included) while it runs. Returns (latencies, compaction wall µs).
    let run = |dispatchers: usize| -> (Vec<u64>, u64) {
        let (fb_tx, _fb_rx) = channel();
        let rt = RetrievalRuntime::with_dispatchers(fb_tx, dispatchers);

        let mut config_a = RetrievalConfig::serving(9.0);
        config_a.warm_start = false;
        let (tx, rx) = channel();
        rt.register(
            RegisterSpec {
                corpus: 0,
                metric_key: 0,
                metric: ma.clone(),
                entries: corpus_a.clone(),
                anchors: 4,
                config: config_a,
                sharding: ShardingConfig {
                    shards: 1,
                    threads: 1,
                    // Tombstones must pile up without triggering the
                    // threshold: the panel times one explicit, full
                    // compaction.
                    compact_threshold: 2.0,
                    routing: None,
                },
            },
            Box::new(move |v| drop(tx.send(v))),
        );
        assert_eq!(rx.recv().unwrap().expect("corpus A registers"), AN);

        let mut config_b = RetrievalConfig::serving(9.0);
        config_b.warm_start = false;
        config_b.workers = 1;
        let (tx, rx) = channel();
        rt.register(
            RegisterSpec {
                corpus: 1,
                metric_key: 1,
                metric: mb.clone(),
                entries: corpus_b.clone(),
                anchors: 4,
                config: config_b,
                sharding: ShardingConfig { shards: 1, threads: 1, ..Default::default() },
            },
            Box::new(move |v| drop(tx.send(v))),
        );
        assert_eq!(rx.recv().unwrap().expect("corpus B registers"), BN);

        let search_b = || -> u64 {
            let (tx, rx) = channel();
            rt.search(
                1,
                qb.clone(),
                BK,
                Instant::now(),
                Box::new(move |v| drop(tx.send(v))),
            );
            rx.recv().unwrap().expect("corpus B search").latency_us
        };
        // Warm B once so executor spin-up stays outside the window.
        search_b();

        // Tombstone every 5th entry of A, acks drained before the
        // compaction is submitted (its mailbox must be empty so the
        // compaction is the in-flight job, not the tail of a queue).
        let (tx, rx) = channel();
        for e in 0..AN / 5 {
            let tx = tx.clone();
            rt.tombstone(0, e * 5, Box::new(move |v| drop(tx.send(v))));
        }
        drop(tx);
        let mut hit = 0usize;
        while let Ok(res) = rx.recv() {
            hit += usize::from(res.expect("tombstone"));
        }
        assert_eq!(hit, AN / 5);

        let (tx, rx) = channel();
        let compact_t0 = Instant::now();
        rt.compact(0, Box::new(move |v| drop(tx.send(v))));
        // Let the compaction get dequeued and *running* before B's
        // searches fire; with one dispatcher they now measure true
        // head-of-line blocking behind an in-flight bulk job.
        std::thread::sleep(Duration::from_millis(20));
        let lats: Vec<u64> = (0..BQ).map(|_| search_b()).collect();
        let rebuilt = rx.recv().unwrap().expect("compact");
        let compact_wall_us = compact_t0.elapsed().as_micros() as u64;
        assert!(rebuilt >= 1, "forced compaction must rebuild the shard");
        (lats, compact_wall_us)
    };

    let (ser_lats, ser_compact_us) = run(1);
    let (iso_lats, iso_compact_us) = run(2);
    let p99_ser = *ser_lats.iter().max().expect("serialized latencies");
    let p99_iso = *iso_lats.iter().max().expect("concurrent latencies");
    let ratio = p99_iso as f64 / p99_ser.max(1) as f64;
    println!(
        "retrieval_tenant_isolation  A={AN}x{AD}d (compact {:.0} ms \
         serialized, {:.0} ms concurrent), B={BN}x{BD}d k={BK}: B p99 \
         {p99_ser} µs serialized vs {p99_iso} µs concurrent ({ratio:.4}x)",
        ser_compact_us as f64 / 1e3,
        iso_compact_us as f64 / 1e3,
    );
    // --- the PR 8 acceptance contract, hard-asserted ---
    assert!(
        ratio < 0.25,
        "tenant isolation regressed: corpus B p99 {p99_iso} µs under \
         concurrent compaction must stay below 25% of the serialized \
         baseline's {p99_ser} µs"
    );

    let mut doc = BTreeMap::new();
    let mut set = |k: &str, v: Json| {
        doc.insert(k.to_string(), v);
    };
    set("bench", Json::String("retrieval_tenant_isolation".into()));
    set("status", Json::String("measured".into()));
    set("a_corpus", Json::Number(AN as f64));
    set("a_d", Json::Number(AD as f64));
    set("a_tombstoned", Json::Number((AN / 5) as f64));
    set("b_corpus", Json::Number(BN as f64));
    set("b_d", Json::Number(BD as f64));
    set("b_k", Json::Number(BK as f64));
    set("b_searches", Json::Number(BQ as f64));
    set("serialized_compact_wall_us", Json::Number(ser_compact_us as f64));
    set("concurrent_compact_wall_us", Json::Number(iso_compact_us as f64));
    set(
        "serialized_latencies_us",
        Json::Array(ser_lats.iter().map(|&l| Json::Number(l as f64)).collect()),
    );
    set(
        "concurrent_latencies_us",
        Json::Array(iso_lats.iter().map(|&l| Json::Number(l as f64)).collect()),
    );
    set("serialized_p99_us", Json::Number(p99_ser as f64));
    set("concurrent_p99_us", Json::Number(p99_iso as f64));
    set("p99_ratio", Json::Number(ratio));
    set(
        "note",
        Json::String(
            "written by `cargo bench --bench retrieval`; serialized = \
             RetrievalRuntime::with_dispatchers(.., 1) (the PR 5 one-loop \
             behavior), concurrent = with_dispatchers(.., 2) \
             (mailbox-per-corpus + priority lanes); latencies are corpus \
             B's blocking search round trips fired 20 ms after corpus A's \
             forced full-shard compaction was submitted; p99 = max over \
             the 5 searches; p99_ratio < 0.25 is hard-asserted"
                .into(),
        ),
    );
    drop(set);
    let rendered = format!("{}\n", Json::Object(doc));
    match std::fs::write("BENCH_PR8.json", &rendered) {
        Ok(()) => println!("  -> recorded BENCH_PR8.json"),
        Err(e) => eprintln!("  -> could not write BENCH_PR8.json: {e}"),
    }
}
