//! Dynamic batching queues — pure logic, no threads.
//!
//! Queries are grouped by [`ShapeClass`] (metric id, dimension, quantized
//! λ): only queries sharing a class can share one vectorized execution,
//! because the artifact signature fixes (d) and the kernel matrix
//! K = e^{−λM} must be identical across the batch. A class flushes when
//!
//! * it reaches `max_batch` queued entries (size trigger), or
//! * its oldest entry has waited `max_delay` (deadline trigger), or
//! * the caller forces a drain (shutdown).
//!
//! The struct is deliberately thread-free so its invariants (no query
//! dropped, duplicated or cross-class mixed; FIFO within a class) are
//! directly property-testable.

use super::MetricId;
use crate::F;
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Batching parameters.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Flush a class once this many queries are queued. Should match the
    /// widest artifact batch width for the served dimension — or, with
    /// [`Self::scale_with_workers`], the per-worker shard width.
    pub max_batch: usize,
    /// Deadline: flush the class when its oldest query has waited this
    /// long, even if the batch is not full.
    pub max_delay: Duration,
    /// Interpret `max_batch` as a *per-worker* shard width: the service
    /// multiplies the size trigger by its CPU executor's worker count,
    /// so a full flush hands every worker one `max_batch`-wide shard.
    /// Leave off (the default) when serving through fixed-width XLA
    /// artifacts.
    pub scale_with_workers: bool,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 64,
            max_delay: Duration::from_millis(2),
            scale_with_workers: false,
        }
    }
}

impl BatcherConfig {
    /// The config the service actually runs: `max_batch` widened to feed
    /// `workers` parallel shards when [`Self::scale_with_workers`] is on.
    pub fn effective(self, workers: usize) -> BatcherConfig {
        if self.scale_with_workers {
            BatcherConfig {
                max_batch: self.max_batch.saturating_mul(workers.max(1)),
                ..self
            }
        } else {
            self
        }
    }
}

/// The routing key: queries in different classes never share a batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ShapeClass {
    pub metric: MetricId,
    pub d: usize,
    /// λ quantized to its bit pattern (exact-match routing).
    lambda_bits: u64,
}

impl ShapeClass {
    pub fn new(metric: MetricId, d: usize, lambda: F) -> Self {
        Self { metric, d, lambda_bits: lambda.to_bits() }
    }

    pub fn lambda(&self) -> F {
        F::from_bits(self.lambda_bits)
    }
}

/// One queued entry (generic payload so tests can use plain ints).
#[derive(Debug)]
struct Entry<T> {
    item: T,
    enqueued: Instant,
}

/// A batch ready for execution.
#[derive(Debug)]
pub struct ReadyBatch<T> {
    pub class: ShapeClass,
    pub items: Vec<T>,
    /// Queue latency of the oldest member at flush time.
    pub oldest_wait: Duration,
    /// True when the *size trigger* released this batch (the class filled
    /// to `max_batch`); false for deadline flushes and shutdown drains.
    /// Carried into the PR 9 batcher span payload so a flame graph shows
    /// whether a query waited for a full batch or timed out into a
    /// partial one.
    pub full: bool,
}

/// Per-class pending queues with size/deadline flush triggers.
#[derive(Debug)]
pub struct PendingBatcher<T> {
    config: BatcherConfig,
    queues: HashMap<ShapeClass, Vec<Entry<T>>>,
    len: usize,
}

impl<T> PendingBatcher<T> {
    pub fn new(config: BatcherConfig) -> Self {
        assert!(config.max_batch >= 1, "max_batch must be at least 1");
        Self { config, queues: HashMap::new(), len: 0 }
    }

    /// Total queries currently queued across classes.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of distinct classes with queued work.
    pub fn class_count(&self) -> usize {
        self.queues.len()
    }

    /// Enqueue one item; returns a full batch if the class hit the size
    /// trigger.
    pub fn push(&mut self, class: ShapeClass, item: T, now: Instant) -> Option<ReadyBatch<T>> {
        let queue = self.queues.entry(class).or_default();
        queue.push(Entry { item, enqueued: now });
        self.len += 1;
        if queue.len() >= self.config.max_batch {
            return self.take(class, now, true);
        }
        None
    }

    /// Remove and return the batch for one class (None if empty).
    fn take(
        &mut self,
        class: ShapeClass,
        now: Instant,
        full: bool,
    ) -> Option<ReadyBatch<T>> {
        let entries = self.queues.remove(&class)?;
        if entries.is_empty() {
            return None;
        }
        self.len -= entries.len();
        let oldest = entries.iter().map(|e| e.enqueued).min().unwrap();
        Some(ReadyBatch {
            class,
            items: entries.into_iter().map(|e| e.item).collect(),
            oldest_wait: now.saturating_duration_since(oldest),
            full,
        })
    }

    /// Flush every class whose oldest entry has exceeded the deadline.
    pub fn poll_expired(&mut self, now: Instant) -> Vec<ReadyBatch<T>> {
        let expired: Vec<ShapeClass> = self
            .queues
            .iter()
            .filter(|(_, q)| {
                q.first()
                    .map(|e| now.saturating_duration_since(e.enqueued) >= self.config.max_delay)
                    .unwrap_or(false)
            })
            .map(|(k, _)| *k)
            .collect();
        expired
            .into_iter()
            .filter_map(|k| self.take(k, now, false))
            .collect()
    }

    /// When the next deadline fires (None when idle). The service thread
    /// uses this as its recv timeout.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .values()
            .filter_map(|q| q.first())
            .map(|e| e.enqueued + self.config.max_delay)
            .min()
    }

    /// Drain everything (shutdown path).
    pub fn drain(&mut self, now: Instant) -> Vec<ReadyBatch<T>> {
        let keys: Vec<ShapeClass> = self.queues.keys().copied().collect();
        keys.into_iter()
            .filter_map(|k| self.take(k, now, false))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simplex::seeded_rng;

    fn class(m: u32, d: usize, lam: F) -> ShapeClass {
        ShapeClass::new(MetricId(m), d, lam)
    }

    fn cfg(max_batch: usize, ms: u64) -> BatcherConfig {
        BatcherConfig {
            max_batch,
            max_delay: Duration::from_millis(ms),
            ..BatcherConfig::default()
        }
    }

    #[test]
    fn effective_scales_only_when_asked() {
        let base = cfg(8, 1);
        assert_eq!(base.effective(4).max_batch, 8);
        let scaled = BatcherConfig { scale_with_workers: true, ..base };
        assert_eq!(scaled.effective(4).max_batch, 32);
        assert_eq!(scaled.effective(0).max_batch, 8, "workers clamp to 1");
        assert_eq!(scaled.effective(1).max_batch, 8);
    }

    #[test]
    fn size_trigger_flushes_exactly_full_batch() {
        let mut b = PendingBatcher::new(cfg(3, 1000));
        let t = Instant::now();
        assert!(b.push(class(0, 16, 9.0), 1, t).is_none());
        assert!(b.push(class(0, 16, 9.0), 2, t).is_none());
        let ready = b.push(class(0, 16, 9.0), 3, t).expect("third fills");
        assert_eq!(ready.items, vec![1, 2, 3]);
        assert!(ready.full, "size trigger marks the batch full");
        assert!(b.is_empty());
    }

    #[test]
    fn classes_do_not_mix() {
        let mut b = PendingBatcher::new(cfg(2, 1000));
        let t = Instant::now();
        assert!(b.push(class(0, 16, 9.0), 1, t).is_none());
        assert!(b.push(class(0, 16, 1.0), 10, t).is_none()); // different λ
        assert!(b.push(class(1, 16, 9.0), 20, t).is_none()); // different metric
        assert!(b.push(class(0, 32, 9.0), 30, t).is_none()); // different d
        assert_eq!(b.class_count(), 4);
        let ready = b.push(class(0, 16, 9.0), 2, t).unwrap();
        assert_eq!(ready.items, vec![1, 2]);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn deadline_trigger() {
        let mut b = PendingBatcher::new(cfg(100, 5));
        let t0 = Instant::now();
        b.push(class(0, 16, 9.0), 1, t0);
        b.push(class(0, 16, 1.0), 2, t0 + Duration::from_millis(3));
        // At +4ms nothing has expired.
        assert!(b.poll_expired(t0 + Duration::from_millis(4)).is_empty());
        // At +6ms only the first class expired.
        let ready = b.poll_expired(t0 + Duration::from_millis(6));
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].items, vec![1]);
        assert!(!ready[0].full, "deadline flush is not a full batch");
        assert!(ready[0].oldest_wait >= Duration::from_millis(5));
        // At +9ms the second follows.
        let ready = b.poll_expired(t0 + Duration::from_millis(9));
        assert_eq!(ready[0].items, vec![2]);
        assert!(b.is_empty());
    }

    #[test]
    fn next_deadline_is_min_over_classes() {
        let mut b = PendingBatcher::new(cfg(100, 10));
        let t0 = Instant::now();
        assert_eq!(b.next_deadline(), None);
        b.push(class(0, 16, 1.0), 1, t0 + Duration::from_millis(5));
        b.push(class(0, 16, 2.0), 2, t0);
        assert_eq!(b.next_deadline(), Some(t0 + Duration::from_millis(10)));
    }

    #[test]
    fn drain_returns_everything_once() {
        let mut b = PendingBatcher::new(cfg(100, 1000));
        let t = Instant::now();
        for i in 0..10 {
            b.push(class(i % 3, 16, 9.0), i, t);
        }
        let drained = b.drain(t);
        let total: usize = drained.iter().map(|r| r.items.len()).sum();
        assert_eq!(total, 10);
        assert!(b.is_empty());
        assert!(b.drain(t).is_empty());
    }

    /// Property sweep: random interleavings never drop, duplicate or
    /// reorder items within a class.
    #[test]
    fn prop_conservation_and_fifo() {
        for seed in 0..80u64 {
            let mut rng = seeded_rng(seed);
            let max_batch = rng.range_usize(1, 8);
            let mut b: PendingBatcher<(u32, usize)> =
                PendingBatcher::new(cfg(max_batch, 3));
            let t0 = Instant::now();
            let n_ops = rng.range_usize(1, 120);
            let mut sent: HashMap<u32, Vec<usize>> = HashMap::new();
            let mut received: HashMap<u32, Vec<usize>> = HashMap::new();
            let collect = |ready: Vec<ReadyBatch<(u32, usize)>>,
                               received: &mut HashMap<u32, Vec<usize>>| {
                for batch in ready {
                    assert!(batch.items.len() <= max_batch);
                    for (cls, seq) in batch.items {
                        received.entry(cls).or_default().push(seq);
                    }
                }
            };
            let mut now = t0;
            for op in 0..n_ops {
                now += Duration::from_micros(rng.range_usize(0, 2000) as u64);
                if rng.bool(0.8) {
                    let cls = rng.range_usize(0, 3) as u32;
                    let seq = sent.entry(cls).or_default().len();
                    sent.get_mut(&cls).unwrap().push(seq);
                    let out =
                        b.push(class(cls, 16, 9.0), (cls, seq), now);
                    collect(out.into_iter().collect(), &mut received);
                } else {
                    let out = b.poll_expired(now);
                    collect(out, &mut received);
                }
                let _ = op;
            }
            collect(b.drain(now), &mut received);
            assert_eq!(b.len(), 0);
            // Conservation + FIFO per class.
            for (cls, seqs) in &sent {
                let got = received.get(cls).cloned().unwrap_or_default();
                assert_eq!(&got, seqs, "class {cls} (seed {seed})");
            }
        }
    }
}
