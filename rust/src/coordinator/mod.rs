//! Layer-3 coordinator: a batched Sinkhorn-distance *service*.
//!
//! The paper's §4.1 observation — Algorithm 1 "can be used as such to
//! compute the distance between r and a family of histograms" and is
//! therefore "amenable to large scale executions on parallel platforms" —
//! is an invitation to build a serving system: individual distance
//! queries are worth batching into one vectorized execution. This module
//! is that system, shaped like a vLLM-style router:
//!
//! * [`Query`] — one distance request `(metric_id, λ, r, c)`;
//! * [`batcher`] — pure dynamic-batching queues: requests are routed by
//!   *shape class* (metric, λ, dimension) and flushed either when a class
//!   fills the artifact's batch width or when the oldest request hits the
//!   latency deadline;
//! * [`service`] — the engine thread owning the PJRT runtime and the
//!   CPU panel executors ([`crate::backend::ShardedExecutor`]: one
//!   K/Kᵀ-bound solver instance per worker thread), the mpsc plumbing
//!   and graceful shutdown; retrieval work (index builds, cascade
//!   walks, recall probes, mutations) is handed off to the
//!   [`crate::retrieval::RetrievalRuntime`] mailbox-per-corpus
//!   dispatcher so a corpus search never stalls a distance-query
//!   deadline flush — and one tenant's compaction never stalls
//!   another tenant's searches;
//! * [`metrics`] — counters/latency snapshots, including per-worker
//!   executor occupancy, per-shard retrieval gauges and off-thread
//!   search latency.
//!
//! Python never appears anywhere on this path: the engine executes
//! AOT-compiled HLO through [`crate::runtime`].

pub mod batcher;
pub mod metrics;
mod service;

pub use batcher::{BatcherConfig, PendingBatcher, ShapeClass};
pub use metrics::{CorpusGauges, StatsSnapshot, WorkerSnapshot};
pub use service::{DistanceService, ServiceError};

use crate::simplex::Histogram;
use crate::sinkhorn::{LambdaSchedule, SolveBudget, SolveOutcome};
use crate::F;

/// Identifier of a registered ground metric.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MetricId(pub u32);

/// Identifier of a registered retrieval corpus.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CorpusId(pub u32);

/// One top-k retrieval request against a registered corpus.
#[derive(Debug, Clone)]
pub struct RetrievalQuery {
    /// Corpus to search (must be registered first).
    pub corpus: CorpusId,
    /// Query histogram.
    pub r: Histogram,
    /// Neighbors requested (clamped to the corpus size).
    pub k: usize,
}

/// Completed retrieval result.
#[derive(Debug, Clone)]
pub struct RetrievalOutcome {
    /// The top-k neighbors in ascending (distance, entry) order.
    pub hits: Vec<crate::retrieval::Hit>,
    /// What the query cost and what the bound cascade pruned.
    pub report: crate::retrieval::RetrievalReport,
    /// Queue wait + search, in microseconds.
    pub latency_us: u64,
}

/// Which backend executed a query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// AOT XLA artifact via PJRT.
    Xla,
    /// Pure-Rust CPU engine (fallback / comparison).
    Cpu,
}

/// One distance request.
#[derive(Debug, Clone)]
pub struct Query {
    /// Ground metric to use (must be registered first).
    pub metric: MetricId,
    /// Entropic regularization weight λ.
    pub lambda: F,
    /// Source histogram.
    pub r: Histogram,
    /// Target histogram.
    pub c: Histogram,
    /// Anytime budget for this query. [`SolveBudget::Unbounded`] (the
    /// `Query::new` default) serves exactly as before; a deadline or
    /// iteration cap turns the CPU solve into a certified anytime solve
    /// whose [`QueryResult::outcome`] interval brackets the exact d^λ.
    /// Queries sharing one batch share one budget: the batch runs under
    /// the *tightest* member budget (earliest deadline wins).
    pub budget: SolveBudget,
}

impl Query {
    /// A query with the default unbounded budget (today's behavior).
    pub fn new(metric: MetricId, lambda: F, r: Histogram, c: Histogram) -> Self {
        Self { metric, lambda, r, c, budget: SolveBudget::Unbounded }
    }

    /// Attach an anytime budget (deadline or iteration cap).
    pub fn with_budget(mut self, budget: SolveBudget) -> Self {
        self.budget = budget;
        self
    }
}

/// Completed query result.
#[derive(Debug, Clone)]
pub struct QueryResult {
    /// The served solve: estimate, certified error interval and run
    /// metadata (iterations, stabilization, convergence). Uncertified
    /// paths (XLA artifacts) carry a vacuous interval.
    pub outcome: SolveOutcome,
    /// Backend that served it.
    pub engine: EngineKind,
    /// How many queries shared the executed batch.
    pub batch_size: usize,
    /// Queue wait + execution, in microseconds.
    pub latency_us: u64,
}

impl QueryResult {
    /// The dual-Sinkhorn divergence d_M^λ(r, c) (the estimate; callers
    /// needing certified bounds read [`Self::outcome`] directly).
    pub fn distance(&self) -> F {
        self.outcome.estimate
    }
}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Where the AOT artifacts live; `None` forces the CPU backend.
    pub artifact_dir: Option<std::path::PathBuf>,
    /// Artifact flavor to serve with.
    pub flavor: crate::runtime::Flavor,
    /// Fall back to the CPU engine when no artifact matches a query's
    /// dimension (otherwise such queries error).
    pub cpu_fallback: bool,
    /// Fixed iteration budget for CPU-backend solves (XLA artifacts carry
    /// their own baked iteration count).
    pub cpu_iterations: usize,
    /// Worker threads in the CPU panel executor. Each worker owns a
    /// private K/Kᵀ-bound [`crate::backend::SolverBackend`] instance, so
    /// panels shard across cores with zero kernel sharing. Defaults to
    /// the machine's available parallelism; 1 recovers the old
    /// single-threaded dispatch exactly. Note the memory trade:
    /// executors are cached per (metric, λ) shape class and each holds
    /// `cpu_workers` kernel copies (~3·d²·8 bytes per worker), so
    /// λ-sweeping workloads on many-core hosts should bound this.
    pub cpu_workers: usize,
    /// Solve strategy for CPU panels. `None` (the default) picks per
    /// shape class via [`crate::backend::BackendKind::auto`]: the
    /// interleaved batch walk normally, log-domain when e^{−λM}
    /// underflows.
    pub cpu_backend: Option<crate::backend::BackendKind>,
    /// Warm-start serving: when set, every CPU executor attaches one
    /// [`crate::sinkhorn::WarmStartStore`] per worker, keyed by
    /// `(MetricId, λ, query fingerprint)`, and CPU solves switch from the
    /// fixed `cpu_iterations` budget to convergence-checked mode, capped
    /// by the warm-start config's own `max_iterations` (not
    /// `cpu_iterations`, whose fixed-budget default of 20 could never
    /// converge — and only converged solves populate the stores).
    /// `None` (the default) serves exactly as before.
    pub warm_start: Option<WarmStartConfig>,
    /// Kernel materialization policy threaded into every CPU solve
    /// config ([`crate::linalg::KernelPolicy`]): auto-resolved per
    /// shape class (the default — dense normally, truncated once d·λ
    /// crosses the sparsity-profitable threshold), or pinned to dense /
    /// threshold-truncated CSR / pivoted-Cholesky low-rank. An explicit
    /// `Dense` is an exactness guarantee: the auto router then never
    /// swaps in an approximate kernel. This is the serving layer's
    /// per-worker kernel memory knob — each executor worker owns one
    /// private kernel instance (dense: ~2·d²·8 bytes; truncated:
    /// ~2·nnz·8 + index bytes), so total kernel memory per shape class
    /// is `cpu_workers × kernel`; [`crate::linalg::KernelPolicy::capped`]
    /// picks a best-effort policy for an explicit byte budget (see its
    /// docs for why truncation cannot squeeze arbitrarily). Caveat for
    /// approximate kernels in *fixed-budget* serving (no `warm_start`):
    /// a truncated support that admits no plan for some (r, c) is
    /// numerically indistinguishable from ordinary unconverged mixing
    /// at tiny budgets, so such pairs are served best-effort (runaway
    /// divergence is still probed and rescued); prefer warm-start
    /// (convergence-checked) serving with non-dense policies — there
    /// the rescue contract is total and infeasible pairs always come
    /// back log-domain-exact. Orthogonal to
    /// `cpu_backend`: the policy shapes the operator inside whichever
    /// backend runs (and `BackendKind::auto` independently routes high
    /// d·λ classes to the truncated backend).
    pub kernel: crate::linalg::KernelPolicy,
    /// ε-scaling schedule threaded into every CPU solve config. With the
    /// default [`LambdaSchedule::Fixed`] nothing anneals; a
    /// [`LambdaSchedule::Geometric`] accelerates cold solves in high-λ
    /// (slow-mixing) shape classes. Warm-started solves skip the anneal
    /// prefix automatically. Note the prefix runs *in addition to*
    /// `cpu_iterations` (stats report the true total): in fixed-budget
    /// serving a schedule adds up to stages×stage_iterations per cold
    /// solve, so it pays off in convergence-checked (warm-start) mode or
    /// high-λ classes, not on tight fixed budgets. Malformed schedules
    /// (λ₀ ≤ 0 or factor ≤ 1) are rejected by `DistanceService::start`.
    pub anneal: LambdaSchedule,
    /// Dynamic batching parameters.
    pub batcher: BatcherConfig,
    /// Retrieval recall probing: every N-th `retrieve` call per corpus
    /// additionally runs the brute-force search and compares, feeding
    /// the `recall_probes` / `recall_matched` gauges (0 = never; probes
    /// solve the whole corpus, so treat this as a sampled audit, not a
    /// steady-state setting). Probes execute on the retrieval runtime
    /// thread like every other search — a probe never stalls the engine
    /// thread — and their brute-force oracle prices the *merged
    /// multi-shard view*, so what is audited is the full
    /// partition-and-merge contract, not one shard. The rest of the
    /// retrieval refine stage is derived from the serving config it
    /// rides: `cpu_workers` executor workers (divided across
    /// concurrently searched shards), `cpu_backend` pinning, the
    /// `kernel` policy, the `anneal` schedule, the batcher's effective
    /// `max_batch` as the refine panel width, and the warm-start
    /// tolerance/iteration cap when `warm_start` is set (1e-9 / 10k
    /// otherwise — retrieval always re-ranks in convergence-checked
    /// mode so the truncated-kernel rescue contract stays total).
    pub retrieval_probe_every: u64,
    /// Shards each registered corpus is partitioned into (clamped to
    /// `[1, corpus size]`). Every shard owns its own per-entry bound
    /// tables, warm cache and refine executor, and the per-shard top-k
    /// heaps merge associatively — pruned results are shard-count
    /// invariant (tie-aware), locked by `rust/tests/retrieval_sharded.rs`.
    /// Inserts route to the shard with the fewest *occupied* slots —
    /// live plus tombstoned, because tombstoned slots keep their memory
    /// until compaction and a live-only count would funnel every insert
    /// into whichever shard was just tombstone-heavy; tombstones
    /// trigger per-shard compaction at 25% dead slots.
    pub retrieval_shards: usize,
    /// Shards one retrieval query walks concurrently on the runtime
    /// thread's scoped pool (0 = available parallelism; clamped to the
    /// shard count *and* to the refine worker budget). The refine
    /// worker budget divides across them, so a sharded search does not
    /// oversubscribe the machine.
    pub retrieval_threads: usize,
    /// Load shedding: when a batch reaches the engine already *late* —
    /// its oldest query waited more than twice the batcher's
    /// `max_delay`, i.e. the engine was backlogged past the flush
    /// deadline it promised — cap the CPU solve at this many iterations
    /// instead of letting the backlog compound. Shed solves come back
    /// certified ([`QueryResult::outcome`] carries the interval), so
    /// accuracy is traded visibly, not silently; the `budget_sheds`
    /// gauge counts affected queries. `None` (the default) never sheds.
    pub shed_iterations: Option<usize>,
    /// Anytime budget for retrieval refine solves: bounded budgets turn
    /// the refine stage into a certified cheap pass that prunes
    /// candidates whose whole interval clears the top-k threshold and
    /// fully re-solves only the straddlers. [`SolveBudget::Unbounded`]
    /// (the default) reproduces the exact pipeline bit-identically.
    pub retrieval_budget: SolveBudget,
    /// Opt-in ANN routing for registered corpora
    /// ([`crate::retrieval::RoutingConfig`], threaded onto every
    /// corpus's [`crate::retrieval::ShardingConfig`]): each shard
    /// k-means-clusters its cached embedded-barycenter coordinates and
    /// the exact cascade + refine re-rank only the router's shortlist.
    /// This is the pipeline's first deliberately *inexact* stage —
    /// recall is audited by the same `retrieval_probe_every` probes and
    /// surfaced through the snapshot's `retrieval_routed` /
    /// `retrieval_shortlist_fraction` gauges. `None` (the default)
    /// keeps the exact every-live-entry walk bit-for-bit. Routing
    /// silently stays off for corpora whose ground metric does not
    /// embed (no centroid coordinates to cluster).
    pub retrieval_routing: Option<crate::retrieval::RoutingConfig>,
    /// Dispatcher threads executing retrieval mailboxes (PR 8). Each
    /// registered corpus owns a FIFO mailbox run by at most one
    /// dispatcher at a time — jobs within a corpus stay strictly
    /// serialized — while searches ride a fast lane that overtakes
    /// other tenants' queued registrations/compactions. `0` (the
    /// default) sizes to available parallelism clamped to `[2, 4]`;
    /// `1` reproduces the PR 5 fully serialized runtime (plus lane
    /// priority among queued jobs). Counts beyond the number of
    /// concurrently active corpora buy nothing.
    pub retrieval_dispatchers: usize,
    /// End-to-end query tracing (PR 9): every `sample_every`-th
    /// query/retrieval mints a [`crate::trace::TraceId`] and records
    /// typed spans across batcher, solve slices, dispatcher mailboxes
    /// and shard walks, surfaced as the snapshot's `stage_breakdown`
    /// rows and exportable as Chrome trace-event JSON
    /// ([`DistanceService::trace_sink`] +
    /// [`crate::trace::chrome_trace`]). `None` (the default) keeps
    /// tracing compiled out of the hot path behind `Option` branches:
    /// no timestamp reads, no allocation, all PR 1–8 bit-identity and
    /// latency contracts untouched.
    pub trace: Option<crate::trace::TraceConfig>,
    /// Telemetry exporter + per-tenant SLO monitor (PR 10): with a
    /// [`crate::telemetry::TelemetryConfig`] set, every `Stats`
    /// instrument additionally folds into a sliding-window ring, a
    /// std-`TcpListener` scrape server serves `/metrics` (Prometheus
    /// text exposition), `/healthz` and `/snapshot` on
    /// [`DistanceService::scrape_addr`], and an optional
    /// [`crate::telemetry::SloPolicy`] arms policy-driven load shedding
    /// for tenants whose latency SLO burns. `None` (the default) keeps
    /// all of it off: no server thread, no window rings, no clock reads
    /// on the hot path — PR 1–9 bit-identity and latency contracts
    /// untouched.
    pub telemetry: Option<crate::telemetry::TelemetryConfig>,
}

/// Warm-start serving knobs (see [`CoordinatorConfig::warm_start`]).
///
/// Only *converged* solves are cached, so warm-start mode carries its
/// own convergence iteration cap instead of borrowing `cpu_iterations`
/// (whose fixed-budget serving default of 20 could never converge — the
/// stores would silently stay empty forever).
#[derive(Debug, Clone, Copy)]
pub struct WarmStartConfig {
    /// LRU capacity (entries) of each per-worker store. One entry holds
    /// two d-vectors, so memory is ~2·d·8 bytes per entry per worker.
    pub capacity: usize,
    /// Convergence tolerance (‖Δu‖₂) for warm-start-mode CPU solves.
    pub tolerance: F,
    /// Iteration cap for warm-start-mode CPU solves. Size it for cold
    /// convergence (thousands); warm hits terminate in a few iterations
    /// regardless.
    pub max_iterations: usize,
}

impl Default for WarmStartConfig {
    fn default() -> Self {
        Self { capacity: 4096, tolerance: 1e-8, max_iterations: 10_000 }
    }
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            artifact_dir: Some(std::path::PathBuf::from("artifacts")),
            flavor: crate::runtime::Flavor::Xla,
            cpu_fallback: true,
            cpu_iterations: 20,
            cpu_workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            cpu_backend: None,
            kernel: crate::linalg::KernelPolicy::Auto,
            warm_start: None,
            anneal: LambdaSchedule::Fixed,
            batcher: BatcherConfig::default(),
            retrieval_probe_every: 0,
            retrieval_shards: 1,
            retrieval_threads: 0,
            shed_iterations: None,
            retrieval_budget: SolveBudget::Unbounded,
            retrieval_routing: None,
            retrieval_dispatchers: 0,
            trace: None,
            telemetry: None,
        }
    }
}

impl CoordinatorConfig {
    /// A CPU-only configuration (no artifacts needed) — used by tests and
    /// as the baseline in the batching ablation bench.
    pub fn cpu_only() -> Self {
        Self { artifact_dir: None, ..Default::default() }
    }

    /// A validating builder: every knob checked at construction, so a
    /// malformed config fails fast with the offending knob named instead
    /// of killing the engine thread at the first cold solve.
    pub fn builder() -> CoordinatorConfigBuilder {
        CoordinatorConfigBuilder { config: Self::default() }
    }

    /// Validate every knob. [`DistanceService::start`] calls this, so
    /// struct-literal configs get the same fail-fast treatment as
    /// builder-made ones; the builder merely moves the failure to
    /// construction time.
    pub fn validate(&self) -> Result<(), String> {
        if self.cpu_iterations == 0 {
            return Err("cpu_iterations must be at least 1".into());
        }
        if self.cpu_workers == 0 {
            return Err("cpu_workers must be at least 1".into());
        }
        if self.batcher.max_batch == 0 {
            return Err("batcher.max_batch must be at least 1".into());
        }
        if let Some(ws) = self.warm_start {
            if ws.capacity == 0 {
                return Err("warm_start.capacity must be at least 1".into());
            }
            if !(ws.tolerance > 0.0 && ws.tolerance.is_finite()) {
                return Err(format!(
                    "warm_start.tolerance must be positive and finite \
                     (got {})",
                    ws.tolerance
                ));
            }
            if ws.max_iterations == 0 {
                return Err("warm_start.max_iterations must be at least 1".into());
            }
        }
        if let Some(routing) = &self.retrieval_routing {
            routing
                .validate()
                .map_err(|e| format!("retrieval_routing: {e}"))?;
        }
        if let Some(trace) = &self.trace {
            trace.validate()?;
        }
        if let Some(telemetry) = &self.telemetry {
            telemetry.validate()?;
        }
        if self.shed_iterations == Some(0) {
            return Err(
                "shed_iterations must be at least 1 when set (shedding to \
                 zero iterations would serve the cold initialization)"
                    .into(),
            );
        }
        // The anneal schedule is only consulted inside the engine thread
        // at the first cold CPU solve, where its asserts would kill the
        // thread (and every in-flight query) long after startup looked
        // healthy.
        if let LambdaSchedule::Geometric { lambda0, factor, .. } = self.anneal {
            if lambda0 <= 0.0
                || !lambda0.is_finite()
                || factor <= 1.0
                || !factor.is_finite()
            {
                return Err(format!(
                    "anneal schedule needs lambda0 > 0 and factor > 1 \
                     (got lambda0={lambda0}, factor={factor})"
                ));
            }
        }
        // Same fail-fast treatment for the kernel policy: its parameter
        // asserts otherwise fire at KernelPolicy::build inside the
        // engine thread.
        match self.kernel {
            crate::linalg::KernelPolicy::Truncated { threshold } => {
                if !(threshold >= 0.0 && threshold < 1.0) {
                    return Err(format!(
                        "truncation threshold must be in [0, 1) (got {threshold})"
                    ));
                }
            }
            crate::linalg::KernelPolicy::LowRank { tolerance, .. } => {
                if !(tolerance >= 0.0 && tolerance.is_finite()) {
                    return Err(format!(
                        "low-rank tolerance must be finite and >= 0 \
                         (got {tolerance})"
                    ));
                }
            }
            crate::linalg::KernelPolicy::Dense
            | crate::linalg::KernelPolicy::Auto => {}
        }
        Ok(())
    }
}

/// Builder for [`CoordinatorConfig`] whose [`Self::build`] validates
/// every knob (see [`CoordinatorConfig::validate`] for the rules).
#[derive(Debug, Clone)]
pub struct CoordinatorConfigBuilder {
    config: CoordinatorConfig,
}

impl CoordinatorConfigBuilder {
    /// Serve from AOT artifacts in `dir` (CPU fallback still applies).
    pub fn artifact_dir(mut self, dir: impl Into<std::path::PathBuf>) -> Self {
        self.config.artifact_dir = Some(dir.into());
        self
    }

    /// CPU-only serving (no artifacts looked up).
    pub fn cpu_only(mut self) -> Self {
        self.config.artifact_dir = None;
        self
    }

    pub fn flavor(mut self, flavor: crate::runtime::Flavor) -> Self {
        self.config.flavor = flavor;
        self
    }

    pub fn cpu_fallback(mut self, on: bool) -> Self {
        self.config.cpu_fallback = on;
        self
    }

    pub fn cpu_iterations(mut self, iterations: usize) -> Self {
        self.config.cpu_iterations = iterations;
        self
    }

    pub fn cpu_workers(mut self, workers: usize) -> Self {
        self.config.cpu_workers = workers;
        self
    }

    pub fn cpu_backend(mut self, kind: crate::backend::BackendKind) -> Self {
        self.config.cpu_backend = Some(kind);
        self
    }

    pub fn kernel(mut self, policy: crate::linalg::KernelPolicy) -> Self {
        self.config.kernel = policy;
        self
    }

    pub fn warm_start(mut self, warm: WarmStartConfig) -> Self {
        self.config.warm_start = Some(warm);
        self
    }

    pub fn anneal(mut self, schedule: LambdaSchedule) -> Self {
        self.config.anneal = schedule;
        self
    }

    pub fn batcher(mut self, batcher: BatcherConfig) -> Self {
        self.config.batcher = batcher;
        self
    }

    pub fn retrieval_probe_every(mut self, every: u64) -> Self {
        self.config.retrieval_probe_every = every;
        self
    }

    pub fn retrieval_shards(mut self, shards: usize) -> Self {
        self.config.retrieval_shards = shards;
        self
    }

    pub fn retrieval_threads(mut self, threads: usize) -> Self {
        self.config.retrieval_threads = threads;
        self
    }

    /// See [`CoordinatorConfig::retrieval_dispatchers`].
    pub fn retrieval_dispatchers(mut self, dispatchers: usize) -> Self {
        self.config.retrieval_dispatchers = dispatchers;
        self
    }

    /// See [`CoordinatorConfig::shed_iterations`].
    pub fn shed_iterations(mut self, iterations: usize) -> Self {
        self.config.shed_iterations = Some(iterations);
        self
    }

    /// See [`CoordinatorConfig::retrieval_budget`].
    pub fn retrieval_budget(mut self, budget: SolveBudget) -> Self {
        self.config.retrieval_budget = budget;
        self
    }

    /// See [`CoordinatorConfig::retrieval_routing`].
    pub fn retrieval_routing(
        mut self,
        routing: crate::retrieval::RoutingConfig,
    ) -> Self {
        self.config.retrieval_routing = Some(routing);
        self
    }

    /// See [`CoordinatorConfig::trace`].
    pub fn trace(mut self, trace: crate::trace::TraceConfig) -> Self {
        self.config.trace = Some(trace);
        self
    }

    /// See [`CoordinatorConfig::telemetry`].
    pub fn telemetry(mut self, telemetry: crate::telemetry::TelemetryConfig) -> Self {
        self.config.telemetry = Some(telemetry);
        self
    }

    /// Validate and produce the config; `Err` names the offending knob.
    pub fn build(self) -> Result<CoordinatorConfig, String> {
        self.config.validate()?;
        Ok(self.config)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_validates() {
        CoordinatorConfig::default().validate().unwrap();
        CoordinatorConfig::cpu_only().validate().unwrap();
    }

    #[test]
    fn builder_happy_path_carries_every_knob() {
        let config = CoordinatorConfig::builder()
            .cpu_only()
            .cpu_iterations(100)
            .cpu_workers(2)
            .cpu_backend(crate::backend::BackendKind::Dense)
            .kernel(crate::linalg::KernelPolicy::Dense)
            .warm_start(WarmStartConfig::default())
            .anneal(LambdaSchedule::geometric(1.0))
            .retrieval_probe_every(3)
            .retrieval_shards(2)
            .retrieval_threads(1)
            .retrieval_dispatchers(2)
            .shed_iterations(16)
            .retrieval_budget(SolveBudget::Iterations(64))
            .retrieval_routing(crate::retrieval::RoutingConfig::default())
            .trace(crate::trace::TraceConfig {
                sample_every: 8,
                ring_capacity: 512,
            })
            .telemetry(crate::telemetry::TelemetryConfig::default())
            .build()
            .unwrap();
        assert!(config.artifact_dir.is_none());
        assert_eq!(config.cpu_iterations, 100);
        assert_eq!(config.cpu_workers, 2);
        assert_eq!(config.cpu_backend, Some(crate::backend::BackendKind::Dense));
        assert!(config.warm_start.is_some());
        assert_eq!(config.retrieval_probe_every, 3);
        assert_eq!(config.retrieval_shards, 2);
        assert_eq!(config.retrieval_threads, 1);
        assert_eq!(config.retrieval_dispatchers, 2);
        assert_eq!(config.shed_iterations, Some(16));
        assert_eq!(config.retrieval_budget, SolveBudget::Iterations(64));
        assert_eq!(
            config.retrieval_routing,
            Some(crate::retrieval::RoutingConfig::default())
        );
        assert_eq!(
            config.trace,
            Some(crate::trace::TraceConfig {
                sample_every: 8,
                ring_capacity: 512,
            })
        );
        assert_eq!(config.telemetry, Some(crate::telemetry::TelemetryConfig::default()));
    }

    #[test]
    fn malformed_telemetry_config_is_rejected() {
        let err = CoordinatorConfig::builder()
            .telemetry(crate::telemetry::TelemetryConfig {
                windows: 1,
                ..Default::default()
            })
            .build()
            .unwrap_err();
        assert!(err.contains("windows"), "{err}");
        let err = CoordinatorConfig::builder()
            .telemetry(crate::telemetry::TelemetryConfig {
                slo: Some(crate::telemetry::SloPolicy {
                    deadline_miss_budget: 0.0,
                    ..Default::default()
                }),
                ..Default::default()
            })
            .build()
            .unwrap_err();
        assert!(err.contains("deadline_miss_budget"), "{err}");
    }

    #[test]
    fn malformed_trace_config_is_rejected() {
        let err = CoordinatorConfig::builder()
            .trace(crate::trace::TraceConfig {
                sample_every: 0,
                ring_capacity: 512,
            })
            .build()
            .unwrap_err();
        assert!(err.contains("sample_every"), "{err}");
        let err = CoordinatorConfig::builder()
            .trace(crate::trace::TraceConfig {
                sample_every: 1,
                ring_capacity: 0,
            })
            .build()
            .unwrap_err();
        assert!(err.contains("ring_capacity"), "{err}");
    }

    #[test]
    fn malformed_routing_is_rejected() {
        let routing = crate::retrieval::RoutingConfig {
            centroids: 0,
            ..Default::default()
        };
        let err = CoordinatorConfig::builder()
            .retrieval_routing(routing)
            .build()
            .unwrap_err();
        assert!(err.contains("retrieval_routing"), "{err}");
    }

    #[test]
    fn zero_cpu_iterations_is_rejected() {
        let err =
            CoordinatorConfig::builder().cpu_iterations(0).build().unwrap_err();
        assert!(err.contains("cpu_iterations"), "{err}");
    }

    #[test]
    fn zero_cpu_workers_is_rejected() {
        let err = CoordinatorConfig::builder().cpu_workers(0).build().unwrap_err();
        assert!(err.contains("cpu_workers"), "{err}");
    }

    #[test]
    fn zero_max_batch_is_rejected() {
        let err = CoordinatorConfig::builder()
            .batcher(BatcherConfig { max_batch: 0, ..BatcherConfig::default() })
            .build()
            .unwrap_err();
        assert!(err.contains("max_batch"), "{err}");
    }

    #[test]
    fn bad_warm_start_knobs_are_rejected_individually() {
        let base = WarmStartConfig::default();
        for (ws, knob) in [
            (WarmStartConfig { capacity: 0, ..base }, "capacity"),
            (WarmStartConfig { tolerance: 0.0, ..base }, "tolerance"),
            (WarmStartConfig { tolerance: F::NAN, ..base }, "tolerance"),
            (WarmStartConfig { max_iterations: 0, ..base }, "max_iterations"),
        ] {
            let err =
                CoordinatorConfig::builder().warm_start(ws).build().unwrap_err();
            assert!(err.contains(knob), "expected {knob} in: {err}");
        }
    }

    #[test]
    fn zero_shed_iterations_is_rejected() {
        let err =
            CoordinatorConfig::builder().shed_iterations(0).build().unwrap_err();
        assert!(err.contains("shed_iterations"), "{err}");
    }

    #[test]
    fn malformed_anneal_is_rejected() {
        for schedule in [
            LambdaSchedule::Geometric {
                lambda0: 0.0,
                factor: 3.0,
                stage_iterations: 30,
            },
            LambdaSchedule::Geometric {
                lambda0: 1.0,
                factor: 1.0,
                stage_iterations: 30,
            },
        ] {
            let err =
                CoordinatorConfig::builder().anneal(schedule).build().unwrap_err();
            assert!(err.contains("anneal"), "{err}");
        }
    }

    #[test]
    fn malformed_kernel_policy_is_rejected() {
        use crate::linalg::KernelPolicy;
        for policy in [
            KernelPolicy::Truncated { threshold: 1.0 },
            KernelPolicy::Truncated { threshold: -0.1 },
            KernelPolicy::LowRank { max_rank: 0, tolerance: -1.0 },
        ] {
            let err =
                CoordinatorConfig::builder().kernel(policy).build().unwrap_err();
            assert!(
                err.contains("threshold") || err.contains("tolerance"),
                "{err}"
            );
        }
    }

    #[test]
    fn query_builder_defaults_to_unbounded() {
        let q = Query::new(
            MetricId(0),
            9.0,
            Histogram::uniform(4),
            Histogram::uniform(4),
        );
        assert!(q.budget.is_unbounded());
        let q = q.with_budget(SolveBudget::Iterations(8));
        assert_eq!(q.budget.iteration_cap(), Some(8));
    }
}
