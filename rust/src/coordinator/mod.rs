//! Layer-3 coordinator: a batched Sinkhorn-distance *service*.
//!
//! The paper's §4.1 observation — Algorithm 1 "can be used as such to
//! compute the distance between r and a family of histograms" and is
//! therefore "amenable to large scale executions on parallel platforms" —
//! is an invitation to build a serving system: individual distance
//! queries are worth batching into one vectorized execution. This module
//! is that system, shaped like a vLLM-style router:
//!
//! * [`Query`] — one distance request `(metric_id, λ, r, c)`;
//! * [`batcher`] — pure dynamic-batching queues: requests are routed by
//!   *shape class* (metric, λ, dimension) and flushed either when a class
//!   fills the artifact's batch width or when the oldest request hits the
//!   latency deadline;
//! * [`service`] — the engine thread owning the PJRT runtime and the
//!   CPU panel executors ([`crate::backend::ShardedExecutor`]: one
//!   K/Kᵀ-bound solver instance per worker thread), the mpsc plumbing
//!   and graceful shutdown; retrieval work (index builds, cascade
//!   walks, recall probes, mutations) is handed off to the dedicated
//!   [`crate::retrieval::RetrievalRuntime`] thread so a corpus search
//!   never stalls a distance-query deadline flush;
//! * [`metrics`] — counters/latency snapshots, including per-worker
//!   executor occupancy, per-shard retrieval gauges and off-thread
//!   search latency.
//!
//! Python never appears anywhere on this path: the engine executes
//! AOT-compiled HLO through [`crate::runtime`].

pub mod batcher;
pub mod metrics;
mod service;

pub use batcher::{BatcherConfig, PendingBatcher, ShapeClass};
pub use metrics::{StatsSnapshot, WorkerSnapshot};
pub use service::{DistanceService, ServiceError};

use crate::simplex::Histogram;
use crate::sinkhorn::LambdaSchedule;
use crate::F;

/// Identifier of a registered ground metric.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MetricId(pub u32);

/// Identifier of a registered retrieval corpus.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CorpusId(pub u32);

/// One top-k retrieval request against a registered corpus.
#[derive(Debug, Clone)]
pub struct RetrievalQuery {
    /// Corpus to search (must be registered first).
    pub corpus: CorpusId,
    /// Query histogram.
    pub r: Histogram,
    /// Neighbors requested (clamped to the corpus size).
    pub k: usize,
}

/// Completed retrieval result.
#[derive(Debug, Clone)]
pub struct RetrievalOutcome {
    /// The top-k neighbors in ascending (distance, entry) order.
    pub hits: Vec<crate::retrieval::Hit>,
    /// What the query cost and what the bound cascade pruned.
    pub report: crate::retrieval::RetrievalReport,
    /// Queue wait + search, in microseconds.
    pub latency_us: u64,
}

/// Which backend executed a query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// AOT XLA artifact via PJRT.
    Xla,
    /// Pure-Rust CPU engine (fallback / comparison).
    Cpu,
}

/// One distance request.
#[derive(Debug, Clone)]
pub struct Query {
    /// Ground metric to use (must be registered first).
    pub metric: MetricId,
    /// Entropic regularization weight λ.
    pub lambda: F,
    /// Source histogram.
    pub r: Histogram,
    /// Target histogram.
    pub c: Histogram,
}

/// Completed query result.
#[derive(Debug, Clone)]
pub struct QueryResult {
    /// The dual-Sinkhorn divergence d_M^λ(r, c).
    pub distance: F,
    /// Backend that served it.
    pub engine: EngineKind,
    /// How many queries shared the executed batch.
    pub batch_size: usize,
    /// Queue wait + execution, in microseconds.
    pub latency_us: u64,
}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Where the AOT artifacts live; `None` forces the CPU backend.
    pub artifact_dir: Option<std::path::PathBuf>,
    /// Artifact flavor to serve with.
    pub flavor: crate::runtime::Flavor,
    /// Fall back to the CPU engine when no artifact matches a query's
    /// dimension (otherwise such queries error).
    pub cpu_fallback: bool,
    /// Fixed iteration budget for CPU-backend solves (XLA artifacts carry
    /// their own baked iteration count).
    pub cpu_iterations: usize,
    /// Worker threads in the CPU panel executor. Each worker owns a
    /// private K/Kᵀ-bound [`crate::backend::SolverBackend`] instance, so
    /// panels shard across cores with zero kernel sharing. Defaults to
    /// the machine's available parallelism; 1 recovers the old
    /// single-threaded dispatch exactly. Note the memory trade:
    /// executors are cached per (metric, λ) shape class and each holds
    /// `cpu_workers` kernel copies (~3·d²·8 bytes per worker), so
    /// λ-sweeping workloads on many-core hosts should bound this.
    pub cpu_workers: usize,
    /// Solve strategy for CPU panels. `None` (the default) picks per
    /// shape class via [`crate::backend::BackendKind::auto`]: the
    /// interleaved batch walk normally, log-domain when e^{−λM}
    /// underflows.
    pub cpu_backend: Option<crate::backend::BackendKind>,
    /// Warm-start serving: when set, every CPU executor attaches one
    /// [`crate::sinkhorn::WarmStartStore`] per worker, keyed by
    /// `(MetricId, λ, query fingerprint)`, and CPU solves switch from the
    /// fixed `cpu_iterations` budget to convergence-checked mode, capped
    /// by the warm-start config's own `max_iterations` (not
    /// `cpu_iterations`, whose fixed-budget default of 20 could never
    /// converge — and only converged solves populate the stores).
    /// `None` (the default) serves exactly as before.
    pub warm_start: Option<WarmStartConfig>,
    /// Kernel materialization policy threaded into every CPU solve
    /// config ([`crate::linalg::KernelPolicy`]): auto-resolved per
    /// shape class (the default — dense normally, truncated once d·λ
    /// crosses the sparsity-profitable threshold), or pinned to dense /
    /// threshold-truncated CSR / pivoted-Cholesky low-rank. An explicit
    /// `Dense` is an exactness guarantee: the auto router then never
    /// swaps in an approximate kernel. This is the serving layer's
    /// per-worker kernel memory knob — each executor worker owns one
    /// private kernel instance (dense: ~2·d²·8 bytes; truncated:
    /// ~2·nnz·8 + index bytes), so total kernel memory per shape class
    /// is `cpu_workers × kernel`; [`crate::linalg::KernelPolicy::capped`]
    /// picks a best-effort policy for an explicit byte budget (see its
    /// docs for why truncation cannot squeeze arbitrarily). Caveat for
    /// approximate kernels in *fixed-budget* serving (no `warm_start`):
    /// a truncated support that admits no plan for some (r, c) is
    /// numerically indistinguishable from ordinary unconverged mixing
    /// at tiny budgets, so such pairs are served best-effort (runaway
    /// divergence is still probed and rescued); prefer warm-start
    /// (convergence-checked) serving with non-dense policies — there
    /// the rescue contract is total and infeasible pairs always come
    /// back log-domain-exact. Orthogonal to
    /// `cpu_backend`: the policy shapes the operator inside whichever
    /// backend runs (and `BackendKind::auto` independently routes high
    /// d·λ classes to the truncated backend).
    pub kernel: crate::linalg::KernelPolicy,
    /// ε-scaling schedule threaded into every CPU solve config. With the
    /// default [`LambdaSchedule::Fixed`] nothing anneals; a
    /// [`LambdaSchedule::Geometric`] accelerates cold solves in high-λ
    /// (slow-mixing) shape classes. Warm-started solves skip the anneal
    /// prefix automatically. Note the prefix runs *in addition to*
    /// `cpu_iterations` (stats report the true total): in fixed-budget
    /// serving a schedule adds up to stages×stage_iterations per cold
    /// solve, so it pays off in convergence-checked (warm-start) mode or
    /// high-λ classes, not on tight fixed budgets. Malformed schedules
    /// (λ₀ ≤ 0 or factor ≤ 1) are rejected by `DistanceService::start`.
    pub anneal: LambdaSchedule,
    /// Dynamic batching parameters.
    pub batcher: BatcherConfig,
    /// Retrieval recall probing: every N-th `retrieve` call per corpus
    /// additionally runs the brute-force search and compares, feeding
    /// the `recall_probes` / `recall_matched` gauges (0 = never; probes
    /// solve the whole corpus, so treat this as a sampled audit, not a
    /// steady-state setting). Probes execute on the retrieval runtime
    /// thread like every other search — a probe never stalls the engine
    /// thread — and their brute-force oracle prices the *merged
    /// multi-shard view*, so what is audited is the full
    /// partition-and-merge contract, not one shard. The rest of the
    /// retrieval refine stage is derived from the serving config it
    /// rides: `cpu_workers` executor workers (divided across
    /// concurrently searched shards), `cpu_backend` pinning, the
    /// `kernel` policy, the `anneal` schedule, the batcher's effective
    /// `max_batch` as the refine panel width, and the warm-start
    /// tolerance/iteration cap when `warm_start` is set (1e-9 / 10k
    /// otherwise — retrieval always re-ranks in convergence-checked
    /// mode so the truncated-kernel rescue contract stays total).
    pub retrieval_probe_every: u64,
    /// Shards each registered corpus is partitioned into (clamped to
    /// `[1, corpus size]`). Every shard owns its own per-entry bound
    /// tables, warm cache and refine executor, and the per-shard top-k
    /// heaps merge associatively — pruned results are shard-count
    /// invariant (tie-aware), locked by `rust/tests/retrieval_sharded.rs`.
    /// Inserts route to the emptiest shard; tombstones trigger
    /// per-shard compaction at 25% dead slots.
    pub retrieval_shards: usize,
    /// Shards one retrieval query walks concurrently on the runtime
    /// thread's scoped pool (0 = available parallelism; clamped to the
    /// shard count *and* to the refine worker budget). The refine
    /// worker budget divides across them, so a sharded search does not
    /// oversubscribe the machine.
    pub retrieval_threads: usize,
}

/// Warm-start serving knobs (see [`CoordinatorConfig::warm_start`]).
///
/// Only *converged* solves are cached, so warm-start mode carries its
/// own convergence iteration cap instead of borrowing `cpu_iterations`
/// (whose fixed-budget serving default of 20 could never converge — the
/// stores would silently stay empty forever).
#[derive(Debug, Clone, Copy)]
pub struct WarmStartConfig {
    /// LRU capacity (entries) of each per-worker store. One entry holds
    /// two d-vectors, so memory is ~2·d·8 bytes per entry per worker.
    pub capacity: usize,
    /// Convergence tolerance (‖Δu‖₂) for warm-start-mode CPU solves.
    pub tolerance: F,
    /// Iteration cap for warm-start-mode CPU solves. Size it for cold
    /// convergence (thousands); warm hits terminate in a few iterations
    /// regardless.
    pub max_iterations: usize,
}

impl Default for WarmStartConfig {
    fn default() -> Self {
        Self { capacity: 4096, tolerance: 1e-8, max_iterations: 10_000 }
    }
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            artifact_dir: Some(std::path::PathBuf::from("artifacts")),
            flavor: crate::runtime::Flavor::Xla,
            cpu_fallback: true,
            cpu_iterations: 20,
            cpu_workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            cpu_backend: None,
            kernel: crate::linalg::KernelPolicy::Auto,
            warm_start: None,
            anneal: LambdaSchedule::Fixed,
            batcher: BatcherConfig::default(),
            retrieval_probe_every: 0,
            retrieval_shards: 1,
            retrieval_threads: 0,
        }
    }
}

impl CoordinatorConfig {
    /// A CPU-only configuration (no artifacts needed) — used by tests and
    /// as the baseline in the batching ablation bench.
    pub fn cpu_only() -> Self {
        Self { artifact_dir: None, ..Default::default() }
    }
}
