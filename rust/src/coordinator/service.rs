//! The service thread: mpsc front door, dynamic batching, engine dispatch.
//!
//! A single engine thread owns the PJRT runtime (PJRT handles are not
//! `Sync`; message passing keeps the unsafe surface zero) plus one
//! [`ShardedExecutor`] per CPU shape class — the thread-pool that fans
//! each flushed panel out across `cpu_workers` private backend
//! instances — and runs the batching loop:
//!
//! ```text
//! clients --submit--> mpsc --> [route -> pending queues] --flush--> engine
//!                                  ^ size trigger  ^ deadline trigger
//! ```
//!
//! Responses travel back through per-query channels, so concurrent
//! callers can block on their own result without coordinating.

use super::batcher::{PendingBatcher, ReadyBatch, ShapeClass};
use super::metrics::{Stats, StatsSnapshot};
use super::{
    CoordinatorConfig, CorpusId, EngineKind, MetricId, Query, QueryResult,
    RetrievalOutcome, RetrievalQuery,
};
use crate::backend::ShardedExecutor;
use crate::metric::CostMatrix;
use crate::retrieval::{
    CorpusIndex, RegisterSpec, RetrievalConfig, RetrievalError, RetrievalRuntime,
    RuntimeFeedback, SearchOutcome, ShardingConfig,
};
use crate::runtime::{RuntimeError, XlaRuntime};
use crate::simplex::Histogram;
use crate::sinkhorn::{SinkhornConfig, SolveBudget, SolveOutcome};
use crate::telemetry::{
    ScrapeBody, ScrapeKind, TelemetryServer, PROMETHEUS_CONTENT_TYPE,
};
use crate::trace::{ctx, PanelTrace, Span, SpanData, Stage, Tenant, TraceId, TraceSink};
use crate::F;
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Errors surfaced to clients.
#[derive(Debug, Clone)]
pub enum ServiceError {
    UnknownMetric(MetricId),
    UnknownCorpus(CorpusId),
    DimensionMismatch { got: usize, want: usize },
    NoBackend(usize),
    InvalidConfig(String),
    Runtime(String),
    Stopped,
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::UnknownMetric(id) => {
                write!(f, "metric {id:?} is not registered")
            }
            ServiceError::UnknownCorpus(id) => {
                write!(f, "corpus {id:?} is not registered")
            }
            ServiceError::InvalidConfig(msg) => {
                write!(f, "invalid coordinator config: {msg}")
            }
            ServiceError::DimensionMismatch { got, want } => write!(
                f,
                "histogram dimension {got} does not match metric dimension {want}"
            ),
            ServiceError::NoBackend(d) => {
                write!(f, "no artifact serves d={d} and CPU fallback is disabled")
            }
            ServiceError::Runtime(msg) => write!(f, "runtime failure: {msg}"),
            ServiceError::Stopped => write!(f, "service is shut down"),
        }
    }
}

impl std::error::Error for ServiceError {}

struct Job {
    query: Query,
    enqueued: Instant,
    /// PR 9: minted at accept time for every `sample_every`-th accepted
    /// query when tracing is on; rides through the batcher so the solve
    /// panel can attribute per-slice spans back to this query.
    trace: Option<TraceId>,
    respond: Sender<Result<QueryResult, ServiceError>>,
}

enum Message {
    Query(Job),
    RegisterMetric(MetricId, CostMatrix, Sender<()>),
    /// Build a retrieval index + service over `entries` against a
    /// registered metric at the given serving λ; acks the corpus size.
    RegisterCorpus {
        id: CorpusId,
        metric: MetricId,
        lambda: F,
        entries: Vec<Histogram>,
        ack: Sender<Result<usize, ServiceError>>,
    },
    /// Pruned top-k search against a registered corpus.
    Retrieve {
        query: RetrievalQuery,
        enqueued: Instant,
        respond: Sender<Result<RetrievalOutcome, ServiceError>>,
    },
    /// Append one entry to a registered corpus (acks its fresh id).
    CorpusInsert {
        id: CorpusId,
        entry: Histogram,
        ack: Sender<Result<usize, ServiceError>>,
    },
    /// Tombstone one corpus entry (acks whether a live entry was hit).
    CorpusTombstone {
        id: CorpusId,
        entry: usize,
        ack: Sender<Result<bool, ServiceError>>,
    },
    /// Compact every shard of the corpus holding tombstones (acks how
    /// many shards rebuilt).
    CorpusCompact {
        id: CorpusId,
        ack: Sender<Result<usize, ServiceError>>,
    },
    Stats(Sender<StatsSnapshot>),
    /// One scrape-server request (PR 10): the server thread round-trips
    /// the render through the engine so instrument reads need no locks —
    /// the engine owns the registry exclusively.
    Scrape {
        kind: ScrapeKind,
        respond: Sender<ScrapeBody>,
    },
    /// Warm the XLA executable cache (compile all variants now).
    Warmup(Sender<Result<usize, ServiceError>>),
}

/// Handle to a running distance service.
///
/// Cloning is intentionally not provided on the handle itself; use
/// [`DistanceService::client`] to get cheap cloneable submitters.
pub struct DistanceService {
    tx: Sender<Message>,
    handle: Option<JoinHandle<()>>,
    /// The tracing sink shared with the engine thread (None unless
    /// [`CoordinatorConfig::trace`] is set).
    trace: Option<Arc<TraceSink>>,
    /// PR 10 scrape server (None unless [`CoordinatorConfig::telemetry`]
    /// is set and the bind succeeded). Must drop *before* `tx` during
    /// shutdown: its handler closure holds a sender clone, so the engine
    /// loop can't see Disconnected while the server lives.
    telemetry: Option<TelemetryServer>,
}

/// Cheap cloneable submission handle.
#[derive(Clone)]
pub struct ServiceClient {
    tx: Sender<Message>,
}

impl DistanceService {
    /// Spawn the engine thread.
    ///
    /// When the artifact directory is configured but unusable (missing
    /// manifest, or no PJRT backend linked into this build), behavior
    /// follows `cpu_fallback`: with it on (the default) the service
    /// starts CPU-only with a warning on stderr; with it off the error
    /// is returned fast.
    ///
    /// PJRT handles are not `Send`, so the [`XlaRuntime`] is constructed
    /// *inside* the engine thread; the init outcome is reported back over
    /// a one-shot channel before this returns.
    pub fn start(config: CoordinatorConfig) -> Result<Self, ServiceError> {
        // One consolidated validation pass ([`CoordinatorConfig::validate`]):
        // knobs whose asserts would otherwise fire inside the engine
        // thread at the first cold solve — killing every in-flight query
        // long after startup looked healthy — fail fast here instead.
        // Builder-made configs already passed this; re-running it keeps
        // struct-literal configs equally safe.
        config.validate().map_err(ServiceError::InvalidConfig)?;
        // The trace sink is shared: the engine thread (and everything
        // it fans out to) records into it, the handle exposes it for
        // export. `None` keeps every hot path on the untraced branch.
        let sink = config.trace.map(TraceSink::new);
        let engine_sink = sink.clone();
        // Captured before `config` moves into the engine thread.
        let telemetry_cfg = config.telemetry.clone();
        let (tx, rx) = channel();
        let (init_tx, init_rx) = channel::<Result<(), ServiceError>>();
        let handle = std::thread::Builder::new()
            .name("sinkhorn-engine".into())
            .spawn(move || {
                let runtime = match &config.artifact_dir {
                    Some(dir) => match XlaRuntime::new(dir) {
                        Ok(rt) => Some(rt),
                        Err(e) if config.cpu_fallback => {
                            eprintln!(
                                "sinkhorn-engine: XLA runtime unavailable \
                                 ({e}); serving on the CPU backends"
                            );
                            None
                        }
                        Err(e) => {
                            let _ = init_tx
                                .send(Err(ServiceError::Runtime(e.to_string())));
                            return;
                        }
                    },
                    None => None,
                };
                let _ = init_tx.send(Ok(()));
                EngineThread::new(config, runtime, rx, engine_sink).run()
            })
            .expect("spawn engine thread");
        match init_rx.recv() {
            Ok(Ok(())) => {
                // The scrape server binds only after the engine is up;
                // every request round-trips through the engine mailbox
                // (the engine owns the registry, so reads are lock-free).
                // A bind failure degrades to "no exporter" — the serving
                // path must not die because a metrics port is taken.
                let telemetry = telemetry_cfg.and_then(|cfg| {
                    let scrape_tx = tx.clone();
                    match TelemetryServer::start(&cfg.bind, move |kind| {
                        let (btx, brx) = channel();
                        scrape_tx
                            .send(Message::Scrape { kind, respond: btx })
                            .ok()?;
                        brx.recv_timeout(Duration::from_secs(2)).ok()
                    }) {
                        Ok(server) => Some(server),
                        Err(e) => {
                            eprintln!(
                                "sinkhorn-engine: telemetry exporter bind \
                                 failed ({e}); serving without /metrics"
                            );
                            None
                        }
                    }
                });
                Ok(Self { tx, handle: Some(handle), trace: sink, telemetry })
            }
            Ok(Err(e)) => {
                let _ = handle.join();
                Err(e)
            }
            Err(_) => Err(ServiceError::Stopped),
        }
    }

    /// The bound scrape-server address, when
    /// [`CoordinatorConfig::telemetry`] is set and the bind succeeded.
    /// With a `:0` bind this reports the resolved ephemeral port.
    pub fn scrape_addr(&self) -> Option<std::net::SocketAddr> {
        self.telemetry.as_ref().map(|s| s.addr())
    }

    /// A cloneable submitter for concurrent client threads.
    pub fn client(&self) -> ServiceClient {
        ServiceClient { tx: self.tx.clone() }
    }

    /// Register (or replace) a ground metric.
    pub fn register_metric(&self, id: MetricId, metric: CostMatrix) -> Result<(), ServiceError> {
        let (ack_tx, ack_rx) = channel();
        self.tx
            .send(Message::RegisterMetric(id, metric, ack_tx))
            .map_err(|_| ServiceError::Stopped)?;
        ack_rx.recv().map_err(|_| ServiceError::Stopped)
    }

    /// Register (or replace) a retrieval corpus bound to a registered
    /// metric at a fixed serving λ. The entries are ingested, validated
    /// and indexed (per-entry projection CDFs, centroid coordinates,
    /// warm-scaling caches) into a
    /// [`crate::retrieval::ShardedCorpus`] of
    /// [`CoordinatorConfig::retrieval_shards`] partitions whose refine
    /// stages share the service's CPU serving knobs (workers, backend
    /// pinning, kernel policy, anneal schedule — see
    /// [`CoordinatorConfig::retrieval_probe_every`] for the full
    /// derivation). Returns the indexed corpus size.
    ///
    /// Latency contract (non-blocking since PR 5): the engine thread
    /// only validates the metric and λ and hands the build off to the
    /// [`crate::retrieval::RetrievalRuntime`] dispatcher — *this
    /// caller* blocks until the index is built, but distance queries
    /// and their batcher deadline flushes are unaffected, during both
    /// registration and every subsequent [`Self::retrieve`] search or
    /// recall probe. Ordering is **per corpus** (PR 8): each corpus
    /// owns a FIFO mailbox, so its jobs execute in submission order
    /// (shards of one search run concurrently) and a search never
    /// observes a half-applied [`Self::corpus_insert`] /
    /// [`Self::corpus_tombstone`] / [`Self::corpus_compact`] — while
    /// jobs of *different* corpora run concurrently on the dispatcher
    /// pool, so this registration never delays another tenant's
    /// searches.
    ///
    /// Invalidation: re-registering the corpus's *metric* drops the
    /// corpus (its precomputed statistics would silently describe the
    /// old metric). A search already executing when the invalidation is
    /// submitted completes against the snapshot it started with —
    /// results in flight stay internally consistent; searches queued
    /// behind the invalidation (or behind a corpus re-registration that
    /// fails to build) fail with [`ServiceError::UnknownCorpus`]. The
    /// same snapshot rule applies to tombstones: an in-flight search
    /// that already dequeued keeps pricing the tombstoned entry; every
    /// search submitted after the tombstone ack excludes it.
    pub fn register_corpus(
        &self,
        id: CorpusId,
        metric: MetricId,
        lambda: F,
        entries: Vec<Histogram>,
    ) -> Result<usize, ServiceError> {
        let (ack_tx, ack_rx) = channel();
        self.tx
            .send(Message::RegisterCorpus { id, metric, lambda, entries, ack: ack_tx })
            .map_err(|_| ServiceError::Stopped)?;
        ack_rx.recv().map_err(|_| ServiceError::Stopped)?
    }

    /// Append one histogram to a registered corpus; returns its fresh
    /// corpus-global entry id (the id space `retrieve` hits report).
    /// The insert lands on exactly one shard (per-entry statistics are
    /// shard-local) on the retrieval runtime thread — the engine thread
    /// never blocks — and the entry is searchable by every query
    /// submitted after this call returns.
    pub fn corpus_insert(
        &self,
        id: CorpusId,
        entry: Histogram,
    ) -> Result<usize, ServiceError> {
        let (ack_tx, ack_rx) = channel();
        self.tx
            .send(Message::CorpusInsert { id, entry, ack: ack_tx })
            .map_err(|_| ServiceError::Stopped)?;
        ack_rx.recv().map_err(|_| ServiceError::Stopped)?
    }

    /// Tombstone one corpus entry id: it disappears from every search
    /// submitted after this call returns (in-flight searches keep their
    /// snapshot — see [`Self::register_corpus`]). Returns whether a
    /// live entry was hit. When the owning shard's tombstone fraction
    /// crosses the compaction threshold, that shard rebuilds itself
    /// in place; ids never change.
    pub fn corpus_tombstone(
        &self,
        id: CorpusId,
        entry: usize,
    ) -> Result<bool, ServiceError> {
        let (ack_tx, ack_rx) = channel();
        self.tx
            .send(Message::CorpusTombstone { id, entry, ack: ack_tx })
            .map_err(|_| ServiceError::Stopped)?;
        ack_rx.recv().map_err(|_| ServiceError::Stopped)?
    }

    /// Explicitly compact every shard of the corpus holding tombstones;
    /// returns how many shards rebuilt. Runs on the retrieval runtime
    /// thread like every other corpus job.
    pub fn corpus_compact(&self, id: CorpusId) -> Result<usize, ServiceError> {
        let (ack_tx, ack_rx) = channel();
        self.tx
            .send(Message::CorpusCompact { id, ack: ack_tx })
            .map_err(|_| ServiceError::Stopped)?;
        ack_rx.recv().map_err(|_| ServiceError::Stopped)?
    }

    /// Async top-k retrieval: returns a receiver for the outcome.
    pub fn submit_retrieval(
        &self,
        query: RetrievalQuery,
    ) -> Result<Receiver<Result<RetrievalOutcome, ServiceError>>, ServiceError> {
        self.client().submit_retrieval(query)
    }

    /// Blocking top-k retrieval convenience wrapper.
    pub fn retrieve(&self, query: RetrievalQuery) -> Result<RetrievalOutcome, ServiceError> {
        let rx = self.submit_retrieval(query)?;
        rx.recv().map_err(|_| ServiceError::Stopped)?
    }

    /// Pre-compile all artifacts (returns how many were compiled).
    pub fn warmup(&self) -> Result<usize, ServiceError> {
        let (tx, rx) = channel();
        self.tx.send(Message::Warmup(tx)).map_err(|_| ServiceError::Stopped)?;
        rx.recv().map_err(|_| ServiceError::Stopped)?
    }

    /// Async submit: returns a receiver for this query's result.
    pub fn submit(&self, query: Query) -> Result<Receiver<Result<QueryResult, ServiceError>>, ServiceError> {
        self.client().submit(query)
    }

    /// Blocking convenience wrapper.
    pub fn distance(&self, query: Query) -> Result<QueryResult, ServiceError> {
        let rx = self.submit(query)?;
        rx.recv().map_err(|_| ServiceError::Stopped)?
    }

    /// Current statistics snapshot.
    pub fn stats(&self) -> Result<StatsSnapshot, ServiceError> {
        let (tx, rx) = channel();
        self.tx.send(Message::Stats(tx)).map_err(|_| ServiceError::Stopped)?;
        rx.recv().map_err(|_| ServiceError::Stopped)
    }

    /// The tracing sink, when [`CoordinatorConfig::trace`] is set: read
    /// sampled spans ([`TraceSink::sampled_spans`] /
    /// [`TraceSink::trace_spans`]) or feed them to
    /// [`crate::trace::chrome_trace`] for a Perfetto-loadable export.
    pub fn trace_sink(&self) -> Option<Arc<TraceSink>> {
        self.trace.clone()
    }

    /// Graceful shutdown: drains pending work, then joins the thread.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        // The scrape server's handler closure owns a sender clone, so it
        // must go first — otherwise the engine never sees Disconnected
        // and the join below deadlocks.
        self.telemetry = None;
        // Dropping the sender disconnects the channel; the engine thread
        // drains and exits.
        let (tx, _rx) = channel();
        let old = std::mem::replace(&mut self.tx, tx);
        drop(old);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for DistanceService {
    fn drop(&mut self) {
        if self.handle.is_some() {
            self.shutdown_inner();
        }
    }
}

impl ServiceClient {
    /// Async submit: returns a receiver for this query's result.
    pub fn submit(&self, query: Query) -> Result<Receiver<Result<QueryResult, ServiceError>>, ServiceError> {
        let (tx, rx) = channel();
        let job = Job { query, enqueued: Instant::now(), trace: None, respond: tx };
        self.tx.send(Message::Query(job)).map_err(|_| ServiceError::Stopped)?;
        Ok(rx)
    }

    /// Blocking convenience wrapper.
    pub fn distance(&self, query: Query) -> Result<QueryResult, ServiceError> {
        let rx = self.submit(query)?;
        rx.recv().map_err(|_| ServiceError::Stopped)?
    }

    /// Async top-k retrieval: returns a receiver for the outcome.
    pub fn submit_retrieval(
        &self,
        query: RetrievalQuery,
    ) -> Result<Receiver<Result<RetrievalOutcome, ServiceError>>, ServiceError> {
        let (tx, rx) = channel();
        self.tx
            .send(Message::Retrieve { query, enqueued: Instant::now(), respond: tx })
            .map_err(|_| ServiceError::Stopped)?;
        Ok(rx)
    }

    /// Blocking top-k retrieval convenience wrapper.
    pub fn retrieve(&self, query: RetrievalQuery) -> Result<RetrievalOutcome, ServiceError> {
        let rx = self.submit_retrieval(query)?;
        rx.recv().map_err(|_| ServiceError::Stopped)?
    }
}

/// State owned by the engine thread.
struct EngineThread {
    config: CoordinatorConfig,
    runtime: Option<XlaRuntime>,
    rx: Receiver<Message>,
    metrics: HashMap<MetricId, CostMatrix>,
    /// One sharded panel executor per (metric, λ) shape class; each holds
    /// `config.cpu_workers` private K/Kᵀ-bound backend instances.
    executors: HashMap<(MetricId, u64), ShardedExecutor>,
    /// The retrieval dispatcher pool (spawned lazily on the first
    /// corpus registration). The engine keeps only validation + promise
    /// plumbing: corpus state, index builds, cascade walks and recall
    /// probes all live in per-corpus mailbox actors, so a long search
    /// can never stall a batcher deadline flush — and one tenant's
    /// bulk work never stalls another's searches (PR 8).
    retrieval: Option<RetrievalRuntime>,
    /// Sender template handed to the runtime at spawn.
    feedback_tx: Sender<RuntimeFeedback>,
    /// Gauge/report pushes from the runtime, drained into `stats` on
    /// every engine wakeup (and right before every stats snapshot).
    feedback_rx: Receiver<RuntimeFeedback>,
    pending: PendingBatcher<Job>,
    stats: Stats,
    /// PR 9 tracing sink (None = tracing off; every record site
    /// branches on this Option and costs nothing when unset).
    trace: Option<Arc<TraceSink>>,
}

impl EngineThread {
    fn new(
        config: CoordinatorConfig,
        runtime: Option<XlaRuntime>,
        rx: Receiver<Message>,
        trace: Option<Arc<TraceSink>>,
    ) -> Self {
        let pending =
            PendingBatcher::new(config.batcher.effective(config.cpu_workers));
        let (feedback_tx, feedback_rx) = channel();
        let stats = Stats::new(config.telemetry.as_ref());
        Self {
            config,
            runtime,
            rx,
            metrics: HashMap::new(),
            executors: HashMap::new(),
            retrieval: None,
            feedback_tx,
            feedback_rx,
            pending,
            stats,
            trace,
        }
    }

    /// The retrieval runtime, spawning it on first use.
    fn retrieval_runtime(&mut self) -> &RetrievalRuntime {
        if self.retrieval.is_none() {
            self.retrieval = Some(RetrievalRuntime::with_dispatchers(
                self.feedback_tx.clone(),
                self.config.retrieval_dispatchers,
            ));
        }
        self.retrieval.as_ref().expect("runtime just ensured")
    }

    /// Fold queued runtime feedback into the gauges (non-blocking).
    fn drain_retrieval_feedback(&mut self) {
        while let Ok(feedback) = self.feedback_rx.try_recv() {
            self.stats.record_runtime(&feedback);
        }
    }

    fn run(mut self) {
        const IDLE: Duration = Duration::from_millis(50);
        loop {
            let timeout = self
                .pending
                .next_deadline()
                .map(|dl| dl.saturating_duration_since(Instant::now()))
                .unwrap_or(IDLE);
            match self.rx.recv_timeout(timeout) {
                Ok(Message::Query(job)) => self.accept(job),
                Ok(Message::RegisterMetric(id, m, ack)) => {
                    self.metrics.insert(id, m);
                    // Invalidate executors/buffers/corpora bound to the
                    // replaced metric (a corpus's precomputed statistics
                    // describe the metric they were built against).
                    self.executors.retain(|(mid, _), _| *mid != id);
                    if let Some(rt) = &self.retrieval {
                        rt.drop_metric(id.0);
                    }
                    if let Some(rt) = self.runtime.as_mut() {
                        rt.invalidate_metric(id.0 as u64);
                    }
                    let _ = ack.send(());
                }
                Ok(Message::RegisterCorpus { id, metric, lambda, entries, ack }) => {
                    self.register_corpus(id, metric, lambda, entries, ack);
                }
                Ok(Message::Retrieve { query, enqueued, respond }) => {
                    // No dispatcher pool yet means no corpus was ever
                    // registered: answer here instead of spawning the
                    // pool just to fail the lookup.
                    if self.retrieval.is_none() {
                        self.stats.inc_errors();
                        let _ = respond
                            .send(Err(ServiceError::UnknownCorpus(query.corpus)));
                    } else {
                        // Mint the retrieval's trace here (the sampling
                        // gate lives with the sink); it crosses the
                        // mailbox inside the job.
                        let trace = self.trace.as_ref().and_then(|sink| {
                            sink.sample().map(|id| ctx::ActiveTrace {
                                sink: Arc::clone(sink),
                                trace: id,
                                tenant: Tenant::Corpus(query.corpus.0),
                            })
                        });
                        self.retrieval_runtime().search_traced(
                            query.corpus.0,
                            query.r,
                            query.k,
                            enqueued,
                            trace,
                            Box::new(move |res: Result<SearchOutcome, _>| {
                                let _ = respond.send(
                                    res.map(|o| RetrievalOutcome {
                                        hits: o.hits,
                                        report: o.report,
                                        latency_us: o.latency_us,
                                    })
                                    .map_err(runtime_retrieval_error),
                                );
                            }),
                        );
                    }
                }
                Ok(Message::CorpusInsert { id, entry, ack }) => {
                    if self.retrieval.is_none() {
                        self.stats.inc_errors();
                        let _ = ack.send(Err(ServiceError::UnknownCorpus(id)));
                    } else {
                        self.retrieval_runtime().insert(
                            id.0,
                            entry,
                            Box::new(move |res| {
                                let _ = ack
                                    .send(res.map_err(runtime_retrieval_error));
                            }),
                        );
                    }
                }
                Ok(Message::CorpusTombstone { id, entry, ack }) => {
                    if self.retrieval.is_none() {
                        self.stats.inc_errors();
                        let _ = ack.send(Err(ServiceError::UnknownCorpus(id)));
                    } else {
                        self.retrieval_runtime().tombstone(
                            id.0,
                            entry,
                            Box::new(move |res| {
                                let _ = ack
                                    .send(res.map_err(runtime_retrieval_error));
                            }),
                        );
                    }
                }
                Ok(Message::CorpusCompact { id, ack }) => {
                    if self.retrieval.is_none() {
                        self.stats.inc_errors();
                        let _ = ack.send(Err(ServiceError::UnknownCorpus(id)));
                    } else {
                        self.retrieval_runtime().compact(
                            id.0,
                            Box::new(move |res| {
                                let _ = ack
                                    .send(res.map_err(runtime_retrieval_error));
                            }),
                        );
                    }
                }
                Ok(Message::Stats(tx)) => {
                    self.drain_retrieval_feedback();
                    self.sample_queue_depths();
                    let _ = tx.send(self.snapshot_with_stages());
                }
                Ok(Message::Scrape { kind, respond }) => {
                    let _ = respond.send(self.scrape(kind));
                }
                Ok(Message::Warmup(tx)) => {
                    let res = match self.runtime.as_mut() {
                        Some(rt) => rt
                            .warmup(self.config.flavor)
                            .map_err(|e| ServiceError::Runtime(e.to_string())),
                        None => Ok(0),
                    };
                    let _ = tx.send(res);
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    // Drain remaining work, then exit. Dropping `self`
                    // afterwards disconnects the retrieval runtime's job
                    // channel; its drop drains queued retrieval jobs
                    // (promised answers still get delivered) and joins.
                    for batch in self.pending.drain(Instant::now()) {
                        self.execute(batch);
                    }
                    return;
                }
            }
            self.drain_retrieval_feedback();
            // Re-evaluate per-tenant burn rates each turn: arming (and
            // disarming) must track the window ring as it slides, not
            // wait for the next scrape. A no-op without telemetry.
            self.stats.evaluate_slo();
            for batch in self.pending.poll_expired(Instant::now()) {
                self.execute(batch);
            }
        }
    }

    /// Sample the retrieval-queue gauges (total + per-corpus) into the
    /// stats; shared by the snapshot path and the scrape path.
    fn sample_queue_depths(&mut self) {
        let depth = self
            .retrieval
            .as_ref()
            .map(|rt| rt.queue_depth() as u64)
            .unwrap_or(0);
        self.stats.set_retrieval_queue_depth(depth);
        let corpus_depths = self
            .retrieval
            .as_ref()
            .map(|rt| rt.corpus_depths())
            .unwrap_or_default();
        self.stats.set_corpus_queue_depths(&corpus_depths);
    }

    /// Snapshot with the PR 9 trace-collector rows grafted on.
    fn snapshot_with_stages(&self) -> StatsSnapshot {
        let mut snap = self.stats.snapshot();
        if let Some(sink) = &self.trace {
            snap.stages = sink.stage_rows();
            snap.traces_sampled = sink.sampled();
            snap.trace_spans = sink.span_count();
            snap.trace_spans_dropped = sink.dropped();
        }
        snap
    }

    /// Answer one scrape-server request on the engine thread. Every
    /// endpoint refreshes gauges + SLO state first, so a scrape never
    /// serves numbers staler than the request itself.
    fn scrape(&mut self, kind: ScrapeKind) -> ScrapeBody {
        self.drain_retrieval_feedback();
        self.sample_queue_depths();
        self.stats.evaluate_slo();
        match kind {
            ScrapeKind::Metrics => {
                let stages = self
                    .trace
                    .as_ref()
                    .map(|sink| sink.stage_histograms())
                    .unwrap_or_default();
                let trace = self
                    .trace
                    .as_ref()
                    .map(|sink| (sink.sampled(), sink.span_count(), sink.dropped()));
                ScrapeBody {
                    content_type: PROMETHEUS_CONTENT_TYPE,
                    body: self.stats.prometheus(&stages, trace),
                }
            }
            ScrapeKind::Healthz => ScrapeBody {
                content_type: "application/json",
                body: self.healthz().to_string(),
            },
            ScrapeKind::Snapshot => ScrapeBody {
                content_type: "application/json",
                body: self.snapshot_with_stages().to_json().to_string(),
            },
            ScrapeKind::SloReport => ScrapeBody {
                content_type: "text/plain; charset=utf-8",
                body: match self.stats.telemetry_report() {
                    Some(report) => format!("{report}\n"),
                    None => "telemetry windows are off\n".into(),
                },
            },
        }
    }

    /// Liveness body: engine mode plus the retrieval pool's structural
    /// gauges (the numbers a load balancer or operator checks first).
    fn healthz(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut root = std::collections::BTreeMap::new();
        root.insert("status".into(), Json::String("ok".into()));
        root.insert(
            "engine".into(),
            Json::String(
                if self.runtime.is_some() { "xla+cpu" } else { "cpu" }.into(),
            ),
        );
        let mut retrieval = std::collections::BTreeMap::new();
        retrieval.insert(
            "spawned".into(),
            Json::Bool(self.retrieval.is_some()),
        );
        if let Some(rt) = &self.retrieval {
            let (fast, bulk) = rt.lane_depths();
            retrieval.insert(
                "queue_depth".into(),
                Json::Number(rt.queue_depth() as f64),
            );
            retrieval
                .insert("dispatchers".into(), Json::Number(rt.dispatchers() as f64));
            retrieval.insert("fast_lane".into(), Json::Number(fast as f64));
            retrieval.insert("bulk_lane".into(), Json::Number(bulk as f64));
            retrieval.insert(
                "corpora".into(),
                Json::Array(
                    rt.corpus_depths()
                        .into_iter()
                        .map(|(corpus, depth)| {
                            let mut row = std::collections::BTreeMap::new();
                            row.insert(
                                "corpus".into(),
                                Json::Number(corpus as f64),
                            );
                            row.insert(
                                "queue_depth".into(),
                                Json::Number(depth as f64),
                            );
                            Json::Object(row)
                        })
                        .collect(),
                ),
            );
        }
        root.insert("retrieval".into(), Json::Object(retrieval));
        Json::Object(root)
    }

    /// The refine-stage configuration a corpus search runs with, derived
    /// from the serving config (documented on
    /// [`CoordinatorConfig::retrieval_probe_every`]).
    fn retrieval_config(&self, lambda: F) -> RetrievalConfig {
        let mut rc = RetrievalConfig::serving(lambda);
        rc.workers = self.config.cpu_workers;
        rc.backend = self.config.cpu_backend;
        rc.panel = self
            .config
            .batcher
            .effective(self.config.cpu_workers)
            .max_batch;
        rc.probe_every = self.config.retrieval_probe_every;
        rc.budget = self.config.retrieval_budget;
        rc.sinkhorn.kernel = self.config.kernel;
        rc.sinkhorn.schedule = self.config.anneal;
        if let Some(ws) = self.config.warm_start {
            rc.sinkhorn.tolerance = ws.tolerance;
            rc.sinkhorn.max_iterations = ws.max_iterations;
        }
        rc
    }

    /// Validate and hand one corpus registration off to the retrieval
    /// runtime (the build runs there; the ack travels straight from the
    /// runtime thread to the registering caller).
    fn register_corpus(
        &mut self,
        id: CorpusId,
        metric_id: MetricId,
        lambda: F,
        entries: Vec<Histogram>,
        ack: Sender<Result<usize, ServiceError>>,
    ) {
        let Some(metric) = self.metrics.get(&metric_id).cloned() else {
            self.stats.inc_errors();
            let _ = ack.send(Err(ServiceError::UnknownMetric(metric_id)));
            return;
        };
        if !(lambda > 0.0 && lambda.is_finite()) {
            self.stats.inc_errors();
            let _ = ack.send(Err(ServiceError::InvalidConfig(format!(
                "corpus serving lambda must be positive and finite (got {lambda})"
            ))));
            return;
        }
        let spec = RegisterSpec {
            corpus: id.0,
            metric_key: metric_id.0,
            metric,
            entries,
            anchors: CorpusIndex::DEFAULT_ANCHORS,
            config: self.retrieval_config(lambda),
            sharding: ShardingConfig {
                shards: self.config.retrieval_shards.max(1),
                threads: self.config.retrieval_threads,
                routing: self.config.retrieval_routing,
                ..ShardingConfig::default()
            },
        };
        self.retrieval_runtime().register(
            spec,
            Box::new(move |res| {
                let _ = ack.send(res.map_err(retrieval_error));
            }),
        );
    }

    /// Validate and enqueue one query (or answer immediately on error).
    fn accept(&mut self, mut job: Job) {
        let metric = match self.metrics.get(&job.query.metric) {
            Some(m) => m,
            None => {
                self.stats.inc_errors();
                let _ = job
                    .respond
                    .send(Err(ServiceError::UnknownMetric(job.query.metric)));
                return;
            }
        };
        let d = metric.dim();
        if job.query.r.dim() != d || job.query.c.dim() != d {
            self.stats.inc_errors();
            let got = if job.query.r.dim() != d { job.query.r.dim() } else { job.query.c.dim() };
            let _ = job
                .respond
                .send(Err(ServiceError::DimensionMismatch { got, want: d }));
            return;
        }
        // Sampling counts *accepted* queries, so rejects can't skew the
        // 1-in-N cadence.
        job.trace = self.trace.as_ref().and_then(|sink| sink.sample());
        let class = ShapeClass::new(job.query.metric, d, job.query.lambda);
        if let Some(ready) = self.pending.push(class, job, Instant::now()) {
            self.execute(ready);
        }
    }

    /// Execute one ready batch on the best available backend.
    fn execute(&mut self, batch: ReadyBatch<Job>) {
        let class = batch.class;
        let oldest_wait = batch.oldest_wait;
        let full = batch.full;
        let jobs = batch.items;
        let size = jobs.len();
        let metric = self.metrics[&class.metric].clone();
        let lambda = class.lambda();
        // Trace only when some member was sampled: an all-untraced
        // batch (the common case) takes no timestamps at all.
        let tsink = if jobs.iter().any(|j| j.trace.is_some()) {
            self.trace.clone()
        } else {
            None
        };

        // Anytime budget: queries sharing the batch share one panel, so
        // the batch runs under the *tightest* member budget. A flush
        // that reached the engine backlogged additionally sheds to the
        // configured iteration cap — accuracy gives way (visibly, via
        // the certificate) instead of the flush deadline.
        let mut budget = jobs
            .iter()
            .fold(SolveBudget::Unbounded, |acc, j| tightest(acc, j.query.budget));
        let mut shed = false;
        if let Some(cap) = shed_cap(
            self.config.shed_iterations,
            oldest_wait,
            self.config.batcher.max_delay,
        ) {
            budget = tightest(budget, SolveBudget::Iterations(cap));
            self.stats.add_budget_sheds(size as u64);
            shed = true;
        }
        // PR 10: a tenant whose latency SLO is burning gets its batches
        // shed to the policy's iteration cap until the burn clears. The
        // cap composes through `tightest`, so Deadline-budgeted queries
        // keep their (tighter) wall-clock bound.
        if let Some(cap) = self.stats.slo_shed_cap(class.metric.0) {
            budget = tightest(budget, SolveBudget::Iterations(cap));
            if !shed {
                self.stats.add_budget_sheds(size as u64);
                shed = true;
            }
        }
        let solve_start = tsink.as_ref().map(|s| s.now_us());

        // Prefer the XLA runtime when it has an artifact for this d.
        let use_xla = self
            .runtime
            .as_ref()
            .map(|rt| rt.select(class.d, size, self.config.flavor).is_ok())
            .unwrap_or(false);

        if use_xla {
            match self.execute_xla(&metric, class.metric, lambda, &jobs) {
                Ok(dists) => {
                    self.stats.record_batch(size, true);
                    // The artifact's iteration count is baked at AOT
                    // time: budgets don't apply and no certificate is
                    // computed, so the outcome interval is vacuous.
                    let outcomes: Vec<SolveOutcome> =
                        dists.into_iter().map(SolveOutcome::uncertified).collect();
                    let trace = tsink.map(|sink| BatchTrace {
                        solve_start: solve_start.unwrap_or(0),
                        solve_end: sink.now_us(),
                        sink,
                        full,
                        shed,
                        warm_hits: 0,
                        warm_misses: 0,
                    });
                    self.respond_all(jobs, outcomes, EngineKind::Xla, size, trace);
                    return;
                }
                Err(e) => {
                    self.stats.inc_errors();
                    if !self.config.cpu_fallback {
                        let msg = e.to_string();
                        for job in jobs {
                            let _ = job
                                .respond
                                .send(Err(ServiceError::Runtime(msg.clone())));
                        }
                        return;
                    }
                    // fall through to CPU
                }
            }
        } else if self.runtime.is_some() && !self.config.cpu_fallback {
            for job in jobs {
                let _ = job.respond.send(Err(ServiceError::NoBackend(class.d)));
            }
            return;
        } else if self.runtime.is_none() && !self.config.cpu_fallback {
            for job in jobs {
                let _ = job.respond.send(Err(ServiceError::NoBackend(class.d)));
            }
            return;
        }

        // CPU path: the panel shards across the thread-pool executor for
        // this shape class. Each worker owns a private backend instance
        // (interleaved batch walk in the dense regime, log-domain when
        // e^{−λM} underflows, or whatever `cpu_backend` pins) — plus, in
        // warm-start mode, a private store of converged scalings.
        let mut cfg = SinkhornConfig::fixed(lambda, self.config.cpu_iterations);
        cfg.schedule = self.config.anneal;
        cfg.kernel = self.config.kernel;
        if let Some(ws) = self.config.warm_start {
            // Convergence-checked under the warm-start config's own cap:
            // warm hits terminate in a handful of iterations, and cold
            // solves get enough headroom to actually converge (only
            // converged solves populate the stores).
            cfg.tolerance = ws.tolerance;
            cfg.max_iterations = ws.max_iterations;
            cfg.check_every = 1;
        }
        let workers = self.config.cpu_workers;
        let pinned = self.config.cpu_backend;
        let warm = self.config.warm_start;
        let executor = self
            .executors
            .entry((class.metric, lambda.to_bits()))
            .or_insert_with(|| {
                let ex = match pinned {
                    Some(kind) => ShardedExecutor::new(&metric, cfg, kind, workers),
                    None => ShardedExecutor::auto(&metric, cfg, workers),
                };
                match warm {
                    Some(ws) => {
                        ex.with_warm_store(class.metric.0 as u64, lambda, ws.capacity)
                    }
                    None => ex,
                }
            });
        let rs: Vec<&crate::simplex::Histogram> =
            jobs.iter().map(|j| &j.query.r).collect();
        let cs: Vec<crate::simplex::Histogram> =
            jobs.iter().map(|j| j.query.c.clone()).collect();
        let (outcomes, reports) = if budget.is_unbounded() {
            // Exactly the pre-anytime path (warm stores stay active);
            // run metadata rides the outcome with a vacuous interval —
            // certificates are only computed under a budget.
            let (outputs, reports) = executor.solve_panel_paired(&rs, &cs);
            let outcomes = outputs
                .iter()
                .map(|o| {
                    SolveOutcome::from_output(
                        o,
                        crate::sinkhorn::ErrorInterval::UNBOUNDED,
                    )
                })
                .collect();
            (outcomes, reports)
        } else {
            // Tag each panel column with its job's trace (None for
            // untraced members) so `drive_budgeted` / the interleaved
            // panel walk can emit per-slice interval spans.
            let panel_trace = tsink.as_ref().map(|sink| PanelTrace {
                sink: Arc::clone(sink),
                tenant: Tenant::Metric(class.metric.0),
                traces: jobs.iter().map(|j| j.trace).collect(),
            });
            executor.solve_panel_outcomes_traced(&rs, &cs, &[], budget, panel_trace)
        };
        // Kernel structure rides on the shard reports (identical across
        // a pool's workers — one record per batch is enough).
        if let Some(report) = reports.first() {
            self.stats.record_kernel(report.kernel);
        }
        for report in &reports {
            self.stats.record_worker(
                report.worker,
                report.queries,
                report.busy,
                report.warm_hits,
                report.warm_misses,
            );
        }
        self.stats.record_batch(size, false);
        let trace = tsink.map(|sink| BatchTrace {
            solve_start: solve_start.unwrap_or(0),
            solve_end: sink.now_us(),
            sink,
            full,
            shed,
            warm_hits: reports.iter().map(|r| r.warm_hits).sum(),
            warm_misses: reports.iter().map(|r| r.warm_misses).sum(),
        });
        self.respond_all(jobs, outcomes, EngineKind::Cpu, size, trace);
    }

    fn execute_xla(
        &mut self,
        metric: &CostMatrix,
        metric_id: MetricId,
        lambda: F,
        jobs: &[Job],
    ) -> Result<Vec<F>, RuntimeError> {
        let rt = self.runtime.as_mut().expect("xla path requires runtime");
        let d = metric.dim();
        let mut out = Vec::with_capacity(jobs.len());
        let mut idx = 0;
        while idx < jobs.len() {
            let remaining = jobs.len() - idx;
            let variant = rt.select(d, remaining, self.config.flavor)?;
            let take = remaining.min(variant.n);
            let r_cols: Vec<Vec<F>> = jobs[idx..idx + take]
                .iter()
                .map(|j| j.query.r.values().to_vec())
                .collect();
            let c_cols: Vec<Vec<F>> = jobs[idx..idx + take]
                .iter()
                .map(|j| j.query.c.values().to_vec())
                .collect();
            // The metric id keys the runtime's device-buffer cache:
            // register_metric invalidates it on replacement.
            let batch = rt.execute_keyed(
                &variant,
                metric,
                Some(metric_id.0 as u64),
                lambda,
                &r_cols,
                &c_cols,
            )?;
            out.extend(batch.distances);
            idx += take;
        }
        Ok(out)
    }

    fn respond_all(
        &mut self,
        jobs: Vec<Job>,
        outcomes: Vec<SolveOutcome>,
        engine: EngineKind,
        batch_size: usize,
        trace: Option<BatchTrace>,
    ) {
        debug_assert_eq!(jobs.len(), outcomes.len());
        let now = Instant::now();
        for (job, outcome) in jobs.into_iter().zip(outcomes) {
            let latency = now.saturating_duration_since(job.enqueued);
            let tenant = job.query.metric.0;
            let missed =
                matches!(job.query.budget, SolveBudget::Deadline(t) if now > t);
            self.stats.record_query_served(tenant, latency, missed);
            self.stats.record_outcome(tenant, &outcome);
            // Three spans per traced member: batcher wait, the shared
            // panel solve, and the whole-query root they nest under.
            if let (Some(bt), Some(id)) = (&trace, job.trace) {
                let tenant = Tenant::Metric(job.query.metric.0);
                let enqueued_us = bt.sink.instant_us(job.enqueued);
                bt.sink.record(Span {
                    trace: id,
                    stage: Stage::Batcher,
                    tenant,
                    start_us: enqueued_us,
                    end_us: bt.solve_start,
                    tid: 0,
                    data: SpanData::Batch { size: batch_size, full: bt.full },
                });
                bt.sink.record(Span {
                    trace: id,
                    stage: Stage::Solve,
                    tenant,
                    start_us: bt.solve_start,
                    end_us: bt.solve_end,
                    tid: 0,
                    data: SpanData::Solve {
                        batch: batch_size,
                        warm_hits: bt.warm_hits,
                        warm_misses: bt.warm_misses,
                        shed: bt.shed,
                    },
                });
                bt.sink.record(Span {
                    trace: id,
                    stage: Stage::Query,
                    tenant,
                    start_us: enqueued_us,
                    end_us: bt.sink.now_us(),
                    tid: 0,
                    data: SpanData::None,
                });
            }
            let _ = job.respond.send(Ok(QueryResult {
                outcome,
                engine,
                batch_size,
                latency_us: crate::util::saturating_micros(latency),
            }));
        }
    }
}

/// Batch-level timing shared by every traced member of one flush,
/// captured in [`EngineThread::execute`] and unpacked into per-query
/// spans in [`EngineThread::respond_all`].
struct BatchTrace {
    sink: Arc<TraceSink>,
    /// Sink-epoch µs at which the batch left the batcher for its solve.
    solve_start: u64,
    /// Sink-epoch µs at which the solve (XLA or CPU panel) returned.
    solve_end: u64,
    /// Whether the size trigger (vs a deadline/drain flush) released it.
    full: bool,
    /// Whether the backlog shed rule capped this batch's budget.
    shed: bool,
    warm_hits: usize,
    warm_misses: usize,
}

/// The tighter of two anytime budgets — the one admitting less work.
/// A smaller cap or earlier deadline wins; in the mixed case the
/// deadline wins (it is the hard realtime constraint, and the capped
/// member still stops when the panel's deadline expires).
fn tightest(a: SolveBudget, b: SolveBudget) -> SolveBudget {
    use SolveBudget::*;
    match (a, b) {
        (Unbounded, x) | (x, Unbounded) => x,
        (Iterations(m), Iterations(n)) => Iterations(m.min(n)),
        (Deadline(s), Deadline(t)) => Deadline(s.min(t)),
        (Deadline(t), Iterations(_)) | (Iterations(_), Deadline(t)) => Deadline(t),
    }
}

/// Load-shed decision, kept pure for testability: a batch whose oldest
/// member already waited more than *twice* the promised flush delay
/// reached the engine backlogged — the previous batch blew through this
/// one's deadline — so its solve sheds to the configured iteration cap
/// and the backlog stops compounding.
fn shed_cap(
    shed_iterations: Option<usize>,
    oldest_wait: Duration,
    max_delay: Duration,
) -> Option<usize> {
    let cap = shed_iterations?;
    if oldest_wait > max_delay.saturating_mul(2) {
        Some(cap)
    } else {
        None
    }
}

/// Map index/search errors onto the client-facing error surface.
fn retrieval_error(e: RetrievalError) -> ServiceError {
    match e {
        RetrievalError::QueryDimensionMismatch { got, want }
        | RetrievalError::DimensionMismatch { got, want, .. } => {
            ServiceError::DimensionMismatch { got, want }
        }
        other => ServiceError::InvalidConfig(other.to_string()),
    }
}

/// Map retrieval-runtime errors onto the client-facing error surface.
fn runtime_retrieval_error(e: crate::retrieval::RuntimeError) -> ServiceError {
    match e {
        crate::retrieval::RuntimeError::UnknownCorpus(key) => {
            ServiceError::UnknownCorpus(CorpusId(key))
        }
        crate::retrieval::RuntimeError::Index(e) => retrieval_error(e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::batcher::BatcherConfig;
    use crate::metric::RandomMetric;
    use crate::simplex::{seeded_rng, Histogram};
    use crate::sinkhorn::SinkhornEngine;

    fn cpu_service(max_batch: usize, delay_ms: u64) -> (DistanceService, CostMatrix) {
        let mut config = CoordinatorConfig::cpu_only();
        config.batcher = BatcherConfig {
            max_batch,
            max_delay: Duration::from_millis(delay_ms),
            ..BatcherConfig::default()
        };
        config.cpu_iterations = 200;
        let svc = DistanceService::start(config).unwrap();
        let mut rng = seeded_rng(0);
        let m = RandomMetric::new(12).sample(&mut rng);
        svc.register_metric(MetricId(0), m.clone()).unwrap();
        (svc, m)
    }

    #[test]
    fn cpu_backend_answers_correctly() {
        let (svc, m) = cpu_service(4, 1);
        let mut rng = seeded_rng(1);
        let r = Histogram::sample_uniform(12, &mut rng);
        let c = Histogram::sample_uniform(12, &mut rng);
        let res = svc
            .distance(Query::new(MetricId(0), 9.0, r.clone(), c.clone()))
            .unwrap();
        assert_eq!(res.engine, EngineKind::Cpu);
        let want = SinkhornEngine::with_config(&m, SinkhornConfig::fixed(9.0, 200))
            .distance(&r, &c)
            .value;
        assert!((res.distance() - want).abs() < 1e-12);
        svc.shutdown();
    }

    #[test]
    fn unknown_metric_is_rejected() {
        let (svc, _m) = cpu_service(4, 1);
        let mut rng = seeded_rng(2);
        let r = Histogram::sample_uniform(12, &mut rng);
        let err = svc
            .distance(Query::new(MetricId(9), 9.0, r.clone(), r))
            .unwrap_err();
        assert!(matches!(err, ServiceError::UnknownMetric(MetricId(9))));
        svc.shutdown();
    }

    #[test]
    fn dimension_mismatch_is_rejected() {
        let (svc, _m) = cpu_service(4, 1);
        let mut rng = seeded_rng(3);
        let r = Histogram::sample_uniform(5, &mut rng);
        let err = svc
            .distance(Query::new(MetricId(0), 9.0, r.clone(), r))
            .unwrap_err();
        assert!(matches!(err, ServiceError::DimensionMismatch { got: 5, want: 12 }));
        svc.shutdown();
    }

    #[test]
    fn batching_batches() {
        let (svc, _m) = cpu_service(8, 50);
        let mut rng = seeded_rng(4);
        // Submit 8 queries of one class quickly: they should share a batch
        // (size trigger), visible via batch_size on results.
        let rxs: Vec<_> = (0..8)
            .map(|_| {
                let r = Histogram::sample_uniform(12, &mut rng);
                let c = Histogram::sample_uniform(12, &mut rng);
                svc.submit(Query::new(MetricId(0), 9.0, r, c)).unwrap()
            })
            .collect();
        let sizes: Vec<usize> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap().batch_size)
            .collect();
        assert!(sizes.iter().all(|&s| s == 8), "batch sizes {sizes:?}");
        let snap = svc.stats().unwrap();
        assert_eq!(snap.queries, 8);
        assert_eq!(snap.batches, 1);
        svc.shutdown();
    }

    #[test]
    fn deadline_flush_serves_partial_batches() {
        let (svc, _m) = cpu_service(1000, 5);
        let mut rng = seeded_rng(5);
        let r = Histogram::sample_uniform(12, &mut rng);
        let c = Histogram::sample_uniform(12, &mut rng);
        let t0 = Instant::now();
        let res = svc
            .distance(Query::new(MetricId(0), 9.0, r, c))
            .unwrap();
        // Must have waited for the deadline, not the (huge) size trigger.
        assert!(t0.elapsed() >= Duration::from_millis(5));
        assert_eq!(res.batch_size, 1);
        svc.shutdown();
    }

    #[test]
    fn shutdown_drains_pending() {
        let (svc, _m) = cpu_service(1000, 10_000); // deadline effectively never
        let mut rng = seeded_rng(6);
        let rxs: Vec<_> = (0..5)
            .map(|_| {
                let r = Histogram::sample_uniform(12, &mut rng);
                let c = Histogram::sample_uniform(12, &mut rng);
                svc.submit(Query::new(MetricId(0), 3.0, r, c)).unwrap()
            })
            .collect();
        svc.shutdown(); // must flush the queue before joining
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
    }

    #[test]
    fn concurrent_clients_conserve_results() {
        let (svc, m) = cpu_service(16, 2);
        let d = m.dim();
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let client = svc.client();
            handles.push(std::thread::spawn(move || {
                let mut rng = seeded_rng(100 + t);
                let mut vals = Vec::new();
                for _ in 0..25 {
                    let r = Histogram::sample_uniform(d, &mut rng);
                    let c = Histogram::sample_uniform(d, &mut rng);
                    let lambda = if rng.bool(0.5) { 9.0 } else { 3.0 };
                    let res = client
                        .distance(Query::new(MetricId(0), lambda, r, c))
                        .unwrap();
                    vals.push(res.distance());
                }
                vals
            }));
        }
        let mut total = 0;
        for h in handles {
            let vals = h.join().unwrap();
            assert_eq!(vals.len(), 25);
            assert!(vals.iter().all(|v| v.is_finite() && *v >= 0.0));
            total += vals.len();
        }
        assert_eq!(total, 100);
        let snap = svc.stats().unwrap();
        assert_eq!(snap.queries, 100);
        assert!(snap.batches <= 100);
        svc.shutdown();
    }

    #[test]
    fn worker_occupancy_is_recorded() {
        let (svc, _m) = cpu_service(8, 50);
        let mut rng = seeded_rng(7);
        let rxs: Vec<_> = (0..8)
            .map(|_| {
                let r = Histogram::sample_uniform(12, &mut rng);
                let c = Histogram::sample_uniform(12, &mut rng);
                svc.submit(Query::new(MetricId(0), 9.0, r, c)).unwrap()
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let snap = svc.stats().unwrap();
        assert!(!snap.workers.is_empty(), "executor workers must be tracked");
        let solved: u64 = snap.workers.iter().map(|w| w.queries).sum();
        assert_eq!(solved, 8, "every query attributed to a worker");
        assert!(snap.workers.iter().any(|w| w.panels > 0));
        svc.shutdown();
    }

    #[test]
    fn worker_count_does_not_change_answers() {
        let mut rng = seeded_rng(8);
        let m = RandomMetric::new(12).sample(&mut rng);
        let queries: Vec<(Histogram, Histogram)> = (0..12)
            .map(|_| {
                (
                    Histogram::sample_uniform(12, &mut rng),
                    Histogram::sample_uniform(12, &mut rng),
                )
            })
            .collect();
        let mut answers: Vec<Vec<F>> = Vec::new();
        for workers in [1usize, 4] {
            let mut config = CoordinatorConfig::cpu_only();
            config.cpu_workers = workers;
            config.batcher = BatcherConfig {
                max_batch: 12,
                max_delay: Duration::from_millis(50),
                ..BatcherConfig::default()
            };
            let svc = DistanceService::start(config).unwrap();
            svc.register_metric(MetricId(0), m.clone()).unwrap();
            let rxs: Vec<_> = queries
                .iter()
                .map(|(r, c)| {
                    svc.submit(Query::new(MetricId(0), 9.0, r.clone(), c.clone()))
                        .unwrap()
                })
                .collect();
            answers.push(
                rxs.into_iter()
                    .map(|rx| rx.recv().unwrap().unwrap().distance())
                    .collect(),
            );
            svc.shutdown();
        }
        for (a, b) in answers[0].iter().zip(&answers[1]) {
            assert!((a - b).abs() < 1e-12, "sharding changed a result: {a} vs {b}");
        }
    }

    #[test]
    fn malformed_anneal_schedule_is_rejected_at_start() {
        use crate::sinkhorn::LambdaSchedule;
        for schedule in [
            LambdaSchedule::Geometric { lambda0: 0.0, factor: 3.0, stage_iterations: 30 },
            LambdaSchedule::Geometric { lambda0: 1.0, factor: 1.0, stage_iterations: 30 },
            LambdaSchedule::Geometric { lambda0: -2.0, factor: 0.5, stage_iterations: 1 },
        ] {
            let mut config = CoordinatorConfig::cpu_only();
            config.anneal = schedule;
            let err = DistanceService::start(config).unwrap_err();
            assert!(
                matches!(err, ServiceError::InvalidConfig(_)),
                "expected InvalidConfig, got {err}"
            );
        }
        // A well-formed schedule still starts.
        let mut config = CoordinatorConfig::cpu_only();
        config.anneal = LambdaSchedule::geometric(1.0);
        DistanceService::start(config).unwrap().shutdown();
    }

    #[test]
    fn warm_start_serving_hits_on_repeats() {
        use super::super::WarmStartConfig;
        let mut config = CoordinatorConfig::cpu_only();
        config.warm_start = Some(WarmStartConfig {
            capacity: 64,
            tolerance: 1e-9,
            ..WarmStartConfig::default()
        });
        config.cpu_workers = 2;
        config.batcher = BatcherConfig {
            max_batch: 1,
            max_delay: Duration::from_millis(1),
            ..BatcherConfig::default()
        };
        let svc = DistanceService::start(config).unwrap();
        let mut rng = seeded_rng(11);
        let m = RandomMetric::new(12).sample(&mut rng);
        svc.register_metric(MetricId(0), m.clone()).unwrap();
        let r = Histogram::sample_uniform(12, &mut rng);
        let c = Histogram::sample_uniform(12, &mut rng);
        let query = Query::new(MetricId(0), 9.0, r, c);
        // Sequential identical queries: the first misses and populates,
        // the repeats hit.
        let first = svc.distance(query.clone()).unwrap();
        let second = svc.distance(query.clone()).unwrap();
        let third = svc.distance(query).unwrap();
        assert!((second.distance() - first.distance()).abs() < 1e-7 * (1.0 + first.distance()));
        assert!((third.distance() - first.distance()).abs() < 1e-7 * (1.0 + first.distance()));
        let snap = svc.stats().unwrap();
        assert!(snap.warm_misses >= 1, "first query must miss: {snap}");
        assert!(snap.warm_hits >= 1, "repeats must hit: {snap}");
        assert!(snap.to_string().contains("warm("));
        svc.shutdown();
    }

    #[test]
    fn corpus_registration_validates_and_retrieval_matches_brute_force() {
        let mut config = CoordinatorConfig::cpu_only();
        config.cpu_workers = 2;
        config.retrieval_probe_every = 2; // probe the second query
        let svc = DistanceService::start(config).unwrap();
        let mut rng = seeded_rng(21);
        let d = 12;
        let m = RandomMetric::new(d).sample(&mut rng);
        svc.register_metric(MetricId(0), m.clone()).unwrap();
        let entries: Vec<Histogram> =
            (0..30).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();

        // Unknown metric / bad lambda / bad dimensions are rejected.
        let err = svc
            .register_corpus(CorpusId(0), MetricId(9), 9.0, entries.clone())
            .unwrap_err();
        assert!(matches!(err, ServiceError::UnknownMetric(MetricId(9))));
        let err = svc
            .register_corpus(CorpusId(0), MetricId(0), -1.0, entries.clone())
            .unwrap_err();
        assert!(matches!(err, ServiceError::InvalidConfig(_)));
        let mut bad = entries.clone();
        bad[3] = Histogram::uniform(5);
        let err = svc.register_corpus(CorpusId(0), MetricId(0), 9.0, bad).unwrap_err();
        assert!(matches!(err, ServiceError::DimensionMismatch { got: 5, want: 12 }));

        // A clean registration serves exact pruned top-k.
        let size = svc
            .register_corpus(CorpusId(0), MetricId(0), 9.0, entries.clone())
            .unwrap();
        assert_eq!(size, 30);
        let q = Histogram::sample_uniform(d, &mut rng);
        let out = svc
            .retrieve(RetrievalQuery { corpus: CorpusId(0), r: q.clone(), k: 5 })
            .unwrap();
        assert_eq!(out.hits.len(), 5);
        assert_eq!(out.report.solved + out.report.pruned, 30);
        // Oracle: a standalone retrieval service over the same corpus.
        let index =
            crate::retrieval::CorpusIndex::from_histograms(&m, entries, 4).unwrap();
        let mut oracle = crate::retrieval::RetrievalService::new(
            index,
            crate::retrieval::RetrievalConfig::serving(9.0),
        );
        let brute = oracle.brute_force(&q, 5).unwrap();
        for (a, b) in out.hits.iter().zip(&brute) {
            assert_eq!(a.entry, b.entry);
            assert!((a.distance - b.distance).abs() < 1e-7 * (1.0 + b.distance));
        }
        // Second query trips the recall probe; gauges accumulate.
        let out2 = svc
            .retrieve(RetrievalQuery { corpus: CorpusId(0), r: q, k: 5 })
            .unwrap();
        let probe = out2.report.probe.expect("second query must probe");
        assert_eq!(probe.matched, probe.k);
        let snap = svc.stats().unwrap();
        assert_eq!(snap.retrievals, 2);
        assert_eq!(snap.retrieval_candidates, 60);
        assert_eq!(snap.recall_probes, 1);
        assert!((snap.recall() - 1.0).abs() < 1e-12);
        assert!(snap.to_string().contains("retrieval(queries=2"));

        // Unknown corpus errors; metric replacement drops the corpus.
        let err = svc
            .retrieve(RetrievalQuery {
                corpus: CorpusId(7),
                r: Histogram::uniform(d),
                k: 1,
            })
            .unwrap_err();
        assert!(matches!(err, ServiceError::UnknownCorpus(CorpusId(7))));
        svc.register_metric(MetricId(0), m).unwrap();
        let err = svc
            .retrieve(RetrievalQuery {
                corpus: CorpusId(0),
                r: Histogram::uniform(d),
                k: 1,
            })
            .unwrap_err();
        assert!(
            matches!(err, ServiceError::UnknownCorpus(CorpusId(0))),
            "metric replacement must invalidate dependent corpora"
        );
        svc.shutdown();
    }

    #[test]
    fn malformed_kernel_policy_is_rejected_at_start() {
        use crate::linalg::KernelPolicy;
        for policy in [
            KernelPolicy::Truncated { threshold: 1.0 },
            KernelPolicy::Truncated { threshold: -0.1 },
            KernelPolicy::Truncated { threshold: F::NAN },
            KernelPolicy::LowRank { max_rank: 0, tolerance: -1.0 },
            KernelPolicy::LowRank { max_rank: 0, tolerance: F::INFINITY },
        ] {
            let mut config = CoordinatorConfig::cpu_only();
            config.kernel = policy;
            let err = DistanceService::start(config).unwrap_err();
            assert!(
                matches!(err, ServiceError::InvalidConfig(_)),
                "expected InvalidConfig for {policy:?}, got {err}"
            );
        }
        // Well-formed policies still start.
        let mut config = CoordinatorConfig::cpu_only();
        config.kernel = KernelPolicy::Truncated { threshold: 1e-6 };
        DistanceService::start(config).unwrap().shutdown();
    }

    #[test]
    fn kernel_policy_is_threaded_and_reported() {
        use crate::linalg::KernelPolicy;
        let mut config = CoordinatorConfig::cpu_only();
        config.kernel = KernelPolicy::Truncated { threshold: 1e-6 };
        config.cpu_iterations = 200;
        config.batcher = BatcherConfig {
            max_batch: 4,
            max_delay: Duration::from_millis(1),
            ..BatcherConfig::default()
        };
        let svc = DistanceService::start(config).unwrap();
        let mut rng = seeded_rng(12);
        let m = RandomMetric::new(12).sample(&mut rng);
        svc.register_metric(MetricId(0), m.clone()).unwrap();
        let r = Histogram::sample_uniform(12, &mut rng);
        let c = Histogram::sample_uniform(12, &mut rng);
        // λ=30 puts plenty of kernel mass under the threshold without
        // approaching the underflow (log-domain) regime.
        let res = svc
            .distance(Query::new(MetricId(0), 30.0, r.clone(), c.clone()))
            .unwrap();
        assert_eq!(res.engine, EngineKind::Cpu);
        let want = SinkhornEngine::with_config(&m, SinkhornConfig::fixed(30.0, 200))
            .distance(&r, &c)
            .value;
        assert!(
            (res.distance() - want).abs() < 1e-3 * (1.0 + want),
            "truncated serving {} vs dense {want}",
            res.distance()
        );
        let snap = svc.stats().unwrap();
        let kernel = snap.kernel.expect("kernel gauge after a CPU batch");
        assert!(kernel.nnz < 12 * 12, "policy must reach the executor: {kernel:?}");
        assert!(snap.to_string().contains("kernel(nnz="));
        svc.shutdown();
    }

    #[test]
    fn pinned_backend_is_honored() {
        use crate::backend::BackendKind;
        let mut config = CoordinatorConfig::cpu_only();
        config.cpu_backend = Some(BackendKind::Greenkhorn);
        config.cpu_iterations = 200;
        config.batcher = BatcherConfig {
            max_batch: 4,
            max_delay: Duration::from_millis(1),
            ..BatcherConfig::default()
        };
        let svc = DistanceService::start(config).unwrap();
        let mut rng = seeded_rng(9);
        let m = RandomMetric::new(10).sample(&mut rng);
        svc.register_metric(MetricId(0), m.clone()).unwrap();
        let r = Histogram::sample_uniform(10, &mut rng);
        let c = Histogram::sample_uniform(10, &mut rng);
        let res = svc
            .distance(Query::new(MetricId(0), 9.0, r.clone(), c.clone()))
            .unwrap();
        assert_eq!(res.engine, EngineKind::Cpu);
        // Greenkhorn at a generous budget lands on the same fixed point.
        let want = SinkhornEngine::with_config(&m, SinkhornConfig::fixed(9.0, 200))
            .distance(&r, &c)
            .value;
        assert!(
            (res.distance() - want).abs() < 1e-4 * (1.0 + want),
            "greenkhorn {} vs dense {want}",
            res.distance()
        );
        svc.shutdown();
    }

    #[test]
    fn budgeted_query_returns_certified_interval() {
        let (svc, m) = cpu_service(4, 1);
        let mut rng = seeded_rng(21);
        let r = Histogram::sample_uniform(12, &mut rng);
        let c = Histogram::sample_uniform(12, &mut rng);
        let res = svc
            .distance(
                Query::new(MetricId(0), 9.0, r.clone(), c.clone())
                    .with_budget(SolveBudget::Iterations(64)),
            )
            .unwrap();
        let out = &res.outcome;
        assert!(out.iterations <= 64, "cap honored: {}", out.iterations);
        assert!(out.interval.width().is_finite(), "budgeted solve certifies");
        // The certificate must bracket the fully-converged reference.
        let want = SinkhornEngine::with_config(&m, SinkhornConfig::fixed(9.0, 2000))
            .distance(&r, &c)
            .value;
        assert!(
            out.interval.contains(want),
            "exact {want} outside [{}, {}]",
            out.interval.lo,
            out.interval.hi
        );
        let snap = svc.stats().unwrap();
        assert!(snap.certified_solves >= 1);
        assert!(snap.to_string().contains("anytime(certified="));
        svc.shutdown();
    }

    #[test]
    fn expired_deadline_is_counted_and_still_certified() {
        let (svc, _m) = cpu_service(4, 1);
        let mut rng = seeded_rng(22);
        let r = Histogram::sample_uniform(12, &mut rng);
        let c = Histogram::sample_uniform(12, &mut rng);
        // A deadline already in the past: the solver still runs at least
        // one certified slice, and the miss is recorded.
        let past = Instant::now() - Duration::from_millis(5);
        let res = svc
            .distance(
                Query::new(MetricId(0), 9.0, r, c)
                    .with_budget(SolveBudget::Deadline(past)),
            )
            .unwrap();
        assert!(res.outcome.interval.width().is_finite());
        assert!(res.outcome.iterations <= 64, "expired deadline stops early");
        let snap = svc.stats().unwrap();
        assert_eq!(snap.deadline_misses, 1);
        svc.shutdown();
    }

    #[test]
    fn tightest_budget_rules() {
        use SolveBudget::*;
        let t0 = Instant::now();
        let t1 = t0 + Duration::from_millis(10);
        assert!(tightest(Unbounded, Unbounded).is_unbounded());
        assert!(matches!(tightest(Unbounded, Iterations(7)), Iterations(7)));
        assert!(matches!(tightest(Iterations(3), Iterations(9)), Iterations(3)));
        match tightest(Deadline(t1), Deadline(t0)) {
            Deadline(t) => assert_eq!(t, t0),
            other => panic!("expected earlier deadline, got {other:?}"),
        }
        // Mixed: the deadline is the hard constraint and wins.
        match tightest(Iterations(3), Deadline(t1)) {
            Deadline(t) => assert_eq!(t, t1),
            other => panic!("expected deadline, got {other:?}"),
        }
    }

    #[test]
    fn shed_cap_triggers_only_when_backlogged() {
        let max_delay = Duration::from_millis(10);
        // No shed configured: never sheds.
        assert_eq!(shed_cap(None, Duration::from_secs(1), max_delay), None);
        // Configured but the batch flushed on time: no shed.
        assert_eq!(shed_cap(Some(32), Duration::from_millis(15), max_delay), None);
        // Oldest member waited more than twice the promised delay: shed.
        assert_eq!(shed_cap(Some(32), Duration::from_millis(25), max_delay), Some(32));
    }

    #[test]
    fn backlogged_batch_sheds_to_iteration_cap() {
        let mut config = CoordinatorConfig::cpu_only();
        config.cpu_iterations = 500;
        config.shed_iterations = Some(16);
        // A long flush delay with max_batch 1 means the solo query waits
        // out the full delay before flushing, tripping the 2x shed rule.
        config.batcher = BatcherConfig {
            max_batch: 1,
            max_delay: Duration::from_micros(1),
            ..BatcherConfig::default()
        };
        let svc = DistanceService::start(config).unwrap();
        let mut rng = seeded_rng(23);
        let m = RandomMetric::new(12).sample(&mut rng);
        svc.register_metric(MetricId(0), m).unwrap();
        let r = Histogram::sample_uniform(12, &mut rng);
        let c = Histogram::sample_uniform(12, &mut rng);
        // Prime the engine so a backlog can form, then measure.
        for _ in 0..4 {
            let _ = svc.distance(Query::new(MetricId(0), 9.0, r.clone(), c.clone()));
        }
        let snap = svc.stats().unwrap();
        // With a 1us flush promise every batch arrives "late"; at least one
        // solve must have shed to the 16-iteration cap.
        assert!(snap.budget_sheds >= 1, "expected sheds, got {}", snap.budget_sheds);
        svc.shutdown();
    }
}
