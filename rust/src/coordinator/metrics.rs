//! Service observability: counters and latency aggregates.

use std::time::Duration;

/// Running statistics collected by the service thread.
#[derive(Debug, Default, Clone)]
pub struct Stats {
    pub queries: u64,
    pub batches: u64,
    pub xla_batches: u64,
    pub cpu_batches: u64,
    pub errors: u64,
    /// Sum of batch sizes (for mean batch occupancy).
    pub batched_queries: u64,
    /// Latency accumulators (microseconds).
    lat_sum_us: u128,
    lat_max_us: u64,
    /// Simple log2 histogram of latency in µs: bucket i = [2^i, 2^{i+1}).
    lat_buckets: [u64; 32],
}

impl Stats {
    pub fn record_batch(&mut self, size: usize, engine_is_xla: bool) {
        self.batches += 1;
        self.batched_queries += size as u64;
        if engine_is_xla {
            self.xla_batches += 1;
        } else {
            self.cpu_batches += 1;
        }
    }

    pub fn record_query_latency(&mut self, latency: Duration) {
        self.queries += 1;
        let us = latency.as_micros().min(u64::MAX as u128) as u64;
        self.lat_sum_us += us as u128;
        self.lat_max_us = self.lat_max_us.max(us);
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(31);
        self.lat_buckets[bucket] += 1;
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            queries: self.queries,
            batches: self.batches,
            xla_batches: self.xla_batches,
            cpu_batches: self.cpu_batches,
            errors: self.errors,
            mean_batch_size: if self.batches > 0 {
                self.batched_queries as f64 / self.batches as f64
            } else {
                0.0
            },
            mean_latency_us: if self.queries > 0 {
                (self.lat_sum_us / self.queries as u128) as u64
            } else {
                0
            },
            max_latency_us: self.lat_max_us,
            p99_latency_us: self.quantile_us(0.99),
            p50_latency_us: self.quantile_us(0.50),
        }
    }

    /// Approximate quantile from the log2 histogram (upper bucket edge).
    fn quantile_us(&self, q: f64) -> u64 {
        let total: u64 = self.lat_buckets.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &count) in self.lat_buckets.iter().enumerate() {
            seen += count;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.lat_max_us
    }
}

/// Immutable snapshot returned to callers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StatsSnapshot {
    pub queries: u64,
    pub batches: u64,
    pub xla_batches: u64,
    pub cpu_batches: u64,
    pub errors: u64,
    pub mean_batch_size: f64,
    pub mean_latency_us: u64,
    pub max_latency_us: u64,
    pub p50_latency_us: u64,
    pub p99_latency_us: u64,
}

impl std::fmt::Display for StatsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "queries={} batches={} (xla={}, cpu={}) errors={} mean_batch={:.2} \
             lat_us(mean={}, p50~{}, p99~{}, max={})",
            self.queries,
            self.batches,
            self.xla_batches,
            self.cpu_batches,
            self.errors,
            self.mean_batch_size,
            self.mean_latency_us,
            self.p50_latency_us,
            self.p99_latency_us,
            self.max_latency_us
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_accounting() {
        let mut s = Stats::default();
        s.record_batch(4, true);
        s.record_batch(2, false);
        let snap = s.snapshot();
        assert_eq!(snap.batches, 2);
        assert_eq!(snap.xla_batches, 1);
        assert_eq!(snap.cpu_batches, 1);
        assert!((snap.mean_batch_size - 3.0).abs() < 1e-12);
    }

    #[test]
    fn latency_quantiles_monotone() {
        let mut s = Stats::default();
        for us in [1u64, 10, 100, 1000, 10_000, 100_000] {
            for _ in 0..10 {
                s.record_query_latency(Duration::from_micros(us));
            }
        }
        let snap = s.snapshot();
        assert!(snap.p50_latency_us <= snap.p99_latency_us);
        assert!(snap.p99_latency_us <= snap.max_latency_us * 2);
        assert_eq!(snap.queries, 60);
        assert!(snap.mean_latency_us > 0);
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let snap = Stats::default().snapshot();
        assert_eq!(snap.queries, 0);
        assert_eq!(snap.mean_batch_size, 0.0);
        assert_eq!(snap.p99_latency_us, 0);
    }
}
