//! Service observability: registry-backed counters and latency
//! aggregates.
//!
//! Since PR 10 every plain counter/gauge/histogram lives in a
//! [`crate::telemetry::Registry`] instrument with a stable
//! `sinkhorn_`-prefixed name — that is what `/metrics` exposes — and
//! `Stats` keeps only the structured extras (per-worker occupancy,
//! kernel structure, per-corpus gauge rows) as fields. The snapshot API
//! and its `Display` are unchanged; mutation happens through the record
//! methods below instead of raw field writes.

use crate::linalg::KernelStats;
use crate::retrieval::{CorpusKey, RetrievalReport, RuntimeFeedback, ShardGauges};
use crate::sinkhorn::SolveOutcome;
use crate::telemetry::{
    CounterId, GaugeId, HistogramId, Labels, PromFamily, PromKind, PromSample,
    PromValue, Registry, SloMonitor, TelemetryConfig, TelemetryReport,
};
use crate::trace::{StageRow, Tenant};
use crate::util::histogram::Log2Histogram;
use crate::util::saturating_micros;
use crate::F;
use std::collections::BTreeMap;
use std::time::Duration;

/// Handles to every statically-registered instrument. Registered once at
/// [`Stats`] construction; updates are O(1) dense-vector folds.
#[derive(Debug, Clone, Copy)]
struct Handles {
    queries: CounterId,
    xla_batches: CounterId,
    cpu_batches: CounterId,
    errors: CounterId,
    batched_queries: CounterId,
    lat: HistogramId,
    retrievals: CounterId,
    retrieval_candidates: CounterId,
    retrieval_solved: CounterId,
    retrieval_pruned: CounterId,
    retrieval_rescued: CounterId,
    retrieval_routed: CounterId,
    retrieval_shortlisted: CounterId,
    retrieval_routed_candidates: CounterId,
    recall_probes: CounterId,
    recall_matched: CounterId,
    recall_expected: CounterId,
    retrieval_offthread: CounterId,
    search: HistogramId,
    retrieval_queue_depth: GaugeId,
    retrieval_hol_blocked_us: CounterId,
    retrieval_pruned_interval: CounterId,
    retrieval_refined: CounterId,
    deadline_misses: CounterId,
    budget_sheds: CounterId,
    certified: CounterId,
    width: HistogramId,
}

impl Handles {
    fn register(reg: &mut Registry) -> Self {
        let n = Labels::none;
        Self {
            queries: reg.counter("sinkhorn_queries_total", "Distance queries served", n()),
            xla_batches: reg.counter(
                "sinkhorn_batches_total",
                "Executed batches, by backend",
                Labels::backend("xla"),
            ),
            cpu_batches: reg.counter(
                "sinkhorn_batches_total",
                "Executed batches, by backend",
                Labels::backend("cpu"),
            ),
            errors: reg.counter("sinkhorn_errors_total", "Failed queries and retrieval jobs", n()),
            batched_queries: reg.counter(
                "sinkhorn_batched_queries_total",
                "Sum of executed batch sizes (mean occupancy numerator)",
                n(),
            ),
            lat: reg.histogram(
                "sinkhorn_query_latency_us",
                "Distance query latency (queue wait + execution), microseconds",
                n(),
            ),
            retrievals: reg.counter("sinkhorn_retrievals_total", "Retrieval queries served", n()),
            retrieval_candidates: reg.counter(
                "sinkhorn_retrieval_candidates_total",
                "Corpus candidates considered across retrievals",
                n(),
            ),
            retrieval_solved: reg.counter(
                "sinkhorn_retrieval_solved_total",
                "Candidates solved by the refine stage",
                n(),
            ),
            retrieval_pruned: reg.counter(
                "sinkhorn_retrieval_pruned_total",
                "Candidates discarded on their lower bound alone",
                n(),
            ),
            retrieval_rescued: reg.counter(
                "sinkhorn_retrieval_rescued_total",
                "Refine solves rescued through the exact log-domain path",
                n(),
            ),
            retrieval_routed: reg.counter(
                "sinkhorn_retrieval_routed_total",
                "Retrievals answered from an ANN-router shortlist",
                n(),
            ),
            retrieval_shortlisted: reg.counter(
                "sinkhorn_retrieval_shortlisted_total",
                "Candidates admitted to routed shortlists",
                n(),
            ),
            retrieval_routed_candidates: reg.counter(
                "sinkhorn_retrieval_routed_candidates_total",
                "Corpus candidates considered by routed queries",
                n(),
            ),
            recall_probes: reg.counter(
                "sinkhorn_recall_probes_total",
                "Brute-force recall probes executed",
                n(),
            ),
            recall_matched: reg.counter(
                "sinkhorn_recall_matched_total",
                "Pruned-top-k entries the probes confirmed",
                n(),
            ),
            recall_expected: reg.counter(
                "sinkhorn_recall_expected_total",
                "Entries the probes compared",
                n(),
            ),
            retrieval_offthread: reg.counter(
                "sinkhorn_retrieval_offthread_total",
                "Searches completed on the retrieval runtime",
                n(),
            ),
            search: reg.histogram(
                "sinkhorn_retrieval_search_us",
                "Pure off-thread search walltime (excludes queue wait), microseconds",
                n(),
            ),
            retrieval_queue_depth: reg.gauge(
                "sinkhorn_retrieval_queue_depth",
                "Retrieval jobs queued or running (sampled)",
                n(),
            ),
            retrieval_hol_blocked_us: reg.counter(
                "sinkhorn_retrieval_hol_blocked_us_total",
                "Microseconds searches waited in their corpus mailbox",
                n(),
            ),
            retrieval_pruned_interval: reg.counter(
                "sinkhorn_retrieval_pruned_interval_total",
                "Candidates pruned because their whole certified interval cleared top-k",
                n(),
            ),
            retrieval_refined: reg.counter(
                "sinkhorn_retrieval_refined_total",
                "Budget-pass straddlers escalated to a full refine solve",
                n(),
            ),
            deadline_misses: reg.counter(
                "sinkhorn_deadline_misses_total",
                "Queries answered after their own deadline",
                n(),
            ),
            budget_sheds: reg.counter(
                "sinkhorn_budget_sheds_total",
                "Queries served under a load-shed iteration cap",
                n(),
            ),
            certified: reg.counter(
                "sinkhorn_certified_solves_total",
                "Solves served with a finite certified error interval",
                n(),
            ),
            width: reg.histogram(
                "sinkhorn_interval_width_ppb",
                "Certified interval width quantized to parts-per-billion",
                n(),
            ),
        }
    }
}

/// Running statistics collected by the service thread.
///
/// Plain counters/gauges/histograms are registry instruments (see
/// [`Handles`]); only structured data stays as fields. Constructed via
/// [`Stats::new`] — `Default` is the telemetry-off construction.
#[derive(Debug, Clone)]
pub struct Stats {
    /// The instrument registry (windowed iff telemetry is configured).
    reg: Registry,
    /// Static instrument handles.
    h: Handles,
    /// Per-tenant windowed instruments + SLO evaluation; `Some` exactly
    /// when telemetry is on.
    slo: Option<SloMonitor>,
    /// Per-worker occupancy of the CPU panel executor (index = worker).
    workers: Vec<WorkerSnapshot>,
    /// Kernel structure of the most recently used CPU executor, with
    /// `mass_loss` tracked as the worst observed across executors (shape
    /// classes can differ; the gauge reports the latest structure and
    /// the worst accuracy concession).
    kernel: Option<KernelStats>,
    /// Per-tenant retrieval gauges, keyed by corpus. Every registered
    /// corpus keeps its row (PR 8 fixed the clobbering where each
    /// feedback push overwrote the whole table); invalidation feedback
    /// purges a dropped corpus's row instead of serving it forever.
    retrieval_corpora: BTreeMap<CorpusKey, CorpusGauges>,
    /// Widest certified interval observed, kept in exact `F` units (the
    /// width histogram's own max lives in the quantized ppb domain and
    /// would round the clamp).
    width_max: F,
}

impl Default for Stats {
    fn default() -> Self {
        Self::new(None)
    }
}

/// Per-tenant retrieval gauges: one row per registered corpus, keyed
/// by [`CorpusKey`] in [`StatsSnapshot::retrieval_shards`]. Rows appear
/// on registration, update on every feedback push from that corpus's
/// mailbox, and vanish when the corpus is invalidated.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct CorpusGauges {
    /// The corpus this row describes.
    pub corpus: CorpusKey,
    /// Jobs queued on this corpus's mailbox (sampled by the engine
    /// right before each snapshot; excludes the job being executed).
    pub queue_depth: u64,
    /// Off-thread searches completed for this corpus.
    pub searches: u64,
    /// Σ µs this corpus's searches waited in its mailbox before
    /// dispatch (the per-tenant slice of
    /// [`StatsSnapshot::retrieval_hol_blocked_us`]).
    pub hol_blocked_us: u64,
    /// Σ µs spent building/rebuilding this corpus's sharded index inside
    /// `register_corpus` (PR 9). `queued_us` measures mailbox *wait*;
    /// this measures the bulk-lane *work* that caused it, so one tenant's
    /// registration pressure is attributable from the same row.
    pub build_us: u64,
    /// Per-shard gauges from the corpus's latest feedback push.
    pub shards: Vec<ShardGauges>,
}

/// Throughput/occupancy counters for one executor worker.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct WorkerSnapshot {
    /// Panels (shards) this worker executed.
    pub panels: u64,
    /// Queries solved by this worker.
    pub queries: u64,
    /// Busy wallclock, microseconds.
    pub busy_us: u64,
    /// Warm-start store hits (0 unless warm-start serving is on).
    pub warm_hits: u64,
    /// Warm-start store misses (0 unless warm-start serving is on).
    pub warm_misses: u64,
}

impl Stats {
    /// Construct the stats surface. With `telemetry` set, the registry
    /// is windowed (a ring of `windows` × `window` slots) and the
    /// per-tenant SLO monitor exists; with `None` every instrument is a
    /// plain cumulative fold and recording never reads the clock — the
    /// zero-overhead contract of [`crate::telemetry`].
    pub fn new(telemetry: Option<&TelemetryConfig>) -> Self {
        let mut reg = Registry::new(telemetry.map(|t| (t.window, t.windows)));
        let h = Handles::register(&mut reg);
        let slo = telemetry.map(|t| SloMonitor::new(t.slo));
        Self {
            reg,
            h,
            slo,
            workers: Vec::new(),
            kernel: None,
            retrieval_corpora: BTreeMap::new(),
            width_max: 0.0,
        }
    }

    /// The instrument registry. Engine-thread-owned; the scrape server
    /// reads it by round-tripping a message through the engine loop,
    /// never by sharing memory.
    pub fn registry(&self) -> &Registry {
        &self.reg
    }

    /// Count one failed query or retrieval job.
    pub fn inc_errors(&mut self) {
        self.reg.add(self.h.errors, 1);
    }

    /// Count `n` queries served under a load-shed iteration cap.
    pub fn add_budget_sheds(&mut self, n: u64) {
        self.reg.add(self.h.budget_sheds, n);
    }

    /// Refresh the sampled retrieval queue-depth gauge.
    pub fn set_retrieval_queue_depth(&mut self, depth: u64) {
        self.reg.set(self.h.retrieval_queue_depth, depth as f64);
    }

    /// Refresh the SLO burn-rate gauges and the armed set. No-op when
    /// telemetry is off or the config carries no policy; cheap enough to
    /// call once per engine-loop turn.
    pub fn evaluate_slo(&mut self) {
        if let Some(slo) = &mut self.slo {
            slo.evaluate(&mut self.reg);
        }
    }

    /// Iteration cap for an SLO-armed tenant's batch; `None` when the
    /// tenant is compliant, the policy is alert-only, or telemetry is
    /// off.
    pub fn slo_shed_cap(&self, tenant: u32) -> Option<usize> {
        self.slo.as_ref()?.shed_cap(tenant)
    }

    /// The windowed per-tenant SLO report (`None` when telemetry is
    /// off).
    pub fn telemetry_report(&self) -> Option<TelemetryReport> {
        self.slo.as_ref().map(|slo| slo.report(&self.reg))
    }

    /// Record one shard executed by `worker` (resizes the table to fit).
    pub fn record_worker(
        &mut self,
        worker: usize,
        queries: usize,
        busy: Duration,
        warm_hits: usize,
        warm_misses: usize,
    ) {
        if worker >= self.workers.len() {
            self.workers.resize(worker + 1, WorkerSnapshot::default());
        }
        let slot = &mut self.workers[worker];
        slot.panels += 1;
        slot.queries += queries as u64;
        slot.busy_us += saturating_micros(busy);
        slot.warm_hits += warm_hits as u64;
        slot.warm_misses += warm_misses as u64;
    }

    /// Record the kernel structure of the executor that just served a
    /// CPU batch (achieved nnz / rank / mass loss). Both accuracy
    /// concessions — mass loss and the Frobenius budget — are kept
    /// sticky-max across shape classes; the structural fields report
    /// the latest executor.
    pub fn record_kernel(&mut self, stats: KernelStats) {
        let (worst_loss, worst_frob) = match self.kernel {
            Some(prev) => (
                prev.mass_loss.max(stats.mass_loss),
                prev.frobenius_budget.max(stats.frobenius_budget),
            ),
            None => (stats.mass_loss, stats.frobenius_budget),
        };
        self.kernel = Some(KernelStats {
            mass_loss: worst_loss,
            frobenius_budget: worst_frob,
            ..stats
        });
    }

    /// Fold one runtime feedback push into the gauges: completed-search
    /// reports accumulate like inline retrievals used to, failed jobs
    /// count as errors, and the per-tenant gauge table upserts the
    /// pushing corpus's row (never another tenant's — PR 8 fixed the
    /// clobbering where `retrieval_shards = gauges.clone()` let every
    /// push overwrite the whole table). Invalidation pushes purge the
    /// corpus's row.
    pub fn record_runtime(&mut self, feedback: &RuntimeFeedback) {
        if feedback.failed {
            self.reg.add(self.h.errors, 1);
        }
        self.reg.add(self.h.retrieval_hol_blocked_us, feedback.queued_us);
        if let Some(report) = &feedback.report {
            self.record_retrieval(report);
            self.reg.add(self.h.retrieval_offthread, 1);
            self.reg.observe(self.h.search, feedback.search_us);
            if let Some(slo) = &mut self.slo {
                slo.on_search(
                    &mut self.reg,
                    feedback.corpus,
                    feedback.search_us,
                    report.probe.map(|p| (p.matched as u64, p.k as u64)),
                );
            }
        }
        if feedback.invalidated {
            self.retrieval_corpora.remove(&feedback.corpus);
            return;
        }
        if !feedback.gauges.is_empty() {
            let row = self.retrieval_corpora.entry(feedback.corpus).or_default();
            row.corpus = feedback.corpus;
            row.shards = feedback.gauges.clone();
            row.hol_blocked_us = row.hol_blocked_us.saturating_add(feedback.queued_us);
            row.build_us = row.build_us.saturating_add(feedback.build_us);
            if feedback.report.is_some() {
                row.searches += 1;
            }
        }
    }

    /// Refresh the sampled per-corpus mailbox backlogs (from
    /// [`crate::retrieval::RetrievalRuntime::corpus_depths`]); corpora
    /// absent from `depths` read zero.
    pub fn set_corpus_queue_depths(&mut self, depths: &[(CorpusKey, u64)]) {
        for row in self.retrieval_corpora.values_mut() {
            row.queue_depth = 0;
        }
        for &(corpus, depth) in depths {
            if let Some(row) = self.retrieval_corpora.get_mut(&corpus) {
                row.queue_depth = depth;
            }
        }
    }

    /// Fold one retrieval query's report into the gauges.
    pub fn record_retrieval(&mut self, report: &RetrievalReport) {
        self.reg.add(self.h.retrievals, 1);
        self.reg.add(self.h.retrieval_candidates, report.corpus as u64);
        self.reg.add(self.h.retrieval_solved, report.solved as u64);
        self.reg.add(self.h.retrieval_pruned, report.pruned as u64);
        self.reg.add(self.h.retrieval_rescued, report.rescued as u64);
        self.reg.add(self.h.retrieval_pruned_interval, report.pruned_interval as u64);
        self.reg.add(self.h.retrieval_refined, report.refined as u64);
        if report.routed {
            self.reg.add(self.h.retrieval_routed, 1);
            self.reg.add(self.h.retrieval_shortlisted, report.shortlist as u64);
            self.reg.add(self.h.retrieval_routed_candidates, report.corpus as u64);
        }
        if let Some(probe) = report.probe {
            self.reg.add(self.h.recall_probes, 1);
            self.reg.add(self.h.recall_matched, probe.matched as u64);
            self.reg.add(self.h.recall_expected, probe.k as u64);
        }
    }

    /// Record one served anytime outcome for `tenant` (its `MetricId`).
    /// Only certified (finite-width) intervals feed the width histogram;
    /// uncertified paths — XLA artifacts and unbounded CPU serving — are
    /// skipped, so the gauge reflects exactly the solves whose accuracy
    /// was being traded.
    pub fn record_outcome(&mut self, tenant: u32, outcome: &SolveOutcome) {
        let width = outcome.interval.width();
        if !width.is_finite() {
            return;
        }
        self.reg.add(self.h.certified, 1);
        self.width_max = self.width_max.max(width);
        // Quantize to ppb so the log2 bucketing has an integer to bite
        // on; sub-ppb widths land in the bottom bucket.
        let ppb = (width * 1e9).min(u64::MAX as F) as u64;
        self.reg.observe(self.h.width, ppb);
        if let Some(slo) = &mut self.slo {
            slo.on_outcome(&mut self.reg, tenant, ppb);
        }
    }

    pub fn record_batch(&mut self, size: usize, engine_is_xla: bool) {
        self.reg.add(self.h.batched_queries, size as u64);
        let backend =
            if engine_is_xla { self.h.xla_batches } else { self.h.cpu_batches };
        self.reg.add(backend, 1);
    }

    /// Record one served query for `tenant` (its `MetricId`): the global
    /// latency and deadline-miss instruments, plus the per-tenant
    /// windowed instruments when telemetry is on. `missed` marks a query
    /// answered after its own [`crate::sinkhorn::SolveBudget`] deadline.
    pub fn record_query_served(&mut self, tenant: u32, latency: Duration, missed: bool) {
        let us = saturating_micros(latency);
        self.reg.add(self.h.queries, 1);
        self.reg.observe(self.h.lat, us);
        if missed {
            self.reg.add(self.h.deadline_misses, 1);
        }
        if let Some(slo) = &mut self.slo {
            slo.on_query(&mut self.reg, tenant, us, missed);
        }
    }

    /// Tenant-less latency fold for call sites without a query attached.
    pub fn record_query_latency(&mut self, latency: Duration) {
        self.record_query_served(0, latency, false);
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        let h = &self.h;
        let queries = self.reg.counter_value(h.queries);
        let xla_batches = self.reg.counter_value(h.xla_batches);
        let cpu_batches = self.reg.counter_value(h.cpu_batches);
        let batches = xla_batches + cpu_batches;
        let (lat, lat_sum) = self.reg.histogram_cum(h.lat);
        let (search, search_sum) = self.reg.histogram_cum(h.search);
        let offthread = self.reg.counter_value(h.retrieval_offthread);
        StatsSnapshot {
            queries,
            batches,
            xla_batches,
            cpu_batches,
            errors: self.reg.counter_value(h.errors),
            mean_batch_size: if batches > 0 {
                self.reg.counter_value(h.batched_queries) as f64 / batches as f64
            } else {
                0.0
            },
            mean_latency_us: if queries > 0 {
                (lat_sum / queries as u128) as u64
            } else {
                0
            },
            max_latency_us: lat.observed_max(),
            p99_latency_us: lat.quantile(0.99),
            p50_latency_us: lat.quantile(0.50),
            warm_hits: self.workers.iter().map(|w| w.warm_hits).sum(),
            warm_misses: self.workers.iter().map(|w| w.warm_misses).sum(),
            workers: self.workers.clone(),
            kernel: self.kernel,
            retrievals: self.reg.counter_value(h.retrievals),
            retrieval_candidates: self.reg.counter_value(h.retrieval_candidates),
            retrieval_solved: self.reg.counter_value(h.retrieval_solved),
            retrieval_pruned: self.reg.counter_value(h.retrieval_pruned),
            retrieval_rescued: self.reg.counter_value(h.retrieval_rescued),
            retrieval_routed: self.reg.counter_value(h.retrieval_routed),
            retrieval_shortlisted: self.reg.counter_value(h.retrieval_shortlisted),
            retrieval_routed_candidates: self
                .reg
                .counter_value(h.retrieval_routed_candidates),
            recall_probes: self.reg.counter_value(h.recall_probes),
            recall_matched: self.reg.counter_value(h.recall_matched),
            recall_expected: self.reg.counter_value(h.recall_expected),
            retrieval_offthread: offthread,
            retrieval_search_mean_us: if offthread > 0 {
                (search_sum / offthread as u128) as u64
            } else {
                0
            },
            retrieval_search_max_us: search.observed_max(),
            retrieval_queue_depth: self.reg.gauge_value(h.retrieval_queue_depth) as u64,
            retrieval_hol_blocked_us: self
                .reg
                .counter_value(h.retrieval_hol_blocked_us),
            retrieval_shards: self.retrieval_corpora.values().cloned().collect(),
            retrieval_pruned_interval: self
                .reg
                .counter_value(h.retrieval_pruned_interval),
            retrieval_refined: self.reg.counter_value(h.retrieval_refined),
            deadline_misses: self.reg.counter_value(h.deadline_misses),
            budget_sheds: self.reg.counter_value(h.budget_sheds),
            certified_solves: self.reg.counter_value(h.certified),
            interval_width_p50: self.width_quantile(0.50),
            interval_width_p99: self.width_quantile(0.99),
            interval_width_max: self.width_max,
            stages: Vec::new(),
            traces_sampled: 0,
            trace_spans: 0,
            trace_spans_dropped: 0,
        }
    }

    /// Approximate interval-width quantile: the upper bucket edge mapped
    /// back from ppb into absolute d^λ units, clamped to the *exact*
    /// observed maximum (`width_max` is kept in `F`, not the quantized
    /// domain, so single-bucket distributions stay exact — the same PR 7
    /// clamp [`Log2Histogram::quantile`] applies in the integer domain).
    fn width_quantile(&self, q: f64) -> F {
        let (width, _) = self.reg.histogram_cum(self.h.width);
        if width.is_empty() {
            return 0.0;
        }
        match width.quantile_bucket(q) {
            Some(i) => ((1u64 << (i + 1)) as F * 1e-9).min(self.width_max),
            None => self.width_max,
        }
    }

    /// Render the full `/metrics` exposition: every registry instrument,
    /// plus hand-composed families for the structured gauges the
    /// registry cannot hold — per-corpus rows keyed by dynamic tenant,
    /// warm-start totals summed over workers, and the PR 9 collector's
    /// per-(stage, tenant) span histograms and trace counters.
    pub fn prometheus(
        &self,
        stages: &[((&'static str, Tenant), Log2Histogram)],
        trace: Option<(u64, u64, u64)>,
    ) -> String {
        let mut fams = self.reg.families();
        if !self.retrieval_corpora.is_empty() {
            let mut depth = Vec::new();
            let mut searches = Vec::new();
            let mut hol = Vec::new();
            let mut build = Vec::new();
            for row in self.retrieval_corpora.values() {
                let labels = vec![("tenant", Tenant::Corpus(row.corpus).label())];
                depth.push(PromSample {
                    labels: labels.clone(),
                    value: PromValue::Gauge(row.queue_depth as f64),
                });
                searches.push(PromSample {
                    labels: labels.clone(),
                    value: PromValue::Counter(row.searches),
                });
                hol.push(PromSample {
                    labels: labels.clone(),
                    value: PromValue::Counter(row.hol_blocked_us),
                });
                build.push(PromSample {
                    labels,
                    value: PromValue::Counter(row.build_us),
                });
            }
            fams.push(PromFamily {
                name: "sinkhorn_corpus_queue_depth",
                help: "Sampled mailbox backlog, per corpus tenant",
                kind: PromKind::Gauge,
                samples: depth,
            });
            fams.push(PromFamily {
                name: "sinkhorn_corpus_searches_total",
                help: "Off-thread searches served, per corpus tenant",
                kind: PromKind::Counter,
                samples: searches,
            });
            fams.push(PromFamily {
                name: "sinkhorn_corpus_hol_blocked_us_total",
                help: "Microseconds waited in the corpus mailbox before dispatch",
                kind: PromKind::Counter,
                samples: hol,
            });
            fams.push(PromFamily {
                name: "sinkhorn_corpus_build_us_total",
                help: "Microseconds spent building the corpus's sharded index",
                kind: PromKind::Counter,
                samples: build,
            });
        }
        for (name, help, v) in [
            (
                "sinkhorn_warm_hits_total",
                "Warm-start store hits across workers",
                self.workers.iter().map(|w| w.warm_hits).sum::<u64>(),
            ),
            (
                "sinkhorn_warm_misses_total",
                "Warm-start store misses across workers",
                self.workers.iter().map(|w| w.warm_misses).sum::<u64>(),
            ),
        ] {
            fams.push(PromFamily {
                name,
                help,
                kind: PromKind::Counter,
                samples: vec![PromSample {
                    labels: Vec::new(),
                    value: PromValue::Counter(v),
                }],
            });
        }
        if !stages.is_empty() {
            fams.push(PromFamily {
                name: "sinkhorn_stage_duration_us",
                help: "Span duration per (stage, tenant); _sum is approximated \
                       from log2 bucket lower edges (within 2x of the true sum)",
                kind: PromKind::Histogram,
                samples: stages
                    .iter()
                    .map(|((stage, tenant), hist)| PromSample {
                        labels: vec![
                            ("stage", stage.to_string()),
                            ("tenant", tenant.label()),
                        ],
                        value: PromValue::histogram(hist, log2_lower_edge_sum(hist)),
                    })
                    .collect(),
            });
        }
        if let Some((sampled, spans, dropped)) = trace {
            for (name, help, v) in [
                (
                    "sinkhorn_traces_sampled_total",
                    "Queries/retrievals that passed the trace sampling gate",
                    sampled,
                ),
                (
                    "sinkhorn_trace_spans_total",
                    "Spans folded by the trace collector",
                    spans,
                ),
                (
                    "sinkhorn_trace_spans_dropped_total",
                    "Spans lost to ring overflow or recording contention",
                    dropped,
                ),
            ] {
                fams.push(PromFamily {
                    name,
                    help,
                    kind: PromKind::Counter,
                    samples: vec![PromSample {
                        labels: Vec::new(),
                        value: PromValue::Counter(v),
                    }],
                });
            }
        }
        fams.sort_by(|a, b| a.name.cmp(b.name));
        crate::telemetry::render_prometheus(&fams)
    }
}

/// Lower-edge sum approximation for histograms whose exact sample sum
/// was never tracked (the trace collector folds log2 buckets only):
/// `Σ count_i · 2^i` understates the true sum by at most 2×.
fn log2_lower_edge_sum(h: &Log2Histogram) -> u128 {
    h.buckets()
        .iter()
        .enumerate()
        .map(|(i, &n)| n as u128 * (1u128 << i))
        .sum()
}

/// Immutable snapshot returned to callers.
///
/// ## Counter monotonicity
///
/// Every plain counter field — `queries`, `batches`, `errors`,
/// `deadline_misses`, `budget_sheds`, `retrieval_hol_blocked_us`,
/// `warm_hits`/`warm_misses`, the `retrieval_*` and `recall_*` totals,
/// `certified_solves`, and the trace counters — is cumulative since
/// service start and **never decreases** across successive snapshots of
/// one service (windowed telemetry views decay; these do not). Gauges
/// (`retrieval_queue_depth`, per-corpus `queue_depth`) and derived
/// means/quantiles may move in either direction. The property is
/// enforced by the `snapshot_counters_are_monotone_under_live_traffic`
/// test in `tests/telemetry_e2e.rs`, which drives real traffic and
/// diffs consecutive snapshots.
///
/// The `Display` rendering is one line of space-separated sections, each
/// printed only when it has something to say:
///
/// * the always-present header — `queries= batches= (xla=, cpu=) errors=
///   mean_batch= lat_us(mean=, p50~, p99~, max=)`;
/// * `workers=[..] balance=` — per-worker executor occupancy;
/// * `warm(hits=, misses=, rate=)` — warm-start store traffic;
/// * `kernel(nnz=, density=, rank=, mass_loss=)` — kernel structure;
/// * `anytime(certified=, width(p50~, p99~, max=), deadline_miss=,
///   shed=)` — certified-interval gauges;
/// * `retrieval(..)`, `rinterval(..)`, `routing(..)`, `recall(..)`,
///   `rsearch(..)` — retrieval pipeline gauges;
/// * `corpora={..} fairness=` — per-tenant rows (with ` build=`µs after
///   `hol_us=` once a tenant has accumulated index-build time);
/// * `stages={stage[tenant]: n= p50~ p99~ ..} traces(sampled=, spans=,
///   dropped=)` — the PR 9 `stage_breakdown` section, present once
///   tracing is enabled and at least one span was collected: clamped
///   log2-histogram p50/p99 of span duration per (stage, tenant).
#[derive(Debug, Clone, PartialEq)]
pub struct StatsSnapshot {
    pub queries: u64,
    pub batches: u64,
    pub xla_batches: u64,
    pub cpu_batches: u64,
    pub errors: u64,
    pub mean_batch_size: f64,
    pub mean_latency_us: u64,
    pub max_latency_us: u64,
    /// Approximate median latency: log2-bucketed upper edge clamped to
    /// `max_latency_us`, so the value is within ±1 bucket (at most 2×)
    /// of the true quantile and never exceeds the observed maximum.
    pub p50_latency_us: u64,
    /// Approximate 99th-percentile latency, same ±1-bucket quantization
    /// and observed-max clamp as `p50_latency_us`.
    pub p99_latency_us: u64,
    /// Total warm-start store hits across workers (0 unless warm-start
    /// serving is on).
    pub warm_hits: u64,
    /// Total warm-start store misses across workers.
    pub warm_misses: u64,
    /// Per-worker executor occupancy (empty until a CPU panel ran).
    pub workers: Vec<WorkerSnapshot>,
    /// Kernel structure of the most recent CPU executor (None until a
    /// CPU panel ran): achieved nnz / rank, with `mass_loss` the worst
    /// observed across shape classes.
    pub kernel: Option<KernelStats>,
    /// Retrieval queries served.
    pub retrievals: u64,
    /// Corpus candidates considered across retrievals.
    pub retrieval_candidates: u64,
    /// Candidates solved by the refine stage.
    pub retrieval_solved: u64,
    /// Candidates pruned on their lower bound alone.
    pub retrieval_pruned: u64,
    /// Refine solves rescued through the exact log-domain path.
    pub retrieval_rescued: u64,
    /// Retrievals answered from an ANN-router shortlist (PR 7). Zero
    /// with routing disabled — the default, exact configuration.
    pub retrieval_routed: u64,
    /// Candidates admitted to routed shortlists (Σ over routed
    /// queries only).
    pub retrieval_shortlisted: u64,
    /// Corpus candidates considered by routed queries (denominator of
    /// [`Self::retrieval_shortlist_fraction`]).
    pub retrieval_routed_candidates: u64,
    /// Brute-force recall probes executed.
    pub recall_probes: u64,
    /// Pruned-top-k entries the probes confirmed.
    pub recall_matched: u64,
    /// Entries the probes compared.
    pub recall_expected: u64,
    /// Searches completed on the dedicated retrieval runtime thread
    /// (every search since PR 5 — the engine thread no longer walks a
    /// corpus).
    pub retrieval_offthread: u64,
    /// Mean pure search walltime on the runtime thread (µs, excludes
    /// queue wait).
    pub retrieval_search_mean_us: u64,
    /// Worst single off-thread search walltime (µs).
    pub retrieval_search_max_us: u64,
    /// Retrieval jobs queued or running when the snapshot was taken.
    pub retrieval_queue_depth: u64,
    /// Σ µs searches waited in their corpus mailbox before dispatch —
    /// the head-of-line blocking counter (PR 8).
    pub retrieval_hol_blocked_us: u64,
    /// Per-tenant retrieval gauges, one row per registered corpus in
    /// ascending corpus-key order: sampled mailbox backlog, served
    /// searches, per-tenant head-of-line wait, and the per-shard gauges
    /// (entries, live count, tombstone fraction, compactions, inserts,
    /// searches, last per-shard search walltime) from the corpus's
    /// latest feedback push. Rows vanish when a corpus is invalidated.
    pub retrieval_shards: Vec<CorpusGauges>,
    /// Candidates discarded because their whole certified interval
    /// cleared the top-k threshold (budgeted retrieval only).
    pub retrieval_pruned_interval: u64,
    /// Budget-pass straddlers escalated to a full refine solve.
    pub retrieval_refined: u64,
    /// Queries answered after their own [`crate::sinkhorn::SolveBudget`]
    /// deadline had already passed.
    pub deadline_misses: u64,
    /// Queries served under a load-shed iteration cap (see
    /// [`super::CoordinatorConfig::shed_iterations`]).
    pub budget_sheds: u64,
    /// Solves served with a finite certified error interval.
    pub certified_solves: u64,
    /// Approximate median certified interval width (log2-bucketed
    /// upper edge clamped to `interval_width_max` — ±1-bucket
    /// quantization, at most 2× the true quantile; 0.0 before any
    /// certified solve).
    pub interval_width_p50: F,
    /// Approximate 99th-percentile certified interval width, same
    /// ±1-bucket quantization and observed-max clamp.
    pub interval_width_p99: F,
    /// Widest certified interval served.
    pub interval_width_max: F,
    /// PR 9 `stage_breakdown`: per-(stage, tenant) span-duration
    /// quantiles from the tracing collector, in ascending (stage,
    /// tenant) order. Empty when tracing is off (`CoordinatorConfig::
    /// trace` unset) or no span has been collected yet.
    pub stages: Vec<StageRow>,
    /// Queries/retrievals that passed the trace sampling gate.
    pub traces_sampled: u64,
    /// Spans folded by the trace collector.
    pub trace_spans: u64,
    /// Spans lost to ring overflow or recording contention — nonzero
    /// means `TraceConfig::ring_capacity` is too small for the traffic.
    pub trace_spans_dropped: u64,
}

impl StatsSnapshot {
    /// Fraction of all considered corpus candidates that were discarded
    /// without a solve (0.0 before any retrieval ran).
    pub fn retrieval_pruned_fraction(&self) -> f64 {
        if self.retrieval_candidates == 0 {
            return 0.0;
        }
        self.retrieval_pruned as f64 / self.retrieval_candidates as f64
    }

    /// Mean fraction of the corpus the ANN router admitted to pricing,
    /// over routed queries only (1.0 before any routed retrieval —
    /// with routing off the exact walk prices everything). The bench
    /// contract pairs this with [`Self::recall`]: small fraction,
    /// probe-audited recall.
    pub fn retrieval_shortlist_fraction(&self) -> f64 {
        if self.retrieval_routed_candidates == 0 {
            return 1.0;
        }
        self.retrieval_shortlisted as f64 / self.retrieval_routed_candidates as f64
    }

    /// Probed recall of the pruned search in [0, 1] (vacuously 1.0
    /// before any probe ran — pruning is exact by construction and the
    /// probes exist to audit that claim in production).
    pub fn recall(&self) -> f64 {
        if self.recall_expected == 0 {
            return 1.0;
        }
        self.recall_matched as f64 / self.recall_expected as f64
    }

    /// Cross-tenant serving fairness: min/max completed off-thread
    /// search counts over corpora that served at least one search
    /// (1.0 = perfectly even — or fewer than two active tenants, where
    /// fairness is vacuous). A value near 0 means one tenant's
    /// searches are being starved relative to another's.
    pub fn retrieval_fairness(&self) -> f64 {
        let mut min = u64::MAX;
        let mut max = 0u64;
        let mut active = 0usize;
        for row in &self.retrieval_shards {
            if row.searches > 0 {
                active += 1;
                min = min.min(row.searches);
                max = max.max(row.searches);
            }
        }
        if active < 2 {
            return 1.0;
        }
        min as f64 / max as f64
    }

    /// Warm-start hit rate in [0, 1]; 0.0 before any lookup happened.
    pub fn warm_hit_rate(&self) -> f64 {
        let total = self.warm_hits + self.warm_misses;
        if total == 0 {
            return 0.0;
        }
        self.warm_hits as f64 / total as f64
    }

    /// Mean worker occupancy: busy time of each worker relative to the
    /// busiest one (1.0 = perfectly balanced pool). Zero when no CPU
    /// panel has run yet.
    pub fn worker_balance(&self) -> f64 {
        let max = self.workers.iter().map(|w| w.busy_us).max().unwrap_or(0);
        if max == 0 {
            return 0.0;
        }
        let sum: u64 = self.workers.iter().map(|w| w.busy_us).sum();
        sum as f64 / (max as f64 * self.workers.len() as f64)
    }

    /// The snapshot as a [`crate::util::json::Json`] object — the body
    /// the scrape server's `/snapshot` endpoint serves. Counters render
    /// as numbers (f64 holds every counter this process can plausibly
    /// accumulate exactly up to 2^53); structured rows nest as arrays.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        fn n(v: u64) -> Json {
            Json::Number(v as f64)
        }
        let mut o = BTreeMap::new();
        o.insert("queries".into(), n(self.queries));
        o.insert("batches".into(), n(self.batches));
        o.insert("xla_batches".into(), n(self.xla_batches));
        o.insert("cpu_batches".into(), n(self.cpu_batches));
        o.insert("errors".into(), n(self.errors));
        o.insert("mean_batch_size".into(), Json::Number(self.mean_batch_size));
        o.insert("mean_latency_us".into(), n(self.mean_latency_us));
        o.insert("p50_latency_us".into(), n(self.p50_latency_us));
        o.insert("p99_latency_us".into(), n(self.p99_latency_us));
        o.insert("max_latency_us".into(), n(self.max_latency_us));
        o.insert("warm_hits".into(), n(self.warm_hits));
        o.insert("warm_misses".into(), n(self.warm_misses));
        o.insert(
            "workers".into(),
            Json::Array(
                self.workers
                    .iter()
                    .map(|w| {
                        let mut row = BTreeMap::new();
                        row.insert("panels".into(), n(w.panels));
                        row.insert("queries".into(), n(w.queries));
                        row.insert("busy_us".into(), n(w.busy_us));
                        row.insert("warm_hits".into(), n(w.warm_hits));
                        row.insert("warm_misses".into(), n(w.warm_misses));
                        Json::Object(row)
                    })
                    .collect(),
            ),
        );
        if let Some(k) = self.kernel {
            let mut row = BTreeMap::new();
            row.insert("dim".into(), n(k.dim as u64));
            row.insert("nnz".into(), n(k.nnz as u64));
            row.insert("rank".into(), n(k.rank as u64));
            row.insert("mass_loss".into(), Json::Number(k.mass_loss));
            row.insert(
                "frobenius_budget".into(),
                Json::Number(k.frobenius_budget),
            );
            o.insert("kernel".into(), Json::Object(row));
        }
        o.insert("retrievals".into(), n(self.retrievals));
        o.insert("retrieval_candidates".into(), n(self.retrieval_candidates));
        o.insert("retrieval_solved".into(), n(self.retrieval_solved));
        o.insert("retrieval_pruned".into(), n(self.retrieval_pruned));
        o.insert("retrieval_rescued".into(), n(self.retrieval_rescued));
        o.insert("retrieval_routed".into(), n(self.retrieval_routed));
        o.insert("retrieval_shortlisted".into(), n(self.retrieval_shortlisted));
        o.insert("recall_probes".into(), n(self.recall_probes));
        o.insert("recall".into(), Json::Number(self.recall()));
        o.insert("retrieval_offthread".into(), n(self.retrieval_offthread));
        o.insert(
            "retrieval_search_mean_us".into(),
            n(self.retrieval_search_mean_us),
        );
        o.insert(
            "retrieval_search_max_us".into(),
            n(self.retrieval_search_max_us),
        );
        o.insert("retrieval_queue_depth".into(), n(self.retrieval_queue_depth));
        o.insert(
            "retrieval_hol_blocked_us".into(),
            n(self.retrieval_hol_blocked_us),
        );
        o.insert(
            "corpora".into(),
            Json::Array(
                self.retrieval_shards
                    .iter()
                    .map(|row| {
                        let mut r = BTreeMap::new();
                        r.insert("corpus".into(), n(row.corpus as u64));
                        r.insert("queue_depth".into(), n(row.queue_depth));
                        r.insert("searches".into(), n(row.searches));
                        r.insert("hol_blocked_us".into(), n(row.hol_blocked_us));
                        r.insert("build_us".into(), n(row.build_us));
                        r.insert("shards".into(), n(row.shards.len() as u64));
                        Json::Object(r)
                    })
                    .collect(),
            ),
        );
        o.insert("deadline_misses".into(), n(self.deadline_misses));
        o.insert("budget_sheds".into(), n(self.budget_sheds));
        o.insert("certified_solves".into(), n(self.certified_solves));
        o.insert(
            "interval_width_p50".into(),
            Json::Number(self.interval_width_p50),
        );
        o.insert(
            "interval_width_p99".into(),
            Json::Number(self.interval_width_p99),
        );
        o.insert(
            "interval_width_max".into(),
            Json::Number(self.interval_width_max),
        );
        o.insert(
            "stages".into(),
            Json::Array(
                self.stages
                    .iter()
                    .map(|s| {
                        let mut r = BTreeMap::new();
                        r.insert("stage".into(), Json::String(s.stage.into()));
                        r.insert("tenant".into(), Json::String(s.tenant.clone()));
                        r.insert("count".into(), n(s.count));
                        r.insert("p50_us".into(), n(s.p50_us));
                        r.insert("p99_us".into(), n(s.p99_us));
                        r.insert("max_us".into(), n(s.max_us));
                        Json::Object(r)
                    })
                    .collect(),
            ),
        );
        o.insert("traces_sampled".into(), n(self.traces_sampled));
        o.insert("trace_spans".into(), n(self.trace_spans));
        o.insert("trace_spans_dropped".into(), n(self.trace_spans_dropped));
        Json::Object(o)
    }
}

impl std::fmt::Display for StatsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "queries={} batches={} (xla={}, cpu={}) errors={} mean_batch={:.2} \
             lat_us(mean={}, p50~{}, p99~{}, max={})",
            self.queries,
            self.batches,
            self.xla_batches,
            self.cpu_batches,
            self.errors,
            self.mean_batch_size,
            self.mean_latency_us,
            self.p50_latency_us,
            self.p99_latency_us,
            self.max_latency_us
        )?;
        if !self.workers.is_empty() {
            write!(f, " workers=[")?;
            for (i, w) in self.workers.iter().enumerate() {
                if i > 0 {
                    write!(f, ", ")?;
                }
                write!(f, "{i}:q={} busy_us={}", w.queries, w.busy_us)?;
            }
            write!(f, "] balance={:.2}", self.worker_balance())?;
        }
        if self.warm_hits + self.warm_misses > 0 {
            write!(
                f,
                " warm(hits={}, misses={}, rate={:.2})",
                self.warm_hits,
                self.warm_misses,
                self.warm_hit_rate()
            )?;
        }
        if let Some(k) = &self.kernel {
            write!(
                f,
                " kernel(nnz={}, density={:.3}, rank={}, mass_loss={:.2e})",
                k.nnz,
                k.density(),
                k.rank,
                k.mass_loss
            )?;
        }
        if self.certified_solves > 0
            || self.deadline_misses > 0
            || self.budget_sheds > 0
        {
            write!(
                f,
                " anytime(certified={}, width(p50~{:.2e}, p99~{:.2e}, \
                 max={:.2e}), deadline_miss={}, shed={})",
                self.certified_solves,
                self.interval_width_p50,
                self.interval_width_p99,
                self.interval_width_max,
                self.deadline_misses,
                self.budget_sheds
            )?;
        }
        if self.retrievals > 0 {
            write!(
                f,
                " retrieval(queries={}, solved={}, pruned={}, fraction={:.2}, rescued={})",
                self.retrievals,
                self.retrieval_solved,
                self.retrieval_pruned,
                self.retrieval_pruned_fraction(),
                self.retrieval_rescued
            )?;
            if self.retrieval_pruned_interval > 0 || self.retrieval_refined > 0 {
                write!(
                    f,
                    " rinterval(pruned={}, refined={})",
                    self.retrieval_pruned_interval, self.retrieval_refined
                )?;
            }
            if self.retrieval_routed > 0 {
                write!(
                    f,
                    " routing(routed={}, shortlist_fraction={:.3})",
                    self.retrieval_routed,
                    self.retrieval_shortlist_fraction()
                )?;
            }
        }
        if self.recall_probes > 0 {
            write!(
                f,
                " recall(probes={}, rate={:.3})",
                self.recall_probes,
                self.recall()
            )?;
        }
        if self.retrieval_offthread > 0 {
            write!(
                f,
                " rsearch(offthread={}, queue={}, hol_us={}, us(mean={}, max={}))",
                self.retrieval_offthread,
                self.retrieval_queue_depth,
                self.retrieval_hol_blocked_us,
                self.retrieval_search_mean_us,
                self.retrieval_search_max_us
            )?;
        }
        if !self.retrieval_shards.is_empty() {
            // One block per tenant: every registered corpus renders,
            // not just the most recently touched one.
            write!(f, " corpora={{")?;
            for (i, c) in self.retrieval_shards.iter().enumerate() {
                if i > 0 {
                    write!(f, ", ")?;
                }
                write!(
                    f,
                    "c{}(q={} s={} hol_us={}",
                    c.corpus, c.queue_depth, c.searches, c.hol_blocked_us
                )?;
                if c.build_us > 0 {
                    write!(f, " build_us={}", c.build_us)?;
                }
                write!(f, ")[")?;
                for (j, g) in c.shards.iter().enumerate() {
                    if j > 0 {
                        write!(f, ", ")?;
                    }
                    write!(
                        f,
                        "{}:live={}/{} ts={:.2} comp={}",
                        g.shard,
                        g.live,
                        g.entries,
                        g.tombstone_fraction,
                        g.compactions
                    )?;
                }
                write!(f, "]")?;
            }
            write!(f, "}} fairness={:.2}", self.retrieval_fairness())?;
        }
        if !self.stages.is_empty() {
            write!(f, " stages={{")?;
            for (i, row) in self.stages.iter().enumerate() {
                if i > 0 {
                    write!(f, ", ")?;
                }
                write!(
                    f,
                    "{}[{}](n={} p50~{} p99~{} max={})",
                    row.stage, row.tenant, row.count, row.p50_us, row.p99_us, row.max_us
                )?;
            }
            write!(
                f,
                "}} traces(sampled={}, spans={}, dropped={})",
                self.traces_sampled, self.trace_spans, self.trace_spans_dropped
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_accounting() {
        let mut s = Stats::default();
        s.record_batch(4, true);
        s.record_batch(2, false);
        let snap = s.snapshot();
        assert_eq!(snap.batches, 2);
        assert_eq!(snap.xla_batches, 1);
        assert_eq!(snap.cpu_batches, 1);
        assert!((snap.mean_batch_size - 3.0).abs() < 1e-12);
    }

    #[test]
    fn latency_quantiles_monotone() {
        let mut s = Stats::default();
        for us in [1u64, 10, 100, 1000, 10_000, 100_000] {
            for _ in 0..10 {
                s.record_query_latency(Duration::from_micros(us));
            }
        }
        let snap = s.snapshot();
        assert!(snap.p50_latency_us <= snap.p99_latency_us);
        assert!(snap.p99_latency_us <= snap.max_latency_us * 2);
        assert_eq!(snap.queries, 60);
        assert!(snap.mean_latency_us > 0);
    }

    #[test]
    fn single_bucket_quantiles_clamp_to_the_observed_max() {
        use crate::sinkhorn::{ErrorInterval, SolveOutcome};
        let mut s = Stats::default();
        for _ in 0..10 {
            s.record_query_latency(Duration::from_micros(100));
        }
        let snap = s.snapshot();
        // The raw upper bucket edge would read 128 µs — a 28%
        // overstatement; the observed-max clamp makes a single-bucket
        // distribution exact.
        assert_eq!(snap.p50_latency_us, 100);
        assert_eq!(snap.p99_latency_us, 100);
        for _ in 0..10 {
            s.record_outcome(0, &SolveOutcome {
                estimate: 1.0,
                interval: ErrorInterval { lo: 0.0, hi: 1e-7 },
                iterations: 10,
                stabilized: false,
                converged: false,
            });
        }
        let snap = s.snapshot();
        assert!((snap.interval_width_p50 - 1e-7).abs() < 1e-12);
        assert!((snap.interval_width_p99 - 1e-7).abs() < 1e-12);
    }

    #[test]
    fn two_bucket_quantiles_stay_within_the_observed_range() {
        use crate::sinkhorn::{ErrorInterval, SolveOutcome};
        let mut s = Stats::default();
        for _ in 0..10 {
            s.record_query_latency(Duration::from_micros(100));
        }
        s.record_query_latency(Duration::from_micros(1000));
        let snap = s.snapshot();
        // p50 lands in the low bucket: its upper edge (128 µs) is
        // within one bucket of the true 100 µs median.
        assert_eq!(snap.p50_latency_us, 128);
        // p99 lands in the high bucket, where the raw 1024 µs edge
        // clamps to the observed 1000 µs maximum.
        assert_eq!(snap.p99_latency_us, 1000);
        assert_eq!(snap.max_latency_us, 1000);
        let certified = |width: F| SolveOutcome {
            estimate: 1.0,
            interval: ErrorInterval { lo: 0.0, hi: width },
            iterations: 10,
            stabilized: false,
            converged: false,
        };
        for _ in 0..10 {
            s.record_outcome(0, &certified(1e-7));
        }
        s.record_outcome(0, &certified(0.5));
        let snap = s.snapshot();
        assert!(
            (snap.interval_width_p50 - 1.28e-7).abs() < 1e-12,
            "{}",
            snap.interval_width_p50
        );
        assert!((snap.interval_width_p99 - 0.5).abs() < 1e-12);
    }

    #[test]
    fn routing_gauges_track_shortlist_fraction() {
        use crate::retrieval::RetrievalReport;
        let mut s = Stats::default();
        let snap = s.snapshot();
        assert_eq!(snap.retrieval_routed, 0);
        assert_eq!(
            snap.retrieval_shortlist_fraction(),
            1.0,
            "vacuous fraction before any routed query"
        );
        assert!(!snap.to_string().contains("routing("));
        // An unrouted (exact) query leaves the routing gauges alone.
        let mut exact = RetrievalReport::empty(100, 5);
        exact.shortlist = 100;
        s.record_retrieval(&exact);
        // Two routed queries shortlist 8 and 12 of 100 candidates.
        let mut routed = RetrievalReport::empty(100, 5);
        routed.routed = true;
        routed.shortlist = 8;
        s.record_retrieval(&routed);
        routed.shortlist = 12;
        s.record_retrieval(&routed);
        let snap = s.snapshot();
        assert_eq!(snap.retrievals, 3);
        assert_eq!(snap.retrieval_routed, 2);
        assert_eq!(snap.retrieval_shortlisted, 20);
        assert_eq!(snap.retrieval_routed_candidates, 200);
        assert!((snap.retrieval_shortlist_fraction() - 0.1).abs() < 1e-12);
        let line = snap.to_string();
        assert!(line.contains("routing(routed=2, shortlist_fraction=0.100)"));
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let snap = Stats::default().snapshot();
        assert_eq!(snap.queries, 0);
        assert_eq!(snap.mean_batch_size, 0.0);
        assert_eq!(snap.p99_latency_us, 0);
        assert!(snap.workers.is_empty());
        assert_eq!(snap.worker_balance(), 0.0);
    }

    #[test]
    fn worker_accounting() {
        let mut s = Stats::default();
        s.record_worker(0, 4, Duration::from_micros(100), 0, 4);
        s.record_worker(2, 2, Duration::from_micros(50), 1, 1);
        s.record_worker(0, 4, Duration::from_micros(100), 3, 1);
        let snap = s.snapshot();
        assert_eq!(snap.workers.len(), 3);
        assert_eq!(snap.workers[0].panels, 2);
        assert_eq!(snap.workers[0].queries, 8);
        assert_eq!(snap.workers[0].busy_us, 200);
        assert_eq!(snap.workers[1], WorkerSnapshot::default());
        assert_eq!(snap.workers[2].queries, 2);
        assert_eq!(snap.workers[0].warm_hits, 3);
        assert_eq!(snap.workers[0].warm_misses, 5);
        assert_eq!(snap.warm_hits, 4);
        assert_eq!(snap.warm_misses, 6);
        assert!((snap.warm_hit_rate() - 0.4).abs() < 1e-12);
        // balance = (200 + 0 + 50) / (200 * 3)
        assert!((snap.worker_balance() - 250.0 / 600.0).abs() < 1e-12);
        let line = snap.to_string();
        assert!(line.contains("workers=["));
        assert!(line.contains("balance="));
        assert!(line.contains("warm(hits=4, misses=6"));
    }

    #[test]
    fn kernel_gauge_tracks_structure_and_worst_loss() {
        let mut s = Stats::default();
        assert!(s.snapshot().kernel.is_none());
        assert!(!s.snapshot().to_string().contains("kernel("));
        s.record_kernel(KernelStats {
            dim: 8,
            nnz: 20,
            rank: 8,
            mass_loss: 1e-5,
            frobenius_budget: 1e-6,
        });
        s.record_kernel(KernelStats::dense(8));
        let snap = s.snapshot();
        let k = snap.kernel.expect("gauge populated");
        assert_eq!(k.nnz, 64, "latest structure wins");
        assert!((k.mass_loss - 1e-5).abs() < 1e-18, "worst loss is sticky");
        assert!((k.frobenius_budget - 1e-6).abs() < 1e-18, "worst budget is sticky");
        assert!(snap.to_string().contains("kernel(nnz=64"));
    }

    #[test]
    fn retrieval_gauges_accumulate_and_render() {
        use crate::retrieval::{ProbeOutcome, RetrievalReport};
        let mut s = Stats::default();
        let snap = s.snapshot();
        assert_eq!(snap.retrieval_pruned_fraction(), 0.0);
        assert_eq!(snap.recall(), 1.0, "vacuous recall before any probe");
        assert!(!snap.to_string().contains("retrieval("));
        let report = RetrievalReport {
            corpus: 200,
            k: 10,
            solved: 40,
            pruned: 160,
            panels: 4,
            rescued: 3,
            failed: 0,
            warm_seeded: 0,
            iterations: 1234,
            pruned_mass: 20,
            pruned_centroid: 40,
            pruned_projection: 100,
            pruned_interval: 7,
            refined: 5,
            threshold: 0.5,
            routed: false,
            shortlist: 200,
            probe: Some(ProbeOutcome { matched: 10, k: 10 }),
        };
        s.record_retrieval(&report);
        s.record_retrieval(&RetrievalReport { probe: None, ..report });
        let snap = s.snapshot();
        assert_eq!(snap.retrievals, 2);
        assert_eq!(snap.retrieval_candidates, 400);
        assert_eq!(snap.retrieval_solved, 80);
        assert_eq!(snap.retrieval_pruned, 320);
        assert_eq!(snap.retrieval_rescued, 6);
        assert!((snap.retrieval_pruned_fraction() - 0.8).abs() < 1e-12);
        assert_eq!(snap.recall_probes, 1);
        assert!((snap.recall() - 1.0).abs() < 1e-12);
        let line = snap.to_string();
        assert!(line.contains("retrieval(queries=2"));
        assert!(line.contains("recall(probes=1"));
        assert_eq!(snap.retrieval_pruned_interval, 14);
        assert_eq!(snap.retrieval_refined, 10);
        assert!(line.contains("rinterval(pruned=14, refined=10)"));
    }

    #[test]
    fn anytime_gauges_accumulate_and_render() {
        use crate::sinkhorn::{ErrorInterval, SolveOutcome};
        let mut s = Stats::default();
        let snap = s.snapshot();
        assert_eq!(snap.certified_solves, 0);
        assert_eq!(snap.interval_width_p50, 0.0);
        assert!(!snap.to_string().contains("anytime("));
        // Uncertified outcomes are skipped entirely.
        s.record_outcome(0, &SolveOutcome::uncertified(1.0));
        assert_eq!(s.snapshot().certified_solves, 0);
        let certified = |width: F| SolveOutcome {
            estimate: 1.0,
            interval: ErrorInterval { lo: 1.0 - width / 2.0, hi: 1.0 + width / 2.0 },
            iterations: 10,
            stabilized: false,
            converged: false,
        };
        for _ in 0..9 {
            s.record_outcome(0, &certified(1e-6));
        }
        s.record_outcome(0, &certified(0.5));
        s.record_query_served(0, Duration::from_micros(100), true);
        s.record_query_served(0, Duration::from_micros(100), true);
        s.add_budget_sheds(3);
        let snap = s.snapshot();
        assert_eq!(snap.certified_solves, 10);
        assert!(
            snap.interval_width_p50 <= snap.interval_width_p99,
            "{} vs {}",
            snap.interval_width_p50,
            snap.interval_width_p99
        );
        assert!(snap.interval_width_p50 < 1e-4, "p50 near the 1e-6 mass");
        assert!(snap.interval_width_p99 >= 0.25, "p99 sees the wide tail");
        assert!((snap.interval_width_max - 0.5).abs() < 1e-12);
        let line = snap.to_string();
        assert!(line.contains("anytime(certified=10"));
        assert!(line.contains("deadline_miss=2"));
        assert!(line.contains("shed=3"));
    }

    #[test]
    fn runtime_feedback_feeds_offthread_and_shard_gauges() {
        use crate::retrieval::{ProbeOutcome, RetrievalReport, RuntimeFeedback, ShardGauges};
        let mut s = Stats::default();
        let snap = s.snapshot();
        assert_eq!(snap.retrieval_offthread, 0);
        assert!(snap.retrieval_shards.is_empty());
        assert!(!snap.to_string().contains("rsearch("));
        assert!(!snap.to_string().contains("shards=["));

        let report = RetrievalReport {
            corpus: 100,
            k: 5,
            solved: 20,
            pruned: 80,
            panels: 2,
            rescued: 0,
            failed: 0,
            warm_seeded: 0,
            iterations: 500,
            pruned_mass: 10,
            pruned_centroid: 30,
            pruned_projection: 40,
            pruned_interval: 0,
            refined: 0,
            threshold: 0.4,
            routed: false,
            shortlist: 100,
            probe: Some(ProbeOutcome { matched: 5, k: 5 }),
        };
        let gauge = |shard: usize, live: usize| ShardGauges {
            shard,
            entries: live + 1,
            live,
            tombstone_fraction: 1.0 / (live + 1) as f64,
            compactions: 1,
            inserts: 2,
            searches: 3,
            last_search_us: 42,
        };
        s.record_runtime(&RuntimeFeedback {
            corpus: 0,
            report: Some(report),
            search_us: 900,
            queued_us: 40,
            build_us: 0,
            failed: false,
            invalidated: false,
            gauges: vec![gauge(0, 50), gauge(1, 49)],
        });
        s.record_runtime(&RuntimeFeedback {
            corpus: 0,
            report: Some(report),
            search_us: 100,
            queued_us: 10,
            build_us: 0,
            failed: false,
            invalidated: false,
            gauges: vec![gauge(0, 50), gauge(1, 48)],
        });
        // A second tenant pushes its own gauges: both rows must stay
        // visible in one snapshot (PR 8 regression — the table used to
        // be clobbered by whichever corpus pushed last).
        s.record_runtime(&RuntimeFeedback {
            corpus: 3,
            report: Some(report),
            search_us: 300,
            queued_us: 0,
            build_us: 0,
            failed: false,
            invalidated: false,
            gauges: vec![gauge(0, 9)],
        });
        // A failed push without gauges: error counted, table untouched.
        s.record_runtime(&RuntimeFeedback {
            corpus: 1,
            report: None,
            search_us: 0,
            queued_us: 0,
            build_us: 0,
            failed: true,
            invalidated: false,
            gauges: Vec::new(),
        });
        s.set_retrieval_queue_depth(3);
        s.set_corpus_queue_depths(&[(0, 2), (3, 1)]);
        let snap = s.snapshot();
        assert_eq!(snap.retrievals, 3, "search feedback folds into retrieval gauges");
        assert_eq!(snap.recall_probes, 3);
        assert_eq!(snap.errors, 1);
        assert_eq!(snap.retrieval_offthread, 3);
        assert_eq!(snap.retrieval_search_max_us, 900);
        assert_eq!(snap.retrieval_queue_depth, 3);
        assert_eq!(snap.retrieval_hol_blocked_us, 50);
        assert_eq!(snap.retrieval_shards.len(), 2, "both tenants visible, keyed");
        let c0 = &snap.retrieval_shards[0];
        assert_eq!((c0.corpus, c0.searches, c0.hol_blocked_us, c0.queue_depth), (0, 2, 50, 2));
        assert_eq!(c0.shards.len(), 2, "latest push per tenant wins");
        assert_eq!(c0.shards[1].live, 48);
        let c3 = &snap.retrieval_shards[1];
        assert_eq!((c3.corpus, c3.searches, c3.queue_depth), (3, 1, 1));
        assert!((snap.retrieval_fairness() - 0.5).abs() < 1e-12, "2 vs 1 searches");
        let line = snap.to_string();
        assert!(line.contains("rsearch(offthread=3, queue=3, hol_us=50"));
        assert!(line.contains("corpora={c0(q=2 s=2 hol_us=50)[0:live=50/51"));
        assert!(line.contains("c3(q=1 s=1 hol_us=0)[0:live=9/10"));
        assert!(line.contains("fairness=0.50"));
    }

    #[test]
    fn invalidation_feedback_purges_a_tenants_gauge_rows() {
        use crate::retrieval::{RuntimeFeedback, ShardGauges};
        let mut s = Stats::default();
        let push = |corpus: CorpusKey| RuntimeFeedback {
            corpus,
            report: None,
            search_us: 0,
            queued_us: 0,
            build_us: 0,
            failed: false,
            invalidated: false,
            gauges: vec![ShardGauges {
                shard: 0,
                entries: 4,
                live: 4,
                tombstone_fraction: 0.0,
                compactions: 0,
                inserts: 0,
                searches: 0,
                last_search_us: 0,
            }],
        };
        s.record_runtime(&push(2));
        s.record_runtime(&push(5));
        assert_eq!(s.snapshot().retrieval_shards.len(), 2);
        // The invalidation tombstone removes exactly that tenant's row
        // (PR 8 regression: DropMetric used to push nothing, and the
        // dropped corpus's last stats were served forever).
        s.record_runtime(&RuntimeFeedback {
            corpus: 2,
            report: None,
            search_us: 0,
            queued_us: 0,
            build_us: 0,
            failed: false,
            invalidated: true,
            gauges: Vec::new(),
        });
        let snap = s.snapshot();
        assert_eq!(snap.retrieval_shards.len(), 1);
        assert_eq!(snap.retrieval_shards[0].corpus, 5);
        assert_eq!(snap.errors, 0, "a clean invalidation is not an error");
    }

    #[test]
    fn build_feedback_accumulates_and_renders_once_nonzero() {
        use crate::retrieval::{RuntimeFeedback, ShardGauges};
        let mut s = Stats::default();
        let push = |build_us: u64| RuntimeFeedback {
            corpus: 7,
            report: None,
            search_us: 0,
            queued_us: 0,
            build_us,
            failed: false,
            invalidated: false,
            gauges: vec![ShardGauges {
                shard: 0,
                entries: 4,
                live: 4,
                tombstone_fraction: 0.0,
                compactions: 0,
                inserts: 4,
                searches: 0,
                last_search_us: 0,
            }],
        };
        s.record_runtime(&push(0));
        let snap = s.snapshot();
        assert_eq!(snap.retrieval_shards[0].build_us, 0);
        assert!(
            !snap.to_string().contains("build_us="),
            "zero build time stays out of the corpora row"
        );
        // Registration then a later re-shard: build time accumulates.
        s.record_runtime(&push(1200));
        s.record_runtime(&push(300));
        let snap = s.snapshot();
        assert_eq!(snap.retrieval_shards[0].build_us, 1500);
        assert!(snap.to_string().contains("c7(q=0 s=0 hol_us=0 build_us=1500)["));
    }

    #[test]
    fn stage_breakdown_renders_only_when_traced() {
        let s = Stats::default();
        let mut snap = s.snapshot();
        assert!(snap.stages.is_empty());
        assert!(!snap.to_string().contains("stages={"));
        snap.stages = vec![StageRow {
            stage: "batcher",
            tenant: "m0".to_string(),
            count: 5,
            p50_us: 128,
            p99_us: 1000,
            max_us: 1000,
        }];
        snap.traces_sampled = 2;
        snap.trace_spans = 5;
        snap.trace_spans_dropped = 0;
        let line = snap.to_string();
        assert!(line.contains("stages={batcher[m0](n=5 p50~128 p99~1000 max=1000)}"));
        assert!(line.contains("traces(sampled=2, spans=5, dropped=0)"));
    }

    #[test]
    fn warm_counters_absent_without_lookups() {
        let mut s = Stats::default();
        s.record_worker(0, 2, Duration::from_micros(10), 0, 0);
        let snap = s.snapshot();
        assert_eq!(snap.warm_hits + snap.warm_misses, 0);
        assert_eq!(snap.warm_hit_rate(), 0.0);
        assert!(!snap.to_string().contains("warm("));
    }
}
