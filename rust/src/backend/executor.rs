//! Sharded thread-pool panel executor.
//!
//! A query panel [(r_1, c_1) … (r_N, c_N)] is split into contiguous,
//! near-equal shards, one per worker. Each worker owns a *private*
//! backend instance — its own K/Kᵀ copies — so the per-iteration kernel
//! sweeps run from each core's cache with no sharing, no locks and no
//! false sharing. Threads are `std::thread::scope` spawns per panel:
//! spawn cost (~10 µs) is three orders of magnitude below a panel solve
//! at serving sizes (d ≥ 64, 20+ iterations), and scoped lifetimes keep
//! the whole structure borrow-checked rather than `Arc`-ed.
//!
//! Shard outputs are re-concatenated in shard order, so the result
//! vector lines up with the input panel exactly like the single-threaded
//! [`crate::sinkhorn::BatchSinkhorn::distances_paired`].

use super::{BackendKind, SolverBackend};
use crate::linalg::KernelStats;
use crate::metric::CostMatrix;
use crate::simplex::Histogram;
use crate::sinkhorn::{
    fingerprint_pair, ScalingInit, SinkhornConfig, SinkhornOutput, SolveBudget,
    SolveOutcome, WarmKey, WarmStartStore,
};
use crate::trace::{ctx, PanelTrace};
use crate::F;
use std::time::{Duration, Instant};

/// What one worker did for one panel (returned per solve call so the
/// coordinator can feed its occupancy metrics incrementally).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShardReport {
    /// Worker index (stable across the executor's lifetime).
    pub worker: usize,
    /// Queries in this worker's shard.
    pub queries: usize,
    /// Wallclock the worker spent solving the shard.
    pub busy: Duration,
    /// Queries seeded from the worker's warm-start store (0 when the
    /// executor runs without one).
    pub warm_hits: usize,
    /// Queries that missed the warm-start store (0 without one).
    pub warm_misses: usize,
    /// Structure of the kernel operator this worker's backend iterates
    /// with (achieved nnz / rank / mass loss — identical across a pool's
    /// workers, carried per report so consumers need no executor handle).
    pub kernel: KernelStats,
}

/// Cumulative per-worker counters (also kept inside the executor for
/// library users who don't run a coordinator).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerStats {
    /// Panels this worker participated in.
    pub panels: u64,
    /// Total queries solved.
    pub queries: u64,
    /// Total busy wallclock.
    pub busy: Duration,
    /// Total warm-start store hits.
    pub warm_hits: u64,
    /// Total warm-start store misses.
    pub warm_misses: u64,
}

/// Per-worker warm-start state: shared-nothing stores, one per worker,
/// all keyed in the same `(metric, λ)` namespace.
struct WarmShards {
    stores: Vec<WarmStartStore>,
    metric_key: u64,
    lambda_bits: u64,
}

/// Thread-pool batch executor: `workers` backend instances of one
/// [`BackendKind`], each bound to the same (M, λ).
pub struct ShardedExecutor {
    backends: Vec<Box<dyn SolverBackend>>,
    kind: BackendKind,
    stats: Vec<WorkerStats>,
    warm: Option<WarmShards>,
}

impl ShardedExecutor {
    /// Build `workers` private backend instances of `kind` (clamped to
    /// at least one).
    pub fn new(
        metric: &CostMatrix,
        config: SinkhornConfig,
        kind: BackendKind,
        workers: usize,
    ) -> Self {
        let workers = workers.max(1);
        let backends = (0..workers).map(|_| kind.build(metric, config)).collect();
        Self { backends, kind, stats: vec![WorkerStats::default(); workers], warm: None }
    }

    /// Attach a per-worker [`WarmStartStore`] (capacity entries each):
    /// every solve first consults its worker's store by
    /// `(metric_key, λ, query fingerprint)` and every *converged* solve
    /// deposits its scalings back. `metric_key` namespaces the keys (the
    /// coordinator passes its `MetricId`; standalone users can pass 0).
    pub fn with_warm_store(mut self, metric_key: u64, lambda: F, capacity: usize) -> Self {
        let stores =
            (0..self.backends.len()).map(|_| WarmStartStore::new(capacity)).collect();
        self.warm = Some(WarmShards {
            stores,
            metric_key,
            lambda_bits: lambda.to_bits(),
        });
        self
    }

    /// Total entries cached across all per-worker warm-start stores.
    pub fn warm_entries(&self) -> usize {
        self.warm
            .as_ref()
            .map(|w| w.stores.iter().map(|s| s.len()).sum())
            .unwrap_or(0)
    }

    /// [`Self::new`] with the regime-appropriate default strategy,
    /// honoring the config's kernel-policy intent: the underflow regime
    /// always goes log-domain; otherwise an explicit
    /// [`crate::linalg::KernelPolicy::Dense`] pins the exact interleaved
    /// walk (so opting into exactness can never be silently overridden
    /// by sparsity routing), explicit Truncated/LowRank policies route
    /// to their structured backends, and
    /// [`crate::linalg::KernelPolicy::Auto`] defers to
    /// [`BackendKind::auto`]'s d·λ rule.
    pub fn auto(metric: &CostMatrix, config: SinkhornConfig, workers: usize) -> Self {
        use crate::linalg::KernelPolicy;
        let kind = if super::dense_kernel_degenerate(metric, config.lambda) {
            BackendKind::LogDomain
        } else {
            match config.kernel {
                KernelPolicy::Dense => BackendKind::Interleaved,
                KernelPolicy::Truncated { .. } => BackendKind::Truncated,
                KernelPolicy::LowRank { .. } => BackendKind::LowRank,
                KernelPolicy::Auto => BackendKind::auto(metric, config.lambda),
            }
        };
        Self::new(metric, config, kind, workers)
    }

    /// Number of worker slots (= private backend instances).
    pub fn workers(&self) -> usize {
        self.backends.len()
    }

    /// The strategy every worker runs.
    pub fn kind(&self) -> BackendKind {
        self.kind
    }

    /// Histogram dimension the executor is bound to.
    pub fn dim(&self) -> usize {
        self.backends[0].dim()
    }

    /// Structure report of the kernel operator the workers iterate with
    /// (every worker holds an identical private instance).
    pub fn kernel_stats(&self) -> KernelStats {
        self.backends[0].kernel_stats()
    }

    /// Cumulative per-worker counters.
    pub fn stats(&self) -> &[WorkerStats] {
        &self.stats
    }

    /// Solve one source against a panel of targets in parallel.
    pub fn solve_panel(
        &mut self,
        r: &Histogram,
        cs: &[Histogram],
    ) -> (Vec<SinkhornOutput>, Vec<ShardReport>) {
        let rs: Vec<&Histogram> = std::iter::repeat(r).take(cs.len()).collect();
        self.solve_panel_paired(&rs, cs)
    }

    /// Solve a fully paired panel (r_j, c_j) in parallel. Outputs are in
    /// input order; the reports describe each worker's shard.
    pub fn solve_panel_paired(
        &mut self,
        rs: &[&Histogram],
        cs: &[Histogram],
    ) -> (Vec<SinkhornOutput>, Vec<ShardReport>) {
        let n = cs.len();
        assert_eq!(rs.len(), n, "paired panel size mismatch");
        if n == 0 {
            return (Vec::new(), Vec::new());
        }
        let shards = self.backends.len().min(n);
        let key_ns = self.warm.as_ref().map(|w| (w.metric_key, w.lambda_bits));
        let kernel = self.kernel_stats();
        if shards == 1 {
            // Degenerate pool (or single query): skip the spawn entirely.
            let t0 = Instant::now();
            let store = self.warm.as_mut().map(|w| &mut w.stores[0]);
            let (out, hits, misses) =
                run_shard(&*self.backends[0], store, key_ns, rs, cs);
            let report = ShardReport {
                worker: 0,
                queries: out.len(),
                busy: t0.elapsed(),
                warm_hits: hits,
                warm_misses: misses,
                kernel,
            };
            self.stats[0].panels += 1;
            self.stats[0].queries += report.queries as u64;
            self.stats[0].busy += report.busy;
            self.stats[0].warm_hits += hits as u64;
            self.stats[0].warm_misses += misses as u64;
            return (out, vec![report]);
        }
        let ranges = shard_ranges(n, shards);
        // One optional store handle per worker, aligned with `backends`
        // (split borrows: stores and backends are disjoint fields).
        let stores: Vec<Option<&mut WarmStartStore>> = match self.warm.as_mut() {
            Some(w) => w.stores.iter_mut().map(Some).collect(),
            None => (0..self.backends.len()).map(|_| None).collect(),
        };

        let mut outputs = Vec::with_capacity(n);
        let mut reports = Vec::with_capacity(shards);
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(shards);
            for (worker, ((backend, store), range)) in self
                .backends
                .iter_mut()
                .zip(stores)
                .zip(ranges)
                .enumerate()
            {
                let rs_shard = &rs[range.clone()];
                let cs_shard = &cs[range];
                handles.push(scope.spawn(move || {
                    let t0 = Instant::now();
                    let (out, hits, misses) =
                        run_shard(&**backend, store, key_ns, rs_shard, cs_shard);
                    (worker, out, hits, misses, t0.elapsed())
                }));
            }
            // Joining in spawn order concatenates shards back into the
            // original panel order.
            for handle in handles {
                let (worker, out, warm_hits, warm_misses, busy) =
                    handle.join().expect("executor worker panicked");
                reports.push(ShardReport {
                    worker,
                    queries: out.len(),
                    busy,
                    warm_hits,
                    warm_misses,
                    kernel,
                });
                outputs.extend(out);
            }
        });
        for report in &reports {
            let slot = &mut self.stats[report.worker];
            slot.panels += 1;
            slot.queries += report.queries as u64;
            slot.busy += report.busy;
            slot.warm_hits += report.warm_hits as u64;
            slot.warm_misses += report.warm_misses as u64;
        }
        (outputs, reports)
    }

    /// Panel re-rank entry point: a fully paired panel with **explicit
    /// caller-managed warm starts** — `inits[j]` seeds pair j (an empty
    /// slice delegates to [`Self::solve_panel_paired`]). The retrieval
    /// refine stage uses this to seed solves from its per-corpus-entry
    /// cache; since the caller owns the seeding policy, the executor's
    /// own per-worker warm stores are bypassed entirely (reports carry
    /// zero warm hits/misses) rather than double-counted.
    pub fn solve_panel_paired_init(
        &mut self,
        rs: &[&Histogram],
        cs: &[Histogram],
        inits: &[ScalingInit],
    ) -> (Vec<SinkhornOutput>, Vec<ShardReport>) {
        if inits.is_empty() {
            return self.solve_panel_paired(rs, cs);
        }
        let n = cs.len();
        assert_eq!(rs.len(), n, "paired panel size mismatch");
        assert_eq!(inits.len(), n, "warm-start slice size mismatch");
        let kernel = self.kernel_stats();
        let shards = self.backends.len().min(n);
        if shards <= 1 {
            let t0 = Instant::now();
            let out = self.backends[0].solve_paired(rs, cs, inits);
            let report = ShardReport {
                worker: 0,
                queries: out.len(),
                busy: t0.elapsed(),
                warm_hits: 0,
                warm_misses: 0,
                kernel,
            };
            self.stats[0].panels += 1;
            self.stats[0].queries += report.queries as u64;
            self.stats[0].busy += report.busy;
            return (out, vec![report]);
        }
        let ranges = shard_ranges(n, shards);
        let mut outputs = Vec::with_capacity(n);
        let mut reports = Vec::with_capacity(shards);
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(shards);
            for (worker, (backend, range)) in
                self.backends.iter_mut().zip(ranges).enumerate()
            {
                let rs_shard = &rs[range.clone()];
                let cs_shard = &cs[range.clone()];
                let inits_shard = &inits[range];
                handles.push(scope.spawn(move || {
                    let t0 = Instant::now();
                    let out = backend.solve_paired(rs_shard, cs_shard, inits_shard);
                    (worker, out, t0.elapsed())
                }));
            }
            // Joining in spawn order concatenates shards back into the
            // original panel order.
            for handle in handles {
                let (worker, out, busy) =
                    handle.join().expect("executor worker panicked");
                reports.push(ShardReport {
                    worker,
                    queries: out.len(),
                    busy,
                    warm_hits: 0,
                    warm_misses: 0,
                    kernel,
                });
                outputs.extend(out);
            }
        });
        for report in &reports {
            let slot = &mut self.stats[report.worker];
            slot.panels += 1;
            slot.queries += report.queries as u64;
            slot.busy += report.busy;
        }
        (outputs, reports)
    }

    /// Anytime paired panel: per-column certified [`SolveOutcome`]s
    /// under one shared `budget`, sharded like
    /// [`Self::solve_panel_paired_init`]. Caller-managed seeding
    /// (`inits[j]`, empty = all-cold) — the per-worker warm stores are
    /// bypassed, matching the explicit-init contract. A deadline budget
    /// is global: every worker races the same wall-clock instant.
    pub fn solve_panel_outcomes(
        &mut self,
        rs: &[&Histogram],
        cs: &[Histogram],
        inits: &[ScalingInit],
        budget: SolveBudget,
    ) -> (Vec<SolveOutcome>, Vec<ShardReport>) {
        self.solve_panel_outcomes_traced(rs, cs, inits, budget, None)
    }

    /// [`Self::solve_panel_outcomes`] with optional PR 9 trace
    /// attribution: `trace.traces[j]` (if any) owns panel column `j`, and
    /// each shard worker gets its sub-slice installed as the thread-local
    /// panel context so the budgeted drivers can emit per-slice spans.
    /// `trace: None` is byte-for-byte the untraced path.
    pub fn solve_panel_outcomes_traced(
        &mut self,
        rs: &[&Histogram],
        cs: &[Histogram],
        inits: &[ScalingInit],
        budget: SolveBudget,
        trace: Option<PanelTrace>,
    ) -> (Vec<SolveOutcome>, Vec<ShardReport>) {
        let n = cs.len();
        assert_eq!(rs.len(), n, "paired panel size mismatch");
        if !inits.is_empty() {
            assert_eq!(inits.len(), n, "warm-start slice size mismatch");
        }
        if let Some(t) = &trace {
            assert_eq!(t.traces.len(), n, "panel trace size mismatch");
        }
        if n == 0 {
            return (Vec::new(), Vec::new());
        }
        let kernel = self.kernel_stats();
        let shards = self.backends.len().min(n);
        if shards <= 1 {
            let _trace_guard = trace.map(|t| ctx::set_panel(t.sink, t.tenant, t.traces));
            let t0 = Instant::now();
            let out = self.backends[0].solve_paired_outcomes(rs, cs, inits, budget);
            let report = ShardReport {
                worker: 0,
                queries: out.len(),
                busy: t0.elapsed(),
                warm_hits: 0,
                warm_misses: 0,
                kernel,
            };
            self.stats[0].panels += 1;
            self.stats[0].queries += report.queries as u64;
            self.stats[0].busy += report.busy;
            return (out, vec![report]);
        }
        let ranges = shard_ranges(n, shards);
        let mut outputs = Vec::with_capacity(n);
        let mut reports = Vec::with_capacity(shards);
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(shards);
            for (worker, (backend, range)) in
                self.backends.iter_mut().zip(ranges).enumerate()
            {
                let rs_shard = &rs[range.clone()];
                let cs_shard = &cs[range.clone()];
                // Thread-locals don't cross scoped spawns: hand each
                // worker its column window to re-install as panel ctx.
                let trace_shard = trace.as_ref().map(|t| {
                    (
                        std::sync::Arc::clone(&t.sink),
                        t.tenant,
                        t.traces[range.clone()].to_vec(),
                    )
                });
                let inits_shard = if inits.is_empty() { &[] } else { &inits[range] };
                handles.push(scope.spawn(move || {
                    let _trace_guard = trace_shard
                        .map(|(sink, tenant, cols)| ctx::set_panel(sink, tenant, cols));
                    let t0 = Instant::now();
                    let out = backend.solve_paired_outcomes(
                        rs_shard, cs_shard, inits_shard, budget,
                    );
                    (worker, out, t0.elapsed())
                }));
            }
            for handle in handles {
                let (worker, out, busy) =
                    handle.join().expect("executor worker panicked");
                reports.push(ShardReport {
                    worker,
                    queries: out.len(),
                    busy,
                    warm_hits: 0,
                    warm_misses: 0,
                    kernel,
                });
                outputs.extend(out);
            }
        });
        for report in &reports {
            let slot = &mut self.stats[report.worker];
            slot.panels += 1;
            slot.queries += report.queries as u64;
            slot.busy += report.busy;
        }
        (outputs, reports)
    }
}

/// Contiguous near-equal shard ranges: the first n % shards shards take
/// one extra query. Shared with the retrieval layer, which uses the
/// same scheme to partition a corpus into [`crate::retrieval`] shards.
pub(crate) fn shard_ranges(n: usize, shards: usize) -> Vec<std::ops::Range<usize>> {
    let base = n / shards;
    let rem = n % shards;
    let mut ranges = Vec::with_capacity(shards);
    let mut lo = 0;
    for w in 0..shards {
        let len = base + usize::from(w < rem);
        ranges.push(lo..lo + len);
        lo += len;
    }
    ranges
}

/// Solve one worker's shard, consulting (and refilling) its warm-start
/// store when one is attached. Returns (outputs, hits, misses).
fn run_shard(
    backend: &dyn SolverBackend,
    store: Option<&mut WarmStartStore>,
    key_ns: Option<(u64, u64)>,
    rs: &[&Histogram],
    cs: &[Histogram],
) -> (Vec<SinkhornOutput>, usize, usize) {
    let (store, (metric_key, lambda_bits)) = match (store, key_ns) {
        (Some(store), Some(ns)) if backend.warm_startable() => (store, ns),
        _ => return (backend.solve_paired(rs, cs, &[]), 0, 0),
    };
    let keys: Vec<WarmKey> = rs
        .iter()
        .zip(cs)
        .map(|(r, c)| WarmKey {
            metric: metric_key,
            lambda_bits,
            fingerprint: fingerprint_pair(r, c),
        })
        .collect();
    let inits: Vec<ScalingInit> = keys
        .iter()
        .map(|k| store.get(k).unwrap_or_default())
        .collect();
    let hits = inits.iter().filter(|i| !i.is_cold()).count();
    let misses = inits.len() - hits;
    let out = backend.solve_paired(rs, cs, &inits);
    for (key, o) in keys.into_iter().zip(&out) {
        if o.stats.converged && o.value.is_finite() {
            store.insert(key, ScalingInit::from_output(o));
        }
    }
    (out, hits, misses)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::RandomMetric;
    use crate::simplex::seeded_rng;
    use crate::sinkhorn::{BatchSinkhorn, SinkhornEngine};

    fn panel(
        d: usize,
        n: usize,
        seed: u64,
    ) -> (CostMatrix, Histogram, Vec<Histogram>) {
        let mut rng = seeded_rng(seed);
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let cs = (0..n).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        (m, r, cs)
    }

    #[test]
    fn matches_sequential_batch_in_order() {
        let (m, r, cs) = panel(16, 23, 0);
        let cfg = SinkhornConfig::fixed(9.0, 25);
        let sequential = BatchSinkhorn::new(&m, cfg).distances(&r, &cs);
        for workers in [1usize, 2, 3, 8] {
            let mut ex =
                ShardedExecutor::new(&m, cfg, BackendKind::Interleaved, workers);
            let (got, reports) = ex.solve_panel(&r, &cs);
            assert_eq!(got.len(), cs.len());
            let total: usize = reports.iter().map(|s| s.queries).sum();
            assert_eq!(total, cs.len(), "workers={workers}");
            for (j, (a, b)) in got.iter().zip(&sequential).enumerate() {
                assert!(
                    (a.value - b.value).abs() < 1e-9 * (1.0 + b.value),
                    "workers={workers} j={j}: {} vs {}",
                    a.value,
                    b.value
                );
            }
        }
    }

    #[test]
    fn paired_mode_matches_scalar_engine() {
        let mut rng = seeded_rng(1);
        let d = 12;
        let m = RandomMetric::new(d).sample(&mut rng);
        let cfg = SinkhornConfig::fixed(7.0, 30);
        let rs: Vec<Histogram> =
            (0..9).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let cs: Vec<Histogram> =
            (0..9).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let r_refs: Vec<&Histogram> = rs.iter().collect();
        let mut ex = ShardedExecutor::new(&m, cfg, BackendKind::Interleaved, 4);
        let (got, _) = ex.solve_panel_paired(&r_refs, &cs);
        let engine = SinkhornEngine::with_config(&m, cfg);
        for j in 0..9 {
            let want = engine.distance(&rs[j], &cs[j]).value;
            assert!(
                (got[j].value - want).abs() < 1e-9 * (1.0 + want),
                "j={j}: {} vs {want}",
                got[j].value
            );
        }
    }

    #[test]
    fn more_workers_than_queries_is_fine() {
        let (m, r, cs) = panel(10, 3, 2);
        let mut ex = ShardedExecutor::new(
            &m,
            SinkhornConfig::fixed(9.0, 10),
            BackendKind::Dense,
            16,
        );
        let (got, reports) = ex.solve_panel(&r, &cs);
        assert_eq!(got.len(), 3);
        assert_eq!(reports.len(), 3, "only 3 shards for 3 queries");
        assert!(reports.iter().all(|s| s.queries == 1));
    }

    #[test]
    fn empty_panel_is_fine() {
        let (m, r, _) = panel(8, 0, 3);
        let mut ex =
            ShardedExecutor::new(&m, SinkhornConfig::fixed(9.0, 5), BackendKind::Dense, 4);
        let (got, reports) = ex.solve_panel(&r, &[]);
        assert!(got.is_empty());
        assert!(reports.is_empty());
    }

    #[test]
    fn cumulative_stats_accumulate() {
        let (m, r, cs) = panel(10, 8, 4);
        let mut ex = ShardedExecutor::new(
            &m,
            SinkhornConfig::fixed(9.0, 10),
            BackendKind::Interleaved,
            2,
        );
        ex.solve_panel(&r, &cs);
        ex.solve_panel(&r, &cs);
        let stats = ex.stats();
        assert_eq!(stats.len(), 2);
        let queries: u64 = stats.iter().map(|s| s.queries).sum();
        assert_eq!(queries, 16);
        assert!(stats.iter().all(|s| s.panels == 2));
    }

    #[test]
    fn warm_store_hits_on_repeat_and_cuts_iterations() {
        let (m, r, cs) = panel(16, 12, 6);
        let cfg = SinkhornConfig {
            lambda: 9.0,
            tolerance: 1e-9,
            max_iterations: 100_000,
            ..Default::default()
        };
        let mut ex = ShardedExecutor::new(&m, cfg, BackendKind::Interleaved, 3)
            .with_warm_store(7, 9.0, 256);
        let (cold, cold_reports) = ex.solve_panel(&r, &cs);
        assert!(cold.iter().all(|o| o.stats.converged));
        assert_eq!(cold_reports.iter().map(|s| s.warm_misses).sum::<usize>(), 12);
        assert_eq!(cold_reports.iter().map(|s| s.warm_hits).sum::<usize>(), 0);
        assert_eq!(ex.warm_entries(), 12);

        // Identical panel again: every query hits its worker's store.
        let (warm, warm_reports) = ex.solve_panel(&r, &cs);
        assert_eq!(warm_reports.iter().map(|s| s.warm_hits).sum::<usize>(), 12);
        assert_eq!(warm_reports.iter().map(|s| s.warm_misses).sum::<usize>(), 0);
        let cold_iters: usize = cold.iter().map(|o| o.stats.iterations).sum();
        let warm_iters: usize = warm.iter().map(|o| o.stats.iterations).sum();
        assert!(
            warm_iters < cold_iters,
            "warm pass took {warm_iters} iterations vs cold {cold_iters}"
        );
        for (a, b) in warm.iter().zip(&cold) {
            assert!((a.value - b.value).abs() < 1e-7 * (1.0 + b.value));
        }
        // Cumulative per-worker stats carry the same counts.
        let stats = ex.stats();
        assert_eq!(stats.iter().map(|s| s.warm_hits).sum::<u64>(), 12);
        assert_eq!(stats.iter().map(|s| s.warm_misses).sum::<u64>(), 12);
    }

    #[test]
    fn warm_store_capacity_is_bounded() {
        let (m, r, _) = panel(10, 0, 7);
        let mut rng = seeded_rng(70);
        let cfg = SinkhornConfig {
            lambda: 7.0,
            tolerance: 1e-8,
            max_iterations: 100_000,
            ..Default::default()
        };
        let mut ex = ShardedExecutor::new(&m, cfg, BackendKind::Dense, 1)
            .with_warm_store(0, 7.0, 4);
        for _ in 0..10 {
            let c = Histogram::sample_uniform(10, &mut rng);
            ex.solve_panel(&r, std::slice::from_ref(&c));
        }
        assert!(ex.warm_entries() <= 4, "LRU bound violated: {}", ex.warm_entries());
    }

    #[test]
    fn reports_carry_kernel_structure() {
        let (m, r, cs) = panel(16, 6, 9);
        // λ=30 on a median-normalized metric: plenty below the default
        // truncation threshold.
        let mut ex = ShardedExecutor::new(
            &m,
            SinkhornConfig::fixed(30.0, 10),
            BackendKind::Truncated,
            3,
        );
        let stats = ex.kernel_stats();
        assert!(stats.nnz < 16 * 16, "truncated executor must hold a sparse kernel");
        let (_, reports) = ex.solve_panel(&r, &cs);
        assert!(reports.iter().all(|s| s.kernel == stats));
        // A dense executor reports the dense structure.
        let mut dense =
            ShardedExecutor::new(&m, SinkhornConfig::fixed(9.0, 10), BackendKind::Dense, 2);
        let (_, dreports) = dense.solve_panel(&r, &cs);
        assert!(dreports.iter().all(|s| s.kernel.nnz == 16 * 16 && s.kernel.mass_loss == 0.0));
    }

    #[test]
    fn explicit_inits_shard_correctly_and_bypass_warm_stores() {
        let (m, r, cs) = panel(14, 9, 11);
        let cfg = SinkhornConfig {
            lambda: 9.0,
            tolerance: 1e-9,
            max_iterations: 100_000,
            ..Default::default()
        };
        let mut ex = ShardedExecutor::new(&m, cfg, BackendKind::Interleaved, 3)
            .with_warm_store(0, 9.0, 64);
        let rs: Vec<&Histogram> = cs.iter().map(|_| &r).collect();
        // Cold pass through the explicit-init entry point (all Cold).
        let inits = vec![ScalingInit::Cold; cs.len()];
        let (cold, reports) = ex.solve_panel_paired_init(&rs, &cs, &inits);
        assert_eq!(cold.len(), cs.len());
        assert_eq!(reports.iter().map(|s| s.queries).sum::<usize>(), cs.len());
        // Caller-managed seeding bypasses the executor's own stores.
        assert_eq!(reports.iter().map(|s| s.warm_hits + s.warm_misses).sum::<usize>(), 0);
        assert_eq!(ex.warm_entries(), 0);
        // Seeding every pair with its own converged scalings re-converges
        // in strictly fewer iterations to the same values.
        let seeds: Vec<ScalingInit> =
            cold.iter().map(ScalingInit::from_output).collect();
        let (warm, _) = ex.solve_panel_paired_init(&rs, &cs, &seeds);
        let cold_iters: usize = cold.iter().map(|o| o.stats.iterations).sum();
        let warm_iters: usize = warm.iter().map(|o| o.stats.iterations).sum();
        assert!(warm_iters < cold_iters, "{warm_iters} vs {cold_iters}");
        for (a, b) in warm.iter().zip(&cold) {
            assert!((a.value - b.value).abs() < 1e-7 * (1.0 + b.value));
        }
        // An empty init slice delegates to the store-managed path.
        let (_, delegated) = ex.solve_panel_paired_init(&rs, &cs, &[]);
        assert_eq!(delegated.iter().map(|s| s.warm_misses).sum::<usize>(), cs.len());
    }

    #[test]
    fn budgeted_panel_brackets_and_matches_unbounded() {
        let (m, r, cs) = panel(12, 7, 13);
        let cfg = SinkhornConfig {
            lambda: 9.0,
            tolerance: 1e-9,
            max_iterations: 100_000,
            ..Default::default()
        };
        let rs: Vec<&Histogram> = cs.iter().map(|_| &r).collect();
        for kind in [BackendKind::Interleaved, BackendKind::Dense] {
            let mut ex = ShardedExecutor::new(&m, cfg, kind, 3);
            let (plain, _) = ex.solve_panel_paired(&rs, &cs);
            // Unbounded outcomes reproduce the plain panel exactly and
            // attach a finite certificate around each estimate.
            let (outcomes, reports) =
                ex.solve_panel_outcomes(&rs, &cs, &[], SolveBudget::Unbounded);
            assert_eq!(outcomes.len(), cs.len());
            assert_eq!(reports.iter().map(|s| s.queries).sum::<usize>(), cs.len());
            for (o, p) in outcomes.iter().zip(&plain) {
                assert_eq!(o.estimate, p.value, "{kind}: unbounded outcome drifted");
                assert!(o.converged);
                assert!(o.interval.hi.is_finite(), "{kind}: vacuous certificate");
                assert!(
                    o.interval.lo - 1e-9 <= o.estimate
                        && o.estimate <= o.interval.hi + 1e-9,
                    "{kind}: estimate outside certificate"
                );
            }
            // A tiny iteration budget still yields estimates + intervals,
            // and a larger budget never widens any column's interval.
            let (small, _) =
                ex.solve_panel_outcomes(&rs, &cs, &[], SolveBudget::Iterations(8));
            let (large, _) =
                ex.solve_panel_outcomes(&rs, &cs, &[], SolveBudget::Iterations(32));
            for (s, l) in small.iter().zip(&large) {
                assert!(s.iterations <= 8, "{kind}: budget overrun");
                assert!(s.estimate.is_finite());
                assert!(
                    l.interval.width() <= s.interval.width() + 1e-12,
                    "{kind}: interval widened with budget"
                );
            }
        }
    }

    #[test]
    fn auto_respects_kernel_policy_intent() {
        use crate::linalg::KernelPolicy;
        let (m, _, _) = panel(12, 0, 10);
        // Explicit structured policies route to their backends…
        let mut cfg = SinkhornConfig::fixed(9.0, 10);
        cfg.kernel = KernelPolicy::Truncated { threshold: 1e-6 };
        assert_eq!(ShardedExecutor::auto(&m, cfg, 1).kind(), BackendKind::Truncated);
        cfg.kernel = KernelPolicy::LowRank { max_rank: 0, tolerance: 1e-9 };
        assert_eq!(ShardedExecutor::auto(&m, cfg, 1).kind(), BackendKind::LowRank);
        // …while the default Dense policy pins the exact walk (and Auto
        // defers to the d·λ rule, which stays dense at 12·9).
        cfg.kernel = KernelPolicy::Dense;
        assert_eq!(ShardedExecutor::auto(&m, cfg, 1).kind(), BackendKind::Interleaved);
        cfg.kernel = KernelPolicy::Auto;
        assert_eq!(ShardedExecutor::auto(&m, cfg, 1).kind(), BackendKind::Interleaved);
    }

    #[test]
    fn auto_picks_log_domain_on_underflow() {
        let (m, r, cs) = panel(8, 4, 5);
        let mut ex = ShardedExecutor::auto(&m, SinkhornConfig::converged(50_000.0), 2);
        assert_eq!(ex.kind(), BackendKind::LogDomain);
        let (got, _) = ex.solve_panel(&r, &cs);
        assert!(got.iter().all(|o| o.value.is_finite() && o.value >= 0.0));
    }
}
