//! Greenkhorn — greedy coordinate Sinkhorn scaling.
//!
//! Instead of rescaling *every* row and column each iteration
//! (Algorithm 1), Greenkhorn repeatedly fixes only the single most
//! violated marginal (Altschuler, Weed & Rigollet, 2017; the greedy
//! family also includes Abid & Gower's stochastic variants). Each update
//! is O(d) thanks to incrementally maintained K·v and Kᵀ·u caches, so d
//! greedy updates cost about one full Sinkhorn iteration — but the
//! updates concentrate on the histogram bins that matter, which wins on
//! spiky (low-entropy) marginals.
//!
//! Budget accounting: one [`SinkhornConfig::max_iterations`] unit buys d
//! greedy updates (one "sweep"), keeping configs comparable across
//! backends. Convergence is declared when the total marginal violation
//! ‖row(P) − r‖₁ + ‖col(P) − c‖₁ drops to [`SinkhornConfig::tolerance`].
//!
//! In the kernel-underflow regime (λ·max(M) ≳ 700) the dense K this
//! solver scales is all zeros off the diagonal, so — like
//! [`crate::sinkhorn::SinkhornEngine`] — construction detects the
//! degeneracy and solves delegate to the log-domain path.

use super::{BackendKind, SolverBackend};
use crate::metric::CostMatrix;
use crate::simplex::Histogram;
use crate::sinkhorn::{
    certify, log_domain, ErrorInterval, ScalingInit, SinkhornConfig, SinkhornOutput,
    SinkhornStats,
};
use crate::F;

/// Greedy-scaling solver bound to (M, λ); precomputes K and Kᵀ.
pub struct GreenkhornBackend {
    d: usize,
    config: SinkhornConfig,
    /// K = exp(−λM), row-major.
    k: Vec<F>,
    /// Kᵀ row-major, for contiguous column updates.
    kt: Vec<F>,
    /// M, for the cost read-off and the log-domain fallback.
    m: Vec<F>,
    degenerate: bool,
}

impl GreenkhornBackend {
    pub fn new(metric: &CostMatrix, config: SinkhornConfig) -> Self {
        let d = metric.dim();
        assert!(config.lambda > 0.0, "lambda must be positive");
        let mut k = vec![0.0; d * d];
        for (out, &mij) in k.iter_mut().zip(metric.data()) {
            *out = (-config.lambda * mij).exp();
        }
        let mut kt = vec![0.0; d * d];
        for i in 0..d {
            for j in 0..d {
                kt[j * d + i] = k[i * d + j];
            }
        }
        let degenerate = config.auto_stabilize
            && crate::sinkhorn::degenerate_off_diagonal(k.iter().copied(), d);
        Self { d, config, k, kt, m: metric.data().to_vec(), degenerate }
    }

    /// Whether solves are being routed through the log-domain path.
    pub fn is_stabilized(&self) -> bool {
        self.degenerate
    }

    fn solve_greedy(
        &self,
        r: &[F],
        c: &[F],
        init: &ScalingInit,
        cap: Option<usize>,
    ) -> SinkhornOutput {
        let d = self.d;
        let cfg = &self.config;

        // Scalings: a warm start seeds both sides; a cold start runs the
        // ε-scaling prefix (in the dense scaling domain, like the engine)
        // and derives v from the carried u against the final kernel.
        let (mut u, mut v, prefix) = match init.scalings() {
            Some((su, sv)) => {
                assert_eq!(su.len(), d, "warm-start dimension mismatch");
                assert_eq!(sv.len(), d, "warm-start dimension mismatch");
                (su.to_vec(), sv.to_vec(), 0)
            }
            None => {
                let mut u = vec![1.0 / d as F; d];
                // Always a dense prefix: the greedy loop's incremental
                // K·v / Kᵀ·u caches are dense, so the prefix must
                // iterate the same kernel (the policy knob is ignored
                // here, as documented on `SinkhornConfig::kernel`).
                let prefix = crate::sinkhorn::dense_anneal_prefix(
                    &self.m,
                    d,
                    cfg.lambda,
                    &cfg.schedule,
                    crate::linalg::KernelPolicy::Dense,
                    r,
                    c,
                    &mut u,
                );
                let mut v = vec![1.0 / d as F; d];
                if prefix > 0 {
                    crate::sinkhorn::kernel_ratio(&self.kt, &u, c, &mut v, d);
                }
                (u, v, prefix)
            }
        };
        // kv[i] = (K v)_i, ktu[j] = (Kᵀ u)_j.
        let mut kv = vec![0.0; d];
        let mut ktu = vec![0.0; d];
        for i in 0..d {
            kv[i] = row_dot(&self.k, i, d, &v);
            ktu[i] = row_dot(&self.kt, i, d, &u);
        }

        // A budget slice caps the sweep count (one sweep = d greedy
        // updates), keeping iteration units comparable across backends.
        let budget = cap.unwrap_or(cfg.max_iterations).saturating_mul(d);
        let check = cfg.check_every != usize::MAX;
        let mut stats =
            SinkhornStats { last_delta: F::INFINITY, ..Default::default() };

        let mut updates = 0usize;
        while updates < budget {
            // Marginal violations of P = diag(u) K diag(v).
            let (mut best_gain, mut best_idx, mut best_is_row) = (0.0, 0, true);
            let mut l1 = 0.0;
            for i in 0..d {
                let a = u[i] * kv[i];
                l1 += (a - r[i]).abs();
                // Only score coordinates an update can actually move:
                // with (K v)_i == 0 the rescale u_i = r_i/(K v)_i is
                // impossible (a no-op sets u_i = 0), and selecting it
                // forever would livelock the greedy loop.
                let g = if kv[i] > 0.0 { gain(r[i], a) } else { 0.0 };
                if g > best_gain {
                    best_gain = g;
                    best_idx = i;
                    best_is_row = true;
                }
            }
            for j in 0..d {
                let b = v[j] * ktu[j];
                l1 += (b - c[j]).abs();
                let g = if ktu[j] > 0.0 { gain(c[j], b) } else { 0.0 };
                if g > best_gain {
                    best_gain = g;
                    best_idx = j;
                    best_is_row = false;
                }
            }
            if check {
                stats.last_delta = l1;
                if l1 <= cfg.tolerance {
                    stats.converged = true;
                    break;
                }
            }
            if best_gain <= 0.0 {
                // Every marginal is exact — or the only violated ones are
                // unfixable in the dense regime (underflowed kernel row):
                // either way no update can improve, so stop; `converged`
                // stays honest via the l1 check.
                stats.converged = check && l1 <= cfg.tolerance;
                break;
            }

            updates += 1;
            if best_is_row {
                let i = best_idx;
                let new_u = if kv[i] > 0.0 { r[i] / kv[i] } else { 0.0 };
                let delta = new_u - u[i];
                u[i] = new_u;
                if delta != 0.0 {
                    let krow = &self.k[i * d..(i + 1) * d];
                    for (t, &kij) in ktu.iter_mut().zip(krow) {
                        *t += delta * kij;
                    }
                }
            } else {
                let j = best_idx;
                let new_v = if ktu[j] > 0.0 { c[j] / ktu[j] } else { 0.0 };
                let delta = new_v - v[j];
                v[j] = new_v;
                if delta != 0.0 {
                    let ktrow = &self.kt[j * d..(j + 1) * d];
                    for (t, &kij) in kv.iter_mut().zip(ktrow) {
                        *t += delta * kij;
                    }
                }
            }
        }
        // Report in sweep units so iteration counts compare across
        // backends (d greedy updates ≈ one full Sinkhorn iteration); the
        // anneal prefix already runs in full-iteration units.
        stats.iterations = prefix
            + updates.div_euclid(d.max(1))
            + usize::from(updates % d.max(1) != 0);

        // d = sum_i u_i * ((K ∘ M) v)_i — same read-off as the engine.
        let mut value = 0.0;
        for i in 0..d {
            let krow = &self.k[i * d..(i + 1) * d];
            let mrow = &self.m[i * d..(i + 1) * d];
            let mut acc = 0.0;
            for j in 0..d {
                acc += krow[j] * mrow[j] * v[j];
            }
            value += u[i] * acc;
        }
        SinkhornOutput { value, u, v, stats }
    }
}

impl SolverBackend for GreenkhornBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Greenkhorn
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn solve(&self, r: &Histogram, c: &Histogram, init: &ScalingInit) -> SinkhornOutput {
        assert_eq!(r.dim(), self.d, "source dimension mismatch");
        assert_eq!(c.dim(), self.d, "target dimension mismatch");
        if self.degenerate {
            return log_domain::solve_init(
                &self.m,
                self.d,
                self.config.lambda,
                &self.config,
                r.values(),
                c.values(),
                init,
            );
        }
        self.solve_greedy(r.values(), c.values(), init, None)
    }

    fn solve_capped(
        &self,
        r: &Histogram,
        c: &Histogram,
        init: &ScalingInit,
        cap: usize,
    ) -> SinkhornOutput {
        assert_eq!(r.dim(), self.d, "source dimension mismatch");
        assert_eq!(c.dim(), self.d, "target dimension mismatch");
        if self.degenerate {
            return log_domain::solve_capped(
                &self.m,
                self.d,
                self.config.lambda,
                &self.config,
                r.values(),
                c.values(),
                init,
                cap,
            );
        }
        self.solve_greedy(r.values(), c.values(), init, Some(cap))
    }

    fn certificate(
        &self,
        r: &Histogram,
        c: &Histogram,
        out: &SinkhornOutput,
    ) -> ErrorInterval {
        certify(&self.m, self.d, self.config.lambda, r.values(), c.values(), out)
    }
}

/// Contiguous row i of a row-major (d, d) buffer dotted with x.
#[inline]
fn row_dot(mat: &[F], i: usize, d: usize, x: &[F]) -> F {
    crate::linalg::dot(&mat[i * d..(i + 1) * d], x)
}

/// Greedy selection score ρ(target, actual) = actual − target +
/// target·log(target/actual): the Bregman divergence Altschuler et al.
/// maximize. Zero targets score their excess mass; exact marginals
/// score 0.
#[inline]
fn gain(target: F, actual: F) -> F {
    if target <= 0.0 {
        return actual.max(0.0);
    }
    if actual <= 0.0 {
        return F::INFINITY;
    }
    actual - target + target * (target / actual).ln()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::RandomMetric;
    use crate::simplex::seeded_rng;
    use crate::sinkhorn::SinkhornEngine;

    fn tight(lambda: F) -> SinkhornConfig {
        SinkhornConfig {
            lambda,
            tolerance: 1e-10,
            max_iterations: 200_000,
            ..Default::default()
        }
    }

    #[test]
    fn matches_dense_engine_at_convergence() {
        for seed in 0..6u64 {
            let mut rng = seeded_rng(seed);
            let d = 12;
            let m = RandomMetric::new(d).sample(&mut rng);
            let r = Histogram::sample_uniform(d, &mut rng);
            let c = Histogram::sample_uniform(d, &mut rng);
            let dense = SinkhornEngine::with_config(&m, tight(8.0)).distance(&r, &c);
            let greedy =
                GreenkhornBackend::new(&m, tight(8.0)).solve(&r, &c, &ScalingInit::Cold);
            assert!(greedy.stats.converged, "seed {seed}: did not converge");
            let rel = (greedy.value - dense.value).abs() / (1.0 + dense.value);
            assert!(
                rel < 1e-6,
                "seed {seed}: greenkhorn {} vs dense {}",
                greedy.value,
                dense.value
            );
        }
    }

    #[test]
    fn marginals_near_feasible_at_convergence() {
        let mut rng = seeded_rng(42);
        let d = 10;
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        let backend = GreenkhornBackend::new(&m, tight(6.0));
        let out = backend.solve(&r, &c, &ScalingInit::Cold);
        assert!(out.stats.converged);
        // Rebuild P = diag(u) K diag(v) and check both marginals.
        for i in 0..d {
            let mut row = 0.0;
            for j in 0..d {
                row += out.u[i] * (-6.0 * m.get(i, j)).exp() * out.v[j];
            }
            assert!((row - r.values()[i]).abs() < 1e-8, "row {i}");
        }
        for j in 0..d {
            let mut col = 0.0;
            for i in 0..d {
                col += out.u[i] * (-6.0 * m.get(i, j)).exp() * out.v[j];
            }
            assert!((col - c.values()[j]).abs() < 1e-8, "col {j}");
        }
    }

    #[test]
    fn handles_sparse_histograms() {
        let mut rng = seeded_rng(5);
        let d = 8;
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::from_weights(&[0.5, 0.5, 0., 0., 0., 0., 0., 0.]).unwrap();
        let c = Histogram::from_weights(&[0., 0., 0., 0., 0., 0., 0.5, 0.5]).unwrap();
        let out =
            GreenkhornBackend::new(&m, tight(9.0)).solve(&r, &c, &ScalingInit::Cold);
        assert!(out.value.is_finite() && out.value > 0.0);
        assert_eq!(out.u[2], 0.0, "zero-mass row scaling must vanish");
    }

    #[test]
    fn fixed_budget_is_respected() {
        let mut rng = seeded_rng(6);
        let d = 10;
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        let out = GreenkhornBackend::new(&m, SinkhornConfig::fixed(9.0, 15))
            .solve(&r, &c, &ScalingInit::Cold);
        assert!(out.stats.iterations <= 15);
        assert!(out.value.is_finite());
    }

    #[test]
    fn warm_start_agrees_and_saves_sweeps() {
        let mut rng = seeded_rng(9);
        let d = 10;
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        let backend = GreenkhornBackend::new(&m, tight(7.0));
        let cold = backend.solve(&r, &c, &ScalingInit::Cold);
        assert!(cold.stats.converged);
        let seed = ScalingInit::from_output(&cold);
        let warm = backend.solve(&r, &c, &seed);
        assert!(warm.stats.converged);
        assert!((warm.value - cold.value).abs() < 1e-7 * (1.0 + cold.value));
        assert!(warm.stats.iterations <= cold.stats.iterations);
    }

    #[test]
    fn annealed_matches_cold() {
        use crate::sinkhorn::LambdaSchedule;
        let mut rng = seeded_rng(10);
        let d = 10;
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        let cold =
            GreenkhornBackend::new(&m, tight(10.0)).solve(&r, &c, &ScalingInit::Cold);
        let annealed_cfg =
            SinkhornConfig { schedule: LambdaSchedule::geometric(1.0), ..tight(10.0) };
        let annealed =
            GreenkhornBackend::new(&m, annealed_cfg).solve(&r, &c, &ScalingInit::Cold);
        assert!(annealed.stats.converged);
        assert!(
            (annealed.value - cold.value).abs() < 1e-7 * (1.0 + cold.value),
            "annealed {} vs cold {}",
            annealed.value,
            cold.value
        );
    }

    #[test]
    fn degenerate_lambda_falls_back_to_log_domain() {
        let mut rng = seeded_rng(7);
        let d = 8;
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        let backend = GreenkhornBackend::new(&m, SinkhornConfig::converged(5_000.0));
        assert!(backend.is_stabilized());
        let out = backend.solve(&r, &c, &ScalingInit::Cold);
        assert!(out.stats.stabilized);
        assert!(out.value.is_finite());
    }

    #[test]
    fn capped_slices_converge_to_the_same_fixed_point() {
        let mut rng = seeded_rng(11);
        let d = 10;
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        let backend = GreenkhornBackend::new(&m, tight(7.0));
        let straight = backend.solve(&r, &c, &ScalingInit::Cold);
        // Drive the same solve in small capped slices, warm-carrying the
        // scalings; the greedy walk resumes from (u, v) so the sliced
        // run reaches the same fixed point.
        let mut carry = ScalingInit::Cold;
        let mut out = backend.solve_capped(&r, &c, &carry, 4);
        for _ in 0..200 {
            if out.stats.converged {
                break;
            }
            carry = ScalingInit::from_output(&out);
            out = backend.solve_capped(&r, &c, &carry, 4);
        }
        assert!(out.stats.converged, "sliced run never converged");
        assert!((out.value - straight.value).abs() < 1e-7 * (1.0 + straight.value));
    }
}
