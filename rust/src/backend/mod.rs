//! Pluggable solve strategies behind one [`SolverBackend`] interface.
//!
//! The crate grew four independent ways to evaluate d_M^λ(r, c) — the
//! dense fixed-point engine, the log-domain stabilized updates, the
//! interleaved batch walk and the exact network simplex — each with its
//! own entry point. This module unifies them (plus a greedy
//! Greenkhorn-style solver in the spirit of Altschuler et al., "Near-
//! linear time approximation algorithms for optimal transport via
//! Sinkhorn iteration") behind a panel-shaped trait, so the coordinator,
//! the benches and the parity tests can swap strategies freely.
//!
//! On top of the trait sits the [`ShardedExecutor`]: a thread-pool panel
//! executor that partitions a query panel across `std::thread` workers,
//! each owning its *own* K/Kᵀ-bound backend instance. The kernel
//! matrices are therefore streamed in parallel with zero sharing — the
//! multi-core analogue of the cache argument in
//! [`crate::sinkhorn::batch`], and the paper's §4.1 "parallel platforms"
//! remark turned into an actual substrate.

mod executor;
mod greenkhorn;

pub(crate) use executor::shard_ranges;
pub use executor::{ShardReport, ShardedExecutor, WorkerStats};
pub use greenkhorn::GreenkhornBackend;

use crate::linalg::{KernelPolicy, KernelStats};
use crate::metric::CostMatrix;
use crate::ot::EmdSolver;
use crate::simplex::Histogram;
use crate::sinkhorn::{
    certify, log_domain, outcome, BatchSinkhorn, ErrorInterval, ScalingInit,
    SinkhornConfig, SinkhornEngine, SinkhornOutput, SinkhornStats, SolveBudget,
    SolveOutcome,
};
use crate::F;

/// A solve strategy bound to one (M, λ) pair.
///
/// Implementations own whatever precomputed state they need (typically
/// K = e^{−λM} and Kᵀ), are cheap to query repeatedly, and are `Send` so
/// the [`ShardedExecutor`] can hand each instance to its own worker
/// thread.
pub trait SolverBackend: Send {
    /// Which strategy this is (stable identifier for routing/metrics).
    fn kind(&self) -> BackendKind;

    /// Histogram dimension d this backend is bound to.
    fn dim(&self) -> usize;

    /// d_M^λ(r, c) for a single pair, seeded by `init`
    /// ([`ScalingInit::Cold`] for a from-scratch solve; a warm seed only
    /// accelerates convergence, never changes the fixed point).
    ///
    /// Implementations must not panic on recoverable solver failure
    /// (they run on [`ShardedExecutor`] worker threads, where a panic
    /// would take the whole coordinator engine down); report failure as
    /// a NaN `value` with `converged: false` instead. Shape mismatches
    /// remain programming errors and may assert.
    fn solve(&self, r: &Histogram, c: &Histogram, init: &ScalingInit) -> SinkhornOutput;

    /// One budget slice: [`Self::solve`] stopped after at most `cap`
    /// fixed-point iterations, convergence checks still active. The
    /// default ignores the cap — sound for backends whose solve is one
    /// atomic unit (the exact simplex), since an early finish only
    /// tightens the certificate.
    fn solve_capped(
        &self,
        r: &Histogram,
        c: &Histogram,
        init: &ScalingInit,
        cap: usize,
    ) -> SinkhornOutput {
        let _ = cap;
        self.solve(r, c, init)
    }

    /// Certified bracket on the exact d^λ for a state this backend
    /// produced. The default is the vacuous [`ErrorInterval::UNBOUNDED`];
    /// backends holding the exact cost matrix override with the dual /
    /// AWR-rounding certificate ([`certify`]).
    fn certificate(
        &self,
        r: &Histogram,
        c: &Histogram,
        out: &SinkhornOutput,
    ) -> ErrorInterval {
        let _ = (r, c, out);
        ErrorInterval::UNBOUNDED
    }

    /// Anytime solve under `budget`: iterate in [`crate::sinkhorn::CERT_STRIDE`]
    /// slices, warm-carrying the scaling and intersecting per-slice
    /// certificates. [`SolveBudget::Unbounded`] reproduces
    /// [`Self::solve`] bit-identically and certifies the final state
    /// once.
    fn solve_outcome(
        &self,
        r: &Histogram,
        c: &Histogram,
        init: &ScalingInit,
        budget: SolveBudget,
    ) -> SolveOutcome {
        outcome::drive_budgeted(
            budget,
            init,
            |seed| self.solve(r, c, seed),
            |seed, cap| self.solve_capped(r, c, seed, cap),
            |out| self.certificate(r, c, out),
        )
    }

    /// Whether this strategy actually consumes initial scalings. The
    /// [`ShardedExecutor`] skips warm-store lookups and inserts entirely
    /// for backends that do not (e.g. the exact simplex, whose
    /// [`Self::solve`] discards the seed) — otherwise every repeat query
    /// would pay fingerprint/clone/insert costs and report a healthy hit
    /// rate with zero effect on iteration counts.
    fn warm_startable(&self) -> bool {
        true
    }

    /// Structure report of the kernel operator this backend iterates
    /// with: nnz (the per-iteration flop proxy), factorization rank and
    /// the kernel mass the approximation discarded. Backends without a
    /// materialized kernel (log-domain, exact) report the implicit
    /// dense structure.
    fn kernel_stats(&self) -> KernelStats {
        KernelStats::dense(self.dim())
    }

    /// One source against a panel of targets C = [c_1 … c_N]
    /// (Algorithm 1's vectorized form). Default: per-pair loop.
    fn solve_panel(&self, r: &Histogram, cs: &[Histogram]) -> Vec<SinkhornOutput> {
        cs.iter().map(|c| self.solve(r, c, &ScalingInit::Cold)).collect()
    }

    /// Fully paired panel with per-query seeds: `inits[j]` seeds pair j;
    /// an empty slice means all-cold.
    fn solve_paired(
        &self,
        rs: &[&Histogram],
        cs: &[Histogram],
        inits: &[ScalingInit],
    ) -> Vec<SinkhornOutput> {
        assert_eq!(rs.len(), cs.len(), "paired panel size mismatch");
        if inits.is_empty() {
            return rs
                .iter()
                .zip(cs)
                .map(|(r, c)| self.solve(r, c, &ScalingInit::Cold))
                .collect();
        }
        assert_eq!(inits.len(), cs.len(), "warm-start slice size mismatch");
        rs.iter()
            .zip(cs)
            .zip(inits)
            .map(|((r, c), init)| self.solve(r, c, init))
            .collect()
    }

    /// Anytime paired panel: per-column [`SolveOutcome`]s under one
    /// shared `budget` (each column gets the full iteration allowance;
    /// a deadline is global). Default: per-pair [`Self::solve_outcome`]
    /// loop; the interleaved backend overrides with the genuinely
    /// panel-sliced walk.
    fn solve_paired_outcomes(
        &self,
        rs: &[&Histogram],
        cs: &[Histogram],
        inits: &[ScalingInit],
        budget: SolveBudget,
    ) -> Vec<SolveOutcome> {
        assert_eq!(rs.len(), cs.len(), "paired panel size mismatch");
        if !inits.is_empty() {
            assert_eq!(inits.len(), cs.len(), "warm-start slice size mismatch");
        }
        rs.iter()
            .zip(cs)
            .enumerate()
            .map(|(j, (r, c))| {
                let cold = ScalingInit::Cold;
                let seed = inits.get(j).unwrap_or(&cold);
                self.solve_outcome(r, c, seed, budget)
            })
            .collect()
    }

    /// Deprecated alias of [`Self::solve`] with a cold seed.
    #[deprecated(since = "0.3.0", note = "use `solve` with `ScalingInit::Cold`")]
    fn solve_pair(&self, r: &Histogram, c: &Histogram) -> SinkhornOutput {
        self.solve(r, c, &ScalingInit::Cold)
    }

    /// Deprecated alias of [`Self::solve`]; `None` maps to
    /// [`ScalingInit::Cold`].
    #[deprecated(since = "0.3.0", note = "use `solve`, which takes the seed directly")]
    fn solve_pair_init(
        &self,
        r: &Histogram,
        c: &Histogram,
        init: Option<&ScalingInit>,
    ) -> SinkhornOutput {
        self.solve(r, c, init.unwrap_or(&ScalingInit::Cold))
    }

    /// Deprecated alias of [`Self::solve_paired`] with no seeds.
    #[deprecated(since = "0.3.0", note = "use `solve_paired` with an empty init slice")]
    fn solve_panel_paired(
        &self,
        rs: &[&Histogram],
        cs: &[Histogram],
    ) -> Vec<SinkhornOutput> {
        self.solve_paired(rs, cs, &[])
    }

    /// Deprecated alias of [`Self::solve_paired`]; `None` seeds map to
    /// [`ScalingInit::Cold`].
    #[deprecated(
        since = "0.3.0",
        note = "use `solve_paired`, whose seeds are `ScalingInit` values (Cold replaces None)"
    )]
    fn solve_panel_paired_init(
        &self,
        rs: &[&Histogram],
        cs: &[Histogram],
        inits: &[Option<ScalingInit>],
    ) -> Vec<SinkhornOutput> {
        let owned: Vec<ScalingInit> =
            inits.iter().map(|i| i.clone().unwrap_or_default()).collect();
        self.solve_paired(rs, cs, &owned)
    }
}

/// The available solve strategies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Dense Sinkhorn-Knopp fixed point ([`SinkhornEngine`]), with its
    /// automatic log-domain fallback on kernel underflow.
    Dense,
    /// Log-sum-exp stabilized updates ([`log_domain`]) — exact at any λ.
    LogDomain,
    /// Interleaved batch walk ([`BatchSinkhorn`]): one pass over K per
    /// iteration updates every panel column. Dense-kernel regime only;
    /// use [`BackendKind::auto`] to route around underflow.
    Interleaved,
    /// Greedy row/column scaling ([`GreenkhornBackend`]).
    Greenkhorn,
    /// Interleaved batch walk over a threshold-truncated CSR Gibbs
    /// kernel ([`crate::linalg::SparseKernel`]): strictly fewer
    /// streamed entries per iteration once e^{−λM} has enough
    /// negligible mass, at a documented per-row mass-loss cost. Dense-
    /// representable regime only (like [`BackendKind::Interleaved`]).
    Truncated,
    /// Interleaved batch walk over a pivoted-Cholesky low-rank kernel
    /// ([`crate::linalg::LowRankKernel`]): O(d·rank) per apply, the
    /// profitable structure at small λ where K is smooth.
    LowRank,
    /// Exact EMD via the transportation network simplex ([`EmdSolver`]);
    /// ignores λ.
    Exact,
}

impl BackendKind {
    /// Stable lowercase name (metrics labels, CLI flags).
    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Dense => "dense",
            BackendKind::LogDomain => "log_domain",
            BackendKind::Interleaved => "interleaved",
            BackendKind::Greenkhorn => "greenkhorn",
            BackendKind::Truncated => "truncated",
            BackendKind::LowRank => "low_rank",
            BackendKind::Exact => "exact",
        }
    }

    /// Parse the name produced by [`Self::as_str`].
    pub fn parse(name: &str) -> Option<BackendKind> {
        match name {
            "dense" => Some(BackendKind::Dense),
            "log_domain" => Some(BackendKind::LogDomain),
            "interleaved" => Some(BackendKind::Interleaved),
            "greenkhorn" => Some(BackendKind::Greenkhorn),
            "truncated" => Some(BackendKind::Truncated),
            "low_rank" => Some(BackendKind::LowRank),
            "exact" => Some(BackendKind::Exact),
            _ => None,
        }
    }

    /// The serving default for (M, λ): the log-domain path when e^{−λM}
    /// underflows (the Fig. 5 "diagonally dominant" regime), the
    /// truncated-kernel walk once d·λ crosses the sparsity-profitable
    /// threshold ([`crate::linalg::kernel::AUTO_SPARSITY_DLAMBDA`] —
    /// past it the kernel has enough sub-threshold entries that CSR
    /// streaming beats the dense sweep), and the dense interleaved
    /// batch walk otherwise.
    ///
    /// Policy-*blind* by construction: this router sees only (M, λ), so
    /// it cannot distinguish a deliberate `KernelPolicy::Dense` from
    /// the `SinkhornConfig` default. Callers with exactness intent
    /// should route through [`ShardedExecutor::auto`], which honors the
    /// config's policy (an explicit Dense pins the exact walk), or pick
    /// the kind themselves.
    pub fn auto(metric: &CostMatrix, lambda: F) -> BackendKind {
        if dense_kernel_degenerate(metric, lambda) {
            BackendKind::LogDomain
        } else if metric.dim() as F * lambda
            >= crate::linalg::kernel::AUTO_SPARSITY_DLAMBDA
            && lambda * metric.median_cost()
                >= crate::linalg::kernel::AUTO_SPARSITY_LAMBDA_MEDIAN
        {
            // Both gates matter: d·λ says the CSR overhead amortizes,
            // λ·median(M) says the default threshold actually drops
            // entries on this metric's scale (d·λ alone would route a
            // costs-≪-1/λ metric to a "sparse" kernel keeping all d²
            // entries).
            BackendKind::Truncated
        } else {
            BackendKind::Interleaved
        }
    }

    /// Construct a backend instance bound to (metric, config.lambda).
    pub fn build(
        self,
        metric: &CostMatrix,
        config: SinkhornConfig,
    ) -> Box<dyn SolverBackend> {
        match self {
            BackendKind::Dense => Box::new(DenseBackend::new(metric, config)),
            BackendKind::LogDomain => Box::new(LogDomainBackend::new(metric, config)),
            BackendKind::Interleaved => {
                Box::new(InterleavedBackend::new(metric, config))
            }
            BackendKind::Greenkhorn => Box::new(GreenkhornBackend::new(metric, config)),
            BackendKind::Truncated => {
                Box::new(InterleavedBackend::truncated(metric, config))
            }
            BackendKind::LowRank => {
                Box::new(InterleavedBackend::low_rank(metric, config))
            }
            BackendKind::Exact => Box::new(ExactBackend::new(metric)),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

// The kernel-underflow routing predicate lives in [`crate::sinkhorn`]
// (one shared implementation for the engine, the backends and this
// router); re-exported here for backend-centric callers.
pub use crate::sinkhorn::dense_kernel_degenerate;

/// [`SinkhornEngine`] behind the trait (per-pair dense fixed point with
/// log-domain auto-fallback).
pub struct DenseBackend {
    engine: SinkhornEngine,
}

impl DenseBackend {
    pub fn new(metric: &CostMatrix, config: SinkhornConfig) -> Self {
        Self { engine: SinkhornEngine::with_config(metric, config) }
    }

    /// Whether solves are being routed through the log-domain path.
    pub fn is_stabilized(&self) -> bool {
        self.engine.is_stabilized()
    }
}

impl SolverBackend for DenseBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Dense
    }

    fn dim(&self) -> usize {
        self.engine.dim()
    }

    fn solve(&self, r: &Histogram, c: &Histogram, init: &ScalingInit) -> SinkhornOutput {
        self.engine.distance_init(r, c, init)
    }

    fn solve_capped(
        &self,
        r: &Histogram,
        c: &Histogram,
        init: &ScalingInit,
        cap: usize,
    ) -> SinkhornOutput {
        self.engine.distance_capped(r, c, init, cap)
    }

    fn certificate(
        &self,
        r: &Histogram,
        c: &Histogram,
        out: &SinkhornOutput,
    ) -> ErrorInterval {
        self.engine.certificate(r, c, out)
    }

    fn kernel_stats(&self) -> KernelStats {
        self.engine.kernel_stats()
    }
}

/// Log-domain stabilized updates behind the trait — numerically exact at
/// any λ, at an O(log) per-element premium over the dense path.
pub struct LogDomainBackend {
    d: usize,
    config: SinkhornConfig,
    m: Vec<F>,
}

impl LogDomainBackend {
    pub fn new(metric: &CostMatrix, config: SinkhornConfig) -> Self {
        assert!(config.lambda > 0.0, "lambda must be positive");
        Self { d: metric.dim(), config, m: metric.data().to_vec() }
    }
}

impl SolverBackend for LogDomainBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::LogDomain
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn solve(&self, r: &Histogram, c: &Histogram, init: &ScalingInit) -> SinkhornOutput {
        assert_eq!(r.dim(), self.d, "source dimension mismatch");
        assert_eq!(c.dim(), self.d, "target dimension mismatch");
        log_domain::solve_init(
            &self.m,
            self.d,
            self.config.lambda,
            &self.config,
            r.values(),
            c.values(),
            init,
        )
    }

    fn solve_capped(
        &self,
        r: &Histogram,
        c: &Histogram,
        init: &ScalingInit,
        cap: usize,
    ) -> SinkhornOutput {
        assert_eq!(r.dim(), self.d, "source dimension mismatch");
        assert_eq!(c.dim(), self.d, "target dimension mismatch");
        log_domain::solve_capped(
            &self.m,
            self.d,
            self.config.lambda,
            &self.config,
            r.values(),
            c.values(),
            init,
            cap,
        )
    }

    fn certificate(
        &self,
        r: &Histogram,
        c: &Histogram,
        out: &SinkhornOutput,
    ) -> ErrorInterval {
        certify(&self.m, self.d, self.config.lambda, r.values(), c.values(), out)
    }
}

/// [`BatchSinkhorn`] behind the trait: the genuinely interleaved panel
/// walk (one pass over the kernel operator per iteration updates all
/// columns). One struct serves three [`BackendKind`]s — the classic
/// dense-policy [`BackendKind::Interleaved`] plus the structured
/// [`BackendKind::Truncated`] / [`BackendKind::LowRank`] flavors, which
/// differ only in the [`KernelPolicy`] their constructors force.
pub struct InterleavedBackend {
    batch: BatchSinkhorn,
    kind: BackendKind,
}

impl InterleavedBackend {
    /// The classic interleaved walk over whatever kernel the config's
    /// policy builds (dense by default).
    pub fn new(metric: &CostMatrix, config: SinkhornConfig) -> Self {
        Self {
            batch: BatchSinkhorn::new(metric, config),
            kind: BackendKind::Interleaved,
        }
    }

    /// Truncated-CSR construction: keeps an explicit
    /// [`KernelPolicy::Truncated`] from the config, defaults the
    /// threshold otherwise — requesting this *kind* is the explicit ask
    /// for truncation (policy-respecting routing lives in
    /// [`ShardedExecutor::auto`]).
    pub fn truncated(metric: &CostMatrix, mut config: SinkhornConfig) -> Self {
        if !matches!(config.kernel, KernelPolicy::Truncated { .. }) {
            config.kernel = KernelPolicy::truncated_default();
        }
        Self {
            batch: BatchSinkhorn::new(metric, config),
            kind: BackendKind::Truncated,
        }
    }

    /// Low-rank construction: keeps an explicit
    /// [`KernelPolicy::LowRank`] from the config, defaults the trace
    /// tolerance otherwise.
    pub fn low_rank(metric: &CostMatrix, mut config: SinkhornConfig) -> Self {
        if !matches!(config.kernel, KernelPolicy::LowRank { .. }) {
            config.kernel = KernelPolicy::low_rank_default();
        }
        Self {
            batch: BatchSinkhorn::new(metric, config),
            kind: BackendKind::LowRank,
        }
    }
}

impl SolverBackend for InterleavedBackend {
    fn kind(&self) -> BackendKind {
        self.kind
    }

    fn dim(&self) -> usize {
        self.batch.dim()
    }

    fn solve(&self, r: &Histogram, c: &Histogram, init: &ScalingInit) -> SinkhornOutput {
        let inits = [init.clone()];
        let mut out = self.batch.distances_paired_init(
            &[r],
            std::slice::from_ref(c),
            &inits,
        );
        out.pop().expect("one output per target")
    }

    fn solve_capped(
        &self,
        r: &Histogram,
        c: &Histogram,
        init: &ScalingInit,
        cap: usize,
    ) -> SinkhornOutput {
        let inits = [init.clone()];
        let mut out = self.batch.distances_paired_capped(
            &[r],
            std::slice::from_ref(c),
            &inits,
            cap,
        );
        out.pop().expect("one output per target")
    }

    fn certificate(
        &self,
        r: &Histogram,
        c: &Histogram,
        out: &SinkhornOutput,
    ) -> ErrorInterval {
        self.batch.certificate(r, c, out)
    }

    fn solve_panel(&self, r: &Histogram, cs: &[Histogram]) -> Vec<SinkhornOutput> {
        self.batch.distances(r, cs)
    }

    fn solve_paired(
        &self,
        rs: &[&Histogram],
        cs: &[Histogram],
        inits: &[ScalingInit],
    ) -> Vec<SinkhornOutput> {
        self.batch.distances_paired_init(rs, cs, inits)
    }

    fn solve_paired_outcomes(
        &self,
        rs: &[&Histogram],
        cs: &[Histogram],
        inits: &[ScalingInit],
        budget: SolveBudget,
    ) -> Vec<SolveOutcome> {
        self.batch.outcomes_paired(rs, cs, inits, budget)
    }

    fn kernel_stats(&self) -> KernelStats {
        self.batch.kernel_stats()
    }
}

/// Exact EMD (network simplex) behind the trait. The "λ = ∞" member of
/// the family: `value` is d_M(r, c), `u`/`v` carry the dual potentials,
/// and `stats.iterations` counts simplex pivots.
///
/// Solver failure (the pivot-limit guard) is reported as a NaN `value`
/// with `converged: false`, never a panic — a panicking backend inside a
/// [`ShardedExecutor`] worker would take down the whole coordinator
/// engine thread for one bad query.
pub struct ExactBackend {
    metric: CostMatrix,
    pivot_limit: Option<usize>,
}

impl ExactBackend {
    pub fn new(metric: &CostMatrix) -> Self {
        Self { metric: metric.clone(), pivot_limit: None }
    }

    /// Override the network-simplex pivot limit (mainly for tests).
    pub fn with_pivot_limit(metric: &CostMatrix, limit: usize) -> Self {
        Self { metric: metric.clone(), pivot_limit: Some(limit) }
    }
}

impl SolverBackend for ExactBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Exact
    }

    fn dim(&self) -> usize {
        self.metric.dim()
    }

    fn warm_startable(&self) -> bool {
        // The simplex solves from scratch; scaling seeds mean nothing.
        false
    }

    fn solve(&self, r: &Histogram, c: &Histogram, init: &ScalingInit) -> SinkhornOutput {
        // The simplex solves from scratch; scaling seeds mean nothing.
        let _ = init;
        let mut solver = EmdSolver::new(&self.metric);
        if let Some(limit) = self.pivot_limit {
            solver = solver.with_pivot_limit(limit);
        }
        match solver.solve(r, c) {
            Ok(plan) => {
                let (u, v) = plan.potentials;
                SinkhornOutput {
                    value: plan.cost,
                    u,
                    v,
                    stats: SinkhornStats {
                        iterations: plan.stats.pivots,
                        last_delta: 0.0,
                        converged: true,
                        stabilized: false,
                    },
                }
            }
            Err(_) => {
                let d = self.metric.dim();
                SinkhornOutput {
                    value: F::NAN,
                    u: vec![0.0; d],
                    v: vec![0.0; d],
                    stats: SinkhornStats {
                        last_delta: F::INFINITY,
                        ..Default::default()
                    },
                }
            }
        }
    }

    fn certificate(
        &self,
        r: &Histogram,
        c: &Histogram,
        out: &SinkhornOutput,
    ) -> ErrorInterval {
        // The network simplex is exact: a successful solve certifies
        // itself as a zero-width interval at d_M(r, c). Budgets cannot
        // slice a pivot sequence, so failure stays vacuous.
        let _ = (r, c);
        if out.value.is_finite() && out.stats.converged {
            ErrorInterval::point(out.value)
        } else {
            ErrorInterval::UNBOUNDED
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::RandomMetric;
    use crate::simplex::seeded_rng;

    fn workload(d: usize, seed: u64) -> (CostMatrix, Histogram, Histogram) {
        let mut rng = seeded_rng(seed);
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        (m, r, c)
    }

    #[test]
    fn kind_roundtrips_through_names() {
        for kind in [
            BackendKind::Dense,
            BackendKind::LogDomain,
            BackendKind::Interleaved,
            BackendKind::Greenkhorn,
            BackendKind::Truncated,
            BackendKind::LowRank,
            BackendKind::Exact,
        ] {
            assert_eq!(BackendKind::parse(kind.as_str()), Some(kind));
        }
        assert_eq!(BackendKind::parse("warp_drive"), None);
    }

    #[test]
    fn every_kind_builds_and_solves() {
        let (m, r, c) = workload(10, 0);
        let cfg = SinkhornConfig::fixed(9.0, 50);
        for kind in [
            BackendKind::Dense,
            BackendKind::LogDomain,
            BackendKind::Interleaved,
            BackendKind::Greenkhorn,
            BackendKind::Truncated,
            BackendKind::LowRank,
            BackendKind::Exact,
        ] {
            let backend = kind.build(&m, cfg);
            assert_eq!(backend.kind(), kind);
            assert_eq!(backend.dim(), 10);
            let out = backend.solve(&r, &c, &ScalingInit::Cold);
            assert!(
                out.value.is_finite() && out.value > 0.0,
                "{kind}: bad value {}",
                out.value
            );
            let stats = backend.kernel_stats();
            assert_eq!(stats.dim, 10, "{kind}: kernel stats dim");
            assert!(stats.nnz > 0 && stats.rank > 0, "{kind}: empty kernel stats");
        }
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_match_new_surface() {
        let (m, r, c) = workload(10, 21);
        let cfg = SinkhornConfig::fixed(9.0, 40);
        let mut rng = seeded_rng(34);
        let r2 = Histogram::sample_uniform(10, &mut rng);
        let c2 = Histogram::sample_uniform(10, &mut rng);
        for kind in [BackendKind::Dense, BackendKind::Interleaved, BackendKind::Greenkhorn] {
            let backend = kind.build(&m, cfg);
            let new = backend.solve(&r, &c, &ScalingInit::Cold);
            let old = backend.solve_pair(&r, &c);
            assert_eq!(old.value, new.value, "{kind}: solve_pair shim drifted");
            let seeded_old = backend.solve_pair_init(&r, &c, None);
            assert_eq!(seeded_old.value, new.value, "{kind}: None seed != Cold");
            let rs = [&r, &r2];
            let cs = [c.clone(), c2.clone()];
            let panel_old = backend.solve_panel_paired(&rs, &cs);
            let panel_new = backend.solve_paired(&rs, &cs, &[]);
            for (o, n) in panel_old.iter().zip(&panel_new) {
                assert_eq!(o.value, n.value, "{kind}: paired shim drifted");
            }
            let inits = vec![None, None];
            let seeded_panel = backend.solve_panel_paired_init(&rs, &cs, &inits);
            for (o, n) in seeded_panel.iter().zip(&panel_new) {
                assert_eq!(o.value, n.value, "{kind}: init shim drifted");
            }
        }
    }

    #[test]
    fn every_certified_backend_brackets_its_own_estimate() {
        // A tight convergence run: the served value must land inside the
        // backend's own certificate for every strategy that issues one.
        let (m, r, c) = workload(10, 5);
        let mut cfg = SinkhornConfig::converged(9.0);
        cfg.tolerance = 1e-12;
        for kind in [
            BackendKind::Dense,
            BackendKind::LogDomain,
            BackendKind::Interleaved,
            BackendKind::Greenkhorn,
            BackendKind::Truncated,
            BackendKind::LowRank,
        ] {
            let backend = kind.build(&m, cfg);
            let outcome = backend.solve_outcome(
                &r,
                &c,
                &ScalingInit::Cold,
                SolveBudget::Unbounded,
            );
            assert!(
                outcome.interval.hi.is_finite(),
                "{kind}: no certificate on a converged solve"
            );
            // Truncated/low-rank estimates price the *approximate*
            // kernel's plan, so compare against the exact-cost bracket
            // with the kernel's own mass-loss as slack.
            let slack = 1e-9 + backend.kernel_stats().mass_loss;
            assert!(
                outcome.estimate >= outcome.interval.lo - slack
                    && outcome.estimate <= outcome.interval.hi + slack,
                "{kind}: estimate {} outside [{}, {}]",
                outcome.estimate,
                outcome.interval.lo,
                outcome.interval.hi,
            );
        }
    }

    #[test]
    fn budgeted_outcomes_tighten_with_iterations() {
        let (m, r, c) = workload(12, 6);
        let cfg = SinkhornConfig::fixed(9.0, 400);
        for kind in [BackendKind::Dense, BackendKind::LogDomain, BackendKind::Interleaved]
        {
            let backend = kind.build(&m, cfg);
            let mut last_width = F::INFINITY;
            for budget in [8usize, 16, 32, 64] {
                let out = backend.solve_outcome(
                    &r,
                    &c,
                    &ScalingInit::Cold,
                    SolveBudget::Iterations(budget),
                );
                assert!(out.iterations <= budget, "{kind}: budget overrun");
                let width = out.interval.width();
                assert!(
                    width <= last_width + 1e-12,
                    "{kind}: width grew {last_width} -> {width} at budget {budget}"
                );
                last_width = width;
            }
            assert!(last_width.is_finite(), "{kind}: certificate never tightened");
        }
    }

    #[test]
    fn exact_backend_certifies_a_point() {
        let (m, r, c) = workload(9, 7);
        let backend = ExactBackend::new(&m);
        let out = backend.solve_outcome(
            &r,
            &c,
            &ScalingInit::Cold,
            SolveBudget::Iterations(1),
        );
        assert!(out.converged);
        assert_eq!(out.interval.width(), 0.0, "exact solve must self-certify");
        assert_eq!(out.interval.lo, out.estimate);
    }

    #[test]
    fn structured_backends_report_structure() {
        let (m, _, _) = workload(12, 11);
        // High λ: plenty of sub-threshold kernel entries to truncate.
        let trunc = BackendKind::Truncated.build(&m, SinkhornConfig::fixed(30.0, 10));
        let ts = trunc.kernel_stats();
        assert!(ts.nnz < 12 * 12, "default threshold must truncate at λ=30");
        assert!(ts.mass_loss > 0.0 && ts.mass_loss < 1e-3);
        // Low λ with an explicitly loose trace tolerance: the kernel
        // factors well below full rank (the e^{−λ‖·‖} eigen-tail decays
        // polynomially, so the near-exact default tolerance would keep
        // full rank — compression is an accuracy trade the policy makes
        // explicit).
        let mut lr_cfg = SinkhornConfig::fixed(0.05, 10);
        lr_cfg.kernel =
            crate::linalg::KernelPolicy::LowRank { max_rank: 0, tolerance: 3e-2 };
        let lr = BackendKind::LowRank.build(&m, lr_cfg);
        let ls = lr.kernel_stats();
        assert!(ls.rank < 12, "tiny λ + loose tolerance must compress: {ls:?}");
        assert!(ls.mass_loss > 0.0 && ls.nnz < 2 * 12 * 12);
        // An explicit policy in the config is honored, not overridden.
        let mut cfg = SinkhornConfig::fixed(30.0, 10);
        cfg.kernel = crate::linalg::KernelPolicy::Truncated { threshold: 0.0 };
        let exact = BackendKind::Truncated.build(&m, cfg);
        assert_eq!(exact.kernel_stats().mass_loss, 0.0);
    }

    #[test]
    fn degeneracy_detector_matches_engine() {
        let (m, _, _) = workload(8, 1);
        for &lambda in &[1.0, 9.0, 60.0, 5_000.0] {
            let engine =
                SinkhornEngine::with_config(&m, SinkhornConfig::converged(lambda));
            assert_eq!(
                dense_kernel_degenerate(&m, lambda),
                engine.is_stabilized(),
                "lambda={lambda}"
            );
        }
    }

    #[test]
    fn auto_routes_by_regime() {
        let (m, _, _) = workload(8, 2);
        assert_eq!(BackendKind::auto(&m, 9.0), BackendKind::Interleaved);
        assert_eq!(BackendKind::auto(&m, 50_000.0), BackendKind::LogDomain);
        // d·λ past the sparsity threshold, but still representable:
        // truncation wins. A bounded metric (max 1) keeps λ·max(M) far
        // below the e^x underflow edge, so the regime is deterministic:
        // 16 · 300 = 4800 ≥ 4096 with zero kernel underflow.
        let d = 16;
        let mut data = vec![0.0; d * d];
        for i in 0..d {
            for j in 0..d {
                if i != j {
                    let gap = (i as F - j as F).abs() / (d - 1) as F;
                    data[i * d + j] = 0.1 + 0.9 * gap;
                }
            }
        }
        let bounded = CostMatrix::from_rows(d, data);
        assert!(!dense_kernel_degenerate(&bounded, 300.0));
        assert_eq!(BackendKind::auto(&bounded, 300.0), BackendKind::Truncated);
    }

    #[test]
    fn panel_defaults_match_pairwise() {
        let (m, r, _) = workload(12, 3);
        let mut rng = seeded_rng(33);
        let cs: Vec<Histogram> =
            (0..5).map(|_| Histogram::sample_uniform(12, &mut rng)).collect();
        let cfg = SinkhornConfig::fixed(7.0, 30);
        let backend = BackendKind::Dense.build(&m, cfg);
        let panel = backend.solve_panel(&r, &cs);
        for (c, out) in cs.iter().zip(&panel) {
            let single = backend.solve(&r, c, &ScalingInit::Cold);
            assert!((single.value - out.value).abs() < 1e-12);
        }
    }

    #[test]
    fn exact_backend_matches_emd_solver() {
        let (m, r, c) = workload(9, 4);
        let direct = EmdSolver::new(&m).solve(&r, &c).unwrap().cost;
        let backend = ExactBackend::new(&m);
        let out = backend.solve(&r, &c, &ScalingInit::Cold);
        assert!((out.value - direct).abs() < 1e-12);
        assert!(out.stats.converged);
    }

    #[test]
    fn exact_backend_reports_failure_as_nan_not_panic() {
        let (m, r, c) = workload(16, 8);
        let backend = ExactBackend::with_pivot_limit(&m, 0);
        let out = backend.solve(&r, &c, &ScalingInit::Cold);
        if out.value.is_nan() {
            // The expected path: the pivot limit tripped and the failure
            // surfaced as data, not a panic.
            assert!(!out.stats.converged);
            assert_eq!(out.u.len(), 16);
        } else {
            // Astronomically unlikely: the NW-corner basis was already
            // optimal, so no pivots were needed and the solve succeeded.
            assert!(out.stats.converged);
        }
    }
}
