//! Chrome trace-event export.
//!
//! Renders spans as the Chrome trace-event JSON array format ("X" complete
//! events), loadable in Perfetto (<https://ui.perfetto.dev>) or
//! `chrome://tracing`: each span becomes one slice on its recording
//! thread's track, with the typed payload flattened into `args`.

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::{Span, SpanData};

fn num(x: f64) -> Json {
    // util::json renders f64 via Display; keep the output parseable when a
    // payload carries an unbounded interval width (±inf).
    Json::Number(if x.is_finite() { x } else { -1.0 })
}

fn args(span: &Span) -> Json {
    let mut m = BTreeMap::new();
    let mut set = |k: &str, v: Json| {
        m.insert(k.to_string(), v);
    };
    set("trace", num(span.trace.0 as f64));
    set("tenant", Json::String(span.tenant.label()));
    match span.data {
        SpanData::None => {}
        SpanData::Batch { size, full } => {
            set("batch_size", num(size as f64));
            set("full", Json::Bool(full));
        }
        SpanData::Solve {
            batch,
            warm_hits,
            warm_misses,
            shed,
        } => {
            set("batch_size", num(batch as f64));
            set("warm_hits", num(warm_hits as f64));
            set("warm_misses", num(warm_misses as f64));
            set("shed", Json::Bool(shed));
        }
        SpanData::Mailbox { queued_us } => {
            set("queued_us", num(queued_us as f64));
        }
        SpanData::Search {
            hits,
            routed,
            rescued,
        } => {
            set("hits", num(hits as f64));
            set("routed", Json::Bool(routed));
            set("rescued", num(rescued as f64));
        }
        SpanData::Shard {
            shard,
            solved,
            pruned,
        } => {
            set("shard", num(shard as f64));
            set("solved", num(solved as f64));
            set("pruned", num(pruned as f64));
        }
        SpanData::Cascade { tier, priced } => {
            set("tier", num(tier as f64));
            set("priced", num(priced as f64));
        }
        SpanData::Refine {
            panels,
            warm_seeded,
            rescued,
        } => {
            set("panels", num(panels as f64));
            set("warm_seeded", num(warm_seeded as f64));
            set("rescued", num(rescued as f64));
        }
        SpanData::Slice {
            index,
            iterations,
            width,
        } => {
            set("slice", num(index as f64));
            set("iterations", num(iterations as f64));
            set("interval_width", num(width));
        }
    }
    Json::Object(m)
}

/// Render spans as a Chrome trace-event JSON array ("X" complete events,
/// microsecond timestamps). `pid` carries the `TraceId` so multiple traces
/// exported together group into separate process tracks; `tid` is the
/// recording thread's per-sink ordinal.
pub fn chrome_trace(spans: &[Span]) -> Json {
    let events = spans
        .iter()
        .map(|span| {
            let mut e = BTreeMap::new();
            let mut set = |k: &str, v: Json| {
                e.insert(k.to_string(), v);
            };
            set("name", Json::String(span.stage.name().to_string()));
            set("ph", Json::String("X".to_string()));
            set("ts", num(span.start_us as f64));
            set("dur", num(span.duration_us() as f64));
            set("pid", num(span.trace.0 as f64));
            set("tid", num(span.tid as f64));
            set("args", args(span));
            Json::Object(e)
        })
        .collect();
    Json::Array(events)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Stage, Tenant, TraceId};

    #[test]
    fn spans_render_as_complete_events() {
        let spans = vec![
            Span {
                trace: TraceId(4),
                stage: Stage::Solve,
                tenant: Tenant::Metric(1),
                start_us: 100,
                end_us: 350,
                tid: 2,
                data: SpanData::Solve {
                    batch: 8,
                    warm_hits: 3,
                    warm_misses: 5,
                    shed: false,
                },
            },
            Span {
                trace: TraceId(4),
                stage: Stage::Slice,
                tenant: Tenant::Metric(1),
                start_us: 120,
                end_us: 180,
                tid: 2,
                data: SpanData::Slice {
                    index: 0,
                    iterations: 8,
                    width: 1.5e-7,
                },
            },
        ];
        let doc = chrome_trace(&spans);
        let events = doc.as_array().expect("array document");
        assert_eq!(events.len(), 2);
        let solve = &events[0];
        assert_eq!(solve.get("name").and_then(Json::as_str), Some("solve"));
        assert_eq!(solve.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(solve.get("ts").and_then(Json::as_f64), Some(100.0));
        assert_eq!(solve.get("dur").and_then(Json::as_f64), Some(250.0));
        assert_eq!(solve.get("pid").and_then(Json::as_f64), Some(4.0));
        let args = solve.get("args").expect("args object");
        assert_eq!(args.get("warm_hits").and_then(Json::as_f64), Some(3.0));
        assert_eq!(args.get("tenant").and_then(Json::as_str), Some("m1"));

        // Round-trips through the crate's own parser (valid JSON).
        let text = format!("{doc}");
        let parsed = Json::parse(&text).expect("self-parseable");
        assert_eq!(parsed.as_array().unwrap().len(), 2);
    }

    #[test]
    fn non_finite_widths_are_sanitized() {
        let spans = vec![Span {
            trace: TraceId(0),
            stage: Stage::Slice,
            tenant: Tenant::None,
            start_us: 0,
            end_us: 1,
            tid: 0,
            data: SpanData::Slice {
                index: 0,
                iterations: 1,
                width: f64::INFINITY,
            },
        }];
        let doc = chrome_trace(&spans);
        let text = format!("{doc}");
        assert!(Json::parse(&text).is_ok());
        let width = doc.as_array().unwrap()[0]
            .get("args")
            .unwrap()
            .get("interval_width")
            .and_then(Json::as_f64)
            .unwrap();
        assert_eq!(width, -1.0);
    }
}
