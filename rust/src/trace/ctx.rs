//! Thread-local trace propagation.
//!
//! Two contexts, both scoped by RAII guards that restore the previous
//! value on drop (so nested executors / re-entrant searches compose):
//!
//! - **Active**: "everything this thread does right now belongs to trace
//!   T". Set around a dispatcher's corpus search and re-set inside each
//!   `ShardedCorpus::run` worker (thread-locals do not cross scoped-thread
//!   spawns, so the seam is plumbed explicitly there).
//! - **Panel**: "this thread is solving an n-column panel whose columns
//!   belong to these (optional) traces". Set by
//!   `ShardedExecutor::solve_panel_outcomes_traced` on each worker with
//!   that worker's sub-slice of the batch. The budgeted drivers consume
//!   columns in order: `drive_budgeted` takes one per call (the per-pair
//!   default backend loop), `BatchSinkhorn::outcomes_paired` takes all n
//!   at once (the interleaved backend slices the whole panel together).
//!
//! When no panel is set, the budgeted drivers fall back to the active
//! context — that is how a retrieval refine's slice spans attribute to the
//! retrieval trace on the single-shard executor path.
//!
//! Everything here is `pub(crate)`: propagation is an implementation seam,
//! not API. The disabled-tracing path reads one thread-local `Option` and
//! branches — no timestamps, no allocation.

use std::cell::RefCell;
use std::sync::Arc;

use super::{Tenant, TraceId, TraceSink};

/// A (sink, trace, tenant) bundle: everything a deep call site needs to
/// record a span against the query that reached it.
#[derive(Clone)]
pub(crate) struct ActiveTrace {
    pub(crate) sink: Arc<TraceSink>,
    pub(crate) trace: TraceId,
    pub(crate) tenant: Tenant,
}

struct PanelCtx {
    sink: Arc<TraceSink>,
    tenant: Tenant,
    traces: Vec<Option<TraceId>>,
    cursor: usize,
}

thread_local! {
    static ACTIVE: RefCell<Option<ActiveTrace>> = const { RefCell::new(None) };
    static PANEL: RefCell<Option<PanelCtx>> = const { RefCell::new(None) };
}

/// Guard restoring the previous active context on drop.
pub(crate) struct ActiveGuard {
    prev: Option<ActiveTrace>,
}

/// Mark everything this thread does until the guard drops as belonging to
/// `ctx`'s trace.
pub(crate) fn set_active(ctx: ActiveTrace) -> ActiveGuard {
    let prev = ACTIVE.with(|c| c.borrow_mut().replace(ctx));
    ActiveGuard { prev }
}

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        ACTIVE.with(|c| *c.borrow_mut() = prev);
    }
}

/// The current thread's active trace, if any.
pub(crate) fn active() -> Option<ActiveTrace> {
    ACTIVE.with(|c| c.borrow().clone())
}

/// Guard restoring the previous panel context on drop.
pub(crate) struct PanelGuard {
    prev: Option<PanelCtx>,
}

/// Install per-column trace attribution for an n-column panel solve on
/// this thread. `traces[j]` is the trace (if sampled) owning column `j`.
pub(crate) fn set_panel(
    sink: Arc<TraceSink>,
    tenant: Tenant,
    traces: Vec<Option<TraceId>>,
) -> PanelGuard {
    let prev = PANEL.with(|c| {
        c.borrow_mut().replace(PanelCtx {
            sink,
            tenant,
            traces,
            cursor: 0,
        })
    });
    PanelGuard { prev }
}

impl Drop for PanelGuard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        PANEL.with(|c| *c.borrow_mut() = prev);
    }
}

/// Consume the next panel column's attribution (for `drive_budgeted`,
/// which solves one column per call). With a panel installed the column's
/// entry is authoritative — even when `None` (untraced column in a traced
/// batch). Without one, falls back to the thread's active trace.
pub(crate) fn next_column() -> Option<ActiveTrace> {
    let from_panel = PANEL.with(|c| {
        let mut b = c.borrow_mut();
        b.as_mut().map(|p| {
            let col = p.traces.get(p.cursor).copied().flatten();
            p.cursor += 1;
            col.map(|trace| ActiveTrace {
                sink: Arc::clone(&p.sink),
                trace,
                tenant: p.tenant,
            })
        })
    });
    match from_panel {
        Some(col) => col,
        None => active(),
    }
}

/// Consume `n` panel columns at once (for `BatchSinkhorn::outcomes_paired`,
/// which slices a whole panel together). Returns `None` when nothing in
/// the window is traced. Without a panel, falls back to the active trace
/// applied to all `n` columns.
#[allow(clippy::type_complexity)]
pub(crate) fn take_columns(n: usize) -> Option<(Arc<TraceSink>, Tenant, Vec<Option<TraceId>>)> {
    let from_panel = PANEL.with(|c| {
        let mut b = c.borrow_mut();
        b.as_mut().map(|p| {
            let cols: Vec<Option<TraceId>> = (0..n)
                .map(|i| p.traces.get(p.cursor + i).copied().flatten())
                .collect();
            p.cursor += n;
            (Arc::clone(&p.sink), p.tenant, cols)
        })
    });
    match from_panel {
        Some((sink, tenant, cols)) => cols
            .iter()
            .any(|c| c.is_some())
            .then_some((sink, tenant, cols)),
        None => active().map(|a| (a.sink, a.tenant, vec![Some(a.trace); n])),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceConfig;

    fn sink() -> Arc<TraceSink> {
        TraceSink::new(TraceConfig {
            sample_every: 1,
            ring_capacity: 16,
        })
    }

    #[test]
    fn active_guard_restores_previous_context() {
        assert!(active().is_none());
        let s = sink();
        {
            let _outer = set_active(ActiveTrace {
                sink: Arc::clone(&s),
                trace: TraceId(1),
                tenant: Tenant::Corpus(0),
            });
            assert_eq!(active().unwrap().trace, TraceId(1));
            {
                let _inner = set_active(ActiveTrace {
                    sink: Arc::clone(&s),
                    trace: TraceId(2),
                    tenant: Tenant::Corpus(0),
                });
                assert_eq!(active().unwrap().trace, TraceId(2));
            }
            assert_eq!(active().unwrap().trace, TraceId(1));
        }
        assert!(active().is_none());
    }

    #[test]
    fn next_column_walks_the_panel_in_order() {
        let s = sink();
        let _g = set_panel(
            Arc::clone(&s),
            Tenant::Metric(3),
            vec![Some(TraceId(10)), None, Some(TraceId(12))],
        );
        assert_eq!(next_column().unwrap().trace, TraceId(10));
        assert!(next_column().is_none()); // untraced column, NOT a fallback
        assert_eq!(next_column().unwrap().trace, TraceId(12));
        assert!(next_column().is_none()); // past the end
    }

    #[test]
    fn take_columns_consumes_a_window() {
        let s = sink();
        let _g = set_panel(
            Arc::clone(&s),
            Tenant::Metric(0),
            vec![Some(TraceId(1)), None, None, Some(TraceId(4))],
        );
        let (_, _, first) = take_columns(2).unwrap();
        assert_eq!(first, vec![Some(TraceId(1)), None]);
        // Second window holds only an untraced column + one traced.
        let (_, _, second) = take_columns(2).unwrap();
        assert_eq!(second, vec![None, Some(TraceId(4))]);
        assert!(take_columns(2).is_none());
    }

    #[test]
    fn budgeted_drivers_fall_back_to_active_without_a_panel() {
        let s = sink();
        let _g = set_active(ActiveTrace {
            sink: Arc::clone(&s),
            trace: TraceId(7),
            tenant: Tenant::Corpus(1),
        });
        assert_eq!(next_column().unwrap().trace, TraceId(7));
        let (_, tenant, cols) = take_columns(3).unwrap();
        assert_eq!(tenant, Tenant::Corpus(1));
        assert_eq!(cols, vec![Some(TraceId(7)); 3]);
    }

    #[test]
    fn panel_overrides_active_even_for_untraced_columns() {
        let s = sink();
        let _a = set_active(ActiveTrace {
            sink: Arc::clone(&s),
            trace: TraceId(9),
            tenant: Tenant::Corpus(0),
        });
        let _p = set_panel(Arc::clone(&s), Tenant::Metric(0), vec![None]);
        assert!(next_column().is_none());
    }
}
