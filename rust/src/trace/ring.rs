//! Bounded per-thread span ring buffers.
//!
//! Each worker thread that records spans into a [`TraceSink`] gets its own
//! ring: a `Mutex<VecDeque<Span>>` that the *owner thread* only ever
//! touches through `try_lock`. The only other party is the collector, which
//! drains with a blocking lock. A worker therefore never blocks on
//! recording: if the collector happens to hold the lock, the span is
//! dropped and counted; if the ring is full, the oldest span is dropped and
//! counted. Poisoned locks are recovered via `into_inner` — a panicking
//! worker must not wedge observability for everyone else.
//!
//! The buffer grows on demand up to `capacity` rather than being
//! preallocated: shard/panel workers are fresh scoped threads per solve,
//! and a ring that only ever holds a handful of spans must not pin
//! `capacity * size_of::<Span>()` (~300 KB at the default 4096).
//!
//! When its owner thread exits, the thread-local cache guard marks the
//! ring [`retired`](ThreadRing::retire); the collector drains any
//! remaining spans and then drops the ring, so long-running services with
//! short-lived worker threads hold only as many rings as there are *live*
//! recording threads.
//!
//! [`TraceSink`]: super::TraceSink

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, TryLockError};

use super::Span;

pub(crate) struct ThreadRing {
    tid: u64,
    capacity: usize,
    retired: AtomicBool,
    buf: Mutex<VecDeque<Span>>,
}

impl ThreadRing {
    pub(crate) fn new(tid: u64, capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Self {
            tid,
            capacity,
            retired: AtomicBool::new(false),
            // Lazy: grows geometrically under push up to `capacity`.
            buf: Mutex::new(VecDeque::new()),
        }
    }

    /// Stable per-sink thread label, stamped into every span's `tid`.
    pub(crate) fn tid(&self) -> u64 {
        self.tid
    }

    /// Owner-thread exit: no further pushes will ever happen. Release
    /// pairs with the Acquire in [`Self::is_retired`] so a collector that
    /// observes the flag also observes every prior push.
    pub(crate) fn retire(&self) {
        self.retired.store(true, Ordering::Release);
    }

    /// True once the owner thread has exited. A collector that reads
    /// `true` *before* draining may free the ring afterwards: the drain is
    /// guaranteed to capture every span the owner ever pushed.
    pub(crate) fn is_retired(&self) -> bool {
        self.retired.load(Ordering::Acquire)
    }

    /// Push a span without ever blocking. Returns the number of spans
    /// dropped by this call: 0 on a clean push, 1 when the ring was full
    /// (oldest evicted) or the collector held the lock (this span lost).
    pub(crate) fn push(&self, span: Span) -> u64 {
        let mut q = match self.buf.try_lock() {
            Ok(q) => q,
            Err(TryLockError::Poisoned(p)) => p.into_inner(),
            Err(TryLockError::WouldBlock) => return 1,
        };
        let mut dropped = 0;
        if q.len() >= self.capacity {
            q.pop_front();
            dropped = 1;
        }
        q.push_back(span);
        dropped
    }

    /// Collector side: drain everything, blocking until the owner thread's
    /// in-flight `try_lock` (if any) releases.
    pub(crate) fn drain(&self) -> Vec<Span> {
        let mut q = self.buf.lock().unwrap_or_else(|p| p.into_inner());
        q.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{SpanData, Stage, Tenant, TraceId};

    fn span(start: u64) -> Span {
        Span {
            trace: TraceId(0),
            stage: Stage::Solve,
            tenant: Tenant::None,
            start_us: start,
            end_us: start + 1,
            tid: 0,
            data: SpanData::None,
        }
    }

    #[test]
    fn ring_drops_oldest_when_full() {
        let ring = ThreadRing::new(7, 3);
        let mut dropped = 0;
        for i in 0..5 {
            dropped += ring.push(span(i));
        }
        assert_eq!(dropped, 2);
        let kept: Vec<u64> = ring.drain().iter().map(|s| s.start_us).collect();
        assert_eq!(kept, vec![2, 3, 4]);
    }

    #[test]
    fn drain_empties_the_ring() {
        let ring = ThreadRing::new(0, 8);
        assert_eq!(ring.push(span(1)), 0);
        assert_eq!(ring.drain().len(), 1);
        assert!(ring.drain().is_empty());
    }

    #[test]
    fn retirement_is_sticky_and_pushes_still_drain() {
        let ring = ThreadRing::new(1, 8);
        assert!(!ring.is_retired());
        ring.push(span(1));
        ring.retire();
        assert!(ring.is_retired());
        // Spans pushed before retirement survive until a drain.
        assert_eq!(ring.drain().len(), 1);
        assert!(ring.is_retired());
    }
}
