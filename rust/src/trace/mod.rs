//! End-to-end query tracing (PR 9): span-structured latency decomposition.
//!
//! The serving stack hands a query across five asynchronous seams —
//! batcher → engine → executor shard workers → dispatcher mailboxes →
//! per-shard cascade + refine — and until now `StatsSnapshot` only exposed
//! marginal aggregates. This module mints a [`TraceId`] per sampled
//! query/retrieval, threads it through every seam, and records typed
//! [`Span`]s (batch size, mailbox wait, cascade tier, per-`CERT_STRIDE`
//! interval width, warm hits, rescues) into bounded per-thread ring
//! buffers. A collector folds the spans into per-stage log2 histograms
//! (the `stage_breakdown` section of `StatsSnapshot`) and retains sampled
//! full traces for export as Chrome trace-event JSON
//! ([`chrome_trace`]) — one query renders as a flame graph in Perfetto.
//!
//! ## Zero-overhead contract
//!
//! Tracing is **off by default**. Every instrumentation site branches on an
//! `Option`-typed handle (`Option<Arc<TraceSink>>` at the coordinator,
//! `Option<TraceId>` per job, thread-local contexts further down): with
//! `TraceConfig` unset there are no timestamp reads and no allocations on
//! the hot path, so all PR 1–8 bit-identity and latency contracts are
//! untouched. Recording never blocks a worker: rings are pushed via
//! `try_lock` with drop-oldest overflow and a [`TraceSink::dropped`]
//! counter.
//!
//! ## Span taxonomy
//!
//! Distance path: `query` (root, enqueue → respond) ⊃ `batcher` (enqueue →
//! solve start, payload batch size / full-trigger) + `solve` (panel solve,
//! payload warm hits/misses, shed) ⊃ `slice` (one per budgeted
//! `CERT_STRIDE` slice, payload iterations + certified interval width).
//!
//! Retrieval path: `retrieve` (root) ⊃ `mailbox` (dispatcher queue wait) +
//! `search` (corpus walk) ⊃ `shard` (per-shard walk) ⊃ `cascade` (bound
//! pricing, payload tier reached) + `refine` (panel re-rank, payload warm
//! seeds / rescues) ⊃ `slice`.

pub(crate) mod ctx;
mod export;
mod ring;

pub use export::chrome_trace;

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::histogram::Log2Histogram;
use crate::util::saturating_micros;
use ring::ThreadRing;

/// How many collected spans the sink retains for export (drop-oldest).
const RETAINED_SPANS: usize = 8192;

/// Sampling + buffering knobs, set via
/// `CoordinatorConfigBuilder::trace(..)`. Default **off** (the config field
/// is an `Option`); `TraceConfig::default()` samples every 64th query with
/// 4096-span per-thread rings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceConfig {
    /// Mint a `TraceId` for every `sample_every`-th query (1 = every
    /// query). Must be ≥ 1.
    pub sample_every: u64,
    /// Capacity of each per-thread span ring buffer. Must be ≥ 1.
    pub ring_capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            sample_every: 64,
            ring_capacity: 4096,
        }
    }
}

impl TraceConfig {
    /// Validate the knobs; mirrors `CoordinatorConfig::validate` style.
    pub fn validate(&self) -> Result<(), String> {
        if self.sample_every == 0 {
            return Err("trace.sample_every must be >= 1".into());
        }
        if self.ring_capacity == 0 {
            return Err("trace.ring_capacity must be >= 1".into());
        }
        Ok(())
    }
}

/// Identity of one sampled query, stable across every span it produces.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TraceId(pub u64);

/// Which pipeline stage a span covers. `name()` is the stable label used
/// in `stage_breakdown` rows and Chrome trace events.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Stage {
    /// Root span of a distance query: enqueue → response.
    Query,
    /// Time spent waiting in the `PendingBatcher` before the panel solved.
    Batcher,
    /// The panel solve itself (executor dispatch included).
    Solve,
    /// One budgeted `CERT_STRIDE` slice inside a solve/refine.
    Slice,
    /// Root span of a retrieval: enqueue → callback.
    Retrieve,
    /// Dispatcher mailbox wait (PR 8 queue).
    Mailbox,
    /// The corpus search walk (all shards).
    Search,
    /// One shard's cascade + refine walk.
    Shard,
    /// Bound-cascade pricing within a shard.
    Cascade,
    /// Panel re-ranking of straddlers within a shard.
    Refine,
}

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Query => "query",
            Stage::Batcher => "batcher",
            Stage::Solve => "solve",
            Stage::Slice => "slice",
            Stage::Retrieve => "retrieve",
            Stage::Mailbox => "mailbox",
            Stage::Search => "search",
            Stage::Shard => "shard",
            Stage::Cascade => "cascade",
            Stage::Refine => "refine",
        }
    }
}

/// Which tenant a span is attributed to: the metric id for distance
/// queries, the corpus id for retrieval. Keys the per-tenant
/// `stage_breakdown` rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Tenant {
    None,
    Metric(u32),
    Corpus(u32),
}

impl Tenant {
    pub fn label(self) -> String {
        match self {
            Tenant::None => "-".into(),
            Tenant::Metric(m) => format!("m{m}"),
            Tenant::Corpus(c) => format!("c{c}"),
        }
    }
}

/// Typed span payload — the "why was this slow" detail next to the timing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SpanData {
    None,
    /// Batcher exit: how big the batch was and whether the size trigger
    /// (rather than the deadline/drain path) released it.
    Batch { size: usize, full: bool },
    /// Panel solve: warm-start hits/misses across shard workers, and
    /// whether load shedding capped the budget.
    Solve {
        batch: usize,
        warm_hits: usize,
        warm_misses: usize,
        shed: bool,
    },
    /// Dispatcher mailbox wait as measured by the PR 8 feedback channel.
    Mailbox { queued_us: u64 },
    /// Whole-corpus search: result count and whether the ANN router
    /// shortlisted (vs exact full walk).
    Search {
        hits: usize,
        routed: bool,
        rescued: usize,
    },
    /// One shard's walk: panel columns solved and cascade-pruned count.
    Shard {
        shard: usize,
        solved: usize,
        pruned: usize,
    },
    /// Cascade pricing: deepest bound tier consulted and candidates
    /// priced. The candidate set *is* the router shortlist when routed
    /// (the `Search` span's `routed` flag says which), so there is no
    /// separate shortlist count to carry.
    Cascade { tier: u8, priced: usize },
    /// Refine: straddler panel size, warm-seeded columns, rescue count.
    Refine {
        panels: usize,
        warm_seeded: usize,
        rescued: usize,
    },
    /// One budgeted `CERT_STRIDE` slice: slice ordinal, Sinkhorn iterations
    /// it ran, and the certified `ErrorInterval` width after intersecting
    /// its certificate.
    Slice {
        index: usize,
        iterations: usize,
        width: f64,
    },
}

/// One recorded interval. Timestamps are microseconds since the sink's
/// epoch (monotonic, via `Instant`); `tid` is a small per-sink thread
/// ordinal assigned at first record.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub trace: TraceId,
    pub stage: Stage,
    pub tenant: Tenant,
    pub start_us: u64,
    pub end_us: u64,
    pub tid: u64,
    pub data: SpanData,
}

impl Span {
    pub fn duration_us(&self) -> u64 {
        self.end_us.saturating_sub(self.start_us)
    }
}

/// One row of the `stage_breakdown` section: clamped log2-histogram
/// quantiles of span duration, keyed by (stage, tenant).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageRow {
    pub stage: &'static str,
    pub tenant: String,
    pub count: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

/// Per-column trace attribution for an anytime panel solve, handed to
/// `ShardedExecutor::solve_panel_outcomes_traced`: `traces[j]` owns panel
/// column `j` (untraced columns are `None`). The executor re-installs the
/// matching sub-slice as the panel context on each shard worker, because
/// thread-locals do not cross scoped-thread spawns.
pub struct PanelTrace {
    pub sink: Arc<TraceSink>,
    pub tenant: Tenant,
    pub traces: Vec<Option<TraceId>>,
}

#[derive(Default)]
struct Collected {
    stages: BTreeMap<(Stage, Tenant), Log2Histogram>,
    spans: VecDeque<Span>,
    span_total: u64,
}

/// The shared tracing sink: mints sampled `TraceId`s, owns the per-thread
/// rings, and folds drained spans into stage histograms + a bounded export
/// buffer. One sink per `DistanceService`; every handle is an
/// `Arc<TraceSink>` and the disabled path is simply `None`.
pub struct TraceSink {
    id: u64,
    epoch: Instant,
    sample_every: u64,
    ring_capacity: usize,
    minted: AtomicU64,
    sampled: AtomicU64,
    dropped: AtomicU64,
    next_tid: AtomicU64,
    rings: Mutex<Vec<Arc<ThreadRing>>>,
    collected: Mutex<Collected>,
}

/// Distinguishes sinks in the per-thread ring cache (a service restart in
/// the same process must not reuse another sink's rings).
static NEXT_SINK_ID: AtomicU64 = AtomicU64::new(1);

/// One thread-local cache entry: the owning thread's ring for one sink.
/// Dropping the entry — thread exit or cache eviction — retires the ring,
/// which licenses the sink's collector to drain the remaining spans and
/// free it. Without this, every short-lived scoped worker (shard/panel
/// threads are fresh per solve) would pin a ring in the sink forever.
struct CachedRing {
    sink_id: u64,
    ring: Arc<ThreadRing>,
}

impl Drop for CachedRing {
    fn drop(&mut self) {
        self.ring.retire();
    }
}

thread_local! {
    /// Per-thread cache of (sink id → ring). Entries are retire-on-drop
    /// guards: thread exit hands the ring back to the sink for a final
    /// drain + prune (see [`TraceSink::collect`]).
    static THREAD_RINGS: std::cell::RefCell<Vec<CachedRing>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

impl TraceSink {
    pub fn new(config: TraceConfig) -> Arc<Self> {
        Arc::new(Self {
            id: NEXT_SINK_ID.fetch_add(1, Ordering::Relaxed),
            epoch: Instant::now(),
            sample_every: config.sample_every.max(1),
            ring_capacity: config.ring_capacity.max(1),
            minted: AtomicU64::new(0),
            sampled: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            next_tid: AtomicU64::new(0),
            rings: Mutex::new(Vec::new()),
            collected: Mutex::new(Collected::default()),
        })
    }

    /// Sampling decision for the next query: every `sample_every`-th call
    /// mints a `TraceId` (so `sample_every == 1` traces everything).
    pub fn sample(&self) -> Option<TraceId> {
        let n = self.minted.fetch_add(1, Ordering::Relaxed);
        if n % self.sample_every == 0 {
            self.sampled.fetch_add(1, Ordering::Relaxed);
            Some(TraceId(n))
        } else {
            None
        }
    }

    /// Microseconds since the sink's epoch, read now.
    pub fn now_us(&self) -> u64 {
        saturating_micros(self.epoch.elapsed())
    }

    /// Microseconds since the sink's epoch for an `Instant` captured
    /// earlier (saturates to 0 for instants predating the sink).
    pub fn instant_us(&self, t: Instant) -> u64 {
        saturating_micros(t.saturating_duration_since(self.epoch))
    }

    /// Record a span into this thread's ring. Never blocks: lock
    /// contention or ring overflow drop spans and bump the counter. The
    /// span's `tid` is overwritten with the recording thread's ordinal.
    pub fn record(&self, mut span: Span) {
        let dropped = THREAD_RINGS.with(|cell| {
            let mut cache = cell.borrow_mut();
            let ring = match cache.iter().find(|c| c.sink_id == self.id) {
                Some(c) => Arc::clone(&c.ring),
                None => {
                    let tid = self.next_tid.fetch_add(1, Ordering::Relaxed);
                    let ring = Arc::new(ThreadRing::new(tid, self.ring_capacity));
                    self.rings
                        .lock()
                        .unwrap_or_else(|p| p.into_inner())
                        .push(Arc::clone(&ring));
                    // Evict entries whose sink died (only this thread's
                    // guard still holds the ring): dropping them retires
                    // the orphaned ring so it frees immediately.
                    cache.retain(|c| Arc::strong_count(&c.ring) > 1);
                    cache.push(CachedRing {
                        sink_id: self.id,
                        ring: Arc::clone(&ring),
                    });
                    ring
                }
            };
            span.tid = ring.tid();
            ring.push(span)
        });
        if dropped > 0 {
            self.dropped.fetch_add(dropped, Ordering::Relaxed);
        }
    }

    /// Drain every thread ring and fold the spans into the stage
    /// histograms + the bounded export buffer; rings whose owner thread
    /// has exited are dropped after their final drain, so ring memory is
    /// bounded by *live* recording threads, not by every worker thread
    /// ever spawned. Called by the readers (`stage_rows`,
    /// `sampled_spans`); safe from any thread.
    pub fn collect(&self) {
        let rings: Vec<Arc<ThreadRing>> = self
            .rings
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .clone();
        let mut dead: Vec<u64> = Vec::new();
        {
            let mut c = self.collected.lock().unwrap_or_else(|p| p.into_inner());
            for ring in rings {
                // Order matters: only a ring observed retired *before* its
                // drain may be pruned — retirement happens-after the
                // owner's last push, so the drain captured everything.
                let retired = ring.is_retired();
                for span in ring.drain() {
                    c.stages
                        .entry((span.stage, span.tenant))
                        .or_default()
                        .record(span.duration_us());
                    if c.spans.len() >= RETAINED_SPANS {
                        c.spans.pop_front();
                    }
                    c.spans.push_back(span);
                    c.span_total += 1;
                }
                if retired {
                    dead.push(ring.tid());
                }
            }
        }
        if !dead.is_empty() {
            self.rings
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .retain(|r| !dead.contains(&r.tid()));
        }
    }

    /// Rings currently held by the sink (live threads + retired rings not
    /// yet swept by [`Self::collect`]).
    #[cfg(test)]
    fn ring_count(&self) -> usize {
        self.rings.lock().unwrap_or_else(|p| p.into_inner()).len()
    }

    /// The `stage_breakdown` rows: per (stage, tenant) clamped p50/p99/max
    /// of span duration, sorted by stage then tenant.
    pub fn stage_rows(&self) -> Vec<StageRow> {
        self.collect();
        let c = self.collected.lock().unwrap_or_else(|p| p.into_inner());
        c.stages
            .iter()
            .map(|((stage, tenant), h)| StageRow {
                stage: stage.name(),
                tenant: tenant.label(),
                count: h.count(),
                p50_us: h.quantile(0.5),
                p99_us: h.quantile(0.99),
                max_us: h.observed_max(),
            })
            .collect()
    }

    /// Full per-(stage, tenant) duration histograms, sorted by stage then
    /// tenant. The PR 10 telemetry exporter renders these as cumulative
    /// `le`-bucketed Prometheus series, which needs the raw buckets, not
    /// the pre-digested [`StageRow`] quantiles.
    pub fn stage_histograms(&self) -> Vec<((&'static str, Tenant), Log2Histogram)> {
        self.collect();
        let c = self.collected.lock().unwrap_or_else(|p| p.into_inner());
        c.stages
            .iter()
            .map(|((stage, tenant), h)| ((stage.name(), *tenant), h.clone()))
            .collect()
    }

    /// All retained sampled spans (most recent `RETAINED_SPANS`), oldest
    /// first. Feed a per-trace subset to [`chrome_trace`] for Perfetto.
    pub fn sampled_spans(&self) -> Vec<Span> {
        self.collect();
        let c = self.collected.lock().unwrap_or_else(|p| p.into_inner());
        c.spans.iter().copied().collect()
    }

    /// Retained spans belonging to one trace, oldest first.
    pub fn trace_spans(&self, trace: TraceId) -> Vec<Span> {
        self.sampled_spans()
            .into_iter()
            .filter(|s| s.trace == trace)
            .collect()
    }

    /// Total queries that passed the sampling gate.
    pub fn sampled(&self) -> u64 {
        self.sampled.load(Ordering::Relaxed)
    }

    /// Total spans folded by the collector (including ones since evicted
    /// from the bounded export buffer).
    pub fn span_count(&self) -> u64 {
        self.collect();
        self.collected
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .span_total
    }

    /// Spans lost to ring overflow or worker-side lock contention.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(sink: &TraceSink, trace: u64, stage: Stage, start: u64, end: u64) -> Span {
        let _ = sink; // spans are plain data; the sink stamps tid on record
        Span {
            trace: TraceId(trace),
            stage,
            tenant: Tenant::Metric(0),
            start_us: start,
            end_us: end,
            tid: 0,
            data: SpanData::None,
        }
    }

    #[test]
    fn sampling_mints_every_nth() {
        let sink = TraceSink::new(TraceConfig {
            sample_every: 3,
            ring_capacity: 16,
        });
        let minted: Vec<Option<TraceId>> = (0..7).map(|_| sink.sample()).collect();
        assert_eq!(
            minted,
            vec![
                Some(TraceId(0)),
                None,
                None,
                Some(TraceId(3)),
                None,
                None,
                Some(TraceId(6)),
            ]
        );
        assert_eq!(sink.sampled(), 3);
    }

    #[test]
    fn recorded_spans_fold_into_stage_rows() {
        let sink = TraceSink::new(TraceConfig {
            sample_every: 1,
            ring_capacity: 64,
        });
        sink.record(span(&sink, 0, Stage::Solve, 10, 110));
        sink.record(span(&sink, 0, Stage::Solve, 10, 1010));
        sink.record(span(&sink, 0, Stage::Batcher, 0, 10));
        let rows = sink.stage_rows();
        assert_eq!(rows.len(), 2);
        let solve = rows.iter().find(|r| r.stage == "solve").unwrap();
        assert_eq!(solve.count, 2);
        assert_eq!(solve.tenant, "m0");
        assert_eq!(solve.max_us, 1000);
        // Clamped quantiles: p50 bucket edge 128, p99 clamped to max 1000.
        assert_eq!(solve.p50_us, 128);
        assert_eq!(solve.p99_us, 1000);
        assert_eq!(sink.span_count(), 3);
        assert_eq!(sink.dropped(), 0);
    }

    #[test]
    fn ring_overflow_counts_dropped_spans() {
        let sink = TraceSink::new(TraceConfig {
            sample_every: 1,
            ring_capacity: 2,
        });
        for i in 0..5 {
            sink.record(span(&sink, 0, Stage::Slice, i, i + 1));
        }
        assert_eq!(sink.dropped(), 3);
        assert_eq!(sink.sampled_spans().len(), 2);
    }

    #[test]
    fn spans_from_worker_threads_get_distinct_tids() {
        let sink = TraceSink::new(TraceConfig {
            sample_every: 1,
            ring_capacity: 64,
        });
        sink.record(span(&sink, 0, Stage::Query, 0, 5));
        std::thread::scope(|scope| {
            scope.spawn(|| sink.record(span(&sink, 0, Stage::Shard, 1, 2)));
            scope.spawn(|| sink.record(span(&sink, 0, Stage::Shard, 2, 3)));
        });
        let spans = sink.sampled_spans();
        assert_eq!(spans.len(), 3);
        let mut tids: Vec<u64> = spans.iter().map(|s| s.tid).collect();
        tids.sort_unstable();
        tids.dedup();
        assert_eq!(tids.len(), 3);
    }

    #[test]
    fn dead_thread_rings_are_flushed_then_pruned() {
        let sink = TraceSink::new(TraceConfig {
            sample_every: 1,
            ring_capacity: 64,
        });
        // Fresh scoped workers per solve is the serving-stack shape that
        // used to leak one ring per thread forever.
        for round in 0..3 {
            std::thread::scope(|scope| {
                for w in 0..4 {
                    let sink = &sink;
                    scope.spawn(move || {
                        sink.record(span(sink, 0, Stage::Shard, round, round + w + 1));
                    });
                }
            });
        }
        // The 12 worker threads are gone; their spans must survive the
        // exit (flushed on the next collect), and their rings must not.
        assert_eq!(sink.sampled_spans().len(), 12);
        assert_eq!(sink.ring_count(), 0);
        assert_eq!(sink.dropped(), 0);
        // A live thread's ring stays resident across collects.
        sink.record(span(&sink, 0, Stage::Query, 0, 5));
        sink.collect();
        assert_eq!(sink.ring_count(), 1);
        assert_eq!(sink.span_count(), 13);
    }

    #[test]
    fn trace_config_validation() {
        assert!(TraceConfig::default().validate().is_ok());
        assert!(TraceConfig {
            sample_every: 0,
            ring_capacity: 8
        }
        .validate()
        .is_err());
        assert!(TraceConfig {
            sample_every: 1,
            ring_capacity: 0
        }
        .validate()
        .is_err());
    }
}
