//! Kernel operators — the Gibbs kernel K = e^{−λM} as a *linear
//! operator* rather than a dense matrix.
//!
//! Every Sinkhorn iteration only ever needs K through four operations:
//! `K·x`, `Kᵀ·x`, their panel (column-stacked) forms, and the final
//! transport-cost read-off Σ u_i K_ij m_ij v_j. The [`KernelOp`] trait
//! captures exactly that contract, which frees the solvers from the
//! dense `d×d` representation and unlocks the two structures the
//! literature exploits at scale:
//!
//! * [`SparseKernel`] — CSR truncation. At serving-scale λ most entries
//!   of e^{−λM} are negligibly small (Altschuler, Weed & Rigollet 2017
//!   reach near-linear time on exactly this observation); entries below
//!   `threshold`·(row max) are dropped, with the per-row relative
//!   dropped mass tracked and reported as [`KernelOp::mass_loss`].
//! * [`LowRankKernel`] — a pivoted-Cholesky factorization K ≈ L·Lᵀ
//!   (Motamed 2020 style): rank grows greedily on the largest residual
//!   diagonal until the trace residual falls below a tolerance, so the
//!   per-iteration cost drops from O(d²) to O(d·rank).
//!
//! [`DenseKernel`] wraps the classic row-major K/Kᵀ pair at zero cost —
//! its `apply*` loops are bit-identical to the historical solver inner
//! loops, so rewiring the engines through the trait changed no numbers.
//! [`KernelPolicy`] is the construction-side knob threaded through
//! `SinkhornConfig` → `CoordinatorConfig` → `ShardedExecutor`.

use super::{dot, pivoted_cholesky, Matrix};
use crate::F;

/// Default relative truncation threshold for [`KernelPolicy::Truncated`]
/// when a backend forces truncation without an explicit policy
/// (entries below threshold·row-max are dropped; the row max is 1 for
/// any zero-diagonal metric).
pub const DEFAULT_TRUNCATION_THRESHOLD: F = 1e-6;

/// Default relative trace tolerance for [`KernelPolicy::LowRank`]: rank
/// grows until the pivoted-Cholesky trace residual drops below
/// tolerance·trace(K).
pub const DEFAULT_LOWRANK_TOLERANCE: F = 1e-9;

/// Safety radius of the truncation cut, in units of the median
/// off-diagonal ground cost: whatever the value threshold asks, entries
/// with m_ij ≤ radius·median(M) are always kept. Without this floor a
/// fixed value threshold at serving-scale λ reduces e^{−λM} to its
/// diagonal — the off-diagonal mass is negligible *as mass* but
/// load-bearing *as transport routes*, and a route-free kernel makes
/// every r ≠ c infeasible. Below the median radius the kept entry count
/// stays strictly under half the dense count.
pub const TRUNCATION_SAFE_RADIUS: F = 0.9;

/// `d·λ` above which [`KernelPolicy::Auto`] (and `BackendKind::auto`)
/// consider truncation profitable: past this product the kernel has
/// enough sub-threshold entries that CSR streaming beats the dense
/// sweep. Calibrated on the paper's λ-quantile workloads (λ ∈ {50, 100}
/// at d ≥ 128 sit well above; the d ≤ 64, λ ≤ 20 bench grid well
/// below). Applied together with [`AUTO_SPARSITY_LAMBDA_MEDIAN`] — the
/// d·λ product alone is metric-scale-blind.
pub const AUTO_SPARSITY_DLAMBDA: F = 4096.0;

/// `λ·median(M)` above which truncation actually bites: past this
/// point the default value threshold falls below the safety-radius cut
/// e^{−λ·0.9·median}, so the truncated kernel reaches its full
/// ~30–45% density. Below it (e.g. a metric with costs ≪ 1/λ) the
/// default threshold drops little or nothing and CSR streaming would
/// only add index overhead, so the auto router stays dense. The value
/// is ln(1e-6⁻¹)/0.9 ≈ 15.3, rounded up.
pub const AUTO_SPARSITY_LAMBDA_MEDIAN: F = 16.0;

/// Structure report of a kernel operator: what one worker actually holds
/// and streams per iteration. Flows through `ShardReport` and the
/// coordinator metrics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelStats {
    /// Histogram dimension d the operator acts on.
    pub dim: usize,
    /// Entries streamed by one `apply` (d² dense, stored entries for
    /// CSR, 2·d·rank for a factored kernel) — the per-iteration flop
    /// proxy: one iteration costs ~2·nnz multiply-adds per solve pass.
    pub nnz: usize,
    /// Factorization rank (d for unfactored kernels).
    pub rank: usize,
    /// Worst-case per-row relative kernel mass discarded by the
    /// approximation (0 for the dense kernel): truncation reports the
    /// max over rows of dropped/total row mass, the low-rank kernel its
    /// relative trace residual.
    pub mass_loss: F,
    /// Upper bound on ‖K − K̃‖_F (0 when exact).
    pub frobenius_budget: F,
}

impl KernelStats {
    /// The stats of an exact dense kernel of dimension d.
    pub fn dense(d: usize) -> Self {
        Self { dim: d, nnz: d * d, rank: d, mass_loss: 0.0, frobenius_budget: 0.0 }
    }

    /// Fraction of the dense entry count this operator streams per
    /// apply (1.0 = no savings).
    pub fn density(&self) -> F {
        if self.dim == 0 {
            return 1.0;
        }
        self.nnz as F / (self.dim * self.dim) as F
    }
}

/// How solvers materialize the Gibbs kernel K = e^{−λM}. `Copy` so it
/// threads through `SinkhornConfig` like every other solver knob.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum KernelPolicy {
    /// Full dense K and Kᵀ (the classic path; exact).
    #[default]
    Dense,
    /// CSR truncation: drop entries below `threshold`·(row max).
    /// `threshold = 0` keeps every representable entry and reproduces
    /// the dense iteration bit-for-bit.
    Truncated {
        /// Relative drop threshold in [0, 1).
        threshold: F,
    },
    /// Pivoted-Cholesky factorization K ≈ L·Lᵀ. Rank grows until the
    /// trace residual falls below `tolerance`·trace(K) or `max_rank`
    /// columns (0 = uncapped) are built; `tolerance = 0` with an
    /// uncapped rank factors to numerical full rank, reproducing the
    /// dense kernel to machine precision.
    LowRank {
        /// Hard rank cap (0 = up to d).
        max_rank: usize,
        /// Relative trace-residual stopping tolerance.
        tolerance: F,
    },
    /// Resolve per (d, λ): truncated once d·λ crosses
    /// [`AUTO_SPARSITY_DLAMBDA`], dense otherwise.
    Auto,
}

impl KernelPolicy {
    /// Truncation at the default threshold.
    pub fn truncated_default() -> Self {
        KernelPolicy::Truncated { threshold: DEFAULT_TRUNCATION_THRESHOLD }
    }

    /// Low-rank factorization at the default trace tolerance, uncapped.
    pub fn low_rank_default() -> Self {
        KernelPolicy::LowRank { max_rank: 0, tolerance: DEFAULT_LOWRANK_TOLERANCE }
    }

    /// Pick the representation for a `max_bytes`-per-worker budget at
    /// dimension d: dense when the classic K/Kᵀ pair (2·d²·8 bytes)
    /// fits, default truncation otherwise. Each `ShardedExecutor`
    /// worker owns one kernel instance, so the executor footprint is
    /// `workers × kernel`. Best-effort, not a hard cap: truncation
    /// shrinks the kernel to its achieved nnz (~30–45% of d² on the
    /// benchmark metrics — [`TRUNCATION_SAFE_RADIUS`] deliberately
    /// keeps every below-median-radius entry, so no threshold can
    /// squeeze an arbitrary budget); check the executor's
    /// `kernel_stats().nnz` when the budget is strict.
    pub fn capped(d: usize, max_bytes: usize) -> Self {
        let dense_bytes = 2 * d * d * std::mem::size_of::<F>();
        if dense_bytes <= max_bytes {
            KernelPolicy::Dense
        } else {
            Self::truncated_default()
        }
    }

    /// Collapse [`KernelPolicy::Auto`] to a concrete policy for the
    /// row-major d×d ground metric `m` at λ; concrete policies return
    /// themselves. Truncation is picked only when it is both *worth
    /// amortizing* (d·λ ≥ [`AUTO_SPARSITY_DLAMBDA`]) and *actually
    /// sparse on this metric's scale*
    /// (λ·median(M) ≥ [`AUTO_SPARSITY_LAMBDA_MEDIAN`]).
    pub fn resolve(&self, m: &[F], d: usize, lambda: F) -> KernelPolicy {
        match *self {
            KernelPolicy::Auto => {
                if d as F * lambda >= AUTO_SPARSITY_DLAMBDA
                    && lambda * median_off_diagonal(m, d)
                        >= AUTO_SPARSITY_LAMBDA_MEDIAN
                {
                    Self::truncated_default()
                } else {
                    KernelPolicy::Dense
                }
            }
            other => other,
        }
    }

    /// Build the operator for K = e^{−λM} over the row-major d×d ground
    /// metric `m`. A low-rank factorization that achieves rank 0 (K
    /// numerically indefinite from entry one — only possible on a
    /// non-PSD kernel) falls back to the dense operator rather than
    /// returning an unusable zero operator.
    pub fn build(&self, m: &[F], d: usize, lambda: F) -> Box<dyn KernelOp> {
        assert_eq!(m.len(), d * d, "kernel build: metric/shape mismatch");
        assert!(lambda > 0.0, "kernel build: lambda must be positive");
        match self.resolve(m, d, lambda) {
            KernelPolicy::Dense => Box::new(DenseKernel::build(m, d, lambda)),
            KernelPolicy::Truncated { threshold } => {
                assert!(
                    (0.0..1.0).contains(&threshold),
                    "truncation threshold must be in [0, 1)"
                );
                Box::new(SparseKernel::build(m, d, lambda, threshold))
            }
            KernelPolicy::LowRank { max_rank, tolerance } => {
                assert!(tolerance >= 0.0, "low-rank tolerance must be >= 0");
                match LowRankKernel::build(m, d, lambda, max_rank, tolerance) {
                    Some(k) => Box::new(k),
                    None => Box::new(DenseKernel::build(m, d, lambda)),
                }
            }
            KernelPolicy::Auto => unreachable!("resolve() returns concrete policies"),
        }
    }
}

/// The Gibbs kernel as a linear operator: everything a Sinkhorn-family
/// solver needs from K = e^{−λM}, without committing to a dense d×d
/// representation. Panels are (d, n) row-major column stacks, matching
/// the batch solvers' layout.
pub trait KernelOp: Send + Sync {
    /// Histogram dimension d (operators are square).
    fn dim(&self) -> usize;

    /// out = K·x.
    fn apply(&self, x: &[F], out: &mut [F]);

    /// out = Kᵀ·x.
    fn apply_transpose(&self, x: &[F], out: &mut [F]);

    /// Panel form of [`Self::apply`]: X and OUT are (d, n) row-major.
    fn apply_panel(&self, x: &[F], out: &mut [F], n: usize);

    /// Panel form of [`Self::apply_transpose`].
    fn apply_transpose_panel(&self, x: &[F], out: &mut [F], n: usize);

    /// Materialize row i of K̃ into `out` (length d). Cold path: used by
    /// plan reconstruction and the default cost read-offs, never inside
    /// the iteration.
    fn write_row(&self, i: usize, out: &mut [F]);

    /// Entries streamed by one apply (see [`KernelStats::nnz`]).
    fn nnz(&self) -> usize;

    /// Factorization rank (d for unfactored kernels).
    fn rank(&self) -> usize;

    /// Worst-case per-row relative kernel mass the approximation
    /// discards (0 when exact). Tests widen their marginal-feasibility
    /// tolerances by this amount.
    fn mass_loss(&self) -> F;

    /// Upper bound on ‖K − K̃‖_F (0 when exact).
    fn frobenius_budget(&self) -> F;

    /// Row sums K·1 (the row marginals of the unscaled kernel).
    fn row_sums(&self) -> Vec<F> {
        let d = self.dim();
        let ones = vec![1.0; d];
        let mut out = vec![0.0; d];
        self.apply(&ones, &mut out);
        out
    }

    /// Column sums Kᵀ·1.
    fn col_sums(&self) -> Vec<F> {
        let d = self.dim();
        let ones = vec![1.0; d];
        let mut out = vec![0.0; d];
        self.apply_transpose(&ones, &mut out);
        out
    }

    /// The transport-cost read-off Σ_ij u_i K̃_ij m_ij v_j against the
    /// row-major ground metric `m`, evaluated over this operator's
    /// support without materializing K∘M.
    fn transport_cost(&self, u: &[F], m: &[F], v: &[F]) -> F {
        let d = self.dim();
        let mut krow = vec![0.0; d];
        let mut value = 0.0;
        for i in 0..d {
            self.write_row(i, &mut krow);
            let mrow = &m[i * d..(i + 1) * d];
            let mut acc = 0.0;
            for j in 0..d {
                acc += krow[j] * mrow[j] * v[j];
            }
            value += u[i] * acc;
        }
        value
    }

    /// Panel form of [`Self::transport_cost`]: U, V are (d, n) panels,
    /// `out` receives the n per-column costs.
    fn transport_cost_panel(&self, u: &[F], m: &[F], v: &[F], n: usize, out: &mut [F]) {
        let d = self.dim();
        let mut krow = vec![0.0; d];
        let mut row_acc = vec![0.0; n];
        out.iter_mut().for_each(|x| *x = 0.0);
        for i in 0..d {
            self.write_row(i, &mut krow);
            let mrow = &m[i * d..(i + 1) * d];
            row_acc.iter_mut().for_each(|x| *x = 0.0);
            for kk in 0..d {
                let w = krow[kk] * mrow[kk];
                if w == 0.0 {
                    continue;
                }
                let vrow = &v[kk * n..(kk + 1) * n];
                for (acc, &vj) in row_acc.iter_mut().zip(vrow) {
                    *acc += w * vj;
                }
            }
            let urow = &u[i * n..(i + 1) * n];
            for j in 0..n {
                out[j] += urow[j] * row_acc[j];
            }
        }
    }

    /// Dense d×d reconstruction of K̃ (diagnostics and tests only).
    fn materialize(&self) -> Matrix {
        let d = self.dim();
        let mut out = Matrix::zeros(d, d);
        for i in 0..d {
            self.write_row(i, out.row_mut(i));
        }
        out
    }

    /// The structure report.
    fn stats(&self) -> KernelStats {
        KernelStats {
            dim: self.dim(),
            nnz: self.nnz(),
            rank: self.rank(),
            mass_loss: self.mass_loss(),
            frobenius_budget: self.frobenius_budget(),
        }
    }
}

/// Median off-diagonal ground cost of a row-major d×d metric — the
/// scale the truncation safety radius and the Auto profitability rule
/// are expressed in (0 when there is no off-diagonal).
fn median_off_diagonal(m: &[F], d: usize) -> F {
    let off: Vec<F> = (0..d)
        .flat_map(|i| (0..d).filter(move |&j| j != i).map(move |j| m[i * d + j]))
        .collect();
    if off.is_empty() {
        0.0
    } else {
        super::median(&off)
    }
}

/// The exact dense kernel: K and Kᵀ both row-major, as every solver
/// held them before the trait existed. The apply loops reproduce the
/// historical inner loops bit-for-bit (scalar applies accumulate with
/// the unrolled [`dot`], panel applies stream K row-major skipping
/// exact zeros), so this wrapper is numerically invisible.
pub struct DenseKernel {
    d: usize,
    /// K = exp(−λM), row-major.
    k: Vec<F>,
    /// Kᵀ row-major (K column-major), for contiguous transpose sweeps.
    kt: Vec<F>,
}

impl DenseKernel {
    /// Materialize K = e^{−λM} and its transpose.
    pub fn build(m: &[F], d: usize, lambda: F) -> Self {
        let mut k = vec![0.0; d * d];
        for (out, &mij) in k.iter_mut().zip(m) {
            *out = (-lambda * mij).exp();
        }
        let mut kt = vec![0.0; d * d];
        for i in 0..d {
            for j in 0..d {
                kt[j * d + i] = k[i * d + j];
            }
        }
        Self { d, k, kt }
    }

    /// Row-major K (tests and the degenerate-kernel probe).
    pub fn data(&self) -> &[F] {
        &self.k
    }
}

/// out = mat·x over a row-major (d, d) buffer: one [`dot`] per row.
fn dense_apply(mat: &[F], d: usize, x: &[F], out: &mut [F]) {
    for (i, o) in out.iter_mut().enumerate() {
        *o = dot(&mat[i * d..(i + 1) * d], x);
    }
}

/// Panel out = mat·X, accumulated row by row over X's rows (the cache
/// pattern the interleaved batch walk is built on).
fn dense_apply_panel(mat: &[F], d: usize, x: &[F], out: &mut [F], n: usize) {
    for i in 0..d {
        let mrow = &mat[i * d..(i + 1) * d];
        let orow = &mut out[i * n..(i + 1) * n];
        orow.iter_mut().for_each(|o| *o = 0.0);
        for (kk, &mik) in mrow.iter().enumerate() {
            if mik == 0.0 {
                continue;
            }
            let xrow = &x[kk * n..(kk + 1) * n];
            for (o, &xv) in orow.iter_mut().zip(xrow) {
                *o += mik * xv;
            }
        }
    }
}

impl KernelOp for DenseKernel {
    fn dim(&self) -> usize {
        self.d
    }

    fn apply(&self, x: &[F], out: &mut [F]) {
        dense_apply(&self.k, self.d, x, out);
    }

    fn apply_transpose(&self, x: &[F], out: &mut [F]) {
        dense_apply(&self.kt, self.d, x, out);
    }

    fn apply_panel(&self, x: &[F], out: &mut [F], n: usize) {
        dense_apply_panel(&self.k, self.d, x, out, n);
    }

    fn apply_transpose_panel(&self, x: &[F], out: &mut [F], n: usize) {
        dense_apply_panel(&self.kt, self.d, x, out, n);
    }

    fn write_row(&self, i: usize, out: &mut [F]) {
        out.copy_from_slice(&self.k[i * self.d..(i + 1) * self.d]);
    }

    fn nnz(&self) -> usize {
        self.d * self.d
    }

    fn rank(&self) -> usize {
        self.d
    }

    fn mass_loss(&self) -> F {
        0.0
    }

    fn frobenius_budget(&self) -> F {
        0.0
    }

    fn transport_cost(&self, u: &[F], m: &[F], v: &[F]) -> F {
        let d = self.d;
        let mut value = 0.0;
        for i in 0..d {
            let krow = &self.k[i * d..(i + 1) * d];
            let mrow = &m[i * d..(i + 1) * d];
            let mut acc = 0.0;
            for j in 0..d {
                acc += krow[j] * mrow[j] * v[j];
            }
            value += u[i] * acc;
        }
        value
    }
}

/// CSR truncation of the Gibbs kernel: entries K_ij ≤ threshold·(row
/// max) are dropped at build time, and every solver pass streams only
/// the survivors. The per-row relative dropped mass is tracked so
/// downstream accuracy claims can be widened by exactly what was
/// discarded.
pub struct SparseKernel {
    d: usize,
    /// CSR row offsets (d + 1 entries).
    row_ptr: Vec<usize>,
    /// Column index per stored entry.
    cols: Vec<usize>,
    /// Kernel value per stored entry.
    vals: Vec<F>,
    /// The relative threshold the kernel was built with.
    threshold: F,
    /// max over rows of dropped/total row mass.
    mass_loss: F,
    /// sqrt(Σ dropped²) — exact ‖K − K̃‖_F.
    frobenius: F,
}

impl SparseKernel {
    /// Threshold-truncate K = e^{−λM}. `threshold` is relative to each
    /// row's largest entry (1 for zero-diagonal metrics); `threshold =
    /// 0` keeps every positive entry, reproducing the dense iteration
    /// bit-for-bit (dense sweeps skip exact zeros too).
    ///
    /// The cut is floored at e^{−λ·[`TRUNCATION_SAFE_RADIUS`]·median(M)}:
    /// entries inside the safety radius survive any threshold, so the
    /// kernel keeps every bin's transport-carrying neighborhood at
    /// arbitrarily large λ (where the *entire* off-diagonal falls below
    /// any fixed value threshold) while the kept count stays strictly
    /// below half the dense count once the radius binds.
    pub fn build(m: &[F], d: usize, lambda: F, threshold: F) -> Self {
        // Median off-diagonal ground cost, for the safety-radius floor.
        // λ-independent, so the O(d² log d) sort is redundant across the
        // anneal prefix's per-stage rebuilds — tolerated because builds
        // are amortized over full solves and the builder API stays
        // (m, d, λ, threshold); cache it here if stage builds ever show
        // up in a profile. (median = 0, e.g. d = 1, degenerates the
        // floor to e⁰ = 1 ≥ every entry, leaving the plain threshold.)
        let radius_cut =
            (-lambda * TRUNCATION_SAFE_RADIUS * median_off_diagonal(m, d)).exp();
        let mut row_ptr = Vec::with_capacity(d + 1);
        row_ptr.push(0);
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        let mut mass_loss: F = 0.0;
        let mut frob2: F = 0.0;
        for i in 0..d {
            let mrow = &m[i * d..(i + 1) * d];
            // Row max of e^{−λm} is e^{−λ·min(m)} — no second exp pass.
            let mmin = mrow.iter().cloned().fold(F::INFINITY, F::min);
            let cut = F::min(threshold * (-lambda * mmin).exp(), radius_cut);
            let mut kept: F = 0.0;
            let mut dropped: F = 0.0;
            for (j, &mij) in mrow.iter().enumerate() {
                let v = (-lambda * mij).exp();
                if v > cut {
                    cols.push(j);
                    vals.push(v);
                    kept += v;
                } else {
                    dropped += v;
                    frob2 += v * v;
                }
            }
            row_ptr.push(cols.len());
            let total = kept + dropped;
            if total > 0.0 {
                mass_loss = mass_loss.max(dropped / total);
            }
        }
        Self { d, row_ptr, cols, vals, threshold, mass_loss, frobenius: frob2.sqrt() }
    }

    /// The relative threshold this kernel was truncated at.
    pub fn threshold(&self) -> F {
        self.threshold
    }
}

impl KernelOp for SparseKernel {
    fn dim(&self) -> usize {
        self.d
    }

    fn apply(&self, x: &[F], out: &mut [F]) {
        for (i, o) in out.iter_mut().enumerate() {
            let mut acc = 0.0;
            for p in self.row_ptr[i]..self.row_ptr[i + 1] {
                acc += self.vals[p] * x[self.cols[p]];
            }
            *o = acc;
        }
    }

    fn apply_transpose(&self, x: &[F], out: &mut [F]) {
        // Scatter over rows: for fixed output j the contributions
        // arrive in ascending i, the same order a dense Kᵀ row sweep
        // accumulates them.
        out.iter_mut().for_each(|o| *o = 0.0);
        for i in 0..self.d {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for p in self.row_ptr[i]..self.row_ptr[i + 1] {
                out[self.cols[p]] += self.vals[p] * xi;
            }
        }
    }

    fn apply_panel(&self, x: &[F], out: &mut [F], n: usize) {
        for i in 0..self.d {
            let orow = &mut out[i * n..(i + 1) * n];
            orow.iter_mut().for_each(|o| *o = 0.0);
            for p in self.row_ptr[i]..self.row_ptr[i + 1] {
                let v = self.vals[p];
                let xrow = &x[self.cols[p] * n..(self.cols[p] + 1) * n];
                for (o, &xv) in orow.iter_mut().zip(xrow) {
                    *o += v * xv;
                }
            }
        }
    }

    fn apply_transpose_panel(&self, x: &[F], out: &mut [F], n: usize) {
        out.iter_mut().for_each(|o| *o = 0.0);
        for i in 0..self.d {
            let xrow = &x[i * n..(i + 1) * n];
            for p in self.row_ptr[i]..self.row_ptr[i + 1] {
                let v = self.vals[p];
                let orow = &mut out[self.cols[p] * n..(self.cols[p] + 1) * n];
                for (o, &xv) in orow.iter_mut().zip(xrow) {
                    *o += v * xv;
                }
            }
        }
    }

    fn write_row(&self, i: usize, out: &mut [F]) {
        out.iter_mut().for_each(|o| *o = 0.0);
        for p in self.row_ptr[i]..self.row_ptr[i + 1] {
            out[self.cols[p]] = self.vals[p];
        }
    }

    fn nnz(&self) -> usize {
        self.vals.len()
    }

    fn rank(&self) -> usize {
        self.d
    }

    fn mass_loss(&self) -> F {
        self.mass_loss
    }

    fn frobenius_budget(&self) -> F {
        self.frobenius
    }

    fn transport_cost(&self, u: &[F], m: &[F], v: &[F]) -> F {
        let d = self.d;
        let mut value = 0.0;
        for i in 0..d {
            let mrow = &m[i * d..(i + 1) * d];
            let mut acc = 0.0;
            for p in self.row_ptr[i]..self.row_ptr[i + 1] {
                let j = self.cols[p];
                acc += self.vals[p] * mrow[j] * v[j];
            }
            value += u[i] * acc;
        }
        value
    }

    fn transport_cost_panel(&self, u: &[F], m: &[F], v: &[F], n: usize, out: &mut [F]) {
        let d = self.d;
        let mut row_acc = vec![0.0; n];
        out.iter_mut().for_each(|x| *x = 0.0);
        for i in 0..d {
            let mrow = &m[i * d..(i + 1) * d];
            row_acc.iter_mut().for_each(|x| *x = 0.0);
            for p in self.row_ptr[i]..self.row_ptr[i + 1] {
                let j = self.cols[p];
                let w = self.vals[p] * mrow[j];
                if w == 0.0 {
                    continue;
                }
                let vrow = &v[j * n..(j + 1) * n];
                for (acc, &vj) in row_acc.iter_mut().zip(vrow) {
                    *acc += w * vj;
                }
            }
            let urow = &u[i * n..(i + 1) * n];
            for j in 0..n {
                out[j] += urow[j] * row_acc[j];
            }
        }
    }
}

/// Pivoted-Cholesky low-rank kernel K ≈ L·Lᵀ (L is d×rank, row-major).
/// Applies cost 2·d·rank multiply-adds instead of d². Only meaningful
/// for symmetric PSD kernels — e^{−λ‖·‖} Gibbs kernels over Euclidean
/// point clouds qualify (completely monotone radial functions are PD by
/// Schoenberg's theorem); an indefinite kernel simply stops the
/// factorization early and reports the larger residual.
///
/// The transport-cost read-off stays at the default `write_row`-based
/// O(d²·rank) (amortized over the panel width): it fuses with the
/// dense, unstructured M, so no factored shortcut exists without
/// caching a dense K∘M — a once-per-solve cost of a few dozen
/// iteration-equivalents, versus the per-iteration saving the
/// factorization buys hundreds of times per solve.
pub struct LowRankKernel {
    d: usize,
    rank: usize,
    /// L, row-major d×rank.
    l: Vec<F>,
    /// Trace residual trace(K − LLᵀ), clamped ≥ 0.
    residual: F,
    /// residual / trace(K): the relative spectral mass discarded.
    rel_residual: F,
}

impl LowRankKernel {
    /// Factor K = e^{−λM}. Returns `None` when not even one pivot is
    /// positive (the caller falls back to the dense kernel). The d×d
    /// kernel is materialized transiently for the factorization — the
    /// saving is in what the solver *holds and streams per iteration*,
    /// not in build-time memory.
    pub fn build(m: &[F], d: usize, lambda: F, max_rank: usize, tolerance: F) -> Option<Self> {
        let mut k = Matrix::zeros(d, d);
        for i in 0..d {
            let mrow = &m[i * d..(i + 1) * d];
            let krow = k.row_mut(i);
            for (out, &mij) in krow.iter_mut().zip(mrow) {
                *out = (-lambda * mij).exp();
            }
        }
        let trace: F = (0..d).map(|i| k.get(i, i)).sum();
        let (l, residual) = pivoted_cholesky(&k, max_rank, tolerance * trace);
        let rank = l.cols();
        if rank == 0 {
            return None;
        }
        let rel = if trace > 0.0 { residual / trace } else { 0.0 };
        Some(Self { d, rank, l: l.data().to_vec(), residual, rel_residual: rel })
    }

    /// t = Lᵀ·x (length rank).
    fn project(&self, x: &[F], t: &mut [F]) {
        t.iter_mut().for_each(|v| *v = 0.0);
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let lrow = &self.l[i * self.rank..(i + 1) * self.rank];
            for (tv, &lv) in t.iter_mut().zip(lrow) {
                *tv += lv * xi;
            }
        }
    }
}

impl KernelOp for LowRankKernel {
    fn dim(&self) -> usize {
        self.d
    }

    fn apply(&self, x: &[F], out: &mut [F]) {
        // out = L (Lᵀ x): two O(d·rank) passes.
        let mut t = vec![0.0; self.rank];
        self.project(x, &mut t);
        for (i, o) in out.iter_mut().enumerate() {
            *o = dot(&self.l[i * self.rank..(i + 1) * self.rank], &t);
        }
    }

    fn apply_transpose(&self, x: &[F], out: &mut [F]) {
        // L·Lᵀ is symmetric by construction.
        self.apply(x, out);
    }

    fn apply_panel(&self, x: &[F], out: &mut [F], n: usize) {
        // T = Lᵀ·X (rank, n), OUT = L·T.
        let mut t = vec![0.0; self.rank * n];
        for i in 0..self.d {
            let lrow = &self.l[i * self.rank..(i + 1) * self.rank];
            let xrow = &x[i * n..(i + 1) * n];
            for (kk, &lv) in lrow.iter().enumerate() {
                if lv == 0.0 {
                    continue;
                }
                let trow = &mut t[kk * n..(kk + 1) * n];
                for (tv, &xv) in trow.iter_mut().zip(xrow) {
                    *tv += lv * xv;
                }
            }
        }
        for i in 0..self.d {
            let lrow = &self.l[i * self.rank..(i + 1) * self.rank];
            let orow = &mut out[i * n..(i + 1) * n];
            orow.iter_mut().for_each(|o| *o = 0.0);
            for (kk, &lv) in lrow.iter().enumerate() {
                if lv == 0.0 {
                    continue;
                }
                let trow = &t[kk * n..(kk + 1) * n];
                for (o, &tv) in orow.iter_mut().zip(trow) {
                    *o += lv * tv;
                }
            }
        }
    }

    fn apply_transpose_panel(&self, x: &[F], out: &mut [F], n: usize) {
        self.apply_panel(x, out, n);
    }

    fn write_row(&self, i: usize, out: &mut [F]) {
        let lrow = &self.l[i * self.rank..(i + 1) * self.rank];
        for (j, o) in out.iter_mut().enumerate() {
            *o = dot(lrow, &self.l[j * self.rank..(j + 1) * self.rank]);
        }
    }

    fn nnz(&self) -> usize {
        // One apply streams L twice (project + expand).
        2 * self.d * self.rank
    }

    fn rank(&self) -> usize {
        self.rank
    }

    fn mass_loss(&self) -> F {
        self.rel_residual
    }

    fn frobenius_budget(&self) -> F {
        // For PSD K the residual K − LLᵀ is itself PSD (a Schur
        // complement), so ‖K − LLᵀ‖_F ≤ trace(K − LLᵀ).
        self.residual
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::RandomMetric;
    use crate::simplex::seeded_rng;

    fn gibbs(d: usize, lambda: F, seed: u64) -> (Vec<F>, usize, F) {
        let mut rng = seeded_rng(seed);
        let m = RandomMetric::new(d).sample(&mut rng);
        (m.data().to_vec(), d, lambda)
    }

    fn rand_vec(d: usize, seed: u64) -> Vec<F> {
        let mut rng = seeded_rng(seed);
        (0..d).map(|_| rng.range_f64(0.0, 2.0)).collect()
    }

    #[test]
    fn dense_kernel_matches_manual_matvec() {
        let (m, d, lam) = gibbs(9, 7.0, 0);
        let k = DenseKernel::build(&m, d, lam);
        let x = rand_vec(d, 1);
        let mut out = vec![0.0; d];
        k.apply(&x, &mut out);
        for i in 0..d {
            let want: F =
                (0..d).map(|j| (-lam * m[i * d + j]).exp() * x[j]).sum();
            assert!((out[i] - want).abs() < 1e-12);
        }
        let mut tout = vec![0.0; d];
        k.apply_transpose(&x, &mut tout);
        for j in 0..d {
            let want: F =
                (0..d).map(|i| (-lam * m[i * d + j]).exp() * x[i]).sum();
            assert!((tout[j] - want).abs() < 1e-12);
        }
        assert_eq!(k.stats(), KernelStats::dense(d));
    }

    #[test]
    fn zero_threshold_truncation_is_exactly_dense() {
        let (m, d, lam) = gibbs(10, 9.0, 2);
        let dense = DenseKernel::build(&m, d, lam);
        let sparse = SparseKernel::build(&m, d, lam, 0.0);
        assert_eq!(sparse.mass_loss(), 0.0);
        assert_eq!(sparse.frobenius_budget(), 0.0);
        let x = rand_vec(d, 3);
        let (mut a, mut b) = (vec![0.0; d], vec![0.0; d]);
        dense.apply(&x, &mut a);
        sparse.apply(&x, &mut b);
        for (av, bv) in a.iter().zip(&b) {
            assert!((av - bv).abs() < 1e-14);
        }
        // Panel applies are bit-identical: same values added in the
        // same order per output slot.
        let n = 3;
        let xp = rand_vec(d * n, 4);
        let (mut ap, mut bp) = (vec![0.0; d * n], vec![0.0; d * n]);
        dense.apply_panel(&xp, &mut ap, n);
        sparse.apply_panel(&xp, &mut bp, n);
        assert_eq!(ap, bp);
        dense.apply_transpose_panel(&xp, &mut ap, n);
        sparse.apply_transpose_panel(&xp, &mut bp, n);
        assert_eq!(ap, bp);
    }

    #[test]
    fn truncation_drops_mass_and_reports_it() {
        let (m, d, lam) = gibbs(16, 20.0, 5);
        let sparse = SparseKernel::build(&m, d, lam, 1e-3);
        assert!(sparse.nnz() < d * d, "high λ must truncate something");
        assert!(sparse.mass_loss() > 0.0);
        assert!(sparse.frobenius_budget() > 0.0);
        // The dropped mass is bounded by the threshold times the row
        // width (each dropped entry is below threshold·rowmax and the
        // row total is at least the diagonal 1).
        assert!(sparse.mass_loss() <= 1e-3 * d as F);
        // Row sums match the dense row sums up to the dropped mass.
        let dense = DenseKernel::build(&m, d, lam);
        for (s, ds) in sparse.row_sums().iter().zip(dense.row_sums()) {
            assert!(*s <= ds + 1e-15);
            assert!((ds - s) / ds <= sparse.mass_loss() + 1e-15);
        }
    }

    #[test]
    fn low_rank_full_tolerance_zero_reconstructs() {
        let (m, d, lam) = gibbs(12, 6.0, 7);
        let lr = LowRankKernel::build(&m, d, lam, 0, 0.0).expect("PD kernel");
        assert!(lr.rank() <= d);
        let dense = DenseKernel::build(&m, d, lam);
        let rec = lr.materialize();
        for i in 0..d {
            for j in 0..d {
                let want = dense.data()[i * d + j];
                assert!(
                    (rec.get(i, j) - want).abs() < 1e-10,
                    "({i},{j}): {} vs {want}",
                    rec.get(i, j)
                );
            }
        }
        let x = rand_vec(d, 8);
        let (mut a, mut b) = (vec![0.0; d], vec![0.0; d]);
        dense.apply(&x, &mut a);
        lr.apply(&x, &mut b);
        for (av, bv) in a.iter().zip(&b) {
            assert!((av - bv).abs() < 1e-10);
        }
    }

    #[test]
    fn low_rank_truncates_at_low_lambda() {
        // λ → 0 sends K toward the all-ones matrix. The e^{−λ‖·‖}
        // kernel's eigen-tail decays only polynomially (it is not
        // smooth at 0), so genuine compression needs a loose trace
        // tolerance — at 3% the rank collapses to a handful of columns.
        let (m, d, _) = gibbs(24, 1.0, 9);
        let lr = LowRankKernel::build(&m, d, 0.05, 0, 3e-2).expect("PD kernel");
        assert!(lr.rank() < d / 3, "rank {} not small at tiny λ", lr.rank());
        assert!(lr.nnz() < d * d);
        // The reported budgets bound the reconstruction error.
        let dense = DenseKernel::build(&m, d, 0.05);
        let rec = lr.materialize();
        let mut frob2 = 0.0;
        for i in 0..d {
            for j in 0..d {
                let e = rec.get(i, j) - dense.data()[i * d + j];
                frob2 += e * e;
            }
        }
        assert!(frob2.sqrt() <= lr.frobenius_budget() + 1e-9);
    }

    #[test]
    fn panel_applies_match_scalar_applies() {
        let (m, d, lam) = gibbs(11, 12.0, 10);
        let ops: Vec<Box<dyn KernelOp>> = vec![
            Box::new(DenseKernel::build(&m, d, lam)),
            Box::new(SparseKernel::build(&m, d, lam, 1e-4)),
            Box::new(LowRankKernel::build(&m, d, lam, 0, 1e-12).unwrap()),
        ];
        let n = 4;
        let xp = rand_vec(d * n, 11);
        for op in &ops {
            let mut panel = vec![0.0; d * n];
            op.apply_panel(&xp, &mut panel, n);
            let mut tpanel = vec![0.0; d * n];
            op.apply_transpose_panel(&xp, &mut tpanel, n);
            for j in 0..n {
                let col: Vec<F> = (0..d).map(|i| xp[i * n + j]).collect();
                let mut want = vec![0.0; d];
                op.apply(&col, &mut want);
                for i in 0..d {
                    assert!((panel[i * n + j] - want[i]).abs() < 1e-12);
                }
                op.apply_transpose(&col, &mut want);
                for i in 0..d {
                    assert!((tpanel[i * n + j] - want[i]).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn transport_cost_matches_dense_readoff() {
        let (m, d, lam) = gibbs(10, 8.0, 12);
        let u = rand_vec(d, 13);
        let v = rand_vec(d, 14);
        let dense = DenseKernel::build(&m, d, lam);
        let want = dense.transport_cost(&u, &m, &v);
        let sparse = SparseKernel::build(&m, d, lam, 0.0);
        assert!((sparse.transport_cost(&u, &m, &v) - want).abs() < 1e-12);
        let lr = LowRankKernel::build(&m, d, lam, 0, 0.0).unwrap();
        assert!((lr.transport_cost(&u, &m, &v) - want).abs() < 1e-9);
        // Panel read-off, column 0 of a width-2 panel.
        let n = 2;
        let mut up = vec![0.0; d * n];
        let mut vp = vec![0.0; d * n];
        for i in 0..d {
            up[i * n] = u[i];
            vp[i * n] = v[i];
            up[i * n + 1] = v[i];
            vp[i * n + 1] = u[i];
        }
        let mut out = vec![0.0; n];
        sparse.transport_cost_panel(&up, &m, &vp, n, &mut out);
        assert!((out[0] - want).abs() < 1e-12);
    }

    #[test]
    fn policy_resolution_and_capping() {
        let (m_small, _, _) = gibbs(16, 9.0, 20);
        assert_eq!(
            KernelPolicy::Auto.resolve(&m_small, 16, 9.0),
            KernelPolicy::Dense,
            "d·λ = 144 is below the amortization gate"
        );
        let mut rng = seeded_rng(21);
        let m_big = RandomMetric::new(128).sample(&mut rng);
        assert_eq!(
            KernelPolicy::Auto.resolve(m_big.data(), 128, 50.0),
            KernelPolicy::truncated_default(),
            "median-normalized metric at d·λ = 6400, λ·median = 50"
        );
        // Metric-scale awareness: shrink every cost by 1000× — the same
        // (d, λ) now keeps every kernel entry above any threshold, so
        // Auto must stay dense instead of paying CSR overhead for zero
        // sparsity.
        let m_tiny: Vec<F> = m_big.data().iter().map(|&x| x * 1e-3).collect();
        assert_eq!(
            KernelPolicy::Auto.resolve(&m_tiny, 128, 50.0),
            KernelPolicy::Dense,
            "λ·median = 0.05: nothing to truncate"
        );
        assert_eq!(
            KernelPolicy::Dense.resolve(&m_small, 4096, 1e6),
            KernelPolicy::Dense,
            "concrete policies resolve to themselves"
        );
        // 2·16²·8 = 4096 bytes: dense fits exactly.
        assert_eq!(KernelPolicy::capped(16, 4096), KernelPolicy::Dense);
        assert_eq!(KernelPolicy::capped(16, 4095), KernelPolicy::truncated_default());
    }

    #[test]
    fn policy_build_dispatches() {
        let (m, d, lam) = gibbs(8, 9.0, 15);
        assert_eq!(KernelPolicy::Dense.build(&m, d, lam).nnz(), d * d);
        let t = KernelPolicy::Truncated { threshold: 1e-2 }.build(&m, d, lam);
        assert!(t.nnz() <= d * d);
        let lr = KernelPolicy::LowRank { max_rank: 3, tolerance: 0.0 }.build(&m, d, lam);
        assert!(lr.rank() <= 3);
        // Auto at small d·λ is dense.
        assert_eq!(KernelPolicy::Auto.build(&m, d, lam).nnz(), d * d);
    }

    #[test]
    fn row_and_col_sums_agree_for_symmetric_kernels() {
        let (m, d, lam) = gibbs(9, 5.0, 16);
        for op in [
            KernelPolicy::Dense.build(&m, d, lam),
            KernelPolicy::Truncated { threshold: 1e-3 }.build(&m, d, lam),
            KernelPolicy::low_rank_default().build(&m, d, lam),
        ] {
            let rows = op.row_sums();
            let cols = op.col_sums();
            for (r, c) in rows.iter().zip(&cols) {
                assert!((r - c).abs() < 1e-9, "symmetric M ⇒ symmetric K̃");
            }
        }
    }
}
