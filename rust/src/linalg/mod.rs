//! Small dense linear-algebra kernels shared by the SVM, the kernel
//! builders and the CPU Sinkhorn engine.
//!
//! Deliberately BLAS-free (the crate is self-contained); the routines are
//! written cache-consciously (row-major, contiguous inner loops, blocked
//! GEMM) and profiled with the in-tree bench harness.

pub mod kernel;

pub use kernel::{
    DenseKernel, KernelOp, KernelPolicy, KernelStats, LowRankKernel, SparseKernel,
};

use crate::F;

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<F>,
}

impl Matrix {
    /// Zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<F>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        Self { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> F {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: F) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Contiguous view of row i.
    #[inline]
    pub fn row(&self, i: usize) -> &[F] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable contiguous view of row i.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [F] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Underlying row-major buffer.
    #[inline]
    pub fn data(&self) -> &[F] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [F] {
        &mut self.data
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// y = self · x (matrix-vector product).
    pub fn matvec(&self, x: &[F]) -> Vec<F> {
        assert_eq!(x.len(), self.cols, "matvec shape mismatch");
        let mut y = vec![0.0; self.rows];
        for (i, yi) in y.iter_mut().enumerate() {
            *yi = dot(self.row(i), x);
        }
        y
    }

    /// Elementwise map into a new matrix.
    pub fn map(&self, f: impl Fn(F) -> F) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }
}

/// Dot product with 4-way unrolled accumulation (keeps the dependency
/// chain short enough for the CPU to pipeline; ~3x naive on long rows).
#[inline]
pub fn dot(a: &[F], b: &[F]) -> F {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for k in 0..chunks {
        let i = 4 * k;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// C = A · B, blocked over k for cache reuse. Shapes: (m,k)·(k,n)->(m,n).
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    const BK: usize = 64;
    for k0 in (0..k).step_by(BK) {
        let k1 = (k0 + BK).min(k);
        for i in 0..m {
            let a_row = &a.data[i * k..(i + 1) * k];
            let c_row = &mut c.data[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let aik = a_row[kk];
                if aik == 0.0 {
                    continue;
                }
                let b_row = &b.data[kk * n..(kk + 1) * n];
                for (cj, bj) in c_row.iter_mut().zip(b_row) {
                    *cj += aik * bj;
                }
            }
        }
    }
    c
}

/// In-place Cholesky factorization A = L·Lᵀ of a symmetric positive
/// definite matrix (lower triangle returned; upper left untouched).
/// Returns `None` if the matrix is not numerically PD.
pub fn cholesky(a: &Matrix) -> Option<Matrix> {
    assert_eq!(a.rows, a.cols, "cholesky needs a square matrix");
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.get(i, j);
            // s -= sum_k l[i,k] l[j,k]
            let (li, lj) = (&l.data[i * n..i * n + j], &l.data[j * n..j * n + j]);
            s -= dot(li, lj);
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l.data[i * n + j] = s.sqrt();
            } else {
                l.data[i * n + j] = s / l.data[j * n + j];
            }
        }
    }
    Some(l)
}

/// Pivoted (rank-revealing) Cholesky: greedily factor a symmetric PSD
/// matrix A ≈ L·Lᵀ with L n×r, pivoting on the largest residual
/// diagonal. Stops when the residual trace drops to `tol` (absolute),
/// when the best pivot goes non-positive (numerical indefiniteness), or
/// after `max_rank` columns (0 = unbounded). Returns (L in the original
/// row order, residual trace trace(A − LLᵀ) clamped ≥ 0). With `tol = 0`
/// and an uncapped rank a PD matrix factors to numerical full rank —
/// the same limit [`cholesky`] computes, reached pivot-first.
pub fn pivoted_cholesky(a: &Matrix, max_rank: usize, tol: F) -> (Matrix, F) {
    assert_eq!(a.rows(), a.cols(), "pivoted_cholesky needs a square matrix");
    let n = a.rows();
    let rmax = if max_rank == 0 { n } else { max_rank.min(n) };
    let mut l = Matrix::zeros(n, rmax);
    let mut diag: Vec<F> = (0..n).map(|i| a.get(i, i)).collect();
    // order[..k] are the chosen pivots, order[k..] the remaining rows.
    let mut order: Vec<usize> = (0..n).collect();
    let mut rank = 0;
    let mut lp = vec![0.0; rmax];
    for k in 0..rmax {
        let rem: F = order[k..].iter().map(|&i| diag[i].max(0.0)).sum();
        if rem <= tol {
            break;
        }
        let (mut best_t, mut best_val) = (k, F::NEG_INFINITY);
        for (t, &i) in order.iter().enumerate().skip(k) {
            if diag[i] > best_val {
                best_val = diag[i];
                best_t = t;
            }
        }
        if best_val <= 0.0 {
            break;
        }
        order.swap(k, best_t);
        let p = order[k];
        let piv = best_val.sqrt();
        l.set(p, k, piv);
        lp[..k].copy_from_slice(&l.row(p)[..k]);
        for t in (k + 1)..n {
            let i = order[t];
            let s = a.get(p, i) - dot(&l.row(i)[..k], &lp[..k]);
            let lik = s / piv;
            l.set(i, k, lik);
            diag[i] -= lik * lik;
        }
        diag[p] = 0.0;
        rank = k + 1;
    }
    let residual: F = order[rank..].iter().map(|&i| diag[i].max(0.0)).sum();
    // Trim L to the achieved rank.
    let mut trimmed = Matrix::zeros(n, rank);
    for i in 0..n {
        trimmed.row_mut(i).copy_from_slice(&l.row(i)[..rank]);
    }
    (trimmed, residual)
}

/// s%-quantile (linear interpolation) of a slice; used for the paper's
/// kernel-width grid {1, q10, q20, q50} and the metric median rescaling.
pub fn quantile(values: &[F], s: F) -> F {
    assert!(!values.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&s), "quantile level must be in [0,1]");
    let mut v: Vec<F> = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in quantile input"));
    let pos = s * (v.len() - 1) as F;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as F) * (v[hi] - v[lo])
    }
}

/// Median shorthand (the paper's q50, used to normalize cost matrices).
pub fn median(values: &[F]) -> F {
    quantile(values, 0.5)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_and_gemm_agree() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let x = vec![1., 0., -1.];
        assert_eq!(a.matvec(&x), vec![-2., -2.]);
        let b = Matrix::from_vec(3, 2, vec![1., 0., 0., 1., 1., 1.]);
        let c = gemm(&a, &b);
        assert_eq!(c.data(), &[4., 5., 10., 11.]);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn cholesky_reconstructs() {
        // A = B B^T + I is SPD.
        let b = Matrix::from_vec(3, 3, vec![1., 2., 0., 0., 1., 1., 1., 0., 1.]);
        let mut a = gemm(&b, &b.transpose());
        for i in 0..3 {
            let v = a.get(i, i) + 1.0;
            a.set(i, i, v);
        }
        let l = cholesky(&a).expect("SPD matrix must factor");
        let rec = gemm(&l, &l.transpose());
        for (x, y) in rec.data().iter().zip(a.data()) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 2., 1.]); // eigenvalue -1
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn pivoted_cholesky_full_rank_reconstructs() {
        let b = Matrix::from_vec(3, 3, vec![1., 2., 0., 0., 1., 1., 1., 0., 1.]);
        let mut a = gemm(&b, &b.transpose());
        for i in 0..3 {
            let v = a.get(i, i) + 1.0;
            a.set(i, i, v);
        }
        let (l, residual) = pivoted_cholesky(&a, 0, 0.0);
        assert_eq!(l.cols(), 3);
        assert!(residual < 1e-12);
        let rec = gemm(&l, &l.transpose());
        for (x, y) in rec.data().iter().zip(a.data()) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn pivoted_cholesky_detects_low_rank() {
        // A = b·bᵀ is exactly rank 1.
        let b = Matrix::from_vec(4, 1, vec![1., 2., -1., 0.5]);
        let a = gemm(&b, &b.transpose());
        let (l, residual) = pivoted_cholesky(&a, 0, 1e-12);
        assert_eq!(l.cols(), 1, "rank-1 matrix must factor with one pivot");
        assert!(residual < 1e-12);
        let rec = gemm(&l, &l.transpose());
        for (x, y) in rec.data().iter().zip(a.data()) {
            assert!((x - y).abs() < 1e-10);
        }
        // A rank cap is honored even when the tolerance is not yet met.
        let spd = {
            let c = Matrix::from_vec(4, 4, vec![
                2., 1., 0., 0., 1., 2., 1., 0., 0., 1., 2., 1., 0., 0., 1., 2.,
            ]);
            gemm(&c, &c.transpose())
        };
        let (l2, res2) = pivoted_cholesky(&spd, 2, 0.0);
        assert_eq!(l2.cols(), 2);
        assert!(res2 > 0.0, "capped factorization must report leftovers");
    }

    #[test]
    fn quantiles() {
        let v = vec![3., 1., 2., 4.];
        assert_eq!(quantile(&v, 0.0), 1.0);
        assert_eq!(quantile(&v, 1.0), 4.0);
        assert_eq!(median(&v), 2.5);
    }

    #[test]
    fn prop_dot_matches_naive() {
        for seed in 0..100u64 {
            let mut rng = crate::simplex::seeded_rng(seed);
            let n = rng.range_usize(0, 64);
            let a: Vec<F> = (0..n).map(|_| rng.range_f64(-10.0, 10.0)).collect();
            let b: Vec<F> = (0..n).map(|_| rng.range_f64(-10.0, 10.0)).collect();
            let naive: F = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-9);
        }
    }

    #[test]
    fn prop_quantile_is_monotone() {
        for seed in 0..100u64 {
            let mut rng = crate::simplex::seeded_rng(seed);
            let n = rng.range_usize(1, 50);
            let v: Vec<F> = (0..n).map(|_| rng.range_f64(-100.0, 100.0)).collect();
            let s1 = rng.f64();
            let s2 = rng.f64();
            let (lo, hi) = if s1 <= s2 { (s1, s2) } else { (s2, s1) };
            assert!(quantile(&v, lo) <= quantile(&v, hi) + 1e-12);
        }
    }
}
