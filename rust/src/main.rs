//! `repro` — the CLI launcher for the sinkhorn-rs reproduction.
//!
//! One subcommand per paper experiment plus service utilities:
//!
//! ```text
//! repro mnist      [--grid G] [--ns a,b,c] [--repeats R] [--skip-emd]   Figure 2
//! repro gap        [--grid G] [--pairs P] [--lambdas l1,l2,...]         Figure 3
//! repro speed      [--dims d1,d2,...] [--skip-emd] [--no-xla]           Figure 4
//! repro iterations [--dims d1,d2,...] [--lambdas ...] [--trials T]      Figure 5
//! repro serve      [--queries N] [--batch B] [--delay-ms D] [--workers W] [--backend NAME]   service demo
//! repro info                                                            artifact manifest
//! ```
//!
//! Argument parsing is hand-rolled (`--key value` pairs) to keep the
//! dependency set at the offline minimum.

use sinkhorn_rs::exp::{ablation, fig2, fig3, fig4, fig5};
use sinkhorn_rs::prelude::*;
use sinkhorn_rs::runtime::XlaRuntime;
use std::collections::HashMap;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprint!("{USAGE}");
        return ExitCode::FAILURE;
    };
    let opts = match Opts::parse(&args[1..]) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}\n");
            eprint!("{USAGE}");
            return ExitCode::FAILURE;
        }
    };
    let result = match cmd.as_str() {
        "mnist" => cmd_mnist(&opts),
        "gap" => cmd_gap(&opts),
        "speed" => cmd_speed(&opts),
        "iterations" => cmd_iterations(&opts),
        "serve" => cmd_serve(&opts),
        "ablation" => cmd_ablation(&opts),
        "info" => cmd_info(&opts),
        other => Err(format!("unknown subcommand '{other}'")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "\
repro — Sinkhorn Distances (Cuturi 2013) reproduction CLI

subcommands:
  mnist        Figure 2: SVM test error per distance vs training size
  gap          Figure 3: (d^l - d_M)/d_M boxplots vs lambda
  speed        Figure 4: seconds/distance vs dimension, EMD vs Sinkhorn
  iterations   Figure 5: Sinkhorn iterations to converge vs d, per lambda
  serve        run the batched distance service on a synthetic query load
  ablation     design-choice ablations (iteration budget, check stride)
  info         print the AOT artifact manifest

common flags: --seed S, --artifacts DIR (default ./artifacts)
see README.md for build instructions and per-subcommand scale flags
";

/// Parsed `--key value` options (plus bare `--flag` booleans).
struct Opts {
    values: HashMap<String, String>,
    flags: Vec<String>,
}

impl Opts {
    fn parse(args: &[String]) -> Result<Self, String> {
        let mut values = HashMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| format!("expected --flag, got '{a}'"))?;
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                values.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.push(key.to_string());
                i += 1;
            }
        }
        Ok(Self { values, flags })
    }

    fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        match self.values.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| format!("cannot parse --{name} '{v}'")),
        }
    }

    fn list<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Result<Vec<T>, String>
    where
        T: Clone,
    {
        match self.values.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse::<T>()
                        .map_err(|_| format!("cannot parse --{name} item '{x}'"))
                })
                .collect(),
        }
    }

    fn artifacts(&self) -> std::path::PathBuf {
        std::path::PathBuf::from(
            self.values
                .get("artifacts")
                .cloned()
                .unwrap_or_else(|| "artifacts".to_string()),
        )
    }
}

fn cmd_mnist(opts: &Opts) -> Result<(), String> {
    let mut config = fig2::Fig2Config {
        grid: opts.get("grid", 12usize)?,
        ns: opts.list("ns", &[40usize, 100, 200])?,
        folds: opts.get("folds", 4usize)?,
        repeats: opts.get("repeats", 2usize)?,
        sinkhorn_iterations: opts.get("iters", 20usize)?,
        seed: opts.get("seed", 2013u64)?,
        ..Default::default()
    };
    if opts.flag("skip-emd") {
        config.distances.retain(|d| *d != fig2::DistanceKind::Emd);
    }
    eprintln!(
        "fig2: grid={} (d={}), ns={:?}, {} folds x {} repeats, {} distances",
        config.grid,
        config.grid * config.grid,
        config.ns,
        config.folds,
        config.repeats,
        config.distances.len()
    );
    let points = fig2::run(&config);
    print!("{}", fig2::render(&points));
    Ok(())
}

fn cmd_gap(opts: &Opts) -> Result<(), String> {
    let config = fig3::Fig3Config {
        grid: opts.get("grid", 12usize)?,
        pairs: opts.get("pairs", 36usize)?,
        lambdas: opts.list("lambdas", &[1.0, 2.0, 5.0, 9.0, 15.0, 25.0, 50.0])?,
        seed: opts.get("seed", 11u64)?,
        ..Default::default()
    };
    eprintln!(
        "fig3: grid={} (d={}), {} pairs, lambdas={:?}",
        config.grid,
        config.grid * config.grid,
        config.pairs,
        config.lambdas
    );
    let points = fig3::run(&config);
    print!("{}", fig3::render(&points));
    Ok(())
}

fn cmd_speed(opts: &Opts) -> Result<(), String> {
    let config = fig4::Fig4Config {
        dims: opts.list("dims", &[64usize, 128, 256, 512])?,
        lambdas: opts.list("lambdas", &[1.0, 9.0])?,
        emd_cap: opts.get("emd-cap", 512usize)?,
        skip_emd: opts.flag("skip-emd"),
        artifact_dir: if opts.flag("no-xla") {
            None
        } else {
            Some(opts.artifacts())
        },
        seed: opts.get("seed", 7u64)?,
        ..Default::default()
    };
    eprintln!("fig4: dims={:?}, lambdas={:?}", config.dims, config.lambdas);
    let points = fig4::run(&config);
    print!("{}", fig4::render(&points));
    Ok(())
}

fn cmd_iterations(opts: &Opts) -> Result<(), String> {
    let config = fig5::Fig5Config {
        dims: opts.list("dims", &[64usize, 128, 256, 512])?,
        lambdas: opts.list("lambdas", &[1.0, 5.0, 9.0, 25.0, 50.0])?,
        trials: opts.get("trials", 8usize)?,
        seed: opts.get("seed", 42u64)?,
        ..Default::default()
    };
    eprintln!(
        "fig5: dims={:?}, lambdas={:?}, trials={}",
        config.dims, config.lambdas, config.trials
    );
    let points = fig5::run(&config);
    print!("{}", fig5::render(&points));
    Ok(())
}

fn cmd_serve(opts: &Opts) -> Result<(), String> {
    use sinkhorn_rs::backend::BackendKind;
    use sinkhorn_rs::coordinator::{CoordinatorConfig, MetricId, Query};
    let queries = opts.get("queries", 512usize)?;
    let d = opts.get("d", 64usize)?;
    let lambda = opts.get("lambda", 9.0f64)?;
    let batch = opts.get("batch", 64usize)?;
    let delay_ms = opts.get("delay-ms", 2u64)?;
    let default_workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let workers = opts.get("workers", default_workers)?;
    let backend = match opts.values.get("backend") {
        None => None,
        Some(name) => Some(
            BackendKind::parse(name)
                .ok_or_else(|| format!("unknown --backend '{name}'"))?,
        ),
    };
    let config = CoordinatorConfig {
        artifact_dir: if opts.flag("no-xla") { None } else { Some(opts.artifacts()) },
        cpu_workers: workers,
        cpu_backend: backend,
        batcher: sinkhorn_rs::coordinator::BatcherConfig {
            max_batch: batch,
            max_delay: std::time::Duration::from_millis(delay_ms),
            scale_with_workers: opts.flag("scale-batch"),
        },
        ..Default::default()
    };
    let service = DistanceService::start(config).map_err(|e| e.to_string())?;
    let mut rng = seeded_rng(opts.get("seed", 0u64)?);
    let metric = RandomMetric::new(d).sample(&mut rng);
    service
        .register_metric(MetricId(0), metric)
        .map_err(|e| e.to_string())?;
    let compiled = service.warmup().map_err(|e| e.to_string())?;
    eprintln!("serve: warmed {compiled} artifacts; issuing {queries} queries at d={d}");

    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..queries)
        .map(|_| {
            let r = Histogram::sample_uniform(d, &mut rng);
            let c = Histogram::sample_uniform(d, &mut rng);
            service
                .submit(Query::new(MetricId(0), lambda, r, c))
                .map_err(|e| e.to_string())
        })
        .collect::<Result<_, _>>()?;
    let mut sum = 0.0;
    for rx in rxs {
        let res = rx.recv().map_err(|e| e.to_string())?.map_err(|e| e.to_string())?;
        sum += res.distance();
    }
    let elapsed = t0.elapsed();
    let stats = service.stats().map_err(|e| e.to_string())?;
    println!(
        "served {queries} queries in {:.3}s ({:.0} q/s); checksum {sum:.4}",
        elapsed.as_secs_f64(),
        queries as f64 / elapsed.as_secs_f64()
    );
    println!("stats: {stats}");
    service.shutdown();
    Ok(())
}

fn cmd_ablation(opts: &Opts) -> Result<(), String> {
    let grid = opts.get("grid", 10usize)?;
    let budgets = opts.list("budgets", &[1usize, 2, 5, 20, 100])?;
    let strides = [1usize, 4, 16, usize::MAX];
    let seed = opts.get("seed", 3u64)?;
    eprintln!("ablation: grid={grid}, budgets={budgets:?}");
    let b = ablation::iteration_budget(grid, 60, 30, &budgets, seed);
    let s = ablation::check_stride(opts.get("d", 128usize)?, &strides, seed);
    print!("{}", ablation::render(&b, &s));
    Ok(())
}

fn cmd_info(opts: &Opts) -> Result<(), String> {
    let rt = XlaRuntime::new(opts.artifacts()).map_err(|e| e.to_string())?;
    println!("platform: {}", rt.platform());
    println!(
        "{:<40} {:>6} {:>6} {:>6} {:>8}",
        "variant", "d", "n", "iters", "flavor"
    );
    for v in &rt.manifest().variants {
        println!(
            "{:<40} {:>6} {:>6} {:>6} {:>8}",
            v.name,
            v.d,
            v.n,
            v.iters,
            v.flavor.as_str()
        );
    }
    Ok(())
}
