//! The dense Sinkhorn-Knopp fixed-point engine (Algorithm 1).
//!
//! Hot-path layout decisions (measured by `cargo bench --bench solvers`):
//!
//! * the Gibbs kernel is held behind the [`KernelOp`] interface, built
//!   once per (M, λ) bind by the config's kernel policy — the default
//!   dense operator materializes `K` and `Kᵀ` row-major so both matvecs
//!   in the iteration stream contiguously; truncated/low-rank operators
//!   trade exactness for fewer streamed entries;
//! * `K∘M` (needed only for the final cost read-off) is materialized
//!   lazily, not in the loop;
//! * the batch path walks N problems per row tile so `K` is read once per
//!   iteration regardless of batch width (the vectorization the paper
//!   credits for GPGPU speed, recreated in cache terms).

use super::outcome::{certify, drive_budgeted, ErrorInterval, SolveBudget, SolveOutcome};
use super::{op_ratio, op_ratio_transpose, ScalingInit, SinkhornConfig};
use crate::linalg::{KernelOp, KernelStats};
use crate::metric::CostMatrix;
use crate::simplex::Histogram;
use crate::F;

/// Result of a Sinkhorn solve.
#[derive(Debug, Clone)]
pub struct SinkhornOutput {
    /// The dual-Sinkhorn divergence d_M^λ(r, c).
    pub value: F,
    /// Scaling vector u (support-aligned with r).
    pub u: Vec<F>,
    /// Scaling vector v (support-aligned with c).
    pub v: Vec<F>,
    /// Iteration statistics.
    pub stats: SinkhornStats,
}

/// Per-solve statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct SinkhornStats {
    /// Fixed-point iterations executed.
    pub iterations: usize,
    /// Last observed ‖x − x'‖₂ (∞ if never checked).
    pub last_delta: F,
    /// Whether the tolerance was met before the iteration cap.
    pub converged: bool,
    /// Whether the log-domain stabilized path was used.
    pub stabilized: bool,
}

/// Sinkhorn solver bound to a ground metric and a λ (holds the Gibbs
/// kernel as a [`KernelOp`] built by the config's
/// [`crate::linalg::KernelPolicy`] — dense by default).
pub struct SinkhornEngine {
    d: usize,
    lambda: F,
    config: SinkhornConfig,
    /// K̃ ≈ exp(−λM) behind the operator interface.
    kernel: Box<dyn KernelOp>,
    /// M, kept for the cost read-off and log-domain fallback.
    m: Vec<F>,
    /// True when exp(−λM) underflowed badly enough that the dense kernel
    /// is unusable and solves are delegated to the log-domain path.
    degenerate: bool,
}

impl SinkhornEngine {
    /// Bind to a metric with λ and default (convergence-driven) config.
    pub fn new(metric: &CostMatrix, lambda: F) -> Self {
        Self::with_config(metric, SinkhornConfig::converged(lambda))
    }

    /// Bind with an explicit config.
    pub fn with_config(metric: &CostMatrix, config: SinkhornConfig) -> Self {
        let d = metric.dim();
        let lambda = config.lambda;
        assert!(lambda > 0.0, "lambda must be positive");
        // The diagonal of K is always 1 (m_ii = 0), so row-level zero
        // tests never fire; instead detect mass underflow: when the bulk
        // of the *off-diagonal* kernel underflows to exactly zero, K is
        // numerically diagonal, the dense fixed point collapses to a
        // meaningless 0-cost answer, and solves must go through the
        // log-domain path. With the (default) dense policy the built
        // kernel itself feeds the check, sparing a second O(d²) exp
        // pass; structured policies don't materialize the full kernel,
        // so they pay the one-off probe.
        // Resolve once; a concrete policy re-resolves to itself, so the
        // build below never repeats the Auto-gate median computation.
        let resolved = config.kernel.resolve(metric.data(), d, lambda);
        let (kernel, degenerate): (Box<dyn KernelOp>, bool) = match resolved {
            crate::linalg::KernelPolicy::Dense => {
                let dense =
                    crate::linalg::DenseKernel::build(metric.data(), d, lambda);
                let degenerate = config.auto_stabilize
                    && super::degenerate_off_diagonal(dense.data().iter().copied(), d);
                (Box::new(dense), degenerate)
            }
            _ => (
                resolved.build(metric.data(), d, lambda),
                config.auto_stabilize && super::dense_kernel_degenerate(metric, lambda),
            ),
        };
        Self { d, lambda, config, kernel, m: metric.data().to_vec(), degenerate }
    }

    /// Problem dimension.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// The entropic weight λ.
    pub fn lambda(&self) -> F {
        self.lambda
    }

    /// Whether solves are being routed through the log-domain path.
    pub fn is_stabilized(&self) -> bool {
        self.degenerate
    }

    /// Structure report of the kernel operator this engine iterates
    /// with (nnz / rank / mass loss).
    pub fn kernel_stats(&self) -> KernelStats {
        self.kernel.stats()
    }

    /// d_M^λ(r, c) for a single pair.
    pub fn distance(&self, r: &Histogram, c: &Histogram) -> SinkhornOutput {
        self.distance_init(r, c, &ScalingInit::Cold)
    }

    /// d_M^λ(r, c) seeded by `init`. [`ScalingInit::Cold`] starts from
    /// the uniform scaling, through the ε-scaling prefix when the config
    /// carries a [`super::LambdaSchedule::Geometric`] schedule. A
    /// [`ScalingInit::Warm`] seed skips the anneal prefix — it is
    /// already (near) a fixed point at λ.
    pub fn distance_init(
        &self,
        r: &Histogram,
        c: &Histogram,
        init: &ScalingInit,
    ) -> SinkhornOutput {
        assert_eq!(r.dim(), self.d, "source dimension mismatch");
        assert_eq!(c.dim(), self.d, "target dimension mismatch");
        if self.degenerate {
            return super::log_domain::solve_init(
                &self.m, self.d, self.lambda, &self.config, r.values(), c.values(), init,
            );
        }
        self.solve_dense(r.values(), c.values(), init, None)
    }

    /// One budget slice: at most `cap` fixed-point iterations from
    /// `init`, replacing the config's iteration cap for this call. A
    /// capped slice is legitimately unconverged, so the approximate-
    /// kernel "unconverged ⇒ rescue" clause is suppressed (poisoned and
    /// diverged states still rescue — through an equally capped
    /// log-domain run). Slices nest: warm-carrying a capped solve's
    /// scalings into the next capped solve reproduces one long run
    /// bit-for-bit on the dense path.
    pub fn distance_capped(
        &self,
        r: &Histogram,
        c: &Histogram,
        init: &ScalingInit,
        cap: usize,
    ) -> SinkhornOutput {
        assert_eq!(r.dim(), self.d, "source dimension mismatch");
        assert_eq!(c.dim(), self.d, "target dimension mismatch");
        if self.degenerate {
            return super::log_domain::solve_capped(
                &self.m, self.d, self.lambda, &self.config, r.values(), c.values(), init,
                cap,
            );
        }
        self.solve_dense(r.values(), c.values(), init, Some(cap))
    }

    /// Certify a solve's scaling state against this engine's exact cost
    /// matrix: a [two-sided bound](certify) on the exact d^λ, sound even
    /// when the engine iterates a truncated or low-rank kernel.
    pub fn certificate(
        &self,
        r: &Histogram,
        c: &Histogram,
        out: &SinkhornOutput,
    ) -> ErrorInterval {
        certify(&self.m, self.d, self.lambda, r.values(), c.values(), out)
    }

    /// Anytime solve: the certified [`SolveOutcome`] under `budget`.
    /// [`SolveBudget::Unbounded`] runs [`Self::distance_init`] unchanged
    /// (bit-identical estimate) and certifies once; bounded budgets
    /// iterate in certificate slices, intersecting the per-slice
    /// intervals.
    pub fn distance_outcome(
        &self,
        r: &Histogram,
        c: &Histogram,
        init: &ScalingInit,
        budget: SolveBudget,
    ) -> SolveOutcome {
        drive_budgeted(
            budget,
            init,
            |seed| self.distance_init(r, c, seed),
            |seed, cap| self.distance_capped(r, c, seed, cap),
            |out| self.certificate(r, c, out),
        )
    }

    /// Batched d_M^λ(r, c_j) for a family of targets (Algorithm 1's
    /// vectorized form). Returns one output per target.
    ///
    /// The batch shares more than a cache-hot K: every member has the same
    /// source r, so each converged solve's row scaling u is carried as the
    /// next target's warm start (the fixed point is unique up to a scalar,
    /// so the carried seed changes only the iteration count, not the
    /// limit). The carry applies in convergence-checked mode; fixed-budget
    /// configs stay cold so their results remain bit-identical to
    /// [`Self::distance`].
    pub fn distances_batch(&self, r: &Histogram, cs: &[Histogram]) -> Vec<SinkhornOutput> {
        let reuse = self.config.check_every != usize::MAX;
        let mut carry = ScalingInit::Cold;
        cs.iter()
            .map(|c| {
                let out = self.distance_init(r, c, &carry);
                if reuse && out.stats.converged {
                    carry = ScalingInit::from_output(&out);
                }
                out
            })
            .collect()
    }

    /// The full transport plan P^λ = diag(u) K diag(v) (dense d×d).
    pub fn plan(&self, r: &Histogram, c: &Histogram) -> (Vec<F>, SinkhornOutput) {
        let out = self.distance(r, c);
        let mut p = vec![0.0; self.d * self.d];
        if out.stats.stabilized {
            // Reconstruct from scalings in log space for safety.
            for i in 0..self.d {
                let lu = out.u[i].max(1e-300).ln();
                for j in 0..self.d {
                    let lv = out.v[j].max(1e-300).ln();
                    p[i * self.d + j] =
                        (lu + lv - self.lambda * self.m[i * self.d + j]).exp();
                }
            }
        } else {
            let mut krow = vec![0.0; self.d];
            for i in 0..self.d {
                let ui = out.u[i];
                self.kernel.write_row(i, &mut krow);
                let prow = &mut p[i * self.d..(i + 1) * self.d];
                for j in 0..self.d {
                    prow[j] = ui * krow[j] * out.v[j];
                }
            }
        }
        (p, out)
    }

    fn solve_dense(
        &self,
        r: &[F],
        c: &[F],
        init: &ScalingInit,
        cap: Option<usize>,
    ) -> SinkhornOutput {
        let d = self.d;
        let cfg = &self.config;
        // x is the paper's iterate (x = 1./u); we track u directly and
        // measure the stopping criterion on u (equivalent up to scaling).
        // The column scaling v is recomputed from u at the top of every
        // iteration, so only u needs seeding.
        let mut u = match init.scalings() {
            Some((su, _)) => {
                assert_eq!(su.len(), d, "warm-start dimension mismatch");
                su.to_vec()
            }
            None => vec![1.0 / d as F; d],
        };
        let prefix = if init.is_cold() {
            super::dense_anneal_prefix(
                &self.m, d, self.lambda, &cfg.schedule, cfg.kernel, r, c, &mut u,
            )
        } else {
            0
        };
        let mut u_prev = vec![0.0; d];
        let mut v = vec![0.0; d];
        let mut stats = SinkhornStats { last_delta: F::INFINITY, ..Default::default() };

        let approx = self.kernel.mass_loss() > 0.0
            || self.kernel.frobenius_budget() > 0.0;
        let convergence_mode = cfg.check_every != usize::MAX;
        let max_iterations = cap.unwrap_or(cfg.max_iterations);
        let mut iter = 0;
        while iter < max_iterations {
            iter += 1;
            // v = c ./ (K' u)
            op_ratio_transpose(&*self.kernel, &u, c, &mut v);
            // u = r ./ (K v)
            std::mem::swap(&mut u, &mut u_prev);
            op_ratio(&*self.kernel, &v, r, &mut u);

            let check = convergence_mode && iter % cfg.check_every == 0;
            // Approximate kernels get a sparse divergence probe in
            // fixed-budget mode too (see the batch path): it never
            // stops early on a small delta, so healthy fixed-budget
            // runs stay bit-identical.
            let probe =
                !convergence_mode && approx && cfg.auto_stabilize && iter % 32 == 0;
            if check || probe {
                let mut acc = 0.0;
                for i in 0..d {
                    let e = u[i] - u_prev[i];
                    acc += e * e;
                }
                let delta = acc.sqrt();
                if check {
                    stats.last_delta = delta;
                    if delta <= cfg.tolerance {
                        stats.converged = true;
                        break;
                    }
                }
                if !delta.is_finite() || delta > 1e130 {
                    // Blow-up: dense-kernel underflow, or an infeasible
                    // truncated support — retry in log domain (same
                    // auto_stabilize gate as the batch path; with the
                    // gate off the diverged state is the caller's). A
                    // capped slice rescues under the same cap so the
                    // budget stays honored.
                    if cfg.auto_stabilize {
                        return super::log_domain::solve_inner(
                            &self.m, d, self.lambda, cfg, r, c, init, cap,
                        );
                    }
                    break;
                }
            }
        }
        stats.iterations = prefix + iter;

        // d = sum(u .* ((K .* M) v)) -- evaluated over the operator's
        // support without materializing K∘M.
        let value = self.kernel.transport_cost(&u, &self.m, &v);

        // Same rescue contract as the batch path: an approximate kernel
        // (truncated / low-rank policy) can make the problem infeasible
        // on its support — the scalings diverge, or the cut-off bins
        // collapse to zero while still carrying mass (a stalled state
        // that even passes the ‖Δu‖ check) — and the exact log-domain
        // solve takes over. At any genuine scaling state u_i > 0
        // wherever r_i > 0, and v likewise; dense solves only hit this
        // via the non-finite guards.
        let poisoned = !value.is_finite()
            || u.iter().any(|x| !x.is_finite())
            || v.iter().any(|x| !x.is_finite())
            || u.iter().zip(r).any(|(&ui, &ri)| ui == 0.0 && ri > 0.0)
            || v.iter().zip(c).any(|(&vi, &ci)| vi == 0.0 && ci > 0.0);
        // A capped slice is legitimately unconverged — only poisoned
        // states rescue there, and under the same cap.
        if cfg.auto_stabilize
            && (poisoned
                || (cap.is_none() && approx && convergence_mode && !stats.converged))
        {
            return super::log_domain::solve_inner(
                &self.m, d, self.lambda, cfg, r, c, init, cap,
            );
        }
        SinkhornOutput { value, u, v, stats }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::{GridMetric, RandomMetric};
    use crate::ot::EmdSolver;
    use crate::simplex::seeded_rng;

    fn setup(d: usize, seed: u64) -> (crate::metric::CostMatrix, Histogram, Histogram) {
        let mut rng = seeded_rng(seed);
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        (m, r, c)
    }

    #[test]
    fn marginals_at_convergence() {
        let (m, r, c) = setup(24, 0);
        let engine = SinkhornEngine::with_config(
            &m,
            SinkhornConfig { lambda: 8.0, tolerance: 1e-12, max_iterations: 50_000, ..Default::default() },
        );
        let (plan, out) = engine.plan(&r, &c);
        assert!(out.stats.converged);
        let d = 24;
        for i in 0..d {
            let row: F = plan[i * d..(i + 1) * d].iter().sum();
            assert!((row - r.values()[i]).abs() < 1e-8, "row {i}");
        }
        for j in 0..d {
            let col: F = (0..d).map(|i| plan[i * d + j]).sum();
            assert!((col - c.values()[j]).abs() < 1e-6, "col {j}");
        }
    }

    #[test]
    fn upper_bounds_exact_emd() {
        // d_M^lam >= d_M always (the entropy penalty only adds cost).
        for seed in 0..5 {
            let (m, r, c) = setup(16, seed);
            let exact = EmdSolver::new(&m).solve(&r, &c).unwrap().cost;
            let sk = SinkhornEngine::new(&m, 9.0).distance(&r, &c);
            assert!(
                sk.value >= exact - 1e-9,
                "sinkhorn {} below exact {}",
                sk.value,
                exact
            );
        }
    }

    #[test]
    fn converges_to_emd_as_lambda_grows() {
        // The Fig. 3 phenomenon: relative gap decreases with lambda.
        let (m, r, c) = setup(12, 3);
        let exact = EmdSolver::new(&m).solve(&r, &c).unwrap().cost;
        let mut prev_gap = F::INFINITY;
        for &lam in &[1.0, 3.0, 9.0, 27.0, 81.0] {
            let cfg = SinkhornConfig {
                lambda: lam,
                tolerance: 1e-10,
                max_iterations: 200_000,
                ..Default::default()
            };
            let sk = SinkhornEngine::with_config(&m, cfg).distance(&r, &c);
            let gap = (sk.value - exact) / exact;
            assert!(gap > -1e-6);
            assert!(gap <= prev_gap + 1e-6, "gap not decreasing at lam={lam}");
            prev_gap = gap;
        }
        assert!(prev_gap < 0.05, "gap at lambda=81 still {prev_gap}");
    }

    #[test]
    fn fixed_budget_runs_exact_count() {
        let (m, r, c) = setup(10, 4);
        let engine = SinkhornEngine::with_config(&m, SinkhornConfig::fixed(9.0, 20));
        let out = engine.distance(&r, &c);
        assert_eq!(out.stats.iterations, 20);
        assert!(!out.stats.converged);
        assert!(out.value.is_finite());
    }

    #[test]
    fn capped_slices_nest_bit_identically() {
        // 3 slices of 8 warm-carried iterations == one fixed 24-iteration
        // run, bit for bit (the property budgeted solves rely on).
        let (m, r, c) = setup(12, 40);
        let engine = SinkhornEngine::with_config(&m, SinkhornConfig::fixed(9.0, 24));
        let straight = engine.distance(&r, &c);
        let mut carry = ScalingInit::Cold;
        let mut sliced = None;
        for _ in 0..3 {
            let out = engine.distance_capped(&r, &c, &carry, 8);
            assert_eq!(out.stats.iterations, 8);
            carry = ScalingInit::from_output(&out);
            sliced = Some(out);
        }
        let sliced = sliced.unwrap();
        assert_eq!(sliced.u, straight.u, "sliced u must equal the straight run's");
        assert_eq!(sliced.v, straight.v);
        assert_eq!(sliced.value, straight.value);
    }

    #[test]
    fn outcome_brackets_and_reproduces_unbounded_solves() {
        use crate::sinkhorn::SolveBudget;
        let (m, r, c) = setup(14, 41);
        let tight = SinkhornEngine::with_config(
            &m,
            SinkhornConfig {
                lambda: 9.0,
                tolerance: 1e-12,
                max_iterations: 200_000,
                ..Default::default()
            },
        );
        let exact = tight.distance(&r, &c).value;
        let engine = SinkhornEngine::new(&m, 9.0);
        // Unbounded: bit-identical estimate, valid certificate.
        let plain = engine.distance(&r, &c);
        let outcome =
            engine.distance_outcome(&r, &c, &ScalingInit::Cold, SolveBudget::Unbounded);
        assert_eq!(outcome.estimate, plain.value);
        assert_eq!(outcome.iterations, plain.stats.iterations);
        assert!(
            outcome.interval.contains(exact),
            "exact {exact} outside {:?}",
            outcome.interval
        );
        // Budgeted: interval brackets the exact value and narrows with
        // budget on the stride lattice.
        let mut prev_width = F::INFINITY;
        for budget in [8, 16, 32, 64] {
            let o = engine.distance_outcome(
                &r,
                &c,
                &ScalingInit::Cold,
                SolveBudget::Iterations(budget),
            );
            assert!(
                o.interval.contains(exact),
                "budget {budget}: exact {exact} outside {:?}",
                o.interval
            );
            assert!(
                o.interval.width() <= prev_width + 1e-12,
                "budget {budget}: width {} above previous {prev_width}",
                o.interval.width()
            );
            prev_width = o.interval.width();
        }
    }

    #[test]
    fn batch_matches_single() {
        // Convergence-checked mode: the batch warm-carries the row scaling
        // across targets, so agreement is to the converged fixed point
        // (not bit-identical stopping states). Tight tolerance makes the
        // fixed point sharp.
        let (m, r, _) = setup(14, 5);
        let mut rng = seeded_rng(99);
        let cs: Vec<Histogram> =
            (0..6).map(|_| Histogram::sample_uniform(14, &mut rng)).collect();
        let engine = SinkhornEngine::with_config(
            &m,
            SinkhornConfig {
                lambda: 7.0,
                tolerance: 1e-11,
                max_iterations: 200_000,
                ..Default::default()
            },
        );
        let batch = engine.distances_batch(&r, &cs);
        for (c, out) in cs.iter().zip(&batch) {
            let single = engine.distance(&r, c);
            assert!(
                (single.value - out.value).abs() < 1e-7 * (1.0 + single.value.abs()),
                "batch {} vs single {}",
                out.value,
                single.value
            );
        }
    }

    #[test]
    fn fixed_budget_batch_is_bit_identical_to_single() {
        // Fixed-budget configs must not warm-carry: the serving path
        // depends on batch == one-by-one exactly.
        let (m, r, _) = setup(12, 15);
        let mut rng = seeded_rng(101);
        let cs: Vec<Histogram> =
            (0..5).map(|_| Histogram::sample_uniform(12, &mut rng)).collect();
        let engine = SinkhornEngine::with_config(&m, SinkhornConfig::fixed(9.0, 25));
        let batch = engine.distances_batch(&r, &cs);
        for (c, out) in cs.iter().zip(&batch) {
            let single = engine.distance(&r, c);
            assert!((single.value - out.value).abs() < 1e-15);
            assert_eq!(out.stats.iterations, 25);
        }
    }

    #[test]
    fn batch_warm_carry_cuts_iterations_on_repeats() {
        // Three identical targets: solves 2 and 3 start at solve 1's
        // fixed point and must converge almost immediately.
        let (m, r, c) = setup(16, 16);
        let engine = SinkhornEngine::with_config(
            &m,
            SinkhornConfig {
                lambda: 9.0,
                tolerance: 1e-10,
                max_iterations: 200_000,
                ..Default::default()
            },
        );
        let batch = engine.distances_batch(&r, &[c.clone(), c.clone(), c]);
        assert!(batch.iter().all(|o| o.stats.converged));
        assert!(
            batch[1].stats.iterations < batch[0].stats.iterations,
            "warm-carried repeat took {} iterations vs cold {}",
            batch[1].stats.iterations,
            batch[0].stats.iterations
        );
        assert!(batch[2].stats.iterations < batch[0].stats.iterations);
        for out in &batch[1..] {
            assert!((out.value - batch[0].value).abs() < 1e-7 * (1.0 + batch[0].value));
        }
    }

    #[test]
    fn warm_start_matches_cold_value() {
        let (m, r, c) = setup(14, 17);
        let engine = SinkhornEngine::with_config(
            &m,
            SinkhornConfig {
                lambda: 8.0,
                tolerance: 1e-10,
                max_iterations: 200_000,
                ..Default::default()
            },
        );
        let cold = engine.distance(&r, &c);
        assert!(cold.stats.converged);
        let warm = engine.distance_init(&r, &c, &ScalingInit::from_output(&cold));
        assert!(warm.stats.converged);
        assert!((warm.value - cold.value).abs() < 1e-7 * (1.0 + cold.value.abs()));
        assert!(warm.stats.iterations <= cold.stats.iterations);
    }

    #[test]
    fn annealed_schedule_matches_fixed_schedule() {
        use crate::sinkhorn::LambdaSchedule;
        let (m, r, c) = setup(12, 18);
        let base = SinkhornConfig {
            lambda: 12.0,
            tolerance: 1e-10,
            max_iterations: 200_000,
            ..Default::default()
        };
        let cold = SinkhornEngine::with_config(&m, base).distance(&r, &c);
        let annealed_cfg =
            SinkhornConfig { schedule: LambdaSchedule::geometric(1.0), ..base };
        let engine = SinkhornEngine::with_config(&m, annealed_cfg);
        assert!(!engine.is_stabilized());
        let annealed = engine.distance(&r, &c);
        assert!(annealed.stats.converged);
        assert!(
            (annealed.value - cold.value).abs() < 1e-7 * (1.0 + cold.value.abs()),
            "annealed {} vs cold {}",
            annealed.value,
            cold.value
        );
    }

    #[test]
    fn auto_stabilizes_on_huge_lambda() {
        // lambda*max(M) >> 700: dense K underflows to all-zero rows.
        let (m, r, c) = setup(8, 6);
        let engine = SinkhornEngine::new(&m, 5_000.0);
        assert!(engine.is_stabilized());
        let out = engine.distance(&r, &c);
        assert!(out.stats.stabilized);
        assert!(out.value.is_finite());
        // At enormous lambda the value approaches the exact EMD.
        let exact = EmdSolver::new(&m).solve(&r, &c).unwrap().cost;
        assert!((out.value - exact) / exact < 0.02);
    }

    #[test]
    fn supports_sparse_histograms() {
        let m = GridMetric::new(3, 3).cost_matrix();
        let r = Histogram::from_weights(&[1.0, 0., 0., 0., 0., 0., 0., 0., 1.0]).unwrap();
        let c = Histogram::from_weights(&[0., 0., 1.0, 0., 0., 0., 1.0, 0., 0.]).unwrap();
        let out = SinkhornEngine::new(&m, 9.0).distance(&r, &c);
        assert!(out.value.is_finite());
        assert!(out.value > 0.0);
    }

    /// Symmetry of the divergence for symmetric M.
    #[test]
    fn prop_symmetric() {
        for seed in 0..16u64 {
            let mut meta = seeded_rng(seed + 7777);
            let d = meta.range_usize(3, 20);
            let (m, r, c) = setup(d, seed);
            let engine = SinkhornEngine::with_config(&m, SinkhornConfig {
                lambda: 6.0, tolerance: 1e-10, max_iterations: 100_000,
                ..Default::default()
            });
            let ab = engine.distance(&r, &c).value;
            let ba = engine.distance(&c, &r).value;
            assert!((ab - ba).abs() < 1e-6 * (1.0 + ab.abs()));
        }
    }

    /// Non-negativity and finiteness across lambda regimes.
    #[test]
    fn prop_finite_nonnegative() {
        for seed in 0..24u64 {
            let mut meta = seeded_rng(seed + 13);
            let lam = meta.range_f64(0.5, 60.0);
            let (m, r, c) = setup(10, seed);
            let out = SinkhornEngine::new(&m, lam).distance(&r, &c);
            assert!(out.value.is_finite());
            assert!(out.value >= -1e-12);
        }
    }
}
