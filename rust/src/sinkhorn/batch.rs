//! Vectorized batch Sinkhorn on the CPU — Algorithm 1's matrix form.
//!
//! The paper's §4.1 observation is that replacing the target histogram c
//! with a column stack C = [c_1 … c_N] turns the per-iteration
//! matrix–vector products into matrix–matrix products, which amortize the
//! pass over K across the batch. [`super::SinkhornEngine::distances_batch`]
//! solves the N problems sequentially (K stays cache-hot but is still
//! streamed once *per problem per iteration*); this module implements the
//! genuinely interleaved version: one pass over K per iteration updates
//! all N columns, i.e. N× less K-traffic. This is the same trade the
//! paper's GPGPU column exploits, expressed in cache terms — and the CPU
//! analogue of what the XLA artifacts do on the runtime path.
//!
//! Layout: U, V are (d, N) row-major panels so the inner loop runs
//! contiguously over the batch dimension.

use super::outcome::{certify, ErrorInterval, SolveBudget, SolveOutcome, CERT_STRIDE};
use super::{
    op_panel_ratio, op_panel_ratio_transpose, ScalingInit, SinkhornConfig,
    SinkhornOutput, SinkhornStats,
};
use crate::linalg::{KernelOp, KernelStats};
use crate::metric::CostMatrix;
use crate::simplex::Histogram;
use crate::F;

/// Batched solver bound to (M, λ); holds the Gibbs kernel behind the
/// [`KernelOp`] interface (dense by default, truncated CSR or low-rank
/// under the config's kernel policy) and iterates whole panels.
pub struct BatchSinkhorn {
    d: usize,
    config: SinkhornConfig,
    kernel: Box<dyn KernelOp>,
    m: Vec<F>,
}

impl BatchSinkhorn {
    pub fn new(metric: &CostMatrix, config: SinkhornConfig) -> Self {
        let d = metric.dim();
        assert!(config.lambda > 0.0, "lambda must be positive");
        let kernel = config.kernel.build(metric.data(), d, config.lambda);
        Self { d, config, kernel, m: metric.data().to_vec() }
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    /// Structure report of the kernel operator the panels iterate with.
    pub fn kernel_stats(&self) -> KernelStats {
        self.kernel.stats()
    }

    /// Solve r vs every column of `cs` in one interleaved iteration.
    /// Returns one output per target (scaling vectors per column).
    pub fn distances(&self, r: &Histogram, cs: &[Histogram]) -> Vec<SinkhornOutput> {
        assert_eq!(r.dim(), self.d, "source dimension mismatch");
        let rs: Vec<&Histogram> = std::iter::repeat(r).take(cs.len()).collect();
        self.distances_paired(&rs, cs)
    }

    /// Fully paired mode: solve (r_j, c_j) for every j.
    pub fn distances_paired(
        &self,
        rs: &[&Histogram],
        cs: &[Histogram],
    ) -> Vec<SinkhornOutput> {
        self.distances_paired_init(rs, cs, &[])
    }

    /// [`Self::distances_paired`] with a per-column seed: `inits[j]`
    /// seeds column j's scaling ([`ScalingInit::Cold`] starts that column
    /// uniform). Pass an empty slice for an all-cold panel. The
    /// ε-scaling prefix runs only when every column is cold — warm
    /// columns are already (near) fixed points at λ★ and annealing them
    /// would discard exactly the structure the warm start carries.
    pub fn distances_paired_init(
        &self,
        rs: &[&Histogram],
        cs: &[Histogram],
        inits: &[ScalingInit],
    ) -> Vec<SinkhornOutput> {
        self.paired_inner(rs, cs, inits, None)
    }

    /// One budget slice of [`Self::distances_paired_init`]: at most
    /// `cap` panel iterations this call. A capped slice is legitimately
    /// unconverged, so only diverged/poisoned columns rescue (through an
    /// equally capped log-domain run); warm-carrying each column's
    /// scalings into the next capped call continues the panel exactly.
    pub fn distances_paired_capped(
        &self,
        rs: &[&Histogram],
        cs: &[Histogram],
        inits: &[ScalingInit],
        cap: usize,
    ) -> Vec<SinkhornOutput> {
        self.paired_inner(rs, cs, inits, Some(cap))
    }

    /// Certify one column's scaling state against this solver's exact
    /// cost matrix (see [`certify`]) — sound under truncated/low-rank
    /// kernel policies because the certificate never reads the
    /// approximate operator.
    pub fn certificate(
        &self,
        r: &Histogram,
        c: &Histogram,
        out: &SinkhornOutput,
    ) -> ErrorInterval {
        certify(&self.m, self.d, self.config.lambda, r.values(), c.values(), out)
    }

    /// Anytime panel solve: certified [`SolveOutcome`]s under `budget`.
    /// [`SolveBudget::Unbounded`] runs [`Self::distances_paired_init`]
    /// unchanged (bit-identical estimates) and certifies each column
    /// once. Bounded budgets advance the whole panel in
    /// [`CERT_STRIDE`]-iteration slices — keeping the one-pass-over-K
    /// amortization — intersecting each column's per-slice certificates,
    /// and stop when every column converged, the iteration budget is
    /// spent, or the deadline passes (at least one slice always runs).
    pub fn outcomes_paired(
        &self,
        rs: &[&Histogram],
        cs: &[Histogram],
        inits: &[ScalingInit],
        budget: SolveBudget,
    ) -> Vec<SolveOutcome> {
        let n = cs.len();
        if n == 0 {
            return Vec::new();
        }
        // PR 9: this panel-sliced loop bypasses `drive_budgeted`, so it
        // consumes all n column attributions itself (unconditionally, to
        // keep any enclosing panel cursor aligned).
        let trace = crate::trace::ctx::take_columns(n);
        let cap = match budget {
            SolveBudget::Unbounded => {
                let outs = self.distances_paired_init(rs, cs, inits);
                return outs
                    .iter()
                    .zip(rs.iter().zip(cs))
                    .map(|(o, (r, c))| {
                        SolveOutcome::from_output(o, self.certificate(r, c, o))
                    })
                    .collect();
            }
            SolveBudget::Iterations(nmax) => Some(nmax.max(1)),
            SolveBudget::Deadline(_) => None,
        };
        let mut carries: Vec<ScalingInit> = if inits.is_empty() {
            vec![ScalingInit::Cold; n]
        } else {
            assert_eq!(inits.len(), n, "warm-start slice size mismatch");
            inits.to_vec()
        };
        let mut intervals = vec![ErrorInterval::UNBOUNDED; n];
        let mut iterations = vec![0usize; n];
        let mut stabilized = vec![false; n];
        let mut spent = 0usize;
        let mut slice_index = 0usize;
        loop {
            let step = match cap {
                Some(nmax) => CERT_STRIDE.min(nmax - spent).max(1),
                None => CERT_STRIDE,
            };
            let slice_start = trace.as_ref().map(|(sink, _, _)| sink.now_us());
            let outs = self.distances_paired_capped(rs, cs, &carries, step);
            spent += step;
            let mut all_done = true;
            for (j, out) in outs.iter().enumerate() {
                iterations[j] += out.stats.iterations;
                stabilized[j] |= out.stats.stabilized;
                intervals[j] =
                    intervals[j].intersect(self.certificate(rs[j], &cs[j], out));
                if !(out.stats.converged
                    || !out.value.is_finite()
                    || out.stats.iterations == 0)
                {
                    all_done = false;
                }
            }
            if let (Some((sink, tenant, cols)), Some(start_us)) = (&trace, slice_start) {
                let end_us = sink.now_us();
                for (j, col) in cols.iter().enumerate() {
                    // Columns that converged in an earlier slice run zero
                    // iterations here; recording them would emit one
                    // no-op span per column per remaining slice and bloat
                    // ring/drop pressure on large panels.
                    if outs[j].stats.iterations == 0 {
                        continue;
                    }
                    if let Some(id) = col {
                        sink.record(crate::trace::Span {
                            trace: *id,
                            stage: crate::trace::Stage::Slice,
                            tenant: *tenant,
                            start_us,
                            end_us,
                            tid: 0,
                            data: crate::trace::SpanData::Slice {
                                index: slice_index,
                                iterations: outs[j].stats.iterations,
                                width: intervals[j].width(),
                            },
                        });
                    }
                }
            }
            slice_index += 1;
            let exhausted = match cap {
                Some(nmax) => spent >= nmax,
                None => budget.expired(),
            };
            if all_done || exhausted {
                return outs
                    .iter()
                    .enumerate()
                    .map(|(j, out)| SolveOutcome {
                        estimate: out.value,
                        interval: intervals[j],
                        iterations: iterations[j],
                        stabilized: stabilized[j],
                        converged: out.stats.converged,
                    })
                    .collect();
            }
            for (carry, out) in carries.iter_mut().zip(&outs) {
                *carry = ScalingInit::from_output(out);
            }
        }
    }

    fn paired_inner(
        &self,
        rs: &[&Histogram],
        cs: &[Histogram],
        inits: &[ScalingInit],
        cap: Option<usize>,
    ) -> Vec<SinkhornOutput> {
        let d = self.d;
        let n = cs.len();
        assert_eq!(rs.len(), n, "paired batch size mismatch");
        if n == 0 {
            return Vec::new();
        }
        assert!(
            inits.is_empty() || inits.len() == n,
            "warm-start slice size mismatch"
        );
        for (k, (r, c)) in rs.iter().zip(cs).enumerate() {
            assert_eq!(r.dim(), d, "pair {k}: source dimension mismatch");
            assert_eq!(c.dim(), d, "pair {k}: target dimension mismatch");
        }

        // Column-stacked panels, row-major (d, n).
        let mut r_panel = vec![0.0; d * n];
        let mut c_panel = vec![0.0; d * n];
        for j in 0..n {
            for i in 0..d {
                r_panel[i * n + j] = rs[j].values()[i];
                c_panel[i * n + j] = cs[j].values()[i];
            }
        }

        let cfg = &self.config;
        let mut u = vec![1.0 / d as F; d * n];
        let mut any_warm = false;
        for (j, seed) in inits.iter().enumerate() {
            if let Some((su, _)) = seed.scalings() {
                assert_eq!(su.len(), d, "pair {j}: warm-start dimension mismatch");
                any_warm = true;
                for i in 0..d {
                    u[i * n + j] = su[i];
                }
            }
        }
        let prefix = if any_warm {
            0
        } else {
            super::anneal_prefix_panel(
                &self.m,
                d,
                self.config.lambda,
                &self.config.schedule,
                self.config.kernel,
                &r_panel,
                &c_panel,
                &mut u,
                n,
            )
        };
        let mut u_prev = vec![0.0; d * n];
        let mut v = vec![0.0; d * n];
        let mut stats = SinkhornStats { last_delta: F::INFINITY, ..Default::default() };

        let approx =
            self.kernel.mass_loss() > 0.0 || self.kernel.frobenius_budget() > 0.0;
        let convergence_mode = cfg.check_every != usize::MAX;
        let max_iterations = cap.unwrap_or(cfg.max_iterations);
        let mut iter = 0;
        let mut diverged = false;
        while iter < max_iterations {
            iter += 1;
            op_panel_ratio_transpose(&*self.kernel, &u, &c_panel, &mut v, n);
            std::mem::swap(&mut u, &mut u_prev);
            op_panel_ratio(&*self.kernel, &v, &r_panel, &mut u, n);

            let check = convergence_mode && iter % cfg.check_every == 0;
            // Approximate kernels also get a sparse divergence probe in
            // *fixed-budget* mode (where no convergence check ever
            // runs): an infeasible truncated support makes the scalings
            // grow geometrically, and without the probe a long budget
            // would ride the runaway into overflow-collapse and serve
            // it. The probe never stops early on a small delta, so
            // healthy fixed-budget runs stay bit-identical.
            let probe =
                !convergence_mode && approx && cfg.auto_stabilize && iter % 32 == 0;
            if check || probe {
                // Max over columns of the per-column delta norm: the batch
                // stops when its *slowest* member meets the tolerance
                // (paper's criterion applied per problem).
                let mut worst = 0.0;
                for j in 0..n {
                    let mut acc = 0.0;
                    for i in 0..d {
                        let e = u[i * n + j] - u_prev[i * n + j];
                        acc += e * e;
                    }
                    worst = F::max(worst, acc);
                }
                let delta = worst.sqrt();
                if check {
                    stats.last_delta = delta;
                    if delta <= cfg.tolerance {
                        stats.converged = true;
                        break;
                    }
                }
                if !delta.is_finite() || delta > 1e130 {
                    // Blow-up: either dense-kernel underflow or — on a
                    // truncated kernel — a genuinely *infeasible* sparse
                    // support (no plan with marginals (r, c) exists on
                    // the kept entries, so the scalings run away).
                    // Iterating further only poisons the panel.
                    diverged = true;
                    break;
                }
            }
        }
        stats.iterations = prefix + iter;

        // Distances: d_j = sum_i u_ij * ((K∘M) v)_ij, fused over the
        // operator's support.
        let mut dist = vec![0.0; n];
        self.kernel.transport_cost_panel(&u, &self.m, &v, n, &mut dist);

        // Divergence rescue, mirroring the scalar engine's log-domain
        // retry on underflow blow-up. An approximate kernel (truncated /
        // low-rank) can make the transport problem infeasible on its
        // support, where the fixed point does not exist: the whole panel
        // is re-solved exactly when the iteration diverged or — for
        // approximate kernels in convergence mode — failed to converge;
        // individually poisoned columns are rescued per column in any
        // mode. A column is poisoned when a scaling went non-finite or
        // *vanished on a positive-mass bin* — at any genuine scaling
        // state u_i > 0 wherever r_i > 0 (and v likewise), while a
        // disconnected truncated support zeroes the cut-off bins and the
        // stalled state even passes the ‖Δu‖ check. Gated on
        // `auto_stabilize` like every other dense→log rescue.
        // A capped slice is legitimately unconverged — only diverged
        // panels and poisoned columns rescue there, under the same cap.
        let rescue_all = cfg.auto_stabilize
            && (diverged
                || (cap.is_none() && approx && convergence_mode && !stats.converged));
        let column_bad = |j: usize, value: F| -> bool {
            if !value.is_finite() {
                return true;
            }
            for i in 0..d {
                let (ui, vi) = (u[i * n + j], v[i * n + j]);
                if !ui.is_finite() || !vi.is_finite() {
                    return true;
                }
                if (ui == 0.0 && rs[j].values()[i] > 0.0)
                    || (vi == 0.0 && cs[j].values()[i] > 0.0)
                {
                    return true;
                }
            }
            false
        };
        (0..n)
            .map(|j| {
                if cfg.auto_stabilize && (rescue_all || column_bad(j, dist[j])) {
                    let init = inits.get(j).cloned().unwrap_or_default();
                    return super::log_domain::solve_inner(
                        &self.m,
                        d,
                        self.config.lambda,
                        cfg,
                        rs[j].values(),
                        cs[j].values(),
                        &init,
                        cap,
                    );
                }
                SinkhornOutput {
                    value: dist[j],
                    u: (0..d).map(|i| u[i * n + j]).collect(),
                    v: (0..d).map(|i| v[i * n + j]).collect(),
                    stats,
                }
            })
            .collect()
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::RandomMetric;
    use crate::simplex::seeded_rng;
    use crate::sinkhorn::SinkhornEngine;

    #[test]
    fn matches_scalar_engine() {
        let mut rng = seeded_rng(0);
        let d = 24;
        let m = RandomMetric::new(d).sample(&mut rng);
        let cfg = SinkhornConfig::fixed(9.0, 20);
        let scalar = SinkhornEngine::with_config(&m, cfg);
        let batch = BatchSinkhorn::new(&m, cfg);
        let r = Histogram::sample_uniform(d, &mut rng);
        let cs: Vec<Histogram> =
            (0..7).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let got = batch.distances(&r, &cs);
        for (c, out) in cs.iter().zip(&got) {
            let want = scalar.distance(&r, c).value;
            assert!(
                (out.value - want).abs() < 1e-10 * (1.0 + want),
                "batch {} vs scalar {want}",
                out.value
            );
        }
    }

    #[test]
    fn paired_mode_matches_per_pair() {
        let mut rng = seeded_rng(1);
        let d = 16;
        let m = RandomMetric::new(d).sample(&mut rng);
        let cfg = SinkhornConfig::fixed(5.0, 30);
        let scalar = SinkhornEngine::with_config(&m, cfg);
        let batch = BatchSinkhorn::new(&m, cfg);
        let rs: Vec<Histogram> =
            (0..5).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let cs: Vec<Histogram> =
            (0..5).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let r_refs: Vec<&Histogram> = rs.iter().collect();
        let got = batch.distances_paired(&r_refs, &cs);
        for j in 0..5 {
            let want = scalar.distance(&rs[j], &cs[j]).value;
            assert!((got[j].value - want).abs() < 1e-10 * (1.0 + want));
        }
    }

    #[test]
    fn converged_mode_reaches_tolerance() {
        let mut rng = seeded_rng(2);
        let d = 12;
        let m = RandomMetric::new(d).sample(&mut rng);
        let cfg = SinkhornConfig {
            lambda: 6.0,
            tolerance: 1e-8,
            max_iterations: 100_000,
            ..Default::default()
        };
        let batch = BatchSinkhorn::new(&m, cfg);
        let r = Histogram::sample_uniform(d, &mut rng);
        let cs: Vec<Histogram> =
            (0..3).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let got = batch.distances(&r, &cs);
        assert!(got[0].stats.converged);
        // Scaling marginals approximately satisfied for each column.
        for (c, out) in cs.iter().zip(&got) {
            let mut col = vec![0.0; d];
            for j in 0..d {
                let mut acc = 0.0;
                for i in 0..d {
                    acc += out.u[i] * (-cfg.lambda * m.get(i, j)).exp();
                }
                col[j] = acc * out.v[j];
            }
            for (got_c, want_c) in col.iter().zip(c.values()) {
                assert!((got_c - want_c).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn warm_inits_cut_panel_iterations() {
        let mut rng = seeded_rng(21);
        let d = 16;
        let m = RandomMetric::new(d).sample(&mut rng);
        let cfg = SinkhornConfig {
            lambda: 9.0,
            tolerance: 1e-9,
            max_iterations: 100_000,
            ..Default::default()
        };
        let batch = BatchSinkhorn::new(&m, cfg);
        let r = Histogram::sample_uniform(d, &mut rng);
        let cs: Vec<Histogram> =
            (0..4).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let r_refs: Vec<&Histogram> = (0..4).map(|_| &r).collect();
        let cold = batch.distances_paired(&r_refs, &cs);
        assert!(cold[0].stats.converged);
        let inits: Vec<crate::sinkhorn::ScalingInit> =
            cold.iter().map(crate::sinkhorn::ScalingInit::from_output).collect();
        let warm = batch.distances_paired_init(&r_refs, &cs, &inits);
        assert!(warm[0].stats.converged);
        assert!(
            warm[0].stats.iterations < cold[0].stats.iterations,
            "warm panel took {} iterations vs cold {}",
            warm[0].stats.iterations,
            cold[0].stats.iterations
        );
        for (a, b) in warm.iter().zip(&cold) {
            assert!((a.value - b.value).abs() < 1e-7 * (1.0 + b.value));
        }
    }

    #[test]
    fn annealed_panel_matches_cold_panel() {
        use crate::sinkhorn::LambdaSchedule;
        let mut rng = seeded_rng(22);
        let d = 12;
        let m = RandomMetric::new(d).sample(&mut rng);
        let base = SinkhornConfig {
            lambda: 14.0,
            tolerance: 1e-9,
            max_iterations: 100_000,
            ..Default::default()
        };
        let r = Histogram::sample_uniform(d, &mut rng);
        let cs: Vec<Histogram> =
            (0..3).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let cold = BatchSinkhorn::new(&m, base).distances(&r, &cs);
        let annealed_cfg =
            SinkhornConfig { schedule: LambdaSchedule::geometric(1.5), ..base };
        let annealed = BatchSinkhorn::new(&m, annealed_cfg).distances(&r, &cs);
        assert!(annealed[0].stats.converged);
        for (a, b) in annealed.iter().zip(&cold) {
            assert!(
                (a.value - b.value).abs() < 1e-7 * (1.0 + b.value),
                "annealed {} vs cold {}",
                a.value,
                b.value
            );
        }
    }

    #[test]
    fn panel_outcomes_bracket_and_reproduce_unbounded() {
        let mut rng = seeded_rng(23);
        let d = 12;
        let m = RandomMetric::new(d).sample(&mut rng);
        let lam = 9.0;
        let cfg = SinkhornConfig::fixed(lam, 40);
        let batch = BatchSinkhorn::new(&m, cfg);
        let rs: Vec<Histogram> =
            (0..4).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let cs: Vec<Histogram> =
            (0..4).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let r_refs: Vec<&Histogram> = rs.iter().collect();
        // References via the tight scalar engine.
        let tight = SinkhornEngine::with_config(
            &m,
            SinkhornConfig {
                lambda: lam,
                tolerance: 1e-12,
                max_iterations: 200_000,
                ..Default::default()
            },
        );
        let exact: Vec<F> =
            (0..4).map(|j| tight.distance(&rs[j], &cs[j]).value).collect();
        // Unbounded reproduces distances_paired bit-for-bit.
        let plain = batch.distances_paired(&r_refs, &cs);
        let outcomes =
            batch.outcomes_paired(&r_refs, &cs, &[], SolveBudget::Unbounded);
        for j in 0..4 {
            assert_eq!(outcomes[j].estimate, plain[j].value);
            assert!(
                outcomes[j].interval.contains(exact[j]),
                "pair {j}: exact {} outside {:?}",
                exact[j],
                outcomes[j].interval
            );
        }
        // Budgeted: per-column widths shrink with the budget.
        let narrow = batch.outcomes_paired(
            &r_refs,
            &cs,
            &[],
            SolveBudget::Iterations(32),
        );
        let wide =
            batch.outcomes_paired(&r_refs, &cs, &[], SolveBudget::Iterations(8));
        for j in 0..4 {
            assert!(wide[j].interval.contains(exact[j]), "pair {j} at budget 8");
            assert!(narrow[j].interval.contains(exact[j]), "pair {j} at budget 32");
            assert!(
                narrow[j].interval.width() <= wide[j].interval.width() + 1e-12,
                "pair {j}: width grew {} -> {}",
                wide[j].interval.width(),
                narrow[j].interval.width()
            );
            assert_eq!(wide[j].iterations, 8);
            assert_eq!(narrow[j].iterations, 32);
        }
    }

    #[test]
    fn empty_batch_is_fine() {
        let mut rng = seeded_rng(3);
        let m = RandomMetric::new(8).sample(&mut rng);
        let batch = BatchSinkhorn::new(&m, SinkhornConfig::fixed(9.0, 5));
        let r = Histogram::uniform(8);
        assert!(batch.distances(&r, &[]).is_empty());
    }

    #[test]
    fn handles_sparse_columns() {
        let mut rng = seeded_rng(4);
        let d = 10;
        let m = RandomMetric::new(d).sample(&mut rng);
        let batch = BatchSinkhorn::new(&m, SinkhornConfig::fixed(9.0, 50));
        let r = Histogram::sample_uniform(d, &mut rng);
        let mut w = vec![0.0; d];
        w[3] = 1.0;
        let dirac = Histogram::from_weights(&w).unwrap();
        let dense = Histogram::sample_uniform(d, &mut rng);
        let out = batch.distances(&r, &[dirac, dense]);
        assert!(out.iter().all(|o| o.value.is_finite() && o.value >= 0.0));
    }
}
