//! Vectorized batch Sinkhorn on the CPU — Algorithm 1's matrix form.
//!
//! The paper's §4.1 observation is that replacing the target histogram c
//! with a column stack C = [c_1 … c_N] turns the per-iteration
//! matrix–vector products into matrix–matrix products, which amortize the
//! pass over K across the batch. [`super::SinkhornEngine::distances_batch`]
//! solves the N problems sequentially (K stays cache-hot but is still
//! streamed once *per problem per iteration*); this module implements the
//! genuinely interleaved version: one pass over K per iteration updates
//! all N columns, i.e. N× less K-traffic. This is the same trade the
//! paper's GPGPU column exploits, expressed in cache terms — and the CPU
//! analogue of what the XLA artifacts do on the runtime path.
//!
//! Layout: U, V are (d, N) row-major panels so the inner loop runs
//! contiguously over the batch dimension.

use super::{panel_ratio, ScalingInit, SinkhornConfig, SinkhornOutput, SinkhornStats};
use crate::metric::CostMatrix;
use crate::simplex::Histogram;
use crate::F;

/// Batched solver bound to (M, λ); precomputes K and Kᵀ like the scalar
/// engine but iterates whole panels.
pub struct BatchSinkhorn {
    d: usize,
    config: SinkhornConfig,
    k: Vec<F>,
    kt: Vec<F>,
    m: Vec<F>,
}

impl BatchSinkhorn {
    pub fn new(metric: &CostMatrix, config: SinkhornConfig) -> Self {
        let d = metric.dim();
        assert!(config.lambda > 0.0, "lambda must be positive");
        let mut k = vec![0.0; d * d];
        for (out, &mij) in k.iter_mut().zip(metric.data()) {
            *out = (-config.lambda * mij).exp();
        }
        let mut kt = vec![0.0; d * d];
        for i in 0..d {
            for j in 0..d {
                kt[j * d + i] = k[i * d + j];
            }
        }
        Self { d, config, k, kt, m: metric.data().to_vec() }
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    /// Solve r vs every column of `cs` in one interleaved iteration.
    /// Returns one output per target (scaling vectors per column).
    pub fn distances(&self, r: &Histogram, cs: &[Histogram]) -> Vec<SinkhornOutput> {
        assert_eq!(r.dim(), self.d, "source dimension mismatch");
        let rs: Vec<&Histogram> = std::iter::repeat(r).take(cs.len()).collect();
        self.distances_paired(&rs, cs)
    }

    /// Fully paired mode: solve (r_j, c_j) for every j.
    pub fn distances_paired(
        &self,
        rs: &[&Histogram],
        cs: &[Histogram],
    ) -> Vec<SinkhornOutput> {
        self.distances_paired_init(rs, cs, &[])
    }

    /// [`Self::distances_paired`] with a per-column warm start: `inits[j]`
    /// seeds column j's scaling (None starts that column uniform). Pass an
    /// empty slice for an all-cold panel. The ε-scaling prefix runs only
    /// when every column is cold — warm columns are already (near) fixed
    /// points at λ★ and annealing them would discard exactly the structure
    /// the warm start carries.
    pub fn distances_paired_init(
        &self,
        rs: &[&Histogram],
        cs: &[Histogram],
        inits: &[Option<ScalingInit>],
    ) -> Vec<SinkhornOutput> {
        let d = self.d;
        let n = cs.len();
        assert_eq!(rs.len(), n, "paired batch size mismatch");
        if n == 0 {
            return Vec::new();
        }
        assert!(
            inits.is_empty() || inits.len() == n,
            "warm-start slice size mismatch"
        );
        for (k, (r, c)) in rs.iter().zip(cs).enumerate() {
            assert_eq!(r.dim(), d, "pair {k}: source dimension mismatch");
            assert_eq!(c.dim(), d, "pair {k}: target dimension mismatch");
        }

        // Column-stacked panels, row-major (d, n).
        let mut r_panel = vec![0.0; d * n];
        let mut c_panel = vec![0.0; d * n];
        for j in 0..n {
            for i in 0..d {
                r_panel[i * n + j] = rs[j].values()[i];
                c_panel[i * n + j] = cs[j].values()[i];
            }
        }

        let cfg = &self.config;
        let mut u = vec![1.0 / d as F; d * n];
        let mut any_warm = false;
        for (j, seed) in inits.iter().enumerate() {
            if let Some(seed) = seed {
                assert_eq!(seed.u.len(), d, "pair {j}: warm-start dimension mismatch");
                any_warm = true;
                for i in 0..d {
                    u[i * n + j] = seed.u[i];
                }
            }
        }
        let prefix = if any_warm {
            0
        } else {
            super::anneal_prefix_panel(
                &self.m,
                d,
                self.config.lambda,
                &self.config.schedule,
                &r_panel,
                &c_panel,
                &mut u,
                n,
            )
        };
        let mut u_prev = vec![0.0; d * n];
        let mut v = vec![0.0; d * n];
        let mut stats = SinkhornStats { last_delta: F::INFINITY, ..Default::default() };

        let mut iter = 0;
        while iter < cfg.max_iterations {
            iter += 1;
            panel_ratio(&self.kt, &u, &c_panel, &mut v, d, n);
            std::mem::swap(&mut u, &mut u_prev);
            panel_ratio(&self.k, &v, &r_panel, &mut u, d, n);

            let check = cfg.check_every != usize::MAX && iter % cfg.check_every == 0;
            if check {
                // Max over columns of the per-column delta norm: the batch
                // stops when its *slowest* member meets the tolerance
                // (paper's criterion applied per problem).
                let mut worst = 0.0;
                for j in 0..n {
                    let mut acc = 0.0;
                    for i in 0..d {
                        let e = u[i * n + j] - u_prev[i * n + j];
                        acc += e * e;
                    }
                    worst = F::max(worst, acc);
                }
                stats.last_delta = worst.sqrt();
                if stats.last_delta <= cfg.tolerance {
                    stats.converged = true;
                    break;
                }
            }
        }
        stats.iterations = prefix + iter;

        // Distances: d_j = sum_i u_ij * ((K∘M) v)_ij, fused rowwise.
        let mut dist = vec![0.0; n];
        let mut row_acc = vec![0.0; n];
        for i in 0..d {
            let krow = &self.k[i * d..(i + 1) * d];
            let mrow = &self.m[i * d..(i + 1) * d];
            row_acc.iter_mut().for_each(|x| *x = 0.0);
            for kk in 0..d {
                let w = krow[kk] * mrow[kk];
                if w == 0.0 {
                    continue;
                }
                let vrow = &v[kk * n..(kk + 1) * n];
                for (acc, &vj) in row_acc.iter_mut().zip(vrow) {
                    *acc += w * vj;
                }
            }
            let urow = &u[i * n..(i + 1) * n];
            for j in 0..n {
                dist[j] += urow[j] * row_acc[j];
            }
        }

        (0..n)
            .map(|j| SinkhornOutput {
                value: dist[j],
                u: (0..d).map(|i| u[i * n + j]).collect(),
                v: (0..d).map(|i| v[i * n + j]).collect(),
                stats,
            })
            .collect()
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::RandomMetric;
    use crate::simplex::seeded_rng;
    use crate::sinkhorn::SinkhornEngine;

    #[test]
    fn matches_scalar_engine() {
        let mut rng = seeded_rng(0);
        let d = 24;
        let m = RandomMetric::new(d).sample(&mut rng);
        let cfg = SinkhornConfig::fixed(9.0, 20);
        let scalar = SinkhornEngine::with_config(&m, cfg);
        let batch = BatchSinkhorn::new(&m, cfg);
        let r = Histogram::sample_uniform(d, &mut rng);
        let cs: Vec<Histogram> =
            (0..7).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let got = batch.distances(&r, &cs);
        for (c, out) in cs.iter().zip(&got) {
            let want = scalar.distance(&r, c).value;
            assert!(
                (out.value - want).abs() < 1e-10 * (1.0 + want),
                "batch {} vs scalar {want}",
                out.value
            );
        }
    }

    #[test]
    fn paired_mode_matches_per_pair() {
        let mut rng = seeded_rng(1);
        let d = 16;
        let m = RandomMetric::new(d).sample(&mut rng);
        let cfg = SinkhornConfig::fixed(5.0, 30);
        let scalar = SinkhornEngine::with_config(&m, cfg);
        let batch = BatchSinkhorn::new(&m, cfg);
        let rs: Vec<Histogram> =
            (0..5).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let cs: Vec<Histogram> =
            (0..5).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let r_refs: Vec<&Histogram> = rs.iter().collect();
        let got = batch.distances_paired(&r_refs, &cs);
        for j in 0..5 {
            let want = scalar.distance(&rs[j], &cs[j]).value;
            assert!((got[j].value - want).abs() < 1e-10 * (1.0 + want));
        }
    }

    #[test]
    fn converged_mode_reaches_tolerance() {
        let mut rng = seeded_rng(2);
        let d = 12;
        let m = RandomMetric::new(d).sample(&mut rng);
        let cfg = SinkhornConfig {
            lambda: 6.0,
            tolerance: 1e-8,
            max_iterations: 100_000,
            ..Default::default()
        };
        let batch = BatchSinkhorn::new(&m, cfg);
        let r = Histogram::sample_uniform(d, &mut rng);
        let cs: Vec<Histogram> =
            (0..3).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let got = batch.distances(&r, &cs);
        assert!(got[0].stats.converged);
        // Scaling marginals approximately satisfied for each column.
        for (c, out) in cs.iter().zip(&got) {
            let mut col = vec![0.0; d];
            for j in 0..d {
                let mut acc = 0.0;
                for i in 0..d {
                    acc += out.u[i] * (-cfg.lambda * m.get(i, j)).exp();
                }
                col[j] = acc * out.v[j];
            }
            for (got_c, want_c) in col.iter().zip(c.values()) {
                assert!((got_c - want_c).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn warm_inits_cut_panel_iterations() {
        let mut rng = seeded_rng(21);
        let d = 16;
        let m = RandomMetric::new(d).sample(&mut rng);
        let cfg = SinkhornConfig {
            lambda: 9.0,
            tolerance: 1e-9,
            max_iterations: 100_000,
            ..Default::default()
        };
        let batch = BatchSinkhorn::new(&m, cfg);
        let r = Histogram::sample_uniform(d, &mut rng);
        let cs: Vec<Histogram> =
            (0..4).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let r_refs: Vec<&Histogram> = (0..4).map(|_| &r).collect();
        let cold = batch.distances_paired(&r_refs, &cs);
        assert!(cold[0].stats.converged);
        let inits: Vec<Option<crate::sinkhorn::ScalingInit>> =
            cold.iter().map(|o| Some(crate::sinkhorn::ScalingInit::from_output(o))).collect();
        let warm = batch.distances_paired_init(&r_refs, &cs, &inits);
        assert!(warm[0].stats.converged);
        assert!(
            warm[0].stats.iterations < cold[0].stats.iterations,
            "warm panel took {} iterations vs cold {}",
            warm[0].stats.iterations,
            cold[0].stats.iterations
        );
        for (a, b) in warm.iter().zip(&cold) {
            assert!((a.value - b.value).abs() < 1e-7 * (1.0 + b.value));
        }
    }

    #[test]
    fn annealed_panel_matches_cold_panel() {
        use crate::sinkhorn::LambdaSchedule;
        let mut rng = seeded_rng(22);
        let d = 12;
        let m = RandomMetric::new(d).sample(&mut rng);
        let base = SinkhornConfig {
            lambda: 14.0,
            tolerance: 1e-9,
            max_iterations: 100_000,
            ..Default::default()
        };
        let r = Histogram::sample_uniform(d, &mut rng);
        let cs: Vec<Histogram> =
            (0..3).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let cold = BatchSinkhorn::new(&m, base).distances(&r, &cs);
        let annealed_cfg =
            SinkhornConfig { schedule: LambdaSchedule::geometric(1.5), ..base };
        let annealed = BatchSinkhorn::new(&m, annealed_cfg).distances(&r, &cs);
        assert!(annealed[0].stats.converged);
        for (a, b) in annealed.iter().zip(&cold) {
            assert!(
                (a.value - b.value).abs() < 1e-7 * (1.0 + b.value),
                "annealed {} vs cold {}",
                a.value,
                b.value
            );
        }
    }

    #[test]
    fn empty_batch_is_fine() {
        let mut rng = seeded_rng(3);
        let m = RandomMetric::new(8).sample(&mut rng);
        let batch = BatchSinkhorn::new(&m, SinkhornConfig::fixed(9.0, 5));
        let r = Histogram::uniform(8);
        assert!(batch.distances(&r, &[]).is_empty());
    }

    #[test]
    fn handles_sparse_columns() {
        let mut rng = seeded_rng(4);
        let d = 10;
        let m = RandomMetric::new(d).sample(&mut rng);
        let batch = BatchSinkhorn::new(&m, SinkhornConfig::fixed(9.0, 50));
        let r = Histogram::sample_uniform(d, &mut rng);
        let mut w = vec![0.0; d];
        w[3] = 1.0;
        let dirac = Histogram::from_weights(&w).unwrap();
        let dense = Histogram::sample_uniform(d, &mut rng);
        let out = batch.distances(&r, &[dirac, dense]);
        assert!(out.iter().all(|o| o.value.is_finite() && o.value >= 0.0));
    }
}
