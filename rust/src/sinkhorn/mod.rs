//! Pure-Rust Sinkhorn engine — the paper's Algorithm 1 on the CPU.
//!
//! This is the "Sinkhorn CPU" series of Figure 4 and the reference
//! implementation the XLA/PJRT path ([`crate::runtime`]) is validated
//! against. Three execution modes:
//!
//! * [`SinkhornEngine::distance`] — single pair, with the paper's
//!   convergence criterion ‖x − x'‖₂ ≤ tol or a fixed iteration budget;
//! * [`SinkhornEngine::distances_batch`] — one source against a family
//!   C = [c_1 … c_N], vectorized exactly like Algorithm 1's matrix form;
//! * log-domain stabilized updates ([`log_domain`]) for large λ where
//!   K = e^{−λM} underflows.
//!
//! The Independence kernel (Property 2: d_{M,0} = rᵀMc, the α = 0 extreme
//! of the Sinkhorn family) lives in [`independence`].

pub mod alpha;
pub mod batch;
mod engine;
pub mod independence;
pub mod log_domain;

pub use alpha::{AlphaConfig, AlphaOutput, AlphaSinkhorn};
pub use batch::BatchSinkhorn;
pub use engine::{SinkhornEngine, SinkhornOutput, SinkhornStats};
pub use independence::{independence_distance, IndependenceKernel};

use crate::F;

/// Configuration of the Sinkhorn-Knopp iteration.
#[derive(Debug, Clone, Copy)]
pub struct SinkhornConfig {
    /// Entropic weight λ of Equation (2); K = exp(−λM).
    pub lambda: F,
    /// Stop when ‖x − x'‖₂ ≤ tol (the paper uses 0.01 in §5.3–5.4).
    pub tolerance: F,
    /// Hard iteration cap. The paper's MNIST run fixes 20 iterations and
    /// §5.4 recommends a fixed budget on parallel platforms.
    pub max_iterations: usize,
    /// Check the stopping criterion every `check_every` iterations (the
    /// paper notes convergence tracking "can be costly on parallel
    /// platforms"; on CPU a stride of 1 is fine, the runtime path uses a
    /// fixed budget instead).
    pub check_every: usize,
    /// Switch to log-domain updates when exp(−λ·max(M)) would underflow.
    pub auto_stabilize: bool,
}

impl Default for SinkhornConfig {
    fn default() -> Self {
        Self {
            lambda: 9.0,
            tolerance: 0.01,
            max_iterations: 10_000,
            check_every: 1,
            auto_stabilize: true,
        }
    }
}

/// True when K = e^{−λM} underflows badly enough that the dense fixed
/// point collapses: more than half of the *off-diagonal* kernel is
/// exactly zero (the diagonal is always 1 since m_ii = 0). This single
/// predicate is the routing criterion shared by [`SinkhornEngine`]'s
/// auto-stabilization, the Greenkhorn backend, and the backend router.
pub fn dense_kernel_degenerate(metric: &crate::metric::CostMatrix, lambda: F) -> bool {
    let d = metric.dim();
    degenerate_off_diagonal(metric.data().iter().map(|&mij| (-lambda * mij).exp()), d)
}

/// The same criterion over an already materialized row-major kernel
/// (spares callers that hold K a second O(d²) exp pass).
pub(crate) fn degenerate_off_diagonal(k: impl Iterator<Item = F>, d: usize) -> bool {
    let off_diag = (d * d - d).max(1);
    let zeros = k
        .enumerate()
        .filter(|&(idx, v)| idx / d != idx % d && v == 0.0)
        .count();
    zeros as f64 > 0.5 * off_diag as f64
}

impl SinkhornConfig {
    /// Fixed-budget config (no convergence checks) — the serving-path
    /// setting: exactly `n` iterations.
    pub fn fixed(lambda: F, n: usize) -> Self {
        Self {
            lambda,
            tolerance: 0.0,
            max_iterations: n,
            check_every: usize::MAX,
            auto_stabilize: true,
        }
    }

    /// Convergence-driven config with the paper's 0.01 tolerance.
    pub fn converged(lambda: F) -> Self {
        Self { lambda, ..Default::default() }
    }
}
