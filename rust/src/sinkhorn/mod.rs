//! Pure-Rust Sinkhorn engine — the paper's Algorithm 1 on the CPU.
//!
//! This is the "Sinkhorn CPU" series of Figure 4 and the reference
//! implementation the XLA/PJRT path ([`crate::runtime`]) is validated
//! against. Three execution modes:
//!
//! * [`SinkhornEngine::distance`] — single pair, with the paper's
//!   convergence criterion ‖x − x'‖₂ ≤ tol or a fixed iteration budget;
//! * [`SinkhornEngine::distances_batch`] — one source against a family
//!   C = [c_1 … c_N], vectorized exactly like Algorithm 1's matrix form;
//! * log-domain stabilized updates ([`log_domain`]) for large λ where
//!   K = e^{−λM} underflows.
//!
//! The Independence kernel (Property 2: d_{M,0} = rᵀMc, the α = 0 extreme
//! of the Sinkhorn family) lives in [`independence`].

pub mod alpha;
pub mod batch;
mod engine;
pub mod independence;
pub mod log_domain;
pub mod outcome;
pub mod warm;

pub use alpha::{AlphaConfig, AlphaOutput, AlphaSinkhorn};
pub use batch::BatchSinkhorn;
pub use engine::{SinkhornEngine, SinkhornOutput, SinkhornStats};
pub use independence::{independence_distance, IndependenceKernel, PreparedHistogram};
pub use outcome::{certify, ErrorInterval, SolveBudget, SolveOutcome, CERT_STRIDE};
pub use warm::{fingerprint_pair, WarmCounters, WarmKey, WarmStartStore};

use crate::linalg::{KernelOp, KernelPolicy};
use crate::F;

/// Configuration of the Sinkhorn-Knopp iteration.
#[derive(Debug, Clone, Copy)]
pub struct SinkhornConfig {
    /// Entropic weight λ of Equation (2); K = exp(−λM).
    pub lambda: F,
    /// Stop when ‖x − x'‖₂ ≤ tol (the paper uses 0.01 in §5.3–5.4).
    pub tolerance: F,
    /// Hard iteration cap. The paper's MNIST run fixes 20 iterations and
    /// §5.4 recommends a fixed budget on parallel platforms.
    pub max_iterations: usize,
    /// Check the stopping criterion every `check_every` iterations (the
    /// paper notes convergence tracking "can be costly on parallel
    /// platforms"; on CPU a stride of 1 is fine, the runtime path uses a
    /// fixed budget instead).
    pub check_every: usize,
    /// Switch to log-domain updates when exp(−λ·max(M)) would underflow.
    pub auto_stabilize: bool,
    /// ε-scaling schedule: anneal λ upward through prefix stages before
    /// the main loop runs at [`Self::lambda`]. [`LambdaSchedule::Fixed`]
    /// (the default) recovers the classic single-λ iteration exactly.
    pub schedule: LambdaSchedule,
    /// How the Gibbs kernel K = e^{−λM} is materialized: dense (the
    /// default, exact), threshold-truncated CSR, a pivoted-Cholesky
    /// low-rank factorization, or auto-resolved per (d, λ). See
    /// [`crate::linalg::KernelPolicy`]. Honored by the dense engine and
    /// the batch solver (and the backends built on them); the
    /// log-domain path never materializes K and Greenkhorn's
    /// incremental caches are inherently dense, so both ignore it.
    pub kernel: KernelPolicy,
}

impl Default for SinkhornConfig {
    fn default() -> Self {
        Self {
            lambda: 9.0,
            tolerance: 0.01,
            max_iterations: 10_000,
            check_every: 1,
            auto_stabilize: true,
            schedule: LambdaSchedule::Fixed,
            kernel: KernelPolicy::Dense,
        }
    }
}

/// ε-scaling (λ-annealing) schedule.
///
/// Sinkhorn's fixed point mixes slowly at large λ (the kernel K = e^{−λM}
/// is nearly diagonal, so mass moves one neighborhood per iteration).
/// ε-scaling solves a short sequence of *easier* problems first: a few
/// iterations at λ₀, then λ₀·factor, …, carrying the scaling across
/// stages, until the target λ★ is reached and the normal convergence
/// loop finishes the job. The carried scaling is transferred between
/// stages by fixing the dual potentials α = log(u)/λ, i.e.
/// `u ← u^(λ_next/λ_prev)` (renormalized so the stopping criterion keeps
/// its scale), the standard transfer in Peyré & Cuturi §4.2.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum LambdaSchedule {
    /// No annealing: run every iteration at the target λ.
    #[default]
    Fixed,
    /// Geometric annealing λ₀ → λ★: prefix stages at
    /// λ₀, λ₀·factor, λ₀·factor², … (strictly below λ★), each running
    /// `stage_iterations` fixed-point iterations.
    Geometric {
        /// First (smallest) stage λ. Must be positive.
        lambda0: F,
        /// Multiplicative step between stages. Must exceed 1.
        factor: F,
        /// Fixed-point iterations spent per prefix stage.
        stage_iterations: usize,
    },
}

impl LambdaSchedule {
    /// A geometric schedule with the usual ×3 step and a 30-iteration
    /// stage budget.
    pub fn geometric(lambda0: F) -> Self {
        LambdaSchedule::Geometric { lambda0, factor: 3.0, stage_iterations: 30 }
    }

    /// The prefix stage λ values for a target λ★ (strictly increasing,
    /// all `< lambda_star`; empty for [`Self::Fixed`] or when λ₀ ≥ λ★).
    pub fn prefix_stages(&self, lambda_star: F) -> Vec<F> {
        match *self {
            LambdaSchedule::Fixed => Vec::new(),
            LambdaSchedule::Geometric { lambda0, factor, .. } => {
                assert!(lambda0 > 0.0, "schedule lambda0 must be positive");
                assert!(factor > 1.0, "schedule factor must exceed 1");
                let mut stages = Vec::new();
                let mut lam = lambda0;
                // 64 stages spans 1e30+ of dynamic range at factor ≈ 3;
                // the cap only guards against pathological factors.
                while lam < lambda_star && stages.len() < 64 {
                    stages.push(lam);
                    lam *= factor;
                }
                stages
            }
        }
    }

    /// Iterations spent per prefix stage (0 for [`Self::Fixed`]).
    pub fn stage_iterations(&self) -> usize {
        match *self {
            LambdaSchedule::Fixed => 0,
            LambdaSchedule::Geometric { stage_iterations, .. } => stage_iterations,
        }
    }
}

/// How a solve is seeded. [`ScalingInit::Cold`] (the default) starts
/// from the uniform scaling and runs the ε-scaling prefix when the
/// config carries one; [`ScalingInit::Warm`] resumes from a previous
/// scaling pair — a converged solution served from a [`WarmStartStore`],
/// or a budget slice's carry state. Dense solvers use the scalings
/// directly; the log-domain path converts to potentials (f, g) =
/// (log u, log v) with zero-mass bins mapping to −∞.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum ScalingInit {
    /// Start from scratch (uniform scaling + anneal prefix, if any).
    #[default]
    Cold,
    /// Resume from a carried scaling pair.
    Warm {
        /// Row scaling (support-aligned with r).
        u: Vec<F>,
        /// Column scaling (support-aligned with c).
        v: Vec<F>,
    },
}

impl ScalingInit {
    /// A warm seed from explicit scaling vectors.
    pub fn warm(u: Vec<F>, v: Vec<F>) -> Self {
        ScalingInit::Warm { u, v }
    }

    /// Capture a solve's converged scalings as a future warm start.
    pub fn from_output(out: &SinkhornOutput) -> Self {
        ScalingInit::Warm { u: out.u.clone(), v: out.v.clone() }
    }

    /// Whether this is the cold (from-scratch) seed.
    pub fn is_cold(&self) -> bool {
        matches!(self, ScalingInit::Cold)
    }

    /// The carried scaling pair, if warm.
    pub fn scalings(&self) -> Option<(&[F], &[F])> {
        match self {
            ScalingInit::Cold => None,
            ScalingInit::Warm { u, v } => Some((u, v)),
        }
    }

    /// Log-domain potentials (f, g) = (log u, log v) of a warm seed;
    /// zeros map to −∞. `None` when cold.
    pub fn potentials(&self) -> Option<(Vec<F>, Vec<F>)> {
        let ln0 = |x: &F| if *x > 0.0 { x.ln() } else { F::NEG_INFINITY };
        self.scalings().map(|(u, v)| {
            (u.iter().map(ln0).collect(), v.iter().map(ln0).collect())
        })
    }
}

/// out = num ./ (mat · x), guarding 0/0 -> 0 (zero-mass bins stay inert).
/// Shared by the dense engine, the anneal prefix and the Greenkhorn
/// backend's derived-scaling setup.
#[inline]
pub(crate) fn kernel_ratio(mat: &[F], x: &[F], num: &[F], out: &mut [F], d: usize) {
    for i in 0..d {
        let den = crate::linalg::dot(&mat[i * d..(i + 1) * d], x);
        out[i] = if den > 0.0 { num[i] / den } else { 0.0 };
    }
}

/// Turn an applied denominator into the Sinkhorn ratio in place:
/// out[i] = num[i] / out[i], guarding 0/0 → 0 (and any non-positive
/// denominator, which only arises from approximate kernels) so
/// zero-mass bins stay inert.
#[inline]
fn ratio_in_place(num: &[F], out: &mut [F]) {
    for (o, &nv) in out.iter_mut().zip(num) {
        *o = if *o > 0.0 { nv / *o } else { 0.0 };
    }
}

/// out = num ./ (K̃ · x) through a [`KernelOp`]. With the dense operator
/// this is exactly [`kernel_ratio`] (same [`crate::linalg::dot`]
/// accumulation).
#[inline]
pub(crate) fn op_ratio(op: &dyn KernelOp, x: &[F], num: &[F], out: &mut [F]) {
    op.apply(x, out);
    ratio_in_place(num, out);
}

/// out = num ./ (K̃ᵀ · x) through a [`KernelOp`].
#[inline]
pub(crate) fn op_ratio_transpose(op: &dyn KernelOp, x: &[F], num: &[F], out: &mut [F]) {
    op.apply_transpose(x, out);
    ratio_in_place(num, out);
}

/// Panel form of [`op_ratio`] over (d, n) column stacks: one pass over
/// the operator updates every column (the K-traffic amortization of
/// [`BatchSinkhorn`]). The dense operator reproduces the historical
/// `panel_ratio` accumulation bit-for-bit.
#[inline]
pub(crate) fn op_panel_ratio(
    op: &dyn KernelOp,
    x: &[F],
    num: &[F],
    out: &mut [F],
    n: usize,
) {
    op.apply_panel(x, out, n);
    ratio_in_place(num, out);
}

/// Panel form of [`op_ratio_transpose`].
#[inline]
pub(crate) fn op_panel_ratio_transpose(
    op: &dyn KernelOp,
    x: &[F],
    num: &[F],
    out: &mut [F],
    n: usize,
) {
    op.apply_transpose_panel(x, out, n);
    ratio_in_place(num, out);
}

/// Column-wise transfer of a (d, n) scaling panel from λ_prev to
/// λ_next = ratio·λ_prev by fixing the dual potential α = log(u)/λ:
/// `u_j ← (u_j/max u_j)^ratio` per column. The max-normalization first
/// keeps every entry in [0, 1] (no overflow at ratio > 1) and re-anchors
/// the scale so the absolute ‖Δu‖ stopping criterion stays meaningful;
/// it is free because (s·u, v/s) describes the same transport plan for
/// any s > 0.
pub(crate) fn transfer_panel(u: &mut [F], d: usize, n: usize, ratio: F) {
    for j in 0..n {
        let mut mx = 0.0;
        for i in 0..d {
            let x = u[i * n + j];
            if x.is_finite() {
                mx = F::max(mx, x);
            }
        }
        if mx <= 0.0 {
            continue;
        }
        for i in 0..d {
            let scaled = u[i * n + j] / mx;
            u[i * n + j] = if scaled > 0.0 { scaled.powf(ratio) } else { 0.0 };
        }
    }
}

/// Run the ε-scaling prefix of `schedule` toward λ★ over a (d, n)
/// column-stacked scaling panel, evolving `u` in place (the column
/// scaling v is recomputed from u at the top of every Sinkhorn
/// iteration, so only u needs carrying). Returns the fixed-point
/// iterations consumed; `u` comes back expressed at the λ★ scale, ready
/// to seed the main loop.
///
/// Each stage λ_s builds its *own* kernel operator through `policy` —
/// K = e^{−λ_s·M} depends on the stage λ, so reusing the λ★ operator
/// (or the previous stage's) would iterate against the wrong kernel and
/// silently corrupt the carried scaling. The per-call rebuild is
/// O(stages·build) — about one extra iteration-equivalent per stage,
/// amortized across all n columns on the batch path; cold solves are
/// exactly where that cost is repaid by the shorter main loop.
#[allow(clippy::too_many_arguments)]
pub(crate) fn anneal_prefix_panel(
    m: &[F],
    d: usize,
    lambda_star: F,
    schedule: &LambdaSchedule,
    policy: KernelPolicy,
    r_panel: &[F],
    c_panel: &[F],
    u: &mut [F],
    n: usize,
) -> usize {
    let stages = schedule.prefix_stages(lambda_star);
    if stages.is_empty() {
        return 0;
    }
    let per_stage = schedule.stage_iterations();
    let mut v = vec![0.0; d * n];
    let mut prev: Option<F> = None;
    let mut iters = 0;
    for &lam_s in &stages {
        if let Some(lp) = prev {
            transfer_panel(u, d, n, lam_s / lp);
        }
        let stage_kernel = policy.build(m, d, lam_s);
        for _ in 0..per_stage {
            op_panel_ratio_transpose(&*stage_kernel, u, c_panel, &mut v, n);
            op_panel_ratio(&*stage_kernel, &v, r_panel, u, n);
        }
        iters += per_stage;
        prev = Some(lam_s);
    }
    if let Some(lp) = prev {
        transfer_panel(u, d, n, lambda_star / lp);
    }
    iters
}

/// Scalar (single-pair) form of [`anneal_prefix_panel`]: a d-vector is a
/// (d, 1) panel with the same memory layout.
#[allow(clippy::too_many_arguments)]
pub(crate) fn dense_anneal_prefix(
    m: &[F],
    d: usize,
    lambda_star: F,
    schedule: &LambdaSchedule,
    policy: KernelPolicy,
    r: &[F],
    c: &[F],
    u: &mut [F],
) -> usize {
    anneal_prefix_panel(m, d, lambda_star, schedule, policy, r, c, u, 1)
}

/// True when K = e^{−λM} underflows badly enough that the dense fixed
/// point collapses: more than half of the *off-diagonal* kernel is
/// exactly zero (the diagonal is always 1 since m_ii = 0). This single
/// predicate is the routing criterion shared by [`SinkhornEngine`]'s
/// auto-stabilization, the Greenkhorn backend, and the backend router.
pub fn dense_kernel_degenerate(metric: &crate::metric::CostMatrix, lambda: F) -> bool {
    let d = metric.dim();
    degenerate_off_diagonal(metric.data().iter().map(|&mij| (-lambda * mij).exp()), d)
}

/// The same criterion over an already materialized row-major kernel
/// (spares callers that hold K a second O(d²) exp pass).
pub(crate) fn degenerate_off_diagonal(k: impl Iterator<Item = F>, d: usize) -> bool {
    let off_diag = (d * d - d).max(1);
    let zeros = k
        .enumerate()
        .filter(|&(idx, v)| idx / d != idx % d && v == 0.0)
        .count();
    zeros as f64 > 0.5 * off_diag as f64
}

impl SinkhornConfig {
    /// Fixed-budget config (no convergence checks) — the serving-path
    /// setting: exactly `n` iterations.
    pub fn fixed(lambda: F, n: usize) -> Self {
        Self {
            lambda,
            tolerance: 0.0,
            max_iterations: n,
            check_every: usize::MAX,
            auto_stabilize: true,
            schedule: LambdaSchedule::Fixed,
            kernel: KernelPolicy::Dense,
        }
    }

    /// Convergence-driven config with the paper's 0.01 tolerance.
    pub fn converged(lambda: F) -> Self {
        Self { lambda, ..Default::default() }
    }

    /// A validating builder seeded with the defaults. Construction fails
    /// fast — [`SinkhornConfigBuilder::build`] rejects malformed knobs
    /// instead of letting an `assert!` fire mid-solve on a worker
    /// thread.
    pub fn builder() -> SinkhornConfigBuilder {
        SinkhornConfigBuilder { cfg: Self::default() }
    }

    /// Check every knob. This is the single source of truth the
    /// builders and `DistanceService::start` share; the messages are the
    /// ones surfaced through `ServiceError::InvalidConfig`.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if !(self.lambda > 0.0 && self.lambda.is_finite()) {
            return Err(ConfigError(format!(
                "lambda must be positive and finite (got {})",
                self.lambda
            )));
        }
        if !(self.tolerance >= 0.0 && self.tolerance.is_finite()) {
            return Err(ConfigError(format!(
                "tolerance must be finite and >= 0 (got {})",
                self.tolerance
            )));
        }
        if self.max_iterations == 0 {
            return Err(ConfigError("max_iterations must be at least 1".into()));
        }
        if self.check_every == 0 {
            return Err(ConfigError(
                "check_every must be at least 1 (usize::MAX = fixed budget)".into(),
            ));
        }
        validate_schedule(&self.schedule)?;
        validate_kernel(&self.kernel)
    }
}

/// A rejected configuration knob (the message names the knob and the
/// offending value).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConfigError(pub String);

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for ConfigError {}

/// Shared schedule validation (also consulted by the coordinator's
/// builder for its `anneal` knob).
pub(crate) fn validate_schedule(schedule: &LambdaSchedule) -> Result<(), ConfigError> {
    if let LambdaSchedule::Geometric { lambda0, factor, stage_iterations } = *schedule {
        if !(lambda0 > 0.0 && lambda0.is_finite()) || !(factor > 1.0 && factor.is_finite())
        {
            return Err(ConfigError(format!(
                "anneal schedule needs lambda0 > 0 and factor > 1 \
                 (got lambda0={lambda0}, factor={factor})"
            )));
        }
        if stage_iterations == 0 {
            return Err(ConfigError(
                "anneal schedule stage_iterations must be at least 1".into(),
            ));
        }
    }
    Ok(())
}

/// Shared kernel-policy validation (also consulted by the coordinator's
/// builder for its `kernel` knob).
pub(crate) fn validate_kernel(kernel: &KernelPolicy) -> Result<(), ConfigError> {
    match *kernel {
        KernelPolicy::Truncated { threshold } => {
            if !(threshold >= 0.0 && threshold < 1.0) {
                return Err(ConfigError(format!(
                    "truncation threshold must be in [0, 1) (got {threshold})"
                )));
            }
        }
        KernelPolicy::LowRank { tolerance, .. } => {
            if !(tolerance >= 0.0 && tolerance.is_finite()) {
                return Err(ConfigError(format!(
                    "low-rank tolerance must be finite and >= 0 (got {tolerance})"
                )));
            }
        }
        KernelPolicy::Dense | KernelPolicy::Auto => {}
    }
    Ok(())
}

/// Validating builder for [`SinkhornConfig`] (see
/// [`SinkhornConfig::builder`]).
#[derive(Debug, Clone)]
pub struct SinkhornConfigBuilder {
    cfg: SinkhornConfig,
}

impl SinkhornConfigBuilder {
    /// Entropic weight λ of Equation (2).
    pub fn lambda(mut self, lambda: F) -> Self {
        self.cfg.lambda = lambda;
        self
    }

    /// Convergence tolerance on ‖Δu‖₂.
    pub fn tolerance(mut self, tolerance: F) -> Self {
        self.cfg.tolerance = tolerance;
        self
    }

    /// Hard iteration cap.
    pub fn max_iterations(mut self, n: usize) -> Self {
        self.cfg.max_iterations = n;
        self
    }

    /// Convergence-check stride (`usize::MAX` = fixed budget).
    pub fn check_every(mut self, stride: usize) -> Self {
        self.cfg.check_every = stride;
        self
    }

    /// Fixed-budget mode: exactly `n` iterations, no convergence checks
    /// (the [`SinkhornConfig::fixed`] shape).
    pub fn fixed_budget(mut self, n: usize) -> Self {
        self.cfg.tolerance = 0.0;
        self.cfg.max_iterations = n;
        self.cfg.check_every = usize::MAX;
        self
    }

    /// Toggle the log-domain auto-stabilization rescue.
    pub fn auto_stabilize(mut self, on: bool) -> Self {
        self.cfg.auto_stabilize = on;
        self
    }

    /// ε-scaling schedule.
    pub fn schedule(mut self, schedule: LambdaSchedule) -> Self {
        self.cfg.schedule = schedule;
        self
    }

    /// Kernel materialization policy.
    pub fn kernel(mut self, kernel: KernelPolicy) -> Self {
        self.cfg.kernel = kernel;
        self
    }

    /// Validate and produce the config.
    pub fn build(self) -> Result<SinkhornConfig, ConfigError> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }
}

#[cfg(test)]
mod schedule_tests {
    use super::*;

    #[test]
    fn fixed_has_no_prefix() {
        assert!(LambdaSchedule::Fixed.prefix_stages(100.0).is_empty());
        assert_eq!(LambdaSchedule::Fixed.stage_iterations(), 0);
    }

    #[test]
    fn geometric_stages_stay_below_target() {
        let s = LambdaSchedule::geometric(1.0);
        assert_eq!(s.prefix_stages(20.0), vec![1.0, 3.0, 9.0]);
        assert_eq!(s.prefix_stages(9.5), vec![1.0, 3.0, 9.0]);
        assert_eq!(s.prefix_stages(1.0), Vec::<F>::new(), "λ₀ ≥ λ★ is a no-op");
        assert_eq!(s.prefix_stages(0.5), Vec::<F>::new());
        assert_eq!(s.stage_iterations(), 30);
    }

    #[test]
    fn transfer_panel_normalizes_and_preserves_zeros() {
        let mut u = vec![4.0, 2.0, 0.0];
        transfer_panel(&mut u, 3, 1, 2.0);
        assert!((u[0] - 1.0).abs() < 1e-15, "max normalizes to 1");
        assert!((u[1] - 0.25).abs() < 1e-15, "(2/4)^2");
        assert_eq!(u[2], 0.0, "zero-mass scaling stays zero");
        // All-zero column is left untouched (nothing to anchor on).
        let mut z = vec![0.0, 0.0];
        transfer_panel(&mut z, 2, 1, 3.0);
        assert_eq!(z, vec![0.0, 0.0]);
        // Columns transfer independently: (d=2, n=2) row-major panel
        // [[2, 0], [1, 8]] -> col 0 = [1, 0.25], col 1 = [0, 1].
        let mut p = vec![2.0, 0.0, 1.0, 8.0];
        transfer_panel(&mut p, 2, 2, 2.0);
        assert!((p[0] - 1.0).abs() < 1e-15);
        assert!((p[2] - 0.25).abs() < 1e-15);
        assert_eq!(p[1], 0.0);
        assert!((p[3] - 1.0).abs() < 1e-15);
    }

    #[test]
    fn builder_accepts_defaults_and_round_trips_knobs() {
        let cfg = SinkhornConfig::builder().build().expect("defaults are valid");
        assert_eq!(cfg.lambda, SinkhornConfig::default().lambda);
        let cfg = SinkhornConfig::builder()
            .lambda(50.0)
            .tolerance(1e-9)
            .max_iterations(777)
            .check_every(3)
            .auto_stabilize(false)
            .schedule(LambdaSchedule::geometric(1.0))
            .kernel(KernelPolicy::truncated_default())
            .build()
            .expect("valid knobs");
        assert_eq!(cfg.lambda, 50.0);
        assert_eq!(cfg.max_iterations, 777);
        assert_eq!(cfg.check_every, 3);
        assert!(!cfg.auto_stabilize);
        let fixed = SinkhornConfig::builder().lambda(9.0).fixed_budget(20).build().unwrap();
        assert_eq!(
            (fixed.tolerance, fixed.max_iterations, fixed.check_every),
            (0.0, 20, usize::MAX),
            "fixed_budget must match SinkhornConfig::fixed"
        );
    }

    #[test]
    fn builder_rejects_each_invalid_knob() {
        // One case per knob; every rejection names the offending value.
        let bad = [
            SinkhornConfig::builder().lambda(0.0).build(),
            SinkhornConfig::builder().lambda(-3.0).build(),
            SinkhornConfig::builder().lambda(F::NAN).build(),
            SinkhornConfig::builder().tolerance(-1e-3).build(),
            SinkhornConfig::builder().tolerance(F::INFINITY).build(),
            SinkhornConfig::builder().max_iterations(0).build(),
            SinkhornConfig::builder().check_every(0).build(),
            SinkhornConfig::builder()
                .schedule(LambdaSchedule::Geometric {
                    lambda0: 0.0,
                    factor: 3.0,
                    stage_iterations: 30,
                })
                .build(),
            SinkhornConfig::builder()
                .schedule(LambdaSchedule::Geometric {
                    lambda0: 1.0,
                    factor: 1.0,
                    stage_iterations: 30,
                })
                .build(),
            SinkhornConfig::builder()
                .schedule(LambdaSchedule::Geometric {
                    lambda0: 1.0,
                    factor: 3.0,
                    stage_iterations: 0,
                })
                .build(),
            SinkhornConfig::builder()
                .kernel(KernelPolicy::Truncated { threshold: 1.0 })
                .build(),
            SinkhornConfig::builder()
                .kernel(KernelPolicy::Truncated { threshold: -0.1 })
                .build(),
            SinkhornConfig::builder()
                .kernel(KernelPolicy::LowRank { max_rank: 4, tolerance: -1.0 })
                .build(),
        ];
        for (i, case) in bad.iter().enumerate() {
            assert!(case.is_err(), "case {i} should have been rejected");
        }
    }

    #[test]
    fn dense_prefix_counts_iterations() {
        // Tiny symmetric metric; just exercise the bookkeeping.
        let m = vec![0.0, 1.0, 1.0, 0.0];
        let r = [0.5, 0.5];
        let c = [0.25, 0.75];
        let mut u = vec![0.5, 0.5];
        let schedule = LambdaSchedule::geometric(1.0);
        let iters = dense_anneal_prefix(
            &m, 2, 9.0, &schedule, KernelPolicy::Dense, &r, &c, &mut u,
        );
        assert_eq!(iters, 60, "two stages (λ=1, 3) x 30 iterations");
        assert!(u.iter().all(|x| x.is_finite() && *x > 0.0));
        let none = dense_anneal_prefix(
            &m, 2, 9.0, &LambdaSchedule::Fixed, KernelPolicy::Dense, &r, &c, &mut u,
        );
        assert_eq!(none, 0);
    }
}
