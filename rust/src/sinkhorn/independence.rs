//! The Independence kernel — the α = 0 / λ → 0 extreme of the Sinkhorn
//! family (Property 2).
//!
//! When the entropic ball shrinks to {rcᵀ}, the Sinkhorn distance has the
//! closed form d_{M,0}(r,c) = rᵀ M c, which is a negative definite kernel
//! whenever M is a Euclidean distance matrix, so e^{−t·rᵀMc} is a valid
//! positive definite SVM kernel. The appendix's Remark also gives the fast
//! evaluation scheme implemented here: with m_ij = ‖φ_i − φ_j‖²,
//!
//! ```text
//! rᵀ M c = rᵀu + cᵀu − 2 (Lr)ᵀ(Lc),
//! ```
//!
//! where u_i = ‖φ_i‖² and L is a Cholesky factor of the Gram matrix
//! K = [⟨φ_i, φ_j⟩]. Preprocessing each histogram to (Lr, rᵀu) makes each
//! subsequent distance evaluation O(rank) instead of O(d²).

use crate::linalg::{cholesky, dot, Matrix};
use crate::metric::CostMatrix;
use crate::simplex::Histogram;
use crate::F;

/// Direct O(d²) evaluation of d_{M,0}(r, c) = rᵀ M c.
pub fn independence_distance(m: &CostMatrix, r: &Histogram, c: &Histogram) -> F {
    let d = m.dim();
    assert_eq!(r.dim(), d, "source dimension mismatch");
    assert_eq!(c.dim(), d, "target dimension mismatch");
    let mut acc = 0.0;
    for (i, &ri) in r.values().iter().enumerate() {
        if ri != 0.0 {
            acc += ri * dot(m.row(i), c.values());
        }
    }
    acc
}

/// Preprocessed representation of one histogram under an
/// [`IndependenceKernel`]: the pair (L r, rᵀ u) of the appendix Remark.
#[derive(Debug, Clone)]
pub struct PreparedHistogram {
    lr: Vec<F>,
    ru: F,
}

impl PreparedHistogram {
    /// The embedded barycenter Σᵢ rᵢ φᵢ = Lᵀr of this histogram in the
    /// kernel's Euclidean embedding (φᵢ = row i of the Cholesky factor,
    /// so ‖φᵢ − φⱼ‖² = mᵢⱼ up to the factorization jitter). This is the
    /// quantity the retrieval cascade's centroid lower bound compares.
    pub fn coordinates(&self) -> &[F] {
        &self.lr
    }
}

/// The Independence kernel with the Cholesky speed-up.
///
/// Requires M to be (numerically) a Euclidean distance matrix: the implied
/// Gram matrix K_ij = ½(u_i + u_j − m_ij) (anchored at point 0) must be
/// PSD; a tiny diagonal jitter is applied to absorb roundoff.
#[derive(Debug, Clone)]
pub struct IndependenceKernel {
    d: usize,
    /// Cholesky factor of the anchored Gram matrix.
    l: Matrix,
    /// u_i = ‖φ_i‖² (with φ_0 at the origin).
    u: Vec<F>,
    /// Total diagonal jitter absorbed by the factorization (0 when the
    /// Gram matrix factored on the first attempt). The embedded
    /// distances satisfy ‖φᵢ − φⱼ‖² = mᵢⱼ + 2·jitter for i ≠ j, which is
    /// exactly the slack [`Self::centroid_gap`] subtracts to stay an
    /// admissible lower bound.
    jitter: F,
}

/// Error for non-Euclidean cost matrices.
#[derive(Debug, Clone)]
pub struct NotEuclidean;

impl std::fmt::Display for NotEuclidean {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cost matrix is not a Euclidean distance matrix (Gram matrix not PSD)")
    }
}

impl std::error::Error for NotEuclidean {}

impl IndependenceKernel {
    /// Build the factorization from a squared-Euclidean cost matrix.
    pub fn new(m: &CostMatrix) -> Result<Self, NotEuclidean> {
        let d = m.dim();
        // Anchor φ_0 = 0: u_i = m_{i,0}, K_ij = (u_i + u_j - m_ij) / 2.
        let u: Vec<F> = (0..d).map(|i| m.get(i, 0)).collect();
        let mut gram = Matrix::zeros(d, d);
        for i in 0..d {
            for j in 0..d {
                gram.set(i, j, 0.5 * (u[i] + u[j] - m.get(i, j)));
            }
        }
        // Jitter loop: absorb floating-point negativity only (scale-aware).
        let scale: F = (0..d).map(|i| gram.get(i, i).abs()).fold(0.0, F::max).max(1e-30);
        let mut jitter = 1e-12 * scale;
        let mut applied: F = 0.0;
        for _ in 0..20 {
            if let Some(l) = cholesky(&gram) {
                return Ok(Self { d, l, u, jitter: applied });
            }
            for i in 0..d {
                let v = gram.get(i, i) + jitter;
                gram.set(i, i, v);
            }
            applied += jitter;
            jitter *= 10.0;
            if jitter > 1e-4 * scale {
                break;
            }
        }
        Err(NotEuclidean)
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    /// Total diagonal jitter the factorization absorbed (0 for a cleanly
    /// PSD Gram matrix).
    pub fn jitter(&self) -> F {
        self.jitter
    }

    /// Admissible centroid lower bound on d_M(r, c) from two prepared
    /// histograms, in O(d).
    ///
    /// The factorization embeds the bins as points φᵢ with
    /// ‖φᵢ − φⱼ‖² = mᵢⱼ + 2·jitter (i ≠ j), so for *any* transport plan
    /// P ∈ U(r, c), Jensen's inequality gives
    /// ‖Σᵢ rᵢφᵢ − Σⱼ cⱼφⱼ‖² ≤ Σᵢⱼ Pᵢⱼ‖φᵢ − φⱼ‖² ≤ ⟨P, M⟩ + 2·jitter.
    /// Minimizing over P: ‖Δbarycenter‖² − 2·jitter ≤ d_M(r, c), and
    /// since the served d_M^λ is the cost of a feasible plan,
    /// d_M ≤ d_M^λ holds for every λ — this bound is admissible for the
    /// whole Sinkhorn family. (It needs M to be of negative type — plain
    /// or squared Euclidean distance matrices both qualify; when the
    /// factorization fails, [`IndependenceKernel::new`] already returned
    /// [`NotEuclidean`] and no bound is offered.)
    pub fn centroid_gap(&self, r: &PreparedHistogram, c: &PreparedHistogram) -> F {
        let mut acc = 0.0;
        for (a, b) in r.lr.iter().zip(&c.lr) {
            let e = a - b;
            acc += e * e;
        }
        (acc - 2.0 * self.jitter).max(0.0)
    }

    /// Preprocess one histogram: O(d²) once, O(d) per distance after.
    pub fn prepare(&self, h: &Histogram) -> PreparedHistogram {
        assert_eq!(h.dim(), self.d, "dimension mismatch");
        // (L^T r): note rᵀKc = (Lᵀr)·(Lᵀc) for K = L Lᵀ.
        let mut lr = vec![0.0; self.d];
        for i in 0..self.d {
            // L is lower triangular; (L^T r)_i = sum_{k>=i} L[k,i] r_k.
            let mut acc = 0.0;
            for k in i..self.d {
                acc += self.l.get(k, i) * h.values()[k];
            }
            lr[i] = acc;
        }
        let ru = dot(&self.u, h.values());
        PreparedHistogram { lr, ru }
    }

    /// d_{M,0}(r, c) from two prepared histograms in O(d).
    pub fn distance(&self, r: &PreparedHistogram, c: &PreparedHistogram) -> F {
        r.ru + c.ru - 2.0 * dot(&r.lr, &c.lr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::GridMetric;
    use crate::simplex::seeded_rng;

    #[test]
    fn direct_matches_manual() {
        let m = CostMatrix::from_rows(2, vec![0., 2., 2., 0.]);
        let r = Histogram::from_weights(&[1.0, 0.0]).unwrap();
        let c = Histogram::from_weights(&[0.0, 1.0]).unwrap();
        assert!((independence_distance(&m, &r, &c) - 2.0).abs() < 1e-12);
        assert_eq!(independence_distance(&m, &r, &r), 0.0);
    }

    #[test]
    fn cholesky_fastpath_matches_direct() {
        // Squared grid distances are a genuine EDM.
        let m = GridMetric::new(4, 4).squared_cost_matrix();
        let kernel = IndependenceKernel::new(&m).expect("grid EDM must factor");
        let mut rng = seeded_rng(17);
        for _ in 0..10 {
            let r = Histogram::sample_uniform(16, &mut rng);
            let c = Histogram::sample_uniform(16, &mut rng);
            let direct = independence_distance(&m, &r, &c);
            let fast = kernel.distance(&kernel.prepare(&r), &kernel.prepare(&c));
            assert!(
                (direct - fast).abs() < 1e-9 * (1.0 + direct.abs()),
                "direct {direct} vs fast {fast}"
            );
        }
    }

    #[test]
    fn powered_edm_still_factors() {
        // M^a for a in (0,1) remains an EDM (footnote 1) — the §5.1.2
        // Independence-kernel configuration [m_ij^a], a in {0.01, 0.1, 1}.
        let m = GridMetric::new(3, 3).squared_cost_matrix();
        for &a in &[0.01, 0.1, 1.0] {
            let ma = m.powf(a);
            assert!(
                IndependenceKernel::new(&ma).is_ok(),
                "M^{a} should be an EDM"
            );
        }
    }

    #[test]
    fn gram_psd_on_equal_norm_histograms() {
        // e^{-t r^T M c} must be a PD kernel (Property 2): check the Gram
        // matrix of a random sample has a Cholesky factorization.
        let m = GridMetric::new(3, 3).squared_cost_matrix();
        let mut rng = seeded_rng(23);
        let hs: Vec<Histogram> =
            (0..8).map(|_| Histogram::sample_uniform(9, &mut rng)).collect();
        let t = 0.7;
        let mut gram = Matrix::zeros(8, 8);
        for i in 0..8 {
            for j in 0..8 {
                let dij = independence_distance(&m, &hs[i], &hs[j]);
                gram.set(i, j, (-t * dij).exp());
            }
        }
        // Symmetrize roundoff then factor.
        for i in 0..8 {
            for j in 0..i {
                let s = 0.5 * (gram.get(i, j) + gram.get(j, i));
                gram.set(i, j, s);
                gram.set(j, i, s);
            }
        }
        // Allow a microscopic jitter for f64 roundoff.
        for i in 0..8 {
            gram.set(i, i, gram.get(i, i) + 1e-12);
        }
        assert!(cholesky(&gram).is_some(), "independence Gram not PSD");
    }

    #[test]
    fn centroid_gap_lower_bounds_exact_emd() {
        use crate::metric::RandomMetric;
        use crate::ot::EmdSolver;
        for seed in 0..40u64 {
            let mut rng = seeded_rng(seed);
            let d = rng.range_usize(3, 16);
            let m = RandomMetric::new(d).sample(&mut rng);
            let kernel = match IndependenceKernel::new(&m) {
                Ok(k) => k,
                // Plain Euclidean distance matrices are of negative type,
                // so this only skips on extreme roundoff.
                Err(_) => continue,
            };
            let r = Histogram::sample_uniform(d, &mut rng);
            let c = Histogram::sample_uniform(d, &mut rng);
            let bound =
                kernel.centroid_gap(&kernel.prepare(&r), &kernel.prepare(&c));
            let exact = EmdSolver::new(&m).solve(&r, &c).unwrap().cost;
            assert!(
                bound <= exact + 1e-9,
                "seed={seed} d={d}: centroid bound {bound} > d_M {exact}"
            );
            assert!(bound >= 0.0);
            // Coincident histograms have a zero gap.
            let self_gap =
                kernel.centroid_gap(&kernel.prepare(&r), &kernel.prepare(&r));
            assert!(self_gap.abs() < 1e-12);
        }
    }

    #[test]
    fn prepared_coordinates_are_the_embedded_barycenter() {
        let m = GridMetric::new(3, 3).squared_cost_matrix();
        let kernel = IndependenceKernel::new(&m).expect("grid EDM must factor");
        let mut rng = seeded_rng(31);
        let r = Histogram::sample_uniform(9, &mut rng);
        let prep = kernel.prepare(&r);
        // coordinates() is (Lᵀ r): recompute it directly from the factor.
        for i in 0..9 {
            let mut acc = 0.0;
            for k in i..9 {
                acc += kernel.l.get(k, i) * r.values()[k];
            }
            assert!((prep.coordinates()[i] - acc).abs() < 1e-12);
        }
    }

    /// Bilinearity and symmetry of r^T M c for symmetric M.
    #[test]
    fn prop_symmetric_form() {
        let m = GridMetric::new(3, 3).cost_matrix();
        for seed in 0..200u64 {
            let mut rng = seeded_rng(seed);
            let r = Histogram::sample_uniform(9, &mut rng);
            let c = Histogram::sample_uniform(9, &mut rng);
            let ab = independence_distance(&m, &r, &c);
            let ba = independence_distance(&m, &c, &r);
            assert!((ab - ba).abs() < 1e-12);
            assert!(ab >= 0.0);
        }
    }
}
