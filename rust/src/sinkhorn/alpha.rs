//! The *primal* Sinkhorn distance d_{M,α} (Definition 1), computed
//! through the dual-Sinkhorn divergence by bisection on λ — exactly the
//! scheme sketched in the paper's §4.2:
//!
//! > "d_{M,α} can be obtained by computing d_M^λ iteratively until the
//! > entropy of the solution P^λ has reached an adequate value
//! > h(r) + h(c) − α. Since the entropy of P^λ decreases monotonically
//! > when λ increases, this search can be carried out by simple
//! > bisection."
//!
//! The entropy target pins the KL-ball radius: KL(P^λ ‖ rcᵀ) = α at the
//! active constraint. Two inactive regimes are detected and short-cut:
//! α ≈ 0 (the independence table rcᵀ is the only feasible point) and α
//! large enough that the unconstrained optimum already has enough entropy
//! (d_{M,α} = d_M, Property 1).

use super::{SinkhornConfig, SinkhornEngine};
use crate::metric::CostMatrix;
use crate::simplex::{entropy, Histogram};
use crate::F;

/// Bisection configuration.
#[derive(Debug, Clone, Copy)]
pub struct AlphaConfig {
    /// λ search interval (log-bisected).
    pub lambda_min: F,
    pub lambda_max: F,
    /// Stop when the entropy target is met within this tolerance (nats).
    pub entropy_tolerance: F,
    /// Max bisection steps.
    pub max_steps: usize,
    /// Inner fixed-point configuration template (λ is overridden).
    pub inner: SinkhornConfig,
}

impl Default for AlphaConfig {
    fn default() -> Self {
        Self {
            lambda_min: 1e-3,
            lambda_max: 1e4,
            entropy_tolerance: 1e-4,
            max_steps: 60,
            inner: SinkhornConfig {
                lambda: 1.0, // overridden per probe
                tolerance: 1e-10,
                max_iterations: 100_000,
                ..Default::default()
            },
        }
    }
}

/// Result of a d_{M,α} evaluation.
#[derive(Debug, Clone)]
pub struct AlphaOutput {
    /// The Sinkhorn distance d_{M,α}(r, c).
    pub value: F,
    /// The dual weight λ(α) the bisection landed on (∞ for the exact-OT
    /// regime shortcut, 0 for the independence regime).
    pub lambda: F,
    /// Entropy h(P) of the returned plan.
    pub plan_entropy: F,
    /// h(r) + h(c) − α, the entropy floor that was targeted.
    pub entropy_target: F,
    /// Bisection probes performed.
    pub probes: usize,
    /// True when the entropic constraint is inactive (α big: EMD regime).
    pub unconstrained: bool,
}

/// Solver for the hard-constraint Sinkhorn distance.
pub struct AlphaSinkhorn<'m> {
    metric: &'m CostMatrix,
    config: AlphaConfig,
}

impl<'m> AlphaSinkhorn<'m> {
    pub fn new(metric: &'m CostMatrix) -> Self {
        Self { metric, config: AlphaConfig::default() }
    }

    pub fn with_config(metric: &'m CostMatrix, config: AlphaConfig) -> Self {
        Self { metric, config }
    }

    /// Evaluate d_{M,α}(r, c) for α ≥ 0 (nats of allowed mutual
    /// information).
    pub fn distance(&self, r: &Histogram, c: &Histogram, alpha: F) -> AlphaOutput {
        assert!(alpha >= 0.0, "alpha must be non-negative");
        let target = entropy(r.values()) + entropy(c.values()) - alpha;
        let cfg = &self.config;

        // α = 0 shortcut: U_0 = {rc^T}, d_{M,0} = r'Mc (Property 2).
        if alpha <= 1e-12 {
            let value = super::independence_distance(self.metric, r, c);
            return AlphaOutput {
                value,
                lambda: 0.0,
                plan_entropy: target, // h(rc^T) = h(r) + h(c)
                entropy_target: target,
                probes: 0,
                unconstrained: false,
            };
        }

        // Vacuous-constraint shortcut (Property 1): every P ∈ U(r,c) has
        // h(P) ≥ max(h(r), h(c)) (conditioning reduces entropy), so when
        // the floor sits at or below that bound the ball is all of U(r,c)
        // and d_{M,α} = d_M exactly — solve with the network simplex.
        if target <= entropy(r.values()).max(entropy(c.values())) + 1e-12 {
            let plan = crate::ot::EmdSolver::new(self.metric)
                .solve(r, c)
                .expect("exact OT solve in unconstrained regime");
            return AlphaOutput {
                value: plan.cost,
                lambda: F::INFINITY,
                plan_entropy: plan.entropy(),
                entropy_target: target,
                probes: 0,
                unconstrained: true,
            };
        }

        let probe = |lambda: F, probes: &mut usize| -> (F, F) {
            *probes += 1;
            let engine = SinkhornEngine::with_config(
                self.metric,
                SinkhornConfig { lambda, ..cfg.inner },
            );
            let (plan, out) = engine.plan(r, c);
            (entropy(&plan), out.value)
        };

        let mut probes = 0;
        // Check the top of the interval first: if even λ_max keeps more
        // entropy than required... it cannot (entropy decreases in λ), so
        // instead: if the λ_max plan *still* violates (h < target is what
        // we need to avoid; constraint wants h >= target), i.e. if
        // h(λ_max) >= target, the constraint never binds within the
        // interval -> unconstrained regime (≈ exact OT).
        let (h_hi, v_hi) = probe(cfg.lambda_max, &mut probes);
        if h_hi >= target {
            return AlphaOutput {
                value: v_hi,
                lambda: cfg.lambda_max,
                plan_entropy: h_hi,
                entropy_target: target,
                probes,
                unconstrained: true,
            };
        }
        let (h_lo, v_lo) = probe(cfg.lambda_min, &mut probes);
        if h_lo <= target {
            // Even the flattest plan we can produce is below the floor:
            // α is so small that the optimum sits at the ball's boundary
            // near rc^T; return the λ_min solution (best approximation).
            return AlphaOutput {
                value: v_lo,
                lambda: cfg.lambda_min,
                plan_entropy: h_lo,
                entropy_target: target,
                probes,
                unconstrained: false,
            };
        }

        // Bisect in log λ: h(λ) is decreasing, find h(λ*) = target.
        let mut lo = cfg.lambda_min.ln();
        let mut hi = cfg.lambda_max.ln();
        let mut best = (cfg.lambda_min, h_lo, v_lo);
        for _ in 0..cfg.max_steps {
            let mid = 0.5 * (lo + hi);
            let lambda = mid.exp();
            let (h, v) = probe(lambda, &mut probes);
            best = (lambda, h, v);
            if (h - target).abs() <= cfg.entropy_tolerance {
                break;
            }
            if h > target {
                // Plan too smooth: the ball allows going further; raise λ.
                lo = mid;
            } else {
                hi = mid;
            }
        }
        // Guarantee feasibility: if the final probe undershot the entropy
        // floor, step back to the feasible side.
        let (mut lambda, mut h, mut v) = best;
        if h < target - cfg.entropy_tolerance {
            lambda = (lo.exp() + lambda) * 0.5;
            let (h2, v2) = probe(lambda, &mut probes);
            h = h2;
            v = v2;
        }
        AlphaOutput {
            value: v,
            lambda,
            plan_entropy: h,
            entropy_target: target,
            probes,
            unconstrained: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::RandomMetric;
    use crate::ot::EmdSolver;
    use crate::simplex::seeded_rng;
    use crate::sinkhorn::independence_distance;

    fn setup(d: usize, seed: u64) -> (CostMatrix, Histogram, Histogram) {
        let mut rng = seeded_rng(seed);
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        (m, r, c)
    }

    #[test]
    fn alpha_zero_is_independence() {
        let (m, r, c) = setup(10, 0);
        let solver = AlphaSinkhorn::new(&m);
        let out = solver.distance(&r, &c, 0.0);
        let want = independence_distance(&m, &r, &c);
        assert!((out.value - want).abs() < 1e-12);
        assert_eq!(out.probes, 0);
    }

    #[test]
    fn alpha_large_recovers_emd() {
        let (m, r, c) = setup(10, 1);
        let solver = AlphaSinkhorn::new(&m);
        // alpha bigger than any possible mutual information: h(r)+h(c).
        let alpha = entropy(r.values()) + entropy(c.values());
        let out = solver.distance(&r, &c, alpha);
        assert!(out.unconstrained);
        assert_eq!(out.probes, 0, "vacuous constraint must shortcut");
        let exact = EmdSolver::new(&m).solve(&r, &c).unwrap().cost;
        assert!(
            (out.value - exact).abs() / exact < 1e-9,
            "unconstrained {} vs exact {exact}",
            out.value
        );
    }

    #[test]
    fn entropy_constraint_is_active_and_met() {
        let (m, r, c) = setup(12, 2);
        let solver = AlphaSinkhorn::new(&m);
        for alpha in [0.05, 0.2, 0.5] {
            let out = solver.distance(&r, &c, alpha);
            if out.unconstrained {
                continue;
            }
            // Feasibility: h(P) >= target (within tolerance)...
            assert!(
                out.plan_entropy >= out.entropy_target - 2e-3,
                "alpha={alpha}: entropy {} below target {}",
                out.plan_entropy,
                out.entropy_target
            );
            // ...and activity: the optimum rides the boundary.
            assert!(
                (out.plan_entropy - out.entropy_target).abs() < 2e-2,
                "alpha={alpha}: constraint unexpectedly slack"
            );
        }
    }

    #[test]
    fn monotone_decreasing_in_alpha() {
        // A bigger ball can only lower the minimum.
        let (m, r, c) = setup(10, 3);
        let solver = AlphaSinkhorn::new(&m);
        let mut prev = F::INFINITY;
        for alpha in [0.01, 0.05, 0.15, 0.4, 1.0] {
            let out = solver.distance(&r, &c, alpha);
            assert!(
                out.value <= prev + 1e-6,
                "d_(M,{alpha}) = {} rose above {prev}",
                out.value
            );
            prev = out.value;
        }
    }

    #[test]
    fn theorem1_triangle_inequality_for_alpha() {
        // The actual statement of Theorem 1 is about d_{M,alpha}.
        let mut rng = seeded_rng(7);
        let d = 8;
        let m = RandomMetric::new(d).sample(&mut rng);
        let solver = AlphaSinkhorn::new(&m);
        for alpha in [0.1, 0.3] {
            for seed in 0..3u64 {
                let mut rng = seeded_rng(100 + seed);
                let x = Histogram::sample_uniform(d, &mut rng);
                let y = Histogram::sample_uniform(d, &mut rng);
                let z = Histogram::sample_uniform(d, &mut rng);
                let dxy = solver.distance(&x, &y, alpha).value;
                let dyz = solver.distance(&y, &z, alpha).value;
                let dxz = solver.distance(&x, &z, alpha).value;
                assert!(
                    dxz <= dxy + dyz + 1e-4,
                    "alpha={alpha} seed={seed}: {dxz} > {dxy} + {dyz}"
                );
            }
        }
    }

    #[test]
    fn bounded_between_emd_and_independence() {
        let (m, r, c) = setup(10, 5);
        let solver = AlphaSinkhorn::new(&m);
        let exact = EmdSolver::new(&m).solve(&r, &c).unwrap().cost;
        let indep = independence_distance(&m, &r, &c);
        for alpha in [0.02, 0.1, 0.5, 2.0] {
            let out = solver.distance(&r, &c, alpha);
            assert!(out.value >= exact - 1e-6, "below EMD at alpha={alpha}");
            assert!(out.value <= indep + 1e-6, "above r'Mc at alpha={alpha}");
        }
    }
}
