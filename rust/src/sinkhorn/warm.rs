//! Warm-start store: an LRU cache of converged Sinkhorn scalings.
//!
//! Cuturi's fixed point amortizes beautifully across related problems: a
//! serving system sees the same (metric, λ) classes over and over, and
//! repeated or near-repeated query histograms re-converge in a handful of
//! iterations when seeded with a previously converged scaling pair
//! (Altschuler et al. 2017 bound iteration count by how far the initial
//! scalings sit from feasibility — a cached fixed point sits at distance
//! ~0). This module provides the cache:
//!
//! * [`WarmKey`] — `(metric key, λ bits, query fingerprint)`: exact-match
//!   identity of a solve. The fingerprint hashes the raw f64 bits of both
//!   histograms, so only bit-identical (r, c) pairs hit.
//! * [`WarmStartStore`] — a bounded LRU map from [`WarmKey`] to
//!   [`ScalingInit`] with hit/miss/insert/evict counters, built on
//!   `HashMap` + `BTreeMap` recency stamps (the crate is dependency-free).
//!
//! The [`crate::backend::ShardedExecutor`] owns one store per worker
//! (shared-nothing, like the kernel matrices), and the coordinator
//! surfaces the counters through `coordinator::metrics`.

use super::ScalingInit;
use crate::simplex::Histogram;
use crate::F;
use std::collections::{BTreeMap, HashMap};

/// Cache identity of one solve: which metric, which λ, which (r, c).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WarmKey {
    /// Caller-chosen metric namespace (the coordinator uses `MetricId.0`;
    /// standalone executors pass any stable value, e.g. 0).
    pub metric: u64,
    /// λ quantized to its bit pattern (same exact-match routing as
    /// `coordinator::ShapeClass`).
    pub lambda_bits: u64,
    /// Fingerprint of the (r, c) histogram pair ([`fingerprint_pair`]).
    pub fingerprint: u64,
}

impl WarmKey {
    /// Key for a query against `metric` at `lambda`.
    pub fn new(metric: u64, lambda: F, r: &Histogram, c: &Histogram) -> Self {
        Self {
            metric,
            lambda_bits: lambda.to_bits(),
            fingerprint: fingerprint_pair(r, c),
        }
    }
}

/// FNV-1a over the dimension and raw f64 bits of both histograms.
/// Bit-exact: two pairs collide only if every weight is identical (or in
/// the astronomically unlikely 64-bit hash collision).
pub fn fingerprint_pair(r: &Histogram, c: &Histogram) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut eat = |word: u64| {
        for byte in word.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    eat(r.dim() as u64);
    for &x in r.values() {
        eat(x.to_bits());
    }
    eat(c.dim() as u64);
    for &x in c.values() {
        eat(x.to_bits());
    }
    h
}

/// Cumulative counters of one store.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WarmCounters {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
}

/// Bounded LRU cache of converged scaling pairs.
#[derive(Debug)]
pub struct WarmStartStore {
    capacity: usize,
    /// Key -> (cached scalings, recency stamp).
    entries: HashMap<WarmKey, (ScalingInit, u64)>,
    /// Recency stamp -> key; the smallest stamp is the LRU victim.
    order: BTreeMap<u64, WarmKey>,
    clock: u64,
    counters: WarmCounters,
}

impl WarmStartStore {
    /// A store holding at most `capacity` entries (clamped to ≥ 1).
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity: capacity.max(1),
            entries: HashMap::new(),
            order: BTreeMap::new(),
            clock: 0,
            counters: WarmCounters::default(),
        }
    }

    /// Entries currently cached.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Maximum entries the store retains.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Cumulative hit/miss/insert/evict counters.
    pub fn counters(&self) -> WarmCounters {
        self.counters
    }

    fn touch(&mut self, key: WarmKey) {
        self.clock += 1;
        let stamp = self.clock;
        if let Some((_, old)) = self.entries.get_mut(&key) {
            self.order.remove(old);
            *old = stamp;
            self.order.insert(stamp, key);
        }
    }

    /// Look up the cached scalings for `key`, counting a hit or a miss
    /// and refreshing recency on a hit.
    pub fn get(&mut self, key: &WarmKey) -> Option<ScalingInit> {
        match self.entries.get(key) {
            Some((init, _)) => {
                let init = init.clone();
                self.counters.hits += 1;
                self.touch(*key);
                Some(init)
            }
            None => {
                self.counters.misses += 1;
                None
            }
        }
    }

    /// Change the retention bound (clamped to ≥ 1), evicting
    /// least-recently-used entries until the store fits. The retrieval
    /// layer uses this so a compacted shard's per-entry cache capacity
    /// tracks its rebuilt (live) entry count instead of staying frozen
    /// at the original build size.
    pub fn resize(&mut self, capacity: usize) {
        self.capacity = capacity.max(1);
        while self.entries.len() > self.capacity {
            let Some((&stamp, &victim)) = self.order.iter().next() else {
                break;
            };
            self.order.remove(&stamp);
            self.entries.remove(&victim);
            self.counters.evictions += 1;
        }
    }

    /// Insert (or refresh) a converged scaling pair, evicting the least
    /// recently used entry when full.
    pub fn insert(&mut self, key: WarmKey, init: ScalingInit) {
        self.clock += 1;
        let stamp = self.clock;
        if let Some((slot, old)) = self.entries.get_mut(&key) {
            *slot = init;
            self.order.remove(old);
            *old = stamp;
            self.order.insert(stamp, key);
            return;
        }
        if self.entries.len() >= self.capacity {
            if let Some((&victim_stamp, &victim)) = self.order.iter().next() {
                self.order.remove(&victim_stamp);
                self.entries.remove(&victim);
                self.counters.evictions += 1;
            }
        }
        self.entries.insert(key, (init, stamp));
        self.order.insert(stamp, key);
        self.counters.insertions += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simplex::seeded_rng;

    fn init(tag: F, d: usize) -> ScalingInit {
        ScalingInit::warm(vec![tag; d], vec![tag + 0.5; d])
    }

    fn cached_u(init: &ScalingInit) -> Vec<F> {
        init.scalings().expect("stores only hold warm seeds").0.to_vec()
    }

    fn key(fp: u64) -> WarmKey {
        WarmKey { metric: 0, lambda_bits: (9.0 as F).to_bits(), fingerprint: fp }
    }

    #[test]
    fn fingerprint_is_bit_exact() {
        let mut rng = seeded_rng(0);
        let a = Histogram::sample_uniform(8, &mut rng);
        let b = Histogram::sample_uniform(8, &mut rng);
        assert_eq!(fingerprint_pair(&a, &b), fingerprint_pair(&a, &b));
        assert_ne!(fingerprint_pair(&a, &b), fingerprint_pair(&b, &a));
        let c = Histogram::sample_uniform(9, &mut rng);
        assert_ne!(fingerprint_pair(&a, &b), fingerprint_pair(&a, &c));
    }

    #[test]
    fn hit_miss_and_counters() {
        let mut store = WarmStartStore::new(8);
        assert!(store.get(&key(1)).is_none());
        store.insert(key(1), init(1.0, 4));
        let got = store.get(&key(1)).expect("cached");
        assert_eq!(cached_u(&got), vec![1.0; 4]);
        let c = store.counters();
        assert_eq!((c.hits, c.misses, c.insertions, c.evictions), (1, 1, 1, 0));
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut store = WarmStartStore::new(2);
        store.insert(key(1), init(1.0, 2));
        store.insert(key(2), init(2.0, 2));
        // Touch 1 so 2 becomes the LRU victim.
        assert!(store.get(&key(1)).is_some());
        store.insert(key(3), init(3.0, 2));
        assert_eq!(store.len(), 2);
        assert!(store.get(&key(2)).is_none(), "2 was evicted");
        assert!(store.get(&key(1)).is_some());
        assert!(store.get(&key(3)).is_some());
        assert_eq!(store.counters().evictions, 1);
    }

    #[test]
    fn reinsert_refreshes_in_place() {
        let mut store = WarmStartStore::new(2);
        store.insert(key(1), init(1.0, 2));
        store.insert(key(1), init(9.0, 2));
        assert_eq!(store.len(), 1);
        assert_eq!(cached_u(&store.get(&key(1)).unwrap()), vec![9.0; 2]);
        store.insert(key(2), init(2.0, 2));
        store.insert(key(3), init(3.0, 2));
        // Recency order before the last insert was [1 (refreshed by the
        // get), 2], so 1 is the LRU victim.
        assert!(store.get(&key(1)).is_none());
        assert!(store.get(&key(2)).is_some());
        assert!(store.get(&key(3)).is_some());
    }

    #[test]
    fn resize_shrinks_by_recency_and_grows_in_place() {
        let mut store = WarmStartStore::new(4);
        for fp in 1..=4u64 {
            store.insert(key(fp), init(fp as F, 2));
        }
        // Touch 1 and 3 so 2 is the coldest, then shrink to 2 slots:
        // the two least-recently-used entries (2, then 4) are evicted.
        assert!(store.get(&key(1)).is_some());
        assert!(store.get(&key(3)).is_some());
        store.resize(2);
        assert_eq!((store.capacity(), store.len()), (2, 2));
        assert!(store.get(&key(2)).is_none() && store.get(&key(4)).is_none());
        assert!(store.get(&key(1)).is_some() && store.get(&key(3)).is_some());
        assert_eq!(store.counters().evictions, 2);
        // Growing never drops entries, and 0 clamps to 1.
        store.resize(8);
        assert_eq!((store.capacity(), store.len()), (8, 2));
        store.resize(0);
        assert_eq!((store.capacity(), store.len()), (1, 1));
    }

    #[test]
    fn capacity_clamps_to_one() {
        let mut store = WarmStartStore::new(0);
        assert_eq!(store.capacity(), 1);
        store.insert(key(1), init(1.0, 1));
        store.insert(key(2), init(2.0, 1));
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn potentials_map_zeros_to_neg_infinity() {
        let s = ScalingInit::warm(vec![1.0, 0.0], vec![0.5, 2.0]);
        let (f, g) = s.potentials().expect("warm seeds have potentials");
        assert_eq!(f[0], 0.0);
        assert_eq!(f[1], F::NEG_INFINITY);
        assert!((g[1] - (2.0 as F).ln()).abs() < 1e-15);
        assert!(ScalingInit::Cold.potentials().is_none());
        assert!(ScalingInit::default().is_cold());
    }
}
