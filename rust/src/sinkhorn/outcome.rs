//! Certified anytime solves: error intervals, budgets and outcomes.
//!
//! Every Sinkhorn scaling state (u, v) prices the *exact* dual-Sinkhorn
//! divergence d_M^λ(r, c) from both sides, no matter how the state was
//! produced (dense, log-domain, greedy, or an approximate-kernel walk):
//!
//! * **lower bound** — the Lagrangian dual of the entropic program at
//!   the potentials (f, g) = (log u, log v),
//!   `lo = (rᵀf + cᵀg − Σᵢⱼ e^{fᵢ+gⱼ−λmᵢⱼ} + 1)/λ`.
//!   Weak duality puts this below the optimal free energy, which sits
//!   below d^λ because the plan entropy h(P★) is nonnegative. Each full
//!   Sinkhorn iteration is exact block-coordinate *ascent* on this dual,
//!   so the bound only improves as iterations accrue.
//! * **upper bound** — round the primal read-off P = e^{f+g−λM} onto
//!   the transport polytope U(r, c) with Altschuler–Weed–Rigollet's
//!   Algorithm 2 (arXiv 1705.09634): shrink rows, then columns, then
//!   patch the missing mass with a rank-one outer product. The rounded
//!   plan P̂ is feasible, so its free energy dominates the optimum, and
//!   entropy subadditivity h(P★) ≤ h(r) + h(c) turns that into
//!   `hi = ⟨P̂, M⟩ + (h(r) + h(c) − h(P̂))/λ ≥ d^λ`.
//!
//! Both bounds are evaluated against the **exact** cost matrix, so they
//! stay sound when the iterates came from a truncated or low-rank kernel
//! — the certificate never inherits the approximation.
//!
//! Budgeted solves slice the iteration into [`CERT_STRIDE`]-sized runs,
//! warm-carrying the scaling between slices (bit-identical to one long
//! run on the dense path) and intersecting the per-slice certificates,
//! so the returned interval width is monotone nonincreasing in the
//! iteration budget on the stride lattice.

use super::{ScalingInit, SinkhornOutput};
use crate::F;
use std::time::{Duration, Instant};

/// Iterations per certificate slice of a budgeted solve. Slices nest —
/// budget 16 replays budget 8's first slice exactly — which is what
/// makes the intersected interval width monotone across budgets.
pub const CERT_STRIDE: usize = 8;

/// A certified two-sided bound on the exact d_M^λ(r, c).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ErrorInterval {
    /// Certified lower bound (≥ 0; d^λ is a nonnegative cost).
    pub lo: F,
    /// Certified upper bound (+∞ when no feasible rounding exists yet).
    pub hi: F,
}

impl ErrorInterval {
    /// The vacuous certificate [0, ∞) — what a solve knows before its
    /// first certified slice.
    pub const UNBOUNDED: Self = Self { lo: 0.0, hi: F::INFINITY };

    /// A zero-width interval at an exactly-known value (the exact
    /// simplex backend's certificate).
    pub fn point(value: F) -> Self {
        Self { lo: value, hi: value }
    }

    /// hi − lo (∞ while one side is still vacuous).
    pub fn width(&self) -> F {
        self.hi - self.lo
    }

    /// Whether `x` lies inside the closed interval.
    pub fn contains(&self, x: F) -> bool {
        self.lo <= x && x <= self.hi
    }

    /// Intersection of two certificates of the same quantity. Both
    /// contain d^λ, so the intersection is mathematically nonempty;
    /// floating-point jitter that crosses the sides collapses to the
    /// midpoint rather than returning an inverted interval.
    pub fn intersect(self, other: Self) -> Self {
        let lo = self.lo.max(other.lo);
        let hi = self.hi.min(other.hi);
        if lo > hi {
            let mid = 0.5 * (lo + hi);
            return Self { lo: mid, hi: mid };
        }
        Self { lo, hi }
    }
}

/// How long a solve may run.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum SolveBudget {
    /// Run the backend's own convergence/iteration policy unchanged; the
    /// certificate is computed once on the final state. Results are
    /// bit-identical to the pre-anytime entry points.
    #[default]
    Unbounded,
    /// At most this many fixed-point iterations (anneal-prefix
    /// iterations count), certified every [`CERT_STRIDE`].
    Iterations(usize),
    /// Iterate in [`CERT_STRIDE`] slices until the wall-clock deadline
    /// passes; at least one slice always runs, so an expired deadline
    /// still yields an estimate and a certificate.
    Deadline(Instant),
}

impl SolveBudget {
    /// A deadline `dur` from now.
    pub fn deadline_in(dur: Duration) -> Self {
        SolveBudget::Deadline(Instant::now() + dur)
    }

    /// Whether this is the exact-reproduction (no budget) mode.
    pub fn is_unbounded(&self) -> bool {
        matches!(self, SolveBudget::Unbounded)
    }

    /// Whether a wall-clock deadline has already passed (always false
    /// for iteration budgets).
    pub fn expired(&self) -> bool {
        match self {
            SolveBudget::Deadline(t) => Instant::now() >= *t,
            _ => false,
        }
    }

    /// The iteration cap, when one is set.
    pub fn iteration_cap(&self) -> Option<usize> {
        match self {
            SolveBudget::Iterations(n) => Some(*n),
            _ => None,
        }
    }

    /// An iteration budget from the Altschuler–Weed–Rigollet analysis:
    /// to serve d^λ within additive error ε on a d-bin problem with
    /// costs bounded by `max_cost`, Sinkhorn needs at most
    /// `2 + 4·ln d / ε′²` iterations at ε′ = ε / (8·max_cost) (their
    /// Theorem 2 marginal-accuracy bound driving Algorithm 2's rounding
    /// guarantee). Pessimistic in practice — the certificate interval is
    /// the ground truth — but it gives deadline planning a principled
    /// worst case.
    pub fn for_error(d: usize, max_cost: F, eps: F) -> Self {
        assert!(eps > 0.0 && eps.is_finite(), "target error must be positive");
        assert!(max_cost > 0.0 && max_cost.is_finite(), "max cost must be positive");
        let eps_prime = eps / (8.0 * max_cost);
        let iters = 2.0 + 4.0 * (d.max(2) as F).ln() / (eps_prime * eps_prime);
        SolveBudget::Iterations(iters.min(1e9).ceil() as usize)
    }

    /// The matching AWR entropic weight for target error ε:
    /// λ = 4·ln d / ε. Together with [`Self::for_error`] this is the
    /// (λ-schedule, budget) pair the anytime tier plans from.
    pub fn lambda_for_error(d: usize, eps: F) -> F {
        assert!(eps > 0.0 && eps.is_finite(), "target error must be positive");
        4.0 * (d.max(2) as F).ln() / eps
    }
}

/// What an anytime solve returns: the served estimate plus its
/// certificate and run metadata — the interval/iteration/stabilized
/// story that used to be side-channeled through per-shard reports.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SolveOutcome {
    /// The served d_M^λ estimate (the primal read-off of the final
    /// state; inside `interval` up to solver-noise at convergence).
    pub estimate: F,
    /// Certified bracket on the exact d^λ.
    pub interval: ErrorInterval,
    /// Fixed-point iterations spent (anneal prefix included).
    pub iterations: usize,
    /// Whether any slice ran on the log-domain stabilized path.
    pub stabilized: bool,
    /// Whether the solve met its convergence criterion before the
    /// budget expired.
    pub converged: bool,
}

impl SolveOutcome {
    /// Wrap a finished [`SinkhornOutput`] with its certificate.
    pub fn from_output(out: &SinkhornOutput, interval: ErrorInterval) -> Self {
        Self {
            estimate: out.value,
            interval,
            iterations: out.stats.iterations,
            stabilized: out.stats.stabilized,
            converged: out.stats.converged,
        }
    }

    /// The served value (accessor mirror for call sites migrating off
    /// bare-`f64` returns).
    pub fn distance(&self) -> F {
        self.estimate
    }

    /// An estimate served without a certificate — paths that only hand
    /// back a bare distance (e.g. a fixed-budget XLA artifact). The
    /// interval is vacuous and `converged` stays false: nothing was
    /// convergence-checked.
    pub fn uncertified(estimate: F) -> Self {
        Self {
            estimate,
            interval: ErrorInterval::UNBOUNDED,
            iterations: 0,
            stabilized: false,
            converged: false,
        }
    }
}

/// Certify a scaling state against the exact cost matrix: lower bound
/// from the dual objective, upper bound from the AWR-rounded primal.
/// Sound for *any* (u, v) ≥ 0 — warm, mid-iteration, or produced by an
/// approximate kernel — because neither bound assumes feasibility.
pub fn certify(
    m: &[F],
    d: usize,
    lambda: F,
    r: &[F],
    c: &[F],
    out: &SinkhornOutput,
) -> ErrorInterval {
    debug_assert_eq!(m.len(), d * d);
    debug_assert_eq!(out.u.len(), d);
    debug_assert_eq!(out.v.len(), d);
    let neg = F::NEG_INFINITY;
    let ln0 = |x: F| if x > 0.0 { x.ln() } else { neg };
    let f: Vec<F> = out.u.iter().map(|&x| ln0(x)).collect();
    let g: Vec<F> = out.v.iter().map(|&x| ln0(x)).collect();

    // P = e^{f + g − λM} against the exact costs; −∞ potentials (zero
    // scalings) contribute zero mass.
    let mut p = vec![0.0; d * d];
    let mut mass = 0.0;
    for i in 0..d {
        if f[i] == neg {
            continue;
        }
        let row = &m[i * d..(i + 1) * d];
        let prow = &mut p[i * d..(i + 1) * d];
        for j in 0..d {
            if g[j] == neg {
                continue;
            }
            let e = (f[i] + g[j] - lambda * row[j]).exp();
            prow[j] = e;
            mass += e;
        }
    }
    if !mass.is_finite() {
        // A diverged scaling prices nothing; the caller's running
        // intersection keeps whatever earlier slices certified.
        return ErrorInterval::UNBOUNDED;
    }

    // Lower: the dual objective at (f, g). Zero-mass bins are excluded
    // (their potentials are −∞ but carry no mass, so they contribute 0).
    let mut dual = 0.0;
    for i in 0..d {
        if r[i] > 0.0 {
            dual += r[i] * f[i];
        }
    }
    for j in 0..d {
        if c[j] > 0.0 {
            dual += c[j] * g[j];
        }
    }
    let mut lo = (dual - mass + 1.0) / lambda;
    if !lo.is_finite() {
        lo = 0.0;
    }
    lo = lo.max(0.0);

    // Upper: AWR Algorithm 2 rounding, in place on p.
    // Shrink rows to their targets…
    let mut row_sum = vec![0.0; d];
    for i in 0..d {
        row_sum[i] = p[i * d..(i + 1) * d].iter().sum();
    }
    for i in 0..d {
        let x = if row_sum[i] > 0.0 { (r[i] / row_sum[i]).min(1.0) } else { 0.0 };
        if x != 1.0 {
            for e in &mut p[i * d..(i + 1) * d] {
                *e *= x;
            }
        }
    }
    // …then columns…
    let mut col_sum = vec![0.0; d];
    for i in 0..d {
        for (j, cs) in col_sum.iter_mut().enumerate() {
            *cs += p[i * d + j];
        }
    }
    let y: Vec<F> = col_sum
        .iter()
        .zip(c)
        .map(|(&s, &cj)| if s > 0.0 { (cj / s).min(1.0) } else { 0.0 })
        .collect();
    for i in 0..d {
        for (j, &yj) in y.iter().enumerate() {
            p[i * d + j] *= yj;
        }
    }
    // …and patch the shortfall with the rank-one correction.
    let mut err_r = vec![0.0; d];
    let mut err_c = vec![0.0; d];
    for i in 0..d {
        let s: F = p[i * d..(i + 1) * d].iter().sum();
        err_r[i] = (r[i] - s).max(0.0);
    }
    for j in 0..d {
        let s: F = (0..d).map(|i| p[i * d + j]).sum();
        err_c[j] = (c[j] - s).max(0.0);
    }
    let shortfall: F = err_r.iter().sum();
    if shortfall > 0.0 {
        let ec_sum: F = err_c.iter().sum();
        if ec_sum > 0.0 {
            // Normalize by the column shortfall so P̂'s columns land
            // exactly on c even under fp drift between the two sums.
            for i in 0..d {
                if err_r[i] == 0.0 {
                    continue;
                }
                let scale = err_r[i] / ec_sum;
                for (j, &ecj) in err_c.iter().enumerate() {
                    p[i * d + j] += scale * ecj;
                }
            }
        }
    }
    // hi = ⟨P̂, M⟩ + (h(r) + h(c) − h(P̂))/λ.
    let mut cost = 0.0;
    let mut h_plan = 0.0;
    for (pe, &me) in p.iter().zip(m) {
        let x = *pe;
        if x > 0.0 {
            cost += x * me;
            h_plan -= x * x.ln();
        }
    }
    let h_marginals = entropy(r) + entropy(c);
    let mut hi = cost + (h_marginals - h_plan) / lambda;
    if !hi.is_finite() {
        hi = F::INFINITY;
    }
    if lo > hi {
        // Solver-noise crossover at (near-)convergence: collapse to the
        // certified upper side rather than inverting.
        lo = hi;
    }
    ErrorInterval { lo, hi }
}

/// Shannon entropy h(p) = −Σ p ln p with 0·ln 0 = 0.
pub(crate) fn entropy(p: &[F]) -> F {
    p.iter().filter(|&&x| x > 0.0).map(|&x| -x * x.ln()).sum()
}

/// Drive a budgeted solve over closures: `full` is the backend's
/// unbounded entry point (bit-identical reproduction), `capped(init,
/// cap)` runs at most `cap` iterations from `init`, and `cert` prices a
/// state. Shared by the [`crate::backend::SolverBackend`] default and
/// the engine/batch convenience wrappers so the slicing policy lives in
/// exactly one place.
pub(crate) fn drive_budgeted(
    budget: SolveBudget,
    init: &ScalingInit,
    full: impl FnOnce(&ScalingInit) -> SinkhornOutput,
    capped: impl Fn(&ScalingInit, usize) -> SinkhornOutput,
    cert: impl Fn(&SinkhornOutput) -> ErrorInterval,
) -> SolveOutcome {
    // PR 9: consume this column's trace attribution unconditionally (even
    // on the unbounded early return) so a panel's column cursor stays
    // aligned with the caller's per-pair loop.
    let trace = crate::trace::ctx::next_column();
    let cap = match budget {
        SolveBudget::Unbounded => {
            let out = full(init);
            let interval = cert(&out);
            return SolveOutcome::from_output(&out, interval);
        }
        SolveBudget::Iterations(n) => Some(n.max(1)),
        SolveBudget::Deadline(_) => None,
    };
    let mut carry = init.clone();
    let mut interval = ErrorInterval::UNBOUNDED;
    let mut iterations = 0usize;
    let mut stabilized = false;
    let mut slice_index = 0usize;
    loop {
        let step = match cap {
            Some(n) => CERT_STRIDE.min(n - iterations).max(1),
            None => CERT_STRIDE,
        };
        let slice_start = trace.as_ref().map(|t| t.sink.now_us());
        let out = capped(&carry, step);
        iterations += out.stats.iterations;
        stabilized |= out.stats.stabilized;
        interval = interval.intersect(cert(&out));
        if let (Some(t), Some(start_us)) = (&trace, slice_start) {
            t.sink.record(crate::trace::Span {
                trace: t.trace,
                stage: crate::trace::Stage::Slice,
                tenant: t.tenant,
                start_us,
                end_us: t.sink.now_us(),
                tid: 0,
                data: crate::trace::SpanData::Slice {
                    index: slice_index,
                    iterations: out.stats.iterations,
                    width: interval.width(),
                },
            });
        }
        slice_index += 1;
        let exhausted = match cap {
            Some(n) => iterations >= n,
            None => budget.expired(),
        };
        // A zero-iteration slice means the backend has nothing left to
        // do (e.g. a greedy solver at exact marginals in fixed-budget
        // mode); continuing would spin forever.
        if out.stats.converged
            || exhausted
            || !out.value.is_finite()
            || out.stats.iterations == 0
        {
            return SolveOutcome {
                estimate: out.value,
                interval,
                iterations,
                stabilized,
                converged: out.stats.converged,
            };
        }
        carry = ScalingInit::from_output(&out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sinkhorn::SinkhornStats;

    fn output(u: Vec<F>, v: Vec<F>, value: F) -> SinkhornOutput {
        SinkhornOutput { value, u, v, stats: SinkhornStats::default() }
    }

    #[test]
    fn interval_algebra() {
        let a = ErrorInterval { lo: 1.0, hi: 3.0 };
        let b = ErrorInterval { lo: 2.0, hi: 5.0 };
        let i = a.intersect(b);
        assert_eq!(i, ErrorInterval { lo: 2.0, hi: 3.0 });
        assert!((i.width() - 1.0).abs() < 1e-15);
        assert!(i.contains(2.5) && !i.contains(4.0));
        // Disjoint-by-jitter collapses to a point instead of inverting.
        let j = ErrorInterval { lo: 3.5, hi: 4.0 }.intersect(a);
        assert!(j.lo == j.hi && j.width() == 0.0);
        assert_eq!(ErrorInterval::point(2.0).width(), 0.0);
        assert!(ErrorInterval::UNBOUNDED.contains(1e18));
    }

    #[test]
    fn budget_modes() {
        assert!(SolveBudget::default().is_unbounded());
        assert_eq!(SolveBudget::Iterations(7).iteration_cap(), Some(7));
        assert!(!SolveBudget::Iterations(7).expired());
        let past = SolveBudget::Deadline(Instant::now() - Duration::from_millis(1));
        assert!(past.expired());
        let future = SolveBudget::deadline_in(Duration::from_secs(3600));
        assert!(!future.expired());
    }

    #[test]
    fn awr_planning_bounds_scale_with_error() {
        let loose = SolveBudget::for_error(16, 1.0, 0.5);
        let tight = SolveBudget::for_error(16, 1.0, 0.1);
        let (Some(a), Some(b)) = (loose.iteration_cap(), tight.iteration_cap()) else {
            panic!("for_error must produce iteration budgets");
        };
        assert!(b > a, "tighter error must buy more iterations: {a} vs {b}");
        assert!(SolveBudget::lambda_for_error(16, 0.1) > SolveBudget::lambda_for_error(16, 0.5));
    }

    #[test]
    fn certify_brackets_a_converged_two_bin_solve() {
        // d = 2, m = [[0, 1], [1, 0]], uniform marginals: by symmetry the
        // entropic plan is [[a, b], [b, a]] with a + b = 1/2 and
        // b/a = e^{−λ}; d^λ = 2b.
        let lambda = 3.0;
        let m = vec![0.0, 1.0, 1.0, 0.0];
        let r = [0.5, 0.5];
        let c = [0.5, 0.5];
        let b = 0.5 / (1.0 + (lambda as F).exp());
        let a = 0.5 - b;
        let exact = 2.0 * b;
        // Scalings realizing that plan: u_i v_j e^{−λ m_ij} = P_ij with
        // u = v = sqrt(a) works since a·(b/a) = b ⇔ e^{−λ} = b/a.
        let s = a.sqrt();
        let out = output(vec![s, s], vec![s, s], exact);
        let iv = certify(&m, 2, lambda, &r, &c, &out);
        assert!(
            iv.lo <= exact + 1e-12 && exact <= iv.hi + 1e-12,
            "exact {exact} outside [{}, {}]",
            iv.lo,
            iv.hi
        );
        // At convergence the width is (h(r) + h(c))/λ up to fp noise.
        let want = (entropy(&r) + entropy(&c)) / lambda;
        assert!((iv.width() - want).abs() < 1e-9, "width {} vs {want}", iv.width());
    }

    #[test]
    fn deadline_budget_terminates_on_a_zero_iteration_slice() {
        use crate::backend::{GreenkhornBackend, SolverBackend};
        use crate::metric::RandomMetric;
        use crate::simplex::{seeded_rng, Histogram};
        use crate::sinkhorn::SinkhornConfig;

        let mut rng = seeded_rng(17);
        let d = 10;
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        let backend = GreenkhornBackend::new(
            &m,
            SinkhornConfig {
                lambda: 6.0,
                tolerance: 1e-10,
                max_iterations: 200_000,
                ..Default::default()
            },
        );
        // Converge once, then re-solve warm-seeded at the already-exact
        // marginals: every deadline slice now runs zero greedy updates.
        // Without the zero-iteration-slice break in `drive_budgeted`
        // this would spin until the far-future deadline — the budget
        // never expires and the slices never progress.
        let cold = backend.solve(&r, &c, &ScalingInit::Cold);
        assert!(cold.stats.converged, "cold solve must converge");
        let warm = ScalingInit::from_output(&cold);
        let budget = SolveBudget::deadline_in(Duration::from_secs(3600));
        let outcome = backend.solve_outcome(&r, &c, &warm, budget);
        assert!(outcome.converged, "warm re-solve at exact marginals");
        assert_eq!(outcome.iterations, 0, "the slice ran no greedy updates");
        assert!(
            outcome.interval.lo <= outcome.interval.hi,
            "interval inverted: [{}, {}]",
            outcome.interval.lo,
            outcome.interval.hi
        );
        assert!(outcome.interval.hi.is_finite());
        assert!(
            outcome.interval.lo <= outcome.estimate + 1e-9
                && outcome.estimate <= outcome.interval.hi + 1e-9,
            "estimate {} outside certified [{}, {}]",
            outcome.estimate,
            outcome.interval.lo,
            outcome.interval.hi
        );
    }

    #[test]
    fn certify_survives_degenerate_states() {
        let m = vec![0.0, 1.0, 1.0, 0.0];
        let r = [0.5, 0.5];
        let c = [0.5, 0.5];
        // All-zero scalings (poisoned): vacuous but well-formed.
        let iv = certify(&m, 2, 9.0, &r, &c, &output(vec![0.0; 2], vec![0.0; 2], F::NAN));
        assert_eq!(iv.lo, 0.0);
        assert!(iv.hi.is_infinite());
        // Diverged scalings (overflowing mass): vacuous, not NaN.
        let iv = certify(
            &m,
            2,
            9.0,
            &r,
            &c,
            &output(vec![1e300; 2], vec![1e300; 2], F::INFINITY),
        );
        assert_eq!(iv, ErrorInterval::UNBOUNDED);
        // Zero-mass bins are skipped, bounds stay finite.
        let iv = certify(
            &m,
            2,
            2.0,
            &[1.0, 0.0],
            &[0.0, 1.0],
            &output(vec![1.0, 0.0], vec![0.0, 1.0], 1.0),
        );
        assert!(iv.lo.is_finite() && iv.hi.is_finite());
        assert!(iv.contains(1.0), "dirac-to-dirac cost 1 outside {iv:?}");
    }
}
