//! Log-domain (stabilized) Sinkhorn updates.
//!
//! The dense kernel K = e^{−λM} underflows once λ·max(M) exceeds ~700 in
//! f64 — precisely the "diagonally dominant" regime the paper probes in
//! Figure 5, where e^{−λM} has "mostly negligible values". The standard
//! remedy keeps the dual variables f = log u, g = log v and replaces the
//! matvecs with log-sum-exp reductions:
//!
//! ```text
//! g_j = log c_j − LSE_i(−λ m_ij + f_i)
//! f_i = log r_i − LSE_j(−λ m_ij + g_j)
//! ```
//!
//! Mathematically identical to Algorithm 1, numerically exact at any λ.
//! The engine ([`super::SinkhornEngine`]) auto-routes here when it detects
//! underflow; it is also the reference for large-λ Fig. 3 points.

use super::{LambdaSchedule, ScalingInit, SinkhornConfig, SinkhornOutput, SinkhornStats};
use crate::F;

/// Solve one pair in the log domain. `m` is the row-major cost matrix.
pub fn solve(
    m: &[F],
    d: usize,
    lambda: F,
    cfg: &SinkhornConfig,
    r: &[F],
    c: &[F],
) -> SinkhornOutput {
    solve_inner(m, d, lambda, cfg, r, c, &ScalingInit::Cold, None)
}

/// [`solve`] seeded by `init`. A [`ScalingInit::Warm`] seed enters as
/// potentials f = log u (the g side is recomputed from f at the top of
/// every iteration) and skips the ε-scaling prefix; a cold start runs the
/// prefix when the config carries a [`LambdaSchedule::Geometric`].
pub fn solve_init(
    m: &[F],
    d: usize,
    lambda: F,
    cfg: &SinkhornConfig,
    r: &[F],
    c: &[F],
    init: &ScalingInit,
) -> SinkhornOutput {
    solve_inner(m, d, lambda, cfg, r, c, init, None)
}

/// One budget slice of [`solve_init`]: at most `cap` iterations this
/// call, replacing the config's iteration cap. Warm-carrying the
/// returned scalings into the next capped call continues the iteration
/// exactly (the g side is recomputed from f before it is read, so only
/// the f potential needs to survive the round-trip through u = e^f).
pub fn solve_capped(
    m: &[F],
    d: usize,
    lambda: F,
    cfg: &SinkhornConfig,
    r: &[F],
    c: &[F],
    init: &ScalingInit,
    cap: usize,
) -> SinkhornOutput {
    solve_inner(m, d, lambda, cfg, r, c, init, Some(cap))
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn solve_inner(
    m: &[F],
    d: usize,
    lambda: F,
    cfg: &SinkhornConfig,
    r: &[F],
    c: &[F],
    init: &ScalingInit,
    cap: Option<usize>,
) -> SinkhornOutput {
    let neg = F::NEG_INFINITY;
    let log_r: Vec<F> = r.iter().map(|&x| if x > 0.0 { x.ln() } else { neg }).collect();
    let log_c: Vec<F> = c.iter().map(|&x| if x > 0.0 { x.ln() } else { neg }).collect();

    // f = log u, g = log v; init u = 1/d (or the warm start's potential).
    // Only the f side of a warm start matters: g is recomputed from f at
    // the top of every iteration before it is ever read.
    let mut f;
    let prefix;
    match init.scalings() {
        Some((su, _)) => {
            assert_eq!(su.len(), d, "warm-start dimension mismatch");
            f = su
                .iter()
                .map(|&x| if x > 0.0 { x.ln() } else { neg })
                .collect();
            prefix = 0;
        }
        None => {
            f = vec![-(d as F).ln(); d];
            prefix = anneal_prefix_log(m, d, lambda, &cfg.schedule, &log_r, &log_c, &mut f);
        }
    }
    let mut g = vec![0.0; d];
    let mut f_prev = vec![0.0; d];
    // Scratch for LSE rows.
    let mut buf = vec![0.0; d];

    let mut stats = SinkhornStats {
        stabilized: true,
        last_delta: F::INFINITY,
        ..Default::default()
    };

    let max_iterations = cap.unwrap_or(cfg.max_iterations);
    let mut iter = 0;
    while iter < max_iterations {
        iter += 1;
        update_g(m, d, lambda, &f, &log_c, &mut g, &mut buf);
        std::mem::swap(&mut f, &mut f_prev);
        update_f(m, d, lambda, &g, &log_r, &mut f, &mut buf);

        let check = cfg.check_every != usize::MAX && iter % cfg.check_every == 0;
        if check {
            // Measure on u = e^f to match the dense criterion.
            let mut delta = 0.0;
            for i in 0..d {
                let (a, b) = (exp0(f[i]), exp0(f_prev[i]));
                let e = a - b;
                delta += e * e;
            }
            stats.last_delta = delta.sqrt();
            if stats.last_delta <= cfg.tolerance {
                stats.converged = true;
                break;
            }
        }
    }
    stats.iterations = prefix + iter;

    // d = sum_ij m_ij * exp(f_i - lam m_ij + g_j).
    let mut value = 0.0;
    for i in 0..d {
        if f[i] == neg {
            continue;
        }
        let row = &m[i * d..(i + 1) * d];
        for j in 0..d {
            if g[j] == neg {
                continue;
            }
            let p = (f[i] - lambda * row[j] + g[j]).exp();
            value += row[j] * p;
        }
    }

    SinkhornOutput {
        value,
        u: f.iter().map(|&x| exp0(x)).collect(),
        v: g.iter().map(|&x| exp0(x)).collect(),
        stats,
    }
}

/// Run the ε-scaling prefix in the log domain: a few LSE iterations at
/// each stage λ_s, with the potential transferred between stages by
/// fixing the dual α = f/λ — in log space `f ← (f − max f)·ratio` (the
/// max-subtraction mirrors [`super::transfer_panel`]'s renormalization
/// and keeps the carried potential centered). Evolves `f` in place and
/// returns the iterations consumed; `f` comes back at the λ★ scale.
fn anneal_prefix_log(
    m: &[F],
    d: usize,
    lambda_star: F,
    schedule: &LambdaSchedule,
    log_r: &[F],
    log_c: &[F],
    f: &mut [F],
) -> usize {
    let stages = schedule.prefix_stages(lambda_star);
    if stages.is_empty() {
        return 0;
    }
    let per_stage = schedule.stage_iterations();
    let mut g = vec![0.0; d];
    let mut buf = vec![0.0; d];
    let mut prev: Option<F> = None;
    let mut iters = 0;
    for &lam_s in &stages {
        if let Some(lp) = prev {
            transfer_potential(f, lam_s / lp);
        }
        for _ in 0..per_stage {
            update_g(m, d, lam_s, f, log_c, &mut g, &mut buf);
            update_f(m, d, lam_s, &g, log_r, f, &mut buf);
        }
        iters += per_stage;
        prev = Some(lam_s);
    }
    if let Some(lp) = prev {
        transfer_potential(f, lambda_star / lp);
    }
    iters
}

/// One g half-iteration: g_j = log c_j − LSE_i(−λ m_ij + f_i) (column
/// reduction). Shared by the main loop and the ε-scaling prefix so the
/// update rule lives in exactly one place.
#[inline]
fn update_g(m: &[F], d: usize, lambda: F, f: &[F], log_c: &[F], g: &mut [F], buf: &mut [F]) {
    let neg = F::NEG_INFINITY;
    for j in 0..d {
        for (i, b) in buf.iter_mut().enumerate() {
            *b = -lambda * m[i * d + j] + f[i];
        }
        g[j] = if log_c[j] == neg { neg } else { log_c[j] - lse(buf) };
    }
}

/// One f half-iteration: f_i = log r_i − LSE_j(−λ m_ij + g_j) (row
/// reduction).
#[inline]
fn update_f(m: &[F], d: usize, lambda: F, g: &[F], log_r: &[F], f: &mut [F], buf: &mut [F]) {
    let neg = F::NEG_INFINITY;
    for i in 0..d {
        let row = &m[i * d..(i + 1) * d];
        for (j, b) in buf.iter_mut().enumerate() {
            *b = -lambda * row[j] + g[j];
        }
        f[i] = if log_r[i] == neg { neg } else { log_r[i] - lse(buf) };
    }
}

/// Log-space scaling transfer: `f ← (f − max f)·ratio`, −∞ staying −∞.
fn transfer_potential(f: &mut [F], ratio: F) {
    let mx = f.iter().cloned().filter(|x| x.is_finite()).fold(F::NEG_INFINITY, F::max);
    if !mx.is_finite() {
        return;
    }
    for x in f.iter_mut() {
        if x.is_finite() {
            *x = (*x - mx) * ratio;
        }
    }
}

#[inline]
fn exp0(x: F) -> F {
    if x == F::NEG_INFINITY {
        0.0
    } else {
        x.exp()
    }
}

/// Numerically-stable log-sum-exp.
#[inline]
fn lse(xs: &[F]) -> F {
    let mx = xs.iter().cloned().fold(F::NEG_INFINITY, F::max);
    if mx == F::NEG_INFINITY {
        return F::NEG_INFINITY;
    }
    let s: F = xs.iter().map(|&x| (x - mx).exp()).sum();
    mx + s.ln()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::RandomMetric;
    use crate::simplex::{seeded_rng, Histogram};
    use crate::sinkhorn::SinkhornEngine;

    #[test]
    fn lse_basic() {
        assert!((lse(&[0.0, 0.0]) - (2.0 as F).ln()).abs() < 1e-12);
        assert_eq!(lse(&[F::NEG_INFINITY, F::NEG_INFINITY]), F::NEG_INFINITY);
        // Stability: huge inputs don't overflow.
        assert!((lse(&[1000.0, 1000.0]) - (1000.0 + (2.0 as F).ln())).abs() < 1e-9);
    }

    #[test]
    fn agrees_with_dense_at_moderate_lambda() {
        let mut rng = seeded_rng(12);
        let d = 14;
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        let cfg = SinkhornConfig {
            lambda: 7.0,
            tolerance: 1e-12,
            max_iterations: 100_000,
            ..Default::default()
        };
        let dense = SinkhornEngine::with_config(&m, cfg).distance(&r, &c);
        assert!(!dense.stats.stabilized);
        let logd = solve(m.data(), d, 7.0, &cfg, r.values(), c.values());
        assert!(
            (dense.value - logd.value).abs() < 1e-8,
            "dense {} vs log {}",
            dense.value,
            logd.value
        );
    }

    #[test]
    fn handles_zero_mass_bins() {
        let mut rng = seeded_rng(4);
        let d = 8;
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::from_weights(&[0.5, 0.5, 0., 0., 0., 0., 0., 0.]).unwrap();
        let c = Histogram::from_weights(&[0., 0., 0., 0., 0., 0., 0.5, 0.5]).unwrap();
        let cfg = SinkhornConfig::converged(30.0);
        let out = solve(m.data(), d, 30.0, &cfg, r.values(), c.values());
        assert!(out.value.is_finite());
        assert!(out.value > 0.0);
        assert_eq!(out.u[2], 0.0);
        assert_eq!(out.v[0], 0.0);
    }

    #[test]
    fn warm_start_agrees_and_converges_faster() {
        let mut rng = seeded_rng(31);
        let d = 12;
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        let cfg = SinkhornConfig {
            lambda: 40.0,
            tolerance: 1e-10,
            max_iterations: 200_000,
            ..Default::default()
        };
        let cold = solve(m.data(), d, 40.0, &cfg, r.values(), c.values());
        assert!(cold.stats.converged);
        let seed = ScalingInit::from_output(&cold);
        let warm = solve_init(m.data(), d, 40.0, &cfg, r.values(), c.values(), &seed);
        assert!(warm.stats.converged);
        assert!((warm.value - cold.value).abs() < 1e-7 * (1.0 + cold.value));
        assert!(warm.stats.iterations < cold.stats.iterations);
    }

    #[test]
    fn capped_slices_continue_the_iteration() {
        // Warm-carried 8-iteration slices track one straight fixed run.
        // The carry round-trips f through u = e^f, so agreement is to
        // exp/ln rounding, not bit-exact like the dense path.
        let mut rng = seeded_rng(44);
        let d = 10;
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        let cfg = SinkhornConfig::fixed(120.0, 24);
        let straight = solve(m.data(), d, 120.0, &cfg, r.values(), c.values());
        let mut carry = ScalingInit::Cold;
        let mut sliced = None;
        for _ in 0..3 {
            let out =
                solve_capped(m.data(), d, 120.0, &cfg, r.values(), c.values(), &carry, 8);
            assert_eq!(out.stats.iterations, 8);
            carry = ScalingInit::from_output(&out);
            sliced = Some(out);
        }
        let sliced = sliced.unwrap();
        assert!(
            (sliced.value - straight.value).abs() < 1e-10 * (1.0 + straight.value),
            "sliced {} vs straight {}",
            sliced.value,
            straight.value
        );
    }

    #[test]
    fn annealed_agrees_with_cold_at_high_lambda() {
        let mut rng = seeded_rng(32);
        let d = 10;
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        let base = SinkhornConfig {
            lambda: 80.0,
            tolerance: 1e-10,
            max_iterations: 200_000,
            ..Default::default()
        };
        let cold = solve(m.data(), d, 80.0, &base, r.values(), c.values());
        assert!(cold.stats.converged);
        let annealed_cfg =
            SinkhornConfig { schedule: LambdaSchedule::geometric(2.0), ..base };
        let annealed =
            solve(m.data(), d, 80.0, &annealed_cfg, r.values(), c.values());
        assert!(annealed.stats.converged);
        assert!(
            (annealed.value - cold.value).abs() < 1e-7 * (1.0 + cold.value),
            "annealed {} vs cold {}",
            annealed.value,
            cold.value
        );
    }

    #[test]
    fn extreme_lambda_stays_finite() {
        let mut rng = seeded_rng(21);
        let d = 10;
        let m = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        let cfg = SinkhornConfig {
            lambda: 1e6,
            tolerance: 1e-9,
            max_iterations: 20_000,
            ..Default::default()
        };
        let out = solve(m.data(), d, 1e6, &cfg, r.values(), c.values());
        assert!(out.value.is_finite(), "value {}", out.value);
        assert!(out.value >= 0.0);
    }
}
