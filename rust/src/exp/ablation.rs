//! Ablations of the design choices the paper fixes by fiat.
//!
//! 1. **Iteration budget** (§5.1.2: "We set the number of fixed-point
//!    iterations to an arbitrary number of 20 iterations"): classification
//!    error and distance accuracy as the fixed budget sweeps {1, 2, 5,
//!    20, 100}. The claim to check: 20 is already in the flat region —
//!    more iterations buy accuracy toward the converged divergence but no
//!    classification benefit.
//! 2. **Convergence criterion stride** (§5.4: checking ‖x−x'‖ "can be
//!    costly on parallel platforms"): wallclock of tolerance-driven
//!    solves as the check stride sweeps {1, 4, 16, ∞(fixed budget)}.

use crate::data::{DigitConfig, SyntheticDigits};
use crate::metric::{GridMetric, RandomMetric};
use crate::simplex::{seeded_rng, Histogram};
use crate::sinkhorn::{SinkhornConfig, SinkhornEngine};
use crate::F;
use std::time::Instant;

/// Result row of the iteration-budget ablation.
#[derive(Debug, Clone)]
pub struct BudgetPoint {
    pub iterations: usize,
    /// 1-NN classification error on digit histograms with the budgeted
    /// Sinkhorn distance (cheap stand-in for the full SVM protocol).
    pub knn_error: F,
    /// Mean |d_budget − d_converged| / d_converged over the eval pairs.
    pub distance_drift: F,
}

/// Sweep the fixed iteration budget.
pub fn iteration_budget(
    grid: usize,
    n_train: usize,
    n_test: usize,
    budgets: &[usize],
    seed: u64,
) -> Vec<BudgetPoint> {
    let gen = SyntheticDigits::new(DigitConfig { grid, ..Default::default() });
    let metric = GridMetric::new(grid, grid).cost_matrix();
    let lambda = 9.0 / metric.median_cost();
    let mut rng = seeded_rng(seed);
    let train = gen.dataset(n_train, &mut rng);
    let test = gen.dataset(n_test, &mut rng);

    // Converged reference distances for the drift metric.
    let reference = SinkhornEngine::with_config(
        &metric,
        SinkhornConfig {
            lambda,
            tolerance: 1e-9,
            max_iterations: 200_000,
            ..Default::default()
        },
    );
    let ref_d: Vec<Vec<F>> = test
        .iter()
        .map(|t| {
            train
                .iter()
                .map(|s| reference.distance(&t.histogram, &s.histogram).value)
                .collect()
        })
        .collect();

    budgets
        .iter()
        .map(|&budget| {
            let engine = SinkhornEngine::with_config(
                &metric,
                SinkhornConfig::fixed(lambda, budget),
            );
            let mut wrong = 0usize;
            let mut drift = 0.0;
            let mut drift_n = 0usize;
            for (ti, t) in test.iter().enumerate() {
                let mut best = (F::INFINITY, 0usize);
                for (si, s) in train.iter().enumerate() {
                    let d = engine.distance(&t.histogram, &s.histogram).value;
                    if d < best.0 {
                        best = (d, train[si].label);
                    }
                    let rd = ref_d[ti][si];
                    if rd > 0.0 {
                        drift += (d - rd).abs() / rd;
                        drift_n += 1;
                    }
                }
                if best.1 != t.label {
                    wrong += 1;
                }
            }
            BudgetPoint {
                iterations: budget,
                knn_error: wrong as F / test.len() as F,
                distance_drift: drift / drift_n.max(1) as F,
            }
        })
        .collect()
}

/// Result row of the convergence-check-stride ablation.
#[derive(Debug, Clone)]
pub struct StridePoint {
    /// Check stride (`usize::MAX` = never check, fixed budget of 20).
    pub check_every: usize,
    pub seconds_per_distance: F,
    pub mean_iterations: F,
}

/// Sweep the convergence-check stride at the paper's 0.01 tolerance.
pub fn check_stride(d: usize, strides: &[usize], seed: u64) -> Vec<StridePoint> {
    let mut rng = seeded_rng(seed);
    let metric = RandomMetric::new(d).sample(&mut rng);
    let pairs: Vec<(Histogram, Histogram)> = (0..8)
        .map(|_| {
            (
                Histogram::sample_uniform(d, &mut rng),
                Histogram::sample_uniform(d, &mut rng),
            )
        })
        .collect();
    strides
        .iter()
        .map(|&stride| {
            let config = if stride == usize::MAX {
                SinkhornConfig::fixed(9.0, 20)
            } else {
                SinkhornConfig {
                    lambda: 9.0,
                    tolerance: 0.01,
                    check_every: stride,
                    max_iterations: 100_000,
                    ..Default::default()
                }
            };
            let engine = SinkhornEngine::with_config(&metric, config);
            let t0 = Instant::now();
            let mut iters = 0usize;
            for (r, c) in &pairs {
                iters += engine.distance(r, c).stats.iterations;
            }
            StridePoint {
                check_every: stride,
                seconds_per_distance: t0.elapsed().as_secs_f64() / pairs.len() as F,
                mean_iterations: iters as F / pairs.len() as F,
            }
        })
        .collect()
}

/// Render both ablations.
pub fn render(budget: &[BudgetPoint], stride: &[StridePoint]) -> String {
    let mut out = String::from("iteration-budget ablation (1-NN on digits):\n");
    let mut t = super::Table::new(&["iterations", "knn_error", "distance_drift"]);
    for p in budget {
        t.row(&[
            p.iterations.to_string(),
            format!("{:.4}", p.knn_error),
            format!("{:.4}", p.distance_drift),
        ]);
    }
    out.push_str(&t.render());
    out.push_str("\nconvergence-check stride ablation (tol 0.01):\n");
    let mut t = super::Table::new(&["check_every", "sec/distance", "iterations"]);
    for p in stride {
        t.row(&[
            if p.check_every == usize::MAX { "fixed(20)".into() } else { p.check_every.to_string() },
            format!("{:.3e}", p.seconds_per_distance),
            format!("{:.1}", p.mean_iterations),
        ]);
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_sweep_shapes() {
        let pts = iteration_budget(8, 30, 15, &[1, 20], 3);
        assert_eq!(pts.len(), 2);
        // More iterations -> closer to the converged distance.
        assert!(pts[1].distance_drift < pts[0].distance_drift);
        // And never a *worse* classifier at this scale than 1 iteration
        // by a large margin (1 iteration is K-smoothed TV-ish already).
        assert!(pts[1].knn_error <= pts[0].knn_error + 0.2);
        assert!(pts.iter().all(|p| p.knn_error <= 1.0));
    }

    #[test]
    fn stride_sweep_runs() {
        let pts = check_stride(32, &[1, 8, usize::MAX], 5);
        assert_eq!(pts.len(), 3);
        assert!(pts.iter().all(|p| p.seconds_per_distance > 0.0));
        // Tolerance-driven runs converge; the fixed run does exactly 20.
        assert_eq!(pts[2].mean_iterations, 20.0);
    }
}
