//! Figure 5 — iterations to converge vs dimension, per λ.
//!
//! Paper §5.4: histograms sampled uniformly on Σ_d, random Gaussian-point
//! ground metric (median-normalized), convergence when the change in the
//! scaling iterate drops below 0.01 in Euclidean norm. As λ grows and
//! e^{−λM} becomes diagonally dominant, the fixed point takes longer to
//! reach — the plot the paper uses to justify a fixed iteration budget.

use crate::metric::RandomMetric;
use crate::simplex::{seeded_rng, Histogram};
use crate::sinkhorn::{SinkhornConfig, SinkhornEngine};
use crate::F;

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Fig5Config {
    pub dims: Vec<usize>,
    pub lambdas: Vec<F>,
    /// Random (r, c) pairs averaged per point.
    pub trials: usize,
    pub tolerance: F,
    pub seed: u64,
}

impl Default for Fig5Config {
    fn default() -> Self {
        Self {
            dims: vec![64, 128, 256, 512],
            lambdas: vec![1.0, 5.0, 9.0, 25.0, 50.0],
            trials: 8,
            tolerance: 0.01,
            seed: 42,
        }
    }
}

/// One measured point.
#[derive(Debug, Clone, Copy)]
pub struct Fig5Point {
    pub d: usize,
    pub lambda: F,
    pub mean_iterations: F,
    pub std_iterations: F,
    /// Fraction of trials that hit the iteration cap instead of the
    /// tolerance (should be 0 at sane settings).
    pub capped_fraction: F,
}

/// Run the sweep.
pub fn run(config: &Fig5Config) -> Vec<Fig5Point> {
    let mut out = Vec::new();
    for &d in &config.dims {
        let mut rng = seeded_rng(config.seed ^ (d as u64) << 20);
        let metric = RandomMetric::new(d).sample(&mut rng);
        // Pre-draw the histogram pairs so every lambda sees identical
        // workloads (paired comparisons across the lambda grid).
        let pairs: Vec<(Histogram, Histogram)> = (0..config.trials)
            .map(|_| {
                (
                    Histogram::sample_uniform(d, &mut rng),
                    Histogram::sample_uniform(d, &mut rng),
                )
            })
            .collect();
        for &lambda in &config.lambdas {
            let engine = SinkhornEngine::with_config(
                &metric,
                SinkhornConfig {
                    lambda,
                    tolerance: config.tolerance,
                    max_iterations: 200_000,
                    ..Default::default()
                },
            );
            let mut iters = Vec::with_capacity(pairs.len());
            let mut capped = 0usize;
            for (r, c) in &pairs {
                let sk = engine.distance(r, c);
                iters.push(sk.stats.iterations as F);
                if !sk.stats.converged {
                    capped += 1;
                }
            }
            let (mean, std) = super::mean_std(&iters);
            out.push(Fig5Point {
                d,
                lambda,
                mean_iterations: mean,
                std_iterations: std,
                capped_fraction: capped as F / pairs.len() as F,
            });
        }
    }
    out
}

/// Render the paper's series as a table (one row per (d, λ)).
pub fn render(points: &[Fig5Point]) -> String {
    let mut t = super::Table::new(&["d", "lambda", "iterations", "std", "capped"]);
    for p in points {
        t.row(&[
            p.d.to_string(),
            format!("{:.1}", p.lambda),
            format!("{:.1}", p.mean_iterations),
            format!("{:.1}", p.std_iterations),
            format!("{:.2}", p.capped_fraction),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iterations_increase_with_lambda() {
        // The Figure 5 shape: more iterations for larger lambda.
        let config = Fig5Config {
            dims: vec![32],
            lambdas: vec![1.0, 9.0, 50.0],
            trials: 4,
            ..Default::default()
        };
        let pts = run(&config);
        assert_eq!(pts.len(), 3);
        assert!(
            pts[0].mean_iterations < pts[1].mean_iterations,
            "{} !< {}",
            pts[0].mean_iterations,
            pts[1].mean_iterations
        );
        assert!(pts[1].mean_iterations < pts[2].mean_iterations);
        assert!(pts.iter().all(|p| p.capped_fraction == 0.0));
    }

    #[test]
    fn render_has_one_row_per_point() {
        let config = Fig5Config {
            dims: vec![16, 32],
            lambdas: vec![1.0, 9.0],
            trials: 2,
            ..Default::default()
        };
        let pts = run(&config);
        let s = render(&pts);
        assert_eq!(s.lines().count(), 2 + pts.len());
    }
}
