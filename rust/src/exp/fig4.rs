//! Figure 4 — wallclock per distance vs dimension: exact EMD solvers vs
//! Sinkhorn (CPU) vs Sinkhorn (batched XLA/PJRT runtime).
//!
//! Paper §5.3: histograms uniform on Σ_d, ground metric from Gaussian
//! points in R^{d/10} divided by its median, Sinkhorn run to tolerance
//! 0.01 with λ ∈ {1, 9}. Our columns map to the paper's as:
//!
//! * `emd` — our transportation network simplex (the Rubner/FastEMD
//!   algorithm family). Mirroring "Rubner's implementation cannot be run
//!   for histograms larger than d=512", the harness records — but flags —
//!   points past the `emd_cap`.
//! * `sinkhorn_cpu λ` — Algorithm 1 on one CPU core (paper's single-core
//!   matlab column).
//! * `sinkhorn_xla λ` — the paper's GPGPU column, reinterpreted for this
//!   stack: the batched AOT artifact executed through PJRT, amortized
//!   over a full batch (per-distance time = batch time / batch size).

use crate::metric::RandomMetric;
use crate::ot::EmdSolver;
use crate::runtime::{Flavor, XlaRuntime};
use crate::simplex::{seeded_rng, Histogram};
use crate::sinkhorn::{SinkhornConfig, SinkhornEngine};
use crate::util::bench::Bench;
use crate::F;

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Fig4Config {
    pub dims: Vec<usize>,
    pub lambdas: Vec<F>,
    pub tolerance: F,
    /// Mirror of the "Rubner cannot run d>512" constraint.
    pub emd_cap: usize,
    /// Skip exact EMD entirely (for quick runs).
    pub skip_emd: bool,
    /// Artifact directory for the XLA column (None = skip the column).
    pub artifact_dir: Option<std::path::PathBuf>,
    pub seed: u64,
    /// Timing harness parameters.
    pub bench: Bench,
}

impl Default for Fig4Config {
    fn default() -> Self {
        Self {
            dims: vec![64, 128, 256, 512],
            lambdas: vec![1.0, 9.0],
            tolerance: 0.01,
            emd_cap: 512,
            skip_emd: false,
            artifact_dir: Some(std::path::PathBuf::from("artifacts")),
            seed: 7,
            bench: Bench { warmup: 1, max_samples: 9, budget_secs: 20.0 },
        }
    }
}

/// One (solver, d) timing.
#[derive(Debug, Clone)]
pub struct Fig4Point {
    pub solver: String,
    pub d: usize,
    /// Median seconds per single distance.
    pub seconds_per_distance: F,
    /// True when past the solver's practical cap (reported but flagged).
    pub over_cap: bool,
}

/// Run the sweep.
pub fn run(config: &Fig4Config) -> Vec<Fig4Point> {
    let mut out = Vec::new();
    let mut runtime = config
        .artifact_dir
        .as_ref()
        .and_then(|dir| XlaRuntime::new(dir).ok());

    for &d in &config.dims {
        let mut rng = seeded_rng(config.seed ^ (d as u64) << 18);
        let metric = RandomMetric::new(d).sample(&mut rng);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);

        // --- exact EMD (network simplex) ---
        if !config.skip_emd && d <= config.emd_cap {
            let solver = EmdSolver::new(&metric);
            let t = config.bench.time(|| solver.solve(&r, &c).unwrap().cost);
            out.push(Fig4Point {
                solver: "emd".into(),
                d,
                seconds_per_distance: t.median_ns / 1e9,
                over_cap: false,
            });
        } else if !config.skip_emd {
            out.push(Fig4Point {
                solver: "emd".into(),
                d,
                seconds_per_distance: F::NAN,
                over_cap: true,
            });
        }

        // --- Sinkhorn CPU, convergence-driven (paper tolerance) ---
        for &lambda in &config.lambdas {
            let engine = SinkhornEngine::with_config(
                &metric,
                SinkhornConfig {
                    lambda,
                    tolerance: config.tolerance,
                    max_iterations: 200_000,
                    ..Default::default()
                },
            );
            let t = config.bench.time(|| engine.distance(&r, &c).value);
            out.push(Fig4Point {
                solver: format!("sinkhorn_cpu l={lambda}"),
                d,
                seconds_per_distance: t.median_ns / 1e9,
                over_cap: false,
            });
        }

        // --- Sinkhorn CPU, vectorized batch (Algorithm 1 matrix form) ---
        for &lambda in &config.lambdas {
            let batch = 64usize;
            let engine = crate::sinkhorn::BatchSinkhorn::new(
                &metric,
                SinkhornConfig {
                    lambda,
                    tolerance: config.tolerance,
                    max_iterations: 200_000,
                    ..Default::default()
                },
            );
            let cs: Vec<Histogram> = (0..batch)
                .map(|_| Histogram::sample_uniform(d, &mut rng))
                .collect();
            let t = config.bench.time(|| engine.distances(&r, &cs).len());
            out.push(Fig4Point {
                solver: format!("sinkhorn_cpu_batch l={lambda} (batch {batch})"),
                d,
                seconds_per_distance: t.median_ns / 1e9 / batch as F,
                over_cap: false,
            });
        }

        // --- Sinkhorn XLA batched (fixed 20 iterations, amortized) ---
        if let Some(rt) = runtime.as_mut() {
            for &lambda in &config.lambdas {
                let Ok(variant) = rt.select(d, usize::MAX, Flavor::Xla) else {
                    continue;
                };
                let batch = variant.n;
                let cs: Vec<Histogram> = (0..batch)
                    .map(|_| Histogram::sample_uniform(d, &mut rng))
                    .collect();
                let r_cols: Vec<Vec<F>> =
                    (0..batch).map(|_| r.values().to_vec()).collect();
                let c_cols: Vec<Vec<F>> =
                    cs.iter().map(|h| h.values().to_vec()).collect();
                // Compile outside the timed region (serving warm state).
                rt.execute(&variant, &metric, lambda, &r_cols, &c_cols).unwrap();
                let t = config.bench.time(|| {
                    rt.execute(&variant, &metric, lambda, &r_cols, &c_cols)
                        .unwrap()
                        .distances
                        .len()
                });
                out.push(Fig4Point {
                    solver: format!("sinkhorn_xla l={lambda} (batch {batch})"),
                    d,
                    seconds_per_distance: t.median_ns / 1e9 / batch as F,
                    over_cap: false,
                });
            }
        }
    }
    out
}

/// Render the figure's series plus the §5.3 headline speedup ratios.
pub fn render(points: &[Fig4Point]) -> String {
    let mut t = super::Table::new(&["solver", "d", "sec/distance", "note"]);
    for p in points {
        t.row(&[
            p.solver.clone(),
            p.d.to_string(),
            if p.seconds_per_distance.is_nan() {
                "n/a".into()
            } else {
                format!("{:.3e}", p.seconds_per_distance)
            },
            if p.over_cap { "over solver cap".into() } else { String::new() },
        ]);
    }
    let mut s = t.render();
    // Headline ratio at the largest dimension where both ran.
    let mut best: Option<(usize, F, F)> = None;
    for p in points.iter().filter(|p| p.solver == "emd" && !p.over_cap) {
        if let Some(q) = points.iter().find(|q| {
            q.d == p.d && q.solver.starts_with("sinkhorn_cpu l=9")
        }) {
            if best.map(|(d, _, _)| p.d > d).unwrap_or(true) {
                best = Some((p.d, p.seconds_per_distance, q.seconds_per_distance));
            }
        }
    }
    if let Some((d, emd, sk)) = best {
        s.push_str(&format!(
            "\nheadline: at d={d}, sinkhorn_cpu(l=9) is {:.0}x faster than exact EMD\n",
            emd / sk
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_sweep_shapes() {
        let config = Fig4Config {
            dims: vec![16, 32],
            lambdas: vec![9.0],
            artifact_dir: None,
            skip_emd: false,
            bench: Bench { warmup: 0, max_samples: 3, budget_secs: 5.0 },
            ..Default::default()
        };
        let pts = run(&config);
        // 2 dims x (emd + 1 cpu lambda + 1 cpu batch lambda) = 6 rows.
        assert_eq!(pts.len(), 6);
        assert!(pts.iter().all(|p| p.seconds_per_distance > 0.0));
        let s = render(&pts);
        assert!(s.contains("emd"));
        assert!(s.contains("headline"));
    }

    #[test]
    fn emd_cap_flags_large_dims() {
        let config = Fig4Config {
            dims: vec![32],
            lambdas: vec![],
            emd_cap: 16,
            artifact_dir: None,
            bench: Bench { warmup: 0, max_samples: 1, budget_secs: 1.0 },
            ..Default::default()
        };
        let pts = run(&config);
        assert_eq!(pts.len(), 1);
        assert!(pts[0].over_cap);
        assert!(pts[0].seconds_per_distance.is_nan());
    }
}
