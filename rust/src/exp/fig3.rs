//! Figure 3 — the gap (d_M^λ − d_M)/d_M between the Sinkhorn distance and
//! the exact EMD, as λ grows.
//!
//! Paper §5.2: boxplots of the relative gap over pairs of distinct MNIST
//! digits. The gap is non-negative (the entropy penalty can only add
//! cost), decreases monotonically in λ, and plateaus around ~10% even at
//! large λ — which the paper argues is fine, since closeness to the EMD
//! is not the goal. We reproduce the distribution over synthetic-digit
//! pairs (the MNIST substitute, see [`crate::data`]), with the exact
//! denominator from the network simplex.

use crate::data::{DigitClass, SyntheticDigits};
use crate::ot::EmdSolver;
use crate::simplex::{seeded_rng, Histogram};
use crate::sinkhorn::{SinkhornConfig, SinkhornEngine};
use crate::F;

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Fig3Config {
    /// Digit grid side (paper: 20 → d=400; default scaled to 12 → d=144
    /// to keep the exact-EMD denominators tractable on one core).
    pub grid: usize,
    /// Number of distinct digit pairs (paper: 40²=1600).
    pub pairs: usize,
    pub lambdas: Vec<F>,
    /// Convergence tolerance for the Sinkhorn side.
    pub tolerance: F,
    pub seed: u64,
}

impl Default for Fig3Config {
    fn default() -> Self {
        Self {
            grid: 12,
            pairs: 36,
            lambdas: vec![1.0, 2.0, 5.0, 9.0, 15.0, 25.0, 50.0],
            tolerance: 1e-6,
            seed: 11,
        }
    }
}

/// Boxplot of relative gaps at one λ.
#[derive(Debug, Clone)]
pub struct Fig3Point {
    pub lambda: F,
    pub gaps: super::BoxStats,
    pub samples: usize,
}

/// Run the study. λ values are interpreted in units of 1/q50(M) — i.e.
/// the engine receives λ/median(M), matching §5.1.2's normalization.
pub fn run(config: &Fig3Config) -> Vec<Fig3Point> {
    let gen = SyntheticDigits::new(crate::data::DigitConfig {
        grid: config.grid,
        ..Default::default()
    });
    let metric = crate::metric::GridMetric::new(config.grid, config.grid).cost_matrix();
    let q50 = metric.median_cost();
    let mut rng = seeded_rng(config.seed);

    // Distinct digit pairs (different random draws; labels may repeat as
    // in the paper's random MNIST pairs).
    let pairs: Vec<(Histogram, Histogram)> = (0..config.pairs)
        .map(|k| {
            let a = gen.sample(DigitClass(k % 10), &mut rng).histogram;
            let b = gen.sample(DigitClass((k / 10 + k) % 10), &mut rng).histogram;
            (a, b)
        })
        .collect();

    // Exact denominators.
    let solver = EmdSolver::new(&metric);
    let exact: Vec<F> = pairs
        .iter()
        .map(|(a, b)| solver.solve(a, b).expect("emd solve").cost)
        .collect();

    let mut out = Vec::new();
    for &lambda in &config.lambdas {
        let engine = SinkhornEngine::with_config(
            &metric,
            SinkhornConfig {
                lambda: lambda / q50,
                tolerance: config.tolerance,
                max_iterations: 500_000,
                ..Default::default()
            },
        );
        let gaps: Vec<F> = pairs
            .iter()
            .zip(&exact)
            .map(|((a, b), &dm)| {
                let dl = engine.distance(a, b).value;
                (dl - dm) / dm
            })
            .collect();
        out.push(Fig3Point {
            lambda,
            gaps: super::BoxStats::from(&gaps),
            samples: gaps.len(),
        });
    }
    out
}

/// Render the boxplot series.
pub fn render(points: &[Fig3Point]) -> String {
    let mut t = super::Table::new(&[
        "lambda", "min", "q1", "median", "q3", "max", "samples",
    ]);
    for p in points {
        t.row(&[
            format!("{:.1}", p.lambda),
            format!("{:.4}", p.gaps.min),
            format!("{:.4}", p.gaps.q1),
            format!("{:.4}", p.gaps.median),
            format!("{:.4}", p.gaps.q3),
            format!("{:.4}", p.gaps.max),
            p.samples.to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gap_is_positive_and_decreasing() {
        let config = Fig3Config {
            grid: 8,
            pairs: 6,
            lambdas: vec![1.0, 5.0, 25.0],
            ..Default::default()
        };
        let pts = run(&config);
        assert_eq!(pts.len(), 3);
        for p in &pts {
            assert!(p.gaps.min > -1e-9, "gap went negative: {:?}", p.gaps);
        }
        // Median gap decreases with lambda (Fig. 3 shape).
        assert!(pts[0].gaps.median > pts[1].gaps.median);
        assert!(pts[1].gaps.median > pts[2].gaps.median);
        let s = render(&pts);
        assert!(s.contains("median"));
    }
}
