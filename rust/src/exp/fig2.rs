//! Figure 2 — classification error of SVMs with e^{−d/t} kernels, for
//! every candidate distance, as a function of training-set size.
//!
//! Protocol (paper §5.1.1, reproduced exactly at reduced default scale):
//!
//! * dataset of N digit histograms on a g×g grid (paper: MNIST 20×20,
//!   N ∈ {3,5,12,17,25}·10³; default here: synthetic digits, smaller N);
//! * 4-fold cross validation with **1 fold train / 3 folds test**,
//!   repeated R times (paper: 6 → 24 experiments; default 2 → 8);
//! * kernel e^{−d/t}, t chosen per training fold by internal CV within
//!   {1, q10, q20, q50} of observed training distances;
//! * SVM regularization C chosen by internal 2-fold/2-repeat CV in
//!   10^{−2:2:4}; indefinite Gram matrices repaired by a diagonal shift;
//! * Sinkhorn λ ∈ {5,7,9,11}/q50(M) selected the same way, 20 fixed
//!   iterations; Independence kernel exponent a ∈ {0.01, 0.1, 1}.

use crate::data::{DigitConfig, SyntheticDigits};
use crate::distances::{
    pairwise, quantile_bandwidths, ClassicalDistance, KernelBuilder,
    MahalanobisDistance,
};
use crate::linalg::Matrix;
use crate::metric::GridMetric;
use crate::simplex::{seeded_rng, Histogram};
use crate::sinkhorn::{independence_distance, SinkhornConfig, SinkhornEngine};
use crate::svm::{error_rate, stratified_folds, MulticlassSvm, SvmConfig};
use crate::F;

/// Which distances to evaluate.
#[derive(Debug, Clone, PartialEq)]
pub enum DistanceKind {
    Classical(ClassicalDistance),
    /// (r−c)ᵀ W (r−c) with W = exp(−M∘M) (a PSD Gaussian kernel on pixel
    /// positions; the paper's non-competitive baseline).
    Mahalanobis,
    /// d_{M^a,0} = rᵀ M^a c with a selected in {0.01, 0.1, 1}.
    Independence,
    /// Exact optimal transportation distance (network simplex).
    Emd,
    /// Dual-Sinkhorn divergence, λ ∈ {5,7,9,11}/q50(M), 20 iterations.
    Sinkhorn,
}

impl DistanceKind {
    pub fn name(&self) -> String {
        match self {
            DistanceKind::Classical(c) => c.name().to_string(),
            DistanceKind::Mahalanobis => "mahalanobis".into(),
            DistanceKind::Independence => "independence".into(),
            DistanceKind::Emd => "emd".into(),
            DistanceKind::Sinkhorn => "sinkhorn".into(),
        }
    }

    /// The full Figure 2 roster.
    pub fn all() -> Vec<DistanceKind> {
        let mut v: Vec<DistanceKind> = ClassicalDistance::ALL
            .iter()
            .map(|&c| DistanceKind::Classical(c))
            .collect();
        v.push(DistanceKind::Mahalanobis);
        v.push(DistanceKind::Independence);
        v.push(DistanceKind::Emd);
        v.push(DistanceKind::Sinkhorn);
        v
    }
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Fig2Config {
    /// Digit grid side (paper: 20 → d=400).
    pub grid: usize,
    /// Dataset sizes to sweep (the figure's x axis).
    pub ns: Vec<usize>,
    pub folds: usize,
    pub repeats: usize,
    pub distances: Vec<DistanceKind>,
    /// Fixed Sinkhorn iteration budget (paper: 20).
    pub sinkhorn_iterations: usize,
    pub seed: u64,
}

impl Default for Fig2Config {
    fn default() -> Self {
        Self {
            grid: 12,
            ns: vec![40, 100, 200],
            folds: 4,
            repeats: 2,
            distances: DistanceKind::all(),
            sinkhorn_iterations: 20,
            seed: 2013,
        }
    }
}

/// One figure point: a distance at a dataset size.
#[derive(Debug, Clone)]
pub struct Fig2Point {
    pub distance: String,
    pub n: usize,
    pub mean_error: F,
    pub std_error: F,
    pub experiments: usize,
}

/// Parameterized variants of one distance: named full pairwise matrices.
struct DistanceFamily {
    #[allow(dead_code)]
    name: String,
    /// (param label, full n×n distance matrix).
    variants: Vec<(String, Matrix)>,
}

/// Run the experiment.
pub fn run(config: &Fig2Config) -> Vec<Fig2Point> {
    let gen = SyntheticDigits::new(DigitConfig { grid: config.grid, ..Default::default() });
    let metric = GridMetric::new(config.grid, config.grid).cost_matrix();
    let q50 = metric.median_cost();
    let mut out = Vec::new();

    for &n in &config.ns {
        // Accumulate errors across folds × repeats per distance.
        let mut errors: Vec<Vec<F>> =
            vec![Vec::new(); config.distances.len()];

        for repeat in 0..config.repeats {
            let mut rng =
                seeded_rng(config.seed ^ (n as u64) << 24 ^ (repeat as u64) << 8);
            let dataset = gen.dataset(n, &mut rng);
            let histograms: Vec<Histogram> =
                dataset.iter().map(|s| s.histogram.clone()).collect();
            let labels: Vec<usize> = dataset.iter().map(|s| s.label).collect();

            // Full pairwise matrices, one per (distance, param).
            let families: Vec<DistanceFamily> = config
                .distances
                .iter()
                .map(|kind| family(kind, &histograms, &metric, q50, config))
                .collect();

            let folds = stratified_folds(&labels, config.folds, &mut rng);
            for f in 0..config.folds {
                // 1 fold train, k-1 folds test.
                let train: Vec<usize> =
                    (0..n).filter(|&i| folds[i] == f).collect();
                let test: Vec<usize> =
                    (0..n).filter(|&i| folds[i] != f).collect();
                for (k, fam) in families.iter().enumerate() {
                    let err = evaluate_family(fam, &labels, &train, &test, &mut rng);
                    errors[k].push(err);
                }
            }
        }

        for (k, kind) in config.distances.iter().enumerate() {
            let (mean, std) = super::mean_std(&errors[k]);
            out.push(Fig2Point {
                distance: kind.name(),
                n,
                mean_error: mean,
                std_error: std,
                experiments: errors[k].len(),
            });
        }
    }
    out
}

/// Build the pairwise matrices for one distance kind.
fn family(
    kind: &DistanceKind,
    hists: &[Histogram],
    metric: &crate::metric::CostMatrix,
    q50: F,
    config: &Fig2Config,
) -> DistanceFamily {
    match kind {
        DistanceKind::Classical(c) => DistanceFamily {
            name: c.name().to_string(),
            variants: vec![(
                "".into(),
                pairwise(|a, b| c.eval(a, b), hists, hists),
            )],
        },
        DistanceKind::Mahalanobis => {
            let d = metric.dim();
            let mut w = Matrix::zeros(d, d);
            for i in 0..d {
                for j in 0..d {
                    let m = metric.get(i, j);
                    w.set(i, j, (-m * m).exp());
                }
            }
            let maha = MahalanobisDistance::new(w);
            DistanceFamily {
                name: "mahalanobis".into(),
                variants: vec![(
                    "".into(),
                    pairwise(|a, b| maha.eval(a, b), hists, hists),
                )],
            }
        }
        DistanceKind::Independence => {
            // a in {0.01, 0.1, 1} over the *squared* grid EDM (Property 2
            // requires a Euclidean distance matrix; (M^2)^a is one for
            // a <= 1).
            let m2 = metric.powf(2.0);
            let variants = [0.01, 0.1, 1.0]
                .iter()
                .map(|&a| {
                    let ma = m2.powf(a);
                    (
                        format!("a={a}"),
                        pairwise(
                            |r, c| independence_distance(&ma, r, c),
                            hists,
                            hists,
                        ),
                    )
                })
                .collect();
            DistanceFamily { name: "independence".into(), variants }
        }
        DistanceKind::Emd => {
            let solver = crate::ot::EmdSolver::new(metric);
            DistanceFamily {
                name: "emd".into(),
                variants: vec![(
                    "".into(),
                    symmetric_pairwise(hists, |a, b| {
                        solver.solve(a, b).expect("emd").cost
                    }),
                )],
            }
        }
        DistanceKind::Sinkhorn => {
            let variants = [5.0, 7.0, 9.0, 11.0]
                .iter()
                .map(|&lam_units| {
                    let lambda = lam_units / q50;
                    let engine = SinkhornEngine::with_config(
                        metric,
                        SinkhornConfig::fixed(lambda, config.sinkhorn_iterations),
                    );
                    (
                        format!("l={lam_units}"),
                        symmetric_pairwise(hists, |a, b| engine.distance(a, b).value),
                    )
                })
                .collect();
            DistanceFamily { name: "sinkhorn".into(), variants }
        }
    }
}

/// Pairwise matrix exploiting d(a,b) = d(b,a) (halves the expensive EMD /
/// Sinkhorn work; also symmetrizes fixed-iteration Sinkhorn outputs).
fn symmetric_pairwise(
    hists: &[Histogram],
    dist: impl Fn(&Histogram, &Histogram) -> F,
) -> Matrix {
    let n = hists.len();
    let mut m = Matrix::zeros(n, n);
    for i in 0..n {
        for j in (i + 1)..n {
            let v = dist(&hists[i], &hists[j]);
            m.set(i, j, v);
            m.set(j, i, v);
        }
    }
    m
}

/// Evaluate one distance family on one outer fold: select (variant, t, C)
/// by internal CV on the training fold, retrain, measure test error.
fn evaluate_family(
    fam: &DistanceFamily,
    labels: &[usize],
    train: &[usize],
    test: &[usize],
    rng: &mut crate::rng::Rng,
) -> F {
    let train_labels: Vec<usize> = train.iter().map(|&i| labels[i]).collect();

    // --- model selection on the training fold ---
    let mut best: Option<(F, usize, F, F)> = None; // (cv_err, variant, t, c)
    for (vi, (_, dmat)) in fam.variants.iter().enumerate() {
        // Bandwidth grid from observed training distances.
        let mut observed = Vec::with_capacity(train.len() * train.len() / 2);
        for (a, &i) in train.iter().enumerate() {
            for &j in &train[a + 1..] {
                observed.push(dmat.get(i, j));
            }
        }
        if observed.is_empty() {
            observed.push(1.0);
        }
        for t in quantile_bandwidths(&observed) {
            for c in SvmConfig::c_grid() {
                let cv = internal_cv_error(dmat, labels, train, t, c, rng);
                if best.map(|(e, _, _, _)| cv < e).unwrap_or(true) {
                    best = Some((cv, vi, t, c));
                }
            }
        }
    }
    let (_, vi, t, c) = best.expect("at least one parameter combo");
    let dmat = &fam.variants[vi].1;

    // --- final train on the full training fold, evaluate on test ---
    let kb = KernelBuilder::new(t);
    let train_gram = kb.square_gram(&submatrix(dmat, train, train));
    let svm = MulticlassSvm::train(
        &train_gram,
        &train_labels,
        SvmConfig { c, ..Default::default() },
    );
    let test_rows = kb.cross_gram(&submatrix(dmat, test, train));
    let preds = svm.predict_batch(&test_rows);
    let truth: Vec<usize> = test.iter().map(|&i| labels[i]).collect();
    error_rate(&preds, &truth)
}

/// Internal 2-fold / 2-repeat CV error of (t, C) on the training fold
/// (the paper's §5.1.1 selection scheme).
fn internal_cv_error(
    dmat: &Matrix,
    labels: &[usize],
    train: &[usize],
    t: F,
    c: F,
    rng: &mut crate::rng::Rng,
) -> F {
    let kb = KernelBuilder::new(t);
    let train_labels: Vec<usize> = train.iter().map(|&i| labels[i]).collect();
    let mut errs = Vec::with_capacity(4);
    for _ in 0..2 {
        let folds = stratified_folds(&train_labels, 2, rng);
        for f in 0..2 {
            let sub_tr: Vec<usize> = (0..train.len())
                .filter(|&k| folds[k] == f)
                .map(|k| train[k])
                .collect();
            let sub_te: Vec<usize> = (0..train.len())
                .filter(|&k| folds[k] != f)
                .map(|k| train[k])
                .collect();
            if sub_tr.is_empty() || sub_te.is_empty() {
                continue;
            }
            let sub_tr_labels: Vec<usize> =
                sub_tr.iter().map(|&i| labels[i]).collect();
            // Internal folds can miss classes entirely at tiny scales.
            let mut classes = sub_tr_labels.clone();
            classes.sort_unstable();
            classes.dedup();
            if classes.len() < 2 {
                continue;
            }
            let gram = kb.square_gram(&submatrix(dmat, &sub_tr, &sub_tr));
            let svm = MulticlassSvm::train(
                &gram,
                &sub_tr_labels,
                SvmConfig { c, ..Default::default() },
            );
            let rows = kb.cross_gram(&submatrix(dmat, &sub_te, &sub_tr));
            let preds = svm.predict_batch(&rows);
            let truth: Vec<usize> = sub_te.iter().map(|&i| labels[i]).collect();
            errs.push(error_rate(&preds, &truth));
        }
    }
    if errs.is_empty() {
        1.0
    } else {
        errs.iter().sum::<F>() / errs.len() as F
    }
}

/// Extract the (rows × cols) submatrix of a full pairwise matrix.
fn submatrix(dmat: &Matrix, rows: &[usize], cols: &[usize]) -> Matrix {
    let mut out = Matrix::zeros(rows.len(), cols.len());
    for (a, &i) in rows.iter().enumerate() {
        for (b, &j) in cols.iter().enumerate() {
            out.set(a, b, dmat.get(i, j));
        }
    }
    out
}

/// Render the figure as a table (rows grouped by N).
pub fn render(points: &[Fig2Point]) -> String {
    let mut t = super::Table::new(&["n", "distance", "test_error", "std", "runs"]);
    for p in points {
        t.row(&[
            p.n.to_string(),
            p.distance.clone(),
            format!("{:.4}", p.mean_error),
            format!("{:.4}", p.std_error),
            p.experiments.to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_protocol_runs_end_to_end() {
        let config = Fig2Config {
            grid: 8,
            ns: vec![40],
            folds: 4,
            repeats: 1,
            distances: vec![
                DistanceKind::Classical(ClassicalDistance::TotalVariation),
                DistanceKind::Sinkhorn,
            ],
            sinkhorn_iterations: 10,
            seed: 1,
        };
        let pts = run(&config);
        assert_eq!(pts.len(), 2);
        for p in &pts {
            assert_eq!(p.experiments, 4);
            assert!(p.mean_error >= 0.0 && p.mean_error <= 1.0);
            // 10 classes, 10 train samples: anything clearly below the
            // 90% chance line means the pipeline learns.
            assert!(p.mean_error < 0.85, "{}: {}", p.distance, p.mean_error);
        }
        let s = render(&pts);
        assert!(s.contains("sinkhorn"));
    }

    #[test]
    fn submatrix_extracts() {
        let m = Matrix::from_vec(3, 3, vec![0., 1., 2., 3., 4., 5., 6., 7., 8.]);
        let s = submatrix(&m, &[2, 0], &[1]);
        assert_eq!(s.data(), &[7., 1.]);
    }
}
