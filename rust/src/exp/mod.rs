//! Experiment harnesses — one module per paper figure.
//!
//! Each harness is a library function returning structured rows, shared by
//! three consumers: the `repro` CLI (prints the paper's series), the
//! criterion-style benches under `rust/benches/`, and the integration
//! smoke tests. Scale parameters default to values sized for this
//! single-core testbed; every harness accepts paper-scale overrides
//! (see README.md §Experiments for the documented substitutions).

pub mod ablation;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;

use crate::F;

/// Fixed-width table printer used by all harnesses (stable, greppable).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (cell, w) in cells.iter().zip(widths) {
                line.push_str(&format!("{cell:>w$}  ", w = w));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Mean and (population) standard deviation.
pub fn mean_std(xs: &[F]) -> (F, F) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<F>() / xs.len() as F;
    let var =
        xs.iter().map(|x| (x - mean) * (x - mean)).sum::<F>() / xs.len() as F;
    (mean, var.sqrt())
}

/// Five-number boxplot summary (min, q1, median, q3, max).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BoxStats {
    pub min: F,
    pub q1: F,
    pub median: F,
    pub q3: F,
    pub max: F,
}

impl BoxStats {
    pub fn from(xs: &[F]) -> Self {
        assert!(!xs.is_empty(), "boxplot of empty sample");
        let q = |s: F| crate::linalg::quantile(xs, s);
        Self { min: q(0.0), q1: q(0.25), median: q(0.5), q3: q(0.75), max: q(1.0) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1.5".into()]);
        t.row(&["longer".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("name"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    fn stats_helpers() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - (2.0 / 3.0 as F).sqrt()).abs() < 1e-12);
        let b = BoxStats::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(b.median, 3.0);
        assert_eq!(b.min, 1.0);
        assert_eq!(b.max, 5.0);
    }
}
