//! Self-contained deterministic random number generation.
//!
//! The build is fully offline with a deliberately tiny dependency set, so
//! instead of the `rand` crate this module provides a small, well-tested
//! xoshiro256++ generator (Blackman & Vigna, 2019) with the handful of
//! distributions the experiment harnesses need: uniform f64, integer
//! ranges, Gaussians (Box–Muller) and Gamma variates (Marsaglia–Tsang).
//!
//! Every stochastic component in the crate threads one of these through
//! explicitly — experiments are reproducible from a single seed.

use crate::F;

/// xoshiro256++ PRNG. 256-bit state, period 2^256 − 1, passes BigCrush.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    spare_normal: Option<F>,
}

impl Rng {
    /// Seed via SplitMix64 (the recommended seeding procedure, avoids
    /// low-entropy states for small seeds).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()], spare_normal: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> F {
        (self.next_u64() >> 11) as F * (1.0 / (1u64 << 53) as F)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: F, hi: F) -> F {
        debug_assert!(lo < hi);
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (Lemire's nearly-divisionless method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply-shift; the tiny modulo bias (< 2^-64) is
        // irrelevant for experiment sampling.
        let x = self.next_u64() as u128;
        ((x * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: F) -> bool {
        self.f64() < p
    }

    /// Standard normal (Box–Muller, caching the paired variate).
    pub fn normal(&mut self) -> F {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        let radius = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(radius * theta.sin());
        radius * theta.cos()
    }

    /// Gamma(shape, 1) via Marsaglia & Tsang (2000), boosted for shape<1.
    pub fn gamma(&mut self, shape: F) -> F {
        if shape < 1.0 {
            let u = self.f64().max(1e-300);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Derive an independent child generator (for parallel workloads).
    pub fn fork(&mut self) -> Rng {
        Rng::seed_from_u64(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval_and_uniform() {
        let mut rng = Rng::seed_from_u64(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as F;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Rng::seed_from_u64(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.below(5)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts {counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed_from_u64(11);
        let n = 200_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as F;
        m2 /= n as F;
        assert!(m1.abs() < 0.01, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.02, "var {m2}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut rng = Rng::seed_from_u64(13);
        for &shape in &[0.5, 1.0, 4.0] {
            let n = 30_000;
            let mean: F = (0..n).map(|_| rng.gamma(shape)).sum::<F>() / n as F;
            assert!(
                (mean - shape).abs() < 0.05 * shape + 0.03,
                "Gamma({shape}) mean {mean}"
            );
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Rng::seed_from_u64(5);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_decorrelates() {
        let mut parent = Rng::seed_from_u64(1);
        let mut a = parent.fork();
        let mut b = parent.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
