//! Partitioned corpus shards and their associative top-k merge.
//!
//! A 10M-entry corpus does not fit one index, one refine executor or one
//! machine — Peyré & Cuturi frame large-scale OT retrieval as a
//! partition-and-merge problem, and this module is that partition:
//!
//! * [`CorpusShard`] — one contiguous slice of the corpus, owning its
//!   own per-entry statistics (anchor CDF tables, centroid coordinates),
//!   its own per-entry warm-start cache and its own
//!   [`crate::backend::ShardedExecutor`] refine pool. Per-entry
//!   statistics are functions of the metric and that entry alone, so a
//!   shard is fully self-contained: inserts touch exactly one shard and
//!   compactions rebuild one shard without a global pause.
//! * [`ShardedCorpus`] — the partition-and-merge layer: it fans a query
//!   out (the cascade walk *and* the refine panels run per shard, at
//!   most [`ShardingConfig::threads`] shards concurrently), then merges
//!   the per-shard top-k max-heaps by `(distance, entry id)`. The merge
//!   is **associative and commutative**: each shard's pruned top-k
//!   equals its own brute-force top-k (the per-shard τ is at least the
//!   global τ, so per-shard pruning is strictly conservative), and
//!   sorted-merge-truncate of per-shard heaps is order-independent —
//!   which is exactly the property a future cross-machine placement
//!   needs, since remote shards will answer in arbitrary order.
//!
//! Entry ids are corpus-global and stable: shard s of an n-entry corpus
//! starts with the id slice `ranges[s]`, inserts draw fresh monotone ids
//! from the corpus counter, and tombstone/compact never renumber ids
//! (only internal slots). Shard-count invariance — the merged pruned
//! top-k over 1, 2, 3 or 7 shards is equivalent (tie-aware) to the
//! monolithic brute force, before and after mutation cycles — is locked
//! down by `rust/tests/retrieval_sharded.rs`.

use super::search::probe_outcome;
use super::{
    CorpusIndex, Hit, RetrievalConfig, RetrievalError, RetrievalReport,
    RetrievalService, RoutingConfig,
};
use crate::backend::shard_ranges;
use crate::metric::CostMatrix;
use crate::simplex::Histogram;
use crate::trace::{ctx, Span, SpanData, Stage};
use crate::F;
use std::collections::{HashMap, HashSet};
use std::time::Instant;

/// How a corpus is partitioned and how much parallelism one search may
/// use.
#[derive(Debug, Clone, Copy)]
pub struct ShardingConfig {
    /// Corpus shards (clamped to `[1, entries]` at build).
    pub shards: usize,
    /// Shards walked concurrently per query (0 = available
    /// parallelism; clamped to the shard count and to the refine worker
    /// budget). Each concurrent shard drives its own refine executor,
    /// so the per-shard refine worker count is the configured worker
    /// budget divided by this — the product never exceeds the budget.
    pub threads: usize,
    /// Tombstone fraction at which a shard compacts itself
    /// automatically after a tombstone lands.
    pub compact_threshold: f64,
    /// Opt-in per-shard ANN routing (see [`super::RoutingConfig`]):
    /// each shard clusters its cached embedded-barycenter coordinates
    /// and prices only the router's shortlist, with the exact cascade +
    /// refine demoted to re-ranking. `None` (the default) keeps the
    /// exact every-live-entry walk bit-for-bit.
    pub routing: Option<RoutingConfig>,
}

impl Default for ShardingConfig {
    fn default() -> Self {
        Self { shards: 1, threads: 0, compact_threshold: 0.25, routing: None }
    }
}

/// Point-in-time observability for one shard (surfaced through the
/// coordinator's `StatsSnapshot`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShardGauges {
    /// Shard index within its corpus.
    pub shard: usize,
    /// Index slots, including tombstoned ones awaiting compaction.
    pub entries: usize,
    /// Live (searchable) entries.
    pub live: usize,
    /// Fraction of slots tombstoned.
    pub tombstone_fraction: f64,
    /// Compaction rebuilds performed (threshold-triggered + explicit).
    pub compactions: u64,
    /// Entries inserted after the initial build.
    pub inserts: u64,
    /// Searches this shard served.
    pub searches: u64,
    /// Walltime of this shard's most recent search, µs.
    pub last_search_us: u64,
}

/// One self-contained corpus partition: index + bounds + warm cache +
/// refine executor, with shard-local mutation counters.
pub struct CorpusShard {
    id: usize,
    service: RetrievalService,
    compactions: u64,
    inserts: u64,
    searches: u64,
    last_search_us: u64,
}

impl CorpusShard {
    fn new(
        id: usize,
        index: CorpusIndex,
        config: RetrievalConfig,
        base: usize,
        routing: Option<RoutingConfig>,
    ) -> Self {
        let mut service = RetrievalService::with_base(index, config, base);
        if let Some(r) = routing {
            // A non-factoring metric leaves the router unbuilt and this
            // shard on the exact path — routing is an accelerator, never
            // a prerequisite.
            service.enable_routing(r);
        }
        Self {
            id,
            service,
            compactions: 0,
            inserts: 0,
            searches: 0,
            last_search_us: 0,
        }
    }

    /// Shard index within its corpus.
    pub fn id(&self) -> usize {
        self.id
    }

    /// Live (searchable) entries.
    pub fn live(&self) -> usize {
        self.service.live()
    }

    /// Index slots, including tombstoned ones.
    pub fn len(&self) -> usize {
        self.service.len()
    }

    pub fn is_empty(&self) -> bool {
        self.service.is_empty()
    }

    /// Fraction of slots currently tombstoned.
    pub fn tombstone_fraction(&self) -> f64 {
        self.service.tombstone_fraction()
    }

    /// Whether this shard holds entry id `entry` live.
    pub fn contains(&self, entry: usize) -> bool {
        self.service.contains(entry)
    }

    /// Shard-local gauges.
    pub fn gauges(&self) -> ShardGauges {
        ShardGauges {
            shard: self.id,
            entries: self.len(),
            live: self.live(),
            tombstone_fraction: self.tombstone_fraction(),
            compactions: self.compactions,
            inserts: self.inserts,
            searches: self.searches,
            last_search_us: self.last_search_us,
        }
    }

    fn search(
        &mut self,
        query: &Histogram,
        k: usize,
    ) -> Result<(Vec<Hit>, RetrievalReport), RetrievalError> {
        let trace = ctx::active();
        let start_us = trace.as_ref().map(|t| t.sink.now_us());
        let t0 = Instant::now();
        let out = self.service.top_k(query, k);
        self.searches += 1;
        self.last_search_us = crate::util::saturating_micros(t0.elapsed());
        if let (Some(t), Some(start_us), Ok((_, report))) = (&trace, start_us, &out) {
            t.sink.record(Span {
                trace: t.trace,
                stage: Stage::Shard,
                tenant: t.tenant,
                start_us,
                end_us: t.sink.now_us(),
                tid: 0,
                data: SpanData::Shard {
                    shard: self.id,
                    solved: report.solved,
                    pruned: report.pruned,
                },
            });
        }
        out
    }

    fn brute(
        &mut self,
        query: &Histogram,
        k: usize,
    ) -> Result<Vec<Hit>, RetrievalError> {
        self.service.brute_force(query, k)
    }

    fn insert(&mut self, h: Histogram, entry: usize) -> Result<(), RetrievalError> {
        self.service.insert(h, entry)?;
        self.inserts += 1;
        Ok(())
    }

    fn tombstone(&mut self, entry: usize) -> bool {
        self.service.tombstone(entry)
    }

    fn compact(&mut self) -> bool {
        let did = self.service.compact();
        if did {
            self.compactions += 1;
        }
        did
    }
}

/// The partition-and-merge layer: a corpus split into [`CorpusShard`]s
/// with a single global entry-id space, merged top-k search, merged
/// recall probes and an incremental mutation API.
pub struct ShardedCorpus {
    shards: Vec<CorpusShard>,
    /// Contiguous id ranges of the initial build (shard s owns
    /// `build_ranges[s]`): ownership of a build-time id is recovered by
    /// binary search instead of a per-entry map — at the 10M-entry
    /// target a materialized id→shard table would cost hundreds of MB
    /// for information the partition already encodes.
    build_ranges: Vec<std::ops::Range<usize>>,
    /// Ids at or past this are post-build inserts.
    initial_total: usize,
    /// Post-build inserts: fresh id → owning shard (only these need
    /// dynamic tracking).
    inserted: HashMap<usize, usize>,
    /// Tombstoned build-time ids (tombstoned inserts just leave
    /// `inserted`).
    dead: HashSet<usize>,
    /// Next fresh entry id (monotone; ids are never reused).
    next_entry: usize,
    /// Shards walked concurrently per query (resolved, ≥ 1).
    threads: usize,
    compact_threshold: f64,
    /// Merged-view recall probing: every N-th search re-runs brute
    /// force across all shards and compares (0 = never).
    probe_every: u64,
    /// Effective (floored) pruning slack, shared with the probes.
    bound_slack: F,
    queries: u64,
    dim: usize,
}

impl ShardedCorpus {
    /// Partition `entries` into contiguous shards and build each one.
    /// Shard s of the initial corpus owns the entry ids of its range;
    /// later inserts draw fresh ids from the corpus-wide counter.
    ///
    /// The per-shard refine worker budget is `config.workers` (0 =
    /// available parallelism) divided by the number of concurrently
    /// searched shards, so a sharded search does not oversubscribe the
    /// machine relative to the monolithic one.
    pub fn new(
        metric: &CostMatrix,
        entries: Vec<Histogram>,
        anchors: usize,
        config: RetrievalConfig,
        sharding: ShardingConfig,
    ) -> Result<Self, RetrievalError> {
        if entries.is_empty() {
            return Err(RetrievalError::EmptyCorpus);
        }
        let n = entries.len();
        let shards = sharding.shards.clamp(1, n);
        let available = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1);
        let workers =
            if config.workers == 0 { available } else { config.workers }.max(1);
        // Concurrency never exceeds the refine worker budget: with
        // threads > workers the division below would floor every shard
        // at one worker and run `threads` of them — more solver threads
        // than the budget, violating the no-oversubscription sizing.
        let threads = if sharding.threads == 0 {
            available
        } else {
            sharding.threads
        }
        .clamp(1, shards)
        .min(workers);
        let mut shard_config = config;
        shard_config.workers = (workers / threads).max(1);
        // Probes are orchestrated here against the *merged* view; a
        // per-shard probe would brute-force one partition and audit
        // nothing about the merge.
        shard_config.probe_every = 0;

        let ranges = shard_ranges(n, shards);
        let mut built = Vec::with_capacity(shards);
        let mut iter = entries.into_iter();
        for (sid, range) in ranges.iter().enumerate() {
            let chunk: Vec<Histogram> = iter.by_ref().take(range.len()).collect();
            let index = CorpusIndex::from_histograms(metric, chunk, anchors)
                .map_err(|e| offset_entry_error(e, range.start))?;
            built.push(CorpusShard::new(
                sid,
                index,
                shard_config,
                range.start,
                sharding.routing,
            ));
        }
        let bound_slack = built[0].service.config().bound_slack;
        Ok(Self {
            shards: built,
            build_ranges: ranges,
            initial_total: n,
            inserted: HashMap::new(),
            dead: HashSet::new(),
            next_entry: n,
            threads,
            compact_threshold: sharding.compact_threshold,
            probe_every: config.probe_every,
            bound_slack,
            queries: 0,
            dim: metric.dim(),
        })
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Index slots across all shards (including tombstoned ones).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.is_empty())
    }

    /// Live (searchable) entries across all shards.
    pub fn live(&self) -> usize {
        self.shards.iter().map(|s| s.live()).sum()
    }

    /// The shard owning live entry id `entry` (None when unknown or
    /// tombstoned): post-build inserts resolve through the dynamic map,
    /// build-time ids by binary search over the contiguous ranges.
    fn owner_of(&self, entry: usize) -> Option<usize> {
        if entry >= self.initial_total {
            return self.inserted.get(&entry).copied();
        }
        if self.dead.contains(&entry) {
            return None;
        }
        let sid = self.build_ranges.partition_point(|r| r.end <= entry);
        (sid < self.build_ranges.len() && self.build_ranges[sid].contains(&entry))
            .then_some(sid)
    }

    /// Whether entry id `entry` is indexed and live.
    pub fn contains(&self, entry: usize) -> bool {
        self.owner_of(entry).is_some()
    }

    /// Per-shard gauges, in shard order.
    pub fn gauges(&self) -> Vec<ShardGauges> {
        self.shards.iter().map(|s| s.gauges()).collect()
    }

    /// Merged pruned top-k: every shard runs its own cascade walk +
    /// refine (at most [`ShardingConfig::threads`] concurrently), and
    /// the per-shard heaps merge by `(distance, entry id)`. Equivalent
    /// to the monolithic search modulo ties; hits come back in
    /// ascending canonical order.
    pub fn search(
        &mut self,
        query: &Histogram,
        k: usize,
    ) -> Result<(Vec<Hit>, RetrievalReport), RetrievalError> {
        if query.dim() != self.dim {
            return Err(RetrievalError::QueryDimensionMismatch {
                got: query.dim(),
                want: self.dim,
            });
        }
        self.queries += 1;
        let per_shard = self.run(&|shard| shard.search(query, k))?;
        let (hits, mut report) = merge_results(per_shard, k);
        if self.probe_every > 0 && self.queries % self.probe_every == 0 {
            let brute = self.brute_force_merged(query, k)?;
            report.probe = Some(probe_outcome(&hits, &brute, self.bound_slack));
        }
        Ok((hits, report))
    }

    /// Merged brute force: every shard solves every live entry, heaps
    /// merged — the multi-shard oracle the pruned search (and every
    /// recall probe) is held to.
    pub fn brute_force(
        &mut self,
        query: &Histogram,
        k: usize,
    ) -> Result<Vec<Hit>, RetrievalError> {
        if query.dim() != self.dim {
            return Err(RetrievalError::QueryDimensionMismatch {
                got: query.dim(),
                want: self.dim,
            });
        }
        self.brute_force_merged(query, k)
    }

    fn brute_force_merged(
        &mut self,
        query: &Histogram,
        k: usize,
    ) -> Result<Vec<Hit>, RetrievalError> {
        let per_shard = self.run(&|shard| shard.brute(query, k))?;
        let mut hits: Vec<Hit> = per_shard.into_iter().flatten().collect();
        sort_canonical(&mut hits);
        let live = self.live();
        hits.truncate(k.min(live));
        Ok(hits)
    }

    /// Append one histogram; returns its fresh corpus-global entry id.
    /// Routed to the shard with the fewest *occupied slots* — live plus
    /// tombstoned, ties to the lowest shard index. Counting tombstoned
    /// slots matters: a heavily tombstoned shard still pays for those
    /// slots at its next compaction, and routing by live count alone
    /// would funnel every insert into exactly the shard about to
    /// rebuild (and leave the partition skewed once it does).
    pub fn insert(&mut self, h: Histogram) -> Result<usize, RetrievalError> {
        let sid = self
            .shards
            .iter()
            .enumerate()
            .min_by_key(|(i, s)| (s.len(), *i))
            .map(|(i, _)| i)
            .expect("a sharded corpus always has at least one shard");
        let entry = self.next_entry;
        // Rejections speak the would-be global id, like every other
        // error from this API (the shard reports its local slot, which
        // aliases an unrelated live entry's id).
        self.shards[sid].insert(h, entry).map_err(|e| match e {
            RetrievalError::DimensionMismatch { got, want, .. } => {
                RetrievalError::DimensionMismatch { entry, got, want }
            }
            other => other,
        })?;
        self.next_entry += 1;
        self.inserted.insert(entry, sid);
        Ok(entry)
    }

    /// Tombstone entry id `entry`. Returns whether a live entry was
    /// hit. When the owning shard's tombstone fraction reaches
    /// [`ShardingConfig::compact_threshold`] it compacts itself — one
    /// shard rebuilds, the others keep serving untouched.
    pub fn tombstone(&mut self, entry: usize) -> bool {
        let Some(sid) = self.owner_of(entry) else {
            return false;
        };
        let hit = self.shards[sid].tombstone(entry);
        if hit {
            if entry >= self.initial_total {
                self.inserted.remove(&entry);
            } else {
                self.dead.insert(entry);
            }
            if self.shards[sid].tombstone_fraction() >= self.compact_threshold {
                self.shards[sid].compact();
            }
        }
        hit
    }

    /// Explicitly compact every shard holding tombstones; returns how
    /// many shards rebuilt.
    pub fn compact(&mut self) -> usize {
        self.shards.iter_mut().map(|s| usize::from(s.compact())).sum()
    }

    /// Run `f` over every shard, at most `self.threads` concurrently,
    /// returning the outcomes in shard order. Execution order is
    /// irrelevant by design: the callers merge associatively.
    fn run<T, F2>(&mut self, f: &F2) -> Result<Vec<T>, RetrievalError>
    where
        T: Send,
        F2: Fn(&mut CorpusShard) -> Result<T, RetrievalError> + Sync,
    {
        let conc = self.threads.min(self.shards.len()).max(1);
        if conc <= 1 || self.shards.len() <= 1 {
            return self
                .shards
                .iter_mut()
                .enumerate()
                .map(|(sid, shard)| contained(sid, shard, f))
                .collect();
        }
        // Exactly `conc` contiguous near-equal shard groups (the same
        // `shard_ranges` split the partition itself uses — a ceil-sized
        // chunking could produce fewer groups than `conc` and leave
        // part of the divided refine worker budget idle), one scoped
        // worker each: spawn cost is orders of magnitude below a shard
        // walk at serving sizes.
        let ranges = shard_ranges(self.shards.len(), conc);
        // A traced walk must survive the scoped-spawn hop: thread-locals
        // don't cross threads, so each worker re-installs the context.
        let active = ctx::active();
        let groups: Vec<Result<Vec<T>, RetrievalError>> =
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(conc);
                let mut rest: &mut [CorpusShard] = &mut self.shards;
                for range in &ranges {
                    let (group, tail) = rest.split_at_mut(range.len());
                    rest = tail;
                    let start = range.start;
                    let active = active.clone();
                    handles.push(scope.spawn(move || {
                        let _guard = active.map(ctx::set_active);
                        group
                            .iter_mut()
                            .enumerate()
                            .map(|(off, shard)| contained(start + off, shard, f))
                            .collect::<Result<Vec<T>, _>>()
                    }));
                }
                // Every per-shard panic is already caught inside the
                // worker; a join error means the worker glue itself
                // died, so it degrades to the same per-request error
                // (attributed to the group's first shard) instead of
                // unwinding into — and poisoning — the dispatcher
                // thread executing this corpus's mailbox.
                handles
                    .into_iter()
                    .zip(&ranges)
                    .map(|(h, range)| {
                        h.join().unwrap_or(Err(RetrievalError::ShardPanicked {
                            shard: range.start,
                        }))
                    })
                    .collect()
            });
        let mut out = Vec::with_capacity(self.shards.len());
        for group in groups {
            out.extend(group?);
        }
        Ok(out)
    }

    /// Arm the one-shot panic hook on shard `shard`: its next search
    /// panics mid-flight. Test-only plumbing for the panic-containment
    /// contract.
    #[cfg(any(test, debug_assertions))]
    #[doc(hidden)]
    pub fn poison_shard(&mut self, shard: usize) {
        self.shards[shard].service.poison_next_search();
    }
}

/// Run `f` on one shard with the panic boundary every shard op crosses:
/// a panicking cascade/refine is caught here and converted into a
/// per-request [`RetrievalError::ShardPanicked`], so one poisoned query
/// fails alone instead of unwinding into whatever thread drives the
/// corpus — in production that is one of the `sinkhorn-retrieval-{i}`
/// dispatcher threads executing this corpus's mailbox (PR 8), which
/// must keep serving every other tenant.
fn contained<T, F2>(
    sid: usize,
    shard: &mut CorpusShard,
    f: &F2,
) -> Result<T, RetrievalError>
where
    F2: Fn(&mut CorpusShard) -> Result<T, RetrievalError>,
{
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(shard)))
        .unwrap_or(Err(RetrievalError::ShardPanicked { shard: sid }))
}

/// Ascending `(distance, entry)` — the canonical result order shared
/// with the per-shard heaps, so merge output is deterministic.
fn sort_canonical(hits: &mut [Hit]) {
    hits.sort_by(|a, b| {
        a.distance.total_cmp(&b.distance).then(a.entry.cmp(&b.entry))
    });
}

/// Merge per-shard `(hits, report)` pairs: concatenate + canonical sort
/// + truncate for the hits (associative, order-independent), field-wise
/// sums for the report. The merged threshold is the k-th best merged
/// distance — the value a global single-heap walk would have ended at.
fn merge_results(
    per_shard: Vec<(Vec<Hit>, RetrievalReport)>,
    k: usize,
) -> (Vec<Hit>, RetrievalReport) {
    let mut hits: Vec<Hit> = Vec::new();
    let mut corpus = 0;
    let mut merged = RetrievalReport::empty(0, 0);
    for (shard_hits, r) in per_shard {
        hits.extend(shard_hits);
        corpus += r.corpus;
        merged.solved += r.solved;
        merged.pruned += r.pruned;
        merged.panels += r.panels;
        merged.rescued += r.rescued;
        merged.failed += r.failed;
        merged.warm_seeded += r.warm_seeded;
        merged.iterations += r.iterations;
        merged.pruned_mass += r.pruned_mass;
        merged.pruned_centroid += r.pruned_centroid;
        merged.pruned_projection += r.pruned_projection;
        merged.pruned_interval += r.pruned_interval;
        merged.refined += r.refined;
        merged.routed |= r.routed;
        merged.shortlist += r.shortlist;
    }
    sort_canonical(&mut hits);
    let k = k.min(corpus);
    hits.truncate(k);
    merged.corpus = corpus;
    merged.k = k;
    merged.threshold =
        hits.last().map(|h| h.distance).unwrap_or(F::INFINITY);
    (hits, merged)
}

/// Shift the entry index of a build error from shard-local to
/// corpus-global coordinates.
fn offset_entry_error(e: RetrievalError, base: usize) -> RetrievalError {
    match e {
        RetrievalError::DimensionMismatch { entry, got, want } => {
            RetrievalError::DimensionMismatch { entry: entry + base, got, want }
        }
        RetrievalError::BadEntry { entry, source } => {
            RetrievalError::BadEntry { entry: entry + base, source }
        }
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::RandomMetric;
    use crate::simplex::seeded_rng;

    fn config(lambda: F) -> RetrievalConfig {
        let mut config = RetrievalConfig::serving(lambda);
        config.workers = 2;
        config
    }

    fn corpus(d: usize, n: usize, seed: u64) -> (CostMatrix, Vec<Histogram>) {
        let mut rng = seeded_rng(seed);
        let m = RandomMetric::new(d).sample(&mut rng);
        let entries =
            (0..n).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        (m, entries)
    }

    fn sharded(
        d: usize,
        n: usize,
        seed: u64,
        shards: usize,
    ) -> (ShardedCorpus, CostMatrix, Vec<Histogram>) {
        let (m, entries) = corpus(d, n, seed);
        let sharding = ShardingConfig { shards, threads: 2, ..Default::default() };
        let sc = ShardedCorpus::new(&m, entries.clone(), 4, config(9.0), sharding)
            .unwrap();
        (sc, m, entries)
    }

    #[test]
    fn partitions_contiguously_and_merges_like_the_monolith() {
        let (mut sc, m, entries) = sharded(10, 23, 0, 3);
        assert_eq!(sc.shard_count(), 3);
        assert_eq!(sc.len(), 23);
        assert_eq!(sc.live(), 23);
        // 23 over 3 shards: 8 + 8 + 7, contiguous id ranges.
        let sizes: Vec<usize> = sc.gauges().iter().map(|g| g.live).collect();
        assert_eq!(sizes, vec![8, 8, 7]);
        assert!(sc.contains(0) && sc.contains(22) && !sc.contains(23));

        let mut rng = seeded_rng(100);
        let q = Histogram::sample_uniform(10, &mut rng);
        let index = CorpusIndex::from_histograms(&m, entries, 4).unwrap();
        let mut mono = RetrievalService::new(index, config(9.0));
        let brute = mono.brute_force(&q, 6).unwrap();
        let (hits, report) = sc.search(&q, 6).unwrap();
        assert_eq!(report.solved + report.pruned, 23);
        if let Err(v) = super::super::topk_equivalent(&hits, &brute, 1e-7) {
            panic!("sharded merge diverged from the monolith: {v}");
        }
        let sharded_brute = sc.brute_force(&q, 6).unwrap();
        if let Err(v) = super::super::topk_equivalent(&sharded_brute, &brute, 1e-7) {
            panic!("merged brute force diverged from the monolith: {v}");
        }
        // Gauges recorded the pruned walk (brute-force oracle passes are
        // not counted as searches).
        let gauges = sc.gauges();
        // (`last_search_us` is deliberately not asserted positive — a
        // sub-microsecond shard walk on a coarse clock is legal.)
        assert!(gauges.iter().all(|g| g.searches == 1), "{gauges:?}");
    }

    #[test]
    fn shard_count_clamps_and_degenerates() {
        let (m, entries) = corpus(8, 4, 1);
        let sharding = ShardingConfig { shards: 9, threads: 3, ..Default::default() };
        let mut sc =
            ShardedCorpus::new(&m, entries, 2, config(9.0), sharding).unwrap();
        assert_eq!(sc.shard_count(), 4, "shards clamp to the corpus size");
        let mut rng = seeded_rng(101);
        let q = Histogram::sample_uniform(8, &mut rng);
        let (hits, _) = sc.search(&q, 10).unwrap();
        assert_eq!(hits.len(), 4);
        // Dimension mismatches error at the merged entry points.
        let bad = Histogram::uniform(5);
        assert!(matches!(
            sc.search(&bad, 1),
            Err(RetrievalError::QueryDimensionMismatch { got: 5, want: 8 })
        ));
        assert!(sc.brute_force(&bad, 1).is_err());
        // Empty corpora are rejected, mismatched entries are reported in
        // global coordinates.
        assert!(matches!(
            ShardedCorpus::new(&m, Vec::new(), 2, config(9.0), ShardingConfig::default()),
            Err(RetrievalError::EmptyCorpus)
        ));
        let (m2, mut entries2) = corpus(8, 6, 2);
        entries2[4] = Histogram::uniform(3);
        let err = ShardedCorpus::new(
            &m2,
            entries2,
            2,
            config(9.0),
            ShardingConfig { shards: 3, ..Default::default() },
        )
        .unwrap_err();
        assert!(matches!(
            err,
            RetrievalError::DimensionMismatch { entry: 4, got: 3, want: 8 }
        ));
    }

    #[test]
    fn inserts_route_to_the_emptiest_shard_with_fresh_ids() {
        let (mut sc, _m, _entries) = sharded(8, 7, 3, 3);
        // Partition is 3 + 2 + 2: the first insert goes to shard 1 (the
        // lowest-index emptiest), the next to shard 2, and the third to
        // shard 0 (a three-way tie breaks to the lowest index).
        let mut rng = seeded_rng(102);
        let a = sc.insert(Histogram::sample_uniform(8, &mut rng)).unwrap();
        let b = sc.insert(Histogram::sample_uniform(8, &mut rng)).unwrap();
        let c = sc.insert(Histogram::sample_uniform(8, &mut rng)).unwrap();
        assert_eq!((a, b, c), (7, 8, 9), "ids are monotone corpus-global");
        let gauges = sc.gauges();
        assert_eq!(
            gauges.iter().map(|g| g.live).collect::<Vec<_>>(),
            vec![4, 3, 3],
            "least-loaded routing balances the partition: {gauges:?}"
        );
        assert_eq!(gauges.iter().map(|g| g.inserts).sum::<u64>(), 3);
        assert_eq!(sc.live(), 10);
        assert!(sc.contains(a) && sc.contains(b) && sc.contains(c));
    }

    #[test]
    fn tombstones_trigger_threshold_compaction_per_shard() {
        let (mut sc, _m, _entries) = sharded(8, 12, 4, 3);
        // Shard 0 owns ids 0..4. Tombstone one: 25% reaches the default
        // threshold, so the shard compacts itself; the others are
        // untouched.
        assert!(sc.tombstone(0));
        assert!(!sc.tombstone(0), "tombstoned ids stay dead");
        assert!(!sc.tombstone(99), "unknown ids are a no-op");
        let gauges = sc.gauges();
        assert_eq!(gauges[0].compactions, 1, "threshold compaction fired");
        assert_eq!(gauges[0].entries, 3, "slot reclaimed");
        assert_eq!(gauges[0].tombstone_fraction, 0.0);
        assert_eq!(gauges[1].compactions + gauges[2].compactions, 0);
        assert_eq!(sc.live(), 11);
        // A below-threshold tombstone waits for the explicit sweep.
        let mut lazy = ShardingConfig { shards: 2, ..Default::default() };
        lazy.compact_threshold = 0.9;
        let (m, entries) = corpus(8, 12, 5);
        let mut sc2 =
            ShardedCorpus::new(&m, entries, 2, config(9.0), lazy).unwrap();
        assert!(sc2.tombstone(1));
        assert_eq!(sc2.gauges()[0].compactions, 0);
        assert_eq!(sc2.compact(), 1, "exactly the dirty shard rebuilds");
        assert_eq!(sc2.compact(), 0);
        assert_eq!(sc2.gauges()[0].compactions, 1);
    }

    #[test]
    fn merged_probe_audits_the_multi_shard_view() {
        let (m, entries) = corpus(10, 18, 6);
        let mut cfg = config(9.0);
        cfg.probe_every = 2;
        let sharding = ShardingConfig { shards: 3, threads: 2, ..Default::default() };
        let mut sc = ShardedCorpus::new(&m, entries, 4, cfg, sharding).unwrap();
        let mut rng = seeded_rng(103);
        let q = Histogram::sample_uniform(10, &mut rng);
        let (_, first) = sc.search(&q, 4).unwrap();
        assert!(first.probe.is_none(), "first query is not probed");
        let (_, second) = sc.search(&q, 4).unwrap();
        let probe = second.probe.expect("second query must probe");
        assert_eq!(probe.k, 4, "probe compares the merged k, not one shard's");
        assert_eq!(probe.matched, probe.k, "merged pruning must be exact");
    }

    #[test]
    fn mutation_cycle_preserves_merge_exactness() {
        let (mut sc, _m, _entries) = sharded(10, 20, 7, 3);
        let mut rng = seeded_rng(104);
        let q = Histogram::sample_uniform(10, &mut rng);
        // Insert a duplicate of the query: it must win the merged top-1.
        let dup = sc.insert(q.clone()).unwrap();
        let (hits, _) = sc.search(&q, 3).unwrap();
        assert!(hits.iter().any(|h| h.entry == dup));
        // Tombstone it and a few originals, compact, and the merged
        // pruned result must still match the merged brute force.
        assert!(sc.tombstone(dup));
        assert!(sc.tombstone(2));
        assert!(sc.tombstone(11));
        sc.compact();
        let brute = sc.brute_force(&q, 5).unwrap();
        let (hits, report) = sc.search(&q, 5).unwrap();
        assert_eq!(report.corpus, 18);
        assert!(hits.iter().all(|h| h.entry != dup && h.entry != 2 && h.entry != 11));
        if let Err(v) = super::super::topk_equivalent(&hits, &brute, 1e-7) {
            panic!("post-mutation merge diverged: {v}");
        }
    }

    #[test]
    fn inserts_spread_by_occupied_slots_not_live_count() {
        // Regression: routing inserts by live count funneled every
        // insert into a heavily tombstoned shard sitting just under the
        // compact threshold — it absorbed the whole write load and then
        // compacted while hottest. Occupied slots (live + tombstoned)
        // must drive the routing instead.
        let (m, entries) = corpus(8, 12, 9);
        let sharding = ShardingConfig {
            shards: 3,
            compact_threshold: 0.9, // keep tombstones resident
            ..Default::default()
        };
        let mut sc =
            ShardedCorpus::new(&m, entries, 2, config(9.0), sharding).unwrap();
        // Shard 0 owns ids 0..4; tombstone three of them. Live is now
        // [1, 4, 4] but every shard still occupies 4 slots.
        for id in 0..3 {
            assert!(sc.tombstone(id));
        }
        let mut rng = seeded_rng(109);
        for _ in 0..6 {
            sc.insert(Histogram::sample_uniform(8, &mut rng)).unwrap();
        }
        let gauges = sc.gauges();
        assert_eq!(
            gauges.iter().map(|g| g.inserts).collect::<Vec<_>>(),
            vec![2, 2, 2],
            "tombstoned slots must count toward the routing load: {gauges:?}"
        );
        // After the deferred compaction the partition reflects the even
        // insert spread — no shard hoarded the write load.
        sc.compact();
        assert_eq!(
            sc.gauges().iter().map(|g| g.entries).collect::<Vec<_>>(),
            vec![3, 6, 6]
        );
    }

    #[test]
    fn shard_panic_is_contained_to_the_request() {
        // Scoped-worker path (threads = 2 over 3 shards).
        let (mut sc, _m, _entries) = sharded(10, 18, 10, 3);
        let mut rng = seeded_rng(110);
        let q = Histogram::sample_uniform(10, &mut rng);
        let (want, _) = sc.search(&q, 4).unwrap();
        sc.poison_shard(1);
        assert_eq!(
            sc.search(&q, 4).unwrap_err(),
            RetrievalError::ShardPanicked { shard: 1 },
            "the poisoned request must fail with the shard attributed"
        );
        // The corpus keeps serving — and serving correctly — afterward.
        let (got, _) = sc.search(&q, 4).unwrap();
        if let Err(v) = super::super::topk_equivalent(&got, &want, 1e-7) {
            panic!("post-panic search diverged: {v}");
        }

        // Serial path (threads = 1) crosses the same boundary.
        let (m, entries) = corpus(10, 12, 11);
        let sharding = ShardingConfig { shards: 2, threads: 1, ..Default::default() };
        let mut serial =
            ShardedCorpus::new(&m, entries, 4, config(9.0), sharding).unwrap();
        serial.poison_shard(0);
        assert_eq!(
            serial.search(&q, 3).unwrap_err(),
            RetrievalError::ShardPanicked { shard: 0 }
        );
        assert!(serial.search(&q, 3).is_ok());
    }
}
