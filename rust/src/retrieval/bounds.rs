//! The admissible lower-bound cascade that prices corpus candidates.
//!
//! Every tier lower-bounds the *exact* transportation distance d_M, and
//! therefore the served entropic distance d_M^λ for **every** λ: the
//! dual-Sinkhorn divergence is the cost ⟨P^λ, M⟩ of a feasible plan, so
//! d_M ≤ d_M^λ. That single inequality is the cascade's admissibility
//! contract — a candidate whose bound exceeds the current k-th best
//! served distance can be discarded without a solve, and the pruned
//! top-k provably equals the brute-force top-k (locked down by
//! `rust/tests/retrieval_exactness.rs`).
//!
//! Tiers, cheapest first (all O(d) per candidate after the
//! [`CorpusIndex`] precomputation):
//!
//! 1. [`BoundTier::Mass`] — ½‖q − c‖₁ · min_{i≠j} m_ij: the TV
//!    discrepancy must move somewhere, and nowhere is cheaper than the
//!    smallest off-diagonal cost.
//! 2. [`BoundTier::Centroid`] — ‖Lᵀq − Lᵀc‖² − 2·jitter through the
//!    negative-type embedding of
//!    [`crate::sinkhorn::IndependenceKernel`] (Jensen: no coupling can
//!    beat the squared distance between embedded barycenters).
//!    Skipped when the metric does not factor.
//! 3. [`BoundTier::Projection`] — the max over anchor axes of the 1-D
//!    quantile-transport cost of the projected histograms
//!    ([`crate::ot::onedim::projection_lower_bound`], served from the
//!    index's cached sorted CDFs).
//!
//! The cascade evaluates tiers cheapest-first and keeps the running max;
//! the *pruning* decision against the k-th-best served distance lives in
//! [`super::RetrievalService`], which prices all candidates before any τ
//! exists and then sweeps them in ascending bound order.

use super::{CorpusIndex, QueryPrep};
use crate::simplex::Histogram;
use crate::F;

/// Which cascade tier produced (or decided) a bound.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BoundTier {
    /// Trivial TV × min-cost bound.
    Mass,
    /// Embedded-barycenter (Jensen) bound.
    Centroid,
    /// 1-D anchor-projection quantile-transport bound.
    Projection,
}

/// A priced candidate: the best (largest) admissible lower bound the
/// cascade reached and the tier that supplied it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BoundValue {
    /// max over the tiers — still a valid lower bound on the served
    /// d_M^λ.
    pub value: F,
    /// The tier achieving [`Self::value`].
    pub tier: BoundTier,
}

/// The tiered lower-bound evaluator. Stateless; one instance prices
/// every (query, candidate) pair of a retrieval service.
#[derive(Debug, Clone, Copy, Default)]
pub struct BoundCascade;

impl BoundCascade {
    pub fn new() -> Self {
        Self
    }

    /// Price candidate `entry` against the prepared query: the max over
    /// every available tier, with the tier that supplied it (per-tier
    /// prune attribution in the search report).
    pub fn evaluate(
        &self,
        index: &CorpusIndex,
        prep: &QueryPrep,
        query: &Histogram,
        entry: usize,
    ) -> BoundValue {
        let mut best = BoundValue {
            value: index.mass_bound(query, entry),
            tier: BoundTier::Mass,
        };
        if let Some(centroid) = index.centroid_bound(prep, entry) {
            if centroid > best.value {
                best = BoundValue { value: centroid, tier: BoundTier::Centroid };
            }
        }
        let projection = index.projection_bound(prep, entry);
        if projection > best.value {
            best = BoundValue { value: projection, tier: BoundTier::Projection };
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::RandomMetric;
    use crate::ot::EmdSolver;
    use crate::simplex::seeded_rng;
    use crate::sinkhorn::{SinkhornConfig, SinkhornEngine};

    #[test]
    fn prop_cascade_is_admissible_for_exact_and_entropic_distances() {
        let cascade = BoundCascade::new();
        for seed in 0..25u64 {
            let mut rng = seeded_rng(seed);
            let d = rng.range_usize(3, 20);
            let m = RandomMetric::new(d).sample(&mut rng);
            let entries: Vec<Histogram> =
                (0..6).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
            let index =
                CorpusIndex::from_histograms(&m, entries.clone(), 4).unwrap();
            let q = Histogram::sample_uniform(d, &mut rng);
            let prep = index.prepare(&q);
            let solver = EmdSolver::new(&m);
            for (e, c) in entries.iter().enumerate() {
                let bound = cascade.evaluate(&index, &prep, &q, e);
                let exact = solver.solve(&q, c).unwrap().cost;
                assert!(
                    bound.value <= exact + 1e-9,
                    "seed={seed} entry={e} tier={:?}: bound {} > d_M {exact}",
                    bound.tier,
                    bound.value
                );
                // λ enters only through d^λ ≥ d_M: the same bound must
                // stay below the served entropic distance at any λ.
                for &lambda in &[3.0, 30.0] {
                    let served = SinkhornEngine::with_config(
                        &m,
                        SinkhornConfig {
                            lambda,
                            tolerance: 1e-10,
                            max_iterations: 100_000,
                            ..Default::default()
                        },
                    )
                    .distance(&q, c)
                    .value;
                    assert!(
                        bound.value <= served + 1e-8,
                        "seed={seed} entry={e} λ={lambda}: bound {} > d^λ {served}",
                        bound.value
                    );
                }
            }
        }
    }

    #[test]
    fn evaluation_is_the_max_of_the_tiers() {
        let mut rng = seeded_rng(7);
        let d = 16;
        let m = RandomMetric::new(d).sample(&mut rng);
        let entries: Vec<Histogram> =
            (0..4).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let index = CorpusIndex::from_histograms(&m, entries.clone(), 4).unwrap();
        let q = Histogram::sample_uniform(d, &mut rng);
        let prep = index.prepare(&q);
        let cascade = BoundCascade::new();
        for e in 0..entries.len() {
            let full = cascade.evaluate(&index, &prep, &q, e);
            // The bound is the max of the individual tiers, and the
            // reported tier is the one achieving it.
            let mass = index.mass_bound(&q, e);
            let centroid = index.centroid_bound(&prep, e).unwrap_or(0.0);
            let projection = index.projection_bound(&prep, e);
            let max = mass.max(centroid).max(projection);
            assert!((full.value - max).abs() < 1e-15);
            let tier_value = match full.tier {
                BoundTier::Mass => mass,
                BoundTier::Centroid => centroid,
                BoundTier::Projection => projection,
            };
            assert!((tier_value - full.value).abs() < 1e-15);
            // A self-query prices to (numerically) zero at every tier.
            let self_prep = index.prepare(&entries[e]);
            let zero = cascade.evaluate(&index, &self_prep, &entries[e], e);
            assert!(zero.value < 1e-10, "self bound {}", zero.value);
        }
    }
}
