//! Mailbox-per-key dispatch: the scheduling layer under the retrieval
//! runtime (PR 8).
//!
//! The PR 5 runtime funnelled every registered corpus through one
//! `sinkhorn-retrieval` thread. That made mutations trivially race-free
//! — and made a compaction of corpus A stall every search of corpus B
//! for its full duration: cross-tenant head-of-line blocking. This
//! module keeps the part of that design that matters (strict FIFO *per
//! corpus*) and discards the part that doesn't (strict FIFO *across*
//! corpora).
//!
//! Mechanics, after the mailbox-per-actor model in fraktor-rs's
//! dispatcher (SNIPPETS.md Snippet 2):
//!
//! - Every key (corpus) owns a [`Mailbox`]: a FIFO queue of jobs plus
//!   the per-key actor state `S`. A mailbox is executed by **at most
//!   one** dispatcher thread at a time (`active` flag), so jobs within
//!   one corpus stay strictly serialized and never observe
//!   half-applied mutations — the PR 5 ordering contract, verbatim.
//! - A fixed pool of dispatcher threads (`sinkhorn-retrieval-{i}`)
//!   pulls *runnable mailboxes* (not jobs) from two shared run queues:
//!   a **fast lane** and a **bulk lane**, chosen by the lane of the job
//!   at the head of the mailbox's queue. Fast-lane mailboxes are
//!   always drained first, so a search of corpus B overtakes a queued
//!   compaction/registration of corpus A — but never reorders against
//!   anything in B's own mailbox.
//! - After running **one** job the worker re-evaluates the mailbox: if
//!   more jobs are queued it goes back to the lane matching its new
//!   head (tail-chaining would let one hot corpus starve the pool);
//!   otherwise it parks until the next submit.
//! - A panicking job is contained: the worker catches the unwind,
//!   drops the key's state (the corpus degrades to unregistered — no
//!   half-mutated index can be observed), reports through the panic
//!   hook, and keeps serving. The mailbox itself is never poisoned.
//!
//! Shutdown is drain-first: dropping the pool lets every queued job
//! run (promises made to callers are kept) before the workers exit.

use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// Mailbox key. The retrieval runtime uses the corpus id.
pub(crate) type Key = u32;

/// Which run queue a mailbox joins while its head job waits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Lane {
    /// Latency-sensitive (searches): drained before any bulk work.
    Fast,
    /// Throughput work (registration, mutation, compaction).
    Bulk,
}

/// A job that knows its scheduling lane.
pub(crate) trait MailboxJob: Send + 'static {
    fn lane(&self) -> Lane;
}

/// Lock a mutex, recovering the guard if a previous holder panicked.
/// The dispatcher contains job panics itself (dropping the actor
/// state), so data behind a poisoned lock is still consistent.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

struct MailboxInner<J, S> {
    queue: VecDeque<J>,
    /// Actor state; `None` until the first state-creating job runs (or
    /// after invalidation / panic containment).
    state: Option<S>,
    /// True while the mailbox sits in a run queue **or** is being
    /// executed — at most one of the two, never both.
    active: bool,
}

/// One key's FIFO queue plus its actor state.
pub(crate) struct Mailbox<J, S> {
    key: Key,
    inner: Mutex<MailboxInner<J, S>>,
}

struct RunQueues<J, S> {
    fast: VecDeque<Arc<Mailbox<J, S>>>,
    bulk: VecDeque<Arc<Mailbox<J, S>>>,
}

type Runner<J, S> = Arc<dyn Fn(Key, &mut Option<S>, J) + Send + Sync>;
type PanicHook = Arc<dyn Fn(Key) + Send + Sync>;

struct Shared<J, S> {
    ready: Mutex<RunQueues<J, S>>,
    available: Condvar,
    /// Every mailbox ever created. Tombstoned (state-less, empty)
    /// mailboxes stay registered — they are a few hundred bytes and
    /// keeping them makes submit/invalidate races impossible.
    registry: Mutex<HashMap<Key, Arc<Mailbox<J, S>>>>,
    shutdown: AtomicBool,
    /// Jobs accepted but not yet responded to, shared with the caller
    /// for queue-depth gauges. Incremented on enqueue; the runner is
    /// responsible for decrementing exactly once per job (the panic
    /// hook covers the unwound case).
    depth: Arc<AtomicUsize>,
    runner: Runner<J, S>,
    panicked: PanicHook,
}

/// Fixed pool of dispatcher threads executing mailboxes. Dropping the
/// pool drains every queued job, then joins the workers.
pub(crate) struct DispatcherPool<J: MailboxJob, S: Send + 'static> {
    shared: Arc<Shared<J, S>>,
    workers: Vec<JoinHandle<()>>,
}

impl<J: MailboxJob, S: Send + 'static> DispatcherPool<J, S> {
    /// Spawn `workers` dispatcher threads (clamped to ≥ 1). `runner`
    /// executes one job against its key's state; `panicked` is called
    /// with the key after a contained job panic (the state has already
    /// been dropped) and must settle the job's promise/accounting.
    pub(crate) fn new(
        workers: usize,
        depth: Arc<AtomicUsize>,
        runner: Runner<J, S>,
        panicked: PanicHook,
    ) -> Self {
        let shared = Arc::new(Shared {
            ready: Mutex::new(RunQueues { fast: VecDeque::new(), bulk: VecDeque::new() }),
            available: Condvar::new(),
            registry: Mutex::new(HashMap::new()),
            shutdown: AtomicBool::new(false),
            depth,
            runner,
            panicked,
        });
        let workers = (0..workers.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("sinkhorn-retrieval-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn retrieval dispatcher")
            })
            .collect();
        Self { shared, workers }
    }

    /// Enqueue `job` on `key`'s mailbox, creating the mailbox first
    /// when `create` is set. Without `create`, a key that has never
    /// been registered gets the job handed back (`Err`) so the caller
    /// can fail its promise inline — a key that *exists* but has no
    /// state accepts the job and lets the runner answer in FIFO order
    /// behind whatever registration or invalidation is queued ahead.
    pub(crate) fn submit(&self, key: Key, job: J, create: bool) -> Result<(), J> {
        let mailbox = {
            let mut registry = lock(&self.shared.registry);
            match registry.get(&key) {
                Some(mb) => Arc::clone(mb),
                None if create => {
                    let mb = Arc::new(Mailbox {
                        key,
                        inner: Mutex::new(MailboxInner {
                            queue: VecDeque::new(),
                            state: None,
                            active: false,
                        }),
                    });
                    registry.insert(key, Arc::clone(&mb));
                    mb
                }
                None => return Err(job),
            }
        };
        self.enqueue(&mailbox, job);
        Ok(())
    }

    /// Enqueue one job per existing mailbox (`make(key)`), in FIFO
    /// position behind whatever each mailbox already holds. Used for
    /// metric invalidation, where the per-corpus ordering contract
    /// requires queued-behind searches to fail *after* the drop, not
    /// before. Returns the number of mailboxes reached.
    pub(crate) fn broadcast(&self, make: impl Fn(Key) -> J) -> usize {
        let mailboxes: Vec<Arc<Mailbox<J, S>>> =
            lock(&self.shared.registry).values().map(Arc::clone).collect();
        for mb in &mailboxes {
            self.enqueue(mb, make(mb.key));
        }
        mailboxes.len()
    }

    /// Per-key queue depth and whether the key currently holds actor
    /// state, sorted by key. Depth counts queued jobs only (not the
    /// one being executed).
    pub(crate) fn depths(&self) -> Vec<(Key, usize, bool)> {
        let mailboxes: Vec<Arc<Mailbox<J, S>>> =
            lock(&self.shared.registry).values().map(Arc::clone).collect();
        let mut out: Vec<(Key, usize, bool)> = mailboxes
            .iter()
            .map(|mb| {
                let inner = lock(&mb.inner);
                (mb.key, inner.queue.len(), inner.state.is_some())
            })
            .collect();
        out.sort_unstable_by_key(|&(k, _, _)| k);
        out
    }

    /// Ready-lane backlogs `(fast, bulk)` — mailboxes runnable but not
    /// yet claimed by a dispatcher. Exposed for the health endpoint.
    pub(crate) fn lane_depths(&self) -> (usize, usize) {
        let ready = lock(&self.shared.ready);
        (ready.fast.len(), ready.bulk.len())
    }

    /// Number of dispatcher threads in the pool.
    pub(crate) fn workers(&self) -> usize {
        self.workers.len()
    }

    fn enqueue(&self, mailbox: &Arc<Mailbox<J, S>>, job: J) {
        self.shared.depth.fetch_add(1, Ordering::Relaxed);
        let schedule = {
            let mut inner = lock(&mailbox.inner);
            inner.queue.push_back(job);
            if inner.active {
                // Already in a lane or being executed; the owning
                // worker re-evaluates the queue when it finishes.
                None
            } else {
                inner.active = true;
                Some(inner.queue[0].lane())
            }
        };
        if let Some(lane) = schedule {
            push_ready(&self.shared, Arc::clone(mailbox), lane);
        }
    }
}

fn push_ready<J: MailboxJob, S>(shared: &Shared<J, S>, mailbox: Arc<Mailbox<J, S>>, lane: Lane) {
    {
        let mut ready = lock(&shared.ready);
        match lane {
            Lane::Fast => ready.fast.push_back(mailbox),
            Lane::Bulk => ready.bulk.push_back(mailbox),
        }
    }
    shared.available.notify_one();
}

fn worker_loop<J: MailboxJob, S>(shared: &Shared<J, S>) {
    loop {
        let mailbox = {
            let mut ready = lock(&shared.ready);
            loop {
                if let Some(mb) = ready.fast.pop_front().or_else(|| ready.bulk.pop_front()) {
                    break mb;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    // Both lanes are empty. Any mailbox not in a lane
                    // is either empty or owned by a live worker that
                    // will re-queue it, so there is nothing left for
                    // this worker to drain.
                    return;
                }
                ready = shared
                    .available
                    .wait(ready)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
            }
        };
        run_one(shared, &mailbox);
    }
}

/// Execute exactly one job from `mailbox`, then hand the mailbox back
/// to the lane matching its new head (or park it if empty).
fn run_one<J: MailboxJob, S>(shared: &Shared<J, S>, mailbox: &Arc<Mailbox<J, S>>) {
    // Take the job *and the state* out under the lock, run unlocked:
    // executing under the mailbox lock would block the engine thread's
    // non-blocking submits for the whole job. `active` stays set, so
    // no other worker can touch this mailbox meanwhile.
    let (job, mut state) = {
        let mut inner = lock(&mailbox.inner);
        debug_assert!(inner.active, "executing a mailbox that was never scheduled");
        match inner.queue.pop_front() {
            Some(job) => (job, inner.state.take()),
            None => {
                inner.active = false;
                return;
            }
        }
    };

    let outcome = catch_unwind(AssertUnwindSafe(|| {
        (shared.runner)(mailbox.key, &mut state, job);
    }));
    if outcome.is_err() {
        // Containment: the job's unwind must not take down the worker
        // or wedge the mailbox. The state may be half-mutated, so it
        // is dropped — the corpus degrades to unregistered — and the
        // hook settles the in-flight promise + depth accounting.
        state = None;
        (shared.panicked)(mailbox.key);
    }

    let next = {
        let mut inner = lock(&mailbox.inner);
        inner.state = state;
        match inner.queue.front() {
            Some(head) => Some(head.lane()),
            None => {
                inner.active = false;
                None
            }
        }
    };
    if let Some(lane) = next {
        push_ready(shared, Arc::clone(mailbox), lane);
    }
}

impl<J: MailboxJob, S: Send + 'static> Drop for DispatcherPool<J, S> {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.available.notify_all();
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::{channel, Receiver, Sender};
    use std::time::Duration;

    /// Toy job interpreted by [`toy_pool`]'s runner; state is a `u32`.
    enum Toy {
        /// Append `(key, tag)` to the shared log, then ack `tag`.
        Log { tag: u32, lane: Lane, log: Arc<Mutex<Vec<(Key, u32)>>>, ack: Sender<u32> },
        /// Signal `entered`, then block until `gate` drops/fires.
        Block { lane: Lane, entered: Sender<()>, gate: Receiver<()> },
        /// Install `value` as the mailbox's state.
        SetState(u32),
        /// Report the current state.
        Report(Sender<Option<u32>>),
        Panic,
    }

    impl MailboxJob for Toy {
        fn lane(&self) -> Lane {
            match self {
                Toy::Log { lane, .. } | Toy::Block { lane, .. } => *lane,
                Toy::SetState(_) | Toy::Report(_) | Toy::Panic => Lane::Bulk,
            }
        }
    }

    fn toy_pool(
        workers: usize,
    ) -> (DispatcherPool<Toy, u32>, Arc<AtomicUsize>, Arc<Mutex<Vec<Key>>>) {
        let depth = Arc::new(AtomicUsize::new(0));
        let panics: Arc<Mutex<Vec<Key>>> = Arc::new(Mutex::new(Vec::new()));
        let runner_depth = Arc::clone(&depth);
        let hook_depth = Arc::clone(&depth);
        let hook_panics = Arc::clone(&panics);
        let pool = DispatcherPool::new(
            workers,
            Arc::clone(&depth),
            Arc::new(move |key, state: &mut Option<u32>, job: Toy| {
                // Mirrors the real runtime's accounting: the runner
                // decrements once per completed job; a panicking job
                // never reaches its decrement and the hook covers it.
                if let Toy::Panic = job {
                    panic!("toy job panic");
                }
                runner_depth.fetch_sub(1, Ordering::Relaxed);
                match job {
                    Toy::Log { tag, log, ack, .. } => {
                        lock(&log).push((key, tag));
                        let _ = ack.send(tag);
                    }
                    Toy::Block { entered, gate, .. } => {
                        let _ = entered.send(());
                        let _ = gate.recv();
                    }
                    Toy::SetState(value) => *state = Some(value),
                    Toy::Report(tx) => {
                        let _ = tx.send(*state);
                    }
                    Toy::Panic => unreachable!(),
                }
            }),
            Arc::new(move |key| {
                hook_depth.fetch_sub(1, Ordering::Relaxed);
                lock(&hook_panics).push(key);
            }),
        );
        (pool, depth, panics)
    }

    fn log_job(tag: u32, lane: Lane, log: &Arc<Mutex<Vec<(Key, u32)>>>, ack: &Sender<u32>) -> Toy {
        Toy::Log { tag, lane, log: Arc::clone(log), ack: ack.clone() }
    }

    #[test]
    fn per_mailbox_fifo_and_cross_mailbox_concurrency() {
        let (pool, depth, _) = toy_pool(2);
        let log = Arc::new(Mutex::new(Vec::new()));
        let (ack_tx, ack_rx) = channel();
        let (entered_tx, entered_rx) = channel();
        let (gate_tx, gate_rx) = channel();

        // Occupy mailbox 0 with a blocking job, queue two more behind it.
        pool.submit(0, Toy::Block { lane: Lane::Bulk, entered: entered_tx, gate: gate_rx }, true)
            .unwrap_or_else(|_| panic!("submit"));
        entered_rx.recv().expect("block job started");
        for tag in [1, 2] {
            pool.submit(0, log_job(tag, Lane::Bulk, &log, &ack_tx), true)
                .unwrap_or_else(|_| panic!("submit"));
        }
        // Mailbox 7 must complete while mailbox 0 is still blocked:
        // that is exactly the cross-tenant isolation the pool exists for.
        pool.submit(7, log_job(70, Lane::Bulk, &log, &ack_tx), true)
            .unwrap_or_else(|_| panic!("submit"));
        assert_eq!(
            ack_rx.recv_timeout(Duration::from_secs(10)),
            Ok(70),
            "tenant 7 blocked behind tenant 0's in-flight job"
        );

        gate_tx.send(()).expect("release gate");
        assert_eq!(ack_rx.recv_timeout(Duration::from_secs(10)), Ok(1));
        assert_eq!(ack_rx.recv_timeout(Duration::from_secs(10)), Ok(2));
        let order: Vec<u32> =
            lock(&log).iter().filter(|(k, _)| *k == 0).map(|&(_, t)| t).collect();
        assert_eq!(order, vec![1, 2], "per-mailbox FIFO violated");
        drop(pool);
        assert_eq!(depth.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn fast_lane_overtakes_queued_bulk_work() {
        // One worker ⇒ scheduling order is fully deterministic once
        // the worker is pinned by the blocking job.
        let (pool, _, _) = toy_pool(1);
        let log = Arc::new(Mutex::new(Vec::new()));
        let (ack_tx, ack_rx) = channel();
        let (entered_tx, entered_rx) = channel();
        let (gate_tx, gate_rx) = channel();

        pool.submit(0, Toy::Block { lane: Lane::Bulk, entered: entered_tx, gate: gate_rx }, true)
            .unwrap_or_else(|_| panic!("submit"));
        entered_rx.recv().expect("block job started");
        // Bulk to tenant 1 first, then fast to tenant 2. With a single
        // serialized queue tag 1 would run first; lanes flip it.
        pool.submit(1, log_job(1, Lane::Bulk, &log, &ack_tx), true)
            .unwrap_or_else(|_| panic!("submit"));
        pool.submit(2, log_job(2, Lane::Fast, &log, &ack_tx), true)
            .unwrap_or_else(|_| panic!("submit"));
        gate_tx.send(()).expect("release gate");

        assert_eq!(ack_rx.recv_timeout(Duration::from_secs(10)), Ok(2), "fast lane did not overtake");
        assert_eq!(ack_rx.recv_timeout(Duration::from_secs(10)), Ok(1));
    }

    #[test]
    fn submit_without_create_rejects_unknown_keys() {
        let (pool, depth, _) = toy_pool(1);
        let (tx, _rx) = channel();
        let rejected = pool.submit(42, Toy::Report(tx), false);
        assert!(rejected.is_err(), "unknown key must hand the job back");
        assert_eq!(depth.load(Ordering::Relaxed), 0, "rejected job leaked depth");
    }

    #[test]
    fn panic_drops_state_but_not_the_worker_or_mailbox() {
        let (pool, depth, panics) = toy_pool(1);
        let (tx, rx) = channel();

        pool.submit(5, Toy::SetState(11), true).unwrap_or_else(|_| panic!("submit"));
        pool.submit(5, Toy::Report(tx.clone()), true).unwrap_or_else(|_| panic!("submit"));
        assert_eq!(rx.recv_timeout(Duration::from_secs(10)), Ok(Some(11)));

        pool.submit(5, Toy::Panic, true).unwrap_or_else(|_| panic!("submit"));
        // The same mailbox (and the single worker) must keep serving;
        // the state was dropped by containment.
        pool.submit(5, Toy::Report(tx), true).unwrap_or_else(|_| panic!("submit"));
        assert_eq!(rx.recv_timeout(Duration::from_secs(10)), Ok(None), "state survived a panic");
        assert_eq!(lock(&panics).as_slice(), &[5]);
        drop(pool);
        assert_eq!(depth.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn drop_drains_queued_jobs_and_broadcast_reaches_every_mailbox() {
        let (pool, depth, _) = toy_pool(2);
        let log = Arc::new(Mutex::new(Vec::new()));
        let (ack_tx, ack_rx) = channel();
        for key in 0..4u32 {
            for tag in 0..3u32 {
                pool.submit(key, log_job(key * 10 + tag, Lane::Bulk, &log, &ack_tx), true)
                    .unwrap_or_else(|_| panic!("submit"));
            }
        }
        let (state_tx, state_rx) = channel();
        assert_eq!(pool.broadcast(|_| Toy::Report(state_tx.clone())), 4);
        drop(state_tx);
        drop(pool); // must drain all 12 logs + 4 reports before joining
        assert_eq!(ack_rx.try_iter().count(), 12, "drop lost queued jobs");
        assert_eq!(state_rx.try_iter().count(), 4, "broadcast missed a mailbox");
        assert_eq!(depth.load(Ordering::Relaxed), 0);
        assert_eq!(lock(&log).len(), 12);
    }

    #[test]
    fn depths_reports_per_key_queue_and_state() {
        let (pool, _, _) = toy_pool(1);
        let (entered_tx, entered_rx) = channel();
        let (gate_tx, gate_rx) = channel();
        pool.submit(3, Toy::SetState(1), true).unwrap_or_else(|_| panic!("submit"));
        pool.submit(3, Toy::Block { lane: Lane::Bulk, entered: entered_tx, gate: gate_rx }, true)
            .unwrap_or_else(|_| panic!("submit"));
        entered_rx.recv().expect("block job started");
        // Worker pinned on key 3; these queue up unexecuted.
        pool.submit(3, Toy::SetState(2), true).unwrap_or_else(|_| panic!("submit"));
        pool.submit(9, Toy::SetState(3), true).unwrap_or_else(|_| panic!("submit"));
        let depths = pool.depths();
        assert_eq!(depths.len(), 2);
        // Key 3's state rides *with* the in-flight block job (taken out
        // of the mailbox for the run), so it reads state-less here.
        assert_eq!(depths[0], (3, 1, false), "key 3: one queued job, state in flight");
        assert_eq!(depths[1].0, 9);
        assert_eq!(depths[1].1, 1, "key 9: one queued job");
        gate_tx.send(()).expect("release gate");

        // Once everything settles (sync through a Report round trip),
        // both keys hold state and no jobs are queued.
        let (tx, rx) = channel();
        pool.submit(3, Toy::Report(tx), true).unwrap_or_else(|_| panic!("submit"));
        assert_eq!(rx.recv_timeout(Duration::from_secs(10)), Ok(Some(2)));
        let depths = pool.depths();
        assert_eq!(depths, vec![(3, 0, true), (9, 0, true)]);
    }
}
