//! ANN routing: k-means candidate generation over embedded-barycenter
//! coordinates.
//!
//! The bound cascade is exact but prices every live entry — O(n·d) per
//! query, linear per shard. This module adds the first deliberately
//! *inexact* stage in the stack: a small k-means router over the
//! `Lᵀr` coordinates each [`super::CorpusIndex`] already caches for the
//! centroid bound (the embedded barycenter of Cuturi §4's independence
//! kernel). At query time the router ranks centroids by squared
//! Euclidean distance to the query's own coordinates and unions the
//! member lists of the nearest few into a shortlist; the exact cascade
//! + panel refine then re-rank only that shortlist.
//!
//! Contract: the shortlist is approximate (entries outside it are never
//! priced), the re-rank is exact, and recall is audited end-to-end by
//! the existing `probe_every` recall probes, which price against the
//! *merged multi-shard* view. With routing disabled (the default) the
//! exact path is preserved bit-for-bit.
//!
//! Lifecycle: inserts are assigned to their nearest centroid
//! incrementally (O(centroids·anchors) per insert, no rebuild);
//! tombstones are honored at shortlist time (dead slots are filtered
//! and never count toward the shortlist floor); compaction rebuilds the
//! router from scratch over the surviving entries.

use crate::F;

/// Knobs for the per-shard ANN routing tier. Opt-in via
/// [`super::ShardingConfig::routing`] (or
/// [`super::RetrievalService::enable_routing`] on a monolithic
/// service); `None` keeps the exact every-live-entry walk.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoutingConfig {
    /// Number of k-means centroids per shard (clamped to the entry
    /// count at build).
    pub centroids: usize,
    /// How many nearest centroids seed the shortlist before the floor
    /// kicks in.
    pub probes: usize,
    /// Minimum live candidates in a shortlist: probing keeps expanding
    /// to further centroids until the union holds at least
    /// `max(k, min_shortlist)` live entries or every centroid has been
    /// consumed. Guards recall when clusters are small or heavily
    /// tombstoned.
    pub min_shortlist: usize,
    /// Lloyd iterations at build/rebuild time.
    pub iterations: usize,
}

impl Default for RoutingConfig {
    fn default() -> Self {
        Self { centroids: 16, probes: 2, min_shortlist: 32, iterations: 8 }
    }
}

impl RoutingConfig {
    /// Basic sanity: every knob must be at least 1.
    pub fn validate(&self) -> Result<(), String> {
        if self.centroids == 0 {
            return Err("routing.centroids must be >= 1".into());
        }
        if self.probes == 0 {
            return Err("routing.probes must be >= 1".into());
        }
        if self.iterations == 0 {
            return Err("routing.iterations must be >= 1".into());
        }
        Ok(())
    }
}

/// Squared Euclidean distance between two coordinate vectors.
fn dist2(a: &[F], b: &[F]) -> F {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// K-means router over per-entry coordinate vectors. Slots are the
/// service's local entry slots; the caller maps them to global ids and
/// filters tombstones through the `dead` predicate at shortlist time.
#[derive(Debug, Clone)]
pub(crate) struct Router {
    config: RoutingConfig,
    /// Coordinate dimensionality (the index's anchor count).
    dim: usize,
    /// `k · dim` row-major centroid matrix, `k ≤ config.centroids`.
    centroids: Vec<F>,
    /// Slot → centroid assignment (parallel to the index's slots).
    assign: Vec<usize>,
    /// Centroid → member slots, in ascending slot order.
    members: Vec<Vec<usize>>,
}

impl Router {
    /// Build a router over `points[slot]` coordinate rows. Returns
    /// `None` on an empty corpus or zero-dimensional coordinates
    /// (nothing to route on). Deterministic: evenly spaced seeds, then
    /// `config.iterations` Lloyd rounds (an emptied cluster keeps its
    /// previous centroid).
    pub(crate) fn build(config: RoutingConfig, points: &[Vec<F>]) -> Option<Self> {
        let n = points.len();
        if n == 0 {
            return None;
        }
        let dim = points[0].len();
        if dim == 0 {
            return None;
        }
        let k = config.centroids.min(n).max(1);
        // Evenly spaced seeds over the slot range — deterministic and,
        // for cluster-major corpora, already close to one seed per
        // cluster.
        let mut centroids = vec![0.0; k * dim];
        for c in 0..k {
            let seed = c * n / k;
            centroids[c * dim..(c + 1) * dim].copy_from_slice(&points[seed]);
        }
        let mut assign = vec![0usize; n];
        for _ in 0..config.iterations {
            for (slot, p) in points.iter().enumerate() {
                assign[slot] = nearest(&centroids, dim, p);
            }
            let mut sums = vec![0.0; k * dim];
            let mut counts = vec![0usize; k];
            for (slot, p) in points.iter().enumerate() {
                let c = assign[slot];
                counts[c] += 1;
                for (s, &x) in sums[c * dim..(c + 1) * dim].iter_mut().zip(p) {
                    *s += x;
                }
            }
            for c in 0..k {
                if counts[c] == 0 {
                    continue; // emptied cluster keeps its centroid
                }
                let inv = 1.0 / counts[c] as F;
                for (out, &s) in centroids[c * dim..(c + 1) * dim]
                    .iter_mut()
                    .zip(&sums[c * dim..(c + 1) * dim])
                {
                    *out = s * inv;
                }
            }
        }
        // Final assignment against the settled centroids.
        let mut members = vec![Vec::new(); k];
        for (slot, p) in points.iter().enumerate() {
            let c = nearest(&centroids, dim, p);
            assign[slot] = c;
            members[c].push(slot);
        }
        Some(Self { config, dim, centroids, assign, members })
    }

    /// Assign a freshly inserted slot to its nearest centroid. Slots
    /// must arrive in order (`slot == self.assign.len()`), matching the
    /// index's append-only slot allocation.
    pub(crate) fn insert(&mut self, slot: usize, point: &[F]) {
        debug_assert_eq!(slot, self.assign.len(), "router slots are append-only");
        debug_assert_eq!(point.len(), self.dim);
        let c = nearest(&self.centroids, self.dim, point);
        self.assign.push(c);
        self.members[c].push(slot);
    }

    /// Candidate shortlist for a query at `point`: the union of the
    /// member lists of the nearest centroids, tombstone-filtered via
    /// `dead`, expanded one centroid at a time past `config.probes`
    /// until at least `max(k, config.min_shortlist)` live candidates
    /// are gathered or every centroid is consumed. Returned in
    /// ascending slot order — the same order the exact path walks.
    pub(crate) fn shortlist(
        &self,
        point: &[F],
        k: usize,
        dead: impl Fn(usize) -> bool,
    ) -> Vec<usize> {
        let want = k.max(self.config.min_shortlist);
        let mut order: Vec<usize> = (0..self.members.len()).collect();
        order.sort_by(|&a, &b| {
            let da = dist2(&self.centroids[a * self.dim..(a + 1) * self.dim], point);
            let db = dist2(&self.centroids[b * self.dim..(b + 1) * self.dim], point);
            da.total_cmp(&db).then(a.cmp(&b))
        });
        let mut out = Vec::new();
        for (rank, &c) in order.iter().enumerate() {
            if rank >= self.config.probes && out.len() >= want {
                break;
            }
            out.extend(self.members[c].iter().copied().filter(|&s| !dead(s)));
        }
        out.sort_unstable();
        out
    }

    /// Number of centroids actually in use (≤ `config.centroids`).
    #[cfg(test)]
    pub(crate) fn centroid_count(&self) -> usize {
        self.members.len()
    }
}

/// Index of the centroid nearest to `p` (ties to the lowest index).
fn nearest(centroids: &[F], dim: usize, p: &[F]) -> usize {
    let k = centroids.len() / dim;
    let mut best = 0;
    let mut best_d = F::INFINITY;
    for c in 0..k {
        let d = dist2(&centroids[c * dim..(c + 1) * dim], p);
        if d < best_d {
            best_d = d;
            best = c;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two tight 2-D clusters around (0,0) and (10,10).
    fn two_clusters() -> Vec<Vec<F>> {
        let mut pts = Vec::new();
        for i in 0..8 {
            let eps = i as F * 0.01;
            pts.push(vec![eps, -eps]);
        }
        for i in 0..8 {
            let eps = i as F * 0.01;
            pts.push(vec![10.0 + eps, 10.0 - eps]);
        }
        pts
    }

    fn config(centroids: usize, probes: usize, min_shortlist: usize) -> RoutingConfig {
        RoutingConfig { centroids, probes, min_shortlist, iterations: 8 }
    }

    #[test]
    fn build_recovers_separated_clusters() {
        let pts = two_clusters();
        let r = Router::build(config(2, 1, 1), &pts).expect("router builds");
        assert_eq!(r.centroid_count(), 2);
        // Every point in cluster 0 shares one assignment, cluster 1 the
        // other, and they differ.
        let a0 = r.assign[0];
        assert!(r.assign[..8].iter().all(|&c| c == a0));
        let a1 = r.assign[8];
        assert!(r.assign[8..].iter().all(|&c| c == a1));
        assert_ne!(a0, a1);
    }

    #[test]
    fn shortlist_probes_nearest_cluster_and_skips_dead_slots() {
        let pts = two_clusters();
        let r = Router::build(config(2, 1, 1), &pts).expect("router builds");
        let near_origin = r.shortlist(&[0.5, 0.5], 1, |_| false);
        assert_eq!(near_origin, (0..8).collect::<Vec<_>>());
        let dead = [0usize, 3];
        let filtered = r.shortlist(&[0.5, 0.5], 1, |s| dead.contains(&s));
        assert_eq!(filtered, vec![1, 2, 4, 5, 6, 7]);
    }

    #[test]
    fn shortlist_expands_past_probes_to_meet_the_floor() {
        let pts = two_clusters();
        // probes=1 but the floor (12) exceeds one cluster's 8 members:
        // the second centroid must be consumed too.
        let r = Router::build(config(2, 1, 12), &pts).expect("router builds");
        let all = r.shortlist(&[0.0, 0.0], 1, |_| false);
        assert_eq!(all, (0..16).collect::<Vec<_>>());
        // With the floor satisfied by one cluster, the far cluster is
        // never touched.
        let r = Router::build(config(2, 1, 4), &pts).expect("router builds");
        let near = r.shortlist(&[0.0, 0.0], 1, |_| false);
        assert_eq!(near, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn insert_assigns_to_the_nearest_centroid_incrementally() {
        let pts = two_clusters();
        let mut r = Router::build(config(2, 1, 1), &pts).expect("router builds");
        let far_cluster = r.assign[8];
        r.insert(16, &[9.7, 10.2]);
        assert_eq!(r.assign[16], far_cluster);
        let near_far = r.shortlist(&[10.0, 10.0], 1, |_| false);
        assert!(near_far.contains(&16));
    }

    #[test]
    fn empty_or_zero_dim_coordinates_disable_routing() {
        assert!(Router::build(RoutingConfig::default(), &[]).is_none());
        assert!(Router::build(RoutingConfig::default(), &[vec![], vec![]]).is_none());
    }

    #[test]
    fn validate_rejects_zero_knobs() {
        assert!(RoutingConfig::default().validate().is_ok());
        assert!(RoutingConfig { centroids: 0, ..Default::default() }.validate().is_err());
        assert!(RoutingConfig { probes: 0, ..Default::default() }.validate().is_err());
        assert!(RoutingConfig { iterations: 0, ..Default::default() }.validate().is_err());
    }
}
